"""Regenerate rust/tests/golden_data/qdq_golden.json from the python quant
oracle (compile.kernels.ref), the cross-language single source of truth.

f32 values are stored as u32 bit patterns so the JSON round-trip is exactly
lossless; rust/tests/golden.rs reassembles them with f32::from_bits and
asserts bit-for-bit equality against rust quant::qdq.

Run from the repo root:  python3 python/tools/gen_goldens.py
"""

import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

import jax
import jax.numpy as jnp
import numpy as np

from compile.kernels import ref as K

ROWS, COLS = 32, 24


def bits(arr):
    return np.asarray(arr, np.float32).reshape(-1).view(np.uint32).tolist()


def main():
    i = np.arange(ROWS)[:, None]
    j = np.arange(COLS)[None, :]
    # exact small rationals so rust regenerates the grid bit-identically:
    # x[i,j] = ((31*i + 17*j) mod 257 - 128) / 16
    x = (((31 * i + 17 * j) % 257 - 128) / 16.0).astype(np.float32)
    xp = (np.abs(x) + 0.25).astype(np.float32)  # post-GELU-like positive input

    cases = []
    for gran, short in [
        ("per_tensor", "pt"),
        ("per_token", "ptok"),
        ("per_channel", "pc"),
    ]:
        for b in (2, 4, 8):
            out = K.qdq(jnp.asarray(x), K.bits_to_qmax(b), gran)
            cases.append(
                {"name": f"qdq_{short}_b{b}", "gran": gran, "asym": False,
                 "bits": b, "input": "input", "out_bits": bits(out)}
            )
    for b in (2, 4, 8):
        out = K.qdq(jnp.asarray(x), K.bits_to_qmax(b), "per_token", asymmetric=True)
        cases.append(
            {"name": f"qdq_ptok_asym_b{b}", "gran": "per_token", "asym": True,
             "bits": b, "input": "input", "out_bits": bits(out)}
        )
    for b in (4, 8):
        out = K.qdq(jnp.asarray(xp), K.bits_to_qmax(b), "per_token", asymmetric=True)
        cases.append(
            {"name": f"qdq_pos_ptok_asym_b{b}", "gran": "per_token", "asym": True,
             "bits": b, "input": "input_pos", "out_bits": bits(out)}
        )

    doc = {
        "comment": "Golden fake-quant vectors from python/compile/kernels/ref.py "
        f"(jax {jax.__version__}). f32 values stored as u32 bit patterns. "
        "Regenerate: python3 python/tools/gen_goldens.py",
        "rows": ROWS,
        "cols": COLS,
        "input_bits": bits(x),
        "input_pos_bits": bits(xp),
        "cases": cases,
    }
    out_path = os.path.join(
        os.path.dirname(__file__), "..", "..", "rust", "tests", "golden_data",
        "qdq_golden.json",
    )
    with open(os.path.normpath(out_path), "w") as f:
        json.dump(doc, f)
    print(f"wrote {len(cases)} cases -> {out_path}")


if __name__ == "__main__":
    main()
