"""L2: GPT-2 style transformer LM in JAX with fake quantization injected at
every linear layer, per the paper's Fig. 1.

The model is pre-LN GPT-2 (causal self-attention, GELU MLP, learned
positional embeddings, tied input/output embeddings). Quantization error is
injected at the four block linears (QKV, attention out-proj, FC1, FC2) via
`quantizer.make_qlinear`; the embedding / LM head matmuls are not quantized
(the paper targets "linear layer components" of the blocks).

Layer parameters are stacked with a leading `n_layer` axis and the blocks
run under `jax.lax.scan`, keeping the lowered HLO size independent of depth.
A separate *unrolled* forward (`forward_probed`) exposes a chosen layer's
attention-out-proj input and FC2 input for the paper's outlier analyses
(Figs. 6, 8) — it is only lowered for the tiny probe artifacts.
"""

from typing import Dict, List, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from .configs import ModelCfg
from .quantizer import QuantConfig, make_qlinear


class ParamDef(NamedTuple):
    name: str
    shape: Tuple[int, ...]
    stacked: bool  # leading n_layer axis
    decay: bool  # weight decay applies (2D linear weights only)
    init: str  # "normal:<std>" | "zeros" | "ones" | "residual"


def param_defs(cfg: ModelCfg) -> List[ParamDef]:
    """Canonical, ordered parameter layout. This order IS the artifact input
    order; rust reproduces it from the manifest."""
    L, d, V, T, f = cfg.n_layer, cfg.d_model, cfg.vocab, cfg.seq, cfg.d_ff
    return [
        ParamDef("wte", (V, d), False, True, "normal:0.02"),
        ParamDef("wpe", (T, d), False, True, "normal:0.01"),
        ParamDef("ln1_w", (L, d), True, False, "ones"),
        ParamDef("ln1_b", (L, d), True, False, "zeros"),
        ParamDef("qkv_w", (L, d, 3 * d), True, True, "normal:0.02"),
        ParamDef("qkv_b", (L, 3 * d), True, False, "zeros"),
        ParamDef("proj_w", (L, d, d), True, True, "residual"),
        ParamDef("proj_b", (L, d), True, False, "zeros"),
        ParamDef("ln2_w", (L, d), True, False, "ones"),
        ParamDef("ln2_b", (L, d), True, False, "zeros"),
        ParamDef("fc1_w", (L, d, f), True, True, "normal:0.02"),
        ParamDef("fc1_b", (L, f), True, False, "zeros"),
        ParamDef("fc2_w", (L, f, d), True, True, "residual"),
        ParamDef("fc2_b", (L, d), True, False, "zeros"),
        ParamDef("lnf_w", (d,), False, False, "ones"),
        ParamDef("lnf_b", (d,), False, False, "zeros"),
    ]


PARAM_NAMES = [d.name for d in param_defs(ModelCfg("x", 1, 4, 1, 8, 8, 1))]

LAYER_KEYS = [
    "ln1_w", "ln1_b", "qkv_w", "qkv_b", "proj_w", "proj_b",
    "ln2_w", "ln2_b", "fc1_w", "fc1_b", "fc2_w", "fc2_b",
]


def _layer_norm(x, w, b, eps=1e-5):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + eps) * w + b


class QMax(NamedTuple):
    """Runtime quantization ranges (qmax = 2^(b-1)-1), one per component."""

    w: jnp.ndarray
    a: jnp.ndarray
    g: jnp.ndarray

    @staticmethod
    def ones():
        one = jnp.ones((), jnp.float32)
        return QMax(one, one, one)


def _block(h, lp: Dict[str, jnp.ndarray], cfg: ModelCfg, qlinear, qmax: QMax,
           collect: bool = False):
    """One transformer block. Returns (h_out, probes-or-None)."""
    B, T, d = h.shape
    nh, hd = cfg.n_head, cfg.d_head

    def lin(x2d, wname, bname):
        y = qlinear(x2d, lp[wname], qmax.w, qmax.a, qmax.g)
        return y + lp[bname]

    # --- attention ---
    a_in = _layer_norm(h, lp["ln1_w"], lp["ln1_b"])
    qkv = lin(a_in.reshape(B * T, d), "qkv_w", "qkv_b").reshape(B, T, 3 * d)
    q, k, v = jnp.split(qkv, 3, axis=-1)
    q = q.reshape(B, T, nh, hd).transpose(0, 2, 1, 3)
    k = k.reshape(B, T, nh, hd).transpose(0, 2, 1, 3)
    v = v.reshape(B, T, nh, hd).transpose(0, 2, 1, 3)
    att = jnp.einsum("bhqd,bhkd->bhqk", q, k) / jnp.sqrt(float(hd))
    mask = jnp.tril(jnp.ones((T, T), bool))
    att = jnp.where(mask, att, -1e30)
    att = jax.nn.softmax(att, axis=-1)
    ctx = jnp.einsum("bhqk,bhkd->bhqd", att, v)
    ctx = ctx.transpose(0, 2, 1, 3).reshape(B, T, d)  # out-proj INPUT (Fig. 6)
    h = h + lin(ctx.reshape(B * T, d), "proj_w", "proj_b").reshape(B, T, d)

    # --- MLP ---
    m_in = _layer_norm(h, lp["ln2_w"], lp["ln2_b"])
    hid = lin(m_in.reshape(B * T, d), "fc1_w", "fc1_b")
    hid = jax.nn.gelu(hid, approximate=True)  # fc2 INPUT (Fig. 8 outliers)
    h = h + lin(hid, "fc2_w", "fc2_b").reshape(B, T, d)

    probes = (ctx, hid.reshape(B, T, cfg.d_ff)) if collect else None
    return h, probes


def _block_with_ctx_delta(h, lp, cfg: ModelCfg, qlinear, qmax: QMax,
                          ctx_delta: Optional[jnp.ndarray]):
    """Block variant that adds `ctx_delta` to the attention out-proj input.

    Differentiating the loss wrt a zero `ctx_delta` yields the activation
    gradient at that point (paper Fig. 10's dL/d(attn-out input)).
    """
    B, T, d = h.shape
    nh, hd = cfg.n_head, cfg.d_head

    def lin(x2d, wname, bname):
        return qlinear(x2d, lp[wname], qmax.w, qmax.a, qmax.g) + lp[bname]

    a_in = _layer_norm(h, lp["ln1_w"], lp["ln1_b"])
    qkv = lin(a_in.reshape(B * T, d), "qkv_w", "qkv_b").reshape(B, T, 3 * d)
    q, k, v = jnp.split(qkv, 3, axis=-1)
    q = q.reshape(B, T, nh, hd).transpose(0, 2, 1, 3)
    k = k.reshape(B, T, nh, hd).transpose(0, 2, 1, 3)
    v = v.reshape(B, T, nh, hd).transpose(0, 2, 1, 3)
    att = jnp.einsum("bhqd,bhkd->bhqk", q, k) / jnp.sqrt(float(hd))
    mask = jnp.tril(jnp.ones((T, T), bool))
    att = jax.nn.softmax(jnp.where(mask, att, -1e30), axis=-1)
    ctx = jnp.einsum("bhqk,bhkd->bhqd", att, v).transpose(0, 2, 1, 3).reshape(B, T, d)
    if ctx_delta is not None:
        ctx = ctx + ctx_delta
    h = h + lin(ctx.reshape(B * T, d), "proj_w", "proj_b").reshape(B, T, d)

    m_in = _layer_norm(h, lp["ln2_w"], lp["ln2_b"])
    hid = jax.nn.gelu(lin(m_in.reshape(B * T, d), "fc1_w", "fc1_b"), approximate=True)
    h = h + lin(hid, "fc2_w", "fc2_b").reshape(B, T, d)
    return h, None


def forward(params: Dict[str, jnp.ndarray], x: jnp.ndarray, cfg: ModelCfg,
            qcfg: QuantConfig, qmax: QMax) -> jnp.ndarray:
    """Scan-based forward pass. x: (B, T) int32 tokens -> (B, T, V) logits."""
    qlinear = make_qlinear(qcfg)
    B, T = x.shape
    h = params["wte"][x] + params["wpe"][None, :T, :]

    stacked = {k: params[k] for k in LAYER_KEYS}

    def body(h, lp):
        h, _ = _block(h, lp, cfg, qlinear, qmax)
        return h, None

    h, _ = jax.lax.scan(body, h, stacked)
    h = _layer_norm(h, params["lnf_w"], params["lnf_b"])
    return h @ params["wte"].T  # tied LM head (not quantized)


def forward_probed(params, x, cfg: ModelCfg, qcfg: QuantConfig, qmax: QMax,
                   probe_layer: int):
    """Unrolled forward that also returns (attn out-proj input, fc2 input)
    of `probe_layer` — the tensors the paper's Figs. 6/8 histogram."""
    qlinear = make_qlinear(qcfg)
    B, T = x.shape
    h = params["wte"][x] + params["wpe"][None, :T, :]
    probes: Optional[Tuple] = None
    for l in range(cfg.n_layer):
        lp = {k: params[k][l] for k in LAYER_KEYS}
        h, p = _block(h, lp, cfg, qlinear, qmax, collect=(l == probe_layer))
        if p is not None:
            probes = p
    h = _layer_norm(h, params["lnf_w"], params["lnf_b"])
    logits = h @ params["wte"].T
    assert probes is not None
    return logits, probes


def nll(logits: jnp.ndarray, y: jnp.ndarray) -> jnp.ndarray:
    """Per-position negative log-likelihood, shape (B, T)."""
    logp = jax.nn.log_softmax(logits, axis=-1)
    return -jnp.take_along_axis(logp, y[..., None], axis=-1)[..., 0]


def loss_fn(params, x, y, cfg, qcfg, qmax: QMax):
    """Mean next-token cross-entropy."""
    logits = forward(params, x, cfg, qcfg, qmax)
    return jnp.mean(nll(logits, y))
