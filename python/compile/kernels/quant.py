"""L1 Pallas fake-quantization kernels.

TPU-oriented expression of the paper's Eq. 1 (see `ref.py` for the oracle):
the (tokens x channels) operand is tiled into VMEM-sized blocks; the scale
reduction (abs-max, or min/max for the asymmetric scheme) happens in-register
on the block the elementwise round/clip/rescale is applied to, so the fake
quantization costs no extra HBM traffic.

All kernels run with `interpret=True`: this environment executes on the CPU
PJRT client, and real-TPU Pallas lowering emits Mosaic custom-calls that the
CPU plugin cannot run. `interpret=True` lowers to plain HLO, which both the
python tests and the rust runtime execute. Real-TPU VMEM/MXU estimates for
these BlockSpecs are recorded in DESIGN.md §Perf.

Tiling strategy per granularity (input reshaped to 2D (M, N)):
  per_token   — grid over row blocks, block (bm, N): a scale needs the whole
                row, so the row (token) lives in one block; bm rows at a time.
  per_channel — grid over column blocks, block (M, bn): whole column in VMEM.
  per_tensor  — two stages: a grid-accumulated abs-max reduction into a (1,1)
                output, then an elementwise kernel taking the scale as input.

Block sizes prefer the TPU-native 128 lanes and cap the sublane dimension at
512 rows; they always divide the input (AOT shapes are static).
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

INTERPRET = True


def _block(dim: int, target: int) -> int:
    """Largest divisor of `dim` that is <= target, preferring powers of two."""
    if dim <= target:
        return dim
    b = target
    while b > 1:
        if dim % b == 0:
            return b
        b //= 2
    return 1


def _as_2d(x):
    if x.ndim == 2:
        return x, None
    return x.reshape(-1, x.shape[-1]), x.shape


def _restore(y, shape):
    return y if shape is None else y.reshape(shape)


# ---------------------------------------------------------------------------
# per-token (row scales)
# ---------------------------------------------------------------------------


def _qdq_per_token_kernel(x_ref, qmax_ref, o_ref):
    x = x_ref[...]
    qmax = qmax_ref[0, 0]
    amax = jnp.max(jnp.abs(x), axis=1, keepdims=True)
    s = jnp.maximum(amax / qmax, ref.EPS)
    o_ref[...] = s * jnp.clip(jnp.round(x / s), -qmax - 1.0, qmax)


def _qdq_per_token_asym_kernel(x_ref, qmax_ref, o_ref):
    x = x_ref[...]
    qmax = qmax_ref[0, 0]
    n = -qmax - 1.0
    xmin = jnp.min(x, axis=1, keepdims=True)
    xmax = jnp.max(x, axis=1, keepdims=True)
    s = jnp.maximum((xmax - xmin) / (2.0 * qmax + 1.0), ref.EPS)
    z = jnp.round(xmin / s) - n
    x_int = jnp.clip(jnp.round(x / s) - z, n, qmax)
    o_ref[...] = s * (x_int + z)


def qdq_per_token(x, qmax, asymmetric: bool = False):
    """Fake-quantize with one (a)symmetric scale per row (token)."""
    x2, shape = _as_2d(x)
    m, n = x2.shape
    bm = _block(m, 512)
    qmax_arr = jnp.asarray(qmax, jnp.float32).reshape(1, 1)
    kernel = _qdq_per_token_asym_kernel if asymmetric else _qdq_per_token_kernel
    out = pl.pallas_call(
        kernel,
        grid=(m // bm,),
        in_specs=[
            pl.BlockSpec((bm, n), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bm, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, n), x2.dtype),
        interpret=INTERPRET,
    )(x2, qmax_arr)
    return _restore(out, shape)


# ---------------------------------------------------------------------------
# per-channel (column scales)
# ---------------------------------------------------------------------------


def _qdq_per_channel_kernel(x_ref, qmax_ref, o_ref):
    x = x_ref[...]
    qmax = qmax_ref[0, 0]
    amax = jnp.max(jnp.abs(x), axis=0, keepdims=True)
    s = jnp.maximum(amax / qmax, ref.EPS)
    o_ref[...] = s * jnp.clip(jnp.round(x / s), -qmax - 1.0, qmax)


def qdq_per_channel(x, qmax):
    """Fake-quantize with one symmetric scale per column (output channel)."""
    x2, shape = _as_2d(x)
    m, n = x2.shape
    bn = _block(n, 128)
    qmax_arr = jnp.asarray(qmax, jnp.float32).reshape(1, 1)
    out = pl.pallas_call(
        _qdq_per_channel_kernel,
        grid=(n // bn,),
        in_specs=[
            pl.BlockSpec((m, bn), lambda j: (0, j)),
            pl.BlockSpec((1, 1), lambda j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((m, bn), lambda j: (0, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), x2.dtype),
        interpret=INTERPRET,
    )(x2, qmax_arr)
    return _restore(out, shape)


# ---------------------------------------------------------------------------
# per-tensor (single scale; two-stage reduce + elementwise)
# ---------------------------------------------------------------------------


def _absmax_kernel(x_ref, o_ref):
    @pl.when(pl.program_id(0) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[0, 0] = jnp.maximum(o_ref[0, 0], jnp.max(jnp.abs(x_ref[...])))


def _qdq_elementwise_kernel(x_ref, s_ref, qmax_ref, o_ref):
    x = x_ref[...]
    s = jnp.maximum(s_ref[0, 0], ref.EPS)
    qmax = qmax_ref[0, 0]
    o_ref[...] = s * jnp.clip(jnp.round(x / s), -qmax - 1.0, qmax)


def qdq_per_tensor(x, qmax):
    """Fake-quantize with a single symmetric scale for the whole tensor."""
    x2, shape = _as_2d(x)
    m, n = x2.shape
    bm = _block(m, 512)
    qmax_arr = jnp.asarray(qmax, jnp.float32).reshape(1, 1)
    amax = pl.pallas_call(
        _absmax_kernel,
        grid=(m // bm,),
        in_specs=[pl.BlockSpec((bm, n), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((1, 1), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, 1), x2.dtype),
        interpret=INTERPRET,
    )(x2)
    s = amax / qmax_arr
    out = pl.pallas_call(
        _qdq_elementwise_kernel,
        grid=(m // bm,),
        in_specs=[
            pl.BlockSpec((bm, n), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bm, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, n), x2.dtype),
        interpret=INTERPRET,
    )(x2, s, qmax_arr)
    return _restore(out, shape)


# ---------------------------------------------------------------------------
# dispatch mirroring ref.qdq
# ---------------------------------------------------------------------------


def qdq(x, qmax, granularity: str, asymmetric: bool = False):
    """Pallas-backed fake quantization matching `ref.qdq` bit-for-bit.

    Asymmetric is implemented for per-token (the only asymmetric variant the
    paper studies: 4-bit per-token asymmetric activations); other asymmetric
    granularities fall back to the jnp oracle.
    """
    if granularity == "per_token":
        return qdq_per_token(x, qmax, asymmetric=asymmetric)
    if asymmetric:
        return ref.qdq_asym(x, qmax, granularity)
    if granularity == "per_channel":
        return qdq_per_channel(x, qmax)
    if granularity == "per_tensor":
        return qdq_per_tensor(x, qmax)
    raise ValueError(f"unknown granularity {granularity!r}")
