"""Pure-jnp oracle for the fake-quantization kernels (paper Eq. 1).

This module is the single source of truth for the quantization numerics.
Every Pallas kernel in `quant.py` / `qmatmul.py` and the rust `quant` module
must match these functions bit-for-bit (same rounding mode: jnp.round is
round-half-to-even, mirrored by rust `f32::round_ties_even`).

Paper (Chitsaz et al., EMNLP 2024 Findings), Eq. 1:

    X_int = clip(round(X / s) - z; N, P)
    X_hat = s * (X_int + z)

with N = -2^(b-1), P = 2^(b-1) - 1 (signed grid).

Symmetric scheme (default): s = max|X| / P, z = 0.
Asymmetric scheme:          s = (max X - min X) / (2^b - 1),
                            z = round(min X / s) - N,
so that min(X) maps to the integer N and max(X) to P.

The bit-width enters only through qmax = P = 2^(b-1) - 1, which is passed as
a *runtime scalar* so that a single lowered artifact serves every bit-width.

Granularity convention (shared with rust::quant::Granularity):
  per_tensor  — one scale for the whole tensor.
  per_token   — one scale per row: reduce the LAST axis only
                (activations/gradients of shape (..., tokens, channels)).
  per_channel — one scale per column: reduce ALL axes except the last
                (weights of shape (d_in, d_out): one scale per output
                channel; Adam moments likewise, the paper's "per-column").
"""

import jax.numpy as jnp

# Guard against zero scales (all-zero tensors quantize to zero).
EPS = 1e-12


def _axes(x, granularity: str):
    if granularity == "per_tensor":
        return tuple(range(x.ndim))
    if granularity == "per_token":
        return (x.ndim - 1,)
    if granularity == "per_channel":
        return tuple(range(x.ndim - 1))
    raise ValueError(f"unknown granularity {granularity!r}")


def quant_params_sym(x, qmax, granularity: str):
    """Return the scale `s` (broadcastable to x) for symmetric quantization."""
    axes = _axes(x, granularity)
    amax = jnp.max(jnp.abs(x), axis=axes, keepdims=True)
    s = amax / qmax
    return jnp.maximum(s, EPS)


def quant_params_asym(x, qmax, granularity: str):
    """Return (s, z) for asymmetric quantization. z is the paper's offset."""
    axes = _axes(x, granularity)
    xmin = jnp.min(x, axis=axes, keepdims=True)
    xmax = jnp.max(x, axis=axes, keepdims=True)
    n = -qmax - 1.0
    s = (xmax - xmin) / (2.0 * qmax + 1.0)
    s = jnp.maximum(s, EPS)
    z = jnp.round(xmin / s) - n
    return s, z


def qdq_sym(x, qmax, granularity: str):
    """Symmetric fake quantization (quantize -> dequantize), Eq. 1 with z=0."""
    s = quant_params_sym(x, qmax, granularity)
    n = -qmax - 1.0
    x_int = jnp.clip(jnp.round(x / s), n, qmax)
    return s * x_int


def qdq_asym(x, qmax, granularity: str):
    """Asymmetric fake quantization, Eq. 1 with the min-anchored offset z."""
    s, z = quant_params_asym(x, qmax, granularity)
    n = -qmax - 1.0
    x_int = jnp.clip(jnp.round(x / s) - z, n, qmax)
    return s * (x_int + z)


def qdq(x, qmax, granularity: str, asymmetric: bool = False):
    """Dispatching oracle used by tests and by the jnp backend."""
    if asymmetric:
        return qdq_asym(x, qmax, granularity)
    return qdq_sym(x, qmax, granularity)


def qmatmul_ref(x, w, qmax_a, qmax_w):
    """Oracle for the fused QDQ-matmul kernel.

    Activations are quantized per-token (row scales), weights per-channel
    (column scales) — the paper's recommended granularity pairing, and the
    one that folds into a GEMM epilogue on real hardware.
    """
    xq = qdq_sym(x, qmax_a, "per_token")
    wq = qdq_sym(w, qmax_w, "per_channel")
    return xq @ wq


def bits_to_qmax(bits: int) -> float:
    """qmax = 2^(b-1) - 1 for signed b-bit quantization."""
    return float(2 ** (bits - 1) - 1)
