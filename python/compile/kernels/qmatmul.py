"""L1 fused QDQ-matmul Pallas kernel.

Computes `qdq_per_token(x) @ qdq_per_channel(w)` — the paper's recommended
W8A8 granularity pairing — in a single kernel: each grid step loads an
(bm, K) activation tile and a (K, bn) weight tile into VMEM, quantizes both
in-register (the scales need full rows of x / full columns of w, so K is not
tiled), and feeds the MXU-shaped `jnp.dot`. On a real TPU the dequant
rescale folds into the GEMM epilogue; here it is expressed directly.

VMEM footprint per grid step: 4*(bm*K + K*bn + bm*bn) bytes; with the
default bm=256, bn=128 and K=768 this is ~1.0 MiB, comfortably inside the
~16 MiB VMEM budget while keeping the 128-lane layout. MXU utilization
estimate for these tiles is recorded in DESIGN.md §Perf.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref
from .quant import _block

INTERPRET = True


def _qmatmul_kernel(x_ref, w_ref, qa_ref, qw_ref, o_ref):
    x = x_ref[...]
    w = w_ref[...]
    qa = qa_ref[0, 0]
    qw = qw_ref[0, 0]

    # per-token (row) symmetric quantization of the activation tile
    sa = jnp.maximum(jnp.max(jnp.abs(x), axis=1, keepdims=True) / qa, ref.EPS)
    xq = sa * jnp.clip(jnp.round(x / sa), -qa - 1.0, qa)

    # per-channel (column) symmetric quantization of the weight tile
    sw = jnp.maximum(jnp.max(jnp.abs(w), axis=0, keepdims=True) / qw, ref.EPS)
    wq = sw * jnp.clip(jnp.round(w / sw), -qw - 1.0, qw)

    o_ref[...] = jnp.dot(xq, wq, preferred_element_type=jnp.float32)


def qmatmul(x, w, qmax_a, qmax_w, bm: int = 256, bn: int = 128):
    """Fused fake-quantized matmul: rows of x per-token, cols of w per-channel.

    x: (M, K) activations, w: (K, N) weights; returns (M, N) float32.
    Matches `ref.qmatmul_ref` bit-for-bit.
    """
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, f"contraction mismatch {k} vs {k2}"
    bm = _block(m, bm)
    bn = _block(n, bn)
    qa = jnp.asarray(qmax_a, jnp.float32).reshape(1, 1)
    qw = jnp.asarray(qmax_w, jnp.float32).reshape(1, 1)
    return pl.pallas_call(
        _qmatmul_kernel,
        grid=(m // bm, n // bn),
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, bn), lambda i, j: (0, j)),
            pl.BlockSpec((1, 1), lambda i, j: (0, 0)),
            pl.BlockSpec((1, 1), lambda i, j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=INTERPRET,
    )(x, w, qa, qw)
