# L1: Pallas fake-quantization kernels + pure-jnp oracle.
from . import qmatmul, quant, ref  # noqa: F401
