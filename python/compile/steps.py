"""Step functions lowered to AOT artifacts.

Each builder returns (fn, input_signature) where fn takes a *flat* argument
list in the canonical order recorded in the manifest:

  train_step:  params(16) + m(16) + v(16) + [x, y, lr, t,
               qmax_w, qmax_a, qmax_g, qmax_m1, qmax_m2]
            -> params'(16) + m'(16) + v'(16) + [loss, gnorm]

  eval_step:   params(16) + [x, y, mask, qmax_w, qmax_a]
            -> [mean_nll, per_pos_nll(B,T)]

  act_probe:   params(16) + [x, qmax_w, qmax_a]
            -> [attn out-proj input (B,T,d), fc2 input (B,T,4d)]

  grad_probe:  params(16) + [x, y, qmax_w, qmax_a, qmax_g]
            -> [d qkv_w (layer 0), d attn-out activation-grad (layer 0 ctx)]

The flat order is fixed by `model.param_defs`; qmax scalars make bit-width a
runtime knob (one artifact per granularity structure).
"""

from typing import Dict, List

import jax
import jax.numpy as jnp

from . import model as M
from .adam import adamw_update
from .configs import ModelCfg
from .model import QMax
from .quantizer import QuantConfig


def _unflatten(cfg: ModelCfg, flat: List[jnp.ndarray]) -> Dict[str, jnp.ndarray]:
    names = [d.name for d in M.param_defs(cfg)]
    assert len(flat) == len(names)
    return dict(zip(names, flat))


def _flatten(cfg: ModelCfg, tree: Dict[str, jnp.ndarray]) -> List[jnp.ndarray]:
    return [tree[d.name] for d in M.param_defs(cfg)]


def n_params_tensors(cfg: ModelCfg) -> int:
    return len(M.param_defs(cfg))


def make_train_step(cfg: ModelCfg, qcfg: QuantConfig):
    NP = n_params_tensors(cfg)

    def train_step(*args):
        params = _unflatten(cfg, list(args[:NP]))
        m = _unflatten(cfg, list(args[NP : 2 * NP]))
        v = _unflatten(cfg, list(args[2 * NP : 3 * NP]))
        x, y, lr, t, qmax_w, qmax_a, qmax_g, qmax_m1, qmax_m2 = args[3 * NP :]
        qmax = QMax(qmax_w, qmax_a, qmax_g)

        loss, grads = jax.value_and_grad(
            lambda p: M.loss_fn(p, x, y, cfg, qcfg, qmax)
        )(params)
        new_p, new_m, new_v, gnorm = adamw_update(
            cfg, qcfg, params, grads, m, v, lr, t, qmax_m1, qmax_m2
        )
        return tuple(
            _flatten(cfg, new_p) + _flatten(cfg, new_m) + _flatten(cfg, new_v)
            + [loss, gnorm]
        )

    return train_step


def make_eval_step(cfg: ModelCfg, qcfg: QuantConfig):
    NP = n_params_tensors(cfg)

    def eval_step(*args):
        params = _unflatten(cfg, list(args[:NP]))
        x, y, mask, qmax_w, qmax_a = args[NP:]
        qmax = QMax(qmax_w, qmax_a, jnp.ones((), jnp.float32))
        logits = M.forward(params, x, cfg, qcfg, qmax)
        per_pos = M.nll(logits, y)
        mean_nll = jnp.sum(per_pos * mask) / jnp.maximum(jnp.sum(mask), 1.0)
        return (mean_nll, per_pos)

    return eval_step


def make_act_probe(cfg: ModelCfg, qcfg: QuantConfig, probe_layer: int):
    NP = n_params_tensors(cfg)

    def act_probe(*args):
        params = _unflatten(cfg, list(args[:NP]))
        x, qmax_w, qmax_a = args[NP:]
        qmax = QMax(qmax_w, qmax_a, jnp.ones((), jnp.float32))
        _, (proj_in, fc2_in) = M.forward_probed(
            params, x, cfg, qcfg, qmax, probe_layer
        )
        return (proj_in, fc2_in)

    return act_probe


def make_grad_probe(cfg: ModelCfg, qcfg: QuantConfig):
    """Gradient snapshot for Fig. 10: the QKV weight gradient of layer 0 and
    the activation gradient flowing into layer 0's attention output."""
    NP = n_params_tensors(cfg)

    def grad_probe(*args):
        params = _unflatten(cfg, list(args[:NP]))
        x, y, qmax_w, qmax_a, qmax_g = args[NP:]
        qmax = QMax(qmax_w, qmax_a, qmax_g)

        grads = jax.grad(
            lambda p: jnp.mean(M.nll(M.forward(p, x, cfg, qcfg, qmax), y))
        )(params)
        dctx = _ctx_grad(params, x, y, cfg, qcfg, qmax)
        return (grads["qkv_w"][0], dctx)

    return grad_probe


def _ctx_grad(params, x, y, cfg, qcfg, qmax):
    """Gradient of the loss wrt layer-0's attention out-proj input, computed
    by splitting the forward at that tensor (additive zero injection)."""

    def f(ctx_delta):
        from .quantizer import make_qlinear

        qlinear = make_qlinear(qcfg)
        B, T = x.shape
        h = params["wte"][x] + params["wpe"][None, :T, :]
        for l in range(cfg.n_layer):
            lp = {k: params[k][l] for k in M.LAYER_KEYS}
            h, p = M._block_with_ctx_delta(
                h, lp, cfg, qlinear, qmax, ctx_delta if l == 0 else None
            )
        h = M._layer_norm(h, params["lnf_w"], params["lnf_b"])
        logits = h @ params["wte"].T
        return jnp.mean(M.nll(logits, y))

    B, T = x.shape
    zero = jnp.zeros((B, T, cfg.d_model), jnp.float32)
    return jax.grad(f)(zero)
