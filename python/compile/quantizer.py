"""Quantization configuration + STE plumbing for the L2 model graph.

Implements the paper's Fig. 1 placement of quantization error exactly:

  forward:  y = qdq_a(x) @ qdq_w(W)          (fake-quant both operands)
  backward: dW = qdq_a(x)^T @ qdq_g(g)       (output-grad quantized for the
                                              weight update only)
            dx = g @ qdq_w(W)^T              (REAL output gradient; the
                                              `quantize_act_grads` variant
                                              uses qdq_g(g) here instead and
                                              reproduces the paper's Fig. 10
                                              instability)

Weight updates use the straight-through estimator: the fake-quant ops are
opaque to autodiff (custom_vjp), so gradients flow to the *latent* fp32
weights as if quantization were identity — while the matmuls in both passes
see the quantized tensors, exactly as STE training does.

The bit-width is a runtime scalar (`qmax = 2^(b-1)-1`), so one lowered
artifact per *granularity structure* serves every bit-width.
"""

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from .kernels import quant as pallas_quant
from .kernels import ref


@dataclasses.dataclass(frozen=True)
class QuantSpec:
    """How to quantize one tensor class (weights, acts, grads, or a moment)."""

    granularity: str  # per_tensor | per_token | per_channel
    asymmetric: bool = False
    backend: str = "jnp"  # jnp | pallas

    def short(self) -> str:
        g = {"per_tensor": "pt", "per_token": "ptok", "per_channel": "pc"}[
            self.granularity
        ]
        a = "_asym" if self.asymmetric else ""
        b = "_pallas" if self.backend == "pallas" else ""
        return f"{g}{a}{b}"


@dataclasses.dataclass(frozen=True)
class QuantConfig:
    """Which model components are fake-quantized (the paper's §4 axes)."""

    weights: Optional[QuantSpec] = None
    acts: Optional[QuantSpec] = None
    grads: Optional[QuantSpec] = None
    quantize_act_grads: bool = False  # Fig. 10 variant: quantize dx path too
    m1: Optional[QuantSpec] = None  # Adam first moment
    m2: Optional[QuantSpec] = None  # Adam second moment

    def name(self) -> str:
        parts = []
        if self.weights:
            parts.append(f"w_{self.weights.short()}")
        if self.acts:
            parts.append(f"a_{self.acts.short()}")
        if self.grads:
            parts.append(f"g_{self.grads.short()}")
            if self.quantize_act_grads:
                parts.append("actgrad")
        if self.m1:
            parts.append(f"m1_{self.m1.short()}")
        if self.m2:
            parts.append(f"m2_{self.m2.short()}")
        return "_".join(parts) if parts else "base"


def qdq(x, qmax, spec: QuantSpec):
    """Fake-quantize `x` according to `spec` (value only, no STE)."""
    if spec.backend == "pallas":
        return pallas_quant.qdq(x, qmax, spec.granularity, spec.asymmetric)
    return ref.qdq(x, qmax, spec.granularity, spec.asymmetric)


def ste_qdq(x, qmax, spec: Optional[QuantSpec]):
    """Fake-quantize with a straight-through gradient (identity jacobian)."""
    if spec is None:
        return x
    return x + jax.lax.stop_gradient(qdq(x, qmax, spec) - x)


def make_qlinear(cfg: QuantConfig):
    """Build the quantized 2D matmul `y = qdq_a(x) @ qdq_w(w)` with the
    paper's asymmetric backward treatment of the output gradient.

    Returns fn(x2d, w, qmax_w, qmax_a, qmax_g) -> y2d. The granularity
    structure is baked (static); the qmax values are traced scalars.
    """

    @jax.custom_vjp
    def qlinear(x, w, qmax_w, qmax_a, qmax_g):
        xq = qdq(x, qmax_a, cfg.acts) if cfg.acts else x
        wq = qdq(w, qmax_w, cfg.weights) if cfg.weights else w
        return xq @ wq

    def fwd(x, w, qmax_w, qmax_a, qmax_g):
        xq = qdq(x, qmax_a, cfg.acts) if cfg.acts else x
        wq = qdq(w, qmax_w, cfg.weights) if cfg.weights else w
        return xq @ wq, (xq, wq, qmax_g)

    def bwd(res, g):
        xq, wq, qmax_g = res
        if cfg.grads is not None:
            gq = qdq(g, qmax_g, cfg.grads)
        else:
            gq = g
        # weight gradient: always from the quantized output gradient
        dw = xq.T @ gq
        # input (activation) gradient: real-valued g unless the unstable
        # quantize_act_grads variant is requested (paper Fig. 10)
        gx = gq if (cfg.grads is not None and cfg.quantize_act_grads) else g
        dx = gx @ wq.T
        zero = jnp.zeros((), jnp.float32)
        return dx, dw, zero, zero, zero

    qlinear.defvjp(fwd, bwd)
    return qlinear


def moment_qdq(x, qmax, spec: Optional[QuantSpec], stacked: bool):
    """Fake-quantize an optimizer moment for storage.

    Only tensors of >=2 dims (linear-layer moments) are quantized, matching
    the paper's focus on linear layer components; 1-D bias/LN moments stay
    fp32. Stacked per-layer tensors (leading L axis) are quantized per layer
    so that per_tensor means "per layer-tensor", as in the paper.
    """
    if spec is None:
        return x
    base_ndim = x.ndim - (1 if stacked else 0)
    if base_ndim < 2:
        return x
    if stacked:
        return jax.vmap(lambda a: qdq(a, qmax, spec))(x)
    return qdq(x, qmax, spec)
