"""AdamW with optionally fake-quantized optimizer states (paper §4.4).

The paper's protocol: the quantized moment values are what is *stored*
between iterations; at each step the stored (already fake-quantized) moments
are combined with the fresh gradient, the update is applied from the newly
quantized moments (so the update sees exactly the storage format), and the
quantized moments are carried to the next step.

This is what makes the second moment fragile (Fig. 12): symmetric linear
quantization around zero collapses the many tiny v-values into the zero bin,
and since v sits in the denominator of the Adam update the de-quantized
zeros produce excessively large steps.

Weight decay is decoupled (AdamW) and applied only to >=2D weights;
gradients are clipped by global norm before the moment update (nanoGPT
setup, Appendix A). The global gradient norm is returned so the coordinator
can track the paper's Fig. 10 spikes.
"""

from typing import Dict, Tuple

import jax.numpy as jnp

from .configs import HP, HyperParams, ModelCfg
from .model import param_defs
from .quantizer import QuantConfig, moment_qdq


def global_norm(tree: Dict[str, jnp.ndarray]) -> jnp.ndarray:
    return jnp.sqrt(sum(jnp.sum(g.astype(jnp.float32) ** 2) for g in tree.values()))


def adamw_update(
    cfg: ModelCfg,
    qcfg: QuantConfig,
    params: Dict[str, jnp.ndarray],
    grads: Dict[str, jnp.ndarray],
    m: Dict[str, jnp.ndarray],
    v: Dict[str, jnp.ndarray],
    lr: jnp.ndarray,
    t: jnp.ndarray,  # 1-based step counter, f32 scalar
    qmax_m1: jnp.ndarray,
    qmax_m2: jnp.ndarray,
    hp: HyperParams = HP,
) -> Tuple[Dict, Dict, Dict, jnp.ndarray]:
    """One AdamW step. Returns (params', m', v', pre-clip grad global norm)."""
    defs = {d.name: d for d in param_defs(cfg)}

    gnorm = global_norm(grads)
    clip_coef = jnp.minimum(1.0, hp.grad_clip / (gnorm + 1e-12))
    grads = {k: g * clip_coef for k, g in grads.items()}

    bc1 = 1.0 - hp.beta1 ** t
    bc2 = 1.0 - hp.beta2 ** t

    new_params, new_m, new_v = {}, {}, {}
    for k, p in params.items():
        g = grads[k]
        stacked = defs[k].stacked
        m_new = hp.beta1 * m[k] + (1.0 - hp.beta1) * g
        v_new = hp.beta2 * v[k] + (1.0 - hp.beta2) * g * g
        # store fake-quantized; the update reads the stored representation
        m_new = moment_qdq(m_new, qmax_m1, qcfg.m1, stacked)
        v_new = moment_qdq(v_new, qmax_m2, qcfg.m2, stacked)
        m_hat = m_new / bc1
        v_hat = v_new / bc2
        step = m_hat / (jnp.sqrt(v_hat) + hp.eps)
        if defs[k].decay:
            step = step + hp.weight_decay * p
        new_params[k] = p - lr * step
        new_m[k] = m_new
        new_v[k] = v_new
    return new_params, new_m, new_v, gnorm
