"""AOT artifact builder: lower every step function to HLO *text* + manifest.

HLO text (not `.serialize()`) is the interchange format: jax >= 0.5 emits
HloModuleProtos with 64-bit instruction ids which the rust side's
xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text parser
reassigns ids and round-trips cleanly. See /opt/xla-example/README.md.

The builder is incremental: each artifact's config hash (spec + source tree
+ jax version) is recorded in `manifest.json`; unchanged artifacts are
skipped, so `make artifacts` is cheap to re-run.

Artifact inventory (see DESIGN.md §6):
  t4/train/<structure>     17 granularity structures incl. the pallas-backend
                           composition proof (bit-width is a runtime scalar)
  t4/eval/<structure>       8 forward structures (PTQ-activation reuses these)
  t4/probe/{act,grad}       outlier / gradient-snapshot probes (Figs 6, 8, 10)
  gpt2s/{train_base,train_wa,eval_base}   ~100M end-to-end configs
  prof/{linear,attn}_<size>_s<seq>        Fig. 3 timing blocks
  k/*                       standalone L1 kernel artifacts (runtime validation
                            + rust-side kernel benches)
"""

import argparse
import hashlib
import json
import os
import sys
import time

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as M
from . import steps
from .configs import GPT2S, PROF, T4, ModelCfg
from .quantizer import QuantConfig, QuantSpec

F32 = jnp.float32
I32 = jnp.int32


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _sig(names_shapes):
    return [
        {"name": n, "dtype": d, "shape": list(s)} for (n, d, s) in names_shapes
    ]


def _spec_of(sig):
    dt = {"f32": F32, "i32": I32}
    return [jax.ShapeDtypeStruct(tuple(e["shape"]), dt[e["dtype"]]) for e in sig]


def _param_sig(cfg: ModelCfg, prefix=""):
    return [(prefix + d.name, "f32", d.shape) for d in M.param_defs(cfg)]


def quant_json(q: QuantConfig):
    def spec(s):
        if s is None:
            return None
        return {
            "granularity": s.granularity,
            "asymmetric": s.asymmetric,
            "backend": s.backend,
        }

    return {
        "weights": spec(q.weights),
        "acts": spec(q.acts),
        "grads": spec(q.grads),
        "quantize_act_grads": q.quantize_act_grads,
        "m1": spec(q.m1),
        "m2": spec(q.m2),
    }


# ---------------------------------------------------------------------------
# quant structures under study (bit-width is runtime, so e.g. w4_pt and w8_pt
# share the "w_pt" artifact)
# ---------------------------------------------------------------------------

S = QuantSpec

TRAIN_STRUCTURES = {
    "base": QuantConfig(),
    "w_pt": QuantConfig(weights=S("per_tensor")),
    "w_pc": QuantConfig(weights=S("per_channel")),
    "a_pt": QuantConfig(acts=S("per_tensor")),
    "a_ptok": QuantConfig(acts=S("per_token")),
    "a_ptok_asym": QuantConfig(acts=S("per_token", asymmetric=True)),
    "a_pc": QuantConfig(acts=S("per_channel")),
    "g_pt": QuantConfig(grads=S("per_tensor")),
    "g_ptok": QuantConfig(grads=S("per_token")),
    "g_ptok_actgrad": QuantConfig(grads=S("per_token"), quantize_act_grads=True),
    "m1_pt": QuantConfig(m1=S("per_tensor")),
    "m1_pc": QuantConfig(m1=S("per_channel")),
    "m2_pt": QuantConfig(m2=S("per_tensor")),
    "m2_pc": QuantConfig(m2=S("per_channel")),
    "wa": QuantConfig(weights=S("per_channel"), acts=S("per_token")),
    "wag": QuantConfig(
        weights=S("per_channel"), acts=S("per_token"), grads=S("per_token")
    ),
    # L1 composition proof: the pallas kernel lowers inside the train step
    "w_pc_pallas": QuantConfig(weights=S("per_channel", backend="pallas")),
}

EVAL_STRUCTURES = {
    k: TRAIN_STRUCTURES[k]
    for k in [
        "base", "w_pt", "w_pc", "a_pt", "a_ptok", "a_ptok_asym", "a_pc", "wa",
    ]
}


# ---------------------------------------------------------------------------
# artifact specs
# ---------------------------------------------------------------------------


def train_inputs(cfg: ModelCfg):
    sig = (
        _param_sig(cfg)
        + _param_sig(cfg, "m.")
        + _param_sig(cfg, "v.")
        + [
            ("x", "i32", (cfg.batch, cfg.seq)),
            ("y", "i32", (cfg.batch, cfg.seq)),
            ("lr", "f32", ()),
            ("t", "f32", ()),
            ("qmax_w", "f32", ()),
            ("qmax_a", "f32", ()),
            ("qmax_g", "f32", ()),
            ("qmax_m1", "f32", ()),
            ("qmax_m2", "f32", ()),
        ]
    )
    return _sig(sig)


def train_outputs(cfg: ModelCfg):
    sig = (
        _param_sig(cfg)
        + _param_sig(cfg, "m.")
        + _param_sig(cfg, "v.")
        + [("loss", "f32", ()), ("gnorm", "f32", ())]
    )
    return _sig(sig)


def eval_inputs(cfg: ModelCfg):
    return _sig(
        _param_sig(cfg)
        + [
            ("x", "i32", (cfg.batch, cfg.seq)),
            ("y", "i32", (cfg.batch, cfg.seq)),
            ("mask", "f32", (cfg.batch, cfg.seq)),
            ("qmax_w", "f32", ()),
            ("qmax_a", "f32", ()),
        ]
    )


def eval_outputs(cfg: ModelCfg):
    return _sig(
        [("mean_nll", "f32", ()), ("per_pos_nll", "f32", (cfg.batch, cfg.seq))]
    )


def collect_artifacts():
    """Yield dicts: {name, fn, inputs, outputs, meta}."""
    arts = []

    def add(name, fn, inputs, outputs, **meta):
        arts.append(
            {"name": name, "fn": fn, "inputs": inputs, "outputs": outputs, "meta": meta}
        )

    # --- t4 study model ---
    for sname, qcfg in TRAIN_STRUCTURES.items():
        add(
            f"t4/train/{sname}",
            steps.make_train_step(T4, qcfg),
            train_inputs(T4),
            train_outputs(T4),
            kind="train",
            model="t4",
            quant=quant_json(qcfg),
        )
    for sname, qcfg in EVAL_STRUCTURES.items():
        add(
            f"t4/eval/{sname}",
            steps.make_eval_step(T4, qcfg),
            eval_inputs(T4),
            eval_outputs(T4),
            kind="eval",
            model="t4",
            quant=quant_json(qcfg),
        )

    probe_layer = T4.n_layer - 1
    add(
        "t4/probe/act",
        steps.make_act_probe(T4, QuantConfig(), probe_layer),
        _sig(
            _param_sig(T4)
            + [("x", "i32", (T4.batch, T4.seq)), ("qmax_w", "f32", ()), ("qmax_a", "f32", ())]
        ),
        _sig(
            [
                ("proj_in", "f32", (T4.batch, T4.seq, T4.d_model)),
                ("fc2_in", "f32", (T4.batch, T4.seq, T4.d_ff)),
            ]
        ),
        kind="act_probe",
        model="t4",
        probe_layer=probe_layer,
    )
    add(
        "t4/probe/grad",
        steps.make_grad_probe(T4, QuantConfig()),
        _sig(
            _param_sig(T4)
            + [
                ("x", "i32", (T4.batch, T4.seq)),
                ("y", "i32", (T4.batch, T4.seq)),
                ("qmax_w", "f32", ()),
                ("qmax_a", "f32", ()),
                ("qmax_g", "f32", ()),
            ]
        ),
        _sig(
            [
                ("d_qkv_w0", "f32", (T4.d_model, 3 * T4.d_model)),
                ("d_ctx0", "f32", (T4.batch, T4.seq, T4.d_model)),
            ]
        ),
        kind="grad_probe",
        model="t4",
    )

    # --- gpt2s end-to-end (~100M params) ---
    for sname in ["base", "wa"]:
        add(
            f"gpt2s/train/{sname}",
            steps.make_train_step(GPT2S, TRAIN_STRUCTURES[sname]),
            train_inputs(GPT2S),
            train_outputs(GPT2S),
            kind="train",
            model="gpt2s",
            quant=quant_json(TRAIN_STRUCTURES[sname]),
        )
    add(
        "gpt2s/eval/base",
        steps.make_eval_step(GPT2S, QuantConfig()),
        eval_inputs(GPT2S),
        eval_outputs(GPT2S),
        kind="eval",
        model="gpt2s",
        quant=quant_json(QuantConfig()),
    )

    # --- Fig. 3 profiling blocks (fwd+bwd) ---
    for size, pcfg in PROF.items():
        d, f, nh, hd = pcfg.d_model, pcfg.d_ff, pcfg.n_head, pcfg.d_head
        for seq in [128, 256, 512, 1024]:
            B = 1

            def make_linear(d=d, f=f, seq=seq, B=B):
                def fwd(x, qkv_w, proj_w, fc1_w, fc2_w):
                    h = x.reshape(B * seq, d)
                    a = h @ qkv_w
                    b = a[:, :d] @ proj_w
                    c = jax.nn.gelu(b @ fc1_w, approximate=True)
                    return jnp.sum(c @ fc2_w)

                return jax.value_and_grad(fwd, argnums=(0, 1, 2, 3, 4))

            def make_attn(nh=nh, hd=hd, seq=seq, B=B):
                def fwd(q, k, v):
                    att = jnp.einsum("bhqd,bhkd->bhqk", q, k) / jnp.sqrt(float(hd))
                    mask = jnp.tril(jnp.ones((seq, seq), bool))
                    att = jax.nn.softmax(jnp.where(mask, att, -1e30), axis=-1)
                    return jnp.sum(jnp.einsum("bhqk,bhkd->bhqd", att, v))

                return jax.value_and_grad(fwd, argnums=(0, 1, 2))

            lin_in = _sig(
                [
                    ("x", "f32", (B, seq, d)),
                    ("qkv_w", "f32", (d, 3 * d)),
                    ("proj_w", "f32", (d, d)),
                    ("fc1_w", "f32", (d, f)),
                    ("fc2_w", "f32", (f, d)),
                ]
            )
            add(
                f"prof/linear_{size}_s{seq}",
                make_linear(),
                lin_in,
                _sig([("loss", "f32", ())]),  # grads omitted from meta
                kind="prof_linear",
                model=size,
                seq=seq,
                flops=2 * B * seq * (d * 3 * d + d * d + d * f + f * d) * 3,
            )
            attn_in = _sig(
                [
                    ("q", "f32", (B, nh, seq, hd)),
                    ("k", "f32", (B, nh, seq, hd)),
                    ("v", "f32", (B, nh, seq, hd)),
                ]
            )
            add(
                f"prof/attn_{size}_s{seq}",
                make_attn(),
                attn_in,
                _sig([("loss", "f32", ())]),
                kind="prof_attn",
                model=size,
                seq=seq,
                flops=2 * B * nh * seq * seq * hd * 2 * 3,
            )

    # --- standalone L1 kernel artifacts ---
    from .kernels import qmatmul as K_mm
    from .kernels import quant as K_q
    from .kernels import ref as K_ref

    M_, N_, K_ = 256, 512, 256
    x_sig = [("x", "f32", (M_, N_)), ("qmax", "f32", ())]
    for gran, short in [
        ("per_tensor", "pt"),
        ("per_channel", "pc"),
        ("per_token", "ptok"),
    ]:
        add(
            f"k/qdq_{short}_pallas",
            (lambda g: lambda x, qmax: (K_q.qdq(x, qmax, g),))(gran),
            _sig(x_sig),
            _sig([("out", "f32", (M_, N_))]),
            kind="kernel",
            gran=gran,
        )
    add(
        "k/qdq_ptok_asym_pallas",
        lambda x, qmax: (K_q.qdq(x, qmax, "per_token", asymmetric=True),),
        _sig(x_sig),
        _sig([("out", "f32", (M_, N_))]),
        kind="kernel",
        gran="per_token_asym",
    )
    add(
        "k/qdq_pt_jnp",
        lambda x, qmax: (K_ref.qdq(x, qmax, "per_tensor"),),
        _sig(x_sig),
        _sig([("out", "f32", (M_, N_))]),
        kind="kernel",
        gran="per_tensor_jnp",
    )
    mm_sig = [
        ("x", "f32", (M_, N_)),
        ("w", "f32", (N_, K_)),
        ("qmax_a", "f32", ()),
        ("qmax_w", "f32", ()),
    ]
    add(
        "k/qmatmul_pallas",
        lambda x, w, qa, qw: (K_mm.qmatmul(x, w, qa, qw),),
        _sig(mm_sig),
        _sig([("out", "f32", (M_, K_))]),
        kind="kernel",
        gran="qmatmul",
    )
    add(
        "k/matmul_ref",
        lambda x, w, qa, qw: (x @ w,),
        _sig(mm_sig),
        _sig([("out", "f32", (M_, K_))]),
        kind="kernel",
        gran="matmul",
    )

    return arts


# ---------------------------------------------------------------------------
# build driver
# ---------------------------------------------------------------------------


def source_hash() -> str:
    h = hashlib.sha256()
    pkg = os.path.dirname(__file__)
    for root, _, files in sorted(os.walk(pkg)):
        for f in sorted(files):
            if f.endswith(".py"):
                with open(os.path.join(root, f), "rb") as fh:
                    h.update(fh.read())
    h.update(jax.__version__.encode())
    return h.hexdigest()


def model_json(cfg: ModelCfg):
    return {
        "n_layer": cfg.n_layer,
        "d_model": cfg.d_model,
        "n_head": cfg.n_head,
        "vocab": cfg.vocab,
        "seq": cfg.seq,
        "batch": cfg.batch,
        "d_ff": cfg.d_ff,
        "n_params": cfg.n_params(),
        "params": [
            {
                "name": d.name,
                "shape": list(d.shape),
                "stacked": d.stacked,
                "decay": d.decay,
                "init": d.init,
            }
            for d in M.param_defs(cfg)
        ],
    }


def write_goldens(out_dir: str):
    """Emit golden .npy cases for the rust quant module's bit-exactness tests.

    The input grid is constructed from exact small rationals so that rust can
    regenerate it bit-identically: x[i,j] = ((31*i + 17*j) mod 257 - 128)/16.
    """
    import numpy as np

    from .kernels import ref as K_ref

    gdir = os.path.join(out_dir, "golden")
    os.makedirs(gdir, exist_ok=True)
    i = np.arange(64)[:, None]
    j = np.arange(48)[None, :]
    x = (((31 * i + 17 * j) % 257 - 128) / 16.0).astype(np.float32)
    np.save(os.path.join(gdir, "input.npy"), x)
    for gran, short in [
        ("per_tensor", "pt"),
        ("per_token", "ptok"),
        ("per_channel", "pc"),
    ]:
        for bits in [2, 4, 8]:
            qmax = K_ref.bits_to_qmax(bits)
            out = np.asarray(K_ref.qdq(jnp.asarray(x), qmax, gran))
            np.save(os.path.join(gdir, f"qdq_{short}_b{bits}.npy"), out)
            if gran == "per_token":
                out = np.asarray(
                    K_ref.qdq(jnp.asarray(x), qmax, gran, asymmetric=True)
                )
                np.save(os.path.join(gdir, f"qdq_{short}_asym_b{bits}.npy"), out)
    # an asymmetric-friendly positive input (post-GELU-like)
    xp = np.abs(x) + 0.25
    np.save(os.path.join(gdir, "input_pos.npy"), xp.astype(np.float32))
    for bits in [4, 8]:
        qmax = K_ref.bits_to_qmax(bits)
        out = np.asarray(
            K_ref.qdq(jnp.asarray(xp.astype(np.float32)), qmax, "per_token", asymmetric=True)
        )
        np.save(os.path.join(gdir, f"qdq_pos_ptok_asym_b{bits}.npy"), out)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--only", default=None, help="substring filter of artifact names")
    args = ap.parse_args()
    out_dir = args.out
    os.makedirs(out_dir, exist_ok=True)
    manifest_path = os.path.join(out_dir, "manifest.json")

    old = {}
    if os.path.exists(manifest_path):
        with open(manifest_path) as f:
            old = json.load(f).get("artifacts", {})

    src_hash = source_hash()
    arts = collect_artifacts()
    manifest = {
        "jax_version": jax.__version__,
        "source_hash": src_hash,
        "models": {
            "t4": model_json(T4),
            "gpt2s": model_json(GPT2S),
            **{k: model_json(v) for k, v in PROF.items()},
        },
        "artifacts": {},
    }

    n_built = n_skipped = 0
    for art in arts:
        name = art["name"]
        fname = name.replace("/", "__") + ".hlo.txt"
        fpath = os.path.join(out_dir, fname)
        key_src = json.dumps(
            {"inputs": art["inputs"], "meta": art["meta"], "src": src_hash},
            sort_keys=True,
        )
        key = hashlib.sha256(key_src.encode()).hexdigest()
        entry = {
            "file": fname,
            "hash": key,
            "inputs": art["inputs"],
            "outputs": art["outputs"],
            **art["meta"],
        }
        manifest["artifacts"][name] = entry

        prev = old.get(name)
        if (
            prev is not None
            and prev.get("hash") == key
            and os.path.exists(fpath)
            and (args.only is None or args.only not in name)
        ):
            n_skipped += 1
            continue
        if args.only is not None and args.only not in name:
            # still need the artifact to exist; rebuild if missing
            if prev is not None and os.path.exists(fpath):
                n_skipped += 1
                continue

        t0 = time.time()
        # keep_unused=True: structures that don't use some qmax scalars must
        # still accept them, so every train artifact shares one input order.
        lowered = jax.jit(art["fn"], keep_unused=True).lower(*_spec_of(art["inputs"]))
        text = to_hlo_text(lowered)
        with open(fpath, "w") as f:
            f.write(text)
        n_built += 1
        print(
            f"built {name}  ({len(text) / 1e6:.2f} MB HLO, {time.time() - t0:.1f}s)",
            flush=True,
        )

    with open(manifest_path, "w") as f:
        json.dump(manifest, f, indent=1)
    write_goldens(out_dir)
    print(f"artifacts: {n_built} built, {n_skipped} up-to-date -> {out_dir}")


if __name__ == "__main__":
    main()
