"""Model-size presets shared between aot.py and the manifest consumed by rust.

`t4` is the study model: every quantization experiment in the paper is run on
it (the paper used GPT-2 small; see DESIGN.md §4 for the scaling argument).
`gpt2s` is the ~100M-parameter end-to-end configuration. The `prof_*`
configs mirror the paper's Fig. 2/3 profiling sizes (GPT-2 Small / Medium /
Large / XL shapes).
"""

import dataclasses


@dataclasses.dataclass(frozen=True)
class ModelCfg:
    name: str
    n_layer: int
    d_model: int
    n_head: int
    vocab: int
    seq: int
    batch: int

    @property
    def d_ff(self) -> int:
        return 4 * self.d_model

    @property
    def d_head(self) -> int:
        assert self.d_model % self.n_head == 0
        return self.d_model // self.n_head

    def n_params(self) -> int:
        d, L, V, T = self.d_model, self.n_layer, self.vocab, self.seq
        per_layer = (
            2 * d  # ln1
            + d * 3 * d + 3 * d  # qkv
            + d * d + d  # proj
            + 2 * d  # ln2
            + d * self.d_ff + self.d_ff  # fc1
            + self.d_ff * d + d  # fc2
        )
        return V * d + T * d + L * per_layer + 2 * d  # wte, wpe, layers, lnf


# Study model: all per-component quantization experiments run here.
T4 = ModelCfg("t4", n_layer=4, d_model=128, n_head=4, vocab=512, seq=128, batch=16)

# ~100M-parameter end-to-end config (12L/768d like GPT-2 small, 8k vocab).
GPT2S = ModelCfg("gpt2s", n_layer=12, d_model=768, n_head=12, vocab=8192, seq=256, batch=2)

# Fig. 2 / Fig. 3 profiling shapes (single block is profiled, so n_layer is
# the bookkeeping value used by the analytic memory model only).
PROF = {
    "small": ModelCfg("small", 12, 768, 12, 50257, 1024, 1),
    "medium": ModelCfg("medium", 24, 1024, 16, 50257, 1024, 1),
    "large": ModelCfg("large", 36, 1280, 20, 50257, 1024, 1),
    "xl": ModelCfg("xl", 48, 1600, 25, 50257, 1024, 1),
}

MODELS = {"t4": T4, "gpt2s": GPT2S}


@dataclasses.dataclass(frozen=True)
class HyperParams:
    """AdamW hyperparameters (paper Appendix A: nanoGPT/FlashAttention setup)."""

    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0


HP = HyperParams()
