"""End-to-end step functions: training reduces loss; eval/train consistency."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile import steps
from compile.configs import ModelCfg
from compile.quantizer import QuantConfig, QuantSpec
from .test_model import init_params, tokens

CFG = ModelCfg("mini", 2, 32, 2, 64, 16, 8)
SC = lambda v: jnp.asarray(v, jnp.float32)


def flat_params(cfg, seed=0):
    from compile import model as M

    p = init_params(cfg, seed)
    return [p[d.name] for d in M.param_defs(cfg)]


def zeros_like_params(cfg):
    from compile import model as M

    return [jnp.zeros(d.shape, jnp.float32) for d in M.param_defs(cfg)]


def markov_batch(cfg, seed):
    """Learnable synthetic stream: x[t+1] = (3*x[t] + 7) mod V with noise."""
    rng = np.random.default_rng(seed)
    x = np.zeros((cfg.batch, cfg.seq + 1), np.int64)
    x[:, 0] = rng.integers(0, cfg.vocab, cfg.batch)
    for t in range(cfg.seq):
        nxt = (3 * x[:, t] + 7) % cfg.vocab
        noise = rng.integers(0, cfg.vocab, cfg.batch)
        take_noise = rng.random(cfg.batch) < 0.1
        x[:, t + 1] = np.where(take_noise, noise, nxt)
    return (
        jnp.asarray(x[:, :-1].astype(np.int32)),
        jnp.asarray(x[:, 1:].astype(np.int32)),
    )


def run_steps(qcfg, n=30, qmaxes=(127.0,) * 5, seed=0):
    ts = jax.jit(steps.make_train_step(CFG, qcfg))
    NP = steps.n_params_tensors(CFG)
    state = flat_params(CFG, seed) + zeros_like_params(CFG) + zeros_like_params(CFG)
    losses = []
    for i in range(n):
        x, y = markov_batch(CFG, 100 + i)
        out = ts(*state, x, y, SC(3e-3), SC(i + 1), *map(SC, qmaxes))
        state = list(out[: 3 * NP])
        losses.append(float(out[-2]))
    return losses


def test_baseline_training_reduces_loss():
    losses = run_steps(QuantConfig())
    assert losses[-1] < losses[0] - 0.3
    assert all(np.isfinite(losses))


def test_w8_pc_training_tracks_baseline():
    base = run_steps(QuantConfig())
    w8 = run_steps(QuantConfig(weights=QuantSpec("per_channel")))
    assert abs(w8[-1] - base[-1]) < 0.35


def test_wa8_training_converges():
    losses = run_steps(
        QuantConfig(weights=QuantSpec("per_channel"), acts=QuantSpec("per_token"))
    )
    assert losses[-1] < losses[0] - 0.25


def test_w2_training_much_worse_than_w8():
    """2-bit weights (qmax=1) should degrade much more than 8-bit."""
    w8 = run_steps(QuantConfig(weights=QuantSpec("per_tensor")))
    w2 = run_steps(
        QuantConfig(weights=QuantSpec("per_tensor")), qmaxes=(1.0, 127.0, 127.0, 127.0, 127.0)
    )
    # direction must hold; at 30 tiny steps the separation is modest
    assert w2[-1] > w8[-1] + 0.05


def test_m2_per_tensor_quant_degrades_or_diverges():
    base = run_steps(QuantConfig(), n=15)
    m2 = run_steps(QuantConfig(m2=QuantSpec("per_tensor")), n=15)
    # Fig. 12: second-moment quantization destabilizes from the onset
    assert (not np.isfinite(m2[-1])) or m2[-1] > base[-1] + 0.5


def test_train_loss_equals_eval_loss_on_same_state():
    qcfg = QuantConfig(weights=QuantSpec("per_channel"), acts=QuantSpec("per_token"))
    ts = jax.jit(steps.make_train_step(CFG, qcfg))
    es = jax.jit(steps.make_eval_step(CFG, qcfg))
    NP = steps.n_params_tensors(CFG)
    state = flat_params(CFG, 1) + zeros_like_params(CFG) + zeros_like_params(CFG)
    x, y = markov_batch(CFG, 0)
    out = ts(*state, x, y, SC(0.0), SC(1.0), *[SC(127.0)] * 5)
    train_loss = float(out[-2])
    mean_nll, per_pos = es(*state[:NP], x, y, jnp.ones((CFG.batch, CFG.seq)), SC(127.0), SC(127.0))
    assert abs(train_loss - float(mean_nll)) < 1e-4
    np.testing.assert_allclose(float(jnp.mean(per_pos)), float(mean_nll), rtol=1e-5)


def test_eval_mask():
    es = jax.jit(steps.make_eval_step(CFG, QuantConfig()))
    state = flat_params(CFG, 2)
    x, y = markov_batch(CFG, 1)
    mask = jnp.zeros((CFG.batch, CFG.seq)).at[:, -1].set(1.0)
    mean_nll, per_pos = es(*state, x, y, mask, SC(1.0), SC(1.0))
    np.testing.assert_allclose(
        float(mean_nll), float(jnp.mean(per_pos[:, -1])), rtol=1e-5
    )


def test_zero_lr_keeps_params():
    ts = jax.jit(steps.make_train_step(CFG, QuantConfig()))
    NP = steps.n_params_tensors(CFG)
    state = flat_params(CFG, 3) + zeros_like_params(CFG) + zeros_like_params(CFG)
    x, y = markov_batch(CFG, 2)
    out = ts(*state, x, y, SC(0.0), SC(1.0), *[SC(1.0)] * 5)
    for a, b in zip(state[:NP], out[:NP]):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_gnorm_positive_and_finite():
    ts = jax.jit(steps.make_train_step(CFG, QuantConfig()))
    state = flat_params(CFG, 4) + zeros_like_params(CFG) + zeros_like_params(CFG)
    x, y = markov_batch(CFG, 3)
    out = ts(*state, x, y, SC(1e-3), SC(1.0), *[SC(1.0)] * 5)
    g = float(out[-1])
    assert np.isfinite(g) and g > 0


def test_grad_probe_outputs_nonzero():
    gp = jax.jit(steps.make_grad_probe(CFG, QuantConfig()))
    state = flat_params(CFG, 5)
    x, y = markov_batch(CFG, 4)
    dqkv, dctx = gp(*state, x, y, SC(1.0), SC(1.0), SC(1.0))
    assert float(jnp.abs(dqkv).max()) > 0
    assert float(jnp.abs(dctx).max()) > 0
    assert dqkv.shape == (CFG.d_model, 3 * CFG.d_model)


def test_act_probe_matches_manual_forward():
    ap = jax.jit(steps.make_act_probe(CFG, QuantConfig(), 0))
    state = flat_params(CFG, 6)
    x, _ = markov_batch(CFG, 5)
    proj_in, fc2_in = ap(*state, x, SC(1.0), SC(1.0))
    assert bool(jnp.all(jnp.isfinite(proj_in))) and bool(jnp.all(jnp.isfinite(fc2_in)))
    # post-GELU fc2 input is bounded below by GELU's minimum (~ -0.17)
    assert float(fc2_in.min()) > -0.2
