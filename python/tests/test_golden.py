"""Golden-file sanity: the cross-language test vectors are valid oracles."""

import os

import numpy as np
import jax.numpy as jnp
import pytest

from compile.kernels import ref

GDIR = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts", "golden")


def need_goldens():
    if not os.path.isdir(GDIR):
        pytest.skip("goldens not built (run `make artifacts`)")


def test_input_grid_formula():
    need_goldens()
    x = np.load(os.path.join(GDIR, "input.npy"))
    i = np.arange(64)[:, None]
    j = np.arange(48)[None, :]
    expect = (((31 * i + 17 * j) % 257 - 128) / 16.0).astype(np.float32)
    np.testing.assert_array_equal(x, expect)


@pytest.mark.parametrize("short,gran", [("pt", "per_tensor"), ("ptok", "per_token"), ("pc", "per_channel")])
@pytest.mark.parametrize("bits", [2, 4, 8])
def test_goldens_match_oracle(short, gran, bits):
    need_goldens()
    x = jnp.asarray(np.load(os.path.join(GDIR, "input.npy")))
    out = np.load(os.path.join(GDIR, f"qdq_{short}_b{bits}.npy"))
    expect = np.asarray(ref.qdq(x, ref.bits_to_qmax(bits), gran))
    np.testing.assert_array_equal(out, expect)


@pytest.mark.parametrize("bits", [4, 8])
def test_goldens_asym_positive(bits):
    need_goldens()
    xp = jnp.asarray(np.load(os.path.join(GDIR, "input_pos.npy")))
    out = np.load(os.path.join(GDIR, f"qdq_pos_ptok_asym_b{bits}.npy"))
    expect = np.asarray(ref.qdq(xp, ref.bits_to_qmax(bits), "per_token", asymmetric=True))
    np.testing.assert_array_equal(out, expect)
