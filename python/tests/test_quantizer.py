"""STE and custom-vjp behaviour of the quantized linear layer (paper Fig. 1)."""

import numpy as np
import jax
import jax.numpy as jnp

from compile.quantizer import QuantConfig, QuantSpec, make_qlinear, qdq, ste_qdq
from compile.kernels import ref


def rnd(shape, seed=0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(0, 1, shape).astype(np.float32))


def test_ste_identity_gradient():
    """d/dx [ste_qdq(x)] must be exactly 1 (straight-through)."""
    x = rnd((16, 16), seed=1)
    spec = QuantSpec("per_tensor")
    g = jax.grad(lambda a: jnp.sum(ste_qdq(a, 7.0, spec) * 3.0))(x)
    np.testing.assert_allclose(np.asarray(g), 3.0 * np.ones_like(g), rtol=1e-6)


def test_ste_value_is_quantized():
    x = rnd((8, 8), seed=2)
    spec = QuantSpec("per_channel")
    np.testing.assert_array_equal(
        np.asarray(ste_qdq(x, 7.0, spec)), np.asarray(ref.qdq(x, 7.0, "per_channel"))
    )


def test_qlinear_forward_quantizes_both_operands():
    x, w = rnd((32, 16), 3), rnd((16, 24), 4)
    cfg = QuantConfig(weights=QuantSpec("per_channel"), acts=QuantSpec("per_token"))
    f = make_qlinear(cfg)
    y = f(x, w, 127.0, 127.0, 1.0)
    expect = ref.qdq(x, 127.0, "per_token") @ ref.qdq(w, 127.0, "per_channel")
    np.testing.assert_allclose(np.asarray(y), np.asarray(expect), rtol=1e-6)


def test_qlinear_weight_grad_uses_quantized_output_grad():
    """dW = qdq_a(x)^T @ qdq_g(g); dx = g @ qdq_w(w)^T with REAL g."""
    x, w = rnd((32, 16), 5), rnd((16, 24), 6)
    up = rnd((32, 24), 7)  # upstream gradient
    cfg = QuantConfig(
        weights=QuantSpec("per_channel"),
        acts=QuantSpec("per_token"),
        grads=QuantSpec("per_token"),
    )
    f = make_qlinear(cfg)
    dx, dw = jax.grad(
        lambda a, b: jnp.sum(f(a, b, 127.0, 127.0, 7.0) * up), argnums=(0, 1)
    )(x, w)

    xq = ref.qdq(x, 127.0, "per_token")
    wq = ref.qdq(w, 127.0, "per_channel")
    gq = ref.qdq(up, 7.0, "per_token")
    np.testing.assert_allclose(np.asarray(dw), np.asarray(xq.T @ gq), rtol=1e-5)
    # dx uses the REAL (unquantized) upstream gradient
    np.testing.assert_allclose(np.asarray(dx), np.asarray(up @ wq.T), rtol=1e-5)


def test_qlinear_actgrad_variant_quantizes_dx_path():
    x, w = rnd((16, 8), 8), rnd((8, 12), 9)
    up = rnd((16, 12), 10)
    cfg = QuantConfig(grads=QuantSpec("per_token"), quantize_act_grads=True)
    f = make_qlinear(cfg)
    dx = jax.grad(lambda a: jnp.sum(f(a, w, 1.0, 1.0, 7.0) * up))(x)
    gq = ref.qdq(up, 7.0, "per_token")
    np.testing.assert_allclose(np.asarray(dx), np.asarray(gq @ w.T), rtol=1e-5)


def test_qlinear_no_quant_is_plain_matmul():
    x, w = rnd((8, 8), 11), rnd((8, 8), 12)
    f = make_qlinear(QuantConfig())
    np.testing.assert_allclose(
        np.asarray(f(x, w, 1.0, 1.0, 1.0)), np.asarray(x @ w), rtol=1e-6
    )
    dx = jax.grad(lambda a: jnp.sum(f(a, w, 1.0, 1.0, 1.0)))(x)
    np.testing.assert_allclose(
        np.asarray(dx), np.asarray(jnp.ones((8, 8)) @ w.T), rtol=1e-6
    )


def test_pallas_backend_matches_jnp_backend():
    x = rnd((64, 32), 13)
    for gran in ["per_tensor", "per_token", "per_channel"]:
        a = qdq(x, 7.0, QuantSpec(gran, backend="jnp"))
        b = qdq(x, 7.0, QuantSpec(gran, backend="pallas"))
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_quant_config_names():
    assert QuantConfig().name() == "base"
    assert QuantConfig(weights=QuantSpec("per_tensor")).name() == "w_pt"
    assert (
        QuantConfig(
            weights=QuantSpec("per_channel"), acts=QuantSpec("per_token")
        ).name()
        == "w_pc_a_ptok"
    )
    assert (
        QuantConfig(acts=QuantSpec("per_token", asymmetric=True)).name()
        == "a_ptok_asym"
    )
