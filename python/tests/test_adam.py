"""Quantized-AdamW correctness (paper §4.4)."""

import numpy as np
import jax.numpy as jnp

from compile.adam import adamw_update, global_norm
from compile.configs import HP, ModelCfg
from compile.model import param_defs
from compile.quantizer import QuantConfig, QuantSpec
from compile.kernels import ref

CFG = ModelCfg("mini", 2, 16, 2, 32, 8, 2)


def make_state(seed=0, grad_scale=1.0):
    rng = np.random.default_rng(seed)
    params, grads, m, v = {}, {}, {}, {}
    for d in param_defs(CFG):
        params[d.name] = jnp.asarray(rng.normal(0, 0.1, d.shape).astype(np.float32))
        grads[d.name] = jnp.asarray(
            rng.normal(0, grad_scale, d.shape).astype(np.float32)
        )
        m[d.name] = jnp.asarray(rng.normal(0, 0.01, d.shape).astype(np.float32))
        v[d.name] = jnp.asarray(
            np.abs(rng.normal(0, 0.001, d.shape)).astype(np.float32)
        )
    return params, grads, m, v


def np_adamw_ref(p, g, m, v, lr, t, decay):
    """Closed-form single-tensor AdamW reference (no quant, no clip)."""
    m_new = HP.beta1 * m + (1 - HP.beta1) * g
    v_new = HP.beta2 * v + (1 - HP.beta2) * g * g
    m_hat = m_new / (1 - HP.beta1**t)
    v_hat = v_new / (1 - HP.beta2**t)
    step = m_hat / (np.sqrt(v_hat) + HP.eps)
    if decay:
        step = step + HP.weight_decay * p
    return p - lr * step, m_new, v_new


def test_baseline_matches_numpy_reference():
    params, grads, m, v = make_state(0, grad_scale=1e-3)  # small grads: no clip
    lr, t = jnp.asarray(1e-3), jnp.asarray(3.0)
    one = jnp.ones(())
    new_p, new_m, new_v, gnorm = adamw_update(
        CFG, QuantConfig(), params, grads, m, v, lr, t, one, one
    )
    defs = {d.name: d for d in param_defs(CFG)}
    for k in params:
        ep, em, ev = np_adamw_ref(
            np.asarray(params[k]), np.asarray(grads[k]), np.asarray(m[k]),
            np.asarray(v[k]), 1e-3, 3.0, defs[k].decay,
        )
        np.testing.assert_allclose(np.asarray(new_p[k]), ep, rtol=2e-4, atol=1e-7)
        np.testing.assert_allclose(np.asarray(new_m[k]), em, rtol=1e-5, atol=1e-8)
        np.testing.assert_allclose(np.asarray(new_v[k]), ev, rtol=1e-5, atol=1e-10)


def test_grad_clip_applied():
    params, grads, m, v = make_state(1, grad_scale=10.0)  # huge grads
    one = jnp.ones(())
    _, new_m, _, gnorm = adamw_update(
        CFG, QuantConfig(), params, grads, m, v, jnp.asarray(1e-3), jnp.asarray(1.0),
        one, one,
    )
    assert float(gnorm) > HP.grad_clip  # pre-clip norm is returned
    # post-clip gradient norm implied by m1 update must be <= clip
    g_implied = {
        k: (np.asarray(new_m[k]) - HP.beta1 * np.asarray(m[k])) / (1 - HP.beta1)
        for k in params
    }
    total = np.sqrt(sum(np.sum(g**2) for g in g_implied.values()))
    assert total <= HP.grad_clip * 1.01


def test_global_norm():
    t = {"a": jnp.asarray([3.0]), "b": jnp.asarray([4.0])}
    assert abs(float(global_norm(t)) - 5.0) < 1e-6


def test_m1_quant_stores_quantized_moments():
    params, grads, m, v = make_state(2, grad_scale=1e-3)
    qcfg = QuantConfig(m1=QuantSpec("per_channel"))
    qmax = jnp.asarray(127.0)
    _, new_m, _, _ = adamw_update(
        CFG, qcfg, params, grads, m, v, jnp.asarray(1e-3), jnp.asarray(1.0),
        qmax, jnp.ones(()),
    )
    defs = {d.name: d for d in param_defs(CFG)}
    for k in params:
        d = defs[k]
        base_ndim = len(d.shape) - (1 if d.stacked else 0)
        stored = np.asarray(new_m[k])
        if base_ndim < 2:
            continue  # 1-D moments stay fp32
        # stored moments must be fixed points of the quantizer
        if d.stacked:
            requant = np.stack(
                [np.asarray(ref.qdq(jnp.asarray(s), 127.0, "per_channel")) for s in stored]
            )
        else:
            requant = np.asarray(ref.qdq(jnp.asarray(stored), 127.0, "per_channel"))
        np.testing.assert_allclose(stored, requant, atol=1e-7)


def test_m2_quant_zero_bin_collapse():
    """Fig. 12 mechanism: symmetric quantization of v flushes small second
    moments to zero, which explodes the Adam step via the denominator."""
    params, grads, m, v = make_state(3, grad_scale=1e-3)
    # craft v with one huge entry per tensor so scales blow up
    v = {
        k: a.at[(0,) * a.ndim].set(1e4) if a.ndim > 0 else a for k, a in v.items()
    }
    base_p, _, _, _ = adamw_update(
        CFG, QuantConfig(), params, grads, m, v, jnp.asarray(1e-3), jnp.asarray(100.0),
        jnp.ones(()), jnp.ones(()),
    )
    q_p, _, new_v, _ = adamw_update(
        CFG, QuantConfig(m2=QuantSpec("per_tensor")), params, grads, m, v,
        jnp.asarray(1e-3), jnp.asarray(100.0), jnp.ones(()), jnp.asarray(127.0),
    )
    # most stored v entries of the outlier layer collapse into the zero bin
    # (per_tensor granularity on the stacked tensor quantizes per layer)
    frac_zero = np.mean(np.asarray(new_v["qkv_w"][0]) == 0.0)
    assert frac_zero > 0.9
    # ...and the resulting update is wildly larger than the fp32 update
    upd_q = np.abs(np.asarray(q_p["qkv_w"]) - np.asarray(params["qkv_w"])).mean()
    upd_b = np.abs(np.asarray(base_p["qkv_w"]) - np.asarray(params["qkv_w"])).mean()
    assert upd_q > 10 * upd_b
