"""L1 kernel correctness: Pallas kernels vs the pure-jnp oracle (ref.py).

The oracle itself is additionally pinned against hand-computed values so a
bug cannot hide in both implementations at once.
"""

import numpy as np
import pytest
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile.kernels import qmatmul, quant, ref

GRANS = ["per_tensor", "per_token", "per_channel"]
BITS = [2, 3, 4, 6, 8]


def rnd(shape, seed=0, scale=1.0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(0, scale, shape).astype(np.float32))


# ---------------------------------------------------------------------------
# oracle pinned against hand-computed values
# ---------------------------------------------------------------------------


def test_oracle_hand_computed_per_tensor():
    # x = [-4, -1, 0, 2], 3 bits -> qmax = 3, s = 4/3
    x = jnp.asarray([[-4.0, -1.0, 0.0, 2.0]])
    out = ref.qdq(x, 3.0, "per_tensor")
    s = 4.0 / 3.0
    # round(x/s) = round([-3, -0.75, 0, 1.5]) = [-3, -1, 0, 2] (ties-to-even)
    np.testing.assert_allclose(out, np.array([[-3.0, -1.0, 0.0, 2.0]]) * s, rtol=1e-6)


def test_oracle_hand_computed_clip():
    # negative extreme must clip at N = -qmax-1... values below N*s clip
    x = jnp.asarray([[-10.0, 10.0]])
    out = ref.qdq(x, 1.0, "per_tensor")  # 2 bits: N=-2, P=1, s=10
    np.testing.assert_allclose(out, [[-10.0, 10.0]])  # -10/10->-1->-10; 10->1->10
    x = jnp.asarray([[-30.0, 10.0]])
    out = ref.qdq(x, 1.0, "per_tensor")  # s=30: round(10/30)=0 -> 0
    np.testing.assert_allclose(out, [[-30.0, 0.0]])


def test_oracle_round_half_even():
    # s = 1 when max|x| == qmax; 0.5 rounds to 0, 1.5 rounds to 2
    x = jnp.asarray([[0.5, 1.5, -0.5, -1.5, 3.0]])
    out = ref.qdq(x, 3.0, "per_tensor")
    np.testing.assert_allclose(out, [[0.0, 2.0, 0.0, -2.0, 3.0]])


def test_oracle_asym_maps_min_max():
    x = jnp.asarray([[0.0, 1.0, 2.0, 3.0]])  # all-positive, like post-GELU
    out = ref.qdq(x, 7.0, "per_token", asymmetric=True)
    # asymmetric must represent the endpoints (sym would waste half the grid)
    np.testing.assert_allclose(out[0, 0], 0.0, atol=1e-6)
    np.testing.assert_allclose(out[0, -1], 3.0, atol=1e-5)


def test_asym_beats_sym_on_positive_data():
    x = jnp.abs(rnd((64, 64), seed=3)) + 0.5
    sym_err = float(jnp.mean((ref.qdq(x, 7.0, "per_token") - x) ** 2))
    asym_err = float(jnp.mean((ref.qdq(x, 7.0, "per_token", asymmetric=True) - x) ** 2))
    assert asym_err < sym_err


# ---------------------------------------------------------------------------
# pallas vs oracle: exact match
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("gran", GRANS)
@pytest.mark.parametrize("bits", BITS)
def test_pallas_matches_ref(gran, bits):
    x = rnd((128, 96), seed=bits)
    qmax = ref.bits_to_qmax(bits)
    a = ref.qdq(x, qmax, gran)
    b = quant.qdq(x, qmax, gran)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.parametrize("bits", BITS)
def test_pallas_asym_matches_ref(bits):
    x = rnd((64, 48), seed=bits + 100)
    qmax = ref.bits_to_qmax(bits)
    a = ref.qdq(x, qmax, "per_token", asymmetric=True)
    b = quant.qdq(x, qmax, "per_token", asymmetric=True)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.parametrize("gran", GRANS)
def test_pallas_3d_input(gran):
    x = rnd((4, 16, 32), seed=7)
    a = ref.qdq(x, 127.0, gran)
    b = quant.qdq(x, 127.0, gran)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@settings(max_examples=25, deadline=None)
@given(
    m=st.integers(1, 96),
    n=st.integers(1, 96),
    bits=st.sampled_from(BITS),
    gran=st.sampled_from(GRANS),
    asym=st.booleans(),
    seed=st.integers(0, 2**31),
    scale=st.sampled_from([1e-4, 1.0, 1e4]),
)
def test_pallas_matches_ref_hypothesis(m, n, bits, gran, asym, seed, scale):
    if asym and gran != "per_token":
        gran = "per_token"
    x = rnd((m, n), seed=seed, scale=scale)
    qmax = ref.bits_to_qmax(bits)
    a = ref.qdq(x, qmax, gran, asymmetric=asym)
    b = quant.qdq(x, qmax, gran, asymmetric=asym)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# adversarial inputs
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("gran", GRANS)
def test_all_zero_tensor(gran):
    x = jnp.zeros((32, 32), jnp.float32)
    out = quant.qdq(x, 127.0, gran)
    assert np.all(np.asarray(out) == 0.0)
    assert np.all(np.isfinite(np.asarray(out)))


def test_single_outlier_channel_per_tensor_destroys_small_values():
    """The paper's Fig. 6/8 mechanism: one outlier channel forces the shared
    scale so high that ordinary channels quantize to zero (per-tensor), while
    per-channel scales preserve them."""
    x = np.full((64, 64), 0.01, np.float32)
    x[:, 13] = 100.0
    x = jnp.asarray(x)
    pt = np.asarray(quant.qdq(x, 7.0, "per_tensor"))
    pc = np.asarray(quant.qdq(x, 7.0, "per_channel"))
    assert np.all(pt[:, 0] == 0.0)  # ordinary channels flushed to zero
    assert np.all(np.abs(pc[:, 0] - 0.01) < 2e-3)  # preserved per-channel


@pytest.mark.parametrize("gran", GRANS)
def test_idempotence(gran):
    x = rnd((32, 64), seed=11)
    once = quant.qdq(x, 7.0, gran)
    twice = quant.qdq(once, 7.0, gran)
    np.testing.assert_allclose(np.asarray(once), np.asarray(twice), atol=1e-6)


@pytest.mark.parametrize("gran", GRANS)
@pytest.mark.parametrize("bits", BITS)
def test_error_bound(gran, bits):
    """Within the clip range, |x_hat - x| <= s/2 (round-to-nearest)."""
    x = rnd((48, 40), seed=bits)
    qmax = ref.bits_to_qmax(bits)
    s = np.asarray(ref.quant_params_sym(x, qmax, gran))
    out = np.asarray(ref.qdq(x, qmax, gran))
    err = np.abs(out - np.asarray(x))
    assert np.all(err <= s / 2 + 1e-7)


def test_more_bits_less_error():
    x = rnd((64, 64), seed=5)
    errs = [
        float(jnp.mean((ref.qdq(x, ref.bits_to_qmax(b), "per_tensor") - x) ** 2))
        for b in [2, 4, 8]
    ]
    assert errs[0] > errs[1] > errs[2]


# ---------------------------------------------------------------------------
# fused qmatmul
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("bits", [4, 8])
def test_qmatmul_matches_ref(bits):
    x = rnd((128, 64), seed=1)
    w = rnd((64, 96), seed=2)
    q = ref.bits_to_qmax(bits)
    a = ref.qmatmul_ref(x, w, q, q)
    b = qmatmul.qmatmul(x, w, q, q)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-5)


@settings(max_examples=15, deadline=None)
@given(
    m=st.sampled_from([8, 32, 100]),
    k=st.sampled_from([16, 64]),
    n=st.sampled_from([8, 48, 128]),
    bits=st.sampled_from([4, 8]),
    seed=st.integers(0, 1000),
)
def test_qmatmul_hypothesis(m, k, n, bits, seed):
    x = rnd((m, k), seed=seed)
    w = rnd((k, n), seed=seed + 1)
    q = ref.bits_to_qmax(bits)
    a = ref.qmatmul_ref(x, w, q, q)
    b = qmatmul.qmatmul(x, w, q, q)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-4, atol=1e-4)


def test_qmatmul_8bit_close_to_fp():
    x = rnd((64, 64), seed=9)
    w = rnd((64, 64), seed=10)
    exact = np.asarray(x @ w)
    q8 = np.asarray(qmatmul.qmatmul(x, w, 127.0, 127.0))
    rel = np.abs(q8 - exact).mean() / np.abs(exact).mean()
    assert rel < 0.02  # 8-bit per-token/channel GEMM stays within ~2%
