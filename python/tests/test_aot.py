"""AOT pipeline: manifest completeness + HLO text validity + reproducibility."""

import json
import os

import jax
import pytest

from compile import aot, steps
from compile.configs import T4
from compile.quantizer import QuantConfig

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def manifest():
    path = os.path.join(ART, "manifest.json")
    if not os.path.exists(path):
        pytest.skip("artifacts not built (run `make artifacts`)")
    with open(path) as f:
        return json.load(f)


def test_manifest_covers_all_structures():
    m = manifest()
    arts = m["artifacts"]
    for s in aot.TRAIN_STRUCTURES:
        assert f"t4/train/{s}" in arts
    for s in aot.EVAL_STRUCTURES:
        assert f"t4/eval/{s}" in arts
    for name in ["t4/probe/act", "t4/probe/grad", "gpt2s/train/base",
                 "gpt2s/train/wa", "gpt2s/eval/base"]:
        assert name in arts
    for size in ["small", "medium", "large", "xl"]:
        for seq in [128, 256, 512, 1024]:
            assert f"prof/linear_{size}_s{seq}" in arts
            assert f"prof/attn_{size}_s{seq}" in arts


def test_artifact_files_exist_and_are_hlo_text():
    m = manifest()
    for name, e in m["artifacts"].items():
        path = os.path.join(ART, e["file"])
        assert os.path.exists(path), name
        with open(path) as f:
            head = f.read(4096)
        assert "HloModule" in head, name
        assert "ENTRY" in open(path).read(), name


def test_train_signature_shapes():
    m = manifest()
    e = m["artifacts"]["t4/train/base"]
    n_tensors = len(m["models"]["t4"]["params"])
    # params + m + v + x,y,lr,t + 5 qmax scalars
    assert len(e["inputs"]) == 3 * n_tensors + 9
    assert len(e["outputs"]) == 3 * n_tensors + 2
    assert e["inputs"][0]["name"] == "wte"
    assert e["inputs"][-1]["name"] == "qmax_m2"
    x = [i for i in e["inputs"] if i["name"] == "x"][0]
    assert x["dtype"] == "i32"
    assert x["shape"] == [T4.batch, T4.seq]


def test_param_layout_matches_model():
    from compile import model as M

    m = manifest()
    defs = M.param_defs(T4)
    mp = m["models"]["t4"]["params"]
    assert [p["name"] for p in mp] == [d.name for d in defs]
    assert [tuple(p["shape"]) for p in mp] == [d.shape for d in defs]
    assert m["models"]["t4"]["n_params"] == T4.n_params()


def test_lowering_is_deterministic():
    """Same function + same spec -> identical HLO text (reproducible AOT)."""
    fn = steps.make_eval_step(T4, QuantConfig())
    spec = aot._spec_of(aot.eval_inputs(T4))
    t1 = aot.to_hlo_text(jax.jit(fn).lower(*spec))
    t2 = aot.to_hlo_text(jax.jit(fn).lower(*spec))
    assert t1 == t2


def test_quant_metadata_recorded():
    m = manifest()
    e = m["artifacts"]["t4/train/wa"]
    assert e["quant"]["weights"]["granularity"] == "per_channel"
    assert e["quant"]["acts"]["granularity"] == "per_token"
    assert e["quant"]["grads"] is None
    e = m["artifacts"]["t4/train/w_pc_pallas"]
    assert e["quant"]["weights"]["backend"] == "pallas"
