"""L2 model correctness: shapes, causality, init loss, scan/unroll parity."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile import model as M
from compile.configs import ModelCfg
from compile.model import QMax
from compile.quantizer import QuantConfig, QuantSpec

CFG = ModelCfg("mini", 2, 32, 2, 64, 16, 4)


def init_params(cfg, seed=0):
    rng = np.random.default_rng(seed)
    out = {}
    for d in M.param_defs(cfg):
        if d.init == "ones":
            out[d.name] = jnp.ones(d.shape, jnp.float32)
        elif d.init == "zeros":
            out[d.name] = jnp.zeros(d.shape, jnp.float32)
        else:
            std = (
                0.02 / np.sqrt(2 * cfg.n_layer)
                if d.init == "residual"
                else float(d.init.split(":")[1])
            )
            out[d.name] = jnp.asarray(rng.normal(0, std, d.shape).astype(np.float32))
    return out


def tokens(cfg, seed=0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.integers(0, cfg.vocab, (cfg.batch, cfg.seq)).astype(np.int32))


def test_forward_shapes():
    params = init_params(CFG)
    logits = M.forward(params, tokens(CFG), CFG, QuantConfig(), QMax.ones())
    assert logits.shape == (CFG.batch, CFG.seq, CFG.vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))


def test_init_loss_near_log_vocab():
    params = init_params(CFG)
    x, y = tokens(CFG, 1), tokens(CFG, 2)
    loss = M.loss_fn(params, x, y, CFG, QuantConfig(), QMax.ones())
    assert abs(float(loss) - np.log(CFG.vocab)) < 0.2


def test_causality():
    """Perturbing a future token must not change past logits."""
    params = init_params(CFG)
    x = tokens(CFG, 3)
    l1 = M.forward(params, x, CFG, QuantConfig(), QMax.ones())
    x2 = x.at[:, -1].set((x[:, -1] + 1) % CFG.vocab)
    l2 = M.forward(params, x2, CFG, QuantConfig(), QMax.ones())
    np.testing.assert_allclose(
        np.asarray(l1[:, :-1]), np.asarray(l2[:, :-1]), atol=1e-5
    )
    assert float(jnp.abs(l1[:, -1] - l2[:, -1]).max()) > 1e-4


def test_scan_matches_unrolled_probe_forward():
    params = init_params(CFG)
    x = tokens(CFG, 4)
    for qcfg in [
        QuantConfig(),
        QuantConfig(weights=QuantSpec("per_channel"), acts=QuantSpec("per_token")),
    ]:
        a = M.forward(params, x, CFG, qcfg, QMax.ones() if qcfg.weights is None else
                      QMax(jnp.asarray(127.0), jnp.asarray(127.0), jnp.ones(())))
        qmax = (QMax.ones() if qcfg.weights is None
                else QMax(jnp.asarray(127.0), jnp.asarray(127.0), jnp.ones(())))
        b, probes = M.forward_probed(params, x, CFG, qcfg, qmax, probe_layer=1)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-4, atol=2e-5)
        assert probes[0].shape == (CFG.batch, CFG.seq, CFG.d_model)
        assert probes[1].shape == (CFG.batch, CFG.seq, CFG.d_ff)


def test_weight_quant_changes_logits():
    params = init_params(CFG)
    x = tokens(CFG, 5)
    base = M.forward(params, x, CFG, QuantConfig(), QMax.ones())
    q4 = M.forward(
        params, x, CFG, QuantConfig(weights=QuantSpec("per_tensor")),
        QMax(jnp.asarray(7.0), jnp.ones(()), jnp.ones(())),
    )
    assert float(jnp.abs(base - q4).max()) > 1e-4


def test_lower_bits_more_logit_error():
    params = init_params(CFG)
    x = tokens(CFG, 6)
    base = M.forward(params, x, CFG, QuantConfig(), QMax.ones())
    errs = []
    for qmax in [1.0, 7.0, 127.0]:  # 2, 4, 8 bits
        q = M.forward(
            params, x, CFG, QuantConfig(weights=QuantSpec("per_channel")),
            QMax(jnp.asarray(qmax), jnp.ones(()), jnp.ones(())),
        )
        errs.append(float(jnp.mean((q - base) ** 2)))
    assert errs[0] > errs[1] > errs[2]


def test_nll_matches_manual():
    logits = jnp.asarray(np.random.default_rng(0).normal(size=(2, 3, 5)).astype(np.float32))
    y = jnp.asarray([[0, 1, 2], [3, 4, 0]], dtype=jnp.int32)
    out = M.nll(logits, y)
    lp = jax.nn.log_softmax(logits, axis=-1)
    manual = -np.take_along_axis(np.asarray(lp), np.asarray(y)[..., None], axis=-1)[..., 0]
    np.testing.assert_allclose(np.asarray(out), manual, rtol=1e-6)


def test_param_count_formula():
    for cfg in [CFG, ModelCfg("t", 4, 128, 4, 512, 128, 16)]:
        total = sum(
            int(np.prod(d.shape)) for d in M.param_defs(cfg)
        )
        assert total == cfg.n_params()
