#!/usr/bin/env bash
# Append the current bench reports to bench_history/.
#
# Runs the floor-gated bench binaries (unless --no-run is given and
# fresh BENCH_*.json files already sit at the repo root), then snapshots
# them under bench_history/<utc-stamp>_<git-sha>/ together with a small
# meta record — so the perf trajectory across PRs lives in-tree and not
# only in expiring CI artifacts. The bench binaries themselves fail on
# any row below the committed floors in rust/tests/bench_baseline.json,
# so every snapshot that lands here already cleared the gate.
#
# Usage: scripts/bench_history.sh [--no-run] [--fast]
#   --no-run  snapshot existing BENCH_*.json without re-running benches
#   --fast    run the benches in QPRETRAIN_BENCH_FAST smoke mode
#             (shorter measurement windows; noisier numbers — the meta
#             record marks the snapshot so trajectories compare like
#             with like)

set -euo pipefail
cd "$(dirname "$0")/.."

run=1
fast=0
for arg in "$@"; do
  case "$arg" in
    --no-run) run=0 ;;
    --fast) fast=1 ;;
    *)
      echo "unknown arg: $arg" >&2
      exit 2
      ;;
  esac
done

if [ "$run" -eq 1 ]; then
  if [ "$fast" -eq 1 ]; then
    QPRETRAIN_BENCH_FAST=1 cargo bench --bench bench_kernels
    QPRETRAIN_BENCH_FAST=1 cargo bench --bench bench_train_loop
    QPRETRAIN_BENCH_FAST=1 cargo bench --bench bench_serve
    QPRETRAIN_BENCH_FAST=1 cargo bench --bench bench_dist
  else
    cargo bench --bench bench_kernels
    cargo bench --bench bench_train_loop
    cargo bench --bench bench_serve
    cargo bench --bench bench_dist
  fi
fi

for f in BENCH_kernels.json BENCH_train_loop.json BENCH_serve.json BENCH_dist.json; do
  if [ ! -f "$f" ]; then
    echo "missing $f at the repo root (run the benches, or drop --no-run)" >&2
    exit 1
  fi
done

sha=$(git rev-parse --short HEAD 2>/dev/null || echo nogit)
stamp=$(date -u +%Y-%m-%dT%H%M%SZ)
dir="bench_history/${stamp}_${sha}"
mkdir -p "$dir"
cp BENCH_kernels.json BENCH_train_loop.json BENCH_serve.json BENCH_dist.json "$dir/"
dirty=false
if ! git diff --quiet 2>/dev/null; then
  dirty=true
fi
cat > "$dir/meta.json" <<EOF
{
  "sha": "$sha",
  "utc": "$stamp",
  "host": "$(uname -sm)",
  "fast_mode": $([ "$fast" -eq 1 ] && echo true || echo false),
  "dirty_worktree": $dirty
}
EOF
echo "snapshotted bench reports to $dir"
