//! L3 coordinator: experiment registry (one entry per paper table/figure),
//! a disk-backed run cache, and a parallel sweep runner.
//!
//! Every training run is identified by a deterministic directory name under
//! `runs/train/...`; completed runs leave a `result.json` + `metrics.jsonl`
//! (+ checkpoint) capped by a terminal `DONE` marker ([`mark_done`]) and
//! are never re-trained — a dir *without* the marker (a worker killed
//! mid-run) is re-trained from scratch. Sweeps with `--jobs N > 1` spawn
//! `qpretrain train ...` worker subprocesses (the PJRT client is not shared
//! across threads; process isolation also mirrors the paper's independent
//! training runs).

pub mod experiments;

use std::io::Write;
use std::path::{Path, PathBuf};
use std::process::Command;

use anyhow::{bail, Context, Result};

use crate::config::{QuantRecipe, TrainHp};
use crate::model::{load_checkpoint, HostState};
use crate::runtime::Runtime;
use crate::train::{train, TrainCfg, TrainResult};
use crate::util::json::{self, Value};

/// Deterministic run directory for a training configuration. The label is
/// the recipe's canonical short form, so pre-redesign run dirs (baseline,
/// w4_pc, w8a8, ...) keep their names.
pub fn run_dir(runs: &Path, model: &str, quant: &QuantRecipe, hp: &TrainHp) -> PathBuf {
    // probe_every changes what the run leaves on disk (act_outliers.csv),
    // so probed runs get their own cache entry.
    let probe = if hp.probe_every > 0 {
        format!("_probe{}", hp.probe_every)
    } else {
        String::new()
    };
    runs.join("train").join(model).join(format!(
        "{}_s{}_seed{}{}",
        quant.label(),
        hp.steps,
        hp.seed,
        probe
    ))
}

/// Summary persisted as `result.json` in each run directory.
#[derive(Debug, Clone)]
pub struct RunSummary {
    pub label: String,
    pub model: String,
    /// Canonical recipe string (`QuantRecipe::to_string()`); old run dirs
    /// hold legacy structure names, which parse as recipe aliases.
    pub structure: String,
    pub steps: usize,
    pub diverged: bool,
    pub diverged_at: Option<usize>,
    pub final_loss: f64,
    pub final_val_loss: f64,
    pub min_val_loss: f64,
    pub steps_per_sec: f64,
    pub dir: PathBuf,
}

impl RunSummary {
    pub fn from_result(cfg: &TrainCfg, r: &TrainResult, dir: &Path) -> RunSummary {
        RunSummary {
            label: r.label.clone(),
            model: cfg.model.clone(),
            structure: cfg.quant.to_string(),
            steps: r.losses.len(),
            diverged: r.diverged,
            diverged_at: r.diverged_at,
            final_loss: r.final_loss(),
            final_val_loss: r.final_val_loss(),
            min_val_loss: r.min_val_loss(),
            steps_per_sec: r.steps_per_sec,
            dir: dir.to_path_buf(),
        }
    }

    pub fn save(&self) -> Result<()> {
        let v = json::obj(vec![
            ("label", json::s(&self.label)),
            ("model", json::s(&self.model)),
            ("structure", json::s(&self.structure)),
            ("steps", json::num(self.steps as f64)),
            ("diverged", Value::Bool(self.diverged)),
            (
                "diverged_at",
                self.diverged_at
                    .map(|s| json::num(s as f64))
                    .unwrap_or(Value::Null),
            ),
            ("final_loss", json::num(self.final_loss)),
            ("final_val_loss", json::num(self.final_val_loss)),
            ("min_val_loss", json::num(self.min_val_loss)),
            ("steps_per_sec", json::num(self.steps_per_sec)),
        ]);
        std::fs::create_dir_all(&self.dir)?;
        std::fs::write(self.dir.join("result.json"), v.to_json())?;
        Ok(())
    }

    pub fn load(dir: &Path) -> Result<RunSummary> {
        let text = std::fs::read_to_string(dir.join("result.json"))
            .with_context(|| format!("no result.json in {dir:?}"))?;
        let v = json::parse(&text)?;
        let f = |k: &str| v.get(k).and_then(|x| x.as_f64()).unwrap_or(f64::NAN);
        Ok(RunSummary {
            label: v.req("label")?.as_str().unwrap_or("").to_string(),
            model: v.req("model")?.as_str().unwrap_or("").to_string(),
            structure: v.req("structure")?.as_str().unwrap_or("").to_string(),
            steps: f("steps") as usize,
            diverged: v.get("diverged").and_then(|x| x.as_bool()).unwrap_or(false),
            diverged_at: v
                .get("diverged_at")
                .and_then(|x| x.as_f64())
                .map(|x| x as usize),
            final_loss: f("final_loss"),
            final_val_loss: f("final_val_loss"),
            min_val_loss: f("min_val_loss"),
            steps_per_sec: f("steps_per_sec"),
            dir: dir.to_path_buf(),
        })
    }

    /// Parse the run's metrics.jsonl (step/loss/gnorm/val rows).
    pub fn metrics(&self) -> Result<Vec<Value>> {
        let text = std::fs::read_to_string(self.dir.join("metrics.jsonl"))?;
        json::parse_jsonl(&text)
    }

    /// Validation-loss curve (step, val_loss).
    pub fn val_curve(&self) -> Result<Vec<(usize, f64)>> {
        Ok(self
            .metrics()?
            .iter()
            .filter_map(|r| {
                let v = r.get("val_loss")?.as_f64()?;
                let s = r.get("step")?.as_usize()?;
                Some((s, v))
            })
            .collect())
    }

    pub fn checkpoint(&self, rt: &Runtime) -> Result<HostState> {
        let model = rt.manifest.model(&self.model)?;
        load_checkpoint(&self.dir.join("final.ckpt"), model)
    }
}

/// Write the terminal `DONE` marker: the run-cache token, written **only
/// after** every other artifact (result.json, metrics, checkpoint, loss
/// curve) has landed. A run dir without it — e.g. a worker killed between
/// artifacts — is treated as absent and re-trained.
pub fn mark_done(dir: &Path) -> Result<()> {
    std::fs::write(dir.join("DONE"), "ok\n")?;
    Ok(())
}

/// Whether `dir` holds a *complete* cached run (see [`mark_done`]).
pub fn is_done(dir: &Path) -> bool {
    dir.join("DONE").exists()
}

/// Execute a single training config, writing run artifacts; returns summary.
pub fn execute_run(rt: &Runtime, mut cfg: TrainCfg, dir: &Path) -> Result<RunSummary> {
    cfg.out_dir = Some(dir.to_path_buf());
    cfg.save_ckpt = true;
    let r = train(rt, &cfg)?;
    let summary = RunSummary::from_result(&cfg, &r, dir);
    summary.save()?;
    // loss curve CSV for plotting
    let mut f = std::fs::File::create(dir.join("loss_curve.csv"))?;
    writeln!(f, "step,loss,gnorm")?;
    for (i, (l, g)) in r.losses.iter().zip(&r.gnorms).enumerate() {
        writeln!(f, "{},{},{}", i + 1, l, g)?;
    }
    mark_done(dir)?;
    Ok(summary)
}

/// Per-worker kernel thread budget when `wave_jobs` training processes run
/// at once (sweep waves, the dist launcher): an explicit pin
/// (`TrainHp::threads` or the process-wide `--threads`) is forwarded
/// as-is; otherwise the machine's thread budget is split across the wave
/// so concurrent workers neither oversubscribe (jobs * all cores) nor idle
/// cores on a short final wave.
pub fn worker_threads(cfg: &TrainCfg, wave_jobs: usize) -> usize {
    if cfg.hp.threads > 0 {
        return cfg.hp.threads;
    }
    match crate::backend::kernels::threads_override() {
        0 => (crate::backend::kernels::max_threads() / wave_jobs.max(1)).max(1),
        pinned => pinned,
    }
}

/// Ensure all configs have completed runs; spawn up to `jobs` worker
/// subprocesses for missing ones (in-process when jobs <= 1).
pub fn ensure_runs(
    rt: &Runtime,
    runs: &Path,
    configs: &[TrainCfg],
    jobs: usize,
) -> Result<Vec<RunSummary>> {
    let mut missing: Vec<(usize, PathBuf)> = Vec::new();
    let mut dirs = Vec::with_capacity(configs.len());
    for (i, cfg) in configs.iter().enumerate() {
        let dir = run_dir(runs, &cfg.model, &cfg.quant, &cfg.hp);
        if !is_done(&dir) {
            missing.push((i, dir.clone()));
        }
        dirs.push(dir);
    }

    if jobs <= 1 {
        for (i, dir) in &missing {
            let cfg = &configs[*i];
            log::info!("training {} ({})", cfg.quant.label(), cfg.model);
            println!("[train] {} ({} steps)", cfg.quant.label(), cfg.hp.steps);
            execute_run(rt, cfg.clone(), dir)?;
        }
    } else {
        for wave in missing.chunks(jobs) {
            let mut children = Vec::new();
            for (i, dir) in wave {
                let cfg = &configs[*i];
                println!("[spawn] {} ({} steps)", cfg.quant.label(), cfg.hp.steps);
                let exe = std::env::current_exe()?;
                let child = Command::new(exe)
                    .args([
                        "train",
                        "--threads",
                        &worker_threads(cfg, wave.len()).to_string(),
                        "--model",
                        &cfg.model,
                        "--quant",
                        &cfg.quant.to_string(),
                        "--steps",
                        &cfg.hp.steps.to_string(),
                        "--seed",
                        &cfg.hp.seed.to_string(),
                        "--probe-every",
                        &cfg.hp.probe_every.to_string(),
                        "--out",
                        dir.to_str().unwrap(),
                        "--quiet",
                    ])
                    .spawn()
                    .with_context(|| "spawning worker")?;
                children.push((cfg.quant.label(), child));
            }
            for (label, mut child) in children {
                let status = child.wait()?;
                if !status.success() {
                    bail!("worker for {label} failed: {status}");
                }
            }
        }
    }

    dirs.iter().map(|d| RunSummary::load(d)).collect()
}

// ---------------------------------------------------------------------------
// report rendering
// ---------------------------------------------------------------------------

/// Render a markdown table.
pub fn md_table(headers: &[&str], rows: &[Vec<String>]) -> String {
    let mut out = String::new();
    out.push_str(&format!("| {} |\n", headers.join(" | ")));
    out.push_str(&format!(
        "|{}\n",
        headers.iter().map(|_| "---|").collect::<String>()
    ));
    for row in rows {
        out.push_str(&format!("| {} |\n", row.join(" | ")));
    }
    out
}

/// Print a report section and append it to `runs/reports/<id>.md`.
pub fn emit_report(runs: &Path, id: &str, title: &str, body: &str) -> Result<()> {
    println!("\n## {title}\n\n{body}");
    let dir = runs.join("reports");
    std::fs::create_dir_all(&dir)?;
    let mut f = std::fs::File::create(dir.join(format!("{id}.md")))?;
    writeln!(f, "# {title}\n\n{body}")?;
    Ok(())
}

pub fn fmt_f(x: f64, prec: usize) -> String {
    if x.is_nan() {
        "diverged".to_string()
    } else {
        format!("{x:.prec$}")
    }
}

/// "ppl or DIV" formatting used across the perplexity tables.
pub fn fmt_ppl(x: f64, diverged: bool) -> String {
    if diverged || !x.is_finite() || x > 1e6 {
        "div".to_string()
    } else {
        format!("{x:.2}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn md_table_renders() {
        let t = md_table(
            &["a", "b"],
            &[vec!["1".into(), "2".into()], vec!["x".into(), "y".into()]],
        );
        assert!(t.contains("| a | b |"));
        assert!(t.lines().count() == 4);
    }

    #[test]
    fn run_dir_is_deterministic_and_label_stable() {
        let hp = TrainHp::default();
        let q = QuantRecipe::none();
        let a = run_dir(Path::new("runs"), "t4", &q, &hp);
        let b = run_dir(Path::new("runs"), "t4", &q, &hp);
        assert_eq!(a, b);
        assert!(a.to_str().unwrap().contains("baseline_s300"));
        // pre-redesign run dirs keep their names through the alias path
        let q = QuantRecipe::parse("w4_pc").unwrap();
        let d = run_dir(Path::new("runs"), "t4", &q, &hp);
        assert!(d.to_str().unwrap().contains("w4_pc_s300"));
        let q = QuantRecipe::parse("w8a8").unwrap();
        let d = run_dir(Path::new("runs"), "t4", &q, &hp);
        assert!(d.to_str().unwrap().contains("w8a8_s300"));
    }

    #[test]
    fn worker_threads_splits_the_budget() {
        let mut cfg = TrainCfg::new("micro", QuantRecipe::none(), TrainHp::default());
        // An explicit per-run pin is forwarded as-is, whatever the wave size.
        cfg.hp.threads = 5;
        for jobs in [1usize, 2, 7] {
            assert_eq!(worker_threads(&cfg, jobs), 5);
        }
        // Without a per-run pin: always >= 1, never more than the machine,
        // and monotonically non-increasing in the wave size. (The
        // process-wide --threads pin, when set — CI legs run with
        // QPRETRAIN_THREADS=7 — wins over the split; that case is the
        // constant function, which satisfies the same invariants.)
        cfg.hp.threads = 0;
        let budget = crate::backend::kernels::max_threads();
        let pinned = crate::backend::kernels::threads_override();
        let mut prev = usize::MAX;
        for jobs in [1usize, 2, 7] {
            let w = worker_threads(&cfg, jobs);
            assert!(w >= 1, "jobs={jobs} gave zero threads");
            assert!(w <= budget.max(pinned), "jobs={jobs} oversubscribes");
            assert!(w <= prev, "budget must not grow with the wave size");
            if pinned == 0 {
                assert_eq!(w, (budget / jobs).max(1));
            } else {
                assert_eq!(w, pinned);
            }
            prev = w;
        }
    }

    #[test]
    fn run_without_done_marker_is_retrained() {
        use crate::runtime::Runtime;
        let runs = std::env::temp_dir().join(format!(
            "qpretrain_done_marker_{}",
            std::process::id()
        ));
        std::fs::remove_dir_all(&runs).ok();
        let hp = TrainHp {
            steps: 1,
            eval_every: 0,
            log_every: usize::MAX,
            seed: 7,
            ..TrainHp::default()
        };
        let cfg = TrainCfg::new("micro", QuantRecipe::none(), hp);
        let rt = Runtime::native();

        // Fresh run: trains, leaves result.json + DONE.
        let s = ensure_runs(&rt, &runs, std::slice::from_ref(&cfg), 1).unwrap();
        let dir = s[0].dir.clone();
        assert!(is_done(&dir));
        let stamp = |p: &Path| std::fs::metadata(p).unwrap().modified().unwrap();
        let first = stamp(&dir.join("result.json"));

        // Complete run: cache hit, nothing rewritten.
        ensure_runs(&rt, &runs, std::slice::from_ref(&cfg), 1).unwrap();
        assert_eq!(stamp(&dir.join("result.json")), first);

        // Interrupted run (result.json present, DONE missing): re-trained.
        std::fs::remove_file(dir.join("DONE")).unwrap();
        std::thread::sleep(std::time::Duration::from_millis(20));
        ensure_runs(&rt, &runs, std::slice::from_ref(&cfg), 1).unwrap();
        assert!(is_done(&dir), "re-train must restore the marker");
        assert!(
            stamp(&dir.join("result.json")) > first,
            "a DONE-less run dir must be re-trained, not served from cache"
        );
        std::fs::remove_dir_all(&runs).ok();
    }

    #[test]
    fn summary_roundtrip() {
        let dir = std::env::temp_dir().join("qpretrain_summary_test");
        std::fs::create_dir_all(&dir).unwrap();
        let s = RunSummary {
            label: "w4_pc".into(),
            model: "t4".into(),
            structure: "w_pc".into(),
            steps: 100,
            diverged: true,
            diverged_at: Some(42),
            final_loss: 3.5,
            final_val_loss: 3.6,
            min_val_loss: 3.4,
            steps_per_sec: 2.0,
            dir: dir.clone(),
        };
        s.save().unwrap();
        let l = RunSummary::load(&dir).unwrap();
        assert_eq!(l.label, "w4_pc");
        assert!(l.diverged);
        assert_eq!(l.diverged_at, Some(42));
        assert!((l.final_loss - 3.5).abs() < 1e-9);
        std::fs::remove_dir_all(dir).ok();
    }
}
