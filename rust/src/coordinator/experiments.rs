//! One experiment per paper table/figure (DESIGN.md §7 index).
//!
//! Every experiment is idempotent: training runs are cached on disk by
//! config, so `experiment all` resumes wherever it stopped, and individual
//! experiments can be re-rendered instantly once their runs exist.

use std::collections::BTreeMap;
use std::io::Write;
use std::path::PathBuf;

use anyhow::{bail, Result};

use crate::config::{Granularity, QuantRecipe, TensorPolicy, TrainHp};
use crate::eval::{fewshot_suite, perplexity_suite};
use crate::runtime::Runtime;
use crate::train::TrainCfg;

use super::{emit_report, ensure_runs, fmt_f, fmt_ppl, md_table, run_dir, RunSummary};

pub struct Ctx {
    pub rt: Runtime,
    pub runs: PathBuf,
    pub steps: usize,
    pub jobs: usize,
    pub eval_batches: usize,
    pub fewshot_episodes: usize,
    pub fewshot_seeds: usize,
}

impl Ctx {
    pub fn hp(&self) -> TrainHp {
        TrainHp {
            steps: self.steps,
            ..TrainHp::default()
        }
    }

    /// Build a t4 training config from a recipe string — the sweep tables
    /// below are plain lists of paper-style recipes.
    fn cfg(&self, recipe: &str) -> TrainCfg {
        TrainCfg::new(
            "t4",
            QuantRecipe::parse(recipe).expect("static sweep recipe"),
            self.hp(),
        )
    }

    fn baseline_cfg(&self) -> TrainCfg {
        self.cfg("base")
    }
}

// cheap analytic reports first, training sweeps next, the slow measured
// timing grid (fig3) last so a budget-limited `all` run loses the least.
pub const ALL: &[&str] = &[
    "fig2", "fig15", "fig4", "tab2", "fig5", "fig6", "fig7", "tab3", "fig8",
    "fig9", "tab4", "fig10", "fig11", "tab5", "fig12", "fig13", "tab1", "tab10",
    "tab11", "abl_bits", "fig3",
];

pub fn run(ctx: &Ctx, id: &str) -> Result<()> {
    match id {
        "fig2" => fig2(ctx),
        "fig15" => fig15(ctx),
        "fig3" => fig3(ctx),
        "fig4" => fig4(ctx),
        "tab2" => tab_eval(ctx, "tab2", "Tables 2+6: weight quantization", &weight_sweep(ctx)),
        "fig5" => fig5(ctx),
        "fig6" => fig6(ctx),
        "fig7" => fig7(ctx),
        "tab3" => tab_eval(ctx, "tab3", "Tables 3+7: activation quantization", &act_sweep(ctx)),
        "fig8" => fig8(ctx),
        "fig9" => fig9(ctx),
        "tab4" => tab_eval(ctx, "tab4", "Tables 4+8: gradient quantization", &grad_sweep(ctx)),
        "fig10" => fig10(ctx),
        "fig11" => fig11(ctx),
        "tab5" => {
            tab_eval(ctx, "tab5", "Tables 5+9: Adam first-moment quantization", &m1_sweep(ctx))
        }
        "fig12" => fig12(ctx),
        "fig13" => fig13(ctx),
        "tab1" => tab1(ctx),
        "tab10" => tab10(ctx),
        "tab11" => tab11(ctx),
        "abl_bits" => abl_bits(ctx),
        "all" => {
            for id in ALL {
                println!("\n================ experiment {id} ================");
                run(ctx, id)?;
            }
            Ok(())
        }
        other => bail!("unknown experiment {other:?}; known: {ALL:?} or 'all'"),
    }
}

// ---------------------------------------------------------------------------
// sweep definitions (paper §4.1-4.5)
// ---------------------------------------------------------------------------

fn weight_sweep(ctx: &Ctx) -> Vec<TrainCfg> {
    ["base", "w4_pt", "w4_pc", "w8_pt", "w8_pc"]
        .iter()
        .map(|r| ctx.cfg(r))
        .collect()
}

fn act_sweep(ctx: &Ctx) -> Vec<TrainCfg> {
    ["base", "a4_pt", "a4_ptok", "a4_ptok_asym", "a8_pt", "a8_ptok"]
        .iter()
        .map(|r| ctx.cfg(r))
        .collect()
}

fn grad_sweep(ctx: &Ctx) -> Vec<TrainCfg> {
    ["base", "g4_pt", "g4_ptok", "g8_pt", "g8_ptok"]
        .iter()
        .map(|r| ctx.cfg(r))
        .collect()
}

fn m1_sweep(ctx: &Ctx) -> Vec<TrainCfg> {
    ["base", "m1_4_pt", "m1_4_pc", "m1_8_pt", "m1_8_pc"]
        .iter()
        .map(|r| ctx.cfg(r))
        .collect()
}

// ---------------------------------------------------------------------------
// generic renderers
// ---------------------------------------------------------------------------

/// Train a sweep and report the validation-loss outcome (a figure's "down"
/// panel in table form) plus a combined loss-curve CSV.
fn train_and_report(
    ctx: &Ctx,
    id: &str,
    title: &str,
    configs: &[TrainCfg],
) -> Result<Vec<RunSummary>> {
    let runs = ensure_runs(&ctx.rt, &ctx.runs, configs, ctx.jobs)?;
    let mut rows = Vec::new();
    for r in &runs {
        rows.push(vec![
            r.label.clone(),
            fmt_f(r.final_val_loss, 4),
            fmt_f(r.min_val_loss, 4),
            if r.diverged {
                format!("yes (step {})", r.diverged_at.unwrap_or(0))
            } else {
                "no".into()
            },
            format!("{:.2}", r.steps_per_sec),
        ]);
    }
    let body = md_table(
        &["config", "final val loss", "min val loss", "diverged", "steps/s"],
        &rows,
    );
    emit_report(&ctx.runs, id, title, &body)?;
    write_val_curves(ctx, id, &runs)?;
    Ok(runs)
}

fn write_val_curves(ctx: &Ctx, id: &str, runs: &[RunSummary]) -> Result<()> {
    let dir = ctx.runs.join("reports");
    std::fs::create_dir_all(&dir)?;
    let mut f = std::fs::File::create(dir.join(format!("{id}_val_curves.csv")))?;
    writeln!(f, "config,step,val_loss")?;
    for r in runs {
        for (s, v) in r.val_curve().unwrap_or_default() {
            writeln!(f, "{},{},{}", r.label, s, v)?;
        }
    }
    Ok(())
}

/// The perplexity + few-shot evaluation table pair (paper Tables 2-9).
fn tab_eval(ctx: &Ctx, id: &str, title: &str, configs: &[TrainCfg]) -> Result<()> {
    let runs = ensure_runs(&ctx.rt, &ctx.runs, configs, ctx.jobs)?;
    let model = ctx.rt.manifest.model("t4")?.clone();

    let mut ppl_rows = Vec::new();
    let mut fs_rows = Vec::new();
    for (cfg, r) in configs.iter().zip(&runs) {
        let state = r.checkpoint(&ctx.rt)?;
        let eval_recipe = cfg.eval_recipe();
        let ppl = perplexity_suite(&ctx.rt, &eval_recipe, &model, &state.params, ctx.eval_batches)?;
        ppl_rows.push(
            std::iter::once(r.label.clone())
                .chain(
                    ["synthwiki103", "synthwiki2", "synthptb", "synth1bw"]
                        .iter()
                        .map(|s| fmt_ppl(*ppl.get(*s).unwrap_or(&f64::NAN), r.diverged)),
                )
                .collect::<Vec<_>>(),
        );

        let fs = fewshot_suite(
            &ctx.rt,
            &eval_recipe,
            &model,
            &state.params,
            ctx.fewshot_episodes,
            ctx.fewshot_seeds,
        )?;
        let mut row = vec![r.label.clone()];
        for (_, mean, sd) in &fs.per_task {
            row.push(format!("{:.1}±{:.1}", 100.0 * mean, 100.0 * sd));
        }
        row.push(format!("{:.2}", 100.0 * fs.average));
        fs_rows.push(row);
    }

    let ppl_tbl = md_table(
        &["config", "synthwiki103 (ppl)", "synthwiki2 (ppl)", "synthptb (ppl)", "synth1bw (ppl)"],
        &ppl_rows,
    );
    let fs_tbl = md_table(
        &[
            "config", "mnli", "mrpc", "rte", "qnli", "sst", "wnli", "arc_easy",
            "arc_chal", "hellaswag", "lambada", "avg",
        ],
        &fs_rows,
    );
    emit_report(
        &ctx.runs,
        id,
        title,
        &format!("### Perplexity\n\n{ppl_tbl}\n### Few-shot accuracy (%)\n\n{fs_tbl}"),
    )
}

// ---------------------------------------------------------------------------
// individual experiments
// ---------------------------------------------------------------------------

fn fig2(ctx: &Ctx) -> Result<()> {
    let csv = crate::memmodel::fig2_table(
        &["small", "medium", "large"],
        &[4, 8, 16, 32, 64],
        1024,
    );
    std::fs::create_dir_all(ctx.runs.join("reports"))?;
    std::fs::write(ctx.runs.join("reports/fig2.csv"), &csv)?;
    let rows: Vec<Vec<String>> = csv
        .lines()
        .skip(1)
        .map(|l| l.split(',').map(String::from).collect())
        .collect();
    let body = md_table(
        &["model", "batch", "peak GB", "params", "grads", "optim", "acts", "logits", "peak phase"],
        &rows,
    );
    emit_report(&ctx.runs, "fig2", "Fig 2/14: peak-memory composition vs batch (ctx 1024)", &body)
}

fn fig15(ctx: &Ctx) -> Result<()> {
    let csv = crate::memmodel::fig15_table(
        &["small", "medium", "large"],
        &[128, 256, 512, 1024, 2048],
        4,
    );
    std::fs::write(ctx.runs.join("reports/fig15.csv"), &csv).ok();
    let rows: Vec<Vec<String>> = csv
        .lines()
        .skip(1)
        .map(|l| l.split(',').map(String::from).collect())
        .collect();
    let body = md_table(
        &["model", "seq", "peak GB", "params", "grads", "optim", "acts", "logits", "peak phase"],
        &rows,
    );
    emit_report(&ctx.runs, "fig15", "Fig 15: peak-memory composition vs seq (batch 4)", &body)
}

fn fig3(ctx: &Ctx) -> Result<()> {
    let rows = crate::timemodel::fig3_rows(3);
    let csv = crate::timemodel::rows_to_csv(&rows);
    std::fs::create_dir_all(ctx.runs.join("reports"))?;
    std::fs::write(ctx.runs.join("reports/fig3.csv"), &csv)?;
    let t_rows: Vec<Vec<String>> = rows
        .iter()
        .map(|r| {
            vec![
                r.size.clone(),
                r.seq.to_string(),
                format!("{:.2}", r.linear_ms),
                format!("{:.2}", r.attn_ms),
                format!("{:.1}%", 100.0 * r.measured_frac),
                format!("{:.1}%", 100.0 * r.analytic_frac),
            ]
        })
        .collect();
    let body = md_table(
        &[
            "model",
            "seq",
            "linear ms",
            "attn ms",
            "linear share (measured)",
            "linear share (analytic)",
        ],
        &t_rows,
    );
    emit_report(&ctx.runs, "fig3", "Fig 3: linear-layer share of block fwd+bwd time", &body)
}

fn fig4(ctx: &Ctx) -> Result<()> {
    let sweep = weight_sweep(ctx);
    train_and_report(ctx, "fig4", "Fig 4: weight quantization during pre-training", &sweep)?;
    Ok(())
}

fn fig5(ctx: &Ctx) -> Result<()> {
    // sharpness of baseline vs weight-quantized checkpoints
    let configs = vec![
        ctx.baseline_cfg(),
        ctx.cfg("w4_pt"),
        ctx.cfg("w4_pc"),
        ctx.cfg("w8_pt"),
    ];
    let runs = ensure_runs(&ctx.rt, &ctx.runs, &configs, ctx.jobs)?;
    let model = ctx.rt.manifest.model("t4")?.clone();
    let radii = [1e-3, 3e-3, 1e-2, 3e-2, 0.1];

    let mut rows = Vec::new();
    let mut curves = Vec::new();
    for (cfg, r) in configs.iter().zip(&runs) {
        let state = r.checkpoint(&ctx.rt)?;
        let c = crate::analysis::m_sharpness(
            &ctx.rt, &cfg.eval_recipe(), &model, &state, &radii, 4, 2,
        )?;
        let mut row = vec![r.label.clone(), fmt_f(c.base_loss, 4)];
        for s in &c.sharpness {
            row.push(format!("{s:.4}"));
        }
        rows.push(row);
        curves.push((r.label.clone(), c));
    }
    let mut headers = vec!["config".to_string(), "base loss".to_string()];
    headers.extend(radii.iter().map(|r| format!("rho={r}")));
    let href: Vec<&str> = headers.iter().map(|s| s.as_str()).collect();
    let tbl = md_table(&href, &rows);

    // loss surfaces for baseline vs w4_pt
    let mut surf_note = String::new();
    for (cfg, r) in configs.iter().zip(&runs).take(2) {
        let state = r.checkpoint(&ctx.rt)?;
        let surf = crate::analysis::loss_surface(
            &ctx.rt, &cfg.eval_recipe(), &model, &state, 0.5, 9, 1,
        )?;
        let path = ctx.runs.join(format!("reports/fig5_surface_{}.csv", r.label));
        std::fs::create_dir_all(ctx.runs.join("reports"))?;
        std::fs::write(&path, surf.to_csv())?;
        // curvature proxy: mean rim loss - center loss
        let center = surf.loss[4][4];
        let rim: f64 = surf.loss.iter().flat_map(|r| r.iter()).sum::<f64>()
            / 81.0;
        surf_note.push_str(&format!(
            "- {}: center loss {:.4}, mean grid loss {:.4} (bowl depth {:.4}) -> {}\n",
            r.label,
            center,
            rim,
            rim - center,
            path.display()
        ));
    }
    emit_report(
        &ctx.runs,
        "fig5",
        "Fig 5: m-sharpness + loss surfaces (baseline vs 4-bit weights)",
        &format!("### m-sharpness (max loss increase)\n\n{tbl}\n### Loss surfaces\n\n{surf_note}"),
    )
}

fn fig6(ctx: &Ctx) -> Result<()> {
    // baseline training with periodic activation probes
    let mut cfg = ctx.baseline_cfg();
    cfg.hp.probe_every = (ctx.steps / 12).max(1);
    let runs = ensure_runs(&ctx.rt, &ctx.runs, &[cfg], ctx.jobs)?;
    let dir = &runs[0].dir;
    let text = std::fs::read_to_string(dir.join("act_outliers.csv"))?;
    let snaps: Vec<(usize, Vec<f32>)> = text
        .lines()
        .map(|l| {
            let mut it = l.split(',');
            let step: usize = it.next().unwrap().parse().unwrap_or(0);
            (step, it.map(|x| x.parse().unwrap_or(0.0)).collect())
        })
        .collect();
    if snaps.len() < 2 {
        bail!("not enough probe snapshots in {dir:?}");
    }
    let k = 8;
    let mut rows = Vec::new();
    for w in snaps.windows(2) {
        let o = crate::analysis::topk_overlap(&w[0].1, &w[1].1, k);
        rows.push(vec![
            format!("{} -> {}", w[0].0, w[1].0),
            format!("{o:.2}"),
        ]);
    }
    let first_last =
        crate::analysis::topk_overlap(&snaps[0].1, &snaps.last().unwrap().1, k);
    rows.push(vec![
        format!("{} -> {} (first vs last)", snaps[0].0, snaps.last().unwrap().0),
        format!("{first_last:.2}"),
    ]);
    let tbl = md_table(&["snapshot pair", &format!("top-{k} channel overlap")], &rows);
    emit_report(
        &ctx.runs,
        "fig6",
        "Fig 6: persistence of activation outlier channels over training",
        &format!(
            "{tbl}\nraw channel abs-max history: {}\n",
            dir.join("act_outliers.csv").display()
        ),
    )
}

fn fig7(ctx: &Ctx) -> Result<()> {
    let sweep = act_sweep(ctx);
    train_and_report(ctx, "fig7", "Fig 7: activation quantization during pre-training", &sweep)?;
    Ok(())
}

fn fig8(ctx: &Ctx) -> Result<()> {
    let configs = vec![ctx.baseline_cfg(), ctx.cfg("a4_pc")];
    let runs = train_and_report(
        ctx,
        "fig8",
        "Fig 8: 4-bit per-channel activation quantization",
        &configs,
    )?;
    // massive activation outliers in FC2 input at the end of training
    let model = ctx.rt.manifest.model("t4")?.clone();
    let state = runs[0].checkpoint(&ctx.rt)?;
    let stats = crate::analysis::activation_stats(&ctx.rt, &model, &state.params)?;
    let mean_ch = crate::util::stats::summarize(&stats.fc2_in_channel_max).mean;
    let note = format!(
        "FC2-input massive outliers (baseline final ckpt): abs-max {:.2}, p99.9 {:.2}, \
         mean channel max {:.3}, max/mean ratio {:.1}x, kurtosis(proj_in) {:.1}\n",
        stats.fc2_in_max,
        stats.fc2_in_p999,
        mean_ch,
        stats.fc2_in_max as f64 / mean_ch.max(1e-9),
        stats.proj_in_kurtosis,
    );
    emit_report(&ctx.runs, "fig8_outliers", "Fig 8 (right): massive activations", &note)
}

fn fig9(ctx: &Ctx) -> Result<()> {
    let sweep = grad_sweep(ctx);
    train_and_report(ctx, "fig9", "Fig 9: gradient quantization during pre-training", &sweep)?;
    Ok(())
}

fn fig10(ctx: &Ctx) -> Result<()> {
    let configs = vec![ctx.cfg("g8_ptok"), ctx.cfg("g8_ptok_actgrad")];
    let runs = train_and_report(
        ctx,
        "fig10",
        "Fig 10: activation-gradient quantization instability",
        &configs,
    )?;
    // gradient histogram + sparsity + quantization error (baseline weights)
    let base = ensure_runs(&ctx.rt, &ctx.runs, &[ctx.baseline_cfg()], ctx.jobs)?;
    let model = ctx.rt.manifest.model("t4")?.clone();
    let state = base[0].checkpoint(&ctx.rt)?;
    let schemes = vec![
        ("int8 per-token".to_string(), TensorPolicy::new(8, Granularity::PerToken)),
        ("int8 per-tensor".to_string(), TensorPolicy::new(8, Granularity::PerTensor)),
        ("int4 per-token".to_string(), TensorPolicy::new(4, Granularity::PerToken)),
        ("int4 per-tensor".to_string(), TensorPolicy::new(4, Granularity::PerTensor)),
    ];
    let g = crate::analysis::gradient_stats(&ctx.rt, &model, &state.params, &schemes)?;
    std::fs::write(
        ctx.runs.join("reports/fig10_grad_hist.csv"),
        g.weight_grad_hist.to_csv(),
    )?;
    let mut rows: Vec<Vec<String>> = g
        .quant_rel_err
        .iter()
        .map(|(n, e)| vec![n.clone(), format!("{e:.4}")])
        .collect();
    rows.push(vec![
        "weight-grad sparsity (|g|<1e-3 max)".into(),
        format!("{:.3}", g.weight_grad_sparsity),
    ]);
    rows.push(vec!["act-grad sparsity".into(), format!("{:.3}", g.act_grad_sparsity)]);
    let spikes: Vec<String> = runs
        .iter()
        .map(|r| format!("{}: {} spikes, diverged={}", r.label, r.steps, r.diverged))
        .collect();
    let tbl = md_table(&["metric", "value"], &rows);
    emit_report(
        &ctx.runs,
        "fig10_stats",
        "Fig 10 (down): gradient sparsity and quantization error",
        &format!("{tbl}\n{}\n", spikes.join("\n")),
    )
}

fn fig11(ctx: &Ctx) -> Result<()> {
    train_and_report(ctx, "fig11", "Fig 11: Adam first-moment quantization", &m1_sweep(ctx))?;
    Ok(())
}

fn fig12(ctx: &Ctx) -> Result<()> {
    let configs = vec![ctx.cfg("m2_8_pc"), ctx.cfg("m2_8_pt")];
    train_and_report(ctx, "fig12", "Fig 12: Adam second-moment quantization", &configs)?;
    // zero-bin analysis on healthy (baseline) second moments
    let base = ensure_runs(&ctx.rt, &ctx.runs, &[ctx.baseline_cfg()], ctx.jobs)?;
    let model = ctx.rt.manifest.model("t4")?.clone();
    let state = base[0].checkpoint(&ctx.rt)?;
    let rep_pc =
        crate::analysis::m2_zero_bin(&state, &model, TensorPolicy::new(8, Granularity::PerChannel));
    let rep_pt =
        crate::analysis::m2_zero_bin(&state, &model, TensorPolicy::new(8, Granularity::PerTensor));
    std::fs::write(ctx.runs.join("reports/fig12_v_hist.csv"), rep_pc.v_hist.to_csv())?;
    let mut rows = Vec::new();
    for ((name, pc), (_, pt)) in rep_pc.per_tensor.iter().zip(&rep_pt.per_tensor) {
        rows.push(vec![name.clone(), format!("{:.3}", pt), format!("{:.3}", pc)]);
    }
    let tbl = md_table(
        &["tensor", "zero-bin frac (8b per-tensor)", "zero-bin frac (8b per-channel)"],
        &rows,
    );
    emit_report(
        &ctx.runs,
        "fig12_zerobin",
        "Fig 12 (down): second-moment zero-bin collapse",
        &tbl,
    )
}

fn fig13(ctx: &Ctx) -> Result<()> {
    let configs = vec![ctx.baseline_cfg(), ctx.cfg("w8a8"), ctx.cfg("w8a8g8")];
    train_and_report(ctx, "fig13", "Fig 13: combined W/A/G 8-bit quantization", &configs)?;
    Ok(())
}

fn tab1(ctx: &Ctx) -> Result<()> {
    let short = ctx.baseline_cfg();
    let mut long = ctx.baseline_cfg();
    long.hp.steps = ctx.steps * 2;
    let runs = ensure_runs(&ctx.rt, &ctx.runs, &[short.clone(), long.clone()], ctx.jobs)?;
    let model = ctx.rt.manifest.model("t4")?.clone();
    let mut rows = Vec::new();
    for (cfg, r) in [short, long].iter().zip(&runs) {
        let state = r.checkpoint(&ctx.rt)?;
        let ppl = perplexity_suite(
            &ctx.rt, &cfg.eval_recipe(), &model, &state.params, ctx.eval_batches,
        )?;
        rows.push(
            std::iter::once(format!("{} steps", cfg.hp.steps))
                .chain(
                    ["synthwiki103", "synthwiki2", "synthptb", "synth1bw"]
                        .iter()
                        .map(|s| fmt_ppl(*ppl.get(*s).unwrap_or(&f64::NAN), false)),
                )
                .collect(),
        );
    }
    let tbl = md_table(
        &["model", "synthwiki103", "synthwiki2", "synthptb", "synth1bw"],
        &rows,
    );
    emit_report(&ctx.runs, "tab1", "Table 1: baseline vs longer-pretrained model", &tbl)
}

fn tab10(ctx: &Ctx) -> Result<()> {
    let base = ensure_runs(&ctx.rt, &ctx.runs, &[ctx.baseline_cfg()], ctx.jobs)?;
    let model = ctx.rt.manifest.model("t4")?.clone();
    let state = base[0].checkpoint(&ctx.rt)?;
    let mut rows = Vec::new();
    for bits in [4u32, 8] {
        for gran in [Granularity::PerTensor, Granularity::PerChannel] {
            let ppl =
                crate::ptq::ptq_weights_ppl(&ctx.rt, &model, &state, bits, gran, ctx.eval_batches)?;
            rows.push(
                std::iter::once(format!("{bits}-bit {}", gran.as_str()))
                    .chain(
                        ["synthwiki103", "synthwiki2", "synthptb", "synth1bw"]
                            .iter()
                            .map(|s| fmt_ppl(*ppl.get(*s).unwrap_or(&f64::NAN), false)),
                    )
                    .collect(),
            );
        }
    }
    let tbl = md_table(
        &["PTQ weights", "synthwiki103", "synthwiki2", "synthptb", "synth1bw"],
        &rows,
    );
    emit_report(&ctx.runs, "tab10", "Table 10: post-training weight quantization", &tbl)
}

fn tab11(ctx: &Ctx) -> Result<()> {
    let base = ensure_runs(&ctx.rt, &ctx.runs, &[ctx.baseline_cfg()], ctx.jobs)?;
    let model = ctx.rt.manifest.model("t4")?.clone();
    let state = base[0].checkpoint(&ctx.rt)?;
    let mut rows = Vec::new();
    for bits in [4u32, 8] {
        for gran in [Granularity::PerTensor, Granularity::PerToken] {
            let ppl =
                crate::ptq::ptq_acts_ppl(&ctx.rt, &model, &state, bits, gran, ctx.eval_batches)?;
            rows.push(
                std::iter::once(format!("{bits}-bit {}", gran.as_str()))
                    .chain(
                        ["synthwiki103", "synthwiki2", "synthptb", "synth1bw"]
                            .iter()
                            .map(|s| fmt_ppl(*ppl.get(*s).unwrap_or(&f64::NAN), false)),
                    )
                    .collect(),
            );
        }
    }
    let tbl = md_table(
        &["PTQ activations", "synthwiki103", "synthwiki2", "synthptb", "synth1bw"],
        &rows,
    );
    emit_report(&ctx.runs, "tab11", "Table 11: post-training activation quantization", &tbl)
}

/// Extension ablation: bit-width sweep on the recommended per-channel weight
/// scheme (one artifact, qmax runtime scalar).
fn abl_bits(ctx: &Ctx) -> Result<()> {
    let mut configs = vec![ctx.baseline_cfg()];
    for bits in [2u32, 3, 4, 6, 8] {
        configs.push(ctx.cfg(&format!("w{bits}_pc")));
    }
    let runs = ensure_runs(&ctx.rt, &ctx.runs, &configs, ctx.jobs)?;
    let rows: Vec<Vec<String>> = runs
        .iter()
        .map(|r| {
            vec![
                r.label.clone(),
                fmt_f(r.final_val_loss, 4),
                if r.diverged {
                    "yes".into()
                } else {
                    "no".into()
                },
            ]
        })
        .collect();
    let tbl = md_table(&["config", "final val loss", "diverged"], &rows);
    emit_report(
        &ctx.runs,
        "abl_bits",
        "Ablation: weight bit-width sweep (per-channel, runtime qmax)",
        &tbl,
    )
}

/// Lookup the baseline run directory (for CLI subcommands that need a ckpt).
pub fn baseline_dir(ctx: &Ctx) -> PathBuf {
    run_dir(&ctx.runs, "t4", &QuantRecipe::none(), &ctx.hp())
}

/// Summaries of every cached run (for `qpretrain report`).
pub fn all_summaries(runs: &PathBuf) -> Vec<RunSummary> {
    let mut out = Vec::new();
    let Ok(models) = std::fs::read_dir(runs.join("train")) else {
        return out;
    };
    for m in models.flatten() {
        if let Ok(entries) = std::fs::read_dir(m.path()) {
            for e in entries.flatten() {
                if let Ok(s) = RunSummary::load(&e.path()) {
                    out.push(s);
                }
            }
        }
    }
    out.sort_by(|a, b| a.label.cmp(&b.label));
    out
}

/// Aggregate per-experiment report files into one markdown document.
pub fn combined_report(runs: &PathBuf) -> Result<String> {
    let mut out = String::from("# qpretrain experiment reports\n\n");
    let dir = runs.join("reports");
    let mut files: Vec<_> = std::fs::read_dir(&dir)?
        .flatten()
        .map(|e| e.path())
        .filter(|p| p.extension().map(|e| e == "md").unwrap_or(false))
        .collect();
    files.sort();
    for f in files {
        out.push_str(&std::fs::read_to_string(&f)?);
        out.push('\n');
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sweep_recipes_parse_and_stay_artifact_compatible() {
        // every sweep recipe must parse, and each one must still map to a
        // legacy artifact structure so the pjrt backend can run the sweeps
        let sweep_recipes = [
            "base", "w4_pt", "w4_pc", "w8_pt", "w8_pc", "a4_pt", "a4_ptok",
            "a4_ptok_asym", "a8_pt", "a8_ptok", "a4_pc", "g4_pt", "g4_ptok",
            "g8_pt", "g8_ptok", "g8_ptok_actgrad", "m1_4_pt", "m1_4_pc",
            "m1_8_pt", "m1_8_pc", "m2_8_pc", "m2_8_pt", "w8a8", "w8a8g8",
        ];
        for r in sweep_recipes {
            let recipe = QuantRecipe::parse(r).unwrap();
            assert!(
                recipe.legacy_structure().is_some(),
                "{r} has no artifact structure"
            );
        }
    }

    #[test]
    fn all_experiment_ids_unique() {
        let mut ids = ALL.to_vec();
        ids.sort();
        ids.dedup();
        assert_eq!(ids.len(), ALL.len());
    }
}
