//! qpretrain CLI — the L3 coordinator entrypoint.
//!
//! Subcommands:
//!   train        train one configuration (also the worker mode used by the
//!                parallel sweep runner)
//!   dist-train   N-process data-parallel training (`--dp N`), bit-identical
//!                to single-process at matched global batch
//!   dist-worker  internal rank-k entrypoint spawned by dist-train
//!   eval         perplexity + few-shot suite on a checkpoint
//!   ptq          post-training quantization of a checkpoint
//!   sharpness    m-sharpness of a checkpoint
//!   losssurface  2-D loss surface scan of a checkpoint
//!   memprofile   analytic peak-memory tables (Figs. 2/14/15)
//!   timeprofile  linear-vs-attention time share (Fig. 3, native kernels)
//!   experiment   reproduce a paper table/figure (or `all`)
//!   report       aggregate all experiment reports
//!   generate     KV-cached autoregressive decode from a checkpoint
//!   serve        batched quantized inference over many requests
//!                (continuous batching + packed-int8 resident weights)
//!   selftest     runtime validation: native backend vs the quant oracle
//!   digest       deterministic micro-train digest (losses/params bit
//!                fingerprints) for cross-leg CI equivalence diffs
//!   list         list models / recipe grammar / experiments
//!
//! The default build runs everything on the pure-rust native backend; with
//! `--features pjrt` and `make artifacts`, the same commands execute the
//! AOT-lowered HLO artifacts instead.

use std::path::PathBuf;

use anyhow::{anyhow, bail, Result};

use qpretrain::config::{DistTransport, Granularity, QuantRecipe, TrainHp};
use qpretrain::coordinator::{self, experiments};
use qpretrain::model::load_checkpoint;
use qpretrain::runtime::Runtime;
use qpretrain::util::cli::Args;
use qpretrain::util::repo_root;

fn main() {
    let args = match Args::from_env() {
        Ok(a) => a,
        Err(e) => {
            eprintln!("argument error: {e}");
            std::process::exit(2);
        }
    };
    if let Err(e) = dispatch(&args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn runs_dir(args: &Args) -> PathBuf {
    args.get("runs")
        .map(PathBuf::from)
        .unwrap_or_else(|| repo_root().join(qpretrain::RUNS_DIR))
}

fn hp_from(args: &Args) -> Result<TrainHp> {
    let mut hp = TrainHp {
        steps: args.usize_or("steps", 300)?,
        seed: args.u64_or("seed", 1337)?,
        probe_every: args.usize_or("probe-every", 0)?,
        threads: args.usize_or("threads", 0)?,
        ..TrainHp::default()
    };
    hp.lr_max = args.f64_or("lr", hp.lr_max)?;
    hp.lr_min = args.f64_or("lr-min", hp.lr_max / 10.0)?;
    hp.warmup = args.usize_or("warmup", hp.warmup)?;
    hp.eval_every = args.usize_or("eval-every", hp.eval_every)?;
    hp.eval_batches = args.usize_or("eval-batches", hp.eval_batches)?;
    hp.dp = args.usize_or("dp", 1)?;
    hp.dist_transport = DistTransport::parse(&args.get_or("transport", "filesystem"))?;
    hp.dist_overlap = on_off(args, "overlap", hp.dist_overlap)?;
    hp.dist_listen = args.get("listen").map(str::to_string);
    hp.dist_connect = args.get("connect").map(str::to_string);
    Ok(hp)
}

fn on_off(args: &Args, key: &str, default: bool) -> Result<bool> {
    match args.get(key) {
        None => Ok(default),
        Some("on") => Ok(true),
        Some("off") => Ok(false),
        Some(v) => bail!("--{key} expects on|off, got {v:?}"),
    }
}

/// Recipe from the CLI: `--quant <recipe>` is the primary interface; the
/// legacy `--structure` + `--wbits/--abits/...` flags still work (the
/// structure name parses as a recipe alias, bit flags override per class).
fn quant_from(args: &Args) -> Result<QuantRecipe> {
    let spec = args
        .get("quant")
        .map(str::to_string)
        .unwrap_or_else(|| args.get_or("structure", "base"));
    QuantRecipe::parse(&spec)?.with_bits(
        args.bits_or("wbits", 0)?,
        args.bits_or("abits", 0)?,
        args.bits_or("gbits", 0)?,
        args.bits_or("m1bits", 0)?,
        args.bits_or("m2bits", 0)?,
    )
}

fn ctx_from(args: &Args) -> Result<experiments::Ctx> {
    Ok(experiments::Ctx {
        rt: Runtime::open_default()?,
        runs: runs_dir(args),
        steps: args.usize_or("steps", 300)?,
        jobs: args.usize_or("jobs", default_jobs())?,
        eval_batches: args.usize_or("eval-batches", 8)?,
        fewshot_episodes: args.usize_or("fewshot-episodes", 24)?,
        fewshot_seeds: args.usize_or("fewshot-seeds", 3)?,
    })
}

fn default_jobs() -> usize {
    std::thread::available_parallelism()
        .map(|n| (n.get() / 2).clamp(1, 8))
        .unwrap_or(1)
}

fn dispatch(args: &Args) -> Result<()> {
    // kernel worker threads for every subcommand (train, timeprofile,
    // experiment sweeps, …); 0/absent = RAYON_NUM_THREADS or all cores.
    // Results are bit-identical at every thread count.
    qpretrain::backend::kernels::set_threads(args.usize_or("threads", 0)?);
    match args.subcommand.as_str() {
        "train" => cmd_train(args),
        "dist-train" => cmd_dist_train(args),
        "dist-worker" => cmd_dist_worker(args),
        "eval" => cmd_eval(args),
        "ptq" => cmd_ptq(args),
        "sharpness" => cmd_sharpness(args),
        "losssurface" => cmd_losssurface(args),
        "memprofile" => cmd_memprofile(args),
        "timeprofile" => cmd_timeprofile(args),
        "experiment" => cmd_experiment(args),
        "report" => cmd_report(args),
        "generate" => cmd_generate(args),
        "serve" => cmd_serve(args),
        "selftest" => cmd_selftest(args),
        "digest" => cmd_digest(args),
        "list" => cmd_list(args),
        "" | "help" => {
            print_help();
            Ok(())
        }
        other => bail!("unknown subcommand {other:?} (try `qpretrain help`)"),
    }
}

fn print_help() {
    println!(
        "qpretrain — quantized pre-training study (EMNLP 2024 Findings reproduction)

USAGE: qpretrain <subcommand> [--options]

  train        --model t4|micro|gpt2s --quant w8_pc --steps 300 [--out DIR]
               (--quant takes any recipe, e.g. w4_pc+a8_ptok+g8_ptok+m1_8_pt+m2_8_pc;
                legacy --structure w_pc --wbits 8 flags still work)
  dist-train   --model micro --quant w8a8g8 --steps 300 --dp 2 [--out DIR]
               [--transport filesystem|channel|socket] [--overlap on|off]
               [--listen HOST:PORT]
               N-way data parallelism: worker processes over the run-dir
               exchange protocol (<out>/dist), worker threads of this
               process over in-memory channels (--transport channel, no
               out dir needed), or worker processes dialing rank 0 over
               TCP (--transport socket: rank 0 binds --listen, default
               127.0.0.1:0, and spawns workers pointed at the bound
               address; a versioned QDGH handshake rejects strangers).
               --overlap on (the default) publishes each cover subtree
               the moment its leaf range finishes backward. Gradients
               ship int8 when the recipe's g policy is 8-bit symmetric
               pt/ptok, f32 otherwise. Bit-identical to --dp 1 at matched
               global batch on every transport/overlap combination.
  eval         --ckpt runs/train/t4/baseline_s300_seed1337 [--suite ppl|fewshot|all]
  ptq          --ckpt DIR --mode weights|acts --bits 8 --gran per_channel
  sharpness    --ckpt DIR [--radii 0.001,0.01,0.1]
  losssurface  --ckpt DIR [--grid 9 --extent 0.5]
  memprofile   [--batches 4,8,16,32,64] (Fig 2/14/15 analytic model)
  timeprofile  [--reps 3]               (Fig 3 measured on native kernels)
  experiment   <fig2|fig3|fig4|...|tab10|tab11|abl_bits|all> [--steps N --jobs K]
  report       aggregate runs/reports/*.md
  generate     --ckpt DIR [--prompt 3,17,42 | --prompt-len 8] --max-new 32
               [--temperature 0.8 --top-k 40 --seed 7] [--ptq-bits 8]
               KV-cached greedy/sampled decode; identical token stream at
               every thread count and with SIMD on or off
  serve        --ckpt DIR --requests 16 --max-batch 8 [--max-seq 128]
               continuous batching over concurrent sessions with packed
               int8 weights resident in memory (bitwise-equal to
               one-at-a-time decode); prints tokens/s, TTFT, occupancy
  selftest     native-backend validation against the rust quant oracle
  digest       [--steps 8 --out digest.json --dp N]
               [--transport filesystem|channel|socket] [--overlap on|off]
               deterministic micro-train digest; byte-identical across
               threads, QPRETRAIN_SIMD / QPRETRAIN_INT8 legs, every --dp,
               all three transports and both overlap settings
  list         models / recipe grammar / experiments

Global options:
  --threads N  kernel worker threads (default: RAYON_NUM_THREADS, else all
               cores). Results are bit-identical at every thread count.

Env knobs: QPRETRAIN_SIMD=off pins the scalar lane emulation;
QPRETRAIN_INT8=off pins the f32 fold of the packed-GEMM integer code
products (both are bit-transparency switches, not numerics changes);
QPRETRAIN_DIST_TIMEOUT_SECS sets the dist exchange deadline (default
120; 0 = frames must already be available — fail instead of waiting).

The default build uses the pure-rust native backend. Build with
`--features pjrt` (plus `make artifacts`) to execute AOT HLO artifacts."
    );
}

// ---------------------------------------------------------------------------

fn cmd_train(args: &Args) -> Result<()> {
    let rt = Runtime::open_default()?;
    let quant = quant_from(args)?;
    let hp = hp_from(args)?;
    let model = args.get_or("model", "t4");
    let mut cfg = qpretrain::train::TrainCfg::new(&model, quant, hp);
    cfg.stop_on_divergence = !args.flag("no-early-stop");

    let out = args
        .get("out")
        .map(PathBuf::from)
        .unwrap_or_else(|| coordinator::run_dir(&runs_dir(args), &model, &cfg.quant, &cfg.hp));
    let summary = coordinator::execute_run(&rt, cfg.clone(), &out)?;
    if !args.flag("quiet") {
        println!(
            "{}: final loss {:.4}, val {:.4}, diverged={}, {:.2} steps/s -> {}",
            summary.label,
            summary.final_loss,
            summary.final_val_loss,
            summary.diverged,
            summary.steps_per_sec,
            out.display()
        );
    }
    Ok(())
}

/// `dist-train`: the N-process data-parallel leader. Same interface as
/// `train` plus `--dp N`; this process is rank 0 and spawns ranks 1..N as
/// `dist-worker` subprocesses exchanging gradient frames under
/// `<out>/dist`. Results are bit-identical at every `--dp` (the reduction
/// tree is shaped by the global batch alone) — `digest --dp` proves it.
fn cmd_dist_train(args: &Args) -> Result<()> {
    let rt = Runtime::open_default()?;
    let quant = quant_from(args)?;
    let hp = hp_from(args)?;
    let model = args.get_or("model", "t4");
    let mut cfg = qpretrain::train::TrainCfg::new(&model, quant, hp);
    cfg.stop_on_divergence = !args.flag("no-early-stop");

    let out = args.get("out").map(PathBuf::from).unwrap_or_else(|| {
        // Own cache namespace: the sharded trainer's tree numerics differ
        // from the whole-batch `train` step, so the dirs must not collide.
        let base = coordinator::run_dir(&runs_dir(args), &model, &cfg.quant, &cfg.hp);
        let name = base.file_name().unwrap_or_default().to_string_lossy().into_owned();
        base.with_file_name(format!("{name}_dp{}", cfg.hp.dp.max(1)))
    });
    let summary = qpretrain::dist::execute_dist_run(&rt, cfg.clone(), &out)?;
    if !args.flag("quiet") {
        println!(
            "{} (dp={}): final loss {:.4}, val {:.4}, diverged={}, {:.2} steps/s -> {}",
            summary.label,
            cfg.hp.dp.max(1),
            summary.final_loss,
            summary.final_val_loss,
            summary.diverged,
            summary.steps_per_sec,
            out.display()
        );
    }
    Ok(())
}

/// `dist-worker`: internal rank-k entrypoint spawned by `dist-train`.
/// Filesystem workers need `--out` (the leader's run dir holds the
/// exchange protocol); socket workers need `--connect` instead (the
/// leader's bound address) — `dist_worker` rejects a missing one loudly.
fn cmd_dist_worker(args: &Args) -> Result<()> {
    let rt = Runtime::open_default()?;
    let quant = quant_from(args)?;
    let hp = hp_from(args)?;
    let rank = args.usize_or("rank", 0)?;
    let model = args.get_or("model", "t4");
    let mut cfg = qpretrain::train::TrainCfg::new(&model, quant, hp);
    cfg.stop_on_divergence = !args.flag("no-early-stop");
    cfg.out_dir = args.get("out").map(PathBuf::from);
    qpretrain::dist::dist_worker(&rt, &cfg, rank)
}

fn open_ckpt(
    args: &Args,
    rt: &Runtime,
) -> Result<(qpretrain::runtime::ModelInfo, qpretrain::model::HostState, QuantRecipe)> {
    let dir = PathBuf::from(args.req("ckpt")?);
    let path = if dir.is_dir() {
        dir.join("final.ckpt")
    } else {
        dir.clone()
    };
    // infer model + training recipe from result.json when present
    let (model_name, spec) = match coordinator::RunSummary::load(
        dir.parent().map(|_| dir.as_path()).unwrap_or(&dir),
    ) {
        Ok(s) => (s.model, s.structure),
        Err(_) => (
            args.get_or("model", "t4"),
            args.get_or("quant", &args.get_or("structure", "base")),
        ),
    };
    let model = rt.model(&model_name)?.clone();
    let state = load_checkpoint(&path, &model)?;
    let eval_recipe = QuantRecipe::parse(&spec)?.forward_only();
    Ok((model, state, eval_recipe))
}

fn cmd_eval(args: &Args) -> Result<()> {
    let rt = Runtime::open_default()?;
    let (model, state, eval_recipe) = open_ckpt(args, &rt)?;
    let recipe = eval_recipe.with_bits(
        args.bits_or("wbits", 0)?,
        args.bits_or("abits", 0)?,
        0,
        0,
        0,
    )?;
    let suite = args.get_or("suite", "all");
    if suite == "ppl" || suite == "all" {
        let ppl = qpretrain::eval::perplexity_suite(
            &rt,
            &recipe,
            &model,
            &state.params,
            args.usize_or("eval-batches", 8)?,
        )?;
        for (k, v) in &ppl {
            println!("{k}: ppl {v:.2}");
        }
    }
    if suite == "fewshot" || suite == "all" {
        let fs = qpretrain::eval::fewshot_suite(
            &rt,
            &recipe,
            &model,
            &state.params,
            args.usize_or("fewshot-episodes", 24)?,
            args.usize_or("fewshot-seeds", 3)?,
        )?;
        for (t, mean, sd) in &fs.per_task {
            println!("{}: {:.1}% ± {:.1}", t.name(), 100.0 * mean, 100.0 * sd);
        }
        println!("paper-average: {:.2}%", 100.0 * fs.average);
    }
    Ok(())
}

fn cmd_ptq(args: &Args) -> Result<()> {
    let rt = Runtime::open_default()?;
    let (model, state, _) = open_ckpt(args, &rt)?;
    let bits = args.bits_or("bits", 8)?;
    let gran = Granularity::parse(&args.get_or("gran", "per_channel"))?;
    let n_batches = args.usize_or("eval-batches", 8)?;
    let mode = args.get_or("mode", "weights");
    let ppl = match mode.as_str() {
        "weights" => qpretrain::ptq::ptq_weights_ppl(&rt, &model, &state, bits, gran, n_batches)?,
        "acts" => qpretrain::ptq::ptq_acts_ppl(&rt, &model, &state, bits, gran, n_batches)?,
        other => bail!("unknown --mode {other:?} (weights|acts)"),
    };
    println!("PTQ {mode} {bits}-bit {}:", gran.as_str());
    for (k, v) in &ppl {
        println!("  {k}: ppl {v:.2}");
    }
    Ok(())
}

fn cmd_sharpness(args: &Args) -> Result<()> {
    let rt = Runtime::open_default()?;
    let (model, state, eval_recipe) = open_ckpt(args, &rt)?;
    let radii: Vec<f64> = args
        .get_or("radii", "0.001,0.003,0.01,0.03,0.1")
        .split(',')
        .map(|s| s.parse().map_err(|_| anyhow!("bad radius {s:?}")))
        .collect::<Result<_>>()?;
    let recipe = eval_recipe.with_bits(
        args.bits_or("wbits", 0)?,
        args.bits_or("abits", 0)?,
        0,
        0,
        0,
    )?;
    let c = qpretrain::analysis::m_sharpness(
        &rt,
        &recipe,
        &model,
        &state,
        &radii,
        args.usize_or("dirs", 4)?,
        args.usize_or("eval-batches", 2)?,
    )?;
    println!("base loss: {:.4}", c.base_loss);
    for (r, s) in c.radii.iter().zip(&c.sharpness) {
        println!("rho={r}: max loss increase {s:.4}");
    }
    Ok(())
}

fn cmd_losssurface(args: &Args) -> Result<()> {
    let rt = Runtime::open_default()?;
    let (model, state, eval_recipe) = open_ckpt(args, &rt)?;
    let recipe = eval_recipe.with_bits(
        args.bits_or("wbits", 0)?,
        args.bits_or("abits", 0)?,
        0,
        0,
        0,
    )?;
    let surf = qpretrain::analysis::loss_surface(
        &rt,
        &recipe,
        &model,
        &state,
        args.f64_or("extent", 0.5)?,
        args.usize_or("grid", 9)?,
        args.usize_or("eval-batches", 1)?,
    )?;
    let out = args.get_or("out", "loss_surface.csv");
    std::fs::write(&out, surf.to_csv())?;
    println!("wrote {out}");
    Ok(())
}

fn cmd_memprofile(args: &Args) -> Result<()> {
    let batches: Vec<usize> = args
        .get_or("batches", "4,8,16,32,64")
        .split(',')
        .map(|s| s.parse().unwrap_or(4))
        .collect();
    print!(
        "{}",
        qpretrain::memmodel::fig2_table(&["small", "medium", "large"], &batches, 1024)
    );
    println!();
    print!(
        "{}",
        qpretrain::memmodel::fig15_table(
            &["small", "medium", "large"],
            &[128, 256, 512, 1024, 2048],
            4
        )
    );
    Ok(())
}

fn cmd_timeprofile(args: &Args) -> Result<()> {
    let rows = qpretrain::timemodel::fig3_rows(args.usize_or("reps", 3)?);
    print!("{}", qpretrain::timemodel::rows_to_csv(&rows));
    Ok(())
}

fn cmd_experiment(args: &Args) -> Result<()> {
    let id = args
        .positional
        .first()
        .ok_or_else(|| anyhow!("usage: qpretrain experiment <id|all>"))?
        .clone();
    let ctx = ctx_from(args)?;
    experiments::run(&ctx, &id)
}

fn cmd_report(args: &Args) -> Result<()> {
    let runs = runs_dir(args);
    let summaries = experiments::all_summaries(&runs);
    println!("{} cached training runs:", summaries.len());
    for s in &summaries {
        println!(
            "  {:<24} {} steps  val {:<8} diverged={}",
            s.label,
            s.steps,
            coordinator::fmt_f(s.final_val_loss, 4),
            s.diverged
        );
    }
    let combined = experiments::combined_report(&runs)?;
    let out = runs.join("reports/ALL.md");
    std::fs::write(&out, &combined)?;
    println!("combined report -> {}", out.display());
    Ok(())
}

// ---------------------------------------------------------------------------
// serving
// ---------------------------------------------------------------------------

/// Model + params + forward recipe for `generate` / `serve`: `--ckpt DIR`
/// loads a trained checkpoint (model + recipe inferred from the run
/// summary), otherwise `--model NAME --init-seed N` decodes from a random
/// init (smoke tests, digests). `--ptq-bits N [--ptq-gran G]` additionally
/// post-training-quantizes the block-linear weights in place before the
/// engine packs them into their resident form.
fn serve_state(
    args: &Args,
    rt: &Runtime,
) -> Result<(qpretrain::runtime::ModelInfo, qpretrain::model::HostState, QuantRecipe)> {
    let (model, mut state, recipe) = if args.get("ckpt").is_some() {
        open_ckpt(args, rt)?
    } else {
        let model = rt.model(&args.get_or("model", "micro"))?.clone();
        let state = qpretrain::model::init_state(&model, args.u64_or("init-seed", 1337)?);
        (model, state, quant_from(args)?.forward_only())
    };
    let ptq_bits = args.bits_or("ptq-bits", 0)?;
    if ptq_bits > 0 {
        let gran = Granularity::parse(&args.get_or("ptq-gran", "per_channel"))?;
        qpretrain::ptq::quantize_weights(
            &mut state,
            &model,
            qpretrain::config::TensorPolicy::new(ptq_bits, gran),
        );
    }
    Ok((model, state, recipe))
}

fn sampler_from(args: &Args) -> Result<qpretrain::serve::Sampler> {
    let t = args.f64_or("temperature", 0.0)?;
    Ok(if t <= 0.0 {
        qpretrain::serve::Sampler::Greedy
    } else {
        qpretrain::serve::Sampler::TopK {
            temperature: t as f32,
            k: args.usize_or("top-k", 40)?,
        }
    })
}

/// Deterministic prompts: explicit `--prompt 3,17,42` token ids, or `n`
/// prompts drawn from the synthetic training corpus with ragged lengths
/// cycling `1..=prompt-len` so the batcher sees staggered admissions.
fn serve_prompts(args: &Args, vocab: usize, n: usize) -> Result<Vec<Vec<i32>>> {
    if let Some(p) = args.get("prompt") {
        let toks: Vec<i32> = p
            .split(',')
            .map(|s| s.trim().parse::<i32>().map_err(|_| anyhow!("bad prompt token {s:?}")))
            .collect::<Result<_>>()?;
        return Ok(vec![toks; n]);
    }
    let plen = args.usize_or("prompt-len", 8)?.max(1);
    let mut it = qpretrain::data::BatchIter::new(
        qpretrain::data::CorpusCfg::train_default(vocab),
        1,
        plen,
    );
    Ok((0..n)
        .map(|i| {
            let b = it.next_batch();
            b.x[..1 + i % plen].to_vec()
        })
        .collect())
}

fn cmd_generate(args: &Args) -> Result<()> {
    use qpretrain::serve::{Engine, ServeCfg};
    let rt = Runtime::open_default()?;
    let (model, state, recipe) = serve_state(args, &rt)?;
    let prompt = serve_prompts(args, model.vocab, 1)?.remove(0);
    let mut eng = Engine::new(
        &model,
        &state.params,
        &recipe,
        ServeCfg::new(1, args.usize_or("max-seq", model.seq)?),
    )?;
    let t0 = std::time::Instant::now();
    let toks = eng.generate(
        &prompt,
        args.usize_or("max-new", 32)?,
        sampler_from(args)?,
        args.u64_or("gen-seed", 7)?,
    )?;
    let dt = t0.elapsed().as_secs_f64();
    let fmt = |v: &[i32]| v.iter().map(i32::to_string).collect::<Vec<_>>().join(",");
    println!("prompt  ({:>3} toks): {}", prompt.len(), fmt(&prompt));
    println!("decoded ({:>3} toks): {}", toks.len(), fmt(&toks));
    println!(
        "{} packed linears resident; {:.1} tokens/s",
        eng.packed_linears(),
        toks.len() as f64 / dt.max(1e-9)
    );
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    use qpretrain::serve::{Engine, Request, ServeCfg};
    let rt = Runtime::open_default()?;
    let (model, state, recipe) = serve_state(args, &rt)?;
    let n = args.usize_or("requests", 8)?.max(1);
    let max_batch = args.usize_or("max-batch", 8)?;
    let max_new = args.usize_or("max-new", 16)?;
    let sampler = sampler_from(args)?;
    let base_seed = args.u64_or("gen-seed", 7)?;
    let reqs: Vec<Request> = serve_prompts(args, model.vocab, n)?
        .into_iter()
        .enumerate()
        .map(|(i, prompt)| Request {
            prompt,
            max_new,
            sampler,
            seed: base_seed.wrapping_add(i as u64),
        })
        .collect();
    let mut eng = Engine::new(
        &model,
        &state.params,
        &recipe,
        ServeCfg::new(max_batch, args.usize_or("max-seq", model.seq)?),
    )?;
    let (done, stats) = eng.run(&reqs)?;
    for c in &done {
        println!(
            "req {:>3}: prompt {:>3} -> {:>3} new toks, {:>3} steps, ttft {:.2} ms",
            c.id,
            c.prompt_len,
            c.generated.len(),
            c.steps,
            c.ttft_secs * 1e3
        );
    }
    println!(
        "{} reqs in {} decode steps; peak batch {}/{}, occupancy {:.2}",
        done.len(),
        stats.steps,
        stats.peak_batch,
        max_batch,
        stats.occupancy
    );
    println!(
        "{:.1} tokens/s over {:.2}s ({} packed linears resident)",
        stats.tokens_out as f64 / stats.wall_secs.max(1e-9),
        stats.wall_secs,
        eng.packed_linears()
    );
    Ok(())
}

/// Runtime validation: the native executor against the rust quant oracle,
/// plus an end-to-end learning check. (Cross-language bit-exactness is
/// covered by `rust/tests/golden.rs` over the committed fixtures.)
fn cmd_selftest(_args: &Args) -> Result<()> {
    use qpretrain::config::TensorPolicy;
    use qpretrain::model::init_state;
    use qpretrain::quant;

    let rt = Runtime::native();
    let model = rt.model("micro")?.clone();

    // 1) forward fake-quant injection: eval("w8_pc") on latent weights must
    //    equal eval("base") on host-side per-layer qdq'd weights, bit for bit
    let state = init_state(&model, 99);
    let mut qstate = state.clone();
    qpretrain::ptq::quantize_weights(
        &mut qstate,
        &model,
        TensorPolicy::new(8, Granularity::PerChannel),
    );
    let mut it = qpretrain::data::BatchIter::new(
        qpretrain::data::CorpusCfg::train_default(model.vocab),
        model.batch,
        model.seq,
    );
    let b = it.next_batch();
    let mask = vec![1.0f32; model.batch * model.seq];
    let w8_pc = QuantRecipe::parse("w8_pc")?;
    let latent = rt.eval_step(&model, &w8_pc, &state.params, &b.x, &b.y, &mask)?;
    let host = rt.eval_step(&model, &QuantRecipe::none(), &qstate.params, &b.x, &b.y, &mask)?;
    let ok = latent.per_pos == host.per_pos;
    println!(
        "native w8_pc forward == host-qdq weights + base forward: {}",
        if ok { "OK (bit-exact)" } else { "FAIL" }
    );
    if !ok {
        bail!("selftest failed: forward fake-quant does not match quant::qdq");
    }

    // 2) oracle spot checks (round-half-to-even, Eq. 1 grid)
    let mut x = vec![-4.0f32, -1.0, 0.0, 2.0];
    quant::qdq(&mut x, 1, 4, TensorPolicy::new(3, Granularity::PerTensor));
    let s = 4.0f32 / 3.0;
    if x != vec![-3.0 * s, -1.0 * s, 0.0, 2.0 * s] {
        bail!("selftest failed: hand-computed per-tensor case");
    }
    println!("quant oracle hand-computed case: OK");

    // 3) end-to-end learning on the native backend
    let cfg = qpretrain::train::TrainCfg::new(
        "micro",
        QuantRecipe::none(),
        TrainHp {
            steps: 20,
            eval_every: 0,
            log_every: usize::MAX,
            ..TrainHp::default()
        },
    );
    let r = qpretrain::train::train(&rt, &cfg)?;
    println!(
        "native 20-step train: {:.4} -> {:.4} ({})",
        r.losses[0],
        r.final_loss(),
        if r.final_loss() < r.losses[0] - 0.1 {
            "OK"
        } else {
            "FAIL"
        }
    );
    if r.final_loss() >= r.losses[0] - 0.1 {
        bail!("selftest failed: native training did not learn");
    }
    println!("selftest OK");
    Ok(())
}

/// Deterministic train-run digest for CI bit-equivalence diffs: a few
/// short micro runs (fp32 baseline, the int8-dispatched w8a8, the w8a8g8
/// integer-backward recipe, a per-tensor actgrad variant that drives the
/// fully-integer tn/nt gradient kernels, and the paper's full combined
/// recipe), fingerprinted at the bit level (loss / grad-norm / validation
/// bit patterns, FNV-64 over the final params and Adam moments). The
/// output is a function of the code and the seed ONLY — never of
/// wall-clock, thread count, SIMD availability, or the int8-accumulator
/// knob (at micro dims the f32 fold of the integer code products is
/// exact, so the i32 and f32 legs agree bit for bit) — so the CI matrix
/// byte-diffs one digest per (threads × QPRETRAIN_SIMD × QPRETRAIN_INT8)
/// leg to prove the determinism contract on real runners, not just dev
/// machines.
fn cmd_digest(args: &Args) -> Result<()> {
    fn state_hash(tensors: &[Vec<f32>]) -> String {
        let mut acc: Vec<u8> = Vec::with_capacity(tensors.len() * 8);
        for t in tensors {
            acc.extend_from_slice(&qpretrain::util::fnv1a64_f32(t).to_le_bytes());
        }
        format!("{:016x}", qpretrain::util::fnv1a64(&acc))
    }
    use qpretrain::util::json::{self, Value};

    let rt = Runtime::native();
    let steps = args.usize_or("steps", 8)?;
    let out = args.get_or("out", "digest.json");
    let mut runs = Vec::new();
    for spec in [
        "base",
        "w8a8",
        "w8a8g8",
        "w8_pt+a8_pt+g8_pt_actgrad",
        "w4_pc+a8_ptok+g8_ptok+m1_8_pt+m2_8_pc",
    ] {
        let hp = TrainHp {
            steps,
            eval_every: steps,
            eval_batches: 2,
            log_every: usize::MAX,
            ..TrainHp::default()
        };
        let cfg = qpretrain::train::TrainCfg::new("micro", QuantRecipe::parse(spec)?, hp);
        let r = qpretrain::train::train(&rt, &cfg)?;
        let hex64 = |v: &[f64]| {
            Value::Arr(v.iter().map(|x| json::s(&format!("{:016x}", x.to_bits()))).collect())
        };
        let val = Value::Arr(
            r.val
                .iter()
                .map(|(s, l)| json::s(&format!("{s}:{:016x}", l.to_bits())))
                .collect(),
        );
        runs.push(json::obj(vec![
            ("recipe", json::s(spec)),
            ("loss_bits", hex64(&r.losses)),
            ("gnorm_bits", hex64(&r.gnorms)),
            ("val_bits", val),
            ("params_fnv", json::s(&state_hash(&r.final_state.params))),
            ("m_fnv", json::s(&state_hash(&r.final_state.m))),
            ("v_fnv", json::s(&state_hash(&r.final_state.v))),
        ]));
    }
    // serve-engine generate digest: greedy + top-k token streams and the
    // FNV of the KV-cached per-step logits from a fixed random init, under
    // the fp32 and int8-dispatched forward recipes. Like the train runs,
    // these are bit-stable across threads / SIMD / int8 legs (KV decode is
    // bitwise-equal to the full forward, and at micro dims the f32 fold of
    // the integer code products is exact).
    let mut gens = Vec::new();
    {
        use qpretrain::serve::{Engine, Sampler, ServeCfg};
        let model = rt.model("micro")?.clone();
        let state = qpretrain::model::init_state(&model, 2024);
        let prompt: Vec<i32> = (1..=4).collect();
        for spec in ["base", "w8a8"] {
            let recipe = QuantRecipe::parse(spec)?;
            let mut eng = Engine::new(&model, &state.params, &recipe, ServeCfg::new(4, 32))?;
            let greedy = eng.generate(&prompt, 12, Sampler::Greedy, 7)?;
            let sampled = eng.generate(
                &prompt,
                12,
                Sampler::TopK {
                    temperature: 0.9,
                    k: 8,
                },
                7,
            )?;
            let logits = eng.decode_logits(&prompt)?;
            let toks =
                |v: &[i32]| Value::Arr(v.iter().map(|&t| json::num(t as f64)).collect());
            gens.push(json::obj(vec![
                ("recipe", json::s(spec)),
                ("greedy", toks(&greedy)),
                ("sampled", toks(&sampled)),
                (
                    "logits_fnv",
                    json::s(&format!("{:016x}", qpretrain::util::fnv1a64_f32(&logits))),
                ),
            ]));
        }
    }

    // dist-train digest: the sharded reduction-tree trainer, fingerprinted
    // the same way. Run at --dp N; the section's *content* is a function of
    // the code and seed only — never of dp (the tree is shaped by the
    // global batch alone), the transport, the overlap knob, threads, SIMD,
    // or the int8 knob — so CI byte-diffs --dp 2 digests across
    // {filesystem, channel} x {overlap on, off} against a --dp 1 digest to
    // prove the N-way trainer bit-matches single-process on every
    // topology, and the thread/simd matrix legs (all --dp 1) keep covering
    // the section too.
    let dp = args.usize_or("dp", 1)?;
    let transport = DistTransport::parse(&args.get_or("transport", "filesystem"))?;
    let overlap = on_off(args, "overlap", TrainHp::default().dist_overlap)?;
    let mut dist_runs = Vec::new();
    {
        // only the filesystem transport needs a scratch dir for the
        // exchange protocol; channel ranks talk through memory
        let tmp = (dp > 1 && transport == DistTransport::Filesystem).then(|| {
            std::env::temp_dir().join(format!("qpretrain_digest_dist_{}", std::process::id()))
        });
        for spec in ["base", "w8a8g8"] {
            let hp = TrainHp {
                steps,
                eval_every: steps,
                eval_batches: 2,
                log_every: usize::MAX,
                dp,
                dist_transport: transport,
                dist_overlap: overlap,
                ..TrainHp::default()
            };
            let mut cfg = qpretrain::train::TrainCfg::new("micro", QuantRecipe::parse(spec)?, hp);
            cfg.out_dir = tmp.clone();
            let r = qpretrain::dist::dist_train(&rt, &cfg)?;
            let hex64 = |v: &[f64]| {
                Value::Arr(v.iter().map(|x| json::s(&format!("{:016x}", x.to_bits()))).collect())
            };
            let val = Value::Arr(
                r.val
                    .iter()
                    .map(|(s, l)| json::s(&format!("{s}:{:016x}", l.to_bits())))
                    .collect(),
            );
            dist_runs.push(json::obj(vec![
                ("recipe", json::s(spec)),
                ("loss_bits", hex64(&r.losses)),
                ("gnorm_bits", hex64(&r.gnorms)),
                ("val_bits", val),
                ("params_fnv", json::s(&state_hash(&r.final_state.params))),
                ("m_fnv", json::s(&state_hash(&r.final_state.m))),
                ("v_fnv", json::s(&state_hash(&r.final_state.v))),
            ]));
        }
        if let Some(tmp) = tmp {
            let _ = std::fs::remove_dir_all(tmp);
        }
    }

    let digest = json::obj(vec![
        ("model", json::s("micro")),
        ("steps", json::num(steps as f64)),
        ("runs", Value::Arr(runs)),
        ("generate", Value::Arr(gens)),
        ("dist", Value::Arr(dist_runs)),
    ]);
    std::fs::write(&out, digest.to_json())?;
    println!("wrote {out} (byte-diffable across threads/simd/int8/dp CI legs)");
    Ok(())
}

fn cmd_list(_args: &Args) -> Result<()> {
    let rt = Runtime::open_default()?;
    println!("backend: {}", rt.backend_name());
    println!("models:");
    let mut models: Vec<_> = rt.manifest.models.keys().collect();
    models.sort();
    for m in models {
        let info = &rt.manifest.models[m];
        println!(
            "  {m}: {}L d{} h{} V{} T{} B{} ({} params)",
            info.n_layer, info.d_model, info.n_head, info.vocab, info.seq, info.batch, info.n_params
        );
    }
    println!(
        "\nquantization recipes (--quant): `+`-joined per-class components
  component = <class><bits>_<granularity>[_asym][_actgrad]
  classes       w (weights), a (activations), g (gradients), m1 / m2 (Adam moments)
  granularity   pt (per-tensor), ptok (per-token), pc (per-channel)
  bits          2..=24, or omitted for the fed-1.0 placement-only form
  examples      w4_pc                 4-bit per-channel weights
                a8_ptok_asym          8-bit asymmetric per-token activations
                g8_ptok_actgrad       8-bit grads incl. the dx path (Fig. 10)
                m2_8_pc               8-bit per-channel Adam second moment
                w8a8 / w8a8g8         combined short labels (paper Fig. 13)
                w4_pc+a8_ptok+g8_ptok+m1_8_pt+m2_8_pc   full combined recipe

  serve eligibility (generate/serve): any weight policy serves; activations
  must be per-token (a*_ptok[_asym]) or unquantized. Per-tensor/per-channel
  activation scales are whole-batch amax statistics, which KV-cached
  incremental decode cannot reproduce row-locally, so those recipes are
  rejected by the serve engine (train-time recipes are unaffected)."
    );
    println!(
        "legacy structure aliases: {}",
        qpretrain::config::QuantRecipe::LEGACY_ALIASES.join(", ")
    );
    if !rt.manifest.artifacts.is_empty() {
        println!("AOT artifacts: {}", rt.manifest.artifacts.len());
    }
    println!("experiments: {:?} + all", experiments::ALL);
    Ok(())
}
