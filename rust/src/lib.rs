//! # qpretrain
//!
//! Reproduction of *"Exploring Quantization for Efficient Pre-Training of
//! Transformer Language Models"* (Chitsaz et al., EMNLP 2024 Findings) as a
//! three-layer rust + JAX + Pallas system:
//!
//! * **L3 (this crate)** — the experiment coordinator: synthetic data
//!   pipeline, training loop over AOT-compiled train steps, evaluation,
//!   post-training quantization, sharpness / outlier / gradient analyses,
//!   memory & time profilers, and one experiment runner per paper
//!   table/figure.
//! * **L2 (python/compile)** — the GPT-2 compute graph with fake
//!   quantization injected per the paper's Fig. 1, AOT-lowered to HLO text.
//! * **L1 (python/compile/kernels)** — Pallas fake-quant kernels.
//!
//! Python never runs at training time: `make artifacts` lowers everything
//! once; this crate loads the HLO text via the PJRT C API (`xla` crate).

pub mod analysis;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod eval;
pub mod memmodel;
pub mod model;
pub mod ptq;
pub mod quant;
pub mod runtime;
pub mod timemodel;
pub mod train;
pub mod util;

/// Repo-relative default artifact directory.
pub const ARTIFACT_DIR: &str = "artifacts";
/// Repo-relative default run-output directory.
pub const RUNS_DIR: &str = "runs";
