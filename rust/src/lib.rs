//! # qpretrain
//!
//! Reproduction of *"Exploring Quantization for Efficient Pre-Training of
//! Transformer Language Models"* (Chitsaz et al., EMNLP 2024 Findings).
//!
//! The crate is organized around a **backend seam** ([`backend`]): the
//! experiment layers speak the [`backend::Backend`] trait — "run one train
//! / eval / probe step over host (params, m, v) state" — and never see how
//! steps execute:
//!
//! * **native backend** (default build) — pure rust implementation of the
//!   quantized GPT-2 forward + backward + AdamW update (embedding, causal
//!   attention, GELU MLP, layernorm, cross-entropy), with fake quantization
//!   injected at the paper's Fig. 1 points via the bit-exact [`quant`]
//!   oracle and quantized Adam moments per §3.4. `cargo test` trains a
//!   small model end-to-end with no PJRT, no Python, no artifacts.
//! * **pjrt backend** (cargo feature `pjrt`) — executes AOT-lowered HLO
//!   artifacts (`python/compile`, lowered once by `make artifacts`) through
//!   the PJRT C API (`xla` crate), as the original three-layer system did.
//!
//! Above the seam sit the experiment layers: synthetic data pipeline
//! ([`data`]), training loop ([`train`]), evaluation ([`eval`]),
//! post-training quantization ([`ptq`]), sharpness / outlier / gradient
//! analyses ([`analysis`]), memory & time models ([`memmodel`],
//! [`timemodel`]), one experiment runner per paper table/figure
//! ([`coordinator`]), and an N-process data-parallel trainer whose runs
//! are bit-identical to single-process at matched global batch ([`dist`]).

// Numeric-kernel code style: explicit index loops mirror the math and the
// python reference; many hot signatures carry model + quant + state.
#![allow(
    clippy::too_many_arguments,
    clippy::needless_range_loop,
    clippy::manual_memcpy,
    clippy::type_complexity,
    clippy::useless_vec,
    clippy::excessive_precision,
    clippy::new_without_default
)]

pub mod analysis;
pub mod backend;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod dist;
pub mod eval;
pub mod memmodel;
pub mod model;
pub mod ptq;
pub mod quant;
pub mod runtime;
pub mod serve;
pub mod timemodel;
pub mod train;
pub mod util;

/// Repo-relative default artifact directory (pjrt feature).
pub const ARTIFACT_DIR: &str = "artifacts";
/// Repo-relative default run-output directory.
pub const RUNS_DIR: &str = "runs";
