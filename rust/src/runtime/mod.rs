//! PJRT runtime: loads the AOT artifacts (`artifacts/*.hlo.txt`) described
//! by `manifest.json` and executes them on the CPU PJRT client.
//!
//! HLO *text* is the interchange format (xla_extension 0.5.1 rejects
//! jax>=0.5 serialized protos with 64-bit instruction ids; the text parser
//! reassigns ids). Lowering uses `return_tuple=True`, so every execution
//! returns one tuple buffer which is decomposed into per-output literals.

use std::cell::RefCell;
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::rc::Rc;
use std::time::Instant;

use anyhow::{anyhow, bail, Context, Result};

use crate::util::json::{self, Value};

// ---------------------------------------------------------------------------
// manifest
// ---------------------------------------------------------------------------

#[derive(Debug, Clone, PartialEq)]
pub struct TensorSig {
    pub name: String,
    pub dtype: String, // "f32" | "i32"
    pub shape: Vec<usize>,
}

impl TensorSig {
    pub fn elems(&self) -> usize {
        self.shape.iter().product()
    }
}

#[derive(Debug, Clone)]
pub struct ParamInfo {
    pub name: String,
    pub shape: Vec<usize>,
    pub stacked: bool,
    pub decay: bool,
    pub init: String,
}

impl ParamInfo {
    pub fn elems(&self) -> usize {
        self.shape.iter().product()
    }
}

#[derive(Debug, Clone)]
pub struct ModelInfo {
    pub name: String,
    pub n_layer: usize,
    pub d_model: usize,
    pub n_head: usize,
    pub vocab: usize,
    pub seq: usize,
    pub batch: usize,
    pub d_ff: usize,
    pub n_params: usize,
    pub params: Vec<ParamInfo>,
}

#[derive(Debug, Clone)]
pub struct ArtifactInfo {
    pub name: String,
    pub file: String,
    pub kind: String,
    pub model: Option<String>,
    pub inputs: Vec<TensorSig>,
    pub outputs: Vec<TensorSig>,
    pub meta: Value,
}

#[derive(Debug, Clone)]
pub struct Manifest {
    pub models: HashMap<String, ModelInfo>,
    pub artifacts: HashMap<String, ArtifactInfo>,
}

fn parse_sig(v: &Value) -> Result<Vec<TensorSig>> {
    v.as_arr()
        .ok_or_else(|| anyhow!("signature is not an array"))?
        .iter()
        .map(|e| {
            Ok(TensorSig {
                name: e.req("name")?.as_str().unwrap_or_default().to_string(),
                dtype: e.req("dtype")?.as_str().unwrap_or_default().to_string(),
                shape: e
                    .req("shape")?
                    .as_arr()
                    .unwrap_or_default()
                    .iter()
                    .map(|d| d.as_usize().unwrap_or(0))
                    .collect(),
            })
        })
        .collect()
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?}; run `make artifacts` first"))?;
        let root = json::parse(&text)?;

        let mut models = HashMap::new();
        for (name, m) in root.req("models")?.as_obj().unwrap_or_default() {
            let params = m
                .req("params")?
                .as_arr()
                .unwrap_or_default()
                .iter()
                .map(|p| {
                    Ok(ParamInfo {
                        name: p.req("name")?.as_str().unwrap_or_default().to_string(),
                        shape: p
                            .req("shape")?
                            .as_arr()
                            .unwrap_or_default()
                            .iter()
                            .map(|d| d.as_usize().unwrap_or(0))
                            .collect(),
                        stacked: p.get("stacked").and_then(|v| v.as_bool()).unwrap_or(false),
                        decay: p.get("decay").and_then(|v| v.as_bool()).unwrap_or(false),
                        init: p
                            .get("init")
                            .and_then(|v| v.as_str())
                            .unwrap_or("zeros")
                            .to_string(),
                    })
                })
                .collect::<Result<Vec<_>>>()?;
            models.insert(
                name.clone(),
                ModelInfo {
                    name: name.clone(),
                    n_layer: m.req("n_layer")?.as_usize().unwrap_or(0),
                    d_model: m.req("d_model")?.as_usize().unwrap_or(0),
                    n_head: m.req("n_head")?.as_usize().unwrap_or(0),
                    vocab: m.req("vocab")?.as_usize().unwrap_or(0),
                    seq: m.req("seq")?.as_usize().unwrap_or(0),
                    batch: m.req("batch")?.as_usize().unwrap_or(0),
                    d_ff: m.req("d_ff")?.as_usize().unwrap_or(0),
                    n_params: m.req("n_params")?.as_usize().unwrap_or(0),
                    params,
                },
            );
        }

        let mut artifacts = HashMap::new();
        for (name, a) in root.req("artifacts")?.as_obj().unwrap_or_default() {
            artifacts.insert(
                name.clone(),
                ArtifactInfo {
                    name: name.clone(),
                    file: a.req("file")?.as_str().unwrap_or_default().to_string(),
                    kind: a
                        .get("kind")
                        .and_then(|v| v.as_str())
                        .unwrap_or("")
                        .to_string(),
                    model: a
                        .get("model")
                        .and_then(|v| v.as_str())
                        .map(|s| s.to_string()),
                    inputs: parse_sig(a.req("inputs")?)?,
                    outputs: parse_sig(a.req("outputs")?)?,
                    meta: a.clone(),
                },
            );
        }
        Ok(Manifest { models, artifacts })
    }

    pub fn model(&self, name: &str) -> Result<&ModelInfo> {
        self.models
            .get(name)
            .ok_or_else(|| anyhow!("unknown model {name:?} in manifest"))
    }

    pub fn artifact(&self, name: &str) -> Result<&ArtifactInfo> {
        self.artifacts
            .get(name)
            .ok_or_else(|| anyhow!("unknown artifact {name:?} in manifest"))
    }
}

// ---------------------------------------------------------------------------
// literal helpers
// ---------------------------------------------------------------------------

pub fn lit_f32(data: &[f32], shape: &[usize]) -> Result<xla::Literal> {
    debug_assert_eq!(data.len(), shape.iter().product::<usize>());
    if shape.is_empty() {
        return Ok(xla::Literal::scalar(data[0]));
    }
    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
    Ok(xla::Literal::vec1(data).reshape(&dims)?)
}

pub fn lit_i32(data: &[i32], shape: &[usize]) -> Result<xla::Literal> {
    debug_assert_eq!(data.len(), shape.iter().product::<usize>());
    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
    Ok(xla::Literal::vec1(data).reshape(&dims)?)
}

pub fn lit_scalar(v: f32) -> xla::Literal {
    xla::Literal::scalar(v)
}

pub fn to_f32(lit: &xla::Literal) -> Result<Vec<f32>> {
    Ok(lit.to_vec::<f32>()?)
}

pub fn scalar_f32(lit: &xla::Literal) -> Result<f32> {
    Ok(lit.to_vec::<f32>()?[0])
}

// ---------------------------------------------------------------------------
// runtime
// ---------------------------------------------------------------------------

/// A compiled artifact plus its signature.
pub struct Executable {
    pub info: ArtifactInfo,
    exe: xla::PjRtLoadedExecutable,
}

impl Executable {
    /// Execute with literal inputs; returns per-output literals (decomposed
    /// from the single result tuple).
    pub fn run(&self, inputs: &[&xla::Literal]) -> Result<Vec<xla::Literal>> {
        if inputs.len() != self.info.inputs.len() {
            bail!(
                "{}: expected {} inputs, got {}",
                self.info.name,
                self.info.inputs.len(),
                inputs.len()
            );
        }
        let bufs = self.exe.execute::<&xla::Literal>(inputs)?;
        let tuple = bufs[0][0].to_literal_sync()?;
        Ok(tuple.to_tuple()?)
    }

    /// Execute and time just the device execution + download.
    pub fn run_timed(&self, inputs: &[&xla::Literal]) -> Result<(Vec<xla::Literal>, f64)> {
        let t0 = Instant::now();
        let out = self.run(inputs)?;
        Ok((out, t0.elapsed().as_secs_f64()))
    }
}

/// Loads + caches compiled executables over one PJRT CPU client.
pub struct Runtime {
    pub client: xla::PjRtClient,
    pub dir: PathBuf,
    pub manifest: Manifest,
    cache: RefCell<HashMap<String, Rc<Executable>>>,
}

impl Runtime {
    pub fn new(dir: &Path) -> Result<Runtime> {
        let manifest = Manifest::load(dir)?;
        let client = xla::PjRtClient::cpu()?;
        Ok(Runtime {
            client,
            dir: dir.to_path_buf(),
            manifest,
            cache: RefCell::new(HashMap::new()),
        })
    }

    /// Open the default artifact directory.
    pub fn open_default() -> Result<Runtime> {
        Runtime::new(&crate::util::artifact_dir())
    }

    /// Compile (or fetch from cache) an artifact by manifest name.
    pub fn exec(&self, name: &str) -> Result<Rc<Executable>> {
        if let Some(e) = self.cache.borrow().get(name) {
            return Ok(e.clone());
        }
        let info = self.manifest.artifact(name)?.clone();
        let path = self.dir.join(&info.file);
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
        )?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp)?;
        log::info!(
            "compiled {name} ({:.2}s)",
            t0.elapsed().as_secs_f64()
        );
        let wrapped = Rc::new(Executable { info, exe });
        self.cache
            .borrow_mut()
            .insert(name.to_string(), wrapped.clone());
        Ok(wrapped)
    }

    /// One-shot convenience: compile + run.
    pub fn run(&self, name: &str, inputs: &[&xla::Literal]) -> Result<Vec<xla::Literal>> {
        self.exec(name)?.run(inputs)
    }
}
