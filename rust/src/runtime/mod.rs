//! Runtime façade over the [`crate::backend`] seam.
//!
//! A [`Runtime`] owns a model registry (the manifest) and a boxed
//! [`Backend`] executor; the train loop, eval harness, PTQ, analyses and
//! coordinator all go through it and never see how steps execute.
//!
//! * Default build: [`Runtime::native`] — models come from the built-in
//!   registry (`backend::native::native_models`), steps run in pure rust.
//! * `--features pjrt`: [`Runtime::pjrt`] loads `manifest.json` +
//!   `*.hlo.txt` AOT artifacts and executes them on the PJRT CPU client.
//!   [`Runtime::open_default`] picks pjrt when the artifact directory
//!   exists and falls back to native otherwise.

use std::collections::HashMap;
use std::path::Path;

use anyhow::{anyhow, Context, Result};

use crate::backend::{ActProbe, Backend, EvalOut, GradProbe, StepOut};
use crate::config::QuantRecipe;
use crate::model::HostState;
use crate::util::json::{self, Value};

// ---------------------------------------------------------------------------
// manifest (model + artifact metadata; pure data, backend-independent)
// ---------------------------------------------------------------------------

#[derive(Debug, Clone, PartialEq)]
pub struct TensorSig {
    pub name: String,
    pub dtype: String, // "f32" | "i32"
    pub shape: Vec<usize>,
}

impl TensorSig {
    pub fn elems(&self) -> usize {
        self.shape.iter().product()
    }
}

#[derive(Debug, Clone)]
pub struct ParamInfo {
    pub name: String,
    pub shape: Vec<usize>,
    pub stacked: bool,
    pub decay: bool,
    pub init: String,
}

impl ParamInfo {
    pub fn elems(&self) -> usize {
        self.shape.iter().product()
    }
}

#[derive(Debug, Clone)]
pub struct ModelInfo {
    pub name: String,
    pub n_layer: usize,
    pub d_model: usize,
    pub n_head: usize,
    pub vocab: usize,
    pub seq: usize,
    pub batch: usize,
    pub d_ff: usize,
    pub n_params: usize,
    pub params: Vec<ParamInfo>,
}

#[derive(Debug, Clone)]
pub struct ArtifactInfo {
    pub name: String,
    pub file: String,
    pub kind: String,
    pub model: Option<String>,
    pub inputs: Vec<TensorSig>,
    pub outputs: Vec<TensorSig>,
    pub meta: Value,
}

#[derive(Debug, Clone)]
pub struct Manifest {
    pub models: HashMap<String, ModelInfo>,
    pub artifacts: HashMap<String, ArtifactInfo>,
}

fn parse_sig(v: &Value) -> Result<Vec<TensorSig>> {
    v.as_arr()
        .ok_or_else(|| anyhow!("signature is not an array"))?
        .iter()
        .map(|e| {
            Ok(TensorSig {
                name: e.req("name")?.as_str().unwrap_or_default().to_string(),
                dtype: e.req("dtype")?.as_str().unwrap_or_default().to_string(),
                shape: e
                    .req("shape")?
                    .as_arr()
                    .unwrap_or_default()
                    .iter()
                    .map(|d| d.as_usize().unwrap_or(0))
                    .collect(),
            })
        })
        .collect()
}

impl Manifest {
    /// The built-in native model registry (no files needed).
    pub fn native() -> Manifest {
        Manifest {
            models: crate::backend::native::native_models(),
            artifacts: HashMap::new(),
        }
    }

    /// Load `manifest.json` from an AOT artifact directory.
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?}; run `make artifacts` first"))?;
        let root = json::parse(&text)?;

        let mut models = HashMap::new();
        for (name, m) in root.req("models")?.as_obj().unwrap_or_default() {
            let params = m
                .req("params")?
                .as_arr()
                .unwrap_or_default()
                .iter()
                .map(|p| {
                    Ok(ParamInfo {
                        name: p.req("name")?.as_str().unwrap_or_default().to_string(),
                        shape: p
                            .req("shape")?
                            .as_arr()
                            .unwrap_or_default()
                            .iter()
                            .map(|d| d.as_usize().unwrap_or(0))
                            .collect(),
                        stacked: p.get("stacked").and_then(|v| v.as_bool()).unwrap_or(false),
                        decay: p.get("decay").and_then(|v| v.as_bool()).unwrap_or(false),
                        init: p
                            .get("init")
                            .and_then(|v| v.as_str())
                            .unwrap_or("zeros")
                            .to_string(),
                    })
                })
                .collect::<Result<Vec<_>>>()?;
            models.insert(
                name.clone(),
                ModelInfo {
                    name: name.clone(),
                    n_layer: m.req("n_layer")?.as_usize().unwrap_or(0),
                    d_model: m.req("d_model")?.as_usize().unwrap_or(0),
                    n_head: m.req("n_head")?.as_usize().unwrap_or(0),
                    vocab: m.req("vocab")?.as_usize().unwrap_or(0),
                    seq: m.req("seq")?.as_usize().unwrap_or(0),
                    batch: m.req("batch")?.as_usize().unwrap_or(0),
                    d_ff: m.req("d_ff")?.as_usize().unwrap_or(0),
                    n_params: m.req("n_params")?.as_usize().unwrap_or(0),
                    params,
                },
            );
        }

        let mut artifacts = HashMap::new();
        for (name, a) in root.req("artifacts")?.as_obj().unwrap_or_default() {
            artifacts.insert(
                name.clone(),
                ArtifactInfo {
                    name: name.clone(),
                    file: a.req("file")?.as_str().unwrap_or_default().to_string(),
                    kind: a
                        .get("kind")
                        .and_then(|v| v.as_str())
                        .unwrap_or("")
                        .to_string(),
                    model: a
                        .get("model")
                        .and_then(|v| v.as_str())
                        .map(|s| s.to_string()),
                    inputs: parse_sig(a.req("inputs")?)?,
                    outputs: parse_sig(a.req("outputs")?)?,
                    meta: a.clone(),
                },
            );
        }
        Ok(Manifest { models, artifacts })
    }

    pub fn model(&self, name: &str) -> Result<&ModelInfo> {
        self.models
            .get(name)
            .ok_or_else(|| anyhow!("unknown model {name:?}"))
    }

    pub fn artifact(&self, name: &str) -> Result<&ArtifactInfo> {
        self.artifacts
            .get(name)
            .ok_or_else(|| anyhow!("unknown artifact {name:?} in manifest"))
    }
}

// ---------------------------------------------------------------------------
// runtime façade
// ---------------------------------------------------------------------------

/// Model registry + executor. All experiment code goes through this.
pub struct Runtime {
    pub manifest: Manifest,
    backend: Box<dyn Backend>,
}

impl Runtime {
    /// Pure-rust native runtime (the default-build path; never fails).
    /// Warms the persistent kernel worker pool so the first train/eval
    /// step of a run doesn't pay thread-spawn latency. Kernel dispatch
    /// honors the `QPRETRAIN_SIMD` knob (`off`/`0` pins the bit-identical
    /// scalar lane emulation; `backend::native::simd_active` introspects).
    pub fn native() -> Runtime {
        crate::backend::kernels::warm_pool();
        Runtime {
            manifest: Manifest::native(),
            backend: Box::new(crate::backend::native::NativeBackend),
        }
    }

    /// PJRT runtime over an AOT artifact directory.
    #[cfg(feature = "pjrt")]
    pub fn pjrt(dir: &Path) -> Result<Runtime> {
        let backend = crate::backend::pjrt::PjrtBackend::new(dir)?;
        Ok(Runtime {
            manifest: backend.manifest().clone(),
            backend: Box::new(backend),
        })
    }

    /// Default runtime: the PJRT artifacts when the feature is on and the
    /// artifact directory exists, the native backend otherwise.
    pub fn open_default() -> Result<Runtime> {
        #[cfg(feature = "pjrt")]
        {
            let dir = crate::util::artifact_dir();
            if dir.join("manifest.json").exists() {
                return Runtime::pjrt(&dir);
            }
            log::info!("no AOT artifacts at {dir:?}; using the native backend");
        }
        Ok(Runtime::native())
    }

    pub fn backend_name(&self) -> &'static str {
        self.backend.name()
    }

    pub fn model(&self, name: &str) -> Result<&ModelInfo> {
        self.manifest.model(name)
    }

    /// One optimizer step over `state` (updated in place); see
    /// [`Backend::train_step`].
    pub fn train_step(
        &self,
        model: &ModelInfo,
        recipe: &QuantRecipe,
        state: &mut HostState,
        x: &[i32],
        y: &[i32],
        lr: f32,
        t: f32,
    ) -> Result<StepOut> {
        self.backend.train_step(model, recipe, state, x, y, lr, t)
    }

    /// Backward-only leaf step for sharded training; see
    /// [`Backend::grad_step`].
    pub fn grad_step(
        &self,
        model: &ModelInfo,
        recipe: &QuantRecipe,
        params: &[Vec<f32>],
        x: &[i32],
        y: &[i32],
        inv_norm: f32,
    ) -> Result<(f64, Vec<Vec<f32>>)> {
        self.backend.grad_step(model, recipe, params, x, y, inv_norm)
    }

    /// AdamW update from pre-combined gradients; see
    /// [`Backend::apply_grads`].
    pub fn apply_grads(
        &self,
        model: &ModelInfo,
        recipe: &QuantRecipe,
        state: &mut HostState,
        grads: &[Vec<f32>],
        lr: f32,
        t: f32,
    ) -> Result<f64> {
        self.backend.apply_grads(model, recipe, state, grads, lr, t)
    }

    /// Forward-only scoring; see [`Backend::eval_step`].
    pub fn eval_step(
        &self,
        model: &ModelInfo,
        recipe: &QuantRecipe,
        params: &[Vec<f32>],
        x: &[i32],
        y: &[i32],
        mask: &[f32],
    ) -> Result<EvalOut> {
        self.backend.eval_step(model, recipe, params, x, y, mask)
    }

    /// Outlier probe of the last block; see [`Backend::act_probe`].
    pub fn act_probe(
        &self,
        model: &ModelInfo,
        params: &[Vec<f32>],
        x: &[i32],
    ) -> Result<ActProbe> {
        self.backend.act_probe(model, params, x)
    }

    /// Gradient snapshot probe; see [`Backend::grad_probe`].
    pub fn grad_probe(
        &self,
        model: &ModelInfo,
        params: &[Vec<f32>],
        x: &[i32],
        y: &[i32],
    ) -> Result<GradProbe> {
        self.backend.grad_probe(model, params, x, y)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn native_runtime_has_models() {
        let rt = Runtime::native();
        assert_eq!(rt.backend_name(), "native");
        let t4 = rt.model("t4").unwrap();
        assert_eq!(t4.params.len(), 16);
        assert_eq!(t4.vocab, 512);
        assert!(rt.model("nope").is_err());
    }

    #[test]
    fn open_default_never_fails_without_artifacts() {
        let rt = Runtime::open_default().unwrap();
        assert!(rt.model("micro").is_ok());
    }
}
