//! Rust mirror of the paper's Eq. 1 linear quantization (see
//! `python/compile/kernels/ref.py`, the cross-language oracle).
//!
//! Bit-exactness with the python side is load-bearing: the native backend
//! injects *this* module's `qdq` at the paper's Fig. 1 points, and the PTQ
//! harness (Tables 10/11) quantizes trained checkpoints with it, so the
//! numerics must be the ones the paper's training graph used. Golden-file
//! tests (`rust/tests/golden.rs`, committed fixtures) pin this: `jnp.round`
//! is round-half-to-even, matched by `f32::round_ties_even`; the scale
//! floor is the same `EPS`.
//!
//! Also provides truly-packed int8/int4 storage (`PackedTensor`) used for
//! memory accounting and the storage-size claims of the paper's §3.3.

use crate::config::{Granularity, TensorPolicy};

pub const EPS: f32 = 1e-12;

/// Quantization parameters for one group: `x_int = clip(round(x/s) - z)`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct QParams {
    pub scale: f32,
    pub zero: f32, // the paper's z offset (0 for symmetric)
}

/// Compute symmetric quant params for a slice.
pub fn params_sym(xs: &[f32], qmax: f32) -> QParams {
    let amax = xs.iter().fold(0.0f32, |a, &x| a.max(x.abs()));
    QParams {
        scale: (amax / qmax).max(EPS),
        zero: 0.0,
    }
}

/// Compute asymmetric quant params (min-anchored offset; see ref.py).
pub fn params_asym(xs: &[f32], qmax: f32) -> QParams {
    let (mut lo, mut hi) = (f32::INFINITY, f32::NEG_INFINITY);
    for &x in xs {
        lo = lo.min(x);
        hi = hi.max(x);
    }
    if xs.is_empty() {
        return QParams { scale: EPS, zero: 0.0 };
    }
    let n = -qmax - 1.0;
    let scale = ((hi - lo) / (2.0 * qmax + 1.0)).max(EPS);
    QParams {
        scale,
        zero: (lo / scale).round_ties_even() - n,
    }
}

/// Quantize one value to the integer grid.
#[inline]
pub fn quantize_one(x: f32, p: QParams, qmax: f32) -> f32 {
    let n = -qmax - 1.0;
    ((x / p.scale).round_ties_even() - p.zero).clamp(n, qmax)
}

/// Fake-quantize one value (quantize + dequantize). `asymmetric` selects
/// the dequant formula: the symmetric path computes `s * x_int` exactly as
/// the python oracle does — adding a `+ 0.0` offset there would flip IEEE
/// `-0.0` codes to `+0.0` and break u32-level bit-exactness with the
/// committed golden fixtures.
#[inline]
pub fn qdq_one(x: f32, p: QParams, qmax: f32, asymmetric: bool) -> f32 {
    let q = quantize_one(x, p, qmax);
    if asymmetric {
        p.scale * (q + p.zero)
    } else {
        p.scale * q
    }
}

/// Group quantization parameters for a (rows x cols) row-major matrix at a
/// runtime qmax: one entry per tensor / row / column depending on
/// granularity. Columns are gathered and fed through the same
/// `params_sym`/`params_asym` used everywhere else (single source of truth
/// for the min/max + scale numerics).
pub fn group_params_qmax(
    data: &[f32],
    rows: usize,
    cols: usize,
    granularity: Granularity,
    asymmetric: bool,
    qmax: f32,
) -> Vec<QParams> {
    let pfn: fn(&[f32], f32) -> QParams = if asymmetric { params_asym } else { params_sym };
    match granularity {
        Granularity::PerTensor => vec![pfn(data, qmax)],
        Granularity::PerToken => (0..rows)
            .map(|r| pfn(&data[r * cols..(r + 1) * cols], qmax))
            .collect(),
        Granularity::PerChannel => {
            let mut col = vec![0.0f32; rows];
            (0..cols)
                .map(|c| {
                    for (r, slot) in col.iter_mut().enumerate() {
                        *slot = data[r * cols + c];
                    }
                    pfn(&col, qmax)
                })
                .collect()
        }
    }
}

/// Fake-quantize with an explicit runtime qmax (the native backend's entry
/// point: artifact structures receive qmax as a runtime scalar, so bit-width
/// never needs to be known here).
pub fn qdq_qmax(
    data: &mut [f32],
    rows: usize,
    cols: usize,
    granularity: Granularity,
    asymmetric: bool,
    qmax: f32,
) {
    assert_eq!(data.len(), rows * cols, "shape mismatch");
    let params = group_params_qmax(data, rows, cols, granularity, asymmetric, qmax);
    match granularity {
        Granularity::PerTensor => {
            let p = params[0];
            for x in data.iter_mut() {
                *x = qdq_one(*x, p, qmax, asymmetric);
            }
        }
        Granularity::PerToken => {
            for r in 0..rows {
                let p = params[r];
                for x in data[r * cols..(r + 1) * cols].iter_mut() {
                    *x = qdq_one(*x, p, qmax, asymmetric);
                }
            }
        }
        Granularity::PerChannel => {
            for r in 0..rows {
                for c in 0..cols {
                    data[r * cols + c] =
                        qdq_one(data[r * cols + c], params[c], qmax, asymmetric);
                }
            }
        }
    }
}

/// Fake-quantize a (rows x cols) row-major matrix in place, matching the
/// python oracle bit-for-bit for every granularity/scheme combination.
pub fn qdq(data: &mut [f32], rows: usize, cols: usize, policy: TensorPolicy) {
    qdq_qmax(
        data,
        rows,
        cols,
        policy.granularity,
        policy.asymmetric,
        policy.qmax(),
    );
}

/// Non-destructive variant.
pub fn qdq_copy(data: &[f32], rows: usize, cols: usize, policy: TensorPolicy) -> Vec<f32> {
    let mut out = data.to_vec();
    qdq(&mut out, rows, cols, policy);
    out
}

// ---------------------------------------------------------------------------
// packed integer storage (real memory savings; §3.3 accounting)
// ---------------------------------------------------------------------------

/// A tensor stored on the integer grid with per-group scales. Bits <= 8.
/// 4-bit values are nibble-packed two-per-byte; this is the storage format
/// whose sizes back the paper's memory-saving estimates.
#[derive(Debug, Clone)]
pub struct PackedTensor {
    pub rows: usize,
    pub cols: usize,
    pub policy: TensorPolicy,
    pub scales: Vec<f32>,
    pub zeros: Vec<f32>,
    pub data: Vec<u8>, // packed two's-complement codes
}

impl PackedTensor {
    pub fn quantize(data: &[f32], rows: usize, cols: usize, policy: TensorPolicy) -> PackedTensor {
        assert!(policy.bits >= 2 && policy.bits <= 8);
        assert_eq!(data.len(), rows * cols);
        let qmax = policy.qmax();

        // group params (shared with qdq: one source of truth for the scales)
        let params = group_params_qmax(
            data,
            rows,
            cols,
            policy.granularity,
            policy.asymmetric,
            qmax,
        );
        let scales: Vec<f32> = params.iter().map(|p| p.scale).collect();
        let zeros: Vec<f32> = params.iter().map(|p| p.zero).collect();

        let param_at = |r: usize, c: usize| -> QParams {
            match policy.granularity {
                Granularity::PerTensor => QParams { scale: scales[0], zero: zeros[0] },
                Granularity::PerToken => QParams { scale: scales[r], zero: zeros[r] },
                Granularity::PerChannel => QParams { scale: scales[c], zero: zeros[c] },
            }
        };

        let n = rows * cols;
        let mut codes = Vec::with_capacity(n);
        for r in 0..rows {
            for c in 0..cols {
                let q = quantize_one(data[r * cols + c], param_at(r, c), qmax) as i8;
                codes.push(q);
            }
        }
        let packed = if policy.bits <= 4 {
            // nibble-pack
            let mut out = Vec::with_capacity(n.div_ceil(2));
            for pair in codes.chunks(2) {
                let lo = (pair[0] as u8) & 0x0F;
                let hi = if pair.len() > 1 {
                    (pair[1] as u8) & 0x0F
                } else {
                    0
                };
                out.push(lo | (hi << 4));
            }
            out
        } else {
            codes.iter().map(|&c| c as u8).collect()
        };
        PackedTensor {
            rows,
            cols,
            policy,
            scales,
            zeros,
            data: packed,
        }
    }

    /// Integer code at (r, c) with sign extension.
    pub fn code(&self, r: usize, c: usize) -> i8 {
        let idx = r * self.cols + c;
        if self.policy.bits <= 4 {
            let byte = self.data[idx / 2];
            let nib = if idx % 2 == 0 { byte & 0x0F } else { byte >> 4 };
            // sign-extend 4-bit two's complement
            ((nib << 4) as i8) >> 4
        } else {
            self.data[idx] as i8
        }
    }

    pub fn dequantize(&self) -> Vec<f32> {
        let mut out = Vec::with_capacity(self.rows * self.cols);
        for r in 0..self.rows {
            for c in 0..self.cols {
                let (s, z) = match self.policy.granularity {
                    Granularity::PerTensor => (self.scales[0], self.zeros[0]),
                    Granularity::PerToken => (self.scales[r], self.zeros[r]),
                    Granularity::PerChannel => (self.scales[c], self.zeros[c]),
                };
                out.push(s * (self.code(r, c) as f32 + z));
            }
        }
        out
    }

    /// Bytes of storage including scales/offsets.
    pub fn storage_bytes(&self) -> usize {
        let zeros = if self.policy.asymmetric {
            self.zeros.len()
        } else {
            0
        };
        self.data.len() + 4 * (self.scales.len() + zeros)
    }
}

// ---------------------------------------------------------------------------
// packed-int8 GEMM operands (the native backend's integer fast path)
// ---------------------------------------------------------------------------

/// Whether a policy can drive the int8 GEMM's *activation* (left) operand:
/// symmetric 8-bit with the scale constant along the reduction axis — one
/// scale per tensor or one per row/token. Asymmetric policies would leak
/// zero-point cross terms into the i32 accumulator; per-channel activation
/// scales vary along k and cannot be factored out of the dot product.
pub fn int8_act_eligible(p: TensorPolicy) -> bool {
    p.bits == 8
        && !p.asymmetric
        && matches!(p.granularity, Granularity::PerTensor | Granularity::PerToken)
}

/// Whether a policy can drive the int8 GEMM's *weight* (right) operand:
/// symmetric 8-bit, scale constant along the reduction axis — per tensor
/// or per output channel (column). Per-token weight scales vary along k.
pub fn int8_weight_eligible(p: TensorPolicy) -> bool {
    p.bits == 8
        && !p.asymmetric
        && matches!(p.granularity, Granularity::PerTensor | Granularity::PerChannel)
}

/// Whether a policy can drive a packed *gradient* operand in the backward
/// GEMMs: symmetric 8-bit per-tensor or per-token. Per-token gradient
/// scales sit on the token axis, which is the **output** axis of the
/// input-grad contraction (`dy @ wᵀ`) and factors row-wise out of the
/// weight-grad contraction (`xᵀ @ dy`), so they never vary along a
/// reduction the integer kernels fold over. Per-channel gradient scales
/// would vary along the weight-grad reduction and are rejected, as are
/// asymmetric and non-8-bit grids (same reasons as [`int8_act_eligible`]).
pub fn int8_grad_eligible(p: TensorPolicy) -> bool {
    p.bits == 8
        && !p.asymmetric
        && matches!(p.granularity, Granularity::PerTensor | Granularity::PerToken)
}

/// A GEMM operand quantized **once** onto the int8 grid: row-major codes
/// plus one scale per group (length 1 for per-tensor operands, `rows` for
/// per-token activations, `cols` for per-channel weights). The scales come
/// from the same [`group_params_qmax`] the qdq oracle uses, so
/// `scale * code` reproduces the fake-quant values bit for bit — with one
/// caveat: an integer code cannot carry the sign of a negative zero, so a
/// value that rounds into the zero bin *from below* dequantizes to `+0.0`
/// where the f32 oracle yields `-0.0` (equal values, different bits).
///
/// Rows are **lane-padded**: `codes` holds `rows * stride` entries with
/// `stride = cols` rounded up to [`I8_LANES`], and the `stride - cols`
/// trailing codes of every row are zero. A zero code contributes exactly
/// 0 to an i32 accumulator, so the widening SIMD GEMM
/// (`kernels::matmul_i8_packed`) can always load full lanes — the padding
/// is semantically inert, not just alignment slack.
#[derive(Debug, Clone)]
pub struct PackedGemmOperand {
    pub codes: Vec<i8>,
    pub scales: Vec<f32>,
    pub rows: usize,
    pub cols: usize,
    /// Row stride of `codes`: `cols.next_multiple_of(I8_LANES)`.
    pub stride: usize,
}

/// Quantize an activation matrix for the int8 GEMM (lane-padded layout;
/// see [`PackedGemmOperand`]). The policy must be [`int8_act_eligible`].
pub fn pack_acts_i8(
    x: &[f32],
    rows: usize,
    cols: usize,
    policy: TensorPolicy,
) -> PackedGemmOperand {
    assert!(int8_act_eligible(policy), "policy not int8-act eligible");
    assert_eq!(x.len(), rows * cols);
    let qmax = policy.qmax();
    let params = group_params_qmax(x, rows, cols, policy.granularity, false, qmax);
    let stride = cols.next_multiple_of(crate::backend::simd::I8_LANES);
    let mut codes = vec![0i8; rows * stride];
    for r in 0..rows {
        let p = match policy.granularity {
            Granularity::PerTensor => params[0],
            Granularity::PerToken => params[r],
            Granularity::PerChannel => unreachable!("rejected by eligibility"),
        };
        let row = &mut codes[r * stride..r * stride + cols];
        for (slot, &v) in row.iter_mut().zip(&x[r * cols..(r + 1) * cols]) {
            *slot = quantize_one(v, p, qmax) as i8;
        }
    }
    PackedGemmOperand {
        codes,
        scales: params.iter().map(|p| p.scale).collect(),
        rows,
        cols,
        stride,
    }
}

/// Quantize a (rows x cols) weight matrix for the int8 GEMM (lane-padded
/// layout; see [`PackedGemmOperand`]). The policy must be
/// [`int8_weight_eligible`].
pub fn pack_weights_i8(
    w: &[f32],
    rows: usize,
    cols: usize,
    policy: TensorPolicy,
) -> PackedGemmOperand {
    assert!(int8_weight_eligible(policy), "policy not int8-weight eligible");
    assert_eq!(w.len(), rows * cols);
    let qmax = policy.qmax();
    let params = group_params_qmax(w, rows, cols, policy.granularity, false, qmax);
    let stride = cols.next_multiple_of(crate::backend::simd::I8_LANES);
    let mut codes = vec![0i8; rows * stride];
    // granularity dispatch hoisted out of the element loop: this runs once
    // per linear per step (the native backend caches the packed operand in
    // its per-step layer cache, so backward reuses it instead of repacking)
    match policy.granularity {
        Granularity::PerTensor => {
            let p = params[0];
            for r in 0..rows {
                let row = &mut codes[r * stride..r * stride + cols];
                for (slot, &v) in row.iter_mut().zip(&w[r * cols..(r + 1) * cols]) {
                    *slot = quantize_one(v, p, qmax) as i8;
                }
            }
        }
        Granularity::PerChannel => {
            for r in 0..rows {
                let row = &mut codes[r * stride..r * stride + cols];
                let wrow = &w[r * cols..(r + 1) * cols];
                for ((slot, &v), p) in row.iter_mut().zip(wrow).zip(&params) {
                    *slot = quantize_one(v, *p, qmax) as i8;
                }
            }
        }
        Granularity::PerToken => unreachable!("rejected by eligibility"),
    }
    PackedGemmOperand {
        codes,
        scales: params.iter().map(|p| p.scale).collect(),
        rows,
        cols,
        stride,
    }
}

/// Dequantize packed *activation* codes back to f32 — bitwise identical to
/// running [`qdq`] on the original matrix (same group params, same codes,
/// same `scale * code` expression as the symmetric [`qdq_one`]), except
/// that zero-bin values quantized from below come back `+0.0` instead of
/// the oracle's `-0.0` (see [`PackedGemmOperand`]). This is what lets the
/// fast path hand backward the cache the reference path would have
/// produced. The lane padding is dropped: the output is tight
/// (rows x cols).
pub fn dequant_acts_i8(p: &PackedGemmOperand) -> Vec<f32> {
    assert_eq!(p.codes.len(), p.rows * p.stride);
    assert!(
        p.scales.len() == 1 || p.scales.len() == p.rows,
        "dequant_acts_i8: scales must be 1 or rows"
    );
    let mut out = Vec::with_capacity(p.rows * p.cols);
    for r in 0..p.rows {
        let s = if p.scales.len() == 1 {
            p.scales[0]
        } else {
            p.scales[r]
        };
        for &c in &p.codes[r * p.stride..r * p.stride + p.cols] {
            out.push(s * c as f32);
        }
    }
    out
}

/// Quantize a gradient matrix for the backward int8 GEMMs (lane-padded
/// layout; see [`PackedGemmOperand`]). The policy must be
/// [`int8_grad_eligible`]; the quantization numerics are exactly the
/// activation ones (symmetric row-wise grid from [`group_params_qmax`]),
/// so `scale * code` reproduces the gradient qdq oracle bit for bit
/// (modulo the `-0.0` caveat documented on [`PackedGemmOperand`]).
pub fn pack_grads_i8(
    g: &[f32],
    rows: usize,
    cols: usize,
    policy: TensorPolicy,
) -> PackedGemmOperand {
    assert!(int8_grad_eligible(policy), "policy not int8-grad eligible");
    pack_acts_i8(g, rows, cols, policy)
}

/// Dequantize packed *weight* codes back to f32 — bitwise identical to the
/// weight qdq oracle (same group params, same codes, same `scale * code`
/// expression), except that zero-bin values quantized from below come back
/// `+0.0` instead of the oracle's `-0.0` (see [`PackedGemmOperand`]).
/// Scales broadcast per column (per-channel) or per tensor. This is how
/// backward's f32 input-grad fallback reuses the cached packed weights: an
/// int-to-float multiply per element, with no re-quantization amax scan.
/// The lane padding is dropped: the output is tight (rows x cols).
pub fn dequant_weights_i8(p: &PackedGemmOperand) -> Vec<f32> {
    assert_eq!(p.codes.len(), p.rows * p.stride);
    assert!(
        p.scales.len() == 1 || p.scales.len() == p.cols,
        "dequant_weights_i8: scales must be 1 or cols"
    );
    let mut out = Vec::with_capacity(p.rows * p.cols);
    for r in 0..p.rows {
        let row = &p.codes[r * p.stride..r * p.stride + p.cols];
        if p.scales.len() == 1 {
            let s = p.scales[0];
            for &c in row {
                out.push(s * c as f32);
            }
        } else {
            for (&c, &s) in row.iter().zip(p.scales.iter()) {
                out.push(s * c as f32);
            }
        }
    }
    out
}

/// The raw integer codes of a packed operand as a tight (rows x cols) i8
/// matrix, lane padding dropped: the canonical wire form of a quantized
/// gradient (the `dist` exchange ships tight codes + scales and re-pads on
/// receive with [`operand_from_codes`], so sender and receiver hold the
/// same operand bit for bit).
pub fn tight_codes_i8(p: &PackedGemmOperand) -> Vec<i8> {
    assert_eq!(p.codes.len(), p.rows * p.stride);
    let mut out = Vec::with_capacity(p.rows * p.cols);
    for r in 0..p.rows {
        out.extend_from_slice(&p.codes[r * p.stride..r * p.stride + p.cols]);
    }
    out
}

/// Rebuild a [`PackedGemmOperand`] from tight wire codes + scales: the
/// inverse of [`tight_codes_i8`]. Re-pads each row to the lane stride with
/// zero codes (semantically inert; see [`PackedGemmOperand`]), so
/// `dequant_acts_i8(operand_from_codes(tight_codes_i8(p), ...))` is
/// bitwise identical to `dequant_acts_i8(p)`.
pub fn operand_from_codes(
    tight: &[i8],
    scales: Vec<f32>,
    rows: usize,
    cols: usize,
) -> PackedGemmOperand {
    assert_eq!(tight.len(), rows * cols, "tight codes must be rows x cols");
    assert!(
        scales.len() == 1 || scales.len() == rows,
        "scales must be per-tensor or per-row"
    );
    let stride = cols.next_multiple_of(crate::backend::simd::I8_LANES);
    let mut codes = vec![0i8; rows * stride];
    for r in 0..rows {
        codes[r * stride..r * stride + cols].copy_from_slice(&tight[r * cols..(r + 1) * cols]);
    }
    PackedGemmOperand {
        codes,
        scales,
        rows,
        cols,
        stride,
    }
}

/// The raw integer codes of a packed operand as a tight (rows x cols) f32
/// matrix — **unscaled**. This is the operand of the f32-accumulation leg
/// of the int8 GEMMs (`QPRETRAIN_INT8=off`): the f32 kernels fold the same
/// integer code products the i32 kernels do, and wherever every partial
/// sum stays below 2^24 the two accumulators agree bit for bit after the
/// shared rescale (the CI digest matrix proves this on the real runners).
pub fn codes_f32(p: &PackedGemmOperand) -> Vec<f32> {
    assert_eq!(p.codes.len(), p.rows * p.stride);
    let mut out = Vec::with_capacity(p.rows * p.cols);
    for r in 0..p.rows {
        for &c in &p.codes[r * p.stride..r * p.stride + p.cols] {
            out.push(c as f32);
        }
    }
    out
}

// ---------------------------------------------------------------------------
// quantization-error metrics (used by analyses and reports)
// ---------------------------------------------------------------------------

pub fn mse(a: &[f32], b: &[f32]) -> f64 {
    assert_eq!(a.len(), b.len());
    if a.is_empty() {
        return 0.0;
    }
    a.iter()
        .zip(b)
        .map(|(&x, &y)| ((x - y) as f64).powi(2))
        .sum::<f64>()
        / a.len() as f64
}

/// Signal-to-quantization-noise ratio in dB.
pub fn sqnr_db(signal: &[f32], quantized: &[f32]) -> f64 {
    let p_sig: f64 = signal.iter().map(|&x| (x as f64).powi(2)).sum();
    let p_err: f64 = signal
        .iter()
        .zip(quantized)
        .map(|(&x, &y)| ((x - y) as f64).powi(2))
        .sum();
    if p_err == 0.0 {
        return f64::INFINITY;
    }
    10.0 * (p_sig / p_err).log10()
}

/// Fraction of values flushed to the zero bin (the paper's Fig. 12 metric).
pub fn zero_bin_fraction(data: &[f32], rows: usize, cols: usize, policy: TensorPolicy) -> f64 {
    let q = qdq_copy(data, rows, cols, policy);
    let nonzero_in = data.iter().filter(|&&x| x != 0.0).count();
    if nonzero_in == 0 {
        return 0.0;
    }
    let flushed = data
        .iter()
        .zip(&q)
        .filter(|(&x, &y)| x != 0.0 && y == 0.0)
        .count();
    flushed as f64 / nonzero_in as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Granularity::*;

    fn grid(rows: usize, cols: usize) -> Vec<f32> {
        // same exact-rational grid as the python golden generator
        let mut v = Vec::with_capacity(rows * cols);
        for i in 0..rows {
            for j in 0..cols {
                v.push((((31 * i + 17 * j) % 257) as f32 - 128.0) / 16.0);
            }
        }
        v
    }

    #[test]
    fn hand_computed_per_tensor() {
        // matches python test_oracle_hand_computed_per_tensor
        let mut x = vec![-4.0, -1.0, 0.0, 2.0];
        qdq(&mut x, 1, 4, TensorPolicy::new(3, PerTensor));
        let s = 4.0f32 / 3.0;
        assert_eq!(x, vec![-3.0 * s, -1.0 * s, 0.0, 2.0 * s]);
    }

    #[test]
    fn round_half_even() {
        let mut x = vec![0.5, 1.5, -0.5, -1.5, 3.0];
        qdq(&mut x, 1, 5, TensorPolicy::new(3, PerTensor));
        assert_eq!(x, vec![0.0, 2.0, 0.0, -2.0, 3.0]);
    }

    #[test]
    fn per_token_rows_independent() {
        let mut x = vec![1.0, 2.0, 100.0, 200.0];
        qdq(&mut x, 2, 2, TensorPolicy::new(8, PerToken));
        assert!((x[0] - 1.0).abs() < 0.02 && (x[2] - 100.0).abs() < 2.0);
    }

    #[test]
    fn per_channel_protects_small_channels_from_outliers() {
        let rows = 16;
        let cols = 8;
        let mut x = vec![0.01f32; rows * cols];
        for r in 0..rows {
            x[r * cols + 3] = 100.0;
        }
        let pt = qdq_copy(&x, rows, cols, TensorPolicy::new(4, PerTensor));
        let pc = qdq_copy(&x, rows, cols, TensorPolicy::new(4, PerChannel));
        assert_eq!(pt[0], 0.0); // flushed by the shared scale
        assert!((pc[0] - 0.01).abs() < 2e-3);
    }

    #[test]
    fn asym_recovers_endpoints() {
        let mut x = vec![0.0, 1.0, 2.0, 3.0];
        qdq(&mut x, 1, 4, TensorPolicy::asym(4, PerToken));
        assert!((x[0] - 0.0).abs() < 1e-6);
        assert!((x[3] - 3.0).abs() < 1e-4);
    }

    #[test]
    fn idempotent() {
        let x = grid(16, 12);
        for g in [PerTensor, PerToken, PerChannel] {
            let once = qdq_copy(&x, 16, 12, TensorPolicy::new(4, g));
            let twice = qdq_copy(&once, 16, 12, TensorPolicy::new(4, g));
            for (a, b) in once.iter().zip(&twice) {
                assert!((a - b).abs() < 1e-6, "{g:?}: {a} vs {b}");
            }
        }
    }

    #[test]
    fn more_bits_less_error() {
        let x = grid(32, 32);
        let e2 = mse(&x, &qdq_copy(&x, 32, 32, TensorPolicy::new(2, PerTensor)));
        let e4 = mse(&x, &qdq_copy(&x, 32, 32, TensorPolicy::new(4, PerTensor)));
        let e8 = mse(&x, &qdq_copy(&x, 32, 32, TensorPolicy::new(8, PerTensor)));
        assert!(e2 > e4 && e4 > e8);
    }

    #[test]
    fn packed_roundtrip_matches_qdq() {
        let x = grid(24, 20);
        for bits in [4u32, 8] {
            for g in [PerTensor, PerToken, PerChannel] {
                let scheme = TensorPolicy::new(bits, g);
                let packed = PackedTensor::quantize(&x, 24, 20, scheme);
                let deq = packed.dequantize();
                let fake = qdq_copy(&x, 24, 20, scheme);
                for (a, b) in deq.iter().zip(&fake) {
                    assert!((a - b).abs() < 1e-5, "bits={bits} {g:?}: {a} vs {b}");
                }
            }
        }
    }

    #[test]
    fn packed_sizes() {
        let x = grid(64, 64);
        let p8 = PackedTensor::quantize(&x, 64, 64, TensorPolicy::new(8, PerChannel));
        let p4 = PackedTensor::quantize(&x, 64, 64, TensorPolicy::new(4, PerChannel));
        assert_eq!(p8.data.len(), 64 * 64);
        assert_eq!(p4.data.len(), 64 * 64 / 2);
        assert!(p4.storage_bytes() < p8.storage_bytes());
        // vs fp32: 4x and 8x smaller (ignoring scales)
        assert!(p8.storage_bytes() * 4 <= 64 * 64 * 4 + 4 * 64 * 4);
    }

    #[test]
    fn int8_eligibility_rules() {
        // activations: symmetric 8-bit per-tensor/per-token only
        assert!(int8_act_eligible(TensorPolicy::new(8, PerTensor)));
        assert!(int8_act_eligible(TensorPolicy::new(8, PerToken)));
        assert!(!int8_act_eligible(TensorPolicy::new(8, PerChannel)));
        assert!(!int8_act_eligible(TensorPolicy::asym(8, PerToken)));
        assert!(!int8_act_eligible(TensorPolicy::new(4, PerToken)));
        assert!(!int8_act_eligible(TensorPolicy::new(0, PerToken)));
        // weights: symmetric 8-bit per-tensor/per-channel only
        assert!(int8_weight_eligible(TensorPolicy::new(8, PerTensor)));
        assert!(int8_weight_eligible(TensorPolicy::new(8, PerChannel)));
        assert!(!int8_weight_eligible(TensorPolicy::new(8, PerToken)));
        assert!(!int8_weight_eligible(TensorPolicy::asym(8, PerChannel)));
        assert!(!int8_weight_eligible(TensorPolicy::new(16, PerChannel)));
        // gradients: symmetric 8-bit per-tensor/per-token only
        assert!(int8_grad_eligible(TensorPolicy::new(8, PerTensor)));
        assert!(int8_grad_eligible(TensorPolicy::new(8, PerToken)));
        assert!(!int8_grad_eligible(TensorPolicy::new(8, PerChannel)));
        assert!(!int8_grad_eligible(TensorPolicy::asym(8, PerToken)));
        assert!(!int8_grad_eligible(TensorPolicy::new(4, PerToken)));
        assert!(!int8_grad_eligible(TensorPolicy::new(0, PerToken)));
    }

    #[test]
    fn packed_grads_dequant_bitexact_with_qdq() {
        // pack_grads_i8 shares the activation packer, so the same bitwise
        // contract holds: scale * code == qdq on the rational grid
        let g = grid(16, 12);
        for gr in [PerTensor, PerToken] {
            let pol = TensorPolicy::new(8, gr);
            let packed = pack_grads_i8(&g, 16, 12, pol);
            let deq = dequant_acts_i8(&packed);
            let fake = qdq_copy(&g, 16, 12, pol);
            let bits = |v: &[f32]| v.iter().map(|f| f.to_bits()).collect::<Vec<u32>>();
            assert_eq!(bits(&deq), bits(&fake), "{gr:?}: grad dequant != qdq");
        }
    }

    #[test]
    fn dequant_weights_bitexact_with_qdq() {
        let w = grid(24, 10);
        for gr in [PerTensor, PerChannel] {
            let pol = TensorPolicy::new(8, gr);
            let packed = pack_weights_i8(&w, 24, 10, pol);
            let deq = dequant_weights_i8(&packed);
            let fake = qdq_copy(&w, 24, 10, pol);
            let bits = |v: &[f32]| v.iter().map(|f| f.to_bits()).collect::<Vec<u32>>();
            assert_eq!(bits(&deq), bits(&fake), "{gr:?}: weight dequant != qdq");
        }
    }

    #[test]
    fn codes_f32_drops_padding_and_matches_codes() {
        let (rows, cols) = (5, 13); // unaligned: stride pads to the lane width
        let x = grid(rows, cols);
        let p = pack_acts_i8(&x, rows, cols, TensorPolicy::new(8, PerToken));
        let cf = codes_f32(&p);
        assert_eq!(cf.len(), rows * cols);
        for r in 0..rows {
            for c in 0..cols {
                assert_eq!(cf[r * cols + c], p.codes[r * p.stride + c] as f32);
            }
        }
    }

    #[test]
    fn packed_gemm_acts_dequant_bitexact_with_qdq() {
        // the rational grid has no value in the tiny window that rounds to
        // the zero bin from below, so the -0.0-sign caveat never triggers
        // and full bitwise equality is the correct expectation here
        let x = grid(16, 12);
        for g in [PerTensor, PerToken] {
            let pol = TensorPolicy::new(8, g);
            let packed = pack_acts_i8(&x, 16, 12, pol);
            let deq = dequant_acts_i8(&packed);
            let fake = qdq_copy(&x, 16, 12, pol);
            let bits = |v: &[f32]| v.iter().map(|f| f.to_bits()).collect::<Vec<u32>>();
            assert_eq!(bits(&deq), bits(&fake), "{g:?}: dequant != qdq");
        }
    }

    #[test]
    fn packed_gemm_weights_match_qdq_values() {
        let w = grid(24, 10);
        for g in [PerTensor, PerChannel] {
            let pol = TensorPolicy::new(8, g);
            let packed = pack_weights_i8(&w, 24, 10, pol);
            let fake = qdq_copy(&w, 24, 10, pol);
            for r in 0..24 {
                for c in 0..10 {
                    let s = if packed.scales.len() == 1 {
                        packed.scales[0]
                    } else {
                        packed.scales[c]
                    };
                    let deq = s * packed.codes[r * packed.stride + c] as f32;
                    assert_eq!(
                        deq.to_bits(),
                        fake[r * 10 + c].to_bits(),
                        "{g:?} at ({r},{c})"
                    );
                }
            }
        }
    }

    #[test]
    fn packed_gemm_rows_are_lane_padded_with_zero_codes() {
        use crate::backend::simd::I8_LANES;
        // cols not a multiple of the lane width: stride rounds up and every
        // padding slot holds code 0 (inert in an i32 accumulator)
        let (rows, cols) = (5, 13);
        let x = grid(rows, cols);
        let a = pack_acts_i8(&x, rows, cols, TensorPolicy::new(8, PerToken));
        let w = pack_weights_i8(&x, rows, cols, TensorPolicy::new(8, PerChannel));
        for p in [&a, &w] {
            assert_eq!(p.stride, cols.next_multiple_of(I8_LANES));
            assert_eq!(p.codes.len(), rows * p.stride);
            for r in 0..rows {
                for c in cols..p.stride {
                    assert_eq!(p.codes[r * p.stride + c], 0, "padding not zero at ({r},{c})");
                }
            }
        }
        // lane-aligned cols: no padding at all
        let tight = pack_acts_i8(&grid(3, 32), 3, 32, TensorPolicy::new(8, PerToken));
        assert_eq!(tight.stride, 32);
        assert_eq!(tight.codes.len(), 3 * 32);
    }

    #[test]
    fn zero_bin_collapse_metric() {
        // tiny values + one huge outlier: symmetric 8-bit flushes the rest
        let mut x = vec![1e-4f32; 256];
        x[0] = 1e4;
        let f = zero_bin_fraction(&x, 1, 256, TensorPolicy::new(8, PerTensor));
        assert!(f > 0.99, "{f}");
        let f = zero_bin_fraction(&x, 1, 256, TensorPolicy::new(8, PerToken));
        assert!(f > 0.99);
    }

    #[test]
    fn sqnr_increases_with_bits() {
        let x = grid(32, 32);
        let s4 = sqnr_db(&x, &qdq_copy(&x, 32, 32, TensorPolicy::new(4, PerTensor)));
        let s8 = sqnr_db(&x, &qdq_copy(&x, 32, 32, TensorPolicy::new(8, PerTensor)));
        assert!(s8 > s4 + 15.0, "s4={s4} s8={s8}"); // ~6 dB per bit
    }
}
