//! Host-side model state: parameter initialization (matching the GPT-2
//! conventions recorded in the manifest / native registry) and checkpoints.
//!
//! `HostState` is the currency of the [`crate::backend`] seam: backends
//! consume and update it in place; nothing here depends on how steps
//! execute.

use std::io::{Read, Write};
use std::path::Path;

use anyhow::{anyhow, bail, Context, Result};
use flate2::read::GzDecoder;
use flate2::write::GzEncoder;

use crate::runtime::{ModelInfo, ParamInfo};
use crate::util::rng::Rng;

/// Full optimizer+model state on the host: params, Adam m and v, step count.
#[derive(Debug, Clone)]
pub struct HostState {
    pub model: String,
    pub step: usize,
    pub params: Vec<Vec<f32>>,
    pub m: Vec<Vec<f32>>,
    pub v: Vec<Vec<f32>>,
}

/// Initialize one parameter tensor per its manifest init spec.
pub fn init_param(p: &ParamInfo, n_layer: usize, rng: &mut Rng) -> Vec<f32> {
    let n = p.elems();
    match p.init.as_str() {
        "ones" => vec![1.0; n],
        "zeros" => vec![0.0; n],
        "residual" => {
            // GPT-2: residual-projection init scaled by 1/sqrt(2L)
            let std = 0.02 / (2.0 * n_layer as f32).sqrt();
            rng.normal_vec(n, 0.0, std)
        }
        s if s.starts_with("normal:") => {
            let std: f32 = s["normal:".len()..].parse().unwrap_or(0.02);
            rng.normal_vec(n, 0.0, std)
        }
        other => {
            log::warn!("unknown init {other:?} for {}, using zeros", p.name);
            vec![0.0; n]
        }
    }
}

/// Fresh training state for a model (params initialized, moments zero).
pub fn init_state(model: &ModelInfo, seed: u64) -> HostState {
    let root = Rng::new(seed);
    let mut params = Vec::with_capacity(model.params.len());
    for (i, p) in model.params.iter().enumerate() {
        let mut rng = root.fork(i as u64);
        params.push(init_param(p, model.n_layer, &mut rng));
    }
    let zeros: Vec<Vec<f32>> = model.params.iter().map(|p| vec![0.0; p.elems()]).collect();
    HostState {
        model: model.name.clone(),
        step: 0,
        params,
        m: zeros.clone(),
        v: zeros,
    }
}

impl HostState {
    pub fn n_scalars(&self) -> usize {
        self.params.iter().map(|p| p.len()).sum()
    }

    /// L2 norm of each parameter tensor (used for filter normalization).
    pub fn param_norms(&self) -> Vec<f64> {
        self.params
            .iter()
            .map(|p| p.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>().sqrt())
            .collect()
    }
}

// ---------------------------------------------------------------------------
// checkpoints: gzip-compressed custom binary format
// ---------------------------------------------------------------------------

const MAGIC: &[u8] = b"QPCKPT1\n";

pub fn save_checkpoint(path: &Path, model: &ModelInfo, state: &HostState) -> Result<()> {
    crate::util::ensure_parent(path)?;
    let file = std::fs::File::create(path).with_context(|| format!("create {path:?}"))?;
    let mut w = GzEncoder::new(file, flate2::Compression::fast());
    w.write_all(MAGIC)?;
    let header = format!(
        "{{\"model\":\"{}\",\"step\":{},\"n_tensors\":{}}}\n",
        state.model,
        state.step,
        model.params.len()
    );
    w.write_all(&(header.len() as u32).to_le_bytes())?;
    w.write_all(header.as_bytes())?;
    for group in [&state.params, &state.m, &state.v] {
        for (p, data) in model.params.iter().zip(group.iter()) {
            if data.len() != p.elems() {
                bail!("tensor {} length mismatch", p.name);
            }
            for x in data.iter() {
                w.write_all(&x.to_le_bytes())?;
            }
        }
    }
    w.finish()?;
    Ok(())
}

pub fn load_checkpoint(path: &Path, model: &ModelInfo) -> Result<HostState> {
    let file = std::fs::File::open(path).with_context(|| format!("open {path:?}"))?;
    let mut r = GzDecoder::new(file);
    let mut magic = [0u8; 8];
    r.read_exact(&mut magic)?;
    if magic != MAGIC {
        bail!("{path:?} is not a qpretrain checkpoint");
    }
    let mut len_bytes = [0u8; 4];
    r.read_exact(&mut len_bytes)?;
    let hlen = u32::from_le_bytes(len_bytes) as usize;
    let mut hdr = vec![0u8; hlen];
    r.read_exact(&mut hdr)?;
    let header = crate::util::json::parse(std::str::from_utf8(&hdr)?.trim())?;
    let step = header.req("step")?.as_usize().unwrap_or(0);
    let name = header
        .req("model")?
        .as_str()
        .ok_or_else(|| anyhow!("bad header"))?
        .to_string();
    if name != model.name {
        bail!(
            "checkpoint is for model {name:?}, expected {:?}",
            model.name
        );
    }

    let mut read_group = || -> Result<Vec<Vec<f32>>> {
        model
            .params
            .iter()
            .map(|p| {
                let n = p.elems();
                let mut bytes = vec![0u8; n * 4];
                r.read_exact(&mut bytes)?;
                Ok(bytes
                    .chunks_exact(4)
                    .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
                    .collect())
            })
            .collect()
    };
    let params = read_group()?;
    let m = read_group()?;
    let v = read_group()?;
    Ok(HostState {
        model: name,
        step,
        params,
        m,
        v,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_model() -> ModelInfo {
        ModelInfo {
            name: "tiny".into(),
            n_layer: 2,
            d_model: 4,
            n_head: 1,
            vocab: 8,
            seq: 8,
            batch: 1,
            d_ff: 16,
            n_params: 0,
            params: vec![
                ParamInfo {
                    name: "wte".into(),
                    shape: vec![8, 4],
                    stacked: false,
                    decay: true,
                    init: "normal:0.02".into(),
                },
                ParamInfo {
                    name: "ln_w".into(),
                    shape: vec![4],
                    stacked: false,
                    decay: false,
                    init: "ones".into(),
                },
                ParamInfo {
                    name: "proj_w".into(),
                    shape: vec![2, 4, 4],
                    stacked: true,
                    decay: true,
                    init: "residual".into(),
                },
            ],
        }
    }

    #[test]
    fn init_respects_specs() {
        let m = tiny_model();
        let s = init_state(&m, 42);
        assert_eq!(s.params[1], vec![1.0; 4]); // ones
        assert!(s.params[0].iter().any(|&x| x != 0.0)); // normal
        // residual init has smaller std than 0.02
        let std0 = crate::util::stats::summarize(&s.params[0]).std;
        let std2 = crate::util::stats::summarize(&s.params[2]).std;
        assert!(std2 < std0);
        assert!(s.m.iter().all(|t| t.iter().all(|&x| x == 0.0)));
    }

    #[test]
    fn init_deterministic() {
        let m = tiny_model();
        let a = init_state(&m, 7);
        let b = init_state(&m, 7);
        assert_eq!(a.params, b.params);
        let c = init_state(&m, 8);
        assert_ne!(a.params[0], c.params[0]);
    }

    #[test]
    fn checkpoint_roundtrip() {
        let m = tiny_model();
        let mut s = init_state(&m, 1);
        s.step = 123;
        s.m[0][0] = 0.5;
        s.v[2][3] = -2.0;
        let dir = std::env::temp_dir().join("qpretrain_ckpt_test");
        let path = dir.join("x.ckpt");
        save_checkpoint(&path, &m, &s).unwrap();
        let loaded = load_checkpoint(&path, &m).unwrap();
        assert_eq!(loaded.step, 123);
        assert_eq!(loaded.params, s.params);
        assert_eq!(loaded.m, s.m);
        assert_eq!(loaded.v, s.v);
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn checkpoint_rejects_wrong_model() {
        let m = tiny_model();
        let s = init_state(&m, 1);
        let dir = std::env::temp_dir().join("qpretrain_ckpt_test2");
        let path = dir.join("x.ckpt");
        save_checkpoint(&path, &m, &s).unwrap();
        let mut other = tiny_model();
        other.name = "other".into();
        assert!(load_checkpoint(&path, &other).is_err());
        std::fs::remove_dir_all(dir).ok();
    }
}
