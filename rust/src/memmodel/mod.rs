//! Analytic peak-memory model (paper §3.3 + Appendix B, Figs. 2/14/15).
//!
//! The paper's figures are PyTorch-profiler *accounting* of training memory;
//! this module reproduces the accounting analytically for mixed-precision
//! (bf16 compute, fp32 Adam states) GPT-2 training with FlashAttention
//! (activation footprint linear in sequence length, no stored attention
//! matrix). It simulates the allocation timeline and reports the composition
//! at whichever phase peaks — reproducing the paper's observation that the
//! peak shifts from end-of-backward (gradients resident) to
//! start-of-backward (activations + logit gradient resident) as batch*seq
//! grows, at which point gradients stop contributing to peak memory.

use crate::runtime::ModelInfo;

const BF16: usize = 2;
const FP32: usize = 4;

/// bf16 activation elements stored per layer per token for the backward pass
/// (pre-LN GPT-2 with FlashAttention): ln1 out (d) + qkv out (3d) + attn out
/// (d) + proj out (d) + ln2 out (d) + fc1/gelu out (2*4d) + fc2 out (d) +
/// residual streams (2d) = 17d; flash softmax stats add O(heads) per token.
fn act_elems_per_layer_token(d_model: usize, n_head: usize) -> usize {
    17 * d_model + 2 * n_head
}

#[derive(Debug, Clone, Copy, PartialEq)]
pub struct MemBreakdown {
    pub params: usize,
    pub grads: usize,
    pub optim: usize,
    pub activations: usize,
    pub logits: usize,
    /// which phase peaked: "bwd_start" or "bwd_end"
    pub peak_phase: &'static str,
}

impl MemBreakdown {
    pub fn total(&self) -> usize {
        self.params + self.grads + self.optim + self.activations + self.logits
    }

    pub fn fractions(&self) -> [(&'static str, f64); 5] {
        let t = self.total() as f64;
        [
            ("params", self.params as f64 / t),
            ("grads", self.grads as f64 / t),
            ("optim", self.optim as f64 / t),
            ("activations", self.activations as f64 / t),
            ("logits", self.logits as f64 / t),
        ]
    }
}

/// Peak-memory composition for training `model` at (batch, seq).
/// `act_bits` / `weight_bits` model the paper's quantized-storage savings
/// (16 = bf16 baseline).
pub fn peak_memory(model: &ModelInfo, batch: usize, seq: usize) -> MemBreakdown {
    peak_memory_quantized(model, batch, seq, 16, 16, 32)
}

pub fn peak_memory_quantized(
    model: &ModelInfo,
    batch: usize,
    seq: usize,
    weight_bits: usize,
    act_bits: usize,
    optim_bits_per_state: usize,
) -> MemBreakdown {
    let n = model.n_params;
    let tokens = batch * seq;

    let params = n * BF16 * weight_bits / 16 + n * FP32; // bf16 copy + fp32 master
    let grads = n * BF16;
    let optim = 2 * n * (optim_bits_per_state / 8);

    let acts_per_layer =
        tokens * act_elems_per_layer_token(model.d_model, model.n_head) * BF16 * act_bits / 16;
    let emb_acts = tokens * model.d_model * BF16 * act_bits / 16;
    let all_acts = emb_acts + model.n_layer * acts_per_layer;

    // logits + softmax workspace in fp32; its gradient materializes at the
    // start of the backward pass
    let logits = tokens * model.vocab * FP32;
    let logit_grad = tokens * model.vocab * FP32;

    // phase 1: start of backward — everything from the forward is resident
    // plus the logit gradient; layer gradients not yet allocated.
    let bwd_start = params + optim + all_acts + logits + logit_grad;
    // phase 2: end of backward — all gradients allocated; activations freed
    // except the earliest layer; logit gradient freed.
    let bwd_end = params + optim + grads + emb_acts + acts_per_layer + logits;

    if bwd_start >= bwd_end {
        MemBreakdown {
            params,
            grads: 0, // gradients do not contribute at this peak (paper App. B)
            optim,
            activations: all_acts,
            logits: logits + logit_grad,
            peak_phase: "bwd_start",
        }
    } else {
        MemBreakdown {
            params,
            grads,
            optim,
            activations: emb_acts + acts_per_layer,
            logits,
            peak_phase: "bwd_end",
        }
    }
}

/// GPT-2 family shapes used by the paper's profiling figures.
pub fn profile_model(name: &str) -> ModelInfo {
    let (n_layer, d_model, n_head) = match name {
        "small" => (12, 768, 12),
        "medium" => (24, 1024, 16),
        "large" => (36, 1280, 20),
        "xl" => (48, 1600, 25),
        other => panic!("unknown profile model {other}"),
    };
    let vocab = 50257;
    let d_ff = 4 * d_model;
    let per_layer = 2 * d_model
        + d_model * 3 * d_model
        + 3 * d_model
        + d_model * d_model
        + d_model
        + 2 * d_model
        + d_model * d_ff
        + d_ff
        + d_ff * d_model
        + d_model;
    let n_params = vocab * d_model + 1024 * d_model + n_layer * per_layer + 2 * d_model;
    ModelInfo {
        name: name.to_string(),
        n_layer,
        d_model,
        n_head,
        vocab,
        seq: 1024,
        batch: 1,
        d_ff,
        n_params,
        params: vec![],
    }
}

/// Render the Fig. 2 table: rows = batch sizes, composition fractions.
pub fn fig2_table(sizes: &[&str], batches: &[usize], seq: usize) -> String {
    let mut out = String::from(
        "model,batch,peak_gb,params_frac,grads_frac,optim_frac,act_frac,logits_frac,peak_phase\n",
    );
    for &size in sizes {
        let m = profile_model(size);
        for &b in batches {
            let mem = peak_memory(&m, b, seq);
            let f = mem.fractions();
            out.push_str(&format!(
                "{size},{b},{:.2},{:.3},{:.3},{:.3},{:.3},{:.3},{}\n",
                mem.total() as f64 / 1e9,
                f[0].1,
                f[1].1,
                f[2].1,
                f[3].1,
                f[4].1,
                mem.peak_phase
            ));
        }
    }
    out
}

/// Fig. 15: memory vs sequence length at fixed batch.
pub fn fig15_table(sizes: &[&str], seqs: &[usize], batch: usize) -> String {
    let mut out = String::from(
        "model,seq,peak_gb,params_frac,grads_frac,optim_frac,act_frac,logits_frac,peak_phase\n",
    );
    for &size in sizes {
        let m = profile_model(size);
        for &s in seqs {
            let mem = peak_memory(&m, batch, s);
            let f = mem.fractions();
            out.push_str(&format!(
                "{size},{s},{:.2},{:.3},{:.3},{:.3},{:.3},{:.3},{}\n",
                mem.total() as f64 / 1e9,
                f[0].1,
                f[1].1,
                f[2].1,
                f[3].1,
                f[4].1,
                mem.peak_phase
            ));
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn profile_model_param_counts_are_plausible() {
        // GPT-2 small ~124M, medium ~350M, large ~774M, xl ~1.5B
        assert!((profile_model("small").n_params as f64 / 124e6 - 1.0).abs() < 0.05);
        assert!((profile_model("medium").n_params as f64 / 350e6 - 1.0).abs() < 0.1);
        assert!((profile_model("large").n_params as f64 / 774e6 - 1.0).abs() < 0.1);
        assert!((profile_model("xl").n_params as f64 / 1.55e9 - 1.0).abs() < 0.1);
    }

    #[test]
    fn activations_dominate_at_large_batch() {
        // paper Fig. 2: with batch up, activations take the majority share
        let m = profile_model("small");
        let mem = peak_memory(&m, 64, 1024);
        // logits (+ their gradient) are activation memory in the profiler's
        // accounting; together they must dominate at large batch
        let act_frac = (mem.activations + mem.logits) as f64 / mem.total() as f64;
        assert!(act_frac > 0.5, "act fraction {act_frac}");
        assert_eq!(mem.peak_phase, "bwd_start");
        assert_eq!(mem.grads, 0); // paper App. B: grads don't hit the peak
    }

    #[test]
    fn gradients_matter_at_tiny_batch() {
        let m = profile_model("xl");
        let mem = peak_memory(&m, 1, 128);
        assert_eq!(mem.peak_phase, "bwd_end");
        assert!(mem.grads > 0);
    }

    #[test]
    fn peak_shifts_with_seq_at_fixed_batch() {
        // paper Fig. 15: increasing seq flips the peak to bwd_start
        let m = profile_model("large");
        let short = peak_memory(&m, 4, 128);
        let long = peak_memory(&m, 4, 2048);
        assert_eq!(short.peak_phase, "bwd_end");
        assert_eq!(long.peak_phase, "bwd_start");
    }

    #[test]
    fn quantized_storage_shrinks_memory() {
        let m = profile_model("small");
        let fp = peak_memory_quantized(&m, 32, 1024, 16, 16, 32);
        let q8 = peak_memory_quantized(&m, 32, 1024, 8, 8, 8);
        assert!(q8.total() < fp.total());
        // activation quantization dominates the savings at large batch
        assert!(q8.activations * 2 <= fp.activations + 1);
    }

    #[test]
    fn memory_grows_monotonically_in_batch() {
        let m = profile_model("medium");
        let mut prev = 0usize;
        for b in [1, 2, 4, 8, 16, 32] {
            let t = peak_memory(&m, b, 1024).total();
            assert!(t > prev);
            prev = t;
        }
    }

    #[test]
    fn tables_render() {
        let t = fig2_table(&["small"], &[4, 8], 1024);
        assert_eq!(t.lines().count(), 3);
        let t = fig15_table(&["small"], &[128, 1024], 4);
        assert!(t.contains("small,1024"));
    }
}
