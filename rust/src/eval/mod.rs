//! Evaluation harness: perplexity over the four synthetic corpora
//! (Tables 2-5, 10, 11 columns) and the few-shot downstream suite
//! (Tables 6-9 columns), scored via the backend's per-position NLL.
//!
//! Parameters arrive as host vectors (one `Vec<f32>` per tensor in manifest
//! order); a [`QuantRecipe`] names the forward quantization (typically a
//! training recipe's [`QuantRecipe::forward_only`] view, e.g. `base`,
//! `w4_pc`, `a8_ptok_asym`).

use std::collections::BTreeMap;

use anyhow::{bail, Result};

use crate::config::QuantRecipe;
use crate::data::corpus::{BatchIter, CorpusCfg};
use crate::data::eval_sets;
use crate::data::fewshot::{paper_average, Episode, Task, TaskGen, ALL_TASKS};
use crate::runtime::{ModelInfo, Runtime};

/// Mean NLL of `params` on `n_batches` of the given corpus.
pub fn corpus_nll(
    rt: &Runtime,
    recipe: &QuantRecipe,
    model: &ModelInfo,
    params: &[Vec<f32>],
    corpus: &CorpusCfg,
    n_batches: usize,
) -> Result<f64> {
    let mut it = BatchIter::new(corpus.clone(), model.batch, model.seq);
    let mask = vec![1.0f32; model.batch * model.seq];
    let mut total = 0.0;
    for _ in 0..n_batches {
        let b = it.next_batch();
        let out = rt.eval_step(model, recipe, params, &b.x, &b.y, &mask)?;
        total += out.mean_nll;
    }
    Ok(total / n_batches as f64)
}

/// Perplexity on all four eval sets; returns (set name -> ppl).
pub fn perplexity_suite(
    rt: &Runtime,
    recipe: &QuantRecipe,
    model: &ModelInfo,
    params: &[Vec<f32>],
    n_batches: usize,
) -> Result<BTreeMap<String, f64>> {
    let mut out = BTreeMap::new();
    for (name, cfg) in eval_sets(model.vocab) {
        let nll = corpus_nll(rt, recipe, model, params, &cfg, n_batches)?;
        out.insert(name.to_string(), nll.exp());
    }
    Ok(out)
}

// ---------------------------------------------------------------------------
// few-shot scoring
// ---------------------------------------------------------------------------

/// Score one batch worth of (sequence, scored-region) rows and return the
/// summed NLL over each row's scored region.
fn score_rows(
    rt: &Runtime,
    recipe: &QuantRecipe,
    model: &ModelInfo,
    params: &[Vec<f32>],
    rows: &[(Vec<i32>, std::ops::Range<usize>)],
) -> Result<Vec<f64>> {
    let (bsz, seq) = (model.batch, model.seq);
    let mut scores = Vec::with_capacity(rows.len());
    let mask = vec![1.0f32; bsz * seq];

    for chunk in rows.chunks(bsz) {
        let mut x = vec![0i32; bsz * seq];
        let mut y = vec![0i32; bsz * seq];
        for (r, (tokens, _)) in chunk.iter().enumerate() {
            if tokens.len() > seq + 1 {
                bail!("episode length {} exceeds model seq {}", tokens.len(), seq);
            }
            for (t, &tok) in tokens.iter().take(seq).enumerate() {
                x[r * seq + t] = tok;
            }
            for (t, &tok) in tokens.iter().skip(1).take(seq).enumerate() {
                y[r * seq + t] = tok;
            }
        }
        let out = rt.eval_step(model, recipe, params, &x, &y, &mask)?;
        let per_pos = out.per_pos;
        for (r, (_, range)) in chunk.iter().enumerate() {
            let mut s = 0.0f64;
            for t in range.clone() {
                s += per_pos[r * seq + t] as f64;
            }
            scores.push(s);
        }
    }
    Ok(scores)
}

/// Accuracy of the model on a set of episodes (argmin candidate NLL).
pub fn score_episodes(
    rt: &Runtime,
    recipe: &QuantRecipe,
    model: &ModelInfo,
    params: &[Vec<f32>],
    episodes: &[Episode],
) -> Result<f64> {
    // flatten: one row per (episode, candidate)
    let mut rows = Vec::new();
    for e in episodes {
        for cand in &e.candidates {
            let mut tokens = e.prompt.clone();
            let start = tokens.len().saturating_sub(1); // predict candidate tokens
            tokens.extend(cand);
            let end = (start + cand.len()).min(model.seq);
            rows.push((tokens, start..end));
        }
    }
    let scores = score_rows(rt, recipe, model, params, &rows)?;
    let mut correct = 0usize;
    let mut idx = 0usize;
    for e in episodes {
        let k = e.candidates.len();
        let cand_scores = &scores[idx..idx + k];
        idx += k;
        // total_cmp: NaN scores (diverged checkpoints) sort last instead of
        // panicking — diverged models just score at chance level.
        let best = cand_scores
            .iter()
            .enumerate()
            .min_by(|a, b| a.1.total_cmp(b.1))
            .map(|(i, _)| i)
            .unwrap_or(0);
        if best == e.correct {
            correct += 1;
        }
    }
    Ok(correct as f64 / episodes.len() as f64)
}

/// Full few-shot suite: every task, `n_seeds` seeds, `n_episodes` each.
/// Returns per-task (mean, sd) plus the paper's aggregate average.
pub struct FewshotReport {
    pub per_task: Vec<(Task, f64, f64)>,
    pub average: f64,
}

pub fn fewshot_suite(
    rt: &Runtime,
    recipe: &QuantRecipe,
    model: &ModelInfo,
    params: &[Vec<f32>],
    n_episodes: usize,
    n_seeds: usize,
) -> Result<FewshotReport> {
    let gen = TaskGen::new(CorpusCfg::train_default(model.vocab));
    let mut per_task = Vec::new();
    let mut means = Vec::new();
    for task in ALL_TASKS {
        let mut accs = Vec::with_capacity(n_seeds);
        for seed in 0..n_seeds {
            let eps = gen.episodes(task, n_episodes, 1000 + seed as u64, 5);
            accs.push(score_episodes(rt, recipe, model, params, &eps)?);
        }
        let mean = accs.iter().sum::<f64>() / accs.len() as f64;
        let var = accs.iter().map(|a| (a - mean) * (a - mean)).sum::<f64>()
            / accs.len() as f64;
        per_task.push((task, mean, var.sqrt()));
        means.push((task, mean));
    }
    Ok(FewshotReport {
        average: paper_average(&means),
        per_task,
    })
}
