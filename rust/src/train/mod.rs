//! L3 training loop, backend-agnostic: drives [`crate::runtime::Runtime`]
//! train steps over host state.
//!
//! The loop owns the (params, m, v) state as a [`HostState`] which the
//! backend updates in place each step; the only other per-step host work is
//! the token batch, the LR scalar, and bookkeeping. Divergence (the paper's
//! non-convergence cases) is detected and recorded rather than treated as
//! an error: several of the paper's configurations are *expected* to blow
//! up, and the experiment reports need the step at which they did.

use std::io::Write;
use std::path::PathBuf;
use std::time::Instant;

use anyhow::Result;

use crate::config::{cosine_lr, QuantRecipe, TrainHp};
use crate::data::{BatchIter, CorpusCfg};
use crate::model::{init_state, save_checkpoint, HostState};
use crate::runtime::Runtime;
use crate::util::stats::{channel_abs_max, Ema};

#[derive(Debug, Clone)]
pub struct TrainCfg {
    pub model: String,
    pub quant: QuantRecipe,
    pub hp: TrainHp,
    pub out_dir: Option<PathBuf>,
    pub save_ckpt: bool,
    /// Stop early once divergence is detected (saves sweep time; the paper's
    /// diverged curves are reported as diverged either way).
    pub stop_on_divergence: bool,
}

impl TrainCfg {
    pub fn new(model: &str, quant: QuantRecipe, hp: TrainHp) -> TrainCfg {
        TrainCfg {
            model: model.to_string(),
            quant,
            hp,
            out_dir: None,
            save_ckpt: false,
            stop_on_divergence: true,
        }
    }

    /// The recipe that scores this config's checkpoints: forward-pass
    /// quantization must match what training used, while gradient and
    /// optimizer-state quantization do not appear in the forward pass.
    /// Derived from the training recipe — there is no lookup table.
    pub fn eval_recipe(&self) -> QuantRecipe {
        self.quant.forward_only()
    }
}

#[derive(Debug, Clone)]
pub struct TrainResult {
    pub label: String,
    pub losses: Vec<f64>,
    pub gnorms: Vec<f64>,
    pub val: Vec<(usize, f64)>,
    pub diverged: bool,
    pub diverged_at: Option<usize>,
    pub spike_steps: Vec<usize>,
    pub steps_per_sec: f64,
    pub final_state: HostState,
}

impl TrainResult {
    pub fn final_loss(&self) -> f64 {
        *self.losses.last().unwrap_or(&f64::NAN)
    }

    pub fn final_val_loss(&self) -> f64 {
        self.val.last().map(|(_, l)| *l).unwrap_or(f64::NAN)
    }

    pub fn min_val_loss(&self) -> f64 {
        self.val
            .iter()
            .map(|(_, l)| *l)
            .fold(f64::INFINITY, f64::min)
    }

    /// Mean loss over consecutive windows of `w` steps (smoothed curve).
    pub fn window_means(&self, w: usize) -> Vec<f64> {
        self.losses
            .chunks(w.max(1))
            .map(|c| c.iter().sum::<f64>() / c.len() as f64)
            .collect()
    }
}

/// Train a model per `cfg`, starting from `seed` init (or `resume`).
pub fn train(rt: &Runtime, cfg: &TrainCfg) -> Result<TrainResult> {
    train_from(rt, cfg, None)
}

pub fn train_from(
    rt: &Runtime,
    cfg: &TrainCfg,
    resume: Option<HostState>,
) -> Result<TrainResult> {
    // Pin the kernel thread count for the duration of this run (results
    // are thread-count-invariant; the knob only affects wall-clock), then
    // restore whatever was set before — a run must neither leak its pin
    // into later runs nor erase a CLI/process-level `--threads` setting.
    struct ThreadsRestore(usize);
    impl Drop for ThreadsRestore {
        fn drop(&mut self) {
            crate::backend::kernels::set_threads(self.0);
        }
    }
    let _threads_guard = (cfg.hp.threads > 0).then(|| {
        let prev = crate::backend::kernels::threads_override();
        crate::backend::kernels::set_threads(cfg.hp.threads);
        ThreadsRestore(prev)
    });
    let model = rt.model(&cfg.model)?.clone();
    let mut state = resume.unwrap_or_else(|| init_state(&model, cfg.hp.seed));
    let start_step = state.step;

    let mut corpus = BatchIter::new(
        CorpusCfg {
            seed: cfg.hp.seed.wrapping_add(start_step as u64),
            ..CorpusCfg::train_default(model.vocab)
        },
        model.batch,
        model.seq,
    );
    let mut metrics = MetricsWriter::open(cfg)?;
    let mut probe = ProbeWriter::open(cfg)?;

    let mut losses = Vec::with_capacity(cfg.hp.steps);
    let mut gnorms = Vec::with_capacity(cfg.hp.steps);
    let mut val = Vec::new();
    let mut spike_steps = Vec::new();
    let mut ema = Ema::new(0.05);
    let mut diverged_at: Option<usize> = None;
    let mut min_loss = f64::INFINITY;

    let t0 = Instant::now();
    let mut steps_done = 0usize;

    for i in 0..cfg.hp.steps {
        let step = start_step + i + 1; // 1-based Adam counter
        let batch = corpus.next_batch();
        let lr = cosine_lr(&cfg.hp, i) as f32;

        let out = rt.train_step(
            &model,
            &cfg.quant,
            &mut state,
            &batch.x,
            &batch.y,
            lr,
            step as f32,
        )?;
        state.step = step;
        let loss = out.loss;
        let gnorm = out.gnorm;
        steps_done = i + 1;

        losses.push(loss);
        gnorms.push(gnorm);
        min_loss = min_loss.min(if loss.is_finite() {
            loss
        } else {
            f64::INFINITY
        });

        // spike + divergence detection
        let ema_v = ema.update(if loss.is_finite() { loss } else { 1e9 });
        if loss.is_finite() && i > 5 && loss > ema_v + 1.0 {
            spike_steps.push(step);
        }
        if diverged_at.is_none() && (!loss.is_finite() || (i > 10 && loss > min_loss + 3.0)) {
            diverged_at = Some(step);
            log::warn!("{}: diverged at step {step} (loss {loss})", cfg.quant.label());
        }

        if step % cfg.hp.log_every == 0 || i + 1 == cfg.hp.steps {
            metrics.log(step, loss, gnorm, cosine_lr(&cfg.hp, i), None)?;
        }

        // periodic validation
        if cfg.hp.eval_every > 0 && (step % cfg.hp.eval_every == 0 || i + 1 == cfg.hp.steps)
        {
            let vl = validation_loss(rt, cfg, &model, &state.params)?;
            val.push((step, vl));
            metrics.log(step, loss, gnorm, cosine_lr(&cfg.hp, i), Some(vl))?;
        }

        // activation-outlier probes (Fig. 6): channel abs-max over training
        if cfg.hp.probe_every > 0 && step % cfg.hp.probe_every == 0 {
            probe.record(rt, &model, step, &state.params)?;
        }

        if cfg.stop_on_divergence && diverged_at.is_some() {
            break;
        }
    }
    let steps_per_sec = steps_done as f64 / t0.elapsed().as_secs_f64();

    if cfg.save_ckpt {
        if let Some(dir) = &cfg.out_dir {
            save_checkpoint(&dir.join("final.ckpt"), &model, &state)?;
        }
    }

    Ok(TrainResult {
        label: cfg.quant.label(),
        losses,
        gnorms,
        val,
        diverged: diverged_at.is_some(),
        diverged_at,
        spike_steps,
        steps_per_sec,
        final_state: state,
    })
}

/// Mean validation NLL over `eval_batches` batches of the held-out
/// (seed-77_777) stream — one scoring implementation shared with the eval
/// harness so validation and eval losses can never drift apart.
pub fn validation_loss(
    rt: &Runtime,
    cfg: &TrainCfg,
    model: &crate::runtime::ModelInfo,
    params: &[Vec<f32>],
) -> Result<f64> {
    crate::eval::corpus_nll(
        rt,
        &cfg.eval_recipe(),
        model,
        params,
        &CorpusCfg {
            seed: 77_777, // held-out validation stream
            ..CorpusCfg::train_default(model.vocab)
        },
        cfg.hp.eval_batches.max(1),
    )
}

// ---------------------------------------------------------------------------
// metric + probe writers
// ---------------------------------------------------------------------------

pub(crate) struct MetricsWriter {
    file: Option<std::fs::File>,
}

impl MetricsWriter {
    pub(crate) fn open(cfg: &TrainCfg) -> Result<MetricsWriter> {
        let file = match &cfg.out_dir {
            None => None,
            Some(dir) => {
                std::fs::create_dir_all(dir)?;
                Some(std::fs::File::create(dir.join("metrics.jsonl"))?)
            }
        };
        Ok(MetricsWriter { file })
    }

    pub(crate) fn log(
        &mut self,
        step: usize,
        loss: f64,
        gnorm: f64,
        lr: f64,
        val: Option<f64>,
    ) -> Result<()> {
        if let Some(f) = &mut self.file {
            let val_part = match val {
                Some(v) => format!(",\"val_loss\":{v}"),
                None => String::new(),
            };
            writeln!(
                f,
                "{{\"step\":{step},\"loss\":{loss},\"gnorm\":{gnorm},\"lr\":{lr}{val_part}}}"
            )?;
        }
        Ok(())
    }
}

/// Writes per-channel activation abs-max rows over training (Fig. 6 data).
pub(crate) struct ProbeWriter {
    file: Option<std::fs::File>,
}

impl ProbeWriter {
    pub(crate) fn open(cfg: &TrainCfg) -> Result<ProbeWriter> {
        let file = match (&cfg.out_dir, cfg.hp.probe_every > 0) {
            (Some(dir), true) => {
                std::fs::create_dir_all(dir)?;
                Some(std::fs::File::create(dir.join("act_outliers.csv"))?)
            }
            _ => None,
        };
        Ok(ProbeWriter { file })
    }

    pub(crate) fn record(
        &mut self,
        rt: &Runtime,
        model: &crate::runtime::ModelInfo,
        step: usize,
        params: &[Vec<f32>],
    ) -> Result<()> {
        let Some(f) = &mut self.file else {
            return Ok(());
        };
        let mut it = BatchIter::new(
            CorpusCfg {
                seed: 55_555,
                ..CorpusCfg::train_default(model.vocab)
            },
            model.batch,
            model.seq,
        );
        let b = it.next_batch();
        let probe = rt.act_probe(model, params, &b.x)?;
        let maxes = channel_abs_max(&probe.proj_in, model.batch * model.seq, model.d_model);
        let row: Vec<String> = maxes.iter().map(|m| format!("{m:.5}")).collect();
        writeln!(f, "{},{}", step, row.join(","))?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn eval_recipe_is_forward_only() {
        let cfg = |r: &str| {
            TrainCfg::new("t4", QuantRecipe::parse(r).unwrap(), TrainHp::default())
        };
        let base = QuantRecipe::none();
        assert_eq!(cfg("base").eval_recipe(), base);
        assert_eq!(
            cfg("w_pc_pallas").eval_recipe(),
            QuantRecipe::parse("w_pc").unwrap()
        );
        assert_eq!(cfg("wag").eval_recipe(), QuantRecipe::parse("wa").unwrap());
        assert_eq!(
            cfg("w8a8g8").eval_recipe(),
            QuantRecipe::parse("w8a8").unwrap()
        );
        // grads / optimizer state: forward pass unquantized
        assert_eq!(cfg("g_ptok").eval_recipe(), base);
        assert_eq!(cfg("m2_pt").eval_recipe(), base);
        // the full combined recipe evals under its W/A components only
        assert_eq!(
            cfg("w4_pc+a8_ptok+g8_ptok+m1_8_pt+m2_8_pc").eval_recipe(),
            QuantRecipe::parse("w4_pc+a8_ptok").unwrap()
        );
    }
}
