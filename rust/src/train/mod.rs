//! L3 training loop: drives an AOT-compiled train-step artifact.
//!
//! The loop owns the (params, m, v) state as PJRT literals — each step feeds
//! the previous step's output literals straight back in, so the only
//! per-step host work is the token batch, the LR scalar, and the loss/gnorm
//! download. Divergence (the paper's non-convergence cases) is detected and
//! recorded rather than treated as an error: several of the paper's
//! configurations are *expected* to blow up, and the experiment reports need
//! the step at which they did.

use std::io::Write;
use std::path::PathBuf;
use std::time::Instant;

use anyhow::{Context, Result};

use crate::config::{cosine_lr, QuantRunCfg, TrainHp};
use crate::data::{BatchIter, CorpusCfg};
use crate::model::{init_state, save_checkpoint, HostState};
use crate::runtime::{lit_i32, lit_scalar, scalar_f32, Runtime};
use crate::util::stats::{channel_abs_max, Ema};

/// Map a train structure to the eval artifact that scores its checkpoints
/// (forward-pass quantization must match what training used; gradient and
/// optimizer-state quantization do not appear in the forward pass).
pub fn eval_structure_for(train_structure: &str) -> &'static str {
    match train_structure {
        "w_pt" => "w_pt",
        "w_pc" | "w_pc_pallas" => "w_pc",
        "a_pt" => "a_pt",
        "a_ptok" => "a_ptok",
        "a_ptok_asym" => "a_ptok_asym",
        "a_pc" => "a_pc",
        "wa" | "wag" => "wa",
        _ => "base",
    }
}

#[derive(Debug, Clone)]
pub struct TrainCfg {
    pub model: String,
    pub quant: QuantRunCfg,
    pub hp: TrainHp,
    pub out_dir: Option<PathBuf>,
    pub save_ckpt: bool,
    /// Stop early once divergence is detected (saves sweep time; the paper's
    /// diverged curves are reported as diverged either way).
    pub stop_on_divergence: bool,
}

impl TrainCfg {
    pub fn new(model: &str, quant: QuantRunCfg, hp: TrainHp) -> TrainCfg {
        TrainCfg {
            model: model.to_string(),
            quant,
            hp,
            out_dir: None,
            save_ckpt: false,
            stop_on_divergence: true,
        }
    }

    pub fn train_artifact(&self) -> String {
        format!("{}/train/{}", self.model, self.quant.structure)
    }

    pub fn eval_artifact(&self) -> String {
        format!(
            "{}/eval/{}",
            self.model,
            eval_structure_for(&self.quant.structure)
        )
    }
}

#[derive(Debug, Clone)]
pub struct TrainResult {
    pub label: String,
    pub losses: Vec<f64>,
    pub gnorms: Vec<f64>,
    pub val: Vec<(usize, f64)>,
    pub diverged: bool,
    pub diverged_at: Option<usize>,
    pub spike_steps: Vec<usize>,
    pub steps_per_sec: f64,
    pub final_state: HostState,
}

impl TrainResult {
    pub fn final_loss(&self) -> f64 {
        *self.losses.last().unwrap_or(&f64::NAN)
    }

    pub fn final_val_loss(&self) -> f64 {
        self.val.last().map(|(_, l)| *l).unwrap_or(f64::NAN)
    }

    pub fn min_val_loss(&self) -> f64 {
        self.val
            .iter()
            .map(|(_, l)| *l)
            .fold(f64::INFINITY, f64::min)
    }
}

/// Train a model per `cfg`, starting from `seed` init (or `resume`).
pub fn train(rt: &Runtime, cfg: &TrainCfg) -> Result<TrainResult> {
    train_from(rt, cfg, None)
}

pub fn train_from(
    rt: &Runtime,
    cfg: &TrainCfg,
    resume: Option<HostState>,
) -> Result<TrainResult> {
    let model = rt.manifest.model(&cfg.model)?.clone();
    let exe = rt
        .exec(&cfg.train_artifact())
        .with_context(|| format!("loading train artifact {}", cfg.train_artifact()))?;
    let np = model.params.len();

    let host = resume.unwrap_or_else(|| init_state(&model, cfg.hp.seed));
    let start_step = host.step;
    let mut state = host.to_literals(&model)?;

    let mut corpus = BatchIter::new(
        CorpusCfg {
            seed: cfg.hp.seed.wrapping_add(start_step as u64),
            ..CorpusCfg::train_default(model.vocab)
        },
        model.batch,
        model.seq,
    );
    let qmaxes = cfg.quant.bits.qmax_scalars();
    let qlits: Vec<xla::Literal> = qmaxes.iter().map(|&q| lit_scalar(q)).collect();

    let mut metrics = MetricsWriter::open(cfg)?;
    let mut probe = ProbeWriter::open(cfg)?;

    let mut losses = Vec::with_capacity(cfg.hp.steps);
    let mut gnorms = Vec::with_capacity(cfg.hp.steps);
    let mut val = Vec::new();
    let mut spike_steps = Vec::new();
    let mut ema = Ema::new(0.05);
    let mut diverged_at: Option<usize> = None;
    let mut min_loss = f64::INFINITY;

    let t0 = Instant::now();
    let mut steps_done = 0usize;

    for i in 0..cfg.hp.steps {
        let step = start_step + i + 1; // 1-based Adam counter
        let batch = corpus.next_batch();
        let x = lit_i32(&batch.x, &[batch.batch, batch.seq])?;
        let y = lit_i32(&batch.y, &[batch.batch, batch.seq])?;
        let lr = lit_scalar(cosine_lr(&cfg.hp, i) as f32);
        let t = lit_scalar(step as f32);

        let mut inputs: Vec<&xla::Literal> = state.iter().collect();
        inputs.push(&x);
        inputs.push(&y);
        inputs.push(&lr);
        inputs.push(&t);
        for q in &qlits {
            inputs.push(q);
        }

        let mut out = exe.run(&inputs)?;
        let loss = scalar_f32(&out[3 * np])? as f64;
        let gnorm = scalar_f32(&out[3 * np + 1])? as f64;
        out.truncate(3 * np);
        state = out;
        steps_done = i + 1;

        losses.push(loss);
        gnorms.push(gnorm);
        min_loss = min_loss.min(if loss.is_finite() { loss } else { f64::INFINITY });

        // spike + divergence detection
        let ema_v = ema.update(if loss.is_finite() { loss } else { 1e9 });
        if loss.is_finite() && i > 5 && loss > ema_v + 1.0 {
            spike_steps.push(step);
        }
        if diverged_at.is_none() && (!loss.is_finite() || (i > 10 && loss > min_loss + 3.0)) {
            diverged_at = Some(step);
            log::warn!("{}: diverged at step {step} (loss {loss})", cfg.quant.label());
        }

        if step % cfg.hp.log_every == 0 || i + 1 == cfg.hp.steps {
            metrics.log(step, loss, gnorm, cosine_lr(&cfg.hp, i), None)?;
        }

        // periodic validation
        if cfg.hp.eval_every > 0 && (step % cfg.hp.eval_every == 0 || i + 1 == cfg.hp.steps)
        {
            let vl = validation_loss(rt, cfg, &model, &state[..np])?;
            val.push((step, vl));
            metrics.log(step, loss, gnorm, cosine_lr(&cfg.hp, i), Some(vl))?;
        }

        // activation-outlier probes (Fig. 6): channel abs-max over training
        if cfg.hp.probe_every > 0 && step % cfg.hp.probe_every == 0 {
            probe.record(rt, &model, step, &state[..np])?;
        }

        if cfg.stop_on_divergence && diverged_at.is_some() {
            break;
        }
    }
    let steps_per_sec = steps_done as f64 / t0.elapsed().as_secs_f64();

    let final_state = HostState::from_literals(&model, &state, start_step + steps_done)?;
    if cfg.save_ckpt {
        if let Some(dir) = &cfg.out_dir {
            save_checkpoint(&dir.join("final.ckpt"), &model, &final_state)?;
        }
    }

    Ok(TrainResult {
        label: cfg.quant.label(),
        losses,
        gnorms,
        val,
        diverged: diverged_at.is_some(),
        diverged_at,
        spike_steps,
        steps_per_sec,
        final_state,
    })
}

/// Mean validation NLL over `eval_batches` held-out batches.
pub fn validation_loss(
    rt: &Runtime,
    cfg: &TrainCfg,
    model: &crate::runtime::ModelInfo,
    params: &[xla::Literal],
) -> Result<f64> {
    // fall back to the unquantized eval graph when the model ships no
    // matching quantized-forward eval artifact (e.g. gpt2s only lowers base)
    let eval_name = if rt.manifest.artifacts.contains_key(&cfg.eval_artifact()) {
        cfg.eval_artifact()
    } else {
        format!("{}/eval/base", cfg.model)
    };
    let exe = rt.exec(&eval_name)?;
    let mut it = BatchIter::new(
        CorpusCfg {
            seed: 77_777, // held-out validation stream
            ..CorpusCfg::train_default(model.vocab)
        },
        model.batch,
        model.seq,
    );
    let mask_data = vec![1.0f32; model.batch * model.seq];
    let mask = crate::runtime::lit_f32(&mask_data, &[model.batch, model.seq])?;
    let qw = lit_scalar(cfg.quant.bits.qmax_scalars()[0]);
    let qa = lit_scalar(cfg.quant.bits.qmax_scalars()[1]);
    let mut total = 0.0;
    for _ in 0..cfg.hp.eval_batches.max(1) {
        let b = it.next_batch();
        let x = lit_i32(&b.x, &[b.batch, b.seq])?;
        let y = lit_i32(&b.y, &[b.batch, b.seq])?;
        let mut inputs: Vec<&xla::Literal> = params.iter().collect();
        inputs.extend([&x, &y, &mask, &qw, &qa]);
        let out = exe.run(&inputs)?;
        total += scalar_f32(&out[0])? as f64;
    }
    Ok(total / cfg.hp.eval_batches.max(1) as f64)
}

// ---------------------------------------------------------------------------
// metric + probe writers
// ---------------------------------------------------------------------------

struct MetricsWriter {
    file: Option<std::fs::File>,
}

impl MetricsWriter {
    fn open(cfg: &TrainCfg) -> Result<MetricsWriter> {
        let file = match &cfg.out_dir {
            None => None,
            Some(dir) => {
                std::fs::create_dir_all(dir)?;
                Some(std::fs::File::create(dir.join("metrics.jsonl"))?)
            }
        };
        Ok(MetricsWriter { file })
    }

    fn log(
        &mut self,
        step: usize,
        loss: f64,
        gnorm: f64,
        lr: f64,
        val: Option<f64>,
    ) -> Result<()> {
        if let Some(f) = &mut self.file {
            let val_part = match val {
                Some(v) => format!(",\"val_loss\":{v}"),
                None => String::new(),
            };
            writeln!(
                f,
                "{{\"step\":{step},\"loss\":{loss},\"gnorm\":{gnorm},\"lr\":{lr}{val_part}}}"
            )?;
        }
        Ok(())
    }
}

/// Writes per-channel activation abs-max rows over training (Fig. 6 data).
struct ProbeWriter {
    file: Option<std::fs::File>,
}

impl ProbeWriter {
    fn open(cfg: &TrainCfg) -> Result<ProbeWriter> {
        let file = match (&cfg.out_dir, cfg.hp.probe_every > 0) {
            (Some(dir), true) => {
                std::fs::create_dir_all(dir)?;
                Some(std::fs::File::create(dir.join("act_outliers.csv"))?)
            }
            _ => None,
        };
        Ok(ProbeWriter { file })
    }

    fn record(
        &mut self,
        rt: &Runtime,
        model: &crate::runtime::ModelInfo,
        step: usize,
        params: &[xla::Literal],
    ) -> Result<()> {
        let Some(f) = &mut self.file else {
            return Ok(());
        };
        let probe = rt.exec(&format!("{}/probe/act", model.name))?;
        let mut it = BatchIter::new(
            CorpusCfg {
                seed: 55_555,
                ..CorpusCfg::train_default(model.vocab)
            },
            model.batch,
            model.seq,
        );
        let b = it.next_batch();
        let x = lit_i32(&b.x, &[b.batch, b.seq])?;
        let one = lit_scalar(1.0);
        let mut inputs: Vec<&xla::Literal> = params.iter().collect();
        inputs.extend([&x, &one, &one]);
        let out = probe.run(&inputs)?;
        let proj_in = crate::runtime::to_f32(&out[0])?;
        let maxes = channel_abs_max(&proj_in, model.batch * model.seq, model.d_model);
        let row: Vec<String> = maxes.iter().map(|m| format!("{m:.5}")).collect();
        writeln!(f, "{},{}", step, row.join(","))?;
        Ok(())
    }
}
