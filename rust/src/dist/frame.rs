//! Gradient-frame wire codec: the length-prefixed binary format one rank
//! publishes per step and every other rank reads back.
//!
//! Layout (all integers little-endian):
//!
//! ```text
//! magic "QDGF" | version u16 | payload_len u64 | payload | fnv1a64(payload) u64
//!
//! payload := step u64 | rank u32 | dp u32 | leaves u32
//!            | part u32 | parts u32 | node_count u32 | node*
//! node    := level u8 | idx u32 | loss f64-bits u64 | tensor_count u16 | tensor*
//! tensor  := kind u8 (0 = f32, 1 = i8)
//!            f32: len u64 | len * f32-le
//!            i8:  view_count u32 | view*
//! view    := rows u32 | cols u32 | scale_count u32 | scale_count * f32-le
//!            | rows*cols i8 codes (tight, no lane padding)
//! ```
//!
//! The codec is **canonical**: `encode(decode(bytes)) == bytes` for every
//! accepted input, and decode rejects anything else — wrong magic, short
//! or long buffers, a payload length that disagrees with the buffer, an
//! FNV-64 mismatch, counts that overflow or overrun the payload, or
//! trailing bytes after a node list. Floats travel as raw bit patterns
//! (`to_bits`/`from_bits`), so NaN payloads and signed zeros survive the
//! wire bit-for-bit — the dequantized gradients a receiver reconstructs
//! are byte-identical to the sender's, which is what the N-way == 1-way
//! proof rests on.
//!
//! `decode` is a fuzz surface (`tests/fuzz.rs` mutates frames for 10k
//! rounds): every read is bounds-checked through [`Cursor`], and every
//! allocation is capped by the number of bytes actually present, so a
//! corrupt count cannot allocate unbounded memory or index out of range.

use anyhow::{bail, Result};

use crate::util::fnv1a64;

pub const MAGIC: &[u8; 4] = b"QDGF";
/// v2 added the multi-part step framing (`part`/`parts` after `leaves`):
/// overlap mode ships a rank's cover as several small frames per step
/// instead of one, and the collector reassembles them in part order.
pub const VERSION: u16 = 2;
/// Hard cap on the declared payload length (256 MiB). A frame for the
/// study models is a few MiB at most; anything bigger is a corrupt or
/// hostile length prefix. The cap is checked *before* any allocation is
/// sized from the prefix — critical for the socket transport, whose
/// reader allocates the receive buffer from the declared length before it
/// has the bytes, so an unchecked prefix would be an OOM lever for any
/// TCP peer.
pub const MAX_PAYLOAD: u64 = 256 << 20;

/// One tensor's gradient payload: raw f32 values, or int8 codes + scales
/// per view (a view is one layer slice of a stacked tensor, or the whole
/// matrix of a plain 2-D tensor).
#[derive(Debug, Clone, PartialEq)]
pub enum WireTensor {
    F32(Vec<f32>),
    I8(Vec<WireView>),
}

/// One quantized 2-D view: tight row-major codes plus the per-tensor
/// (1) or per-row (`rows`) scales that dequantize them.
#[derive(Debug, Clone, PartialEq)]
pub struct WireView {
    pub rows: u32,
    pub cols: u32,
    pub scales: Vec<f32>,
    pub codes: Vec<i8>,
}

/// One reduction-tree node: which subtree it is, the f64 loss sum over
/// the leaves it covers, and the 16 per-parameter gradient tensors.
#[derive(Debug, Clone, PartialEq)]
pub struct WireNode {
    pub level: u8,
    pub idx: u32,
    pub loss: f64,
    pub tensors: Vec<WireTensor>,
}

/// A rank's per-step shipment: its cover of the reduction tree, or — in
/// overlap mode — one slice of it. `part`/`parts` frame the slice: a
/// barrier-mode step is a single `part 0 of 1` frame holding the whole
/// cover; an overlap-mode step ships `parts` frames (one per cover node,
/// in cover order), and the collector reassembles them by part index into
/// the same node sequence.
#[derive(Debug, Clone, PartialEq)]
pub struct Frame {
    pub step: u64,
    pub rank: u32,
    pub dp: u32,
    pub leaves: u32,
    pub part: u32,
    pub parts: u32,
    pub nodes: Vec<WireNode>,
}

// ---------------------------------------------------------------------------
// encode
// ---------------------------------------------------------------------------

fn put_u16(buf: &mut Vec<u8>, v: u16) {
    buf.extend_from_slice(&v.to_le_bytes());
}
fn put_u32(buf: &mut Vec<u8>, v: u32) {
    buf.extend_from_slice(&v.to_le_bytes());
}
fn put_u64(buf: &mut Vec<u8>, v: u64) {
    buf.extend_from_slice(&v.to_le_bytes());
}
fn put_f32s(buf: &mut Vec<u8>, vs: &[f32]) {
    for v in vs {
        buf.extend_from_slice(&v.to_bits().to_le_bytes());
    }
}

pub fn encode(f: &Frame) -> Vec<u8> {
    let mut payload = Vec::new();
    put_u64(&mut payload, f.step);
    put_u32(&mut payload, f.rank);
    put_u32(&mut payload, f.dp);
    put_u32(&mut payload, f.leaves);
    put_u32(&mut payload, f.part);
    put_u32(&mut payload, f.parts);
    put_u32(&mut payload, f.nodes.len() as u32);
    for n in &f.nodes {
        payload.push(n.level);
        put_u32(&mut payload, n.idx);
        put_u64(&mut payload, n.loss.to_bits());
        put_u16(&mut payload, n.tensors.len() as u16);
        for t in &n.tensors {
            match t {
                WireTensor::F32(vs) => {
                    payload.push(0);
                    put_u64(&mut payload, vs.len() as u64);
                    put_f32s(&mut payload, vs);
                }
                WireTensor::I8(views) => {
                    payload.push(1);
                    put_u32(&mut payload, views.len() as u32);
                    for v in views {
                        put_u32(&mut payload, v.rows);
                        put_u32(&mut payload, v.cols);
                        put_u32(&mut payload, v.scales.len() as u32);
                        put_f32s(&mut payload, &v.scales);
                        payload.extend(v.codes.iter().map(|&c| c as u8));
                    }
                }
            }
        }
    }
    let mut out = Vec::with_capacity(payload.len() + 22);
    out.extend_from_slice(MAGIC);
    put_u16(&mut out, VERSION);
    put_u64(&mut out, payload.len() as u64);
    let digest = fnv1a64(&payload);
    out.extend_from_slice(&payload);
    put_u64(&mut out, digest);
    out
}

// ---------------------------------------------------------------------------
// decode
// ---------------------------------------------------------------------------

/// Bounds-checked reader over the payload slice. Every `take_*` returns
/// `Err` instead of slicing past the end.
struct Cursor<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Cursor<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if n > self.buf.len() - self.pos {
            bail!("frame truncated: need {n} bytes at offset {}", self.pos);
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }
    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }
    fn u16(&mut self) -> Result<u16> {
        Ok(u16::from_le_bytes(self.take(2)?.try_into().unwrap()))
    }
    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }
    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }
    fn f32s(&mut self, n: usize) -> Result<Vec<f32>> {
        let bytes = self.take(n.checked_mul(4).ok_or_else(|| anyhow::anyhow!("count overflow"))?)?;
        Ok(bytes
            .chunks_exact(4)
            .map(|c| f32::from_bits(u32::from_le_bytes(c.try_into().unwrap())))
            .collect())
    }
    fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }
}

pub fn decode(bytes: &[u8]) -> Result<Frame> {
    if bytes.len() < MAGIC.len() + 2 + 8 + 8 {
        bail!("frame shorter than the fixed header");
    }
    if &bytes[..4] != MAGIC {
        bail!("bad frame magic");
    }
    let version = u16::from_le_bytes(bytes[4..6].try_into().unwrap());
    if version != VERSION {
        bail!("unsupported frame version {version}");
    }
    let payload_len = u64::from_le_bytes(bytes[6..14].try_into().unwrap());
    if payload_len > MAX_PAYLOAD {
        bail!("frame declares a {payload_len}-byte payload (cap {MAX_PAYLOAD}): rejecting");
    }
    let expect = (bytes.len() - 14 - 8) as u64;
    if payload_len != expect {
        bail!("frame length prefix {payload_len} disagrees with buffer ({expect} payload bytes)");
    }
    let payload = &bytes[14..bytes.len() - 8];
    let stored = u64::from_le_bytes(bytes[bytes.len() - 8..].try_into().unwrap());
    let actual = fnv1a64(payload);
    if stored != actual {
        bail!("frame integrity check failed: fnv {actual:016x} != stored {stored:016x}");
    }

    let mut c = Cursor {
        buf: payload,
        pos: 0,
    };
    let step = c.u64()?;
    let rank = c.u32()?;
    let dp = c.u32()?;
    let leaves = c.u32()?;
    let part = c.u32()?;
    let parts = c.u32()?;
    if parts == 0 || part >= parts {
        bail!("frame part {part} of {parts} is out of range");
    }
    let node_count = c.u32()? as usize;
    // each node costs at least 15 bytes; reject counts the payload can't hold
    if node_count > c.remaining() / 15 {
        bail!("frame claims {node_count} nodes in {} bytes", c.remaining());
    }
    let mut nodes = Vec::with_capacity(node_count);
    for _ in 0..node_count {
        let level = c.u8()?;
        let idx = c.u32()?;
        let loss = f64::from_bits(c.u64()?);
        let tensor_count = c.u16()? as usize;
        if tensor_count > c.remaining() {
            bail!("frame claims {tensor_count} tensors in {} bytes", c.remaining());
        }
        let mut tensors = Vec::with_capacity(tensor_count);
        for _ in 0..tensor_count {
            match c.u8()? {
                0 => {
                    let n = c.u64()?;
                    let n = usize::try_from(n)
                        .map_err(|_| anyhow::anyhow!("f32 tensor length {n} overflows"))?;
                    tensors.push(WireTensor::F32(c.f32s(n)?));
                }
                1 => {
                    let view_count = c.u32()? as usize;
                    if view_count > c.remaining() / 12 {
                        bail!("frame claims {view_count} views in {} bytes", c.remaining());
                    }
                    let mut views = Vec::with_capacity(view_count);
                    for _ in 0..view_count {
                        let rows = c.u32()?;
                        let cols = c.u32()?;
                        let scale_count = c.u32()? as usize;
                        if scale_count != 1 && scale_count != rows as usize {
                            bail!("view scale count {scale_count} is neither 1 nor rows {rows}");
                        }
                        let scales = c.f32s(scale_count)?;
                        let n = (rows as u64)
                            .checked_mul(cols as u64)
                            .and_then(|n| usize::try_from(n).ok())
                            .filter(|&n| n <= c.remaining())
                            .ok_or_else(|| {
                                anyhow::anyhow!("view {rows}x{cols} exceeds the payload")
                            })?;
                        let codes = c.take(n)?.iter().map(|&b| b as i8).collect();
                        views.push(WireView {
                            rows,
                            cols,
                            scales,
                            codes,
                        });
                    }
                    tensors.push(WireTensor::I8(views));
                }
                k => bail!("unknown tensor kind {k}"),
            }
        }
        nodes.push(WireNode {
            level,
            idx,
            loss,
            tensors,
        });
    }
    if c.remaining() != 0 {
        bail!("{} trailing bytes after the node list", c.remaining());
    }
    Ok(Frame {
        step,
        rank,
        dp,
        leaves,
        part,
        parts,
        nodes,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_frame() -> Frame {
        Frame {
            step: 7,
            rank: 1,
            dp: 2,
            leaves: 4,
            part: 1,
            parts: 3,
            nodes: vec![WireNode {
                level: 1,
                idx: 1,
                loss: 3.25,
                tensors: vec![
                    WireTensor::F32(vec![1.0, -0.5, f32::MIN_POSITIVE, -0.0]),
                    WireTensor::I8(vec![
                        WireView {
                            rows: 2,
                            cols: 3,
                            scales: vec![0.125],
                            codes: vec![1, -2, 3, -4, 5, -6],
                        },
                        WireView {
                            rows: 2,
                            cols: 2,
                            scales: vec![0.5, 0.25],
                            codes: vec![127, -128, 0, 64],
                        },
                    ]),
                    WireTensor::F32(vec![]),
                ],
            }],
        }
    }

    #[test]
    fn roundtrip_is_exact() {
        let f = sample_frame();
        let bytes = encode(&f);
        let back = decode(&bytes).unwrap();
        assert_eq!(back, f);
        assert_eq!(encode(&back), bytes, "codec is canonical");
    }

    #[test]
    fn nan_and_negative_zero_survive_bit_for_bit() {
        let mut f = sample_frame();
        f.nodes[0].loss = f64::from_bits(0x7ff8_dead_beef_0001);
        f.nodes[0].tensors[0] = WireTensor::F32(vec![f32::from_bits(0xffc0_0001), -0.0]);
        let back = decode(&encode(&f)).unwrap();
        let WireTensor::F32(vs) = &back.nodes[0].tensors[0] else {
            panic!("kind changed")
        };
        assert_eq!(vs[0].to_bits(), 0xffc0_0001);
        assert_eq!(vs[1].to_bits(), (-0.0f32).to_bits());
        assert_eq!(back.nodes[0].loss.to_bits(), 0x7ff8_dead_beef_0001);
    }

    #[test]
    fn out_of_range_part_framing_is_rejected() {
        // parts == 0 and part >= parts cannot be expressed by encode, so
        // forge them at the byte level (offsets 14 + 8+4+4+4 = part, +4 =
        // parts) and re-stamp the FNV so only the framing check can fire
        let good = encode(&sample_frame());
        let forge = |part: u32, parts: u32| {
            let mut b = good.clone();
            b[34..38].copy_from_slice(&part.to_le_bytes());
            b[38..42].copy_from_slice(&parts.to_le_bytes());
            let end = b.len() - 8;
            let fnv = crate::util::fnv1a64(&b[14..end]);
            b[end..].copy_from_slice(&fnv.to_le_bytes());
            b
        };
        assert!(decode(&forge(0, 0)).is_err(), "parts == 0 must be rejected");
        assert!(decode(&forge(3, 3)).is_err(), "part >= parts must be rejected");
        assert!(decode(&forge(0, 1)).is_ok(), "forging harness must be sound");
    }

    #[test]
    fn adversarial_length_prefix_is_capped_before_allocation() {
        // a hostile peer declares a huge payload; decode must reject on the
        // cap alone — before sizing anything from the prefix — even when
        // the buffer is tiny and even when the prefix matches the buffer
        let mut b = encode(&sample_frame());
        b[6..14].copy_from_slice(&(300u64 << 20).to_le_bytes());
        let err = decode(&b).unwrap_err().to_string();
        assert!(err.contains("cap"), "want the cap error, got {err:?}");
        assert!(decode(&u64::MAX.to_le_bytes().repeat(4)).is_err());
        // exactly at the cap the prefix check falls through to the
        // buffer-length comparison (no 256 MiB test allocation needed)
        let mut at_cap = encode(&sample_frame());
        at_cap[6..14].copy_from_slice(&MAX_PAYLOAD.to_le_bytes());
        let err = decode(&at_cap).unwrap_err().to_string();
        assert!(err.contains("disagrees"), "cap boundary is inclusive, got {err:?}");
    }

    #[test]
    fn corruption_is_rejected() {
        let bytes = encode(&sample_frame());
        // flip one payload byte: FNV must catch it
        let mut bad = bytes.clone();
        bad[20] ^= 0x40;
        assert!(decode(&bad).is_err());
        // truncate: length prefix must catch it
        assert!(decode(&bytes[..bytes.len() - 3]).is_err());
        // append: length prefix must catch it too
        let mut long = bytes.clone();
        long.push(0);
        assert!(decode(&long).is_err());
        // wrong magic
        let mut wrong = bytes.clone();
        wrong[0] = b'X';
        assert!(decode(&wrong).is_err());
        assert!(decode(&[]).is_err());
    }
}
