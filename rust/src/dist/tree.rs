//! Fixed-shape binary reduction tree over the leaves of a global batch.
//!
//! This is the combine topology of the data-parallel trainer: every rank
//! reduces gradients through the *same* pairwise tree, whose shape is a
//! function of the leaf count alone — never of the worker count, the
//! thread count, or arrival order. That is what makes an N-way run
//! bit-identical to a 1-way run at matched global batch: `dp` only decides
//! *who computes which subtree*, not *which subtrees exist*.
//!
//! Node `(level, idx)` covers the half-open leaf range
//! `[idx * 2^level, min((idx + 1) * 2^level, leaves))`. Level 0 nodes are
//! the leaves themselves; at each level, children `(l-1, 2i)` and
//! `(l-1, 2i+1)` combine into `(l, i)`. When the right child's range is
//! empty (odd counts), the left child **carries** to its parent unchanged —
//! no combine happens, so a carried value is bit-identical at every level
//! it rides through.

/// Root level of the tree over `leaves` leaves: the smallest `l` with
/// `2^l >= leaves` (a single node covering everything). One leaf is its
/// own root.
pub fn root_level(leaves: usize) -> u32 {
    assert!(leaves > 0, "tree over zero leaves");
    let mut l = 0u32;
    while (1usize << l) < leaves {
        l += 1;
    }
    l
}

/// Leaf range covered by node `(level, idx)`, clamped to `leaves`.
/// Empty (`lo == hi`) when the node sits entirely past the last leaf.
pub fn node_range(level: u32, idx: usize, leaves: usize) -> (usize, usize) {
    let span = 1usize << level;
    let lo = (idx * span).min(leaves);
    let hi = ((idx + 1) * span).min(leaves);
    (lo, hi)
}

/// Whether node `(level, idx)` is a carry: its right child's range is
/// empty, so its value is its left child's value, passed through without a
/// combine (and, on the wire, without a re-quantization).
pub fn is_carry(level: u32, idx: usize, leaves: usize) -> bool {
    if level == 0 {
        return false;
    }
    let (lo, hi) = node_range(level - 1, 2 * idx + 1, leaves);
    lo == hi
}

/// The maximal set of tree nodes whose ranges exactly tile `[lo, hi)`:
/// what a rank owning that leaf range ships on the wire. Every returned
/// node's range is fully inside `[lo, hi)`, so the rank can evaluate it
/// from its own leaves; together with the other ranks' covers, the set
/// tiles `[0, leaves)` and every rank completes the identical tree.
///
/// Deterministic: nodes come out in leaf order (depth-first left to
/// right), highest level first within a position.
pub fn cover(lo: usize, hi: usize, leaves: usize) -> Vec<(u32, usize)> {
    assert!(lo <= hi && hi <= leaves, "cover range out of bounds");
    let mut out = Vec::new();
    if lo == hi {
        return out;
    }
    let mut stack = vec![(root_level(leaves), 0usize)];
    while let Some((l, i)) = stack.pop() {
        let (nlo, nhi) = node_range(l, i, leaves);
        if nhi <= lo || nlo >= hi || nlo == nhi {
            continue;
        }
        if lo <= nlo && nhi <= hi {
            out.push((l, i));
            continue;
        }
        debug_assert!(l > 0, "leaf straddles the cover range");
        // push right first so the left child pops first (leaf order)
        stack.push((l - 1, 2 * i + 1));
        stack.push((l - 1, 2 * i));
    }
    out
}

/// [`cover`] plus, per node, the first leaf index at which the node
/// becomes evaluable: a rank that has completed leaf backwards
/// `[lo, ready)` can evaluate (and publish) every cover node whose
/// `ready_at <= ready`. Because the cover tiles `[lo, hi)` in leaf order,
/// `ready_at` values are strictly increasing and the last one is `hi` —
/// the overlap emission loop walks this schedule front to back, shipping
/// each subtree the moment its leaf range completes.
pub fn cover_schedule(lo: usize, hi: usize, leaves: usize) -> Vec<((u32, usize), usize)> {
    cover(lo, hi, leaves)
        .into_iter()
        .map(|(l, i)| ((l, i), node_range(l, i, leaves).1))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn root_level_matches_ceil_log2() {
        for (leaves, want) in [(1, 0), (2, 1), (3, 2), (4, 2), (5, 3), (8, 3), (9, 4)] {
            assert_eq!(root_level(leaves), want, "leaves={leaves}");
        }
    }

    #[test]
    fn node_ranges_clamp_and_tile() {
        // B = 5: level 1 = [0,2) [2,4) [4,5) (carry) ; level 3 root = [0,5)
        assert_eq!(node_range(1, 2, 5), (4, 5));
        assert_eq!(node_range(1, 3, 5), (5, 5)); // empty
        assert_eq!(node_range(3, 0, 5), (0, 5));
        assert!(is_carry(1, 2, 5));
        assert!(!is_carry(1, 0, 5));
        // B = 5 level 2: [0,4) and [4,5); the latter is a carry of a carry
        assert!(is_carry(2, 1, 5));
        assert!(!is_carry(3, 0, 5));
    }

    #[test]
    fn cover_tiles_any_shard_split() {
        for leaves in 1..=17 {
            for dp in 1..=leaves {
                let mut tiled = Vec::new();
                for rank in 0..dp {
                    let lo = rank * leaves / dp;
                    let hi = (rank + 1) * leaves / dp;
                    for (l, i) in cover(lo, hi, leaves) {
                        let (nlo, nhi) = node_range(l, i, leaves);
                        assert!(lo <= nlo && nhi <= hi, "cover node escapes its shard");
                        assert!(nlo < nhi, "empty cover node");
                        tiled.push((nlo, nhi));
                    }
                }
                tiled.sort_unstable();
                let mut pos = 0;
                for (nlo, nhi) in tiled {
                    assert_eq!(nlo, pos, "gap or overlap at leaf {pos} (B={leaves} dp={dp})");
                    pos = nhi;
                }
                assert_eq!(pos, leaves, "cover does not reach the last leaf");
            }
        }
    }

    #[test]
    fn cover_is_maximal() {
        // a shard owning an aligned power-of-two block ships exactly one node
        assert_eq!(cover(0, 2, 4), vec![(1, 0)]);
        assert_eq!(cover(2, 4, 4), vec![(1, 1)]);
        assert_eq!(cover(0, 4, 4), vec![(2, 0)]);
        // B=4 dp=3 shards: [0,1) [1,2) [2,4)
        assert_eq!(cover(0, 1, 4), vec![(0, 0)]);
        assert_eq!(cover(1, 2, 4), vec![(0, 1)]);
        assert_eq!(cover(2, 4, 4), vec![(1, 1)]);
        // unaligned range decomposes into O(log B) nodes
        assert_eq!(cover(1, 5, 8), vec![(0, 1), (1, 1), (0, 4)]);
    }

    #[test]
    fn cover_schedule_ready_points_ascend_and_end_at_hi() {
        for leaves in 1..=17 {
            for dp in 1..=leaves {
                for rank in 0..dp {
                    let lo = rank * leaves / dp;
                    let hi = (rank + 1) * leaves / dp;
                    let sched = cover_schedule(lo, hi, leaves);
                    assert_eq!(
                        sched.iter().map(|&(n, _)| n).collect::<Vec<_>>(),
                        cover(lo, hi, leaves),
                        "schedule must be the cover in emission order"
                    );
                    let mut prev = lo;
                    for &((l, i), ready) in &sched {
                        let (nlo, nhi) = node_range(l, i, leaves);
                        assert_eq!(ready, nhi, "ready point is the node's range end");
                        assert_eq!(nlo, prev, "nodes tile in leaf order");
                        assert!(ready > prev, "ready points strictly ascend");
                        prev = ready;
                    }
                    if lo < hi {
                        assert_eq!(prev, hi, "last node completes the shard");
                    }
                }
            }
        }
    }

    #[test]
    fn cover_schedule_multi_node_shard() {
        // the 8-leaf [1,5) shard emits leaf 1 after leaf 1 completes,
        // subtree (1,1)=[2,4) after leaf 3, and leaf 4 after leaf 4
        assert_eq!(
            cover_schedule(1, 5, 8),
            vec![((0, 1), 2), ((1, 1), 4), ((0, 4), 5)]
        );
    }
}
