//! TCP socket transport: rank 0 listens, workers dial.
//!
//! The wire format is the same encoded QDGF frames as every other
//! transport ([`super::frame`], FNV-64 integrity included — TCP checksums
//! do not replace it), carried as length-prefixed messages over TCP in a
//! hub topology: rank 0 (`--listen`, default `127.0.0.1:0` — loopback,
//! OS-assigned port) accepts one connection per worker (`--connect
//! host:port`), and **relays** every worker frame to the other workers,
//! so a worker needs exactly one address no matter the dp. Same-machine
//! multi-process rides loopback today; the handshake and framing are
//! host-agnostic, so multi-host is "point `--connect` somewhere else"
//! tomorrow.
//!
//! A join opens with a versioned `QDGH` handshake — protocol version, dp,
//! rank, a step-0 **epoch nonce** (a config fingerprint both ends derive
//! independently, [`epoch_nonce`]), and the recipe label. Any mismatch is
//! a loud typed error on both ends (the leader replies with an `ABRT`
//! control frame before closing), never a hang: a stray worker from a
//! different run, a version-skewed binary, or a recipe drift is caught
//! before a single gradient byte moves.
//!
//! After the join, each connection gets a reader thread that feeds
//! decoded-frame bytes into the same [`Stash`]/`merge_parts` collect path
//! as the channel transport. The loudness contract matches the other
//! transports:
//!
//! * aborts broadcast as `ABRT` control frames (first-wins slot locally,
//!   relayed through the hub), so every rank fails with the root cause;
//! * every wait respects `QPRETRAIN_DIST_TIMEOUT_SECS` (0 = frames must
//!   already be queued — fail-fast), via read timeouts on the reader poll
//!   and capped-backoff reconnect while a worker joins;
//! * a peer disconnect maps to the hung-up-peer error: a reader's EOF
//!   with an incomplete step shipment fails `collect` immediately (no
//!   timeout burn), and the leader additionally polls its spawned
//!   children's exit status;
//! * success tears down gracefully: FIN the write half, drain until the
//!   peer FINs back, so no frame in flight ever dies to an RST.
//!
//! Message framing is `kind u8 | len u32 | payload`: kind 0 a QDGF frame,
//! kind 1 an `ABRT` (payload = error text), kind 2 the `QDGH` handshake.
//! The declared length is capped ([`frame::MAX_PAYLOAD`]) *before* the
//! receive buffer is allocated — a hostile or corrupt peer cannot OOM the
//! receiver with a length prefix.

use std::io::{ErrorKind, Read, Write};
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream};
use std::process::Child;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender, TryRecvError};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, ensure, Context, Result};

use super::frame::{self, Frame, MAX_PAYLOAD};
use super::{Stash, Transport, WIRE_WRITTEN};
use crate::runtime::Runtime;
use crate::train::{TrainCfg, TrainResult};
use crate::util::fnv1a64;
use crate::util::net::parse_addr;

pub const HS_MAGIC: &[u8; 4] = b"QDGH";
pub const HS_VERSION: u16 = 1;

/// Message kinds on the stream.
pub const MSG_FRAME: u8 = 0;
pub const MSG_ABORT: u8 = 1;
pub const MSG_HELLO: u8 = 2;

/// Reader-thread poll granularity: how long a blocked read sleeps before
/// rechecking the shutdown flag. Not a protocol timeout — deadlines are
/// enforced by the callers.
const READ_POLL: Duration = Duration::from_millis(100);

// ---------------------------------------------------------------------------
// QDGH handshake codec
// ---------------------------------------------------------------------------

/// The `QDGH` join handshake. Canonical codec
/// (`encode_handshake(decode_handshake(b)) == b` for every accepted
/// input — `tests/fuzz.rs` mutates it for 10k rounds); *validation*
/// against the run's identity happens separately on each end.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Handshake {
    pub version: u16,
    pub dp: u32,
    pub rank: u32,
    /// Step-0 epoch nonce: both ends derive it from their own config
    /// ([`epoch_nonce`]), so equality proves the dialer belongs to this
    /// run — not a stray worker from a crashed or concurrent one.
    pub nonce: u64,
    /// Recipe label, so a recipe drift fails at join, not at frame decode.
    pub recipe: String,
}

/// `magic "QDGH" | version u16 | dp u32 | rank u32 | nonce u64
///  | recipe_len u16 | recipe bytes` (integers little-endian).
pub fn encode_handshake(h: &Handshake) -> Vec<u8> {
    debug_assert!(h.recipe.len() <= u16::MAX as usize);
    let mut b = Vec::with_capacity(24 + h.recipe.len());
    b.extend_from_slice(HS_MAGIC);
    b.extend_from_slice(&h.version.to_le_bytes());
    b.extend_from_slice(&h.dp.to_le_bytes());
    b.extend_from_slice(&h.rank.to_le_bytes());
    b.extend_from_slice(&h.nonce.to_le_bytes());
    b.extend_from_slice(&(h.recipe.len() as u16).to_le_bytes());
    b.extend_from_slice(h.recipe.as_bytes());
    b
}

pub fn decode_handshake(bytes: &[u8]) -> Result<Handshake> {
    if bytes.len() < 24 {
        bail!("handshake truncated: {} bytes, fixed part is 24", bytes.len());
    }
    if &bytes[..4] != HS_MAGIC {
        bail!("bad handshake magic (expected QDGH)");
    }
    let version = u16::from_le_bytes(bytes[4..6].try_into().unwrap());
    if version != HS_VERSION {
        bail!("unsupported handshake version {version} (this build speaks {HS_VERSION})");
    }
    let dp = u32::from_le_bytes(bytes[6..10].try_into().unwrap());
    let rank = u32::from_le_bytes(bytes[10..14].try_into().unwrap());
    let nonce = u64::from_le_bytes(bytes[14..22].try_into().unwrap());
    let recipe_len = u16::from_le_bytes(bytes[22..24].try_into().unwrap()) as usize;
    if bytes.len() != 24 + recipe_len {
        bail!(
            "handshake recipe length {recipe_len} disagrees with buffer ({} bytes after header)",
            bytes.len() - 24
        );
    }
    let recipe = std::str::from_utf8(&bytes[24..])
        .context("handshake recipe is not UTF-8")?
        .to_string();
    Ok(Handshake { version, dp, rank, nonce, recipe })
}

/// The step-0 epoch nonce: an FNV-64 fingerprint of everything that must
/// agree for ranks to be bit-identical replicas of one run. Leader and
/// workers compute it independently from their own configs, so a worker
/// spawned with drifted args — or dialed into the wrong leader — fails
/// the handshake instead of training a subtly different model.
pub fn epoch_nonce(cfg: &TrainCfg) -> u64 {
    fnv1a64(
        format!(
            "{}|{}|{}|{}|{}",
            cfg.model,
            cfg.quant.label(),
            cfg.hp.seed,
            cfg.hp.steps,
            cfg.hp.dp.max(1)
        )
        .as_bytes(),
    )
}

// ---------------------------------------------------------------------------
// message framing over the stream
// ---------------------------------------------------------------------------

/// Why a receive stopped without yielding a message.
enum RecvFail {
    /// Connection-level failure (EOF mid-message, reset, shutdown, a join
    /// deadline): the peer is gone as far as this stream is concerned.
    Closed(String),
    /// The peer spoke, but spoke garbage (oversized length prefix): a
    /// protocol violation worth surfacing verbatim.
    Protocol(String),
}

impl RecvFail {
    fn into_error(self) -> anyhow::Error {
        match self {
            RecvFail::Closed(m) | RecvFail::Protocol(m) => anyhow!("{m}"),
        }
    }
}

/// Fill `buf` exactly, riding out read-timeout wakeups (used to poll
/// `shutdown` / `deadline` between chunks). `Ok(false)` is a clean EOF at
/// offset 0 — the peer FIN'd at a message boundary.
fn read_full(
    s: &mut TcpStream,
    buf: &mut [u8],
    shutdown: &AtomicBool,
    deadline: Option<Instant>,
) -> Result<bool, RecvFail> {
    let mut filled = 0;
    while filled < buf.len() {
        match s.read(&mut buf[filled..]) {
            Ok(0) => {
                if filled == 0 {
                    return Ok(false);
                }
                return Err(RecvFail::Closed(format!(
                    "connection closed mid-message ({filled} of {} bytes)",
                    buf.len()
                )));
            }
            Ok(n) => filled += n,
            Err(e) if e.kind() == ErrorKind::WouldBlock || e.kind() == ErrorKind::TimedOut => {
                if shutdown.load(Ordering::SeqCst) {
                    return Err(RecvFail::Closed("shutting down".to_string()));
                }
                if deadline.is_some_and(|d| Instant::now() >= d) {
                    return Err(RecvFail::Closed("timed out waiting for bytes".to_string()));
                }
            }
            Err(e) if e.kind() == ErrorKind::Interrupted => {}
            Err(e) => return Err(RecvFail::Closed(format!("socket read failed: {e}"))),
        }
    }
    Ok(true)
}

/// Read one `kind u8 | len u32 | payload` message. `Ok(None)` is a clean
/// FIN at a message boundary. The declared length is checked against
/// [`MAX_PAYLOAD`] *before* the payload buffer is allocated.
fn read_msg(
    s: &mut TcpStream,
    shutdown: &AtomicBool,
    deadline: Option<Instant>,
) -> Result<Option<(u8, Vec<u8>)>, RecvFail> {
    let mut hdr = [0u8; 5];
    if !read_full(s, &mut hdr, shutdown, deadline)? {
        return Ok(None);
    }
    let kind = hdr[0];
    let len = u32::from_le_bytes(hdr[1..5].try_into().unwrap()) as u64;
    if len > MAX_PAYLOAD {
        return Err(RecvFail::Protocol(format!(
            "peer declared a {len}-byte message (cap {MAX_PAYLOAD}): rejecting before allocation"
        )));
    }
    let mut payload = vec![0u8; len as usize];
    if !read_full(s, &mut payload, shutdown, deadline)? {
        return Err(RecvFail::Closed("connection closed before the message body".to_string()));
    }
    Ok(Some((kind, payload)))
}

fn write_msg(s: &mut TcpStream, kind: u8, payload: &[u8]) -> std::io::Result<()> {
    debug_assert!(payload.len() as u64 <= MAX_PAYLOAD);
    let mut hdr = [0u8; 5];
    hdr[0] = kind;
    hdr[1..5].copy_from_slice(&(payload.len() as u32).to_le_bytes());
    s.write_all(&hdr)?;
    s.write_all(payload)
}

fn send_msg(w: &Mutex<TcpStream>, kind: u8, payload: &[u8]) -> std::io::Result<()> {
    let mut s = w.lock().unwrap_or_else(|p| p.into_inner());
    write_msg(&mut s, kind, payload)
}

fn set_abort(slot: &Mutex<Option<String>>, msg: &str) {
    let mut s = slot.lock().unwrap_or_else(|p| p.into_inner());
    if s.is_none() {
        *s = Some(msg.to_string());
    }
}

// ---------------------------------------------------------------------------
// the transport
// ---------------------------------------------------------------------------

/// One rank's endpoint of the TCP exchange. Rank 0 holds one connection
/// per worker (and relays between them); a worker holds exactly one, to
/// rank 0. Built with [`listen`] / [`connect`].
pub struct SocketTransport {
    rank: usize,
    timeout: Duration,
    /// First-wins abort slot (the ABORT marker's in-memory twin); fed
    /// locally by [`Transport::abort`] and remotely by `ABRT` frames.
    abort: Arc<Mutex<Option<String>>>,
    /// Tells reader threads to stop riding out read timeouts.
    shutdown: Arc<AtomicBool>,
    /// `(peer rank, write half)`.
    writers: Vec<(usize, Arc<Mutex<TcpStream>>)>,
    /// `(peer rank, reader-saw-EOF flag)` — the hung-up-peer signal.
    eofs: Vec<(usize, Arc<AtomicBool>)>,
    readers: Vec<JoinHandle<()>>,
    rx: Receiver<Vec<u8>>,
    stash: Stash,
    /// Leader-spawn path only: worker children, polled during collect.
    children: Vec<(usize, Child)>,
}

/// Rank 0: accept and validate `dp - 1` worker joins on `listener`, then
/// start the per-connection reader threads. A handshake mismatch — wrong
/// version, dp, rank, nonce, or recipe — fails the run (after telling the
/// dialer why with an `ABRT`); it never hangs, and the deadline bounds
/// even a dialer that connects and says nothing.
pub fn listen(
    listener: TcpListener,
    dp: usize,
    timeout: Duration,
    nonce: u64,
    recipe: &str,
) -> Result<SocketTransport> {
    listen_with(listener, dp, timeout, nonce, recipe, Vec::new())
}

pub(crate) fn listen_with(
    listener: TcpListener,
    dp: usize,
    timeout: Duration,
    nonce: u64,
    recipe: &str,
    mut children: Vec<(usize, Child)>,
) -> Result<SocketTransport> {
    let accepted = accept_all(&listener, dp, timeout, nonce, recipe, &mut children);
    match accepted {
        Ok(conns) => SocketTransport::build(0, dp, timeout, conns, children),
        Err(e) => {
            for (_, child) in &mut children {
                let _ = child.kill();
                let _ = child.wait();
            }
            Err(e)
        }
    }
}

fn accept_all(
    listener: &TcpListener,
    dp: usize,
    timeout: Duration,
    nonce: u64,
    recipe: &str,
    children: &mut [(usize, Child)],
) -> Result<Vec<(usize, TcpStream)>> {
    ensure!(dp >= 2, "socket transport needs dp >= 2, got {dp}");
    let ours = Handshake {
        version: HS_VERSION,
        dp: dp as u32,
        rank: 0,
        nonce,
        recipe: recipe.to_string(),
    };
    listener.set_nonblocking(true).context("making the dist listener pollable")?;
    let deadline = Instant::now() + timeout;
    let mut conns: Vec<(usize, TcpStream)> = Vec::with_capacity(dp - 1);
    while conns.len() < dp - 1 {
        for (rank, child) in children.iter_mut() {
            if let Some(status) = child.try_wait()? {
                if !status.success() {
                    bail!("dist worker rank {rank} exited before joining: {status}");
                }
            }
        }
        match listener.accept() {
            Ok((mut stream, _)) => {
                let hs = handshake_accept(&mut stream, &ours, deadline)?;
                let r = hs.rank as usize;
                ensure!(
                    !conns.iter().any(|(cr, _)| *cr == r),
                    "duplicate join for rank {r}"
                );
                conns.push((r, stream));
            }
            Err(e) if e.kind() == ErrorKind::WouldBlock => {
                if Instant::now() >= deadline {
                    bail!(
                        "rank 0 timed out after {timeout:?} waiting for worker joins \
                         ({} of {} joined)",
                        conns.len(),
                        dp - 1
                    );
                }
                std::thread::sleep(Duration::from_millis(2));
            }
            Err(e) => return Err(e).context("accepting a worker join"),
        }
    }
    Ok(conns)
}

/// Leader side of one join: read the dialer's `QDGH`, validate it against
/// this run, reply with ours (or an `ABRT` naming the mismatch).
fn handshake_accept(
    stream: &mut TcpStream,
    ours: &Handshake,
    deadline: Instant,
) -> Result<Handshake> {
    stream.set_nonblocking(false).context("configuring a joined socket")?;
    stream.set_nodelay(true).ok();
    stream.set_read_timeout(Some(Duration::from_millis(50))).context("setting join timeout")?;
    let never = AtomicBool::new(false);
    let msg = read_msg(stream, &never, Some(deadline))
        .map_err(|e| e.into_error().context("reading a worker handshake"))?;
    let Some((kind, payload)) = msg else {
        bail!("worker closed the connection before its handshake");
    };
    let check = || -> Result<Handshake> {
        ensure!(
            kind == MSG_HELLO,
            "expected a QDGH handshake, got message kind {kind}"
        );
        let hs = decode_handshake(&payload)?;
        ensure!(
            hs.dp == ours.dp,
            "handshake dp mismatch: worker says {}, this run is dp {}",
            hs.dp,
            ours.dp
        );
        ensure!(
            hs.rank >= 1 && hs.rank < ours.dp,
            "handshake rank {} out of range for dp {}",
            hs.rank,
            ours.dp
        );
        ensure!(
            hs.nonce == ours.nonce,
            "handshake epoch nonce mismatch (worker {:#018x}, run {:#018x}) — \
             the dialer belongs to a different run",
            hs.nonce,
            ours.nonce
        );
        ensure!(
            hs.recipe == ours.recipe,
            "handshake recipe mismatch (worker {:?}, run {:?})",
            hs.recipe,
            ours.recipe
        );
        Ok(hs)
    };
    match check() {
        Ok(hs) => {
            write_msg(stream, MSG_HELLO, &encode_handshake(ours))
                .context("replying to a worker handshake")?;
            Ok(hs)
        }
        Err(e) => {
            // tell the dialer why before hanging up — typed error, no hang
            let _ = write_msg(stream, MSG_ABORT, format!("{e:#}").as_bytes());
            let _ = stream.shutdown(Shutdown::Both);
            Err(e.context("rejecting a worker join"))
        }
    }
}

/// Worker rank `rank`: dial the leader with capped-backoff reconnect (the
/// leader may still be binding), handshake, and start the reader thread.
pub fn connect(
    addr: SocketAddr,
    rank: usize,
    dp: usize,
    timeout: Duration,
    nonce: u64,
    recipe: &str,
) -> Result<SocketTransport> {
    ensure!(
        dp >= 2 && rank >= 1 && rank < dp,
        "bad socket worker rank {rank} for dp {dp}"
    );
    let ours = Handshake {
        version: HS_VERSION,
        dp: dp as u32,
        rank: rank as u32,
        nonce,
        recipe: recipe.to_string(),
    };
    let deadline = Instant::now() + timeout;
    let mut backoff = Duration::from_millis(20);
    let mut stream = loop {
        match TcpStream::connect(addr) {
            Ok(s) => break s,
            Err(e) => {
                if Instant::now() >= deadline {
                    bail!("dist rank {rank} could not join {addr} within {timeout:?}: {e}");
                }
                std::thread::sleep(backoff);
                backoff = (backoff * 2).min(Duration::from_millis(500));
            }
        }
    };
    stream.set_nodelay(true).ok();
    stream.set_read_timeout(Some(Duration::from_millis(50))).context("setting join timeout")?;
    write_msg(&mut stream, MSG_HELLO, &encode_handshake(&ours))
        .with_context(|| format!("dist rank {rank}: sending handshake"))?;
    let never = AtomicBool::new(false);
    let reply = read_msg(&mut stream, &never, Some(deadline))
        .map_err(|e| e.into_error().context(format!("dist rank {rank}: handshake reply")))?;
    match reply {
        Some((MSG_HELLO, payload)) => {
            let hs = decode_handshake(&payload)?;
            ensure!(
                hs.rank == 0 && hs.dp == ours.dp && hs.nonce == nonce && hs.recipe == recipe,
                "dist rank {rank}: leader handshake mismatch \
                 (rank {} dp {} nonce {:#018x} recipe {:?}; \
                  expected 0/{dp}/{nonce:#018x}/{recipe:?})",
                hs.rank,
                hs.dp,
                hs.nonce,
                hs.recipe
            );
        }
        Some((MSG_ABORT, payload)) => {
            bail!(
                "dist rank {rank} rejected at join: {}",
                String::from_utf8_lossy(&payload)
            );
        }
        Some((kind, _)) => bail!("dist rank {rank}: unexpected message kind {kind} during join"),
        None => bail!("dist rank {rank}: leader closed the connection during the handshake"),
    }
    SocketTransport::build(rank, dp, timeout, vec![(0, stream)], Vec::new())
}

fn spawn_reader(
    mut stream: TcpStream,
    src: usize,
    relay: Vec<(usize, Arc<Mutex<TcpStream>>)>,
    tx: Sender<Vec<u8>>,
    abort: Arc<Mutex<Option<String>>>,
    eof: Arc<AtomicBool>,
    shutdown: Arc<AtomicBool>,
) -> JoinHandle<()> {
    std::thread::spawn(move || {
        loop {
            match read_msg(&mut stream, &shutdown, None) {
                Ok(Some((MSG_FRAME, payload))) => {
                    // hub relay: rank 0 forwards worker frames to the other
                    // workers verbatim (workers spawn with an empty relay
                    // list). A failed forward is not fatal here — the dead
                    // target's own reader flags the hangup.
                    for (_, w) in &relay {
                        let _ = send_msg(w, MSG_FRAME, &payload);
                    }
                    if tx.send(payload).is_err() {
                        break; // transport dropped; nothing left to feed
                    }
                }
                Ok(Some((MSG_ABORT, payload))) => {
                    let msg = String::from_utf8_lossy(&payload).into_owned();
                    for (_, w) in &relay {
                        let _ = send_msg(w, MSG_ABORT, msg.as_bytes());
                    }
                    set_abort(&abort, &msg);
                    // keep draining: the peer may still FIN cleanly
                }
                Ok(Some((kind, _))) => {
                    set_abort(
                        &abort,
                        &format!("dist rank {src} sent an unknown message kind {kind}"),
                    );
                    break;
                }
                Ok(None) => break, // clean FIN at a message boundary
                Err(RecvFail::Closed(_)) => break, // collect classifies via the EOF flag
                Err(RecvFail::Protocol(msg)) => {
                    set_abort(&abort, &format!("dist rank {src}: {msg}"));
                    break;
                }
            }
        }
        eof.store(true, Ordering::SeqCst);
    })
}

impl SocketTransport {
    fn build(
        rank: usize,
        dp: usize,
        timeout: Duration,
        conns: Vec<(usize, TcpStream)>,
        children: Vec<(usize, Child)>,
    ) -> Result<SocketTransport> {
        let abort = Arc::new(Mutex::new(None));
        let shutdown = Arc::new(AtomicBool::new(false));
        let (tx, rx) = channel();
        // writers first, so each reader can relay to all *other* peers
        let mut writers = Vec::with_capacity(conns.len());
        let mut streams = Vec::with_capacity(conns.len());
        for (r, s) in conns {
            // A write timeout bounds publish/relay against a stalled peer;
            // zero-timeout mode leaves writes blocking (0 is rejected by
            // set_write_timeout, and fail-fast is about collect anyway).
            if !timeout.is_zero() {
                s.set_write_timeout(Some(timeout)).context("setting socket write timeout")?;
            }
            let writer = Arc::new(Mutex::new(s.try_clone().context("cloning socket writer")?));
            writers.push((r, writer));
            streams.push((r, s));
        }
        let mut eofs = Vec::with_capacity(streams.len());
        let mut readers = Vec::with_capacity(streams.len());
        for (r, s) in streams {
            s.set_read_timeout(Some(READ_POLL)).context("setting reader poll timeout")?;
            let eof = Arc::new(AtomicBool::new(false));
            let relay: Vec<_> = writers.iter().filter(|(wr, _)| *wr != r).cloned().collect();
            readers.push(spawn_reader(
                s,
                r,
                relay,
                tx.clone(),
                abort.clone(),
                eof.clone(),
                shutdown.clone(),
            ));
            eofs.push((r, eof));
        }
        Ok(SocketTransport {
            rank,
            timeout,
            abort,
            shutdown,
            writers,
            eofs,
            readers,
            rx,
            stash: Stash::new(rank, dp),
            children,
        })
    }

    fn check_abort(&self) -> Result<()> {
        let slot = self.abort.lock().unwrap_or_else(|p| p.into_inner()).clone();
        if let Some(msg) = slot {
            bail!("dist peer aborted: {msg}");
        }
        Ok(())
    }

    fn check_children(&mut self) -> Result<()> {
        let mut failed: Option<String> = None;
        for (rank, child) in &mut self.children {
            if let Some(status) = child.try_wait()? {
                if !status.success() {
                    failed = Some(format!("dist worker rank {rank} exited: {status}"));
                    break;
                }
            }
        }
        if let Some(msg) = failed {
            self.abort(&msg);
            bail!("{msg}");
        }
        Ok(())
    }

    /// Tests only: shrink the collect deadline after the join completed
    /// (join and collect share the construction-time timeout otherwise).
    pub fn set_timeout(&mut self, timeout: Duration) {
        self.timeout = timeout;
    }

    /// Graceful success-path teardown: FIN our write half so each peer's
    /// drain sees EOF, drain our side until the peer FINs back (bounded —
    /// a peer that never FINs can only cost the grace window, not a
    /// hang), then reap children (leader-spawn path).
    pub(crate) fn finish(&mut self) -> Result<()> {
        for (_, w) in &self.writers {
            let s = w.lock().unwrap_or_else(|p| p.into_inner());
            let _ = s.shutdown(Shutdown::Write);
        }
        let grace = Instant::now()
            + self
                .timeout
                .min(Duration::from_secs(10))
                .max(Duration::from_millis(100));
        while self.eofs.iter().any(|(_, e)| !e.load(Ordering::SeqCst)) && Instant::now() < grace
        {
            std::thread::sleep(Duration::from_millis(2));
        }
        self.shutdown.store(true, Ordering::SeqCst);
        for h in self.readers.drain(..) {
            let _ = h.join();
        }
        let mut err: Option<anyhow::Error> = None;
        for (rank, child) in &mut self.children {
            match child.wait() {
                Ok(s) if s.success() => {}
                Ok(s) => err = err.or(Some(anyhow!("dist worker rank {rank} exited: {s}"))),
                Err(e) => err = err.or(Some(e.into())),
            }
        }
        self.children.clear();
        match err {
            Some(e) => Err(e),
            None => Ok(()),
        }
    }

    pub(crate) fn kill_children(&mut self) {
        for (_, child) in &mut self.children {
            let _ = child.kill();
            let _ = child.wait();
        }
        self.children.clear();
    }
}

impl Drop for SocketTransport {
    fn drop(&mut self) {
        self.shutdown.store(true, Ordering::SeqCst);
        for (_, w) in &self.writers {
            let s = w.lock().unwrap_or_else(|p| p.into_inner());
            let _ = s.shutdown(Shutdown::Both);
        }
        for h in self.readers.drain(..) {
            let _ = h.join();
        }
        self.kill_children();
    }
}

impl Transport for SocketTransport {
    /// Send the encoded frame to every connection this rank holds (a
    /// worker's single leader connection is enough — the hub relays). A
    /// failed send maps to the hung-up-peer error, unless a peer abort is
    /// already pending (the root cause wins).
    fn publish(&mut self, frame: &Frame) -> Result<()> {
        self.check_abort()?;
        let bytes = frame::encode(frame);
        WIRE_WRITTEN.fetch_add(bytes.len() as u64, Ordering::Relaxed);
        for (r, w) in &self.writers {
            if let Err(e) = send_msg(w, MSG_FRAME, &bytes) {
                self.check_abort()?;
                let msg = format!(
                    "dist rank {r} hung up (step {} part {} send failed: {e})",
                    frame.step, frame.part
                );
                self.abort(&msg);
                bail!("{msg}");
            }
        }
        Ok(())
    }

    /// Receive until every peer's step-`step` shipment assembles.
    /// Everything already queued is admitted before the deadline is
    /// judged (zero timeout succeeds on queued frames, fails fast
    /// otherwise), and an EOF'd peer with an incomplete shipment fails
    /// immediately — after one extra drain round, closing the race where
    /// the reader's final frames are still in the queue when its EOF flag
    /// flips.
    fn collect(&mut self, step: u64) -> Result<Vec<Frame>> {
        let deadline = Instant::now() + self.timeout;
        let mut suspects: Vec<usize> = Vec::new();
        loop {
            self.check_abort()?;
            loop {
                match self.rx.try_recv() {
                    Ok(bytes) => self.stash.admit(step, &bytes)?,
                    Err(TryRecvError::Empty) | Err(TryRecvError::Disconnected) => break,
                }
            }
            if let Some(frames) = self.stash.try_assemble(step)? {
                return Ok(frames);
            }
            self.check_children()?;
            for (r, eof) in &self.eofs {
                if eof.load(Ordering::SeqCst) && !self.stash.is_complete(step, *r as u32) {
                    if suspects.contains(r) {
                        let msg = format!(
                            "dist rank {r} hung up mid-run (connection closed before its \
                             step {step} shipment completed)"
                        );
                        self.abort(&msg);
                        bail!("{msg}");
                    }
                    suspects.push(*r);
                }
            }
            let now = Instant::now();
            if now >= deadline {
                let msg = format!(
                    "dist rank {} timed out after {:?} collecting step {step}",
                    self.rank, self.timeout
                );
                self.abort(&msg);
                bail!("{msg}");
            }
            match self.rx.recv_timeout((deadline - now).min(Duration::from_millis(5))) {
                Ok(bytes) => self.stash.admit(step, &bytes)?,
                Err(RecvTimeoutError::Timeout) => {}
                Err(RecvTimeoutError::Disconnected) => {
                    // all readers exited; the suspects pass above will
                    // classify the hangup — just avoid a busy spin
                    std::thread::sleep(Duration::from_millis(1));
                }
            }
        }
    }

    /// First-wins locally, broadcast as `ABRT` control frames to every
    /// connection (the hub relays a worker's abort to the other workers).
    fn abort(&self, msg: &str) {
        set_abort(&self.abort, msg);
        for (_, w) in &self.writers {
            let _ = send_msg(w, MSG_ABORT, msg.as_bytes());
        }
    }
}

// ---------------------------------------------------------------------------
// leader entry
// ---------------------------------------------------------------------------

/// Socket leader: bind `--listen` (default `127.0.0.1:0`), spawn `dp - 1`
/// `dist-worker` processes dialing the *actual* bound address, accept
/// their joins, and run rank 0. No out dir is required — the exchange
/// lives on the wire (run artifacts still land in `--out` when given).
pub(crate) fn dist_train_socket(rt: &Runtime, cfg: &TrainCfg, dp: usize) -> Result<TrainResult> {
    let spec = cfg.hp.dist_listen.as_deref().unwrap_or("127.0.0.1:0");
    let addr = parse_addr(spec)?;
    let listener =
        TcpListener::bind(addr).with_context(|| format!("binding dist listener on {addr}"))?;
    let actual = listener.local_addr().context("reading the bound listener address")?;

    let threads = crate::coordinator::worker_threads(cfg, dp);
    let mut leader_cfg = cfg.clone();
    leader_cfg.hp.threads = threads;

    let exe = super::worker_exe()?;
    let mut children = Vec::with_capacity(dp - 1);
    for rank in 1..dp {
        let mut cmd = super::worker_cmd(&exe, cfg, rank, dp, threads);
        cmd.args(["--connect", &actual.to_string()]);
        let child = cmd
            .spawn()
            .with_context(|| format!("spawning dist worker rank {rank}"))?;
        children.push((rank, child));
    }

    let nonce = epoch_nonce(cfg);
    let mut tp = listen_with(
        listener,
        dp,
        super::dist_timeout(),
        nonce,
        &cfg.quant.label(),
        children,
    )?;
    match super::rank_loop(rt, &leader_cfg, dp, 0, Some(&mut tp)) {
        Ok(result) => {
            tp.finish()?;
            Ok(result)
        }
        Err(e) => {
            tp.abort(&format!("{e:#}"));
            tp.kill_children();
            Err(e)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::super::frame::{WireNode, WireTensor};
    use super::*;

    fn frame_with(step: u64, rank: u32, dp: u32, part: u32, parts: u32, idx: u32) -> Frame {
        Frame {
            step,
            rank,
            dp,
            leaves: 4,
            part,
            parts,
            nodes: vec![WireNode {
                level: 0,
                idx,
                loss: 0.5 * (idx as f64 + 1.0),
                tensors: vec![WireTensor::F32(vec![idx as f32, -2.0, 0.125])],
            }],
        }
    }

    fn pair(timeout: Duration) -> (SocketTransport, SocketTransport) {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let join = Duration::from_secs(20).max(timeout);
        let worker =
            std::thread::spawn(move || connect(addr, 1, 2, join, 0xA11CE, "w8a8g8"));
        let mut leader = listen(listener, 2, join, 0xA11CE, "w8a8g8").unwrap();
        leader.set_timeout(timeout);
        let mut worker = worker.join().unwrap().unwrap();
        worker.set_timeout(timeout);
        (leader, worker)
    }

    #[test]
    fn handshake_codec_is_canonical() {
        let h = Handshake {
            version: HS_VERSION,
            dp: 3,
            rank: 2,
            nonce: 0xDEAD_BEEF_0BAD_F00D,
            recipe: "w8a8g8".to_string(),
        };
        let b = encode_handshake(&h);
        let back = decode_handshake(&b).unwrap();
        assert_eq!(back, h);
        assert_eq!(encode_handshake(&back), b);
        // skew/truncate/trail are rejected
        assert!(decode_handshake(&b[..b.len() - 1]).is_err(), "truncated recipe");
        assert!(decode_handshake(&b[..10]).is_err(), "truncated header");
        let mut trailing = b.clone();
        trailing.push(0);
        assert!(decode_handshake(&trailing).is_err(), "trailing byte");
        let mut skew = b.clone();
        skew[4] = 99;
        let err = decode_handshake(&skew).unwrap_err().to_string();
        assert!(err.contains("version"), "got: {err}");
        let mut magic = b;
        magic[0] = b'X';
        assert!(decode_handshake(&magic).is_err(), "bad magic");
    }

    #[test]
    fn frames_cross_the_wire_and_assemble() {
        let (mut leader, mut worker) = pair(Duration::from_secs(10));
        for part in 0..3u32 {
            worker.publish(&frame_with(1, 1, 2, part, 3, part)).unwrap();
        }
        let got = leader.collect(1).unwrap();
        assert_eq!(got.len(), 1);
        assert_eq!((got[0].part, got[0].parts), (0, 1));
        let idxs: Vec<u32> = got[0].nodes.iter().map(|n| n.idx).collect();
        assert_eq!(idxs, vec![0, 1, 2]);
        // and the other direction, one step ahead stashes fine
        leader.publish(&frame_with(1, 0, 2, 0, 1, 7)).unwrap();
        leader.publish(&frame_with(2, 0, 2, 0, 1, 9)).unwrap();
        assert_eq!(worker.collect(1).unwrap()[0].nodes[0].idx, 7);
        assert_eq!(worker.collect(2).unwrap()[0].nodes[0].idx, 9);
    }

    #[test]
    fn abort_broadcasts_as_abrt_and_keeps_root_cause() {
        let (mut leader, worker) = pair(Duration::from_secs(10));
        worker.abort("rank 1 lost its gradients");
        worker.abort("a later, less interesting failure");
        let err = leader.collect(1).unwrap_err().to_string();
        assert!(err.contains("rank 1 lost its gradients"), "got: {err}");
    }

    #[test]
    fn hub_relays_worker_frames_and_aborts_to_other_workers() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let t = Duration::from_secs(20);
        let w1 = std::thread::spawn(move || connect(addr, 1, 3, t, 7, "base"));
        let w2 = std::thread::spawn(move || connect(addr, 2, 3, t, 7, "base"));
        let _leader = listen(listener, 3, t, 7, "base").unwrap();
        let mut w1 = w1.join().unwrap().unwrap();
        let mut w2 = w2.join().unwrap().unwrap();
        // w1's frame reaches w2 through the hub without the leader's loop
        // running at all (the relay lives in the reader threads)
        w1.publish(&frame_with(1, 1, 3, 0, 1, 5)).unwrap();
        w2.set_timeout(Duration::from_secs(5));
        // w2 needs frames from ranks 0 and 1; only 1's arrives, so wait for
        // the stash then check it via a peeked collect timeout
        let t0 = Instant::now();
        let err = w2.collect(1).unwrap_err().to_string();
        assert!(err.contains("timed out"), "got: {err}");
        assert!(t0.elapsed() >= Duration::from_secs(5), "waited the deadline");
        assert!(w2.stash.is_complete(1, 1), "rank 1's relayed frame is stashed");
        // the timeout above broadcast an ABRT through the hub: w1 sees it
        let err = w1.collect(1).unwrap_err().to_string();
        assert!(err.contains("aborted"), "got: {err}");
    }

    #[test]
    fn zero_timeout_fails_fast_but_accepts_queued_frames() {
        let (mut leader, _worker) = pair(Duration::ZERO);
        let t = Instant::now();
        let err = leader.collect(1).unwrap_err().to_string();
        assert!(err.contains("timed out"), "got: {err}");
        assert!(t.elapsed() < Duration::from_millis(200), "zero timeout must fail fast");

        // fresh pair: a frame that already crossed the wire still collects
        // at zero patience
        let (mut leader, mut worker) = pair(Duration::from_secs(10));
        worker.publish(&frame_with(1, 1, 2, 0, 1, 3)).unwrap();
        let deadline = Instant::now() + Duration::from_secs(10);
        loop {
            // wait for the reader thread to surface the bytes, then collect
            // with zero patience
            leader.set_timeout(Duration::ZERO);
            match leader.collect(1) {
                Ok(got) => {
                    assert_eq!(got[0].nodes[0].idx, 3);
                    break;
                }
                Err(_) => {
                    assert!(Instant::now() < deadline, "frame never surfaced");
                    *leader.abort.lock().unwrap() = None; // clear the timeout's abort
                    std::thread::sleep(Duration::from_millis(5));
                }
            }
        }
    }

    #[test]
    fn dropped_peer_is_a_hung_up_error_not_a_timeout() {
        let (mut leader, mut worker) = pair(Duration::from_secs(30));
        worker.publish(&frame_with(1, 1, 2, 0, 1, 4)).unwrap();
        drop(worker); // "mid-step worker kill" at the transport level
        assert_eq!(leader.collect(1).unwrap()[0].nodes[0].idx, 4, "pre-kill frame survives");
        let t = Instant::now();
        let err = leader.collect(2).unwrap_err().to_string();
        assert!(err.contains("hung up"), "got: {err}");
        assert!(
            t.elapsed() < Duration::from_secs(5),
            "EOF detection must not burn the 30s deadline (took {:?})",
            t.elapsed()
        );
    }

    #[test]
    fn oversized_message_length_is_rejected_before_allocation() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let evil = std::thread::spawn(move || {
            let mut s = TcpStream::connect(addr).unwrap();
            let hello = encode_handshake(&Handshake {
                version: HS_VERSION,
                dp: 2,
                rank: 1,
                nonce: 42,
                recipe: "base".to_string(),
            });
            write_msg(&mut s, MSG_HELLO, &hello).unwrap();
            let never = AtomicBool::new(false);
            let (kind, _) = read_msg(&mut s, &never, None).unwrap().unwrap();
            assert_eq!(kind, MSG_HELLO);
            // declare a 2 GiB frame; never send it
            let mut hdr = [0u8; 5];
            hdr[0] = MSG_FRAME;
            hdr[1..5].copy_from_slice(&u32::MAX.to_le_bytes());
            s.write_all(&hdr).unwrap();
            s // keep the socket open so only the cap can fire
        });
        let mut leader = listen(listener, 2, Duration::from_secs(20), 42, "base").unwrap();
        let _s = evil.join().unwrap();
        leader.set_timeout(Duration::from_secs(10));
        let err = leader.collect(1).unwrap_err().to_string();
        assert!(err.contains("cap"), "got: {err}");
    }

    #[test]
    fn corrupt_frame_over_tcp_is_rejected() {
        let (mut leader, worker) = pair(Duration::from_secs(10));
        let mut bytes = frame::encode(&frame_with(1, 1, 2, 0, 1, 0));
        bytes[20] ^= 0x40; // payload flip: FNV must catch it after the trip
        send_msg(&worker.writers[0].1, MSG_FRAME, &bytes).unwrap();
        let err = format!("{:#}", leader.collect(1).unwrap_err());
        assert!(err.contains("integrity"), "got: {err}");
    }

    #[test]
    fn handshake_mismatches_are_rejected_loudly() {
        let cases: Vec<(&str, Box<dyn Fn(&mut Handshake) + Send>, &str)> = vec![
            ("dp", Box::new(|h: &mut Handshake| h.dp = 3), "dp mismatch"),
            ("rank", Box::new(|h: &mut Handshake| h.rank = 0), "out of range"),
            ("nonce", Box::new(|h: &mut Handshake| h.nonce ^= 1), "nonce mismatch"),
            (
                "recipe",
                Box::new(|h: &mut Handshake| h.recipe = "w4a4".to_string()),
                "recipe mismatch",
            ),
        ];
        for (name, skew, want) in cases {
            let listener = TcpListener::bind("127.0.0.1:0").unwrap();
            let addr = listener.local_addr().unwrap();
            let dialer = std::thread::spawn(move || {
                let mut s = TcpStream::connect(addr).unwrap();
                let mut h = Handshake {
                    version: HS_VERSION,
                    dp: 2,
                    rank: 1,
                    nonce: 77,
                    recipe: "w8a8g8".to_string(),
                };
                skew(&mut h);
                write_msg(&mut s, MSG_HELLO, &encode_handshake(&h)).unwrap();
                let never = AtomicBool::new(false);
                read_msg(&mut s, &never, Some(Instant::now() + Duration::from_secs(20)))
            });
            let err = listen(listener, 2, Duration::from_secs(20), 77, "w8a8g8")
                .map(|_| ())
                .unwrap_err();
            let err = format!("{err:#}");
            assert!(err.contains(want), "case {name}: got {err:?}");
            // the dialer was told why before the close — typed, not a hang
            let reply = dialer.join().unwrap();
            match reply {
                Ok(Some((kind, payload))) => {
                    assert_eq!(kind, MSG_ABORT, "case {name}");
                    let text = String::from_utf8_lossy(&payload).into_owned();
                    assert!(text.contains(want), "case {name}: dialer saw {text:?}");
                }
                other => panic!("case {name}: dialer got {:?}", other.map(|o| o.map(|(k, _)| k))),
            }
        }
    }

    #[test]
    fn worker_side_rejects_a_skewed_leader() {
        // a "leader" that answers the handshake with the wrong nonce
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let fake = std::thread::spawn(move || {
            let (mut s, _) = listener.accept().unwrap();
            let never = AtomicBool::new(false);
            let _ = read_msg(&mut s, &never, None).unwrap();
            let reply = Handshake {
                version: HS_VERSION,
                dp: 2,
                rank: 0,
                nonce: 999, // wrong run
                recipe: "w8a8g8".to_string(),
            };
            write_msg(&mut s, MSG_HELLO, &encode_handshake(&reply)).unwrap();
            s
        });
        let err = connect(addr, 1, 2, Duration::from_secs(20), 77, "w8a8g8")
            .map(|_| ())
            .unwrap_err()
            .to_string();
        assert!(err.contains("leader handshake mismatch"), "got: {err}");
        let _ = fake.join();
    }
}
