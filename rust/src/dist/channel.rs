//! In-process channel transport: ranks are threads of one process.
//!
//! The wire format is identical to the filesystem exchange — the same
//! encoded QDGF frames ([`super::frame`]) — but they travel over bounded
//! in-memory MPSC channels instead of `<out>/dist` files, so there is no
//! disk traffic, no poll loop, and no out-dir requirement. Each rank owns
//! one receiver; publishing sends the encoded frame to every peer's
//! channel. The failure semantics mirror the filesystem protocol's
//! ABORT-marker/deadline design:
//!
//! * a shared first-wins **abort slot** replaces the ABORT file — any
//!   rank's error is visible to every peer on its next send/receive;
//! * every blocking wait (a full channel on publish, an empty one on
//!   collect) has the same `QPRETRAIN_DIST_TIMEOUT_SECS` deadline, checked
//!   with `>=` so a zero timeout means "must already be there";
//! * a hung-up peer (dropped receiver, e.g. a panicked thread) fails the
//!   sender loudly instead of blocking forever.
//!
//! Channel capacity is sized so that a healthy run never blocks on
//! publish: peers run at most one step ahead (a step-`s+1` frame can only
//! exist after its sender collected step `s`), and a step ships at most
//! one frame per cover node, so `2 * (dp - 1) * (2 * root_level + 2)`
//! slots bound everything in flight.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::Ordering;
use std::sync::mpsc::{
    sync_channel, Receiver, RecvTimeoutError, SyncSender, TryRecvError, TrySendError,
};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Result};

use super::frame::{self, Frame};
use super::{tree, Stash, Transport, WIRE_WRITTEN};
use crate::runtime::Runtime;
use crate::train::{TrainCfg, TrainResult};

/// One rank's endpoint of the in-process exchange. Build the full set with
/// [`connect`]; each endpoint then moves to its rank's thread.
pub struct ChannelTransport {
    rank: usize,
    dp: usize,
    timeout: Duration,
    /// First-wins abort slot shared by all ranks (the ABORT marker's
    /// in-memory twin).
    abort: Arc<Mutex<Option<String>>>,
    /// Senders into each peer's receiver; `None` at this rank's own index.
    peers: Vec<Option<SyncSender<Vec<u8>>>>,
    rx: Receiver<Vec<u8>>,
    /// Frames received but not yet assembled ([`Stash`], shared with the
    /// socket transport).
    stash: Stash,
}

/// Wire up `dp` fully-connected endpoints. `capacity` bounds each rank's
/// receive queue (see the module docs for sizing).
pub fn connect(dp: usize, capacity: usize, timeout: Duration) -> Vec<ChannelTransport> {
    let abort = Arc::new(Mutex::new(None));
    let mut txs = Vec::with_capacity(dp);
    let mut rxs = Vec::with_capacity(dp);
    for _ in 0..dp {
        let (tx, rx) = sync_channel(capacity.max(1));
        txs.push(tx);
        rxs.push(rx);
    }
    rxs.into_iter()
        .enumerate()
        .map(|(rank, rx)| ChannelTransport {
            rank,
            dp,
            timeout,
            abort: abort.clone(),
            peers: txs
                .iter()
                .enumerate()
                .map(|(r, tx)| (r != rank).then(|| tx.clone()))
                .collect(),
            rx,
            stash: Stash::new(rank, dp),
        })
        .collect()
}

impl ChannelTransport {
    fn check_abort(&self) -> Result<()> {
        if let Some(msg) = self.abort.lock().unwrap().clone() {
            bail!("dist peer aborted: {msg}");
        }
        Ok(())
    }
}

impl Transport for ChannelTransport {
    /// Send the encoded frame to every peer. A full channel backs off
    /// (50µs doubling to 1ms) under the usual deadline; in a healthy run
    /// the capacity bound means this never blocks at all.
    fn publish(&mut self, frame: &Frame) -> Result<()> {
        let bytes = frame::encode(frame);
        WIRE_WRITTEN.fetch_add(bytes.len() as u64, Ordering::Relaxed);
        let deadline = Instant::now() + self.timeout;
        for (r, tx) in self.peers.iter().enumerate() {
            let Some(tx) = tx else { continue };
            let mut msg = bytes.clone();
            let mut backoff = Duration::from_micros(50);
            loop {
                self.check_abort()?;
                match tx.try_send(msg) {
                    Ok(()) => break,
                    Err(TrySendError::Full(m)) => {
                        msg = m;
                        if Instant::now() >= deadline {
                            let e = format!(
                                "dist rank {} timed out after {:?} publishing step {} part {} \
                                 to rank {r}",
                                self.rank, self.timeout, frame.step, frame.part
                            );
                            self.abort(&e);
                            bail!("{e}");
                        }
                        std::thread::sleep(backoff);
                        backoff = (backoff * 2).min(Duration::from_millis(1));
                    }
                    Err(TrySendError::Disconnected(_)) => {
                        self.check_abort()?;
                        bail!("dist rank {r} hung up (its receiver is gone)");
                    }
                }
            }
        }
        Ok(())
    }

    /// Receive until every peer's step-`step` shipment assembles.
    /// Everything already queued is admitted before the deadline is
    /// judged, so — like the filesystem collect — a zero timeout succeeds
    /// when the frames have already arrived.
    fn collect(&mut self, step: u64) -> Result<Vec<Frame>> {
        let deadline = Instant::now() + self.timeout;
        loop {
            self.check_abort()?;
            loop {
                match self.rx.try_recv() {
                    Ok(bytes) => self.stash.admit(step, &bytes)?,
                    Err(TryRecvError::Empty) => break,
                    Err(TryRecvError::Disconnected) => {
                        self.check_abort()?;
                        break;
                    }
                }
            }
            if let Some(frames) = self.stash.try_assemble(step)? {
                return Ok(frames);
            }
            let now = Instant::now();
            if now >= deadline {
                let msg = format!(
                    "dist rank {} timed out after {:?} collecting step {step}",
                    self.rank, self.timeout
                );
                self.abort(&msg);
                bail!("{msg}");
            }
            match self.rx.recv_timeout((deadline - now).min(Duration::from_millis(5))) {
                Ok(bytes) => self.stash.admit(step, &bytes)?,
                Err(RecvTimeoutError::Timeout) => {}
                Err(RecvTimeoutError::Disconnected) => {
                    self.check_abort()?;
                    bail!("dist rank {}: all peers hung up mid-collect", self.rank);
                }
            }
        }
    }

    /// First write wins — an abort caused by another abort must not mask
    /// the root cause.
    fn abort(&self, msg: &str) {
        let mut slot = self.abort.lock().unwrap();
        if slot.is_none() {
            *slot = Some(msg.to_string());
        }
    }
}

fn panic_msg(p: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = p.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = p.downcast_ref::<String>() {
        s.clone()
    } else {
        "unknown panic".to_string()
    }
}

/// Channel leader: run all `dp` ranks as threads of this process (rank 0
/// on the calling thread). The kernel thread budget is split once,
/// process-globally, exactly like the filesystem leader splits it across
/// worker processes; rank configs carry `threads = 0` so the per-rank
/// guard inside [`super::rank_loop`] stays a no-op (the pool knob is
/// process-global and must not be raced from dp threads). Worker panics
/// are caught, turned into aborts, and surfaced as errors — never a hang.
pub(crate) fn dist_train_channel(rt: &Runtime, cfg: &TrainCfg, dp: usize) -> Result<TrainResult> {
    struct ThreadsRestore(usize);
    impl Drop for ThreadsRestore {
        fn drop(&mut self) {
            crate::backend::kernels::set_threads(self.0);
        }
    }
    let threads = crate::coordinator::worker_threads(cfg, dp);
    let prev = crate::backend::kernels::threads_override();
    crate::backend::kernels::set_threads(threads);
    let _threads_guard = ThreadsRestore(prev);

    let model_batch = rt.model(&cfg.model)?.batch;
    let capacity = 2 * (dp - 1) * (2 * tree::root_level(model_batch) as usize + 2);
    let mut transports = connect(dp, capacity, super::dist_timeout());
    let mut leader_tp = transports.remove(0);

    let mut rank_cfg = cfg.clone();
    rank_cfg.hp.threads = 0;
    let rank_cfg = &rank_cfg;

    std::thread::scope(|scope| {
        let mut handles = Vec::with_capacity(dp - 1);
        for (i, mut tp) in transports.into_iter().enumerate() {
            let rank = i + 1;
            handles.push(scope.spawn(move || -> Result<()> {
                // Runtime is not Sync (backends are free-form boxed state),
                // so every rank thread builds its own — they are
                // stateless lookups over the same static model zoo.
                let rt = Runtime::native();
                let out = catch_unwind(AssertUnwindSafe(|| {
                    super::rank_loop(&rt, rank_cfg, dp, rank, Some(&mut tp))
                }));
                match out {
                    Ok(Ok(_)) => Ok(()),
                    Ok(Err(e)) => {
                        tp.abort(&format!("rank {rank}: {e:#}"));
                        Err(e)
                    }
                    Err(p) => {
                        let msg = panic_msg(&*p);
                        tp.abort(&format!("rank {rank} panicked: {msg}"));
                        bail!("dist rank {rank} panicked: {msg}");
                    }
                }
            }));
        }

        let leader = match catch_unwind(AssertUnwindSafe(|| {
            super::rank_loop(rt, rank_cfg, dp, 0, Some(&mut leader_tp))
        })) {
            Ok(r) => r,
            Err(p) => {
                let msg = panic_msg(&*p);
                Err(anyhow!("dist rank 0 panicked: {msg}"))
            }
        };
        if let Err(e) = &leader {
            leader_tp.abort(&format!("{e:#}"));
        }

        let mut worker_err: Option<anyhow::Error> = None;
        for h in handles {
            match h.join() {
                Ok(Ok(())) => {}
                Ok(Err(e)) => worker_err = worker_err.or(Some(e)),
                Err(_) => {
                    worker_err = worker_err.or(Some(anyhow!("dist worker thread died")));
                }
            }
        }
        match (leader, worker_err) {
            (Ok(r), None) => Ok(r),
            (Ok(_), Some(e)) => Err(e),
            (Err(e), _) => Err(e),
        }
    })
}

#[cfg(test)]
mod tests {
    use super::super::frame::{encode, WireNode, WireTensor};
    use super::*;

    fn frame_with(step: u64, rank: u32, dp: u32, part: u32, parts: u32, idx: u32) -> Frame {
        Frame {
            step,
            rank,
            dp,
            leaves: 4,
            part,
            parts,
            nodes: vec![WireNode {
                level: 0,
                idx,
                loss: 1.5 * (idx as f64 + 1.0),
                tensors: vec![WireTensor::F32(vec![idx as f32, -1.0, 0.25])],
            }],
        }
    }

    #[test]
    fn multi_part_shipment_assembles_in_cover_order() {
        let mut tps = connect(2, 8, Duration::from_secs(5));
        let mut t1 = tps.pop().unwrap();
        let mut t0 = tps.pop().unwrap();
        // rank 0 ships step 1 as three parts, deliberately in order (the
        // protocol publishes parts in cover order)
        for part in 0..3u32 {
            t0.publish(&frame_with(1, 0, 2, part, 3, part)).unwrap();
        }
        let got = t1.collect(1).unwrap();
        assert_eq!(got.len(), 1);
        let f = &got[0];
        assert_eq!((f.part, f.parts), (0, 1), "merged frame is part 0 of 1");
        assert_eq!(f.nodes.len(), 3);
        let idxs: Vec<u32> = f.nodes.iter().map(|n| n.idx).collect();
        assert_eq!(idxs, vec![0, 1, 2], "nodes concatenate in part order");
        // byte-identical to the same nodes shipped as one barrier frame
        let mut barrier = frame_with(1, 0, 2, 0, 1, 0);
        barrier.nodes = (0..3).map(|i| frame_with(1, 0, 2, 0, 1, i).nodes.remove(0)).collect();
        assert_eq!(encode(f), encode(&barrier));
    }

    #[test]
    fn next_step_frames_stash_without_disturbing_current() {
        let mut tps = connect(2, 8, Duration::from_secs(5));
        let mut t1 = tps.pop().unwrap();
        let mut t0 = tps.pop().unwrap();
        // rank 0 races ahead: step 1 then step 2 land before rank 1 collects
        t0.publish(&frame_with(1, 0, 2, 0, 1, 7)).unwrap();
        t0.publish(&frame_with(2, 0, 2, 0, 1, 9)).unwrap();
        let s1 = t1.collect(1).unwrap();
        assert_eq!(s1[0].step, 1);
        assert_eq!(s1[0].nodes[0].idx, 7);
        let s2 = t1.collect(2).unwrap();
        assert_eq!(s2[0].step, 2);
        assert_eq!(s2[0].nodes[0].idx, 9);
    }

    #[test]
    fn abort_reaches_peers_and_keeps_root_cause() {
        let mut tps = connect(3, 8, Duration::from_secs(5));
        let t2 = tps.pop().unwrap();
        let mut t1 = tps.pop().unwrap();
        let _t0 = tps.pop().unwrap();
        t2.abort("rank 2 lost its gradients");
        t2.abort("a later, less interesting failure");
        let err = t1.collect(1).unwrap_err().to_string();
        assert!(err.contains("rank 2 lost its gradients"), "got: {err}");
    }

    #[test]
    fn zero_timeout_fails_fast_but_accepts_queued_frames() {
        // regression: the deadline used to be checked with a strict `>`,
        // so a zero timeout silently granted one extra poll round
        let mut tps = connect(2, 8, Duration::ZERO);
        let mut t1 = tps.pop().unwrap();
        let mut t0 = tps.pop().unwrap();
        let t = Instant::now();
        let err = t1.collect(1).unwrap_err().to_string();
        assert!(err.contains("timed out"), "got: {err}");
        assert!(t.elapsed() < Duration::from_millis(200), "zero timeout must fail fast");
        // clear the abort the timeout dropped, then show a frame that is
        // already queued still collects at zero patience
        *t1.abort.lock().unwrap() = None;
        t0.publish(&frame_with(1, 0, 2, 0, 1, 3)).unwrap();
        let got = t1.collect(1).unwrap();
        assert_eq!(got[0].nodes[0].idx, 3);
    }

    #[test]
    fn hung_up_peer_fails_the_sender() {
        let mut tps = connect(2, 8, Duration::from_secs(5));
        let t1 = tps.pop().unwrap();
        let mut t0 = tps.pop().unwrap();
        drop(t1);
        let err = t0.publish(&frame_with(1, 0, 2, 0, 1, 0)).unwrap_err().to_string();
        assert!(err.contains("hung up"), "got: {err}");
    }
}
