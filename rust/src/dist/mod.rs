//! N-process data-parallel trainer, bit-identical to a single process.
//!
//! `dist-train` shards the **global batch** (never the model) across `dp`
//! ranks: rank `k` owns the leaf sequences
//! [`crate::config::shard_range`]`(B, dp, k)`, runs the native backend's
//! backward over each of its leaves ([`crate::runtime::Runtime::grad_step`]
//! on a batch-1 view of the model), and combines gradients through a
//! **fixed-shape pairwise reduction tree** ([`tree`]) whose shape is a
//! function of the global batch `B` alone — never of `dp`. Every rank
//! completes the *same* tree from the exchanged node values and applies
//! the *same* AdamW update ([`crate::runtime::Runtime::apply_grads`]), so
//! (params, m, v) stay in bit-lockstep on all ranks and an N-way run is
//! byte-identical to a 1-way run at matched global batch (`qpretrain
//! digest --dp` proves it in CI).
//!
//! Two design rules make that hold:
//!
//! 1. **The tree is the numerics.** Leaf gradients are terms of the
//!    *global* mean (`inv_norm = 1/(B*seq)` is folded into the logit
//!    gradients), so nodes combine by pure summation, and odd "carry"
//!    nodes pass through *unchanged* — no combine, no re-quantization.
//! 2. **A node's canonical value is its packed form.** When the recipe's
//!    `g` policy is int8-eligible ([`wire_policy`]), every node value is
//!    defined as `dequant(pack_grads_i8(sum of child values))`, the wire
//!    ships exactly those codes + scales ([`frame`]), and a received node
//!    is *never* re-packed (requantization is not bitwise idempotent).
//!    Receiver dequant is therefore unconditionally bit-identical to the
//!    sender's value. Ineligible recipes ship raw f32 — lossless either
//!    way.
//!
//! Frame I/O sits behind the [`Transport`] seam, with three
//! implementations selected by `--transport`:
//!
//! * **filesystem** ([`Exchange`]): ranks are separate processes; frames
//!   land in `<out>/dist/step_<s>_rank_<k>_part_<p>.frame`
//!   (length-prefixed binary with an FNV-64 integrity check, published
//!   atomically via tmp+rename — the file's existence is the step
//!   barrier, collected with a capped-exponential-backoff poll). A
//!   killed worker fails loudly through an `ABORT` marker, leader-side
//!   child exit polling, and a timeout.
//! * **channel** ([`channel`]): ranks are threads of one process,
//!   exchanging the same encoded frames over bounded in-memory MPSC
//!   channels — no disk, no poll loop, no out dir; the same
//!   abort/timeout/deadline semantics through a shared abort slot.
//! * **socket** ([`socket`]): ranks are separate processes exchanging the
//!   same encoded frames over TCP — rank 0 listens (`--listen`, default
//!   loopback + OS port), workers dial (`--connect`) after a versioned
//!   `QDGH` handshake, and rank 0 relays every worker frame to the other
//!   workers. Loopback multi-process today, multi-host tomorrow; same
//!   loudness contract (`ABRT` control frames, deadline, hung-up-peer
//!   detection, graceful FIN + drain on success).
//!
//! On top of the seam, `--overlap on` (the default) overlaps shard
//! backward with publish: each subtree of the rank's cover ships as its
//! own frame part the moment its leaf range completes
//! ([`tree::cover_schedule`]), so peers start tree completion while
//! stragglers are still in backward. The collector reassembles parts in
//! cover order into the identical node set, so transport and overlap are
//! wall-clock knobs only — `digest --dp 2` is byte-identical across all
//! of them, and to `--dp 1`.

pub mod channel;
pub mod frame;
pub mod socket;
pub mod tree;

use std::collections::HashMap;
use std::io::Write;
use std::path::{Path, PathBuf};
use std::process::{Child, Command};
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, ensure, Context, Result};

use crate::config::{cosine_lr, shard_range, DistTransport, QuantRecipe, TensorPolicy};
use crate::coordinator::RunSummary;
use crate::data::{BatchIter, CorpusCfg};
use crate::model::{init_state, save_checkpoint};
use crate::quant::{
    dequant_acts_i8, int8_grad_eligible, operand_from_codes, pack_grads_i8, tight_codes_i8,
    PackedGemmOperand,
};
use crate::runtime::{ModelInfo, ParamInfo, Runtime};
use crate::train::{validation_loss, MetricsWriter, ProbeWriter, TrainCfg, TrainResult};
use crate::util::stats::Ema;
use frame::{Frame, WireNode, WireTensor, WireView};

// ---------------------------------------------------------------------------
// wire policy + gradient node algebra
// ---------------------------------------------------------------------------

/// The gradient-exchange quantization policy: the recipe's `g` policy when
/// it is int8-eligible (8-bit symmetric per-tensor/per-token — exactly
/// [`crate::quant::pack_grads_i8`]'s domain), `None` otherwise. Selected
/// by the recipe alone; there is no separate knob.
pub fn wire_policy(recipe: &QuantRecipe) -> Option<TensorPolicy> {
    recipe.grads.filter(|p| int8_grad_eligible(*p))
}

/// The quantization view split of one parameter tensor, following the
/// moment-qdq convention in `backend::native::adamw_update`: only >= 2-D
/// base tensors quantize; stacked tensors split into per-layer
/// `(shape[1], shape[2])` views, plain 2-D tensors are one view. 1-D
/// tensors (biases, layernorm) return `None` and always travel as f32.
fn view_dims(info: &ParamInfo) -> Option<(usize, usize, usize)> {
    let base_ndim = info.shape.len() - usize::from(info.stacked);
    if base_ndim < 2 {
        return None;
    }
    if info.stacked {
        Some((info.shape[0], info.shape[1], info.shape[2]))
    } else {
        Some((1, info.shape[0], info.shape[1]))
    }
}

/// One per-parameter gradient tensor at a tree node: the dequantized f32
/// value (what downstream sums / AdamW consume) plus, when the wire policy
/// applies to this tensor, the packed views that *define* that value and
/// are shipped verbatim.
struct GradTensor {
    data: Vec<f32>,
    packed: Option<Vec<PackedGemmOperand>>,
}

impl GradTensor {
    /// Build the canonical tensor from a raw f32 gradient: pack each view
    /// once and take the dequant as the value (or keep raw f32 when the
    /// policy does not apply).
    fn from_raw(info: &ParamInfo, raw: Vec<f32>, policy: Option<TensorPolicy>) -> GradTensor {
        match (policy, view_dims(info)) {
            (Some(p), Some((views, rows, cols))) => {
                debug_assert_eq!(raw.len(), views * rows * cols);
                let mut packed = Vec::with_capacity(views);
                let mut data = Vec::with_capacity(raw.len());
                for v in 0..views {
                    let view = &raw[v * rows * cols..(v + 1) * rows * cols];
                    let op = pack_grads_i8(view, rows, cols, p);
                    data.extend_from_slice(&dequant_acts_i8(&op));
                    packed.push(op);
                }
                GradTensor { data, packed: Some(packed) }
            }
            _ => GradTensor { data: raw, packed: None },
        }
    }
}

/// One reduction-tree node: loss sum (f64) over the leaves it covers plus
/// the per-parameter gradient tensors.
struct GradNode {
    loss: f64,
    tensors: Vec<GradTensor>,
}

impl GradNode {
    /// A leaf node from one sequence's backward output.
    fn leaf(
        model: &ModelInfo,
        loss_sum: f64,
        grads: Vec<Vec<f32>>,
        policy: Option<TensorPolicy>,
    ) -> GradNode {
        let tensors = model
            .params
            .iter()
            .zip(grads)
            .map(|(info, g)| GradTensor::from_raw(info, g, policy))
            .collect();
        GradNode { loss: loss_sum, tensors }
    }

    /// The canonical combine: sum the child values, then re-canonicalize
    /// (pack once) under the wire policy. Both children must be canonical.
    fn combine(
        model: &ModelInfo,
        a: GradNode,
        b: GradNode,
        policy: Option<TensorPolicy>,
    ) -> GradNode {
        let tensors = model
            .params
            .iter()
            .zip(a.tensors.into_iter().zip(b.tensors))
            .map(|(info, (ta, tb))| {
                let mut sum = ta.data;
                for (s, x) in sum.iter_mut().zip(&tb.data) {
                    *s += x;
                }
                GradTensor::from_raw(info, sum, policy)
            })
            .collect();
        GradNode { loss: a.loss + b.loss, tensors }
    }
}

/// Evaluate tree node `(level, idx)` by consuming `nodes`: a present entry
/// (own leaf or a received wire node) is taken as-is; otherwise the node is
/// built from its children. Carry nodes (empty right child) pass the left
/// child through unchanged — no combine, no re-quantization.
fn take_node(
    level: u32,
    idx: usize,
    leaves: usize,
    nodes: &mut HashMap<(u32, usize), GradNode>,
    model: &ModelInfo,
    policy: Option<TensorPolicy>,
) -> Result<GradNode> {
    if let Some(n) = nodes.remove(&(level, idx)) {
        return Ok(n);
    }
    ensure!(level > 0, "missing leaf {idx} in the reduction tree");
    let left = take_node(level - 1, 2 * idx, leaves, nodes, model, policy)?;
    if tree::is_carry(level, idx, leaves) {
        return Ok(left);
    }
    let right = take_node(level - 1, 2 * idx + 1, leaves, nodes, model, policy)?;
    Ok(GradNode::combine(model, left, right, policy))
}

// ---------------------------------------------------------------------------
// wire conversion
// ---------------------------------------------------------------------------

fn to_wire(level: u32, idx: usize, node: &GradNode) -> WireNode {
    let tensors = node
        .tensors
        .iter()
        .map(|t| match &t.packed {
            Some(ops) => WireTensor::I8(
                ops.iter()
                    .map(|op| WireView {
                        rows: op.rows as u32,
                        cols: op.cols as u32,
                        scales: op.scales.clone(),
                        codes: tight_codes_i8(op),
                    })
                    .collect(),
            ),
            None => WireTensor::F32(t.data.clone()),
        })
        .collect();
    WireNode {
        level: level as u8,
        idx: idx as u32,
        loss: node.loss,
        tensors,
    }
}

/// Reconstruct a canonical node from the wire: exact dequant of the
/// shipped codes + scales (never re-packed), with every dimension checked
/// against the model so a wrong-shaped frame fails loudly.
fn from_wire(model: &ModelInfo, wn: &WireNode, policy: Option<TensorPolicy>) -> Result<GradNode> {
    ensure!(
        wn.tensors.len() == model.params.len(),
        "wire node has {} tensors, model {} has {} parameters",
        wn.tensors.len(),
        model.name,
        model.params.len()
    );
    let mut tensors = Vec::with_capacity(wn.tensors.len());
    for (info, wt) in model.params.iter().zip(&wn.tensors) {
        let quantized = policy.is_some() && view_dims(info).is_some();
        let t = match wt {
            WireTensor::F32(data) => {
                ensure!(!quantized, "{}: expected i8 wire tensor, got f32", info.name);
                ensure!(
                    data.len() == info.elems(),
                    "{}: wire tensor has {} elements, expected {}",
                    info.name,
                    data.len(),
                    info.elems()
                );
                GradTensor { data: data.clone(), packed: None }
            }
            WireTensor::I8(views) => {
                ensure!(quantized, "{}: unexpected i8 wire tensor", info.name);
                let (nviews, rows, cols) =
                    view_dims(info).expect("quantized implies 2-D views");
                ensure!(
                    views.len() == nviews,
                    "{}: wire tensor has {} views, expected {nviews}",
                    info.name,
                    views.len()
                );
                let mut data = Vec::with_capacity(info.elems());
                let mut packed = Vec::with_capacity(nviews);
                for v in views {
                    ensure!(
                        v.rows as usize == rows && v.cols as usize == cols,
                        "{}: wire view is {}x{}, expected {rows}x{cols}",
                        info.name,
                        v.rows,
                        v.cols
                    );
                    let op = operand_from_codes(&v.codes, v.scales.clone(), rows, cols);
                    data.extend_from_slice(&dequant_acts_i8(&op));
                    packed.push(op);
                }
                GradTensor { data, packed: Some(packed) }
            }
        };
        tensors.push(t);
    }
    Ok(GradNode { loss: wn.loss, tensors })
}

// ---------------------------------------------------------------------------
// the transport seam
// ---------------------------------------------------------------------------

/// The frame-I/O seam of the dist trainer. An implementation must deliver
/// every published frame to every other rank byte-exactly and exactly
/// once, block `collect` until a peer's complete step shipment is in, and
/// fail loudly — a broadcast `abort` reaches every peer, and every wait
/// respects the deadline (`QPRETRAIN_DIST_TIMEOUT_SECS`). Nothing above
/// the seam depends on *how* bytes move, which is what makes transport a
/// wall-clock knob instead of a numerics knob.
pub trait Transport {
    /// Ship one frame — one part of this rank's step — to every peer.
    fn publish(&mut self, frame: &Frame) -> Result<()>;

    /// Block until every peer's complete step-`step` shipment arrived;
    /// returns one reassembled frame per peer (parts merged in part
    /// order, normalized to `part 0 of 1`), in rank order.
    fn collect(&mut self, step: u64) -> Result<Vec<Frame>>;

    /// Broadcast a fatal error so every peer fails with its message.
    fn abort(&self, msg: &str);
}

/// Reassemble one peer's per-step shipment from its parts (already
/// sorted by part index): concatenate the node lists in part order and
/// normalize the framing to a single `part 0 of 1` frame — byte-identical
/// to what a barrier-mode publish of the same cover produces, which is
/// the overlap-correctness property `dist::tests` proves.
fn merge_parts(mut parts: Vec<Frame>) -> Frame {
    let mut f = parts.remove(0);
    for p in parts {
        f.nodes.extend(p.nodes);
    }
    f.part = 0;
    f.parts = 1;
    f
}

/// Per-step reassembly state shared by the push-style transports (channel
/// and socket): received frames decode into a stash keyed by
/// `(step, rank)` — a peer may already be shipping step `s + 1` while we
/// collect `s` — and a peer's shipment merges once all its parts are in.
/// The filesystem transport reads parts in order from disk and needs no
/// stash.
pub(crate) struct Stash {
    rank: usize,
    dp: usize,
    map: HashMap<(u64, u32), Vec<Frame>>,
}

impl Stash {
    fn new(rank: usize, dp: usize) -> Stash {
        Stash { rank, dp, map: HashMap::new() }
    }

    /// Decode and stash one received frame, validating it comes from a
    /// peer of this exchange and is for the current or the next step
    /// (anything else means the lockstep protocol broke).
    fn admit(&mut self, step: u64, bytes: &[u8]) -> Result<()> {
        WIRE_READ.fetch_add(bytes.len() as u64, Ordering::Relaxed);
        let f = frame::decode(bytes).context("decoding transport frame")?;
        ensure!(
            f.dp as usize == self.dp
                && (f.rank as usize) < self.dp
                && f.rank as usize != self.rank,
            "transport frame from rank {} dp {} (expected a peer of rank {} dp {})",
            f.rank,
            f.dp,
            self.rank,
            self.dp
        );
        ensure!(
            f.step == step || f.step == step + 1,
            "transport frame for step {} while collecting step {step} \
             (peers run at most one step ahead)",
            f.step
        );
        self.map.entry((f.step, f.rank)).or_default().push(f);
        Ok(())
    }

    /// Is rank `r`'s step-`step` shipment fully stashed? (Part 0 announces
    /// how many parts the shipment has.)
    fn is_complete(&self, step: u64, r: u32) -> bool {
        self.map.get(&(step, r)).is_some_and(|parts| {
            parts
                .iter()
                .find(|f| f.part == 0)
                .is_some_and(|p0| parts.len() >= p0.parts as usize)
        })
    }

    /// If every peer's step-`step` shipment is complete in the stash,
    /// merge each into its single-frame form (in rank order) and return
    /// them; otherwise leave the stash untouched and return `None`.
    fn try_assemble(&mut self, step: u64) -> Result<Option<Vec<Frame>>> {
        for r in 0..self.dp as u32 {
            if r as usize != self.rank && !self.is_complete(step, r) {
                return Ok(None);
            }
        }
        let mut frames = Vec::with_capacity(self.dp - 1);
        for r in 0..self.dp as u32 {
            if r as usize == self.rank {
                continue;
            }
            let mut parts = self.map.remove(&(step, r)).unwrap();
            parts.sort_by_key(|f| f.part);
            let want = parts[0].parts;
            ensure!(
                parts.len() as u32 == want,
                "rank {r} shipped {} frames for step {step}, part 0 claims {want}",
                parts.len()
            );
            for (i, f) in parts.iter().enumerate() {
                ensure!(
                    f.part as usize == i && f.parts == want,
                    "rank {r} step {step} part framing is inconsistent \
                     (part {} of {}, expected {i} of {want})",
                    f.part,
                    f.parts
                );
            }
            frames.push(merge_parts(parts));
        }
        Ok(Some(frames))
    }
}

static WIRE_WRITTEN: AtomicU64 = AtomicU64::new(0);
static WIRE_READ: AtomicU64 = AtomicU64::new(0);
static EXCHANGE_NANOS: AtomicU64 = AtomicU64::new(0);

/// Drain the process-global wire byte counters: (bytes published, bytes
/// collected) since the last call. Benches use this to report f32 vs int8
/// exchange volume.
pub fn take_wire_stats() -> (u64, u64) {
    (
        WIRE_WRITTEN.swap(0, Ordering::Relaxed),
        WIRE_READ.swap(0, Ordering::Relaxed),
    )
}

/// Drain rank 0's cumulative publish+collect wall-clock (nanoseconds)
/// since the last call. Only the rank-0 loop of the calling process
/// records (filesystem workers are subprocesses), so the number compares
/// fairly across transports — `bench_dist` uses it for the
/// channel-vs-filesystem and overlap-vs-barrier rows.
pub fn take_exchange_nanos() -> u64 {
    EXCHANGE_NANOS.swap(0, Ordering::Relaxed)
}

fn dist_timeout() -> Duration {
    // No lower clamp: 0 is a legitimate value meaning "frames must
    // already be there when collect runs" (and it must fail fast, not
    // burn a poll round — see `zero_timeout` in tests/dist.rs).
    let secs = std::env::var("QPRETRAIN_DIST_TIMEOUT_SECS")
        .ok()
        .and_then(|s| s.parse::<u64>().ok())
        .unwrap_or(120);
    Duration::from_secs(secs)
}

/// The per-step frame exchange over `<out>/dist`. Publishing is atomic
/// (tmp + rename), so a frame file's existence is the step barrier.
/// Failure is loud on three paths: any rank can drop an `ABORT` marker
/// (peers bail with its message on their next poll), the leader polls its
/// children's exit status, and every wait has a deadline
/// (`QPRETRAIN_DIST_TIMEOUT_SECS`, default 120s).
pub struct Exchange {
    dir: PathBuf,
    rank: usize,
    dp: usize,
    timeout: Duration,
    /// Leader only: spawned worker children, polled during collect.
    children: Vec<(usize, Child)>,
    /// Parts this rank published per step, pending GC. Driven by the
    /// publishes actually made (not a `step - 1` guess), so every stale
    /// step — including step 1 — is removed the moment the next collect
    /// proves all peers consumed it.
    published: HashMap<u64, u32>,
}

impl Exchange {
    pub fn new(dir: &Path, rank: usize, dp: usize, timeout: Duration) -> Result<Exchange> {
        std::fs::create_dir_all(dir)
            .with_context(|| format!("creating exchange dir {dir:?}"))?;
        Ok(Exchange {
            dir: dir.to_path_buf(),
            rank,
            dp,
            timeout,
            children: Vec::new(),
            published: HashMap::new(),
        })
    }

    fn attach_children(&mut self, children: Vec<(usize, Child)>) {
        self.children = children;
    }

    fn frame_path(&self, step: u64, rank: usize, part: u32) -> PathBuf {
        self.dir.join(format!("step_{step}_rank_{rank}_part_{part}.frame"))
    }

    fn abort_path(&self) -> PathBuf {
        self.dir.join("ABORT")
    }

    /// A peer aborted, a child died, or we ran out of patience?
    fn check_failures(&mut self) -> Result<()> {
        let ap = self.abort_path();
        if ap.exists() {
            let msg = std::fs::read_to_string(&ap).unwrap_or_default();
            bail!("dist peer aborted: {}", msg.trim());
        }
        let mut failed: Option<String> = None;
        for (rank, child) in &mut self.children {
            if let Some(status) = child.try_wait()? {
                // A clean exit is fine (a worker legitimately finishes its
                // final step while the leader is still collecting it).
                if !status.success() {
                    failed = Some(format!("dist worker rank {rank} exited: {status}"));
                    break;
                }
            }
        }
        if let Some(msg) = failed {
            self.abort(&msg);
            bail!("{msg}");
        }
        Ok(())
    }

    /// Poll `path` into existence with capped exponential backoff
    /// (300µs doubling to 5ms — cheap frames arrive within a beat or
    /// two, slow peers stop burning a CPU on a fixed-rate spin). The
    /// deadline check is `>=`, so a zero timeout fails on the first miss
    /// instead of taking an extra poll round.
    fn read_with_deadline(&mut self, path: &Path, deadline: Instant) -> Result<Vec<u8>> {
        let mut backoff = Duration::from_micros(300);
        loop {
            self.check_failures()?;
            match std::fs::read(path) {
                Ok(b) => return Ok(b),
                Err(e) if e.kind() == std::io::ErrorKind::NotFound => {}
                Err(e) => return Err(e).context(format!("reading {path:?}")),
            }
            if Instant::now() >= deadline {
                let msg = format!(
                    "dist rank {} timed out after {:?} waiting for {path:?}",
                    self.rank, self.timeout
                );
                self.abort(&msg);
                bail!("{msg}");
            }
            std::thread::sleep(backoff);
            backoff = (backoff * 2).min(Duration::from_millis(5));
        }
    }

    /// Remove this rank's own frames for every published step before
    /// `upto`: a peer's step-`upto` frame exists only after that peer
    /// consumed every earlier frame, so once collect(`upto`) has seen all
    /// peers, the older files are dead. Keeps the dir bounded at ≤ 2
    /// steps of live frames (2·dp single-part, 2·Σparts with overlap)
    /// regardless of run length.
    fn gc(&mut self, upto: u64) {
        let dead: Vec<u64> = self.published.keys().copied().filter(|&s| s < upto).collect();
        for s in dead {
            let parts = self.published.remove(&s).unwrap_or(0);
            for p in 0..parts {
                let _ = std::fs::remove_file(self.frame_path(s, self.rank, p));
            }
        }
    }
}

impl Transport for Exchange {
    /// Publish one part of this rank's step (atomic tmp + rename).
    fn publish(&mut self, frame: &Frame) -> Result<()> {
        let bytes = frame::encode(frame);
        WIRE_WRITTEN.fetch_add(bytes.len() as u64, Ordering::Relaxed);
        let tmp = self
            .dir
            .join(format!("step_{}_rank_{}_part_{}.tmp", frame.step, self.rank, frame.part));
        std::fs::write(&tmp, &bytes).with_context(|| format!("writing {tmp:?}"))?;
        std::fs::rename(&tmp, self.frame_path(frame.step, self.rank, frame.part))?;
        *self.published.entry(frame.step).or_insert(0) += 1;
        Ok(())
    }

    /// Collect every other rank's complete step-`step` shipment, blocking
    /// with a deadline: part 0 announces how many parts the peer ships
    /// this step (1 in barrier mode, one per cover node with overlap),
    /// then the remaining parts are read in order and merged. On success,
    /// garbage-collects every own frame older than `step`.
    fn collect(&mut self, step: u64) -> Result<Vec<Frame>> {
        let deadline = Instant::now() + self.timeout;
        let mut frames = Vec::with_capacity(self.dp - 1);
        for r in 0..self.dp {
            if r == self.rank {
                continue;
            }
            let mut parts: Vec<Frame> = Vec::new();
            let mut want = 1u32;
            let mut part = 0u32;
            while part < want {
                let path = self.frame_path(step, r, part);
                let bytes = self.read_with_deadline(&path, deadline)?;
                WIRE_READ.fetch_add(bytes.len() as u64, Ordering::Relaxed);
                let f = frame::decode(&bytes).with_context(|| format!("decoding {path:?}"))?;
                ensure!(
                    f.step == step
                        && f.rank as usize == r
                        && f.dp as usize == self.dp
                        && f.part == part,
                    "frame {path:?} is for step {} rank {} dp {} part {} \
                     (expected {step}/{r}/{}/{part})",
                    f.step,
                    f.rank,
                    f.dp,
                    f.part,
                    self.dp
                );
                if part == 0 {
                    want = f.parts;
                } else {
                    ensure!(
                        f.parts == want,
                        "frame {path:?} claims {} parts, part 0 claimed {want}",
                        f.parts
                    );
                }
                parts.push(f);
                part += 1;
            }
            frames.push(merge_parts(parts));
        }
        self.gc(step);
        Ok(frames)
    }

    /// Drop the abort marker so every peer fails loudly on its next poll.
    fn abort(&self, msg: &str) {
        let tmp = self.dir.join(format!("ABORT.tmp.{}", self.rank));
        if std::fs::write(&tmp, msg).is_ok() {
            let _ = std::fs::rename(&tmp, self.abort_path());
        }
    }
}

impl Exchange {
    /// Leader: wait for all children; any non-success exit is an error.
    fn finish(&mut self) -> Result<()> {
        let mut err = None;
        for (rank, child) in &mut self.children {
            match child.wait() {
                Ok(s) if s.success() => {}
                Ok(s) => err = err.or(Some(anyhow!("dist worker rank {rank} exited: {s}"))),
                Err(e) => err = err.or(Some(e.into())),
            }
        }
        self.children.clear();
        match err {
            Some(e) => Err(e),
            None => Ok(()),
        }
    }

    fn kill_children(&mut self) {
        for (_, child) in &mut self.children {
            let _ = child.kill();
            let _ = child.wait();
        }
        self.children.clear();
    }
}

// ---------------------------------------------------------------------------
// the rank loop (identical numerics on every rank)
// ---------------------------------------------------------------------------

/// The per-rank training loop. All ranks run the same code over the same
/// replicated state; only leaf backwards and the wire differ. Rank 0 alone
/// performs I/O (metrics, validation, probes, checkpoint).
fn rank_loop(
    rt: &Runtime,
    cfg: &TrainCfg,
    dp: usize,
    rank: usize,
    mut ex: Option<&mut dyn Transport>,
) -> Result<TrainResult> {
    struct ThreadsRestore(usize);
    impl Drop for ThreadsRestore {
        fn drop(&mut self) {
            crate::backend::kernels::set_threads(self.0);
        }
    }
    let _threads_guard = (cfg.hp.threads > 0).then(|| {
        let prev = crate::backend::kernels::threads_override();
        crate::backend::kernels::set_threads(cfg.hp.threads);
        ThreadsRestore(prev)
    });

    let model = rt.model(&cfg.model)?.clone();
    ensure!(dp >= 1 && rank < dp, "rank {rank} out of range for dp {dp}");
    ensure!(
        dp <= model.batch,
        "dp {dp} exceeds the global batch {} of model {}",
        model.batch,
        model.name
    );
    let mut leaf_model = model.clone();
    leaf_model.batch = 1;
    let policy = wire_policy(&cfg.quant);
    let (lo, hi) = shard_range(model.batch, dp, rank);
    let seq = model.seq;
    let global_m = model.batch * seq;
    let inv_norm = 1.0f32 / global_m as f32;
    let root_level = tree::root_level(model.batch);
    let my_cover = tree::cover(lo, hi, model.batch);
    let schedule = tree::cover_schedule(lo, hi, model.batch);
    // With overlap on, each cover node ships the moment its leaf range
    // completes — `parts` frames per step instead of one. The wire content
    // is identical either way (same nodes, same canonical packed values),
    // so the received tree — and the training trajectory — is bit-equal.
    let overlap = cfg.hp.dist_overlap && dp > 1;
    let parts = schedule.len().max(1) as u32;

    // Every rank generates the *global* batch stream (cheap, deterministic)
    // and backwards only its own leaf range — simpler and provably
    // identical to slicing a shared stream.
    let mut corpus = BatchIter::new(
        CorpusCfg {
            seed: cfg.hp.seed,
            ..CorpusCfg::train_default(model.vocab)
        },
        model.batch,
        model.seq,
    );
    let mut state = init_state(&model, cfg.hp.seed);

    // Rank 0 keeps the run artifacts; workers write nothing.
    let io_cfg = if rank == 0 {
        cfg.clone()
    } else {
        TrainCfg {
            out_dir: None,
            ..cfg.clone()
        }
    };
    let mut metrics = MetricsWriter::open(&io_cfg)?;
    let mut probe = ProbeWriter::open(&io_cfg)?;

    let mut losses = Vec::with_capacity(cfg.hp.steps);
    let mut gnorms = Vec::with_capacity(cfg.hp.steps);
    let mut val = Vec::new();
    let mut spike_steps = Vec::new();
    let mut ema = Ema::new(0.05);
    let mut diverged_at: Option<usize> = None;
    let mut min_loss = f64::INFINITY;

    let t0 = Instant::now();
    let mut steps_done = 0usize;

    for i in 0..cfg.hp.steps {
        let step = i + 1; // 1-based Adam counter
        let batch = corpus.next_batch();
        let lr = cosine_lr(&cfg.hp, i) as f32;

        // Leaf backwards over this rank's shard, reducing to the maximal
        // tree-node cover as leaf ranges complete (these exact values go
        // on the wire, so peers never recompute them). With overlap on,
        // each finished cover node is published immediately — the publish
        // rides inside the remaining shard backward instead of after it.
        let mut nodes: HashMap<(u32, usize), GradNode> = HashMap::new();
        let mut next = 0usize;
        for leaf in lo..hi {
            let x = &batch.x[leaf * seq..(leaf + 1) * seq];
            let y = &batch.y[leaf * seq..(leaf + 1) * seq];
            let (loss_sum, grads) =
                rt.grad_step(&leaf_model, &cfg.quant, &state.params, x, y, inv_norm)?;
            nodes.insert((0, leaf), GradNode::leaf(&model, loss_sum, grads, policy));
            while next < schedule.len() && schedule[next].1 == leaf + 1 {
                let (l, idx) = schedule[next].0;
                let n = take_node(l, idx, model.batch, &mut nodes, &model, policy)?;
                if overlap {
                    if let Some(ex) = ex.as_deref_mut() {
                        let t = Instant::now();
                        ex.publish(&Frame {
                            step: step as u64,
                            rank: rank as u32,
                            dp: dp as u32,
                            leaves: model.batch as u32,
                            part: next as u32,
                            parts,
                            nodes: vec![to_wire(l, idx, &n)],
                        })?;
                        if rank == 0 {
                            EXCHANGE_NANOS
                                .fetch_add(t.elapsed().as_nanos() as u64, Ordering::Relaxed);
                        }
                    }
                }
                nodes.insert((l, idx), n);
                next += 1;
            }
        }

        // Exchange covers with every peer (barrier mode publishes the
        // whole cover as a single frame here; overlap mode already did).
        if let Some(ex) = ex.as_deref_mut() {
            if dp > 1 {
                let t = Instant::now();
                if !overlap {
                    let wire_nodes = my_cover
                        .iter()
                        .map(|&(l, idx)| to_wire(l, idx, &nodes[&(l, idx)]))
                        .collect();
                    ex.publish(&Frame {
                        step: step as u64,
                        rank: rank as u32,
                        dp: dp as u32,
                        leaves: model.batch as u32,
                        part: 0,
                        parts: 1,
                        nodes: wire_nodes,
                    })?;
                }
                let collected = ex.collect(step as u64)?;
                if rank == 0 {
                    EXCHANGE_NANOS.fetch_add(t.elapsed().as_nanos() as u64, Ordering::Relaxed);
                }
                for fr in collected {
                    let (plo, phi) = shard_range(model.batch, dp, fr.rank as usize);
                    let expect = tree::cover(plo, phi, model.batch);
                    let mut got: Vec<(u32, usize)> = fr
                        .nodes
                        .iter()
                        .map(|n| (n.level as u32, n.idx as usize))
                        .collect();
                    got.sort_unstable();
                    let mut want = expect.clone();
                    want.sort_unstable();
                    ensure!(
                        got == want,
                        "rank {} shipped cover {got:?}, expected {want:?}",
                        fr.rank
                    );
                    ensure!(
                        fr.leaves as usize == model.batch,
                        "rank {} frame is over {} leaves, expected {}",
                        fr.rank,
                        fr.leaves,
                        model.batch
                    );
                    for wn in &fr.nodes {
                        nodes.insert(
                            (wn.level as u32, wn.idx as usize),
                            from_wire(&model, wn, policy)?,
                        );
                    }
                }
            }
        }

        // Complete the (identical) tree and take the replicated update.
        let root = take_node(root_level, 0, model.batch, &mut nodes, &model, policy)?;
        let loss = root.loss / global_m as f64;
        let grads: Vec<Vec<f32>> = root.tensors.into_iter().map(|t| t.data).collect();
        let gnorm = rt.apply_grads(&model, &cfg.quant, &mut state, &grads, lr, step as f32)?;
        state.step = step;
        steps_done = i + 1;

        losses.push(loss);
        gnorms.push(gnorm);
        min_loss = min_loss.min(if loss.is_finite() {
            loss
        } else {
            f64::INFINITY
        });

        // Spike + divergence detection: pure functions of the replicated
        // loss stream, so every rank decides (and breaks) in lockstep.
        let ema_v = ema.update(if loss.is_finite() { loss } else { 1e9 });
        if loss.is_finite() && i > 5 && loss > ema_v + 1.0 {
            spike_steps.push(step);
        }
        if diverged_at.is_none() && (!loss.is_finite() || (i > 10 && loss > min_loss + 3.0)) {
            diverged_at = Some(step);
            if rank == 0 {
                log::warn!("{}: diverged at step {step} (loss {loss})", cfg.quant.label());
            }
        }

        if step % cfg.hp.log_every == 0 || i + 1 == cfg.hp.steps {
            metrics.log(step, loss, gnorm, cosine_lr(&cfg.hp, i), None)?;
        }
        if rank == 0
            && cfg.hp.eval_every > 0
            && (step % cfg.hp.eval_every == 0 || i + 1 == cfg.hp.steps)
        {
            let vl = validation_loss(rt, cfg, &model, &state.params)?;
            val.push((step, vl));
            metrics.log(step, loss, gnorm, cosine_lr(&cfg.hp, i), Some(vl))?;
        }
        if cfg.hp.probe_every > 0 && step % cfg.hp.probe_every == 0 {
            probe.record(rt, &model, step, &state.params)?;
        }

        if cfg.stop_on_divergence && diverged_at.is_some() {
            break;
        }
    }
    let steps_per_sec = steps_done as f64 / t0.elapsed().as_secs_f64();

    if io_cfg.save_ckpt {
        if let Some(dir) = &io_cfg.out_dir {
            save_checkpoint(&dir.join("final.ckpt"), &model, &state)?;
        }
    }

    Ok(TrainResult {
        label: cfg.quant.label(),
        losses,
        gnorms,
        val,
        diverged: diverged_at.is_some(),
        diverged_at,
        spike_steps,
        steps_per_sec,
        final_state: state,
    })
}

// ---------------------------------------------------------------------------
// launcher (leader) + worker entrypoint
// ---------------------------------------------------------------------------

/// The worker binary: `QPRETRAIN_BIN` override (tests and benches run from
/// test binaries and point this at `CARGO_BIN_EXE_qpretrain`), else the
/// current executable.
fn worker_exe() -> Result<PathBuf> {
    match std::env::var_os("QPRETRAIN_BIN") {
        Some(p) => Ok(PathBuf::from(p)),
        None => Ok(std::env::current_exe()?),
    }
}

fn exchange_dir(out: &Path) -> PathBuf {
    out.join("dist")
}

/// Leader entry: run `cfg` data-parallel over `cfg.hp.dp` ranks. `dp <= 1`
/// degenerates to the same sharded numerics with no exchange at all;
/// otherwise `cfg.hp.dist_transport` picks the topology — worker processes
/// over the filesystem exchange, worker threads over in-process channels,
/// or worker processes dialing rank 0 over TCP. The trajectory is
/// bit-identical across transports.
pub fn dist_train(rt: &Runtime, cfg: &TrainCfg) -> Result<TrainResult> {
    let dp = cfg.hp.dp.max(1);
    if dp == 1 {
        return rank_loop(rt, cfg, 1, 0, None);
    }
    match cfg.hp.dist_transport {
        DistTransport::Filesystem => dist_train_fs(rt, cfg, dp),
        DistTransport::Channel => channel::dist_train_channel(rt, cfg, dp),
        DistTransport::Socket => socket::dist_train_socket(rt, cfg, dp),
    }
}

/// The `dist-worker` spawn command shared by the multi-process leaders
/// (filesystem and socket): everything that must replicate bit-exactly —
/// model, recipe, schedule, seed, thread split, overlap — travels as
/// args, and the int8-accumulator knob as env (it may have been set
/// programmatically by a test rather than via the environment). The
/// caller appends its transport-specific args (`--out` / `--connect`).
fn worker_cmd(exe: &Path, cfg: &TrainCfg, rank: usize, dp: usize, threads: usize) -> Command {
    let mut cmd = Command::new(exe);
    cmd.args([
        "dist-worker",
        "--rank",
        &rank.to_string(),
        "--dp",
        &dp.to_string(),
        "--model",
        &cfg.model,
        "--quant",
        &cfg.quant.to_string(),
        "--steps",
        &cfg.hp.steps.to_string(),
        "--seed",
        &cfg.hp.seed.to_string(),
        "--lr",
        &cfg.hp.lr_max.to_string(),
        "--lr-min",
        &cfg.hp.lr_min.to_string(),
        "--warmup",
        &cfg.hp.warmup.to_string(),
        "--threads",
        &threads.to_string(),
        "--overlap",
        if cfg.hp.dist_overlap { "on" } else { "off" },
        "--transport",
        cfg.hp.dist_transport.as_str(),
    ]);
    if !cfg.stop_on_divergence {
        cmd.arg("--no-early-stop");
    }
    cmd.env(
        "QPRETRAIN_INT8",
        if crate::backend::native::int8_gemm_enabled() {
            "on"
        } else {
            "off"
        },
    );
    cmd
}

/// Filesystem leader: spawn `dp - 1` `dist-worker` processes (this process
/// is rank 0). Requires `cfg.out_dir` (the exchange protocol lives in
/// `<out>/dist`; the dir is wiped first — stale frames or an old ABORT
/// from a crashed run must not poison this one — and removed again on
/// success).
fn dist_train_fs(rt: &Runtime, cfg: &TrainCfg, dp: usize) -> Result<TrainResult> {
    let out = cfg.out_dir.clone().ok_or_else(|| {
        anyhow!("dist-train with dp > 1 needs an out dir (--out) for the exchange protocol")
    })?;
    let exdir = exchange_dir(&out);
    let _ = std::fs::remove_dir_all(&exdir);

    // Split the kernel thread budget across the dp processes, exactly like
    // coordinator sweeps split it across wave workers.
    let threads = crate::coordinator::worker_threads(cfg, dp);
    let mut leader_cfg = cfg.clone();
    leader_cfg.hp.threads = threads;

    let exe = worker_exe()?;
    let mut ex = Exchange::new(&exdir, 0, dp, dist_timeout())?;
    let mut children = Vec::with_capacity(dp - 1);
    for rank in 1..dp {
        let mut cmd = worker_cmd(&exe, cfg, rank, dp, threads);
        cmd.args(["--out", out.to_str().ok_or_else(|| anyhow!("non-UTF8 out dir"))?]);
        let child = cmd
            .spawn()
            .with_context(|| format!("spawning dist worker rank {rank}"))?;
        children.push((rank, child));
    }
    ex.attach_children(children);

    match rank_loop(rt, &leader_cfg, dp, 0, Some(&mut ex)) {
        Ok(result) => {
            ex.finish()?;
            let _ = std::fs::remove_dir_all(&exdir);
            Ok(result)
        }
        Err(e) => {
            ex.abort(&format!("{e:#}"));
            ex.kill_children();
            Err(e)
        }
    }
}

/// Worker entry (`dist-worker` subcommand): join the leader's exchange as
/// `rank` — over the filesystem protocol under `cfg.out_dir`, or by
/// dialing the leader's socket (`--connect`) — and run the same loop. Any
/// error reaches the leader loudly (ABORT marker / `ABRT` control frame)
/// before propagating.
pub fn dist_worker(rt: &Runtime, cfg: &TrainCfg, rank: usize) -> Result<()> {
    let dp = cfg.hp.dp;
    ensure!(dp > 1 && rank > 0 && rank < dp, "bad dist worker rank {rank} for dp {dp}");
    match cfg.hp.dist_transport {
        DistTransport::Filesystem => {
            let out = cfg
                .out_dir
                .clone()
                .ok_or_else(|| anyhow!("dist-worker needs --out (the leader's run dir)"))?;
            let mut ex = Exchange::new(&exchange_dir(&out), rank, dp, dist_timeout())?;
            match rank_loop(rt, cfg, dp, rank, Some(&mut ex)) {
                Ok(_) => Ok(()),
                Err(e) => {
                    ex.abort(&format!("rank {rank}: {e:#}"));
                    Err(e)
                }
            }
        }
        DistTransport::Socket => {
            let spec = cfg.hp.dist_connect.as_deref().ok_or_else(|| {
                anyhow!(
                    "dist-worker --transport socket needs --connect <host:port> \
                     (the leader's --listen address)"
                )
            })?;
            let addr = crate::util::net::parse_addr(spec)?;
            let mut tp = socket::connect(
                addr,
                rank,
                dp,
                dist_timeout(),
                socket::epoch_nonce(cfg),
                &cfg.quant.label(),
            )?;
            match rank_loop(rt, cfg, dp, rank, Some(&mut tp)) {
                Ok(_) => tp.finish(),
                Err(e) => {
                    tp.abort(&format!("rank {rank}: {e:#}"));
                    Err(e)
                }
            }
        }
        DistTransport::Channel => bail!(
            "dist-worker is for multi-process transports; channel ranks are threads \
             (run dist-train --transport channel)"
        ),
    }
}

/// Dist counterpart of [`crate::coordinator::execute_run`]: run `cfg`
/// data-parallel into `dir`, persist the summary + loss curve, and mark
/// the run `DONE` (the coordinator's cache token) only after everything
/// else landed.
pub fn execute_dist_run(rt: &Runtime, mut cfg: TrainCfg, dir: &Path) -> Result<RunSummary> {
    cfg.out_dir = Some(dir.to_path_buf());
    cfg.save_ckpt = true;
    let r = dist_train(rt, &cfg)?;
    let summary = RunSummary::from_result(&cfg, &r, dir);
    summary.save()?;
    let mut f = std::fs::File::create(dir.join("loss_curve.csv"))?;
    writeln!(f, "step,loss,gnorm")?;
    for (i, (l, g)) in r.losses.iter().zip(&r.gnorms).enumerate() {
        writeln!(f, "{},{},{}", i + 1, l, g)?;
    }
    crate::coordinator::mark_done(dir)?;
    Ok(summary)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn micro() -> (Runtime, ModelInfo) {
        let rt = Runtime::native();
        let m = rt.model("micro").unwrap().clone();
        (rt, m)
    }

    #[test]
    fn wire_policy_follows_the_recipe() {
        let p = |s: &str| wire_policy(&QuantRecipe::parse(s).unwrap());
        assert!(p("base").is_none());
        assert!(p("w8a8").is_none()); // no gradient component
        assert!(p("w8a8g8").is_some()); // 8-bit symmetric per-tensor
        assert!(p("g8_ptok").is_some());
        assert!(p("g8_pc").is_none()); // per-channel grads are not eligible
        assert!(p("g4_pt").is_none()); // nor 4-bit
    }

    #[test]
    fn view_dims_follow_the_moment_qdq_convention() {
        let (_, m) = micro();
        for info in &m.params {
            let v = view_dims(info);
            let base_ndim = info.shape.len() - usize::from(info.stacked);
            if base_ndim < 2 {
                assert!(v.is_none(), "{} should stay f32", info.name);
            } else {
                let (views, rows, cols) = v.unwrap();
                assert_eq!(views * rows * cols, info.elems(), "{}", info.name);
                assert_eq!(views, if info.stacked { m.n_layer } else { 1 });
            }
        }
        // 16 params; the 6 weight matrices quantize, biases/LN stay f32
        let quantized = m.params.iter().filter(|p| view_dims(p).is_some()).count();
        assert_eq!(quantized, 6);
    }

    #[test]
    fn wire_roundtrip_is_bit_exact_for_both_kinds() {
        let (_, m) = micro();
        let policy = wire_policy(&QuantRecipe::parse("w8a8g8").unwrap());
        for pol in [None, policy] {
            let grads: Vec<Vec<f32>> = m
                .params
                .iter()
                .enumerate()
                .map(|(i, p)| {
                    (0..p.elems())
                        .map(|j| ((i * 31 + j * 7) % 13) as f32 * 0.05 - 0.3)
                        .collect()
                })
                .collect();
            let node = GradNode::leaf(&m, 1.25, grads, pol);
            let wn = to_wire(0, 0, &node);
            let back = from_wire(&m, &wn, pol).unwrap();
            assert_eq!(back.loss.to_bits(), node.loss.to_bits());
            for (a, b) in node.tensors.iter().zip(&back.tensors) {
                assert_eq!(a.data.len(), b.data.len());
                for (x, y) in a.data.iter().zip(&b.data) {
                    assert_eq!(x.to_bits(), y.to_bits());
                }
            }
        }
    }

    #[test]
    fn from_wire_rejects_wrong_shapes() {
        let (_, m) = micro();
        let node = GradNode::leaf(
            &m,
            0.0,
            m.params.iter().map(|p| vec![0.0f32; p.elems()]).collect(),
            None,
        );
        let mut wn = to_wire(0, 0, &node);
        // kind mismatch: claim i8 under an f32 policy
        wn.tensors[0] = WireTensor::I8(vec![]);
        assert!(from_wire(&m, &wn, None).is_err());
        // length mismatch
        let mut wn = to_wire(0, 0, &node);
        if let WireTensor::F32(v) = &mut wn.tensors[0] {
            v.pop();
        }
        assert!(from_wire(&m, &wn, None).is_err());
        // tensor-count mismatch
        let mut wn = to_wire(0, 0, &node);
        wn.tensors.pop();
        assert!(from_wire(&m, &wn, None).is_err());
    }

    #[test]
    fn incremental_publish_is_byte_identical_to_single_shot() {
        // overlap mode ships the cover one node per frame as leaf ranges
        // complete; barrier mode ships it whole. After reassembly the two
        // must be the same bytes on the wire — for raw-f32 and packed-i8
        // policies alike — or transports could not mix freely with the
        // overlap knob.
        let (_, m) = micro();
        let i8_policy = wire_policy(&QuantRecipe::parse("w8a8g8").unwrap());
        let leaf = |s: usize| {
            GradNode::leaf(
                &m,
                s as f64 + 0.5,
                m.params
                    .iter()
                    .map(|p| {
                        (0..p.elems())
                            .map(|j| ((j * (2 * s + 3)) % 19) as f32 * 0.07 - 0.6)
                            .collect()
                    })
                    .collect(),
                i8_policy,
            )
        };
        for policy in [None, i8_policy] {
            for (lo, hi, leaves) in [(1usize, 5usize, 8usize), (0, 8, 8), (2, 5, 5), (0, 2, 4)] {
                let cover = tree::cover(lo, hi, leaves);

                // single-shot: all leaves first, then the whole cover
                let mut nodes = HashMap::new();
                for s in lo..hi {
                    nodes.insert((0, s), leaf(s));
                }
                let mut wire_nodes = Vec::new();
                for &(l, idx) in &cover {
                    let n = take_node(l, idx, leaves, &mut nodes, &m, policy).unwrap();
                    wire_nodes.push(to_wire(l, idx, &n));
                    nodes.insert((l, idx), n);
                }
                let barrier = Frame {
                    step: 3,
                    rank: 1,
                    dp: 2,
                    leaves: leaves as u32,
                    part: 0,
                    parts: 1,
                    nodes: wire_nodes,
                };

                // incremental: evaluate + emit each node at its ready point
                let schedule = tree::cover_schedule(lo, hi, leaves);
                let parts = schedule.len() as u32;
                let mut nodes = HashMap::new();
                let mut next = 0usize;
                let mut shipped = Vec::new();
                for s in lo..hi {
                    nodes.insert((0, s), leaf(s));
                    while next < schedule.len() && schedule[next].1 == s + 1 {
                        let (l, idx) = schedule[next].0;
                        let n = take_node(l, idx, leaves, &mut nodes, &m, policy).unwrap();
                        shipped.push(frame::encode(&Frame {
                            step: 3,
                            rank: 1,
                            dp: 2,
                            leaves: leaves as u32,
                            part: next as u32,
                            parts,
                            nodes: vec![to_wire(l, idx, &n)],
                        }));
                        nodes.insert((l, idx), n);
                        next += 1;
                    }
                }
                assert_eq!(shipped.len(), cover.len(), "one frame per cover node");

                let reassembled =
                    merge_parts(shipped.iter().map(|b| frame::decode(b).unwrap()).collect());
                assert_eq!(
                    frame::encode(&reassembled),
                    frame::encode(&barrier),
                    "shard [{lo},{hi}) of {leaves} leaves, policy {policy:?}"
                );
            }
        }
    }

    #[test]
    fn carry_nodes_pass_through_without_requantization() {
        // B=3: node (1,1) is a carry of leaf 2; the root combines (1,0)
        // with it. Evaluating from leaves must equal evaluating from the
        // exact leaf-2 value inserted at (1,1) — i.e. the carry never
        // re-packs.
        let (_, m) = micro();
        let policy = wire_policy(&QuantRecipe::parse("w8a8g8").unwrap());
        let leaf = |s: u64| {
            GradNode::leaf(
                &m,
                s as f64,
                m.params
                    .iter()
                    .map(|p| {
                        (0..p.elems())
                            .map(|j| ((j as u64).wrapping_mul(s * 2 + 1) % 17) as f32 * 0.1 - 0.8)
                            .collect()
                    })
                    .collect(),
                policy,
            )
        };
        let mut nodes = HashMap::new();
        for s in 0..3u64 {
            nodes.insert((0, s as usize), leaf(s));
        }
        let root_a = take_node(2, 0, 3, &mut nodes, &m, policy).unwrap();

        let mut nodes = HashMap::new();
        nodes.insert((0, 0), leaf(0));
        nodes.insert((0, 1), leaf(1));
        nodes.insert((1, 1), leaf(2)); // the carry value IS leaf 2
        let root_b = take_node(2, 0, 3, &mut nodes, &m, policy).unwrap();

        assert_eq!(root_a.loss.to_bits(), root_b.loss.to_bits());
        for (a, b) in root_a.tensors.iter().zip(&root_b.tensors) {
            for (x, y) in a.data.iter().zip(&b.data) {
                assert_eq!(x.to_bits(), y.to_bits());
            }
        }
    }
}
