//! Post-training quantization harness (paper Appendix C, Tables 10 & 11).
//!
//! Weight PTQ quantizes a trained checkpoint's block-linear weights with the
//! rust `quant` module (bit-exact with the training graph's quantizer) and
//! evaluates through the *unquantized* eval artifact. Activation PTQ reuses
//! the activation-quantized eval artifacts with the qmax runtime scalar on
//! unmodified baseline weights.

use anyhow::Result;

use crate::config::{Granularity, QuantRecipe, TensorPolicy};
use crate::model::HostState;
use crate::quant;
use crate::runtime::{ModelInfo, Runtime};

/// The block-linear weight tensors the paper quantizes ("all linear layers
/// of Transformers"); embeddings / LN / biases stay fp32.
pub const LINEAR_WEIGHTS: [&str; 4] = ["qkv_w", "proj_w", "fc1_w", "fc2_w"];

/// Quantize the linear weights of a checkpoint in place. Stacked per-layer
/// tensors are quantized layer-by-layer (per_tensor = per layer tensor, as
/// in training).
pub fn quantize_weights(state: &mut HostState, model: &ModelInfo, policy: TensorPolicy) {
    for (info, data) in model.params.iter().zip(state.params.iter_mut()) {
        if !LINEAR_WEIGHTS.contains(&info.name.as_str()) {
            continue;
        }
        assert!(info.stacked && info.shape.len() == 3, "{}", info.name);
        let (l, rows, cols) = (info.shape[0], info.shape[1], info.shape[2]);
        for layer in 0..l {
            let slice = &mut data[layer * rows * cols..(layer + 1) * rows * cols];
            quant::qdq(slice, rows, cols, policy);
        }
    }
}

/// Aggregate quantization error introduced by weight PTQ (diagnostics).
pub fn weight_ptq_error(state: &HostState, model: &ModelInfo, policy: TensorPolicy) -> (f64, f64) {
    let mut mse_sum = 0.0;
    let mut n = 0usize;
    let mut sqnr_min = f64::INFINITY;
    for (info, data) in model.params.iter().zip(state.params.iter()) {
        if !LINEAR_WEIGHTS.contains(&info.name.as_str()) {
            continue;
        }
        let (l, rows, cols) = (info.shape[0], info.shape[1], info.shape[2]);
        for layer in 0..l {
            let slice = &data[layer * rows * cols..(layer + 1) * rows * cols];
            let q = quant::qdq_copy(slice, rows, cols, policy);
            mse_sum += quant::mse(slice, &q) * slice.len() as f64;
            n += slice.len();
            sqnr_min = sqnr_min.min(quant::sqnr_db(slice, &q));
        }
    }
    (mse_sum / n.max(1) as f64, sqnr_min)
}

/// Table 10 row: weight-PTQ a checkpoint and return perplexities per set.
pub fn ptq_weights_ppl(
    rt: &Runtime,
    model: &ModelInfo,
    baseline: &HostState,
    bits: u32,
    gran: Granularity,
    n_batches: usize,
) -> Result<std::collections::BTreeMap<String, f64>> {
    let mut state = baseline.clone();
    quantize_weights(&mut state, model, TensorPolicy::new(bits, gran));
    crate::eval::perplexity_suite(rt, &QuantRecipe::none(), model, &state.params, n_batches)
}

/// Table 11 row: activation-PTQ via the quantized eval artifact.
pub fn ptq_acts_ppl(
    rt: &Runtime,
    model: &ModelInfo,
    baseline: &HostState,
    bits: u32,
    gran: Granularity,
    n_batches: usize,
) -> Result<std::collections::BTreeMap<String, f64>> {
    let recipe = QuantRecipe {
        acts: Some(TensorPolicy::new(bits, gran)),
        ..QuantRecipe::none()
    };
    crate::eval::perplexity_suite(rt, &recipe, model, &baseline.params, n_batches)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::init_state;
    use crate::runtime::ParamInfo;

    fn model() -> ModelInfo {
        ModelInfo {
            name: "t".into(),
            n_layer: 2,
            d_model: 8,
            n_head: 2,
            vocab: 32,
            seq: 8,
            batch: 1,
            d_ff: 32,
            n_params: 0,
            params: vec![
                ParamInfo {
                    name: "wte".into(),
                    shape: vec![32, 8],
                    stacked: false,
                    decay: true,
                    init: "normal:0.02".into(),
                },
                ParamInfo {
                    name: "qkv_w".into(),
                    shape: vec![2, 8, 24],
                    stacked: true,
                    decay: true,
                    init: "normal:0.02".into(),
                },
                ParamInfo {
                    name: "fc1_w".into(),
                    shape: vec![2, 8, 32],
                    stacked: true,
                    decay: true,
                    init: "normal:0.02".into(),
                },
            ],
        }
    }

    #[test]
    fn quantizes_only_linear_weights() {
        let m = model();
        let base = init_state(&m, 3);
        let mut q = base.clone();
        quantize_weights(&mut q, &m, TensorPolicy::new(4, Granularity::PerChannel));
        assert_eq!(q.params[0], base.params[0]); // wte untouched
        assert_ne!(q.params[1], base.params[1]); // qkv_w quantized
        assert_ne!(q.params[2], base.params[2]);
    }

    #[test]
    fn ptq_is_idempotent() {
        let m = model();
        let mut a = init_state(&m, 4);
        quantize_weights(&mut a, &m, TensorPolicy::new(8, Granularity::PerChannel));
        let mut b = a.clone();
        quantize_weights(&mut b, &m, TensorPolicy::new(8, Granularity::PerChannel));
        for (x, y) in a.params[1].iter().zip(&b.params[1]) {
            assert!((x - y).abs() < 1e-6);
        }
    }

    #[test]
    fn lower_bits_higher_error() {
        let m = model();
        let s = init_state(&m, 5);
        let (mse4, _) = weight_ptq_error(&s, &m, TensorPolicy::new(4, Granularity::PerChannel));
        let (mse8, _) = weight_ptq_error(&s, &m, TensorPolicy::new(8, Granularity::PerChannel));
        assert!(mse4 > mse8 * 10.0);
    }

    #[test]
    fn per_channel_beats_per_tensor_with_outlier_column() {
        let m = model();
        let mut s = init_state(&m, 6);
        // inject an outlier output-channel into layer 0 of qkv_w
        for r in 0..8 {
            s.params[1][r * 24 + 5] = 3.0;
        }
        let (mse_pt, _) = weight_ptq_error(&s, &m, TensorPolicy::new(4, Granularity::PerTensor));
        let (mse_pc, _) = weight_ptq_error(&s, &m, TensorPolicy::new(4, Granularity::PerChannel));
        assert!(mse_pc < mse_pt);
    }
}
