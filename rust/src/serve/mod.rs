//! Batched quantized inference: KV-cached decode + continuous batching.
//!
//! The deployment half of the paper's story — a checkpoint pre-trained
//! with w8a8(g8) serves from **packed-int8 weights resident in memory**
//! with no extra calibration (the PreQuant-style PTQ-for-inference path):
//!
//! * **Resident weights** — every block linear is quantized exactly once
//!   at engine construction ([`native::pack_resident_weight`]): packed i8
//!   codes on the [`native::int8_structure`] path, fake-quantized f32
//!   otherwise. Because packing is a deterministic function of weights and
//!   policy, load-time packing is bit-identical to the training forward's
//!   pack-per-step.
//! * **KV-cached decode** — each session owns per-layer K/V buffers sized
//!   by the `max_seq` budget (recycled through a slab pool as sessions
//!   retire). A decode step runs every forward op on the new token rows
//!   only and attends over the cached keys ([`kernels::decode_attn`])
//!   instead of re-forwarding the full context.
//! * **Continuous batching** — a scheduler admits and retires sessions
//!   *per decode step*, so ragged-length concurrent requests share one
//!   batched GEMM per linear instead of padding to the longest request.
//! * **Sampling** — greedy argmax plus temperature/top-k driven by
//!   [`util::rng`](crate::util::rng), so any generation replays
//!   deterministically from its seed.
//!
//! **Why decode is bitwise-equal to the full re-forward** (pinned by
//! `tests/serve.rs`): every op in the forward graph is row-local —
//! LayerNorm, bias, GELU, residual adds, and the logits dot-products work
//! row by row; per-token activation quantization scales each row from its
//! own amax ([`QuantRecipe::serve_forward`] rejects batch-statistic
//! activation policies up front); and the GEMM kernels compute each output
//! row on the same ascending-`k` lane tree at any row count. Attention row
//! `i` of the full causal tile is a max/exp/sum over exactly the first
//! `i + 1` keys — precisely what [`kernels::decode_attn`] computes from
//! the cache, using the same `math::matmul_nt` / `softmax_row` /
//! `math::matmul` building blocks. The same row-locality makes a batched
//! decode step bit-identical to the same sessions stepped one at a time,
//! which is what lets the scheduler re-batch freely.

use std::collections::VecDeque;
use std::time::Instant;

use anyhow::{bail, Result};

use crate::backend::kernels::{self, bias_add, gelu, layer_norm_fwd, matmul_nt, par_chunks_mut};
use crate::backend::native::{
    self, pack_resident_weight, resident_linear, resident_linear_acc, ResidentWeight,
};
use crate::config::{QuantRecipe, TensorPolicy};
use crate::runtime::ModelInfo;
use crate::util::rng::Rng;

// ---------------------------------------------------------------------------
// sampling
// ---------------------------------------------------------------------------

/// Token-selection policy for one generation request.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Sampler {
    /// Argmax over the logits; ties break to the lowest token id, so the
    /// result is exact and thread/SIMD-invariant.
    Greedy,
    /// Temperature softmax over the `k` highest logits (`k == 0` keeps the
    /// whole vocabulary). `temperature <= 0` degenerates to greedy.
    TopK { temperature: f32, k: usize },
}

/// Sample one token id from a logits row. Deterministic given the rng
/// state: candidates are ordered by (logit desc, id asc) — a total order,
/// so equal logits cannot reorder across platforms — and the inverse-CDF
/// walk accumulates in f64 in that fixed order.
pub fn sample_token(logits: &[f32], sampler: Sampler, rng: &mut Rng) -> i32 {
    let greedy = || {
        let mut best = 0usize;
        for (i, &l) in logits.iter().enumerate() {
            if l > logits[best] {
                best = i;
            }
        }
        best as i32
    };
    match sampler {
        Sampler::Greedy => greedy(),
        Sampler::TopK { temperature, k } => {
            if temperature <= 0.0 {
                return greedy();
            }
            let mut idx: Vec<usize> = (0..logits.len()).collect();
            idx.sort_by(|&a, &b| {
                logits[b]
                    .partial_cmp(&logits[a])
                    .unwrap_or(std::cmp::Ordering::Equal)
                    .then(a.cmp(&b))
            });
            let keep = if k == 0 { idx.len() } else { k.min(idx.len()) };
            let top = &idx[..keep];
            let mx = logits[top[0]] as f64;
            let t = temperature as f64;
            let weights: Vec<f64> =
                top.iter().map(|&i| ((logits[i] as f64 - mx) / t).exp()).collect();
            let total: f64 = weights.iter().sum();
            let mut u = rng.f64() * total;
            for (w, &i) in weights.iter().zip(top) {
                u -= w;
                if u <= 0.0 {
                    return i as i32;
                }
            }
            top[keep - 1] as i32 // float round-off fell off the end
        }
    }
}

// ---------------------------------------------------------------------------
// engine
// ---------------------------------------------------------------------------

/// Scheduler budget: how many sessions share one batched decode step, and
/// the per-session context budget (clamped to the model's learned
/// positional-embedding length).
#[derive(Debug, Clone, Copy)]
pub struct ServeCfg {
    pub max_batch: usize,
    pub max_seq: usize,
}

impl ServeCfg {
    pub fn new(max_batch: usize, max_seq: usize) -> ServeCfg {
        ServeCfg { max_batch, max_seq }
    }
}

/// One generation request: prompt token ids, generation budget, sampling
/// policy and the per-request rng seed (replays are deterministic).
#[derive(Debug, Clone)]
pub struct Request {
    pub prompt: Vec<i32>,
    pub max_new: usize,
    pub sampler: Sampler,
    pub seed: u64,
}

/// A finished request, in the order requests were submitted.
#[derive(Debug, Clone)]
pub struct Completion {
    pub id: usize,
    pub prompt_len: usize,
    pub generated: Vec<i32>,
    /// Wall seconds from admission to the first sampled token (prefill
    /// latency).
    pub ttft_secs: f64,
    /// Decode steps this session consumed (prefill + generation).
    pub steps: usize,
}

/// Aggregate scheduler statistics for one [`Engine::run`].
#[derive(Debug, Clone, Copy, Default)]
pub struct ServeStats {
    /// Batched decode steps executed.
    pub steps: usize,
    /// Session-rows decoded across all steps.
    pub rows: usize,
    /// Largest number of sessions sharing one step.
    pub peak_batch: usize,
    /// `rows / (steps * max_batch)` — how full the batch slots ran.
    pub occupancy: f64,
    /// Tokens sampled (sum of `generated` lengths).
    pub tokens_out: usize,
    pub wall_secs: f64,
}

/// Per-layer resident weights (quantized once at construction) plus the
/// fp32 norm/bias parameters.
struct LayerWeights {
    ln1_w: Vec<f32>,
    ln1_b: Vec<f32>,
    qkv: ResidentWeight,
    qkv_b: Vec<f32>,
    proj: ResidentWeight,
    proj_b: Vec<f32>,
    ln2_w: Vec<f32>,
    ln2_b: Vec<f32>,
    fc1: ResidentWeight,
    fc1_b: Vec<f32>,
    fc2: ResidentWeight,
    fc2_b: Vec<f32>,
}

/// One session's K/V storage: per (layer, head) rings of `cap` positions
/// by `hd` lanes, laid out `[(layer * h + head) * cap + pos] * hd`.
/// Recycled through the engine's slab pool when the session retires.
struct KvSlab {
    k: Vec<f32>,
    v: Vec<f32>,
}

struct Session {
    id: usize,
    tokens: Vec<i32>,
    prompt_len: usize,
    max_new: usize,
    generated: usize,
    /// Tokens already fed through the model (== cached KV positions).
    fed: usize,
    sampler: Sampler,
    rng: Rng,
    kv: KvSlab,
    admitted: Instant,
    ttft: Option<f64>,
    steps: usize,
    done: bool,
}

/// The batched quantized inference engine: resident weights + KV slab pool
/// + the continuous-batching scheduler.
pub struct Engine {
    model: ModelInfo,
    /// Activation policy of the serve-eligible forward recipe.
    acts: Option<TensorPolicy>,
    wte: Vec<f32>,
    wpe: Vec<f32>,
    lnf_w: Vec<f32>,
    lnf_b: Vec<f32>,
    layers: Vec<LayerWeights>,
    cfg: ServeCfg,
    /// Effective per-session context budget: `min(cfg.max_seq, model.seq)`
    /// (the learned positional table bounds addressable positions).
    cap: usize,
    /// Retired sessions' K/V slabs, reused before allocating new ones.
    pool: Vec<KvSlab>,
}

impl Engine {
    /// Build an engine from a checkpoint's parameters: derives the
    /// serve-eligible forward recipe ([`QuantRecipe::serve_forward`]) and
    /// quantizes every block linear into its resident form **once**.
    pub fn new(
        model: &ModelInfo,
        params: &[Vec<f32>],
        recipe: &QuantRecipe,
        cfg: ServeCfg,
    ) -> Result<Engine> {
        let fwd = recipe.serve_forward()?;
        if params.len() != native::N_PARAM_TENSORS {
            bail!(
                "{}: expected {} parameter tensors, got {}",
                model.name,
                native::N_PARAM_TENSORS,
                params.len()
            );
        }
        for (info, p) in model.params.iter().zip(params.iter()) {
            if p.len() != info.elems() {
                bail!(
                    "{}: parameter {} has {} elements, expected {}",
                    model.name,
                    info.name,
                    p.len(),
                    info.elems()
                );
            }
        }
        if cfg.max_batch == 0 {
            bail!("max_batch must be at least 1");
        }
        let cap = cfg.max_seq.clamp(1, model.seq);
        let (d, f) = (model.d_model, model.d_ff);
        let sl = |p: &[f32], l: usize, n: usize| p[l * n..(l + 1) * n].to_vec();
        let layers = (0..model.n_layer)
            .map(|l| LayerWeights {
                ln1_w: sl(&params[native::LN1_W], l, d),
                ln1_b: sl(&params[native::LN1_B], l, d),
                qkv: pack_resident_weight(
                    &params[native::QKV_W][l * d * 3 * d..(l + 1) * d * 3 * d],
                    d,
                    3 * d,
                    &fwd,
                ),
                qkv_b: sl(&params[native::QKV_B], l, 3 * d),
                proj: pack_resident_weight(
                    &params[native::PROJ_W][l * d * d..(l + 1) * d * d],
                    d,
                    d,
                    &fwd,
                ),
                proj_b: sl(&params[native::PROJ_B], l, d),
                ln2_w: sl(&params[native::LN2_W], l, d),
                ln2_b: sl(&params[native::LN2_B], l, d),
                fc1: pack_resident_weight(
                    &params[native::FC1_W][l * d * f..(l + 1) * d * f],
                    d,
                    f,
                    &fwd,
                ),
                fc1_b: sl(&params[native::FC1_B], l, f),
                fc2: pack_resident_weight(
                    &params[native::FC2_W][l * f * d..(l + 1) * f * d],
                    f,
                    d,
                    &fwd,
                ),
                fc2_b: sl(&params[native::FC2_B], l, d),
            })
            .collect();
        Ok(Engine {
            model: model.clone(),
            acts: fwd.acts,
            wte: params[native::WTE].clone(),
            wpe: params[native::WPE].clone(),
            lnf_w: params[native::LNF_W].clone(),
            lnf_b: params[native::LNF_B].clone(),
            layers,
            cfg,
            cap,
            pool: Vec::new(),
        })
    }

    /// Number of block linears resident as packed i8 codes (4 per layer on
    /// the int8-structured path, 0 on the f32 path).
    pub fn packed_linears(&self) -> usize {
        self.layers
            .iter()
            .flat_map(|lw| [&lw.qkv, &lw.proj, &lw.fc1, &lw.fc2])
            .filter(|w| w.is_packed())
            .count()
    }

    /// Effective per-session context budget.
    pub fn context_budget(&self) -> usize {
        self.cap
    }

    fn take_slab(&mut self) -> KvSlab {
        self.pool.pop().unwrap_or_else(|| {
            let n = self.model.n_layer * self.model.d_model * self.cap;
            KvSlab {
                k: vec![0.0f32; n],
                v: vec![0.0f32; n],
            }
        })
    }

    fn admit(&mut self, id: usize, req: &Request) -> Result<Session> {
        if req.prompt.is_empty() {
            bail!("request {id}: empty prompt");
        }
        if req.prompt.len() > self.cap {
            bail!(
                "request {id}: prompt length {} exceeds the context budget {}",
                req.prompt.len(),
                self.cap
            );
        }
        for &tok in &req.prompt {
            if tok < 0 || tok as usize >= self.model.vocab {
                bail!(
                    "request {id}: token id {tok} out of vocab range 0..{}",
                    self.model.vocab
                );
            }
        }
        Ok(Session {
            id,
            tokens: req.prompt.clone(),
            prompt_len: req.prompt.len(),
            max_new: req.max_new,
            generated: 0,
            fed: 0,
            sampler: req.sampler,
            rng: Rng::new(req.seed),
            kv: self.take_slab(),
            admitted: Instant::now(),
            ttft: None,
            steps: 0,
            done: false,
        })
    }

    /// One batched decode step over the active sessions: feed each
    /// session's next token at its own position, append K/V to its cache,
    /// and return the logits rows `(sessions, vocab)` in session order.
    fn decode_rows(&self, active: &mut [Session]) -> Vec<f32> {
        let m = &self.model;
        let (d, f, h, v) = (m.d_model, m.d_ff, m.n_head, m.vocab);
        let hd = d / h;
        let rows = active.len();
        let inv_sqrt_hd = 1.0f32 / (hd as f32).sqrt();

        // embeddings: x[r] = wte[token] + wpe[position] (row-local gather,
        // value-identical to the full forward's row-parallel embed)
        let mut x = vec![0.0f32; rows * d];
        for (ri, sess) in active.iter().enumerate() {
            let tok = sess.tokens[sess.fed] as usize;
            let wte_row = &self.wte[tok * d..(tok + 1) * d];
            let wpe_row = &self.wpe[sess.fed * d..(sess.fed + 1) * d];
            let dst = &mut x[ri * d..(ri + 1) * d];
            for c in 0..d {
                dst[c] = wte_row[c] + wpe_row[c];
            }
        }

        let ring = self.cap * hd; // one (layer, head) ring in the slab
        for (l, lw) in self.layers.iter().enumerate() {
            // --- attention ---
            let (a, _, _) = layer_norm_fwd(&x, &lw.ln1_w, &lw.ln1_b, rows, d);
            let mut qkv = resident_linear(a, &lw.qkv, rows, d, 3 * d, self.acts);
            bias_add(&mut qkv, &lw.qkv_b, rows, 3 * d);

            // append this step's K/V head rows to each session's rings
            for (ri, sess) in active.iter_mut().enumerate() {
                let row = &qkv[ri * 3 * d..(ri + 1) * 3 * d];
                for hh in 0..h {
                    let o = (l * h + hh) * ring + sess.fed * hd;
                    sess.kv.k[o..o + hd].copy_from_slice(&row[d + hh * hd..d + (hh + 1) * hd]);
                    sess.kv.v[o..o + hd]
                        .copy_from_slice(&row[2 * d + hh * hd..2 * d + (hh + 1) * hd]);
                }
            }

            // incremental attention over the cached prefix, parallel over
            // (session, head) pairs — each pair is an independent
            // `decode_attn` call on the serial reference kernels, so the
            // schedule never affects values
            let kv_refs: Vec<(&[f32], &[f32], usize)> = active
                .iter()
                .map(|s| (s.kv.k.as_slice(), s.kv.v.as_slice(), s.fed + 1))
                .collect();
            let mut ctx = vec![0.0f32; rows * d];
            let max_len = kv_refs.iter().map(|r| r.2).max().unwrap_or(1);
            par_chunks_mut(&mut ctx, hd, 4 * max_len * hd, |pairs, cc| {
                for (ci, pair) in pairs.clone().enumerate() {
                    let (ri, hh) = (pair / h, pair % h);
                    let (ks, vs, len) = kv_refs[ri];
                    let o = (l * h + hh) * ring;
                    let q = &qkv[ri * 3 * d + hh * hd..ri * 3 * d + (hh + 1) * hd];
                    kernels::decode_attn(
                        q,
                        &ks[o..o + len * hd],
                        &vs[o..o + len * hd],
                        len,
                        hd,
                        inv_sqrt_hd,
                        &mut cc[ci * hd..(ci + 1) * hd],
                    );
                }
            });

            let mut h2 = x.clone();
            resident_linear_acc(&ctx, &lw.proj, rows, d, d, self.acts, &mut h2);
            bias_add(&mut h2, &lw.proj_b, rows, d);

            // --- MLP ---
            let (mm, _, _) = layer_norm_fwd(&h2, &lw.ln2_w, &lw.ln2_b, rows, d);
            let mut u = resident_linear(mm, &lw.fc1, rows, d, f, self.acts);
            bias_add(&mut u, &lw.fc1_b, rows, f);
            let g = gelu(&u);
            let mut hout = h2.clone();
            resident_linear_acc(&g, &lw.fc2, rows, f, d, self.acts, &mut hout);
            bias_add(&mut hout, &lw.fc2_b, rows, d);
            x = hout;
        }

        let (hf, _, _) = layer_norm_fwd(&x, &self.lnf_w, &self.lnf_b, rows, d);
        matmul_nt(&hf, &self.wte, rows, d, v)
    }

    /// Run a set of requests to completion under continuous batching:
    /// every decode step re-fills the batch from the waiting queue, so a
    /// short request retiring immediately frees its slot (and K/V slab)
    /// for the next one. Completions return in request order. Token
    /// streams are identical at any `max_batch`, including 1 — batching is
    /// a throughput decision, never a results decision.
    pub fn run(&mut self, reqs: &[Request]) -> Result<(Vec<Completion>, ServeStats)> {
        let t0 = Instant::now();
        let mut queue: VecDeque<usize> = (0..reqs.len()).collect();
        let mut active: Vec<Session> = Vec::new();
        let mut out: Vec<Option<Completion>> = vec![None; reqs.len()];
        let mut stats = ServeStats::default();

        while !queue.is_empty() || !active.is_empty() {
            while active.len() < self.cfg.max_batch {
                let Some(id) = queue.pop_front() else { break };
                active.push(self.admit(id, &reqs[id])?);
            }
            let logits = self.decode_rows(&mut active);
            stats.steps += 1;
            stats.rows += active.len();
            stats.peak_batch = stats.peak_batch.max(active.len());

            let v = self.model.vocab;
            for (ri, sess) in active.iter_mut().enumerate() {
                sess.fed += 1;
                sess.steps += 1;
                // prefill rows (fed < prompt_len) discard their logits;
                // once every token is consumed, this row's logits predict
                // the next position
                if sess.fed == sess.tokens.len() && sess.generated < sess.max_new {
                    let row = &logits[ri * v..(ri + 1) * v];
                    let tok = sample_token(row, sess.sampler, &mut sess.rng);
                    sess.tokens.push(tok);
                    sess.generated += 1;
                    sess.ttft
                        .get_or_insert_with(|| sess.admitted.elapsed().as_secs_f64());
                }
                // retire when the budget is spent or the context is full
                // (no further position can be fed)
                sess.done = sess.generated == sess.max_new || sess.fed == self.cap;
            }
            let mut i = 0;
            while i < active.len() {
                if active[i].done {
                    let sess = active.swap_remove(i);
                    stats.tokens_out += sess.generated;
                    out[sess.id] = Some(Completion {
                        id: sess.id,
                        prompt_len: sess.prompt_len,
                        generated: sess.tokens[sess.prompt_len..].to_vec(),
                        ttft_secs: sess.ttft.unwrap_or_default(),
                        steps: sess.steps,
                    });
                    self.pool.push(sess.kv);
                } else {
                    i += 1;
                }
            }
        }

        stats.wall_secs = t0.elapsed().as_secs_f64();
        stats.occupancy = if stats.steps == 0 {
            0.0
        } else {
            stats.rows as f64 / (stats.steps * self.cfg.max_batch) as f64
        };
        Ok((
            out.into_iter()
                .map(|c| c.expect("every request completes"))
                .collect(),
            stats,
        ))
    }

    /// Single-request convenience wrapper over [`Engine::run`].
    pub fn generate(
        &mut self,
        prompt: &[i32],
        max_new: usize,
        sampler: Sampler,
        seed: u64,
    ) -> Result<Vec<i32>> {
        let (mut done, _) = self.run(&[Request {
            prompt: prompt.to_vec(),
            max_new,
            sampler,
            seed,
        }])?;
        Ok(done.remove(0).generated)
    }

    /// KV-cached scoring of a fixed sequence: feed `tokens` one position
    /// per step and return every step's logits row `(len, vocab)`. This is
    /// the decode side of the bitwise equivalence proofs — row `s` must
    /// equal row `s` of [`native::forward_logits`] over the same sequence.
    pub fn decode_logits(&mut self, tokens: &[i32]) -> Result<Vec<f32>> {
        if tokens.is_empty() || tokens.len() > self.cap {
            bail!(
                "decode_logits: sequence length {} outside 1..={}",
                tokens.len(),
                self.cap
            );
        }
        let req = Request {
            prompt: tokens.to_vec(),
            max_new: 0,
            sampler: Sampler::Greedy,
            seed: 0,
        };
        let mut sess = vec![self.admit(usize::MAX, &req)?];
        let v = self.model.vocab;
        let mut out = Vec::with_capacity(tokens.len() * v);
        for _ in 0..tokens.len() {
            let logits = self.decode_rows(&mut sess);
            debug_assert_eq!(logits.len(), v);
            out.extend_from_slice(&logits);
            sess[0].fed += 1;
        }
        self.pool.push(sess.remove(0).kv);
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::native::{forward_logits, model_info};
    use crate::model::init_state;

    fn tiny() -> ModelInfo {
        model_info("tt", 2, 16, 2, 32, 8, 2)
    }

    #[test]
    fn sampler_greedy_is_argmax_lowest_tie() {
        let mut rng = Rng::new(1);
        let logits = [0.25f32, 1.5, 1.5, -0.5];
        assert_eq!(sample_token(&logits, Sampler::Greedy, &mut rng), 1);
    }

    #[test]
    fn sampler_topk_deterministic_per_seed() {
        let logits: Vec<f32> = (0..16).map(|i| ((i * 7) % 5) as f32 * 0.3).collect();
        let s = Sampler::TopK {
            temperature: 0.8,
            k: 4,
        };
        let draw = |seed| {
            let mut rng = Rng::new(seed);
            (0..32).map(|_| sample_token(&logits, s, &mut rng)).collect::<Vec<_>>()
        };
        assert_eq!(draw(9), draw(9));
        assert_ne!(draw(9), draw(10));
        // k = 4 keeps only the four highest logits
        let top: Vec<i32> = draw(3);
        for t in top {
            assert!(logits[t as usize] >= 0.9, "sampled outside top-k: {t}");
        }
    }

    #[test]
    fn rejects_batch_statistic_act_recipes() {
        let model = tiny();
        let st = init_state(&model, 1);
        let bad = QuantRecipe::parse("w8_pc+a8_pt").unwrap();
        assert!(Engine::new(&model, &st.params, &bad, ServeCfg::new(4, 8)).is_err());
        let good = QuantRecipe::parse("w8a8").unwrap();
        assert!(Engine::new(&model, &st.params, &good, ServeCfg::new(4, 8)).is_ok());
    }

    #[test]
    fn w8a8_engine_keeps_weights_packed() {
        let model = tiny();
        let st = init_state(&model, 2);
        let quant = Engine::new(
            &model,
            &st.params,
            &QuantRecipe::parse("w8a8").unwrap(),
            ServeCfg::new(2, 8),
        )
        .unwrap();
        assert_eq!(quant.packed_linears(), 4 * model.n_layer);
        let base = Engine::new(
            &model,
            &st.params,
            &QuantRecipe::none(),
            ServeCfg::new(2, 8),
        )
        .unwrap();
        assert_eq!(base.packed_linears(), 0);
    }

    #[test]
    fn decode_matches_full_forward_smoke() {
        // the deep thread/simd matrix lives in tests/serve.rs; this is the
        // in-module smoke version
        let model = tiny();
        let st = init_state(&model, 3);
        let recipe = QuantRecipe::parse("w8a8").unwrap();
        let mut rng = Rng::new(7);
        let x: Vec<i32> = (0..model.batch * model.seq)
            .map(|_| rng.below(model.vocab) as i32)
            .collect();
        let full = forward_logits(&model, &st.params, &x, &recipe.forward_only()).unwrap();
        let mut eng =
            Engine::new(&model, &st.params, &recipe, ServeCfg::new(2, model.seq)).unwrap();
        let t = model.seq;
        for b in 0..model.batch {
            let seq = &x[b * t..(b + 1) * t];
            let dec = eng.decode_logits(seq).unwrap();
            assert_eq!(dec, full[b * t * model.vocab..(b + 1) * t * model.vocab]);
        }
    }

    #[test]
    fn batched_equals_sequential() {
        let model = tiny();
        let st = init_state(&model, 5);
        let recipe = QuantRecipe::parse("w8a8").unwrap();
        let mut rng = Rng::new(11);
        let reqs: Vec<Request> = (0..5)
            .map(|i| Request {
                prompt: (0..rng.range(1, 5)).map(|_| rng.below(model.vocab) as i32).collect(),
                max_new: 3 + i % 3,
                sampler: if i % 2 == 0 {
                    Sampler::Greedy
                } else {
                    Sampler::TopK {
                        temperature: 0.9,
                        k: 8,
                    }
                },
                seed: 100 + i as u64,
            })
            .collect();
        let mut batched =
            Engine::new(&model, &st.params, &recipe, ServeCfg::new(4, model.seq)).unwrap();
        let (bc, bstats) = batched.run(&reqs).unwrap();
        let mut seq =
            Engine::new(&model, &st.params, &recipe, ServeCfg::new(1, model.seq)).unwrap();
        let (sc, _) = seq.run(&reqs).unwrap();
        for (b, s) in bc.iter().zip(&sc) {
            assert_eq!(b.generated, s.generated, "request {}", b.id);
        }
        assert!(bstats.peak_batch >= 4, "peak batch {}", bstats.peak_batch);
        assert!(bstats.steps < sc.iter().map(|c| c.steps).sum::<usize>());
    }

    #[test]
    fn slabs_recycle_across_requests() {
        let model = tiny();
        let st = init_state(&model, 6);
        let mut eng = Engine::new(
            &model,
            &st.params,
            &QuantRecipe::none(),
            ServeCfg::new(2, model.seq),
        )
        .unwrap();
        let reqs: Vec<Request> = (0..6)
            .map(|i| Request {
                prompt: vec![(i % 8) as i32],
                max_new: 2,
                sampler: Sampler::Greedy,
                seed: i as u64,
            })
            .collect();
        eng.run(&reqs).unwrap();
        // at most max_batch slabs were ever alive
        assert!(eng.pool.len() <= 2, "pool grew to {}", eng.pool.len());
    }

    #[test]
    fn rejects_bad_requests() {
        let model = tiny();
        let st = init_state(&model, 1);
        let mut eng = Engine::new(
            &model,
            &st.params,
            &QuantRecipe::none(),
            ServeCfg::new(2, 4),
        )
        .unwrap();
        let bad = |prompt: Vec<i32>| Request {
            prompt,
            max_new: 1,
            sampler: Sampler::Greedy,
            seed: 0,
        };
        assert!(eng.run(&[bad(vec![])]).is_err());
        assert!(eng.run(&[bad(vec![model.vocab as i32])]).is_err());
        assert!(eng.run(&[bad(vec![-1])]).is_err());
        assert!(eng.run(&[bad(vec![0; 5])]).is_err()); // beyond max_seq 4
    }
}
