//! Deterministic SplitMix64 PRNG with normal sampling and Zipf support.
//!
//! Every stochastic component of the system (corpus generation, parameter
//! init, sharpness directions, few-shot episode sampling) is seeded through
//! this generator so experiments are exactly reproducible.

#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        Rng {
            state: seed.wrapping_add(0x9E3779B97F4A7C15),
        }
    }

    /// Derive an independent stream (e.g. per shard / per tensor).
    pub fn fork(&self, stream: u64) -> Rng {
        let mut r = Rng::new(self.state ^ stream.wrapping_mul(0xA24BAED4963EE407));
        r.next_u64();
        r
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        (self.next_u64() % n as u64) as usize
    }

    /// Uniform integer in [lo, hi).
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.below(hi - lo)
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        loop {
            let u1 = self.f64();
            if u1 <= f64::MIN_POSITIVE {
                continue;
            }
            let u2 = self.f64();
            return (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos();
        }
    }

    pub fn normal_f32(&mut self, mean: f32, std: f32) -> f32 {
        mean + std * self.normal() as f32
    }

    /// Fill a vector with N(mean, std) f32 samples.
    pub fn normal_vec(&mut self, n: usize, mean: f32, std: f32) -> Vec<f32> {
        (0..n).map(|_| self.normal_f32(mean, std)).collect()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len())]
    }

    pub fn bool_with(&mut self, p: f64) -> bool {
        self.f64() < p
    }
}

/// Precomputed Zipf(α) sampler over `n` items via inverse-CDF binary search.
/// Token frequencies in natural text are approximately Zipfian, which is the
/// property that matters for quantization studies: long-tailed activation
/// and gradient statistics.
#[derive(Debug, Clone)]
pub struct Zipf {
    cdf: Vec<f64>,
}

impl Zipf {
    pub fn new(n: usize, alpha: f64) -> Self {
        let mut cdf = Vec::with_capacity(n);
        let mut acc = 0.0;
        for k in 0..n {
            acc += 1.0 / ((k + 2) as f64).powf(alpha);
            cdf.push(acc);
        }
        let total = acc;
        for c in cdf.iter_mut() {
            *c /= total;
        }
        Zipf { cdf }
    }

    pub fn sample(&self, rng: &mut Rng) -> usize {
        let u = rng.f64();
        match self
            .cdf
            .binary_search_by(|c| c.partial_cmp(&u).unwrap())
        {
            Ok(i) => i,
            Err(i) => i.min(self.cdf.len() - 1),
        }
    }

    pub fn len(&self) -> usize {
        self.cdf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.cdf.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn forks_are_independent() {
        let root = Rng::new(1);
        let mut a = root.fork(0);
        let mut b = root.fork(1);
        let av: Vec<u64> = (0..8).map(|_| a.next_u64()).collect();
        let bv: Vec<u64> = (0..8).map(|_| b.next_u64()).collect();
        assert_ne!(av, bv);
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(7);
        let n = 20000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.03, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn zipf_is_long_tailed() {
        let z = Zipf::new(1000, 1.05);
        let mut r = Rng::new(3);
        let mut counts = vec![0usize; 1000];
        for _ in 0..50000 {
            counts[z.sample(&mut r)] += 1;
        }
        assert!(counts[0] > counts[100] && counts[100] > 0);
        assert!(counts[0] > 20 * counts[500].max(1));
    }

    #[test]
    fn below_in_range() {
        let mut r = Rng::new(9);
        for _ in 0..1000 {
            assert!(r.below(7) < 7);
            let x = r.range(3, 10);
            assert!((3..10).contains(&x));
        }
    }
}
