//! Tiny property-based testing harness (proptest is unavailable offline).
//!
//! Runs a property over `n` generated cases; on failure it greedily shrinks
//! the failing input with a user-supplied shrinker and reports the seed so
//! the case can be replayed deterministically.

use super::rng::Rng;

pub struct Config {
    pub cases: usize,
    pub seed: u64,
    pub max_shrink_steps: usize,
}

impl Default for Config {
    fn default() -> Self {
        Config {
            cases: 100,
            seed: 0xC0FFEE,
            max_shrink_steps: 200,
        }
    }
}

/// Check `prop` over `cases` inputs drawn from `gen`. Panics (with the seed
/// and the shrunk counterexample debug-printed) on the first failure.
pub fn check<T, G, P>(cfg: Config, gen: G, prop: P)
where
    T: std::fmt::Debug + Clone,
    G: Fn(&mut Rng) -> T,
    P: Fn(&T) -> bool,
{
    check_with_shrink(cfg, gen, |_| Vec::new(), prop)
}

/// Like [`check`] but with a shrinker producing smaller candidate inputs.
pub fn check_with_shrink<T, G, S, P>(cfg: Config, gen: G, shrink: S, prop: P)
where
    T: std::fmt::Debug + Clone,
    G: Fn(&mut Rng) -> T,
    S: Fn(&T) -> Vec<T>,
    P: Fn(&T) -> bool,
{
    for case in 0..cfg.cases {
        let mut rng = Rng::new(cfg.seed).fork(case as u64);
        let input = gen(&mut rng);
        if prop(&input) {
            continue;
        }
        // shrink greedily
        let mut worst = input;
        let mut steps = 0;
        'outer: while steps < cfg.max_shrink_steps {
            for cand in shrink(&worst) {
                steps += 1;
                if !prop(&cand) {
                    worst = cand;
                    continue 'outer;
                }
                if steps >= cfg.max_shrink_steps {
                    break;
                }
            }
            break;
        }
        panic!(
            "property failed (seed={:#x}, case={case}).\ncounterexample: {worst:#?}",
            cfg.seed
        );
    }
}

/// Generator helpers.
pub mod gen {
    use super::Rng;

    pub fn f32_vec(rng: &mut Rng, max_len: usize, scale: f32) -> Vec<f32> {
        let n = rng.range(1, max_len + 1);
        (0..n).map(|_| rng.normal_f32(0.0, scale)).collect()
    }

    /// Occasionally injects outliers / zeros / negatives — the adversarial
    /// patterns the paper's quantization analysis cares about.
    pub fn f32_vec_adversarial(rng: &mut Rng, max_len: usize) -> Vec<f32> {
        let mut v = f32_vec(rng, max_len, 1.0);
        match rng.below(4) {
            0 => {} // plain gaussian
            1 => {
                let i = rng.below(v.len());
                v[i] = 1e6; // massive outlier
            }
            2 => v.iter_mut().for_each(|x| *x = 0.0),
            _ => {
                let i = rng.below(v.len());
                v[i] = -1e-7; // tiny value near zero bin
            }
        }
        v
    }

    /// Shrinker for vectors: halve length, zero elements.
    pub fn shrink_f32_vec(v: &[f32]) -> Vec<Vec<f32>> {
        let mut out = Vec::new();
        if v.len() > 1 {
            out.push(v[..v.len() / 2].to_vec());
            out.push(v[v.len() / 2..].to_vec());
        }
        for i in 0..v.len().min(4) {
            if v[i] != 0.0 {
                let mut w = v.to_vec();
                w[i] = 0.0;
                out.push(w);
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_valid_property() {
        check(
            Config::default(),
            |rng| gen::f32_vec(rng, 32, 1.0),
            |v| !v.is_empty(),
        );
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn fails_and_shrinks() {
        check_with_shrink(
            Config {
                cases: 50,
                ..Default::default()
            },
            |rng| gen::f32_vec(rng, 64, 10.0),
            |v| gen::shrink_f32_vec(v),
            |v| v.iter().all(|x| x.abs() < 5.0), // will fail for gaussian*10
        );
    }
}
