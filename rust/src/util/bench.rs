//! Custom micro-benchmark harness (criterion is unavailable offline).
//!
//! Bench binaries (`cargo bench`) call [`bench`] per case; it warms up,
//! auto-scales iteration count to a target measurement time, and prints
//! criterion-style `name  time ± sd  (throughput)` rows plus a
//! machine-readable JSONL file under `runs/bench/`.
//!
//! [`check_against_baseline`] is the CI perf-regression gate: the bench
//! binaries call it on their own `BENCH_*.json` report against the
//! committed floors in `rust/tests/bench_baseline.json`, failing the run
//! (instead of merely uploading an artifact nobody diffs) when a metric
//! regresses below its floor.

use std::time::{Duration, Instant};

use crate::util::json::Value;

pub struct BenchResult {
    pub name: String,
    pub mean_ns: f64,
    pub sd_ns: f64,
    pub iters: u64,
}

impl BenchResult {
    pub fn mean_secs(&self) -> f64 {
        self.mean_ns / 1e9
    }

    /// Achieved GFLOP/s given the FLOPs one iteration performs.
    pub fn gflops(&self, flops: u64) -> f64 {
        flops as f64 / self.mean_secs() / 1e9
    }
}

/// Whether `QPRETRAIN_BENCH_FAST` is set (CI smoke mode): the single
/// definition of the fast-mode predicate — bench binaries that also shrink
/// their own workloads (step counts, reps) must consult this, not re-parse
/// the variable.
pub fn fast_mode() -> bool {
    static CACHE: std::sync::OnceLock<bool> = std::sync::OnceLock::new();
    *CACHE.get_or_init(|| {
        matches!(std::env::var("QPRETRAIN_BENCH_FAST"), Ok(v) if !v.is_empty() && v != "0")
    })
}

/// Seconds of measurement per case. `QPRETRAIN_BENCH_FAST=1` shrinks it so
/// CI can smoke-run the bench binaries without paying full measurement time.
fn target_secs() -> f64 {
    if fast_mode() {
        0.05
    } else {
        1.0
    }
}

fn warmup_window() -> Duration {
    Duration::from_secs_f64((target_secs() * 0.15).clamp(0.01, 0.15))
}

/// Run `f` repeatedly, returning per-iteration timing. `f` should perform one
/// unit of work and return a value that is black-boxed to prevent DCE.
pub fn bench<T, F: FnMut() -> T>(name: &str, mut f: F) -> BenchResult {
    // warmup + calibration
    let t0 = Instant::now();
    let mut warm_iters = 0u64;
    while t0.elapsed() < warmup_window() {
        std::hint::black_box(f());
        warm_iters += 1;
        if warm_iters > 1_000_000 {
            break;
        }
    }
    let per_iter = t0.elapsed().as_secs_f64() / warm_iters as f64;
    let iters = ((target_secs() / per_iter) as u64).clamp(5, 5_000_000);

    // measure in 5 batches for a std-dev estimate
    let batches = 5u64;
    let per_batch = (iters / batches).max(1);
    let mut batch_ns = Vec::with_capacity(batches as usize);
    for _ in 0..batches {
        let t = Instant::now();
        for _ in 0..per_batch {
            std::hint::black_box(f());
        }
        batch_ns.push(t.elapsed().as_nanos() as f64 / per_batch as f64);
    }
    let mean = batch_ns.iter().sum::<f64>() / batches as f64;
    let var = batch_ns.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / batches as f64;
    let res = BenchResult {
        name: name.to_string(),
        mean_ns: mean,
        sd_ns: var.sqrt(),
        iters: per_batch * batches,
    };
    println!("{}", format_row(&res, None));
    res
}

/// Like [`bench`] but annotates throughput as `elems/s` given elements
/// processed per iteration.
pub fn bench_throughput<T, F: FnMut() -> T>(name: &str, elems: u64, mut f: F) -> BenchResult {
    let res = bench_quiet(name, &mut f);
    println!("{}", format_row(&res, Some(elems)));
    res
}

fn bench_quiet<T, F: FnMut() -> T>(name: &str, f: &mut F) -> BenchResult {
    let t0 = Instant::now();
    let mut warm_iters = 0u64;
    while t0.elapsed() < warmup_window() {
        std::hint::black_box(f());
        warm_iters += 1;
        if warm_iters > 1_000_000 {
            break;
        }
    }
    let per_iter = t0.elapsed().as_secs_f64() / warm_iters as f64;
    let iters = ((target_secs() / per_iter) as u64).clamp(5, 5_000_000);
    let batches = 5u64;
    let per_batch = (iters / batches).max(1);
    let mut batch_ns = Vec::with_capacity(batches as usize);
    for _ in 0..batches {
        let t = Instant::now();
        for _ in 0..per_batch {
            std::hint::black_box(f());
        }
        batch_ns.push(t.elapsed().as_nanos() as f64 / per_batch as f64);
    }
    let mean = batch_ns.iter().sum::<f64>() / batches as f64;
    let var = batch_ns.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / batches as f64;
    BenchResult {
        name: name.to_string(),
        mean_ns: mean,
        sd_ns: var.sqrt(),
        iters: per_batch * batches,
    }
}

fn format_row(r: &BenchResult, elems: Option<u64>) -> String {
    let (t, unit) = human_time(r.mean_ns);
    let (sd, sd_unit) = human_time(r.sd_ns);
    let mut row = format!(
        "{:<52} {:>9.3} {unit} ± {:>6.2} {sd_unit}  ({} iters)",
        r.name, t, sd, r.iters
    );
    if let Some(e) = elems {
        let rate = e as f64 / (r.mean_ns / 1e9);
        row.push_str(&format!("  [{:.2} Melem/s]", rate / 1e6));
    }
    row
}

fn human_time(ns: f64) -> (f64, &'static str) {
    if ns < 1e3 {
        (ns, "ns")
    } else if ns < 1e6 {
        (ns / 1e3, "µs")
    } else if ns < 1e9 {
        (ns / 1e6, "ms")
    } else {
        (ns / 1e9, "s ")
    }
}

/// Print a section header in bench output.
pub fn section(title: &str) {
    println!("\n=== {title} ===");
}

/// Check one bench report against the committed perf floors
/// (`rust/tests/bench_baseline.json`) and fail on regression.
///
/// The baseline holds a `floors` array; each entry names the `bench`
/// section it applies to, a numeric `field` with its `min` floor, and any
/// number of extra string keys that select matching rows in the report's
/// `results` array (a row matches when every selector key equals the
/// row's same-named string value). Every matching row must clear the
/// floor, and at least one row must match — a renamed row must fail the
/// gate, not silently skip it. Entries with `"requires_simd": true` are
/// skipped when the report's top-level `simd` flag is false (ISA-speedup
/// floors are meaningless on machines without the vector path). Floors
/// are intentionally generous: the gate catches collapses (a lost fast
/// path, an accidental serial fallback), not noise.
pub fn check_against_baseline(report: &Value, bench_name: &str) -> anyhow::Result<()> {
    let path = crate::util::repo_root().join("rust/tests/bench_baseline.json");
    let text = std::fs::read_to_string(&path)
        .map_err(|e| anyhow::anyhow!("reading {}: {e}", path.display()))?;
    let baseline = crate::util::json::parse(&text)?;
    let simd_on = report.get("simd").and_then(|v| v.as_bool()).unwrap_or(false);
    let rows = report.req("results")?.as_arr().unwrap_or_default().to_vec();
    let mut checked = 0usize;
    for floor in baseline.req("floors")?.as_arr().unwrap_or_default() {
        // malformed entries must fail the gate, not silently disable it
        let bench = floor
            .req("bench")?
            .as_str()
            .ok_or_else(|| anyhow::anyhow!("baseline: non-string bench in {}", floor.to_json()))?
            .to_string();
        if bench != bench_name {
            continue;
        }
        let requires_simd = floor
            .get("requires_simd")
            .and_then(|v| v.as_bool())
            .unwrap_or(false);
        if requires_simd && !simd_on {
            println!("baseline: skipping {} (no SIMD on this host)", floor.to_json());
            continue;
        }
        let field = floor
            .req("field")?
            .as_str()
            .ok_or_else(|| anyhow::anyhow!("baseline: non-string field in {}", floor.to_json()))?
            .to_string();
        let min = floor
            .req("min")?
            .as_f64()
            .ok_or_else(|| anyhow::anyhow!("baseline: non-numeric min in {}", floor.to_json()))?;
        let selectors: Vec<(String, String)> = floor
            .as_obj()
            .unwrap_or_default()
            .iter()
            .filter(|(k, v)| {
                !matches!(k.as_str(), "bench" | "field" | "min" | "requires_simd" | "comment")
                    && v.as_str().is_some()
            })
            .map(|(k, v)| (k.clone(), v.as_str().unwrap_or_default().to_string()))
            .collect();
        let mut matched = 0usize;
        for row in &rows {
            let hit = selectors
                .iter()
                .all(|(k, want)| row.get(k).and_then(|v| v.as_str()) == Some(want.as_str()));
            if !hit {
                continue;
            }
            matched += 1;
            let got = row.get(&field).and_then(|v| v.as_f64()).ok_or_else(|| {
                anyhow::anyhow!("baseline: row {} lacks field {field:?}", row.to_json())
            })?;
            if got < min {
                anyhow::bail!(
                    "perf regression: {bench_name} row {} has {field} = {got:.3} \
                     below the committed floor {min:.3}",
                    row.to_json()
                );
            }
        }
        if matched == 0 {
            anyhow::bail!(
                "baseline floor {} matched no rows in {bench_name} — renamed row?",
                floor.to_json()
            );
        }
        checked += matched;
    }
    // a bench with no floors at all means the gate is mis-keyed (bench
    // renamed, baseline typo) — that must fail, not silently stop gating
    if checked == 0 {
        anyhow::bail!("baseline has no floors for bench {bench_name:?} — gate mis-keyed?");
    }
    println!("baseline gate: {checked} {bench_name} rows at or above their committed floors");
    Ok(())
}

/// Write bench results as JSONL for the report generator.
pub fn write_jsonl(path: &std::path::Path, rows: &[BenchResult]) -> anyhow::Result<()> {
    use std::io::Write;
    super::ensure_parent(path)?;
    let mut f = std::fs::File::create(path)?;
    for r in rows {
        writeln!(
            f,
            "{{\"name\":\"{}\",\"mean_ns\":{},\"sd_ns\":{},\"iters\":{}}}",
            r.name, r.mean_ns, r.sd_ns, r.iters
        )?;
    }
    Ok(())
}
