//! Custom micro-benchmark harness (criterion is unavailable offline).
//!
//! Bench binaries (`cargo bench`) call [`bench`] per case; it warms up,
//! auto-scales iteration count to a target measurement time, and prints
//! criterion-style `name  time ± sd  (throughput)` rows plus a
//! machine-readable JSONL file under `runs/bench/`.

use std::time::{Duration, Instant};

pub struct BenchResult {
    pub name: String,
    pub mean_ns: f64,
    pub sd_ns: f64,
    pub iters: u64,
}

impl BenchResult {
    pub fn mean_secs(&self) -> f64 {
        self.mean_ns / 1e9
    }

    /// Achieved GFLOP/s given the FLOPs one iteration performs.
    pub fn gflops(&self, flops: u64) -> f64 {
        flops as f64 / self.mean_secs() / 1e9
    }
}

/// Whether `QPRETRAIN_BENCH_FAST` is set (CI smoke mode): the single
/// definition of the fast-mode predicate — bench binaries that also shrink
/// their own workloads (step counts, reps) must consult this, not re-parse
/// the variable.
pub fn fast_mode() -> bool {
    static CACHE: std::sync::OnceLock<bool> = std::sync::OnceLock::new();
    *CACHE.get_or_init(|| {
        matches!(std::env::var("QPRETRAIN_BENCH_FAST"), Ok(v) if !v.is_empty() && v != "0")
    })
}

/// Seconds of measurement per case. `QPRETRAIN_BENCH_FAST=1` shrinks it so
/// CI can smoke-run the bench binaries without paying full measurement time.
fn target_secs() -> f64 {
    if fast_mode() {
        0.05
    } else {
        1.0
    }
}

fn warmup_window() -> Duration {
    Duration::from_secs_f64((target_secs() * 0.15).clamp(0.01, 0.15))
}

/// Run `f` repeatedly, returning per-iteration timing. `f` should perform one
/// unit of work and return a value that is black-boxed to prevent DCE.
pub fn bench<T, F: FnMut() -> T>(name: &str, mut f: F) -> BenchResult {
    // warmup + calibration
    let t0 = Instant::now();
    let mut warm_iters = 0u64;
    while t0.elapsed() < warmup_window() {
        std::hint::black_box(f());
        warm_iters += 1;
        if warm_iters > 1_000_000 {
            break;
        }
    }
    let per_iter = t0.elapsed().as_secs_f64() / warm_iters as f64;
    let iters = ((target_secs() / per_iter) as u64).clamp(5, 5_000_000);

    // measure in 5 batches for a std-dev estimate
    let batches = 5u64;
    let per_batch = (iters / batches).max(1);
    let mut batch_ns = Vec::with_capacity(batches as usize);
    for _ in 0..batches {
        let t = Instant::now();
        for _ in 0..per_batch {
            std::hint::black_box(f());
        }
        batch_ns.push(t.elapsed().as_nanos() as f64 / per_batch as f64);
    }
    let mean = batch_ns.iter().sum::<f64>() / batches as f64;
    let var = batch_ns.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / batches as f64;
    let res = BenchResult {
        name: name.to_string(),
        mean_ns: mean,
        sd_ns: var.sqrt(),
        iters: per_batch * batches,
    };
    println!("{}", format_row(&res, None));
    res
}

/// Like [`bench`] but annotates throughput as `elems/s` given elements
/// processed per iteration.
pub fn bench_throughput<T, F: FnMut() -> T>(name: &str, elems: u64, mut f: F) -> BenchResult {
    let res = bench_quiet(name, &mut f);
    println!("{}", format_row(&res, Some(elems)));
    res
}

fn bench_quiet<T, F: FnMut() -> T>(name: &str, f: &mut F) -> BenchResult {
    let t0 = Instant::now();
    let mut warm_iters = 0u64;
    while t0.elapsed() < warmup_window() {
        std::hint::black_box(f());
        warm_iters += 1;
        if warm_iters > 1_000_000 {
            break;
        }
    }
    let per_iter = t0.elapsed().as_secs_f64() / warm_iters as f64;
    let iters = ((target_secs() / per_iter) as u64).clamp(5, 5_000_000);
    let batches = 5u64;
    let per_batch = (iters / batches).max(1);
    let mut batch_ns = Vec::with_capacity(batches as usize);
    for _ in 0..batches {
        let t = Instant::now();
        for _ in 0..per_batch {
            std::hint::black_box(f());
        }
        batch_ns.push(t.elapsed().as_nanos() as f64 / per_batch as f64);
    }
    let mean = batch_ns.iter().sum::<f64>() / batches as f64;
    let var = batch_ns.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / batches as f64;
    BenchResult {
        name: name.to_string(),
        mean_ns: mean,
        sd_ns: var.sqrt(),
        iters: per_batch * batches,
    }
}

fn format_row(r: &BenchResult, elems: Option<u64>) -> String {
    let (t, unit) = human_time(r.mean_ns);
    let (sd, sd_unit) = human_time(r.sd_ns);
    let mut row = format!(
        "{:<52} {:>9.3} {unit} ± {:>6.2} {sd_unit}  ({} iters)",
        r.name, t, sd, r.iters
    );
    if let Some(e) = elems {
        let rate = e as f64 / (r.mean_ns / 1e9);
        row.push_str(&format!("  [{:.2} Melem/s]", rate / 1e6));
    }
    row
}

fn human_time(ns: f64) -> (f64, &'static str) {
    if ns < 1e3 {
        (ns, "ns")
    } else if ns < 1e6 {
        (ns / 1e3, "µs")
    } else if ns < 1e9 {
        (ns / 1e6, "ms")
    } else {
        (ns / 1e9, "s ")
    }
}

/// Print a section header in bench output.
pub fn section(title: &str) {
    println!("\n=== {title} ===");
}

/// Write bench results as JSONL for the report generator.
pub fn write_jsonl(path: &std::path::Path, rows: &[BenchResult]) -> anyhow::Result<()> {
    use std::io::Write;
    super::ensure_parent(path)?;
    let mut f = std::fs::File::create(path)?;
    for r in rows {
        writeln!(
            f,
            "{{\"name\":\"{}\",\"mean_ns\":{},\"sd_ns\":{},\"iters\":{}}}",
            r.name, r.mean_ns, r.sd_ns, r.iters
        )?;
    }
    Ok(())
}
