//! Hand-rolled CLI argument parser (clap is unavailable offline).
//!
//! Grammar: `qpretrain <subcommand> [--key value | --flag] [positional...]`.

use std::collections::BTreeMap;

use anyhow::{anyhow, bail, Result};

#[derive(Debug, Clone, Default)]
pub struct Args {
    pub subcommand: String,
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
}

impl Args {
    /// Parse from an iterator of argument strings (without argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Result<Args> {
        let mut out = Args::default();
        let mut it = argv.into_iter().peekable();
        if let Some(first) = it.peek() {
            if !first.starts_with("--") {
                out.subcommand = it.next().unwrap();
            }
        }
        while let Some(a) = it.next() {
            if let Some(key) = a.strip_prefix("--") {
                if key.is_empty() {
                    bail!("bare `--` is not supported");
                }
                if let Some((k, v)) = key.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if it
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = it.next().unwrap();
                    out.options.insert(key.to_string(), v);
                } else {
                    out.flags.push(key.to_string());
                }
            } else {
                out.positional.push(a);
            }
        }
        Ok(out)
    }

    pub fn from_env() -> Result<Args> {
        Args::parse(std::env::args().skip(1))
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(|s| s.as_str())
    }

    pub fn req(&self, key: &str) -> Result<&str> {
        self.get(key).ok_or_else(|| anyhow!("missing --{key}"))
    }

    pub fn get_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    pub fn usize_or(&self, key: &str, default: usize) -> Result<usize> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| anyhow!("--{key} expects an integer, got {v:?}")),
        }
    }

    pub fn u64_or(&self, key: &str, default: u64) -> Result<u64> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| anyhow!("--{key} expects an integer, got {v:?}")),
        }
    }

    pub fn f64_or(&self, key: &str, default: f64) -> Result<f64> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| anyhow!("--{key} expects a number, got {v:?}")),
        }
    }

    /// A bit-width option: 0 (= no override / disabled) or 2..=32. Parsed
    /// through u64 and range-checked *before* any narrowing, so an
    /// out-of-range value is a CLI error — never a silent `as u32`
    /// truncation (4294967297 must not become 1).
    pub fn bits_or(&self, key: &str, default: u32) -> Result<u32> {
        let Some(v) = self.get(key) else {
            return Ok(default);
        };
        let bits: u64 = v
            .parse()
            .map_err(|_| anyhow!("--{key} expects an integer, got {v:?}"))?;
        match bits {
            0 | 2..=32 => Ok(bits as u32),
            _ => bail!("--{key} expects a bit width of 0 (off) or 2..=32, got {bits}"),
        }
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &str) -> Vec<String> {
        s.split_whitespace().map(String::from).collect()
    }

    #[test]
    fn parses_subcommand_options_flags() {
        // note: a bare token after `--flag` is consumed as its value, so
        // positionals go before flags (documented grammar)
        let a = Args::parse(argv("train --model t4 --steps 300 pos1 --verbose")).unwrap();
        assert_eq!(a.subcommand, "train");
        assert_eq!(a.get("model"), Some("t4"));
        assert_eq!(a.usize_or("steps", 0).unwrap(), 300);
        assert!(a.flag("verbose"));
        assert_eq!(a.positional, vec!["pos1"]);
    }

    #[test]
    fn equals_form() {
        let a = Args::parse(argv("x --k=v --n=3")).unwrap();
        assert_eq!(a.get("k"), Some("v"));
        assert_eq!(a.usize_or("n", 0).unwrap(), 3);
    }

    #[test]
    fn trailing_flag() {
        let a = Args::parse(argv("x --quiet")).unwrap();
        assert!(a.flag("quiet"));
    }

    #[test]
    fn numeric_errors() {
        let a = Args::parse(argv("x --n abc")).unwrap();
        assert!(a.usize_or("n", 0).is_err());
    }

    #[test]
    fn bits_range_checked_before_narrowing() {
        // regression: these used to flow through `usize_or(..)? as u32`,
        // so 2^32+1 silently truncated to a *valid* width of 1 (and
        // 2^32+2 to 2) instead of erroring
        for v in ["4294967297", "4294967298", "1", "33", "64", "-8", "8.5"] {
            let a = Args::parse(argv(&format!("x --wbits {v}"))).unwrap();
            assert!(a.bits_or("wbits", 0).is_err(), "--wbits {v} must be rejected");
        }
        for (v, want) in [("0", 0u32), ("2", 2), ("8", 8), ("32", 32)] {
            let a = Args::parse(argv(&format!("x --wbits {v}"))).unwrap();
            assert_eq!(a.bits_or("wbits", 0).unwrap(), want);
        }
        assert_eq!(Args::parse(argv("x")).unwrap().bits_or("wbits", 0).unwrap(), 0);
    }
}
