//! Minimal JSON parser + writer (serde is unavailable offline).
//!
//! Supports the full JSON grammar; numbers are `f64`. Object key order is
//! preserved (the artifact manifest relies on input-signature order).

use std::collections::BTreeMap;
use std::fmt::Write as _;

use anyhow::{anyhow, bail, Result};

#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Value>),
    Obj(Vec<(String, Value)>),
}

impl Value {
    pub fn get(&self, key: &str) -> Option<&Value> {
        match self {
            Value::Obj(kvs) => kvs.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    pub fn req(&self, key: &str) -> Result<&Value> {
        self.get(key).ok_or_else(|| anyhow!("missing key {key:?}"))
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Value]> {
        match self {
            Value::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&[(String, Value)]> {
        match self {
            Value::Obj(o) => Some(o),
            _ => None,
        }
    }

    pub fn is_null(&self) -> bool {
        matches!(self, Value::Null)
    }

    /// Serialize to a compact JSON string.
    pub fn to_json(&self) -> String {
        let mut s = String::new();
        self.write_to(&mut s);
        s
    }

    fn write_to(&self, out: &mut String) {
        match self {
            Value::Null => out.push_str("null"),
            Value::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Value::Num(n) => {
                if n.is_finite() {
                    if *n == n.trunc() && n.abs() < 1e15 {
                        let _ = write!(out, "{}", *n as i64);
                    } else {
                        let _ = write!(out, "{}", n);
                    }
                } else {
                    out.push_str("null"); // JSON has no NaN/Inf
                }
            }
            Value::Str(s) => write_escaped(s, out),
            Value::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write_to(out);
                }
                out.push(']');
            }
            Value::Obj(o) => {
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write_to(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Convenience constructors for building JSON output.
pub fn obj(kvs: Vec<(&str, Value)>) -> Value {
    Value::Obj(kvs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

pub fn num(n: f64) -> Value {
    Value::Num(n)
}

pub fn s(v: &str) -> Value {
    Value::Str(v.to_string())
}

pub fn arr_f64(v: &[f64]) -> Value {
    Value::Arr(v.iter().map(|x| Value::Num(*x)).collect())
}

pub fn parse(input: &str) -> Result<Value> {
    let mut p = Parser {
        bytes: input.as_bytes(),
        pos: 0,
        depth: 0,
    };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        bail!("trailing characters at byte {}", p.pos);
    }
    Ok(v)
}

/// Recursion ceiling for nested containers. The parser is recursive-descent,
/// so a pathological `[[[[…` input would otherwise overflow the stack
/// (abort, not `Err`) — found by the byte-mutation fuzz loop in
/// `tests/fuzz.rs`. Real run-dir artifacts nest a handful of levels.
const MAX_DEPTH: usize = 128;

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
    depth: usize,
}

impl<'a> Parser<'a> {
    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            bail!(
                "expected {:?} at byte {} (found {:?})",
                b as char,
                self.pos,
                self.peek().map(|c| c as char)
            )
        }
    }

    fn value(&mut self) -> Result<Value> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.nested(Parser::object),
            Some(b'[') => self.nested(Parser::array),
            Some(b'"') => Ok(Value::Str(self.string()?)),
            Some(b't') => self.lit("true", Value::Bool(true)),
            Some(b'f') => self.lit("false", Value::Bool(false)),
            Some(b'n') => self.lit("null", Value::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => bail!("unexpected {:?} at byte {}", other.map(|c| c as char), self.pos),
        }
    }

    fn nested(&mut self, inner: fn(&mut Parser<'a>) -> Result<Value>) -> Result<Value> {
        if self.depth >= MAX_DEPTH {
            bail!("nesting deeper than {MAX_DEPTH} at byte {}", self.pos);
        }
        self.depth += 1;
        let v = inner(self);
        self.depth -= 1;
        v
    }

    fn lit(&mut self, word: &str, v: Value) -> Result<Value> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            bail!("invalid literal at byte {}", self.pos)
        }
    }

    fn number(&mut self) -> Result<Value> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.pos += 1;
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])?;
        Ok(Value::Num(text.parse::<f64>()?))
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => bail!("unterminated string"),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            let hex = std::str::from_utf8(
                                self.bytes
                                    .get(self.pos + 1..self.pos + 5)
                                    .ok_or_else(|| anyhow!("bad \\u escape"))?,
                            )?;
                            let cp = u32::from_str_radix(hex, 16)?;
                            out.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            self.pos += 4;
                        }
                        other => bail!("bad escape {:?}", other.map(|c| c as char)),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // consume one UTF-8 scalar
                    let rest = std::str::from_utf8(&self.bytes[self.pos..])?;
                    let c = rest.chars().next().unwrap();
                    out.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Value> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Value::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Value::Arr(items));
                }
                other => bail!("expected , or ] (found {:?})", other.map(|c| c as char)),
            }
        }
    }

    fn object(&mut self) -> Result<Value> {
        self.expect(b'{')?;
        let mut kvs = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Value::Obj(kvs));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            kvs.push((key, val));
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Value::Obj(kvs));
                }
                other => bail!("expected , or }} (found {:?})", other.map(|c| c as char)),
            }
        }
    }
}

/// Parse a JSONL metrics file into a vec of objects.
pub fn parse_jsonl(text: &str) -> Result<Vec<Value>> {
    text.lines()
        .filter(|l| !l.trim().is_empty())
        .map(parse)
        .collect()
}

/// Group a flat object list by string key (helper for report generation).
pub fn group_by<'a>(rows: &'a [Value], key: &str) -> BTreeMap<String, Vec<&'a Value>> {
    let mut out: BTreeMap<String, Vec<&Value>> = BTreeMap::new();
    for r in rows {
        if let Some(v) = r.get(key).and_then(|v| v.as_str()) {
            out.entry(v.to_string()).or_default().push(r);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let src = r#"{"a": [1, 2.5, -3e2], "b": {"c": null, "d": true}, "e": "x\ny"}"#;
        let v = parse(src).unwrap();
        let re = parse(&v.to_json()).unwrap();
        assert_eq!(v, re);
        assert_eq!(v.get("a").unwrap().as_arr().unwrap()[2].as_f64(), Some(-300.0));
        assert!(v.get("b").unwrap().get("c").unwrap().is_null());
    }

    #[test]
    fn escapes() {
        let v = parse(r#""aA\t\"b\"""#).unwrap();
        assert_eq!(v.as_str(), Some("aA\t\"b\""));
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{,}").is_err());
        assert!(parse("[1 2]").is_err());
        assert!(parse("{\"a\": }").is_err());
        assert!(parse("123abc").is_err());
    }

    #[test]
    fn deep_nesting_errors_instead_of_overflowing() {
        // 1<<16 opens would blow the thread stack without the depth ceiling
        let deep = "[".repeat(1 << 16);
        assert!(parse(&deep).is_err());
        let deep_obj = "{\"k\":".repeat(1 << 16);
        assert!(parse(&deep_obj).is_err());
        // ... while reasonable nesting still parses
        let ok = format!("{}1{}", "[".repeat(64), "]".repeat(64));
        assert!(parse(&ok).is_ok());
    }

    #[test]
    fn preserves_key_order() {
        let v = parse(r#"{"z": 1, "a": 2, "m": 3}"#).unwrap();
        let keys: Vec<_> = v.as_obj().unwrap().iter().map(|(k, _)| k.as_str()).collect();
        assert_eq!(keys, vec!["z", "a", "m"]);
    }
}
