//! Streaming statistics, histograms and tensor summaries used by the
//! analysis modules (activation outliers, gradient sparsity, Adam zero-bin).

/// Basic summary of a slice.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub max: f64,
    pub abs_max: f64,
}

pub fn summarize(xs: &[f32]) -> Summary {
    if xs.is_empty() {
        return Summary {
            n: 0,
            mean: 0.0,
            std: 0.0,
            min: 0.0,
            max: 0.0,
            abs_max: 0.0,
        };
    }
    let n = xs.len();
    let mut sum = 0.0f64;
    let mut min = f64::INFINITY;
    let mut max = f64::NEG_INFINITY;
    for &x in xs {
        let x = x as f64;
        sum += x;
        min = min.min(x);
        max = max.max(x);
    }
    let mean = sum / n as f64;
    let var = xs
        .iter()
        .map(|&x| {
            let d = x as f64 - mean;
            d * d
        })
        .sum::<f64>()
        / n as f64;
    Summary {
        n,
        mean,
        std: var.sqrt(),
        min,
        max,
        abs_max: min.abs().max(max.abs()),
    }
}

/// Quantile of a slice (copies + sorts; fine at analysis sizes).
pub fn quantile(xs: &[f32], q: f64) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    let mut v: Vec<f32> = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap_or(std::cmp::Ordering::Equal));
    let idx = ((v.len() - 1) as f64 * q).round() as usize;
    v[idx] as f64
}

/// Excess kurtosis — the paper's outlier phenomena show up as heavy tails.
pub fn kurtosis(xs: &[f32]) -> f64 {
    let s = summarize(xs);
    if s.n == 0 || s.std == 0.0 {
        return 0.0;
    }
    let m4 = xs
        .iter()
        .map(|&x| {
            let d = (x as f64 - s.mean) / s.std;
            d.powi(4)
        })
        .sum::<f64>()
        / s.n as f64;
    m4 - 3.0
}

/// Fraction of entries with |x| <= eps * max|x| (gradient sparsity, Fig. 10).
pub fn sparsity(xs: &[f32], rel_eps: f64) -> f64 {
    let s = summarize(xs);
    if s.n == 0 || s.abs_max == 0.0 {
        return 1.0;
    }
    let thr = rel_eps * s.abs_max;
    xs.iter().filter(|&&x| (x as f64).abs() <= thr).count() as f64 / s.n as f64
}

/// Fixed-bin histogram over [lo, hi]; out-of-range values clamp to end bins.
#[derive(Debug, Clone)]
pub struct Histogram {
    pub lo: f64,
    pub hi: f64,
    pub counts: Vec<u64>,
}

impl Histogram {
    pub fn new(lo: f64, hi: f64, bins: usize) -> Self {
        assert!(bins > 0 && hi > lo);
        Histogram {
            lo,
            hi,
            counts: vec![0; bins],
        }
    }

    pub fn add(&mut self, x: f64) {
        let bins = self.counts.len();
        let t = (x - self.lo) / (self.hi - self.lo);
        let idx = ((t * bins as f64) as isize).clamp(0, bins as isize - 1) as usize;
        self.counts[idx] += 1;
    }

    pub fn add_all(&mut self, xs: &[f32]) {
        for &x in xs {
            self.add(x as f64);
        }
    }

    pub fn total(&self) -> u64 {
        self.counts.iter().sum()
    }

    /// Fraction of mass in the bin containing `x`.
    pub fn frac_at(&self, x: f64) -> f64 {
        let bins = self.counts.len();
        let t = (x - self.lo) / (self.hi - self.lo);
        let idx = ((t * bins as f64) as isize).clamp(0, bins as isize - 1) as usize;
        self.counts[idx] as f64 / self.total().max(1) as f64
    }

    /// Render as `bin_center,count` CSV lines.
    pub fn to_csv(&self) -> String {
        let bins = self.counts.len();
        let w = (self.hi - self.lo) / bins as f64;
        let mut out = String::from("bin_center,count\n");
        for (i, c) in self.counts.iter().enumerate() {
            out.push_str(&format!("{},{}\n", self.lo + (i as f64 + 0.5) * w, c));
        }
        out
    }
}

/// Per-channel abs-max over a row-major (rows, cols) matrix — the statistic
/// the paper tracks over training to show persistent outlier channels (Fig 6).
pub fn channel_abs_max(data: &[f32], rows: usize, cols: usize) -> Vec<f32> {
    assert_eq!(data.len(), rows * cols);
    let mut out = vec![0.0f32; cols];
    for r in 0..rows {
        let row = &data[r * cols..(r + 1) * cols];
        for (c, &x) in row.iter().enumerate() {
            let a = x.abs();
            if a > out[c] {
                out[c] = a;
            }
        }
    }
    out
}

/// Exponential moving average (loss-spike detection).
#[derive(Debug, Clone, Copy)]
pub struct Ema {
    pub alpha: f64,
    pub value: Option<f64>,
}

impl Ema {
    pub fn new(alpha: f64) -> Self {
        Ema { alpha, value: None }
    }

    pub fn update(&mut self, x: f64) -> f64 {
        let v = match self.value {
            None => x,
            Some(v) => v + self.alpha * (x - v),
        };
        self.value = Some(v);
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basics() {
        let s = summarize(&[1.0, 2.0, 3.0, -4.0]);
        assert_eq!(s.n, 4);
        assert!((s.mean - 0.5).abs() < 1e-9);
        assert_eq!(s.min, -4.0);
        assert_eq!(s.max, 3.0);
        assert_eq!(s.abs_max, 4.0);
    }

    #[test]
    fn quantiles() {
        let xs: Vec<f32> = (0..101).map(|i| i as f32).collect();
        assert_eq!(quantile(&xs, 0.0), 0.0);
        assert_eq!(quantile(&xs, 1.0), 100.0);
        assert_eq!(quantile(&xs, 0.5), 50.0);
    }

    #[test]
    fn histogram_clamps_and_counts() {
        let mut h = Histogram::new(-1.0, 1.0, 4);
        h.add_all(&[-5.0, -0.9, -0.1, 0.1, 0.9, 5.0]);
        assert_eq!(h.total(), 6);
        assert_eq!(h.counts[0], 2); // -5 clamped + -0.9
        assert_eq!(h.counts[3], 2); // 0.9 + 5 clamped
    }

    #[test]
    fn sparsity_detects_spiky_grads() {
        let mut xs = vec![1e-6f32; 1000];
        xs[0] = 1.0;
        assert!(sparsity(&xs, 1e-3) > 0.99);
        let dense: Vec<f32> = (0..1000).map(|i| (i as f32 + 1.0) / 1000.0).collect();
        assert!(sparsity(&dense, 1e-3) < 0.01);
    }

    #[test]
    fn channel_max() {
        // 2x3: channels are columns
        let m = channel_abs_max(&[1.0, -5.0, 2.0, -3.0, 4.0, 0.5], 2, 3);
        assert_eq!(m, vec![3.0, 5.0, 2.0]);
    }

    #[test]
    fn kurtosis_heavy_tail() {
        let normalish: Vec<f32> = (0..1000).map(|i| ((i % 7) as f32 - 3.0) / 3.0).collect();
        let mut heavy = vec![0.01f32; 1000];
        heavy[3] = 10.0;
        assert!(kurtosis(&heavy) > kurtosis(&normalish) + 10.0);
    }
}
