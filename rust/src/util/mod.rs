//! Substrate utilities written in-repo because the offline crate set has no
//! serde/clap/criterion/proptest: a JSON parser, a deterministic PRNG,
//! streaming statistics, a CLI argument parser, a property-testing harness,
//! and an `.npy` reader/writer for cross-language golden files.

pub mod bench;
pub mod cli;
pub mod json;
pub mod npy;
pub mod quickcheck;
pub mod rng;
pub mod stats;

use std::path::{Path, PathBuf};

/// Locate the repository root by walking up from the current directory until
/// a directory containing `Cargo.toml` + `artifacts` or `python` is found.
pub fn repo_root() -> PathBuf {
    let mut dir = std::env::current_dir().unwrap_or_else(|_| PathBuf::from("."));
    loop {
        if dir.join("Cargo.toml").exists() && dir.join("python").exists() {
            return dir;
        }
        if !dir.pop() {
            return PathBuf::from(".");
        }
    }
}

/// `repo_root()/artifacts`, overridable with `QPRETRAIN_ARTIFACTS`.
pub fn artifact_dir() -> PathBuf {
    if let Ok(p) = std::env::var("QPRETRAIN_ARTIFACTS") {
        return PathBuf::from(p);
    }
    repo_root().join(crate::ARTIFACT_DIR)
}

/// Create all parent directories of `path`.
pub fn ensure_parent(path: &Path) -> std::io::Result<()> {
    if let Some(p) = path.parent() {
        std::fs::create_dir_all(p)?;
    }
    Ok(())
}
