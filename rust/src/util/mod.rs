//! Substrate utilities written in-repo because the offline crate set has no
//! serde/clap/criterion/proptest: a JSON parser, a deterministic PRNG,
//! streaming statistics, a CLI argument parser, a property-testing harness,
//! and an `.npy` reader/writer for cross-language golden files.

pub mod bench;
pub mod cli;
pub mod json;
pub mod net;
pub mod npy;
pub mod quickcheck;
pub mod rng;
pub mod stats;

use std::path::{Path, PathBuf};

/// Locate the repository root by walking up from the current directory until
/// a directory containing `Cargo.toml` + `artifacts` or `python` is found.
pub fn repo_root() -> PathBuf {
    let mut dir = std::env::current_dir().unwrap_or_else(|_| PathBuf::from("."));
    loop {
        if dir.join("Cargo.toml").exists() && dir.join("python").exists() {
            return dir;
        }
        if !dir.pop() {
            return PathBuf::from(".");
        }
    }
}

/// `repo_root()/artifacts`, overridable with `QPRETRAIN_ARTIFACTS`.
pub fn artifact_dir() -> PathBuf {
    if let Ok(p) = std::env::var("QPRETRAIN_ARTIFACTS") {
        return PathBuf::from(p);
    }
    repo_root().join(crate::ARTIFACT_DIR)
}

/// Create all parent directories of `path`.
pub fn ensure_parent(path: &Path) -> std::io::Result<()> {
    if let Some(p) = path.parent() {
        std::fs::create_dir_all(p)?;
    }
    Ok(())
}

const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;

#[inline]
fn fnv1a64_step(h: u64, byte: u8) -> u64 {
    (h ^ byte as u64).wrapping_mul(FNV_PRIME)
}

/// FNV-1a 64-bit hash (the offline crate set has no hashing crate). Used by
/// the `digest` subcommand to fingerprint parameter/moment tensors so CI
/// can diff train-run digests across matrix legs without shipping the full
/// state.
pub fn fnv1a64(bytes: &[u8]) -> u64 {
    bytes.iter().fold(FNV_OFFSET, |h, &b| fnv1a64_step(h, b))
}

/// [`fnv1a64`] over the little-endian bit patterns of an f32 tensor (the
/// exact bits, so the digest detects sign-of-zero and last-ulp drift).
pub fn fnv1a64_f32(values: &[f32]) -> u64 {
    values.iter().fold(FNV_OFFSET, |h, v| {
        v.to_bits().to_le_bytes().iter().fold(h, |h, &b| fnv1a64_step(h, b))
    })
}
