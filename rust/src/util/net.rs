//! Socket-address parsing for the dist socket transport (`--listen` /
//! `--connect`). The offline crate set has no url/clap helpers, so this is
//! a thin, loudly-erroring wrapper over `std::net`.

use std::net::{SocketAddr, ToSocketAddrs};

use anyhow::{bail, Context, Result};

/// Parse a `host:port` string into a [`SocketAddr`].
///
/// Accepted spellings:
/// - `127.0.0.1:9000`, `[::1]:9000` — literal IP + port (no resolution);
/// - `:9000` — shorthand for `127.0.0.1:9000` (loopback, the single-machine
///   dist default);
/// - `somehost:9000` — resolved through the system resolver (`/etc/hosts`
///   works offline); the first resolved address wins.
///
/// A missing or non-numeric port is a loud error — the dist transport never
/// guesses a port (rank 0 binds `127.0.0.1:0` to *ask the OS* for one, which
/// is different from the user omitting it).
pub fn parse_addr(spec: &str) -> Result<SocketAddr> {
    let spec = spec.trim();
    if spec.is_empty() {
        bail!("empty socket address (expected host:port)");
    }
    let full = if spec.starts_with(':') && spec[1..].bytes().all(|b| b.is_ascii_digit()) {
        format!("127.0.0.1{spec}")
    } else {
        spec.to_string()
    };
    // Literal ip:port first: no resolver involved, exact error messages.
    if let Ok(addr) = full.parse::<SocketAddr>() {
        return Ok(addr);
    }
    let Some((host, port)) = full.rsplit_once(':') else {
        bail!("socket address {spec:?} has no port (expected host:port)");
    };
    if host.is_empty() || port.is_empty() || !port.bytes().all(|b| b.is_ascii_digit()) {
        bail!("socket address {spec:?} is malformed (expected host:port with a numeric port)");
    }
    full.to_socket_addrs()
        .with_context(|| format!("resolving socket address {spec:?}"))?
        .next()
        .with_context(|| format!("socket address {spec:?} resolved to no addresses"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_v4_and_v6_parse() {
        assert_eq!(parse_addr("127.0.0.1:9000").unwrap(), "127.0.0.1:9000".parse().unwrap());
        assert_eq!(parse_addr(" 10.0.0.2:1 ").unwrap(), "10.0.0.2:1".parse().unwrap());
        assert_eq!(parse_addr("[::1]:4000").unwrap(), "[::1]:4000".parse().unwrap());
    }

    #[test]
    fn bare_port_defaults_to_loopback() {
        assert_eq!(parse_addr(":9000").unwrap(), "127.0.0.1:9000".parse().unwrap());
    }

    #[test]
    fn port_zero_is_legal_for_os_assignment() {
        assert_eq!(parse_addr("127.0.0.1:0").unwrap().port(), 0);
    }

    #[test]
    fn hostnames_resolve() {
        // /etc/hosts carries localhost even offline.
        let addr = parse_addr("localhost:8125").unwrap();
        assert_eq!(addr.port(), 8125);
        assert!(addr.ip().is_loopback());
    }

    #[test]
    fn malformed_specs_are_loud_errors() {
        for bad in ["", "   ", "127.0.0.1", "host", "host:", ":", "host:port", "1.2.3.4:99x"] {
            let err = parse_addr(bad).unwrap_err().to_string();
            assert!(
                err.contains("socket address") || err.contains("empty socket address"),
                "bad spec {bad:?} gave unexpected error {err:?}"
            );
        }
    }
}
