//! Minimal `.npy` (NumPy format v1.0) reader/writer for f32 C-order arrays.
//!
//! Used for the cross-language golden files emitted by `python -m
//! compile.aot` and for exporting analysis tensors.

use std::io::{Read, Write};
use std::path::Path;

use anyhow::{anyhow, bail, Result};

const MAGIC: &[u8] = b"\x93NUMPY";

pub struct NpyArray {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl NpyArray {
    pub fn rows_cols(&self) -> Result<(usize, usize)> {
        match self.shape.as_slice() {
            [r, c] => Ok((*r, *c)),
            s => bail!("expected 2-D array, got shape {s:?}"),
        }
    }
}

pub fn read_f32<P: AsRef<Path>>(path: P) -> Result<NpyArray> {
    let mut f = std::fs::File::open(path.as_ref())
        .map_err(|e| anyhow!("open {:?}: {e}", path.as_ref()))?;
    let mut buf = Vec::new();
    f.read_to_end(&mut buf)?;
    parse_f32(&buf)
}

pub fn parse_f32(buf: &[u8]) -> Result<NpyArray> {
    if buf.len() < 10 || &buf[..6] != MAGIC {
        bail!("not an npy file");
    }
    let (major, _minor) = (buf[6], buf[7]);
    let (hdr_len, hdr_start) = if major == 1 {
        // the >= 10 check above already covers the two u16 length bytes
        (u16::from_le_bytes([buf[8], buf[9]]) as usize, 10)
    } else {
        // v2.0+ uses a u32 header length: four bytes at offset 8, so a
        // 10- or 11-byte file must error, not index out of bounds
        if buf.len() < 12 {
            bail!("truncated npy v{major} header: {} bytes", buf.len());
        }
        (
            u32::from_le_bytes([buf[8], buf[9], buf[10], buf[11]]) as usize,
            12,
        )
    };
    let hdr_end = hdr_start
        .checked_add(hdr_len)
        .filter(|&e| e <= buf.len())
        .ok_or_else(|| {
            anyhow!(
                "truncated npy header: {hdr_start} + {hdr_len} exceeds {} bytes",
                buf.len()
            )
        })?;
    let header = std::str::from_utf8(&buf[hdr_start..hdr_end])?;
    if !header.contains("'descr': '<f4'") && !header.contains("\"descr\": \"<f4\"") {
        bail!("only little-endian f32 supported (header: {header})");
    }
    if header.contains("'fortran_order': True") {
        bail!("fortran order not supported");
    }
    let shape = parse_shape(header)?;
    // checked arithmetic: a fuzzed header can claim shapes whose product
    // (or byte count) overflows usize, which would panic in debug builds
    let n = shape
        .iter()
        .try_fold(1usize, |acc, &d| acc.checked_mul(d))
        .ok_or_else(|| anyhow!("npy shape {shape:?} overflows usize"))?;
    let nbytes = n
        .checked_mul(4)
        .ok_or_else(|| anyhow!("npy byte count for shape {shape:?} overflows usize"))?;
    let body = &buf[hdr_end..];
    if body.len() < nbytes {
        bail!("truncated npy body: {} < {}", body.len(), nbytes);
    }
    let data: Vec<f32> = body[..nbytes]
        .chunks_exact(4)
        .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
        .collect();
    Ok(NpyArray { shape, data })
}

fn parse_shape(header: &str) -> Result<Vec<usize>> {
    let start = header
        .find("'shape':")
        .or_else(|| header.find("\"shape\":"))
        .ok_or_else(|| anyhow!("no shape in header"))?;
    let rest = &header[start..];
    let open = rest.find('(').ok_or_else(|| anyhow!("no shape tuple"))?;
    let close = rest.find(')').ok_or_else(|| anyhow!("no shape tuple end"))?;
    let inner = &rest[open + 1..close];
    let mut out = Vec::new();
    for part in inner.split(',') {
        let t = part.trim();
        if t.is_empty() {
            continue;
        }
        out.push(t.parse::<usize>()?);
    }
    if out.is_empty() {
        out.push(1); // 0-d scalar -> treat as length-1
    }
    Ok(out)
}

pub fn write_f32<P: AsRef<Path>>(path: P, shape: &[usize], data: &[f32]) -> Result<()> {
    assert_eq!(shape.iter().product::<usize>(), data.len());
    let shape_str = match shape.len() {
        1 => format!("({},)", shape[0]),
        _ => format!(
            "({})",
            shape
                .iter()
                .map(|d| d.to_string())
                .collect::<Vec<_>>()
                .join(", ")
        ),
    };
    let mut header = format!(
        "{{'descr': '<f4', 'fortran_order': False, 'shape': {shape_str}, }}"
    );
    // pad so that total header (magic+ver+len+dict+\n) is a multiple of 64
    let unpadded = MAGIC.len() + 4 + header.len() + 1;
    let pad = (64 - unpadded % 64) % 64;
    header.push_str(&" ".repeat(pad));
    header.push('\n');

    super::ensure_parent(path.as_ref())?;
    let mut f = std::fs::File::create(path)?;
    f.write_all(MAGIC)?;
    f.write_all(&[1, 0])?;
    f.write_all(&(header.len() as u16).to_le_bytes())?;
    f.write_all(header.as_bytes())?;
    for x in data {
        f.write_all(&x.to_le_bytes())?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let dir = std::env::temp_dir().join("qpretrain_npy_test");
        let path = dir.join("a.npy");
        let data: Vec<f32> = (0..12).map(|i| i as f32 * 0.5 - 3.0).collect();
        write_f32(&path, &[3, 4], &data).unwrap();
        let arr = read_f32(&path).unwrap();
        assert_eq!(arr.shape, vec![3, 4]);
        assert_eq!(arr.data, data);
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse_f32(b"not npy at all").is_err());
    }

    #[test]
    fn truncated_headers_error_not_panic() {
        // v2.0 magic+version with only 2 of the 4 u32 length bytes: used to
        // index buf[10]/buf[11] out of bounds
        let mut v2 = Vec::from(MAGIC);
        v2.extend_from_slice(&[2, 0, 0x10, 0x00]); // 10 bytes total
        assert!(parse_f32(&v2).is_err());
        v2.push(0x00); // 11 bytes
        assert!(parse_f32(&v2).is_err());

        // v1.0 with a header length that runs past the end of the buffer
        let mut v1 = Vec::from(MAGIC);
        v1.extend_from_slice(&[1, 0]);
        v1.extend_from_slice(&u16::MAX.to_le_bytes());
        v1.extend_from_slice(b"{'descr'");
        assert!(parse_f32(&v1).is_err());

        // v2.0 with a u32 header length near usize::MAX: hdr_start + hdr_len
        // must use checked arithmetic
        let mut big = Vec::from(MAGIC);
        big.extend_from_slice(&[2, 0]);
        big.extend_from_slice(&u32::MAX.to_le_bytes());
        big.extend_from_slice(b"{}");
        assert!(parse_f32(&big).is_err());
    }

    #[test]
    fn oversized_shape_errors_not_panics() {
        // header claims more elements than the body holds (and a product
        // that would overflow a u32-ish budget) -> Err, never a panic
        let hdr = "{'descr': '<f4', 'fortran_order': False, \
                   'shape': (18446744073709551615, 4), }\n";
        let mut buf = Vec::from(MAGIC);
        buf.extend_from_slice(&[1, 0]);
        buf.extend_from_slice(&(hdr.len() as u16).to_le_bytes());
        buf.extend_from_slice(hdr.as_bytes());
        buf.extend_from_slice(&[0u8; 16]);
        assert!(parse_f32(&buf).is_err());
    }
}
