//! Execution-time share of linear layers (paper §3.3, Fig. 3).
//!
//! The paper profiled GPU kernels with Nsight; here the same question —
//! what fraction of a block's fwd+bwd time goes to the linear layers vs the
//! attention core, across model sizes and sequence lengths — is answered by
//! timing the native backend's matmul kernels next to an analytic FLOPs
//! model. The claim being reproduced is about the *ratio* and its trends
//! (O(T d^2) vs O(T^2 d)), not absolute kernel times.
//!
//! Measurement strategy: matmul time is linear in the row count, so the
//! linear-layer component is timed over a row sample sized to a fixed FLOP
//! budget and extrapolated to the full sequence; the attention component
//! (quadratic in T, so rows cannot be subsampled) is timed over a head
//! sample. fwd+bwd is 3x the forward matmul work (one forward GEMM, two
//! backward GEMMs of the same shape).

use std::time::Instant;

use crate::backend::kernels::{self, matmul, matmul_nt};
use crate::util::rng::Rng;

/// Pin the kernels to one thread for the duration of a timing closure
/// (via the pool's scoped [`kernels::with_threads`], which restores the
/// previous knob even on panic). The row/head-sample extrapolation below
/// assumes time is linear in the sample size, which only holds at a fixed
/// thread schedule — the work planner would otherwise give the small
/// sample fewer threads than the full problem. The Fig. 3 claim is about
/// the linear-vs-attention *ratio*, which is schedule-independent;
/// `bench_linear_fraction` reports the parallel speedup separately on
/// full-size kernels.
fn timed_single_threaded<T>(f: impl FnOnce() -> T) -> T {
    kernels::with_threads(1, f)
}

pub const SIZES: [&str; 4] = ["small", "medium", "large", "xl"];
pub const SEQS: [usize; 4] = [128, 256, 512, 1024];

/// FLOP budget per timed sample (keeps the full grid interactive).
const SAMPLE_MACS: usize = 24_000_000;

#[derive(Debug, Clone)]
pub struct FractionRow {
    pub size: String,
    pub seq: usize,
    pub linear_ms: f64,
    pub attn_ms: f64,
    pub measured_frac: f64,
    pub analytic_frac: f64,
}

fn median(mut xs: Vec<f64>) -> f64 {
    xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
    xs[xs.len() / 2]
}

/// Time the four block linears (QKV, out-proj, FC1, FC2) forward over a row
/// sample, extrapolated to `seq` rows and fwd+bwd; returns milliseconds.
pub fn time_linear(d_model: usize, d_ff: usize, seq: usize, reps: usize) -> f64 {
    let d = d_model;
    let macs_per_row = d * (3 * d) + d * d + d * d_ff + d_ff * d;
    let cap = seq.max(1); // clamp bounds must satisfy min <= max for seq < 8
    let rows = (SAMPLE_MACS / macs_per_row).clamp(8.min(cap), cap);
    let mut rng = Rng::new(0x11A);
    let x = rng.normal_vec(rows * d, 0.0, 0.5);
    let xf = rng.normal_vec(rows * d_ff, 0.0, 0.5);
    let w_qkv = rng.normal_vec(d * 3 * d, 0.0, 0.02);
    let w_proj = rng.normal_vec(d * d, 0.0, 0.02);
    let w_fc1 = rng.normal_vec(d * d_ff, 0.0, 0.02);
    let w_fc2 = rng.normal_vec(d_ff * d, 0.0, 0.02);

    let times = timed_single_threaded(|| {
        let mut times = Vec::with_capacity(reps);
        for _ in 0..reps.max(1) {
            let t0 = Instant::now();
            std::hint::black_box(matmul(&x, &w_qkv, rows, d, 3 * d));
            std::hint::black_box(matmul(&x, &w_proj, rows, d, d));
            std::hint::black_box(matmul(&x, &w_fc1, rows, d, d_ff));
            std::hint::black_box(matmul(&xf, &w_fc2, rows, d_ff, d));
            times.push(t0.elapsed().as_secs_f64());
        }
        times
    });
    median(times) * (seq as f64 / rows as f64) * 3.0 * 1e3
}

/// Time the attention core (QKᵀ and P@V) forward over a head sample,
/// extrapolated to `n_head` heads and fwd+bwd; returns milliseconds.
pub fn time_attn(d_model: usize, n_head: usize, seq: usize, reps: usize) -> f64 {
    let hd = d_model / n_head;
    let macs_per_head = 2 * seq * seq * hd;
    let heads = (SAMPLE_MACS / macs_per_head.max(1)).clamp(1, n_head.max(1));
    let mut rng = Rng::new(0xA77);
    let q = rng.normal_vec(heads * seq * hd, 0.0, 0.5);
    let k = rng.normal_vec(heads * seq * hd, 0.0, 0.5);
    let p = rng.normal_vec(heads * seq * seq, 0.0, 0.1);
    let v = rng.normal_vec(heads * seq * hd, 0.0, 0.5);

    let times = timed_single_threaded(|| {
        let mut times = Vec::with_capacity(reps);
        for _ in 0..reps.max(1) {
            let t0 = Instant::now();
            for h in 0..heads {
                let qs = &q[h * seq * hd..(h + 1) * seq * hd];
                let ks = &k[h * seq * hd..(h + 1) * seq * hd];
                let ps = &p[h * seq * seq..(h + 1) * seq * seq];
                let vs = &v[h * seq * hd..(h + 1) * seq * hd];
                std::hint::black_box(matmul_nt(qs, ks, seq, hd, seq));
                std::hint::black_box(matmul(ps, vs, seq, seq, hd));
            }
            times.push(t0.elapsed().as_secs_f64());
        }
        times
    });
    median(times) * (n_head as f64 / heads as f64) * 3.0 * 1e3
}

/// Analytic FLOPs of the two components (fwd+bwd ~ 3x fwd).
pub fn analytic_fraction(d_model: usize, n_head: usize, seq: usize) -> f64 {
    let d = d_model as f64;
    let t = seq as f64;
    let hd = (d_model / n_head) as f64;
    let h = n_head as f64;
    let linear = 2.0 * t * (d * 3.0 * d + d * d + d * 4.0 * d + 4.0 * d * d) * 3.0;
    let attn = 2.0 * h * t * t * hd * 2.0 * 3.0;
    linear / (linear + attn)
}

/// Measure the full Fig. 3 grid on the native kernels.
pub fn fig3_rows(reps: usize) -> Vec<FractionRow> {
    let mut out = Vec::new();
    for size in SIZES {
        let m = crate::memmodel::profile_model(size);
        for seq in SEQS {
            let lin = time_linear(m.d_model, m.d_ff, seq, reps);
            let att = time_attn(m.d_model, m.n_head, seq, reps);
            out.push(FractionRow {
                size: size.to_string(),
                seq,
                linear_ms: lin,
                attn_ms: att,
                measured_frac: lin / (lin + att),
                analytic_frac: analytic_fraction(m.d_model, m.n_head, seq),
            });
        }
    }
    out
}

pub fn rows_to_csv(rows: &[FractionRow]) -> String {
    let mut out =
        String::from("model,seq,linear_ms,attn_ms,measured_linear_frac,analytic_linear_frac\n");
    for r in rows {
        out.push_str(&format!(
            "{},{},{:.3},{:.3},{:.4},{:.4}\n",
            r.size, r.seq, r.linear_ms, r.attn_ms, r.measured_frac, r.analytic_frac
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn analytic_fraction_trends() {
        // decreasing in seq (attention is quadratic)...
        let f128 = analytic_fraction(768, 12, 128);
        let f1024 = analytic_fraction(768, 12, 1024);
        assert!(f128 > f1024);
        // ...and increasing in model width at fixed seq
        let small = analytic_fraction(768, 12, 512);
        let xl = analytic_fraction(1600, 25, 512);
        assert!(xl > small);
        // paper: >80% at small seq for GPT-2 small
        assert!(f128 > 0.8, "{f128}");
    }

    #[test]
    fn fraction_bounded() {
        for d in [768, 1600] {
            for t in [128, 4096] {
                let f = analytic_fraction(d, d / 64, t);
                assert!(f > 0.0 && f < 1.0);
            }
        }
    }

    #[test]
    fn measured_times_positive_and_scale_with_seq() {
        // tiny shapes so the test stays fast
        let l128 = time_linear(64, 256, 128, 1);
        let l512 = time_linear(64, 256, 512, 1);
        assert!(l128 > 0.0);
        // extrapolation is linear in rows: 4x seq ~ 4x time (loose factor)
        assert!(l512 > l128 * 1.5, "l128={l128} l512={l512}");
        let a = time_attn(64, 4, 128, 1);
        assert!(a > 0.0);
    }
}
