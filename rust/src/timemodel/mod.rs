//! Execution-time share of linear layers (paper §3.3, Fig. 3).
//!
//! The paper profiled GPU kernels with Nsight; here the same question —
//! what fraction of a block's fwd+bwd time goes to the linear layers vs the
//! attention core, across model sizes and sequence lengths — is answered by
//! timing the AOT-compiled `prof/linear_*` and `prof/attn_*` artifacts on
//! the CPU PJRT client, next to an analytic FLOPs model. The claim being
//! reproduced is about the *ratio* and its trends (O(T d^2) vs O(T^2 d)),
//! not absolute kernel times.

use anyhow::Result;

use crate::runtime::{lit_f32, Runtime};
use crate::util::rng::Rng;

pub const SIZES: [&str; 4] = ["small", "medium", "large", "xl"];
pub const SEQS: [usize; 4] = [128, 256, 512, 1024];

#[derive(Debug, Clone)]
pub struct FractionRow {
    pub size: String,
    pub seq: usize,
    pub linear_ms: f64,
    pub attn_ms: f64,
    pub measured_frac: f64,
    pub analytic_frac: f64,
}

fn median(mut xs: Vec<f64>) -> f64 {
    xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
    xs[xs.len() / 2]
}

/// Time one prof artifact: median of `reps` runs after one warmup.
pub fn time_artifact(rt: &Runtime, name: &str, reps: usize) -> Result<f64> {
    let exe = rt.exec(name)?;
    let mut rng = Rng::new(0x7177);
    let inputs: Vec<xla::Literal> = exe
        .info
        .inputs
        .iter()
        .map(|sig| {
            let data = rng.normal_vec(sig.elems(), 0.0, 0.5);
            lit_f32(&data, &sig.shape)
        })
        .collect::<Result<_>>()?;
    let refs: Vec<&xla::Literal> = inputs.iter().collect();
    exe.run(&refs)?; // warmup
    let mut times = Vec::with_capacity(reps);
    for _ in 0..reps {
        let (_, dt) = exe.run_timed(&refs)?;
        times.push(dt * 1e3);
    }
    Ok(median(times))
}

/// Analytic FLOPs of the two components (fwd+bwd ~ 3x fwd).
pub fn analytic_fraction(d_model: usize, n_head: usize, seq: usize) -> f64 {
    let d = d_model as f64;
    let t = seq as f64;
    let hd = (d_model / n_head) as f64;
    let h = n_head as f64;
    let linear = 2.0 * t * (d * 3.0 * d + d * d + d * 4.0 * d + 4.0 * d * d) * 3.0;
    let attn = 2.0 * h * t * t * hd * 2.0 * 3.0;
    linear / (linear + attn)
}

/// Measure the full Fig. 3 grid.
pub fn fig3_rows(rt: &Runtime, reps: usize) -> Result<Vec<FractionRow>> {
    let mut out = Vec::new();
    for size in SIZES {
        let m = crate::memmodel::profile_model(size);
        for seq in SEQS {
            let lin = time_artifact(rt, &format!("prof/linear_{size}_s{seq}"), reps)?;
            let att = time_artifact(rt, &format!("prof/attn_{size}_s{seq}"), reps)?;
            out.push(FractionRow {
                size: size.to_string(),
                seq,
                linear_ms: lin,
                attn_ms: att,
                measured_frac: lin / (lin + att),
                analytic_frac: analytic_fraction(m.d_model, m.n_head, seq),
            });
        }
    }
    Ok(out)
}

pub fn rows_to_csv(rows: &[FractionRow]) -> String {
    let mut out =
        String::from("model,seq,linear_ms,attn_ms,measured_linear_frac,analytic_linear_frac\n");
    for r in rows {
        out.push_str(&format!(
            "{},{},{:.3},{:.3},{:.4},{:.4}\n",
            r.size, r.seq, r.linear_ms, r.attn_ms, r.measured_frac, r.analytic_frac
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn analytic_fraction_trends() {
        // decreasing in seq (attention is quadratic)...
        let f128 = analytic_fraction(768, 12, 128);
        let f1024 = analytic_fraction(768, 12, 1024);
        assert!(f128 > f1024);
        // ...and increasing in model width at fixed seq
        let small = analytic_fraction(768, 12, 512);
        let xl = analytic_fraction(1600, 25, 512);
        assert!(xl > small);
        // paper: >80% at small seq for GPT-2 small
        assert!(f128 > 0.8, "{f128}");
    }

    #[test]
    fn fraction_bounded() {
        for d in [768, 1600] {
            for t in [128, 4096] {
                let f = analytic_fraction(d, d / 64, t);
                assert!(f > 0.0 && f < 1.0);
            }
        }
    }
}
