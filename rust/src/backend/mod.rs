//! Backend seam: everything above this module (train loop, eval harness,
//! PTQ, analyses, coordinator) speaks the [`Backend`] trait in terms of host
//! `f32` vectors; everything below it decides *how* a train/eval/probe step
//! is executed.
//!
//! Two implementations:
//!
//! * [`native`] — pure rust. Implements the quantized GPT-2 forward +
//!   backward + AdamW update directly (embedding, causal attention, GELU
//!   MLP, layernorm, cross-entropy), injecting fake quantization at the
//!   paper's Fig. 1 points by calling the bit-exact [`crate::quant`] oracle.
//!   The default build ships only this backend: no PJRT, no artifacts, no
//!   Python.
//! * [`pjrt`] (cargo feature `pjrt`) — executes the AOT-lowered HLO
//!   artifacts through the PJRT C API, as the seed system did.
//!
//! The native backend's compute runs on [`kernels`] — cache-blocked
//! kernels fanned out over a **persistent worker pool** (spawned once per
//! process, warmed by `Runtime` construction) that are bit-identical to
//! the retained serial reference in [`math`] at every thread count
//! (`--threads` / `RAYON_NUM_THREADS`); cross-row reductions run on
//! fixed-shape trees whose block layout never depends on the thread
//! count. The matmul inner loops of both modules run on the
//! runtime-dispatched [`simd`] microkernels (AVX2/FMA f32x8 + widening
//! i8→i32 lanes, `QPRETRAIN_SIMD=off` to disable), whose scalar emulation
//! walks the exact same fixed lane/tail structure — so results are
//! bit-identical with or without SIMD, at every thread count. Symmetric
//! 8-bit recipes additionally dispatch the forward linears to a
//! packed-int8 GEMM (lane-padded i8 codes, i32 accumulation, single
//! rescale) with the f32 qdq path retained as the reference oracle
//! ([`native::set_int8_gemm`]).
//!
//! Both backends take a [`QuantRecipe`](crate::config::QuantRecipe): which
//! components are fake-quantized, at which granularity/symmetry, and at
//! which bit-width. The native backend honors any recipe; the PJRT backend
//! maps the recipe's placement back to a legacy artifact structure name
//! (bit-widths travel as runtime qmax scalars there) and rejects recipes
//! the artifact vocabulary cannot express.

pub mod kernels;
pub mod math;
pub mod native;
pub mod simd;
#[cfg(feature = "pjrt")]
pub mod pjrt;

use anyhow::Result;

use crate::config::QuantRecipe;
use crate::model::HostState;
use crate::runtime::ModelInfo;

/// Result of one training step.
#[derive(Debug, Clone, Copy)]
pub struct StepOut {
    pub loss: f64,
    /// Pre-clip global gradient norm (the paper's Fig. 10 spike statistic).
    pub gnorm: f64,
}

/// Result of one evaluation step.
#[derive(Debug, Clone)]
pub struct EvalOut {
    /// Mask-weighted mean NLL: `sum(nll * mask) / max(sum(mask), 1)`.
    /// The floor of 1 on the denominator mirrors the L2 eval graph
    /// (`python/compile/steps.py`), so masks are expected to be 0/1
    /// indicators with at least one position set; an all-zero mask yields
    /// 0, not NaN, on every backend.
    pub mean_nll: f64,
    /// Per-position NLL, row-major (batch * seq).
    pub per_pos: Vec<f32>,
}

/// Activation snapshot of the probe layer (last block), matching the AOT
/// `probe/act` artifact: the attention out-proj input and the FC2 input.
#[derive(Debug, Clone)]
pub struct ActProbe {
    /// (batch*seq, d_model) row-major.
    pub proj_in: Vec<f32>,
    /// (batch*seq, d_ff) row-major (post-GELU).
    pub fc2_in: Vec<f32>,
}

/// Gradient snapshot matching the AOT `probe/grad` artifact.
#[derive(Debug, Clone)]
pub struct GradProbe {
    /// Layer-0 QKV weight gradient, (d_model, 3*d_model) row-major.
    pub d_qkv_w0: Vec<f32>,
    /// Activation gradient at layer 0's attention out-proj input,
    /// (batch*seq, d_model) row-major.
    pub d_ctx0: Vec<f32>,
}

/// Executor abstraction: run one train / eval / probe step over host state.
pub trait Backend {
    fn name(&self) -> &'static str;

    /// One optimizer step: consumes a token batch, updates `state`
    /// (params, m, v) in place, and returns (loss, pre-clip grad norm).
    /// `t` is the 1-based Adam step counter.
    fn train_step(
        &self,
        model: &ModelInfo,
        recipe: &QuantRecipe,
        state: &mut HostState,
        x: &[i32],
        y: &[i32],
        lr: f32,
        t: f32,
    ) -> Result<StepOut>;

    /// Half of a sharded (data-parallel) step: backward only, over a
    /// *leaf* token batch shaped for this `model` (the dist trainer passes
    /// a batch-1 view of the global model), with `inv_norm` folded into
    /// the logit gradients — `1 / (global_batch * seq)` makes leaf
    /// gradients terms of the global mean, so shards combine by pure
    /// summation. Returns the unnormalized NLL **sum** over the leaf's
    /// positions plus the per-parameter gradients; no state is touched.
    /// Backends without a sharded-step path keep the default error.
    fn grad_step(
        &self,
        model: &ModelInfo,
        recipe: &QuantRecipe,
        params: &[Vec<f32>],
        x: &[i32],
        y: &[i32],
        inv_norm: f32,
    ) -> Result<(f64, Vec<Vec<f32>>)> {
        let _ = (model, recipe, params, x, y, inv_norm);
        anyhow::bail!("backend {:?} does not support sharded gradient steps", self.name())
    }

    /// The other half of a sharded step: one AdamW update from
    /// already-combined gradients (clip, moment update, moment qdq per the
    /// recipe, parameter update — identical to the tail of
    /// [`Backend::train_step`]). Returns the pre-clip global grad norm.
    fn apply_grads(
        &self,
        model: &ModelInfo,
        recipe: &QuantRecipe,
        state: &mut HostState,
        grads: &[Vec<f32>],
        lr: f32,
        t: f32,
    ) -> Result<f64> {
        let _ = (model, recipe, state, grads, lr, t);
        anyhow::bail!("backend {:?} does not support sharded gradient steps", self.name())
    }

    /// Forward-only scoring under the recipe's forward-pass components
    /// (implementations apply [`QuantRecipe::forward_only`] themselves, so
    /// passing a full training recipe is fine).
    fn eval_step(
        &self,
        model: &ModelInfo,
        recipe: &QuantRecipe,
        params: &[Vec<f32>],
        x: &[i32],
        y: &[i32],
        mask: &[f32],
    ) -> Result<EvalOut>;

    /// Unquantized forward capturing the probe layer's outlier tensors.
    fn act_probe(&self, model: &ModelInfo, params: &[Vec<f32>], x: &[i32]) -> Result<ActProbe>;

    /// Unquantized backward capturing the Fig. 10 gradient snapshot.
    fn grad_probe(
        &self,
        model: &ModelInfo,
        params: &[Vec<f32>],
        x: &[i32],
        y: &[i32],
    ) -> Result<GradProbe>;
}
