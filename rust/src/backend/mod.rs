//! Backend seam: everything above this module (train loop, eval harness,
//! PTQ, analyses, coordinator) speaks the [`Backend`] trait in terms of host
//! `f32` vectors; everything below it decides *how* a train/eval/probe step
//! is executed.
//!
//! Two implementations:
//!
//! * [`native`] — pure rust. Implements the quantized GPT-2 forward +
//!   backward + AdamW update directly (embedding, causal attention, GELU
//!   MLP, layernorm, cross-entropy), injecting fake quantization at the
//!   paper's Fig. 1 points by calling the bit-exact [`crate::quant`] oracle.
//!   The default build ships only this backend: no PJRT, no artifacts, no
//!   Python.
//! * [`pjrt`] (cargo feature `pjrt`) — executes the AOT-lowered HLO
//!   artifacts through the PJRT C API, as the seed system did.
//!
//! The native backend's compute runs on [`kernels`] — thread-parallel,
//! cache-blocked f32 kernels that are bit-identical to the retained serial
//! reference in [`math`] at every thread count (`--threads` /
//! `RAYON_NUM_THREADS`).
//!
//! A *structure* names which components are fake-quantized and at which
//! granularity (e.g. `"w_pc"`, `"a_ptok_asym"`, `"wag"`); bit-widths arrive
//! separately as runtime qmax scalars, mirroring the artifact convention
//! that one structure serves every bit-width.

pub mod kernels;
pub mod math;
pub mod native;
#[cfg(feature = "pjrt")]
pub mod pjrt;

use anyhow::{bail, Result};

use crate::config::Granularity;
use crate::model::HostState;
use crate::runtime::ModelInfo;

/// How one tensor class is quantized (granularity is static per structure;
/// the bit-width is a runtime qmax scalar).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct QSpec {
    pub granularity: Granularity,
    pub asymmetric: bool,
}

impl QSpec {
    pub fn sym(granularity: Granularity) -> QSpec {
        QSpec {
            granularity,
            asymmetric: false,
        }
    }

    pub fn asym(granularity: Granularity) -> QSpec {
        QSpec {
            granularity,
            asymmetric: true,
        }
    }
}

/// Which model components a structure fake-quantizes — the rust mirror of
/// `python/compile/quantizer.QuantConfig` and of `aot.TRAIN_STRUCTURES`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct QuantStructure {
    pub weights: Option<QSpec>,
    pub acts: Option<QSpec>,
    pub grads: Option<QSpec>,
    /// Fig. 10 variant: quantize the activation-gradient (dx) path too.
    pub quantize_act_grads: bool,
    pub m1: Option<QSpec>,
    pub m2: Option<QSpec>,
}

impl QuantStructure {
    /// Parse a structure name (the artifact-key vocabulary).
    pub fn parse(name: &str) -> Result<QuantStructure> {
        use Granularity::*;
        let mut s = QuantStructure::default();
        match name {
            "base" => {}
            "w_pt" => s.weights = Some(QSpec::sym(PerTensor)),
            // the pallas-lowered artifact computes the same numbers; natively
            // they are one and the same code path
            "w_pc" | "w_pc_pallas" => s.weights = Some(QSpec::sym(PerChannel)),
            "a_pt" => s.acts = Some(QSpec::sym(PerTensor)),
            "a_ptok" => s.acts = Some(QSpec::sym(PerToken)),
            "a_ptok_asym" => s.acts = Some(QSpec::asym(PerToken)),
            "a_pc" => s.acts = Some(QSpec::sym(PerChannel)),
            "g_pt" => s.grads = Some(QSpec::sym(PerTensor)),
            "g_ptok" => s.grads = Some(QSpec::sym(PerToken)),
            "g_ptok_actgrad" => {
                s.grads = Some(QSpec::sym(PerToken));
                s.quantize_act_grads = true;
            }
            "m1_pt" => s.m1 = Some(QSpec::sym(PerTensor)),
            "m1_pc" => s.m1 = Some(QSpec::sym(PerChannel)),
            "m2_pt" => s.m2 = Some(QSpec::sym(PerTensor)),
            "m2_pc" => s.m2 = Some(QSpec::sym(PerChannel)),
            "wa" => {
                s.weights = Some(QSpec::sym(PerChannel));
                s.acts = Some(QSpec::sym(PerToken));
            }
            "wag" => {
                s.weights = Some(QSpec::sym(PerChannel));
                s.acts = Some(QSpec::sym(PerToken));
                s.grads = Some(QSpec::sym(PerToken));
            }
            other => bail!("unknown quant structure {other:?}"),
        }
        Ok(s)
    }

    /// Forward-pass components only (what an eval structure keeps).
    pub fn forward_only(&self) -> QuantStructure {
        QuantStructure {
            weights: self.weights,
            acts: self.acts,
            ..QuantStructure::default()
        }
    }

    /// Every structure name `parse` accepts.
    pub const ALL: [&'static str; 17] = [
        "base", "w_pt", "w_pc", "w_pc_pallas", "a_pt", "a_ptok", "a_ptok_asym",
        "a_pc", "g_pt", "g_ptok", "g_ptok_actgrad", "m1_pt", "m1_pc", "m2_pt",
        "m2_pc", "wa", "wag",
    ];
}

/// Result of one training step.
#[derive(Debug, Clone, Copy)]
pub struct StepOut {
    pub loss: f64,
    /// Pre-clip global gradient norm (the paper's Fig. 10 spike statistic).
    pub gnorm: f64,
}

/// Result of one evaluation step.
#[derive(Debug, Clone)]
pub struct EvalOut {
    /// Mask-weighted mean NLL: `sum(nll * mask) / max(sum(mask), 1)`.
    /// The floor of 1 on the denominator mirrors the L2 eval graph
    /// (`python/compile/steps.py`), so masks are expected to be 0/1
    /// indicators with at least one position set; an all-zero mask yields
    /// 0, not NaN, on every backend.
    pub mean_nll: f64,
    /// Per-position NLL, row-major (batch * seq).
    pub per_pos: Vec<f32>,
}

/// Activation snapshot of the probe layer (last block), matching the AOT
/// `probe/act` artifact: the attention out-proj input and the FC2 input.
#[derive(Debug, Clone)]
pub struct ActProbe {
    /// (batch*seq, d_model) row-major.
    pub proj_in: Vec<f32>,
    /// (batch*seq, d_ff) row-major (post-GELU).
    pub fc2_in: Vec<f32>,
}

/// Gradient snapshot matching the AOT `probe/grad` artifact.
#[derive(Debug, Clone)]
pub struct GradProbe {
    /// Layer-0 QKV weight gradient, (d_model, 3*d_model) row-major.
    pub d_qkv_w0: Vec<f32>,
    /// Activation gradient at layer 0's attention out-proj input,
    /// (batch*seq, d_model) row-major.
    pub d_ctx0: Vec<f32>,
}

/// Executor abstraction: run one train / eval / probe step over host state.
///
/// `qmax` carries the five runtime quantization ranges in artifact input
/// order (w, a, g, m1, m2); components a structure does not quantize ignore
/// theirs (fed 1.0 by convention).
pub trait Backend {
    fn name(&self) -> &'static str;

    /// One optimizer step: consumes a token batch, updates `state`
    /// (params, m, v) in place, and returns (loss, pre-clip grad norm).
    /// `t` is the 1-based Adam step counter.
    fn train_step(
        &self,
        model: &ModelInfo,
        structure: &str,
        qmax: &[f32; 5],
        state: &mut HostState,
        x: &[i32],
        y: &[i32],
        lr: f32,
        t: f32,
    ) -> Result<StepOut>;

    /// Forward-only scoring under the structure's forward quantization.
    fn eval_step(
        &self,
        model: &ModelInfo,
        structure: &str,
        qmax_w: f32,
        qmax_a: f32,
        params: &[Vec<f32>],
        x: &[i32],
        y: &[i32],
        mask: &[f32],
    ) -> Result<EvalOut>;

    /// Unquantized forward capturing the probe layer's outlier tensors.
    fn act_probe(&self, model: &ModelInfo, params: &[Vec<f32>], x: &[i32]) -> Result<ActProbe>;

    /// Unquantized backward capturing the Fig. 10 gradient snapshot.
    fn grad_probe(
        &self,
        model: &ModelInfo,
        params: &[Vec<f32>],
        x: &[i32],
        y: &[i32],
    ) -> Result<GradProbe>;
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_every_structure() {
        for s in QuantStructure::ALL {
            QuantStructure::parse(s).unwrap();
        }
        assert!(QuantStructure::parse("bogus").is_err());
    }

    #[test]
    fn pallas_alias_matches_w_pc() {
        assert_eq!(
            QuantStructure::parse("w_pc_pallas").unwrap(),
            QuantStructure::parse("w_pc").unwrap()
        );
    }

    #[test]
    fn forward_only_drops_backward_components() {
        let s = QuantStructure::parse("wag").unwrap();
        let f = s.forward_only();
        assert!(f.weights.is_some() && f.acts.is_some());
        assert!(f.grads.is_none() && !f.quantize_act_grads);
        assert_eq!(f, QuantStructure::parse("wa").unwrap());
    }

    #[test]
    fn actgrad_variant_sets_flag() {
        let s = QuantStructure::parse("g_ptok_actgrad").unwrap();
        assert!(s.quantize_act_grads);
        assert_eq!(s.grads, Some(QSpec::sym(Granularity::PerToken)));
    }
}
