//! Parallel, cache-blocked kernels for the native backend.
//!
//! Every kernel here computes **bit-identical** results to the retained
//! serial reference in [`super::math`], at any thread count, by
//! construction: work is split only across *independent output rows or
//! tiles*, and each output element is accumulated in the exact serial
//! order (k ascending in the matmuls, r ascending in the reductions).
//! Cross-output reductions that cannot be split without reordering float
//! adds (layernorm dw/db, the global grad norm) stay serial — they are
//! O(rows·d) next to the O(rows·d²) matmuls. `rust/tests/kernels.rs`
//! asserts the equivalence property over randomized and degenerate shapes;
//! `rust/tests/native.rs` asserts full train runs are invariant across
//! `RAYON_NUM_THREADS` values.
//!
//! Threading substrate: the offline crate set has no rayon, so the
//! fork-join is built on `std::thread::scope` with static contiguous
//! chunking (which is also what keeps the split deterministic — no work
//! stealing, no atomics in the hot loop). The thread count resolves from,
//! in priority order: [`set_threads`] (the CLI `--threads` knob /
//! `TrainHp::threads`), the `RAYON_NUM_THREADS` or `QPRETRAIN_THREADS`
//! environment variables, then `available_parallelism`. Kernels fall back
//! to the serial path below a work threshold so tiny shapes don't pay
//! spawn overhead.

use std::ops::Range;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::OnceLock;

pub use super::math::{GELU_A, GELU_C, LN_EPS};

// ---------------------------------------------------------------------------
// thread-count resolution + fork-join substrate
// ---------------------------------------------------------------------------

/// Process-wide override set by `--threads` / `TrainHp::threads`; 0 = unset.
static THREAD_OVERRIDE: AtomicUsize = AtomicUsize::new(0);

/// Test hook: when set, [`plan`] ignores the work thresholds so property
/// tests exercise the parallel path even on tiny shapes.
static FORCE_PARALLEL: AtomicBool = AtomicBool::new(false);

/// Override the kernel thread count for this process (0 restores the
/// environment/auto resolution). Safe to call at any time; kernels read it
/// per invocation, and results are identical at every thread count.
pub fn set_threads(n: usize) {
    THREAD_OVERRIDE.store(n, Ordering::Relaxed);
}

/// Force the parallel path regardless of problem size (test hook for the
/// bit-exactness suite; leaves the thread count untouched).
pub fn force_parallel(on: bool) {
    FORCE_PARALLEL.store(on, Ordering::Relaxed);
}

fn env_threads() -> usize {
    static CACHE: OnceLock<usize> = OnceLock::new();
    *CACHE.get_or_init(|| {
        for key in ["RAYON_NUM_THREADS", "QPRETRAIN_THREADS"] {
            if let Ok(v) = std::env::var(key) {
                if let Ok(n) = v.trim().parse::<usize>() {
                    if n > 0 {
                        return n;
                    }
                }
            }
        }
        std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1)
    })
}

/// The raw process-wide override (0 = unset); lets callers save/restore
/// the knob around a scoped pin.
pub fn threads_override() -> usize {
    THREAD_OVERRIDE.load(Ordering::Relaxed)
}

/// The resolved kernel thread budget (override > env > all cores).
pub fn max_threads() -> usize {
    match THREAD_OVERRIDE.load(Ordering::Relaxed) {
        0 => env_threads(),
        n => n,
    }
}

/// Don't fork at all below this many scalar ops of total work…
const MIN_PAR_WORK: usize = 1 << 20;
/// …and give every thread at least this much once we do.
const MIN_WORK_PER_THREAD: usize = 1 << 19;

/// Threads to use for `chunks` independent chunks of `work_per_chunk`
/// scalar ops each.
fn plan(chunks: usize, work_per_chunk: usize) -> usize {
    if chunks <= 1 {
        return 1;
    }
    if FORCE_PARALLEL.load(Ordering::Relaxed) {
        return max_threads().min(chunks).max(1);
    }
    let total = chunks.saturating_mul(work_per_chunk.max(1));
    if total < MIN_PAR_WORK {
        return 1;
    }
    max_threads()
        .min(total / MIN_WORK_PER_THREAD)
        .min(chunks)
        .max(1)
}

/// Run `f` over contiguous spans of `data`, viewed as `data.len() / chunk`
/// chunks of `chunk` elements. `f(range, sub)` receives the global chunk
/// index range and the matching sub-slice; spans are disjoint, so the split
/// is race-free by construction. Runs serially (one call covering all
/// chunks) when the work is too small to be worth forking.
pub fn par_chunks_mut<T, F>(data: &mut [T], chunk: usize, work_per_chunk: usize, f: F)
where
    T: Send,
    F: Fn(Range<usize>, &mut [T]) + Sync,
{
    assert!(chunk > 0, "chunk size must be positive");
    assert_eq!(data.len() % chunk, 0, "buffer is not whole chunks");
    let chunks = data.len() / chunk;
    if chunks == 0 {
        return;
    }
    let nt = plan(chunks, work_per_chunk);
    if nt <= 1 {
        f(0..chunks, data);
        return;
    }
    let per = chunks.div_ceil(nt);
    std::thread::scope(|s| {
        let f = &f;
        let mut work: Vec<(usize, &mut [T])> = data.chunks_mut(per * chunk).enumerate().collect();
        let (_, first) = work.remove(0);
        for (i, sub) in work {
            let start = i * per;
            let end = start + sub.len() / chunk;
            s.spawn(move || f(start..end, sub));
        }
        f(0..per.min(chunks), first);
    });
}

/// Two-buffer variant of [`par_chunks_mut`]: both buffers are split at the
/// same chunk boundaries (they must contain the same number of chunks).
pub fn par_chunks2_mut<A, B, F>(
    a: &mut [A],
    ca: usize,
    b: &mut [B],
    cb: usize,
    work_per_chunk: usize,
    f: F,
) where
    A: Send,
    B: Send,
    F: Fn(Range<usize>, &mut [A], &mut [B]) + Sync,
{
    assert!(ca > 0 && cb > 0, "chunk sizes must be positive");
    assert!(a.len() % ca == 0 && b.len() % cb == 0, "buffers not whole chunks");
    let chunks = a.len() / ca;
    assert_eq!(chunks, b.len() / cb, "chunk counts differ");
    if chunks == 0 {
        return;
    }
    let nt = plan(chunks, work_per_chunk);
    if nt <= 1 {
        f(0..chunks, a, b);
        return;
    }
    let per = chunks.div_ceil(nt);
    std::thread::scope(|s| {
        let f = &f;
        let mut work: Vec<(usize, (&mut [A], &mut [B]))> = a
            .chunks_mut(per * ca)
            .zip(b.chunks_mut(per * cb))
            .enumerate()
            .collect();
        let (_, (a0, b0)) = work.remove(0);
        for (i, (sa, sb)) in work {
            let start = i * per;
            let end = start + sa.len() / ca;
            s.spawn(move || f(start..end, sa, sb));
        }
        f(0..per.min(chunks), a0, b0);
    });
}

/// Three-buffer variant of [`par_chunks_mut`] (same chunk counts required).
pub fn par_chunks3_mut<A, B, C, F>(
    a: &mut [A],
    ca: usize,
    b: &mut [B],
    cb: usize,
    c: &mut [C],
    cc: usize,
    work_per_chunk: usize,
    f: F,
) where
    A: Send,
    B: Send,
    C: Send,
    F: Fn(Range<usize>, &mut [A], &mut [B], &mut [C]) + Sync,
{
    assert!(ca > 0 && cb > 0 && cc > 0, "chunk sizes must be positive");
    assert!(
        a.len() % ca == 0 && b.len() % cb == 0 && c.len() % cc == 0,
        "buffers not whole chunks"
    );
    let chunks = a.len() / ca;
    assert_eq!(chunks, b.len() / cb, "chunk counts differ");
    assert_eq!(chunks, c.len() / cc, "chunk counts differ");
    if chunks == 0 {
        return;
    }
    let nt = plan(chunks, work_per_chunk);
    if nt <= 1 {
        f(0..chunks, a, b, c);
        return;
    }
    let per = chunks.div_ceil(nt);
    std::thread::scope(|s| {
        let f = &f;
        let mut work: Vec<(usize, ((&mut [A], &mut [B]), &mut [C]))> = a
            .chunks_mut(per * ca)
            .zip(b.chunks_mut(per * cb))
            .zip(c.chunks_mut(per * cc))
            .enumerate()
            .collect();
        let (_, ((a0, b0), c0)) = work.remove(0);
        for (i, ((sa, sb), sc)) in work {
            let start = i * per;
            let end = start + sa.len() / ca;
            s.spawn(move || f(start..end, sa, sb, sc));
        }
        f(0..per.min(chunks), a0, b0, c0);
    });
}

// ---------------------------------------------------------------------------
// matmul kernels (row-parallel, k-panel cache blocking)
// ---------------------------------------------------------------------------

/// k-dimension panel size: a panel of `b` rows (K_PANEL x n) stays cache
/// resident while it is re-used across every output row of a thread's
/// chunk. Panels are walked in ascending k order, so each output element
/// still accumulates in the exact serial order.
pub const K_PANEL: usize = 64;

/// `c = a @ b` where a is (m x k), b is (k x n), all row-major.
pub fn matmul(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    let mut c = vec![0.0f32; m * n];
    matmul_acc(&mut c, a, b, m, k, n);
    c
}

/// `c += a @ b` (shapes as [`matmul`]).
pub fn matmul_acc(c: &mut [f32], a: &[f32], b: &[f32], m: usize, k: usize, n: usize) {
    assert_eq!(a.len(), m * k, "matmul_acc: a has wrong shape");
    assert_eq!(b.len(), k * n, "matmul_acc: b has wrong shape");
    assert_eq!(c.len(), m * n, "matmul_acc: c has wrong shape");
    if m == 0 || n == 0 || k == 0 {
        return;
    }
    par_chunks_mut(c, n, 2 * k * n, |rows, cc| {
        for l0 in (0..k).step_by(K_PANEL) {
            let l1 = (l0 + K_PANEL).min(k);
            for (ri, i) in rows.clone().enumerate() {
                let arow = &a[i * k..(i + 1) * k];
                let crow = &mut cc[ri * n..(ri + 1) * n];
                for l in l0..l1 {
                    let av = arow[l];
                    let brow = &b[l * n..(l + 1) * n];
                    for (cv, &bv) in crow.iter_mut().zip(brow.iter()) {
                        *cv += av * bv;
                    }
                }
            }
        }
    });
}

/// `aᵀ @ b` where a is (m x k), b is (m x n); result is (k x n).
pub fn matmul_tn(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    let mut c = vec![0.0f32; k * n];
    matmul_tn_acc(&mut c, a, b, m, k, n);
    c
}

/// `c += aᵀ @ b` (shapes as [`matmul_tn`]) — the weight-gradient kernel.
/// Parallel over output rows (the k dimension); the reduction dimension m
/// is walked in ascending order per output element, matching the serial
/// reference bit for bit. Each thread's output chunk is small enough to
/// stay cache resident across the whole reduction.
pub fn matmul_tn_acc(c: &mut [f32], a: &[f32], b: &[f32], m: usize, k: usize, n: usize) {
    assert_eq!(a.len(), m * k, "matmul_tn_acc: a has wrong shape");
    assert_eq!(b.len(), m * n, "matmul_tn_acc: b has wrong shape");
    assert_eq!(c.len(), k * n, "matmul_tn_acc: c has wrong shape");
    if m == 0 || n == 0 || k == 0 {
        return;
    }
    par_chunks_mut(c, n, 2 * m * n, |lrange, cc| {
        for r in 0..m {
            let arow = &a[r * k..(r + 1) * k];
            let brow = &b[r * n..(r + 1) * n];
            for (li, l) in lrange.clone().enumerate() {
                let av = arow[l];
                let crow = &mut cc[li * n..(li + 1) * n];
                for (cv, &bv) in crow.iter_mut().zip(brow.iter()) {
                    *cv += av * bv;
                }
            }
        }
    });
}

/// `a @ bᵀ` where a is (m x k), b is (n x k); result is (m x n).
/// Dot-product form, parallel over output rows.
pub fn matmul_nt(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    assert_eq!(a.len(), m * k, "matmul_nt: a has wrong shape");
    assert_eq!(b.len(), n * k, "matmul_nt: b has wrong shape");
    let mut c = vec![0.0f32; m * n];
    if m == 0 || n == 0 {
        return c;
    }
    par_chunks_mut(&mut c, n, 2 * k * n, |rows, cc| {
        for (ri, i) in rows.clone().enumerate() {
            let arow = &a[i * k..(i + 1) * k];
            let crow = &mut cc[ri * n..(ri + 1) * n];
            for (j, cv) in crow.iter_mut().enumerate() {
                let brow = &b[j * k..(j + 1) * k];
                let mut acc = 0.0f32;
                for (&av, &bv) in arow.iter().zip(brow.iter()) {
                    acc += av * bv;
                }
                *cv = acc;
            }
        }
    });
    c
}

/// Column sums accumulated into `acc` (the bias-gradient kernel), parallel
/// over column blocks; rows are reduced in ascending order per column.
pub fn col_sum_acc(acc: &mut [f32], x: &[f32], rows: usize, cols: usize) {
    assert_eq!(x.len(), rows * cols, "col_sum_acc: x has wrong shape");
    assert_eq!(acc.len(), cols, "col_sum_acc: acc has wrong shape");
    if rows == 0 || cols == 0 {
        return;
    }
    par_chunks_mut(acc, 1, 2 * rows, |crange, ac| {
        for r in 0..rows {
            let row = &x[r * cols..(r + 1) * cols];
            for (ci, c) in crange.clone().enumerate() {
                ac[ci] += row[c];
            }
        }
    });
}

// ---------------------------------------------------------------------------
// elementwise / row-wise kernels
// ---------------------------------------------------------------------------

/// `a += b` elementwise (residual-gradient accumulation).
pub fn add_assign(a: &mut [f32], b: &[f32]) {
    assert_eq!(a.len(), b.len(), "add_assign: length mismatch");
    par_chunks_mut(a, 1, 2, |range, ac| {
        for (ai, i) in range.clone().enumerate() {
            ac[ai] += b[i];
        }
    });
}

/// Add a length-`cols` bias row to every row of the (rows x cols) matrix.
pub fn bias_add(x: &mut [f32], bias: &[f32], rows: usize, cols: usize) {
    assert_eq!(x.len(), rows * cols, "bias_add: x has wrong shape");
    assert_eq!(bias.len(), cols, "bias_add: bias has wrong shape");
    if rows == 0 || cols == 0 {
        return;
    }
    par_chunks_mut(x, cols, cols, |rows_r, xc| {
        for ri in 0..(rows_r.end - rows_r.start) {
            let row = &mut xc[ri * cols..(ri + 1) * cols];
            for (rv, &bv) in row.iter_mut().zip(bias.iter()) {
                *rv += bv;
            }
        }
    });
}

/// Row-wise layernorm over (rows x d), parallel over rows; identical
/// per-row arithmetic to [`super::math::layer_norm_fwd`].
pub fn layer_norm_fwd(
    x: &[f32],
    w: &[f32],
    b: &[f32],
    rows: usize,
    d: usize,
) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    assert_eq!(x.len(), rows * d, "layer_norm_fwd: x has wrong shape");
    assert_eq!(w.len(), d, "layer_norm_fwd: w has wrong shape");
    assert_eq!(b.len(), d, "layer_norm_fwd: b has wrong shape");
    let mut y = vec![0.0f32; rows * d];
    let mut xhat = vec![0.0f32; rows * d];
    let mut rstd = vec![0.0f32; rows];
    if rows == 0 || d == 0 {
        return (y, xhat, rstd);
    }
    par_chunks3_mut(&mut y, d, &mut xhat, d, &mut rstd, 1, 8 * d, |rr, yc, xc, rc| {
        for (ri, r) in rr.clone().enumerate() {
            let xr = &x[r * d..(r + 1) * d];
            let mut mean = 0.0f32;
            for &v in xr {
                mean += v;
            }
            mean /= d as f32;
            let mut var = 0.0f32;
            for &v in xr {
                let dv = v - mean;
                var += dv * dv;
            }
            var /= d as f32;
            let rs = 1.0 / (var + LN_EPS).sqrt();
            rc[ri] = rs;
            let xh = &mut xc[ri * d..(ri + 1) * d];
            let yr = &mut yc[ri * d..(ri + 1) * d];
            for c in 0..d {
                let h = (xr[c] - mean) * rs;
                xh[c] = h;
                yr[c] = h * w[c] + b[c];
            }
        }
    });
    (y, xhat, rstd)
}

/// Layernorm backward: dx is computed row-parallel; the dw/db column
/// accumulators are cross-row reductions, so they keep the serial row
/// order (bit-identical to [`super::math::layer_norm_bwd`]) in a second,
/// O(rows·d) pass.
pub fn layer_norm_bwd(
    dy: &[f32],
    xhat: &[f32],
    rstd: &[f32],
    w: &[f32],
    rows: usize,
    d: usize,
    dw_acc: &mut [f32],
    db_acc: &mut [f32],
) -> Vec<f32> {
    assert_eq!(dy.len(), rows * d, "layer_norm_bwd: dy has wrong shape");
    assert_eq!(xhat.len(), rows * d, "layer_norm_bwd: xhat has wrong shape");
    assert_eq!(rstd.len(), rows, "layer_norm_bwd: rstd has wrong shape");
    assert_eq!(w.len(), d, "layer_norm_bwd: w has wrong shape");
    assert_eq!(dw_acc.len(), d, "layer_norm_bwd: dw has wrong shape");
    assert_eq!(db_acc.len(), d, "layer_norm_bwd: db has wrong shape");
    let mut dx = vec![0.0f32; rows * d];
    if rows == 0 || d == 0 {
        return dx;
    }
    par_chunks_mut(&mut dx, d, 12 * d, |rr, dxc| {
        for (ri, r) in rr.clone().enumerate() {
            let dyr = &dy[r * d..(r + 1) * d];
            let xhr = &xhat[r * d..(r + 1) * d];
            let mut m1 = 0.0f32; // mean(dxhat)
            let mut m2 = 0.0f32; // mean(dxhat * xhat)
            for c in 0..d {
                let dxh = dyr[c] * w[c];
                m1 += dxh;
                m2 += dxh * xhr[c];
            }
            m1 /= d as f32;
            m2 /= d as f32;
            let rs = rstd[r];
            let dxr = &mut dxc[ri * d..(ri + 1) * d];
            for c in 0..d {
                let dxh = dyr[c] * w[c];
                dxr[c] = rs * (dxh - m1 - xhr[c] * m2);
            }
        }
    });
    // serial row-order pass: a parallel split here would reorder the float
    // accumulation and break bit-exactness with the serial reference
    for r in 0..rows {
        let dyr = &dy[r * d..(r + 1) * d];
        let xhr = &xhat[r * d..(r + 1) * d];
        for c in 0..d {
            dw_acc[c] += dyr[c] * xhr[c];
            db_acc[c] += dyr[c];
        }
    }
    dx
}

/// Tanh-approximate GELU (elementwise-parallel; same arithmetic per
/// element as [`super::math::gelu`]).
pub fn gelu(u: &[f32]) -> Vec<f32> {
    let mut out = vec![0.0f32; u.len()];
    par_chunks_mut(&mut out, 1, 16, |range, oc| {
        for (oi, i) in range.clone().enumerate() {
            let x = u[i];
            let t = (GELU_C * (x + GELU_A * x * x * x)).tanh();
            oc[oi] = 0.5 * x * (1.0 + t);
        }
    });
    out
}

/// GELU backward: `du = dg * gelu'(u)`.
pub fn gelu_bwd(u: &[f32], dg: &[f32]) -> Vec<f32> {
    assert_eq!(u.len(), dg.len(), "gelu_bwd: length mismatch");
    let mut out = vec![0.0f32; u.len()];
    par_chunks_mut(&mut out, 1, 24, |range, oc| {
        for (oi, i) in range.clone().enumerate() {
            let x = u[i];
            let d = dg[i];
            let inner = GELU_C * (x + GELU_A * x * x * x);
            let t = inner.tanh();
            let dinner = GELU_C * (1.0 + 3.0 * GELU_A * x * x);
            oc[oi] = d * (0.5 * (1.0 + t) + 0.5 * x * (1.0 - t * t) * dinner);
        }
    });
    out
}

/// Causal row softmax of one (t x t) score tile into `p` (entries above
/// the diagonal stay exactly 0; `p` must arrive zeroed). Serial per tile —
/// the native backend fans tiles out across (batch, head) pairs.
pub fn causal_softmax(scores: &[f32], p: &mut [f32], t: usize) {
    assert_eq!(scores.len(), t * t, "causal_softmax: scores shape");
    assert_eq!(p.len(), t * t, "causal_softmax: p shape");
    for i in 0..t {
        let row = &scores[i * t..(i + 1) * t];
        let mut mx = f32::NEG_INFINITY;
        for &sv in row.iter().take(i + 1) {
            mx = mx.max(sv);
        }
        let mut z = 0.0f32;
        let prow = &mut p[i * t..(i + 1) * t];
        for j in 0..=i {
            let e = (row[j] - mx).exp();
            prow[j] = e;
            z += e;
        }
        for pj in prow.iter_mut().take(i + 1) {
            *pj /= z;
        }
    }
}

/// Per-position NLL without materializing probabilities (eval path),
/// row-parallel: `nll = -(l_target - max - ln(sum(exp(l - max))))`,
/// clamped finite so a diverged checkpoint scores terribly instead of
/// poisoning aggregates.
pub fn nll_only(logits: &[f32], y: &[i32], m: usize, v: usize) -> Vec<f32> {
    assert_eq!(logits.len(), m * v, "nll_only: logits shape");
    assert_eq!(y.len(), m, "nll_only: targets shape");
    let mut per_pos = vec![0.0f32; m];
    par_chunks_mut(&mut per_pos, 1, 6 * v, |rows, pp| {
        for (ri, r) in rows.clone().enumerate() {
            let row = &logits[r * v..(r + 1) * v];
            let mut mx = f32::NEG_INFINITY;
            for &l in row {
                mx = mx.max(l);
            }
            let mut z = 0.0f32;
            for &l in row {
                z += (l - mx).exp();
            }
            let nll = -(row[y[r] as usize] - mx - z.ln());
            pp[ri] = if nll.is_finite() { nll } else { -f32::MIN_POSITIVE.ln() };
        }
    });
    per_pos
}

/// Per-position NLL and softmax probabilities from logits (row-stable,
/// row-parallel; the backward path needs the probs for dlogits).
pub fn nll_rows(logits: &[f32], y: &[i32], m: usize, v: usize) -> (Vec<f32>, Vec<f32>) {
    assert_eq!(logits.len(), m * v, "nll_rows: logits shape");
    assert_eq!(y.len(), m, "nll_rows: targets shape");
    let mut per_pos = vec![0.0f32; m];
    let mut probs = vec![0.0f32; m * v];
    par_chunks2_mut(&mut per_pos, 1, &mut probs, v, 8 * v, |rows, pp, pc| {
        for (ri, r) in rows.clone().enumerate() {
            let row = &logits[r * v..(r + 1) * v];
            let mut mx = f32::NEG_INFINITY;
            for &l in row {
                mx = mx.max(l);
            }
            let prow = &mut pc[ri * v..(ri + 1) * v];
            let mut z = 0.0f32;
            for (pj, &l) in prow.iter_mut().zip(row.iter()) {
                let e = (l - mx).exp();
                *pj = e;
                z += e;
            }
            for pj in prow.iter_mut() {
                *pj /= z;
            }
            let target = y[r] as usize;
            pp[ri] = -(prow[target].max(f32::MIN_POSITIVE)).ln();
        }
    });
    (per_pos, probs)
}

#[cfg(test)]
mod tests {
    use super::*;

    // tests that mutate the process-wide thread knobs serialize on this
    static KNOBS: std::sync::Mutex<()> = std::sync::Mutex::new(());

    #[test]
    fn thread_override_wins() {
        let _g = KNOBS.lock().unwrap_or_else(|e| e.into_inner());
        set_threads(3);
        assert_eq!(max_threads(), 3);
        set_threads(0);
        assert!(max_threads() >= 1);
    }

    #[test]
    fn par_chunks_covers_every_chunk_once() {
        use std::sync::atomic::AtomicU32;
        let _g = KNOBS.lock().unwrap_or_else(|e| e.into_inner());
        set_threads(4);
        force_parallel(true);
        let mut data = vec![0u8; 37 * 3];
        let count = AtomicU32::new(0);
        par_chunks_mut(&mut data, 3, 1, |range, sub| {
            assert_eq!(sub.len(), (range.end - range.start) * 3);
            count.fetch_add((range.end - range.start) as u32, Ordering::Relaxed);
            for b in sub.iter_mut() {
                *b += 1;
            }
        });
        force_parallel(false);
        set_threads(0);
        assert_eq!(count.load(Ordering::Relaxed), 37);
        assert!(data.iter().all(|&b| b == 1), "every element touched exactly once");
    }

    #[test]
    fn matmul_small() {
        // [1 2; 3 4] @ [5 6; 7 8] = [19 22; 43 50]
        let a = [1.0, 2.0, 3.0, 4.0];
        let b = [5.0, 6.0, 7.0, 8.0];
        assert_eq!(matmul(&a, &b, 2, 2, 2), vec![19.0, 22.0, 43.0, 50.0]);
    }

    #[test]
    fn matches_serial_reference_on_fixed_case() {
        let m = 5;
        let k = K_PANEL + 3; // straddle a panel boundary
        let n = 7;
        let a: Vec<f32> = (0..m * k).map(|i| (i as f32 * 0.37).sin()).collect();
        let b: Vec<f32> = (0..k * n).map(|i| (i as f32 * 0.11).cos()).collect();
        assert_eq!(matmul(&a, &b, m, k, n), super::super::math::matmul(&a, &b, m, k, n));
    }

    #[test]
    #[should_panic(expected = "matmul_acc: a has wrong shape")]
    fn shape_checks_fire_in_release() {
        // promoted from debug_assert: must fail loudly in --release too
        let mut c = vec![0.0f32; 4];
        matmul_acc(&mut c, &[0.0; 3], &[0.0; 4], 2, 2, 2);
    }

    #[test]
    fn causal_softmax_rows_normalized_and_masked() {
        let t = 4;
        let scores: Vec<f32> = (0..t * t).map(|i| (i as f32 * 0.3).sin()).collect();
        let mut p = vec![0.0f32; t * t];
        causal_softmax(&scores, &mut p, t);
        for i in 0..t {
            let row = &p[i * t..(i + 1) * t];
            let sum: f32 = row.iter().take(i + 1).sum();
            assert!((sum - 1.0).abs() < 1e-6, "row {i} sums to {sum}");
            assert!(row.iter().skip(i + 1).all(|&x| x == 0.0), "row {i} not masked");
        }
    }
}
