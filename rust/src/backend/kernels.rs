//! Parallel, cache-blocked kernels for the native backend.
//!
//! Every kernel here computes **bit-identical** results to the retained
//! serial reference in [`super::math`], at any thread count, by
//! construction: work is split only across *independent output rows or
//! tiles*, and each output element is accumulated in the exact serial
//! order (k ascending in the matmuls, r ascending in the reductions).
//! Cross-output float reductions (layernorm dw/db, embedding wpe, the
//! global grad norm) run as **fixed-shape tree reductions**: the block
//! shape is a function of the problem size only — never of the thread
//! count — so the combine order is frozen and the results are identical at
//! every thread count (and to the serial reference, which walks the same
//! tree). The embedding wte scatter is parallelized owner-computes (each
//! worker owns a destination row range and accumulates its hits in
//! ascending batch order — exactly the serial scatter order per row).
//! `rust/tests/kernels.rs` asserts the equivalence property over
//! randomized and degenerate shapes; `rust/tests/native.rs` asserts full
//! train runs are invariant across `RAYON_NUM_THREADS` values.
//!
//! Threading substrate: a **persistent worker pool** (the offline crate
//! set has no rayon). Workers are spawned once — lazily on first parallel
//! dispatch, or eagerly via [`warm_pool`] when a `Runtime` is constructed —
//! and each fork-join hands a job per part to the shared queue, runs part
//! 0 inline, helps drain, and blocks on a per-dispatch barrier. This
//! replaces the per-call `std::thread::scope` spawn (~tens of µs per
//! kernel), which is what capped small-kernel scaling. Static contiguous
//! chunking is kept (no work stealing, no atomics in the hot loop), so the
//! split stays deterministic. The thread count resolves from, in priority
//! order: [`set_threads`] (the CLI `--threads` knob / `TrainHp::threads`),
//! the `RAYON_NUM_THREADS` or `QPRETRAIN_THREADS` environment variables,
//! then `available_parallelism`. Kernels fall back to the serial path
//! below a work threshold so tiny shapes don't pay handoff overhead.
//!
//! The matmul inner loops run on the runtime-dispatched [`super::simd`]
//! microkernels (AVX2/FMA f32x8 `axpy`/`dot`, widening i8→i32 lanes for
//! [`matmul_i8`]): the scalar emulation walks the exact same fixed
//! lane/tail structure, so results are bit-identical with or without SIMD
//! — and [`super::math`] uses the same microkernels serially, so the
//! kernels==math contract is preserved along both axes (threads × ISA).
//! The GEMM walks are **4-row register blocked** (`axpy4`/`dot4`/
//! `axpy4_i8`): each shared-operand load feeds four independent
//! accumulator rows, which changes only load scheduling — every output
//! element keeps its exact 1-row accumulation sequence, so the blocked
//! kernels stay bit-identical to [`super::math`]'s unblocked walk. The
//! knobs mirror the thread knobs: `QPRETRAIN_SIMD=off` env,
//! [`set_simd`] / [`with_simd`] / [`simd_active`] (re-exported from
//! [`super::simd`]).
//!
//! The module also hosts the packed-int8 GEMMs: forward [`matmul_i8`] /
//! [`matmul_i8_packed`] plus the backward forms [`matmul_i8_tn_packed`]
//! (weight grad), [`matmul_i8_nt_packed`] (input grad, reusing the
//! forward-packed weight operand) and the row-factored
//! [`matmul_i8_tn_scaled_acc`] for per-token scale sets. i32 accumulation
//! is exact, hence associative, hence trivially deterministic under any
//! parallel split; the rescale ([`rescale_i32`] / [`rescale_f32`]) is
//! elementwise. Packed operands carry rows padded to the i8 lane width
//! (`quant::PackedGemmOperand`), so the packed GEMMs never issue a
//! partial-lane load. The native backend dispatches to them for symmetric
//! 8-bit recipes (see `backend::native::int8_dispatch`).

use std::ops::Range;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::OnceLock;

pub use super::math::{GELU_A, GELU_C, LN_EPS, NORM_BLOCK, REDUCE_ROWS};
use super::simd;
pub use super::simd::{set_simd, simd_active, simd_supported, with_simd, F32_LANES, I8_LANES};

// ---------------------------------------------------------------------------
// thread-count resolution + fork-join substrate
// ---------------------------------------------------------------------------

/// Process-wide override set by `--threads` / `TrainHp::threads`; 0 = unset.
static THREAD_OVERRIDE: AtomicUsize = AtomicUsize::new(0);

/// Test hook: when set, [`plan`] ignores the work thresholds so property
/// tests exercise the parallel path even on tiny shapes.
static FORCE_PARALLEL: AtomicBool = AtomicBool::new(false);

/// Override the kernel thread count for this process (0 restores the
/// environment/auto resolution). Safe to call at any time; kernels read it
/// per invocation, and results are identical at every thread count.
pub fn set_threads(n: usize) {
    THREAD_OVERRIDE.store(n, Ordering::Relaxed);
}

/// Force the parallel path regardless of problem size (test hook for the
/// bit-exactness suite; leaves the thread count untouched).
pub fn force_parallel(on: bool) {
    FORCE_PARALLEL.store(on, Ordering::Relaxed);
}

fn env_threads() -> usize {
    static CACHE: OnceLock<usize> = OnceLock::new();
    *CACHE.get_or_init(|| {
        for key in ["RAYON_NUM_THREADS", "QPRETRAIN_THREADS"] {
            if let Ok(v) = std::env::var(key) {
                if let Ok(n) = v.trim().parse::<usize>() {
                    if n > 0 {
                        return n;
                    }
                }
            }
        }
        std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1)
    })
}

/// The raw process-wide override (0 = unset); lets callers save/restore
/// the knob around a scoped pin.
pub fn threads_override() -> usize {
    THREAD_OVERRIDE.load(Ordering::Relaxed)
}

/// The resolved kernel thread budget (override > env > all cores).
pub fn max_threads() -> usize {
    match THREAD_OVERRIDE.load(Ordering::Relaxed) {
        0 => env_threads(),
        n => n,
    }
}

/// Don't fork at all below this many scalar ops of total work… (the pool
/// handoff is ~µs, far below the old per-call spawn cost, so the floor sits
/// an order of magnitude lower than it did under `std::thread::scope`)
const MIN_PAR_WORK: usize = 1 << 17;
/// …and give every thread at least this much once we do.
const MIN_WORK_PER_THREAD: usize = 1 << 16;

/// Threads to use for `chunks` independent chunks of `work_per_chunk`
/// scalar ops each.
fn plan(chunks: usize, work_per_chunk: usize) -> usize {
    if chunks <= 1 {
        return 1;
    }
    if FORCE_PARALLEL.load(Ordering::Relaxed) {
        return max_threads().min(chunks).max(1);
    }
    let total = chunks.saturating_mul(work_per_chunk.max(1));
    if total < MIN_PAR_WORK {
        return 1;
    }
    max_threads()
        .min(total / MIN_WORK_PER_THREAD)
        .min(chunks)
        .max(1)
}

// ---------------------------------------------------------------------------
// persistent worker pool
// ---------------------------------------------------------------------------

/// The persistent worker pool behind every parallel kernel: workers are
/// spawned once per process (up to the requested part count) and reused by
/// every dispatch, replacing the per-call `std::thread::scope` spawn.
mod pool {
    use std::collections::VecDeque;
    use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
    use std::sync::{Arc, Condvar, Mutex, OnceLock};

    /// Hard cap on persistent workers. Tests pin absurd counts (64+); idle
    /// workers cost only a parked thread, but a bound keeps a bad knob
    /// value from exhausting the process thread limit.
    const MAX_WORKERS: usize = 192;

    /// One part of one dispatch. `ctx` is a type-erased pointer to the
    /// dispatcher's `Sync` closure; `call` is the monomorphized trampoline
    /// that knows its real type.
    struct Job {
        call: unsafe fn(*const (), usize),
        ctx: *const (),
        part: usize,
        state: Arc<DispatchState>,
    }

    // SAFETY: `ctx` points at a `Sync` closure on the dispatcher's stack,
    // and the dispatcher cannot return (or unwind) past its barrier until
    // every job has run, so the pointer never outlives its referent.
    unsafe impl Send for Job {}

    /// Per-dispatch barrier state (Arc'd so a worker signalling completion
    /// can never touch freed dispatcher stack).
    struct DispatchState {
        remaining: AtomicUsize,
        panicked: AtomicBool,
        lock: Mutex<()>,
        done: Condvar,
    }

    struct Shared {
        queue: Mutex<VecDeque<Job>>,
        ready: Condvar,
    }

    pub struct Pool {
        shared: Arc<Shared>,
        spawned: Mutex<usize>,
    }

    fn run_job(job: Job) {
        let ok = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| unsafe {
            (job.call)(job.ctx, job.part)
        }))
        .is_ok();
        if !ok {
            job.state.panicked.store(true, Ordering::SeqCst);
        }
        // decrement under the barrier lock so the dispatcher cannot miss
        // the wakeup between its counter check and its wait
        let _g = job.state.lock.lock().unwrap_or_else(|e| e.into_inner());
        if job.state.remaining.fetch_sub(1, Ordering::SeqCst) == 1 {
            job.state.done.notify_all();
        }
    }

    fn worker(shared: Arc<Shared>) {
        loop {
            let job = {
                let mut q = shared.queue.lock().unwrap_or_else(|e| e.into_inner());
                loop {
                    if let Some(j) = q.pop_front() {
                        break j;
                    }
                    q = shared.ready.wait(q).unwrap_or_else(|e| e.into_inner());
                }
            };
            run_job(job);
        }
    }

    impl Pool {
        /// Grow the pool to at least `want` workers (capped; workers are
        /// never torn down — they park on the queue condvar between jobs).
        pub fn ensure_workers(&self, want: usize) {
            let want = want.min(MAX_WORKERS);
            let mut n = self.spawned.lock().unwrap_or_else(|e| e.into_inner());
            while *n < want {
                let shared = Arc::clone(&self.shared);
                std::thread::Builder::new()
                    .name(format!("qpretrain-worker-{}", *n))
                    .spawn(move || worker(shared))
                    .expect("spawn kernel pool worker");
                *n += 1;
            }
        }

        /// Live persistent workers (0 before the first parallel dispatch).
        pub fn workers(&self) -> usize {
            *self.spawned.lock().unwrap_or_else(|e| e.into_inner())
        }
    }

    pub fn get() -> &'static Pool {
        static POOL: OnceLock<Pool> = OnceLock::new();
        POOL.get_or_init(|| Pool {
            shared: Arc::new(Shared {
                queue: Mutex::new(VecDeque::new()),
                ready: Condvar::new(),
            }),
            spawned: Mutex::new(0),
        })
    }

    /// Barrier guard: waits for every queued part of this dispatch even if
    /// the inline part panics — the queued jobs borrow the dispatcher's
    /// closure, so returning (or unwinding) before they finish would free
    /// it under them.
    struct Barrier<'a> {
        state: &'a DispatchState,
    }

    impl Drop for Barrier<'_> {
        fn drop(&mut self) {
            let mut g = self.state.lock.lock().unwrap_or_else(|e| e.into_inner());
            while self.state.remaining.load(Ordering::SeqCst) > 0 {
                g = self.state.done.wait(g).unwrap_or_else(|e| e.into_inner());
            }
            drop(g);
            if self.state.panicked.load(Ordering::SeqCst) && !std::thread::panicking() {
                panic!("kernel pool worker panicked");
            }
        }
    }

    /// Fork-join over the pool: run `f(part)` for every part in `0..parts`.
    /// Parts 1.. are enqueued for the workers, part 0 runs inline, and the
    /// caller helps drain the queue before blocking on the barrier — so a
    /// dispatch completes even when parts exceed live workers (or when a
    /// job itself dispatches). Which thread runs a part never affects the
    /// result: parts own disjoint output spans with fixed contents.
    pub fn dispatch<F: Fn(usize) + Sync>(parts: usize, f: &F) {
        if parts <= 1 {
            if parts == 1 {
                f(0);
            }
            return;
        }
        unsafe fn call<F: Fn(usize) + Sync>(ctx: *const (), part: usize) {
            (*(ctx as *const F))(part)
        }
        let pool = get();
        pool.ensure_workers(parts - 1);
        let state = Arc::new(DispatchState {
            remaining: AtomicUsize::new(parts - 1),
            panicked: AtomicBool::new(false),
            lock: Mutex::new(()),
            done: Condvar::new(),
        });
        let barrier = Barrier { state: &*state };
        {
            let mut q = pool.shared.queue.lock().unwrap_or_else(|e| e.into_inner());
            for part in 1..parts {
                q.push_back(Job {
                    call: call::<F>,
                    ctx: f as *const F as *const (),
                    part,
                    state: Arc::clone(&state),
                });
            }
        }
        pool.shared.ready.notify_all();
        f(0);
        // help drain: our own parts may still be queued while the workers
        // are busy, and running any queued job is forward progress. The
        // guard must drop before the job runs (a job may itself dispatch),
        // hence the scoped pop instead of a while-let over the lock.
        loop {
            let job = {
                let mut q = pool.shared.queue.lock().unwrap_or_else(|e| e.into_inner());
                q.pop_front()
            };
            let Some(job) = job else { break };
            run_job(job);
        }
        drop(barrier);
    }
}

/// Pre-spawn the worker pool for the resolved thread budget (called by
/// `Runtime` constructors so the first kernel dispatch of a run doesn't pay
/// thread-spawn latency; dispatches grow the pool on demand either way).
pub fn warm_pool() {
    let n = max_threads();
    if n > 1 {
        pool::get().ensure_workers(n - 1);
    }
}

/// Live persistent pool workers (0 until the pool is first used/warmed).
pub fn pool_workers() -> usize {
    pool::get().workers()
}

/// Run `f` with the thread override pinned to `n` (0 = restore the
/// environment/auto resolution), restoring the previous override afterwards
/// even on panic. Results are identical at every value; only wall-clock
/// changes.
pub fn with_threads<T>(n: usize, f: impl FnOnce() -> T) -> T {
    struct Restore(usize);
    impl Drop for Restore {
        fn drop(&mut self) {
            THREAD_OVERRIDE.store(self.0, Ordering::Relaxed);
        }
    }
    let _guard = Restore(THREAD_OVERRIDE.swap(n, Ordering::Relaxed));
    f()
}

/// Raw mutable base pointer that may be captured by a `Sync` dispatch
/// closure. Soundness is the caller's: parts must write disjoint spans.
struct SendPtr<T>(*mut T);
unsafe impl<T: Send> Sync for SendPtr<T> {}
impl<T> Clone for SendPtr<T> {
    fn clone(&self) -> Self {
        *self
    }
}
impl<T> Copy for SendPtr<T> {}

/// Part boundaries for `chunks` chunks split `nt` ways: part `p` covers
/// chunk indices `p*per..min((p+1)*per, chunks)` (empty for trailing parts
/// when the split is uneven).
fn part_range(part: usize, per: usize, chunks: usize) -> Range<usize> {
    let start = (part * per).min(chunks);
    let end = ((part + 1) * per).min(chunks);
    start..end
}

/// Run `f` over contiguous spans of `data`, viewed as `data.len() / chunk`
/// chunks of `chunk` elements. `f(range, sub)` receives the global chunk
/// index range and the matching sub-slice; spans are disjoint, so the split
/// is race-free by construction. Runs serially (one call covering all
/// chunks) when the work is too small to be worth a pool handoff.
pub fn par_chunks_mut<T, F>(data: &mut [T], chunk: usize, work_per_chunk: usize, f: F)
where
    T: Send,
    F: Fn(Range<usize>, &mut [T]) + Sync,
{
    assert!(chunk > 0, "chunk size must be positive");
    assert_eq!(data.len() % chunk, 0, "buffer is not whole chunks");
    let chunks = data.len() / chunk;
    if chunks == 0 {
        return;
    }
    let nt = plan(chunks, work_per_chunk);
    if nt <= 1 {
        f(0..chunks, data);
        return;
    }
    let per = chunks.div_ceil(nt);
    let base = SendPtr(data.as_mut_ptr());
    pool::dispatch(nt, &|part| {
        let r = part_range(part, per, chunks);
        if r.is_empty() {
            return;
        }
        // SAFETY: parts cover disjoint chunk ranges within bounds, and the
        // dispatch barrier ends every view before `data`'s borrow does.
        let sub = unsafe {
            std::slice::from_raw_parts_mut(base.0.add(r.start * chunk), r.len() * chunk)
        };
        f(r, sub);
    });
}

/// Two-buffer variant of [`par_chunks_mut`]: both buffers are split at the
/// same chunk boundaries (they must contain the same number of chunks).
pub fn par_chunks2_mut<A, B, F>(
    a: &mut [A],
    ca: usize,
    b: &mut [B],
    cb: usize,
    work_per_chunk: usize,
    f: F,
) where
    A: Send,
    B: Send,
    F: Fn(Range<usize>, &mut [A], &mut [B]) + Sync,
{
    assert!(ca > 0 && cb > 0, "chunk sizes must be positive");
    assert!(a.len() % ca == 0 && b.len() % cb == 0, "buffers not whole chunks");
    let chunks = a.len() / ca;
    assert_eq!(chunks, b.len() / cb, "chunk counts differ");
    if chunks == 0 {
        return;
    }
    let nt = plan(chunks, work_per_chunk);
    if nt <= 1 {
        f(0..chunks, a, b);
        return;
    }
    let per = chunks.div_ceil(nt);
    let pa = SendPtr(a.as_mut_ptr());
    let pb = SendPtr(b.as_mut_ptr());
    pool::dispatch(nt, &|part| {
        let r = part_range(part, per, chunks);
        if r.is_empty() {
            return;
        }
        // SAFETY: as in `par_chunks_mut`, per buffer.
        let (sa, sb) = unsafe {
            (
                std::slice::from_raw_parts_mut(pa.0.add(r.start * ca), r.len() * ca),
                std::slice::from_raw_parts_mut(pb.0.add(r.start * cb), r.len() * cb),
            )
        };
        f(r, sa, sb);
    });
}

/// Three-buffer variant of [`par_chunks_mut`] (same chunk counts required).
pub fn par_chunks3_mut<A, B, C, F>(
    a: &mut [A],
    ca: usize,
    b: &mut [B],
    cb: usize,
    c: &mut [C],
    cc: usize,
    work_per_chunk: usize,
    f: F,
) where
    A: Send,
    B: Send,
    C: Send,
    F: Fn(Range<usize>, &mut [A], &mut [B], &mut [C]) + Sync,
{
    assert!(ca > 0 && cb > 0 && cc > 0, "chunk sizes must be positive");
    assert!(
        a.len() % ca == 0 && b.len() % cb == 0 && c.len() % cc == 0,
        "buffers not whole chunks"
    );
    let chunks = a.len() / ca;
    assert_eq!(chunks, b.len() / cb, "chunk counts differ");
    assert_eq!(chunks, c.len() / cc, "chunk counts differ");
    if chunks == 0 {
        return;
    }
    let nt = plan(chunks, work_per_chunk);
    if nt <= 1 {
        f(0..chunks, a, b, c);
        return;
    }
    let per = chunks.div_ceil(nt);
    let pa = SendPtr(a.as_mut_ptr());
    let pb = SendPtr(b.as_mut_ptr());
    let pc = SendPtr(c.as_mut_ptr());
    pool::dispatch(nt, &|part| {
        let r = part_range(part, per, chunks);
        if r.is_empty() {
            return;
        }
        // SAFETY: as in `par_chunks_mut`, per buffer.
        let (sa, sb, sc) = unsafe {
            (
                std::slice::from_raw_parts_mut(pa.0.add(r.start * ca), r.len() * ca),
                std::slice::from_raw_parts_mut(pb.0.add(r.start * cb), r.len() * cb),
                std::slice::from_raw_parts_mut(pc.0.add(r.start * cc), r.len() * cc),
            )
        };
        f(r, sa, sb, sc);
    });
}

// ---------------------------------------------------------------------------
// matmul kernels (row-parallel, k-panel cache blocking)
// ---------------------------------------------------------------------------

/// k-dimension panel size: a panel of `b` rows (K_PANEL x n) stays cache
/// resident while it is re-used across every output row of a thread's
/// chunk. Panels are walked in ascending k order, so each output element
/// still accumulates in the exact serial order.
pub const K_PANEL: usize = 64;

/// `c = a @ b` where a is (m x k), b is (k x n), all row-major.
pub fn matmul(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    let mut c = vec![0.0f32; m * n];
    matmul_acc(&mut c, a, b, m, k, n);
    c
}

/// `c += a @ b` (shapes as [`matmul`]).
pub fn matmul_acc(c: &mut [f32], a: &[f32], b: &[f32], m: usize, k: usize, n: usize) {
    assert_eq!(a.len(), m * k, "matmul_acc: a has wrong shape");
    assert_eq!(b.len(), k * n, "matmul_acc: b has wrong shape");
    assert_eq!(c.len(), m * n, "matmul_acc: c has wrong shape");
    if m == 0 || n == 0 || k == 0 {
        return;
    }
    par_chunks_mut(c, n, 2 * k * n, |rows, cc| {
        let nrows = rows.end - rows.start;
        for l0 in (0..k).step_by(K_PANEL) {
            let l1 = (l0 + K_PANEL).min(k);
            // 4-row register blocks: one b-row load feeds 4 output rows.
            // Each output row still accumulates k-ascending, so the block
            // walk is bit-identical to the 1-row walk (and to math::matmul).
            let mut ri = 0;
            while ri + 4 <= nrows {
                let i = rows.start + ri;
                let cblk = &mut cc[ri * n..(ri + 4) * n];
                for l in l0..l1 {
                    let coeff = [
                        a[i * k + l],
                        a[(i + 1) * k + l],
                        a[(i + 2) * k + l],
                        a[(i + 3) * k + l],
                    ];
                    simd::axpy4(cblk, &coeff, &b[l * n..(l + 1) * n]);
                }
                ri += 4;
            }
            while ri < nrows {
                let i = rows.start + ri;
                let arow = &a[i * k..(i + 1) * k];
                let crow = &mut cc[ri * n..(ri + 1) * n];
                for l in l0..l1 {
                    simd::axpy(crow, arow[l], &b[l * n..(l + 1) * n]);
                }
                ri += 1;
            }
        }
    });
}

/// `aᵀ @ b` where a is (m x k), b is (m x n); result is (k x n).
pub fn matmul_tn(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    let mut c = vec![0.0f32; k * n];
    matmul_tn_acc(&mut c, a, b, m, k, n);
    c
}

/// `c += aᵀ @ b` (shapes as [`matmul_tn`]) — the weight-gradient kernel.
/// Parallel over output rows (the k dimension); the reduction dimension m
/// is walked in ascending order per output element, matching the serial
/// reference bit for bit. Each thread's output chunk is small enough to
/// stay cache resident across the whole reduction.
pub fn matmul_tn_acc(c: &mut [f32], a: &[f32], b: &[f32], m: usize, k: usize, n: usize) {
    assert_eq!(a.len(), m * k, "matmul_tn_acc: a has wrong shape");
    assert_eq!(b.len(), m * n, "matmul_tn_acc: b has wrong shape");
    assert_eq!(c.len(), k * n, "matmul_tn_acc: c has wrong shape");
    if m == 0 || n == 0 || k == 0 {
        return;
    }
    par_chunks_mut(c, n, 2 * m * n, |lrange, cc| {
        let nl = lrange.end - lrange.start;
        for r in 0..m {
            let arow = &a[r * k..(r + 1) * k];
            let brow = &b[r * n..(r + 1) * n];
            // 4-row blocks over the output rows (the k dimension): the
            // shared b row is loaded once per 4 accumulator rows, and each
            // output row keeps its exact r-ascending accumulation order
            let mut li = 0;
            while li + 4 <= nl {
                let l = lrange.start + li;
                let coeff = [arow[l], arow[l + 1], arow[l + 2], arow[l + 3]];
                simd::axpy4(&mut cc[li * n..(li + 4) * n], &coeff, brow);
                li += 4;
            }
            while li < nl {
                let l = lrange.start + li;
                simd::axpy(&mut cc[li * n..(li + 1) * n], arow[l], brow);
                li += 1;
            }
        }
    });
}

/// `a @ bᵀ` where a is (m x k), b is (n x k); result is (m x n).
/// Dot-product form, parallel over output rows.
pub fn matmul_nt(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    assert_eq!(a.len(), m * k, "matmul_nt: a has wrong shape");
    assert_eq!(b.len(), n * k, "matmul_nt: b has wrong shape");
    let mut c = vec![0.0f32; m * n];
    if m == 0 || n == 0 {
        return c;
    }
    par_chunks_mut(&mut c, n, 2 * k * n, |rows, cc| {
        for (ri, i) in rows.clone().enumerate() {
            let arow = &a[i * k..(i + 1) * k];
            let crow = &mut cc[ri * n..(ri + 1) * n];
            // 4-column blocks: the a row is loaded once per 4 dot products
            // (four independent accumulators, each folding on the exact
            // 1-row lane tree, so every output bit is unchanged)
            let mut j = 0;
            while j + 4 <= n {
                let d4 = simd::dot4(arow, &b[j * k..(j + 4) * k]);
                crow[j..j + 4].copy_from_slice(&d4);
                j += 4;
            }
            while j < n {
                crow[j] = simd::dot(arow, &b[j * k..(j + 1) * k]);
                j += 1;
            }
        }
    });
    c
}

/// Column sums accumulated into `acc` (the bias-gradient kernel), parallel
/// over column blocks; rows are reduced in ascending order per column.
pub fn col_sum_acc(acc: &mut [f32], x: &[f32], rows: usize, cols: usize) {
    assert_eq!(x.len(), rows * cols, "col_sum_acc: x has wrong shape");
    assert_eq!(acc.len(), cols, "col_sum_acc: acc has wrong shape");
    if rows == 0 || cols == 0 {
        return;
    }
    par_chunks_mut(acc, 1, 2 * rows, |crange, ac| {
        for r in 0..rows {
            let row = &x[r * cols..(r + 1) * cols];
            for (ci, c) in crange.clone().enumerate() {
                ac[ci] += row[c];
            }
        }
    });
}

// ---------------------------------------------------------------------------
// packed-int8 GEMM (the quantized fast path)
// ---------------------------------------------------------------------------

/// `c = a @ b` over tightly packed int8 codes with i32 accumulation, a is
/// (m x k), b is (k x n), row-major, k-panel blocked and row-parallel like
/// [`matmul`]. For |codes| <= 127 the i32 accumulator is exact up to
/// k ~ 2^17 rows of reduction — far beyond any model dimension here — so
/// integer adds are associative and both the parallel split and the SIMD
/// lane layout are deterministic by arithmetic, not just by ordering
/// discipline. The b rows are staged into an [`I8_LANES`]-padded scratch
/// so the widening inner loop never issues a partial-lane load; the
/// native backend's hot path uses [`matmul_i8_packed`], whose operands
/// ship pre-padded from `quant::pack_{acts,weights}_i8`.
pub fn matmul_i8(a: &[i8], b: &[i8], m: usize, k: usize, n: usize) -> Vec<i32> {
    assert_eq!(a.len(), m * k, "matmul_i8: a has wrong shape");
    assert_eq!(b.len(), k * n, "matmul_i8: b has wrong shape");
    if m == 0 || n == 0 || k == 0 {
        return vec![0i32; m * n];
    }
    let sb = n.next_multiple_of(I8_LANES);
    if sb == n {
        return matmul_i8_core(a, k, b, sb, m, k, n);
    }
    let mut bp = vec![0i8; k * sb];
    for l in 0..k {
        bp[l * sb..l * sb + n].copy_from_slice(&b[l * n..(l + 1) * n]);
    }
    matmul_i8_core(a, k, &bp, sb, m, k, n)
}

/// [`matmul_i8`] over pre-padded [`crate::quant::PackedGemmOperand`]s (the
/// layout `quant::pack_acts_i8` / `pack_weights_i8` produce): rows are
/// padded to [`I8_LANES`] with zero codes, which contribute exactly 0 to
/// the i32 accumulator, so the hot loop runs full lanes with no tail.
pub fn matmul_i8_packed(
    x: &crate::quant::PackedGemmOperand,
    w: &crate::quant::PackedGemmOperand,
) -> Vec<i32> {
    let (m, k, n) = (x.rows, x.cols, w.cols);
    assert_eq!(x.cols, w.rows, "matmul_i8_packed: inner dims differ");
    if m == 0 || n == 0 || k == 0 {
        return vec![0i32; m * n];
    }
    matmul_i8_core(&x.codes, x.stride, &w.codes, w.stride, m, k, n)
}

/// Shared strided core: a is (m x k) with row stride `sa`, b is (k x n)
/// with row stride `sb` (a multiple of [`I8_LANES`] when the operand is
/// lane-padded); accumulates into an sb-wide scratch and trims the padded
/// columns at the end.
fn matmul_i8_core(
    a: &[i8],
    sa: usize,
    b: &[i8],
    sb: usize,
    m: usize,
    k: usize,
    n: usize,
) -> Vec<i32> {
    let mut cp = vec![0i32; m * sb];
    par_chunks_mut(&mut cp, sb, 2 * k * sb, |rows, cc| {
        let nrows = rows.end - rows.start;
        for l0 in (0..k).step_by(K_PANEL) {
            let l1 = (l0 + K_PANEL).min(k);
            // 4-row register blocks, as in matmul_acc (i32 accumulation is
            // exact, so the blocking is trivially value-preserving here)
            let mut ri = 0;
            while ri + 4 <= nrows {
                let i = rows.start + ri;
                let cblk = &mut cc[ri * sb..(ri + 4) * sb];
                for l in l0..l1 {
                    let coeff = [
                        a[i * sa + l],
                        a[(i + 1) * sa + l],
                        a[(i + 2) * sa + l],
                        a[(i + 3) * sa + l],
                    ];
                    simd::axpy4_i8(cblk, &coeff, &b[l * sb..(l + 1) * sb]);
                }
                ri += 4;
            }
            while ri < nrows {
                let i = rows.start + ri;
                let arow = &a[i * sa..i * sa + k];
                let crow = &mut cc[ri * sb..(ri + 1) * sb];
                for l in l0..l1 {
                    simd::axpy_i8(crow, arow[l], &b[l * sb..(l + 1) * sb]);
                }
                ri += 1;
            }
        }
    });
    if sb == n {
        return cp;
    }
    let mut c = vec![0i32; m * n];
    for i in 0..m {
        c[i * n..(i + 1) * n].copy_from_slice(&cp[i * sb..i * sb + n]);
    }
    c
}

// ---------------------------------------------------------------------------
// backward packed-int8 GEMMs (weight-grad tn and input-grad nt forms)
// ---------------------------------------------------------------------------

/// Group scale for row `r` of a packed operand whose scales broadcast
/// row-wise (length 1 per-tensor, length `rows` per-token).
#[inline(always)]
fn row_scale(p: &crate::quant::PackedGemmOperand, r: usize) -> f32 {
    if p.scales.len() == 1 {
        p.scales[0]
    } else {
        p.scales[r]
    }
}

/// Weight-grad contraction `xᵀ @ g` over packed codes with exact i32
/// accumulation: x is packed (m x k) activations, g is packed (m x n)
/// gradients, result is (k x n). Valid only when **both** scale sets are
/// per-tensor — the reduction runs over the m rows, so any per-token scale
/// would vary along it; the native dispatcher routes those recipes to
/// [`matmul_i8_tn_scaled_acc`] instead. Row-parallel over the k output
/// rows with the same 4-row register blocks as [`matmul_i8`]; i32
/// accumulation is exact, hence deterministic under any split.
pub fn matmul_i8_tn_packed(
    x: &crate::quant::PackedGemmOperand,
    g: &crate::quant::PackedGemmOperand,
) -> Vec<i32> {
    let (m, k, n) = (x.rows, x.cols, g.cols);
    assert_eq!(g.rows, m, "matmul_i8_tn_packed: reduction dims differ");
    if m == 0 || n == 0 || k == 0 {
        return vec![0i32; k * n];
    }
    let sg = g.stride;
    let mut cp = vec![0i32; k * sg];
    par_chunks_mut(&mut cp, sg, 2 * m * sg, |lrange, cc| {
        let nl = lrange.end - lrange.start;
        for r in 0..m {
            let xrow = &x.codes[r * x.stride..r * x.stride + k];
            let grow = &g.codes[r * sg..(r + 1) * sg];
            let mut li = 0;
            while li + 4 <= nl {
                let l = lrange.start + li;
                let coeff = [xrow[l], xrow[l + 1], xrow[l + 2], xrow[l + 3]];
                simd::axpy4_i8(&mut cc[li * sg..(li + 4) * sg], &coeff, grow);
                li += 4;
            }
            while li < nl {
                let l = lrange.start + li;
                simd::axpy_i8(&mut cc[li * sg..(li + 1) * sg], xrow[l], grow);
                li += 1;
            }
        }
    });
    if sg == n {
        return cp;
    }
    let mut c = vec![0i32; k * n];
    for l in 0..k {
        c[l * n..(l + 1) * n].copy_from_slice(&cp[l * sg..l * sg + n]);
    }
    c
}

/// Row-factored weight-grad contraction `dw += xᵀ @ g` for per-token
/// scales: both operands arrive as packed codes, and reduction row `r`
/// contributes `(sx_r * sg_r * x[r,l]) * g[r,:]` to output row `l`. The
/// per-row scale product is hoisted into the axpy coefficient, so the
/// inner loops run on raw integer codes (as f32) — no per-element
/// dequantized operand is ever materialized. The accumulation walks the
/// exact loop structure of [`matmul_tn_acc`] (r ascending per output
/// element, 4-row blocks), so when the scales are powers of two every
/// float product equals the materialized-qdq oracle's and the result is
/// bit-identical to it; the path is independent of the int8 accumulator
/// knob because the integer code products (<= 127^2) are exact in f32.
pub fn matmul_i8_tn_scaled_acc(
    dw: &mut [f32],
    x: &crate::quant::PackedGemmOperand,
    g: &crate::quant::PackedGemmOperand,
) {
    let (m, k, n) = (x.rows, x.cols, g.cols);
    assert_eq!(g.rows, m, "matmul_i8_tn_scaled_acc: reduction dims differ");
    assert_eq!(dw.len(), k * n, "matmul_i8_tn_scaled_acc: dw has wrong shape");
    if m == 0 || n == 0 || k == 0 {
        return;
    }
    // stage the gradient codes once as a tight f32 matrix (shared by every
    // part; the per-part work is O(m*k*n/parts), this is O(m*n) once)
    let gf = crate::quant::codes_f32(g);
    par_chunks_mut(dw, n, 2 * m * n, |lrange, cc| {
        let nl = lrange.end - lrange.start;
        for r in 0..m {
            let s = row_scale(x, r) * row_scale(g, r);
            let xrow = &x.codes[r * x.stride..r * x.stride + k];
            let grow = &gf[r * n..(r + 1) * n];
            let mut li = 0;
            while li + 4 <= nl {
                let l = lrange.start + li;
                let coeff = [
                    s * xrow[l] as f32,
                    s * xrow[l + 1] as f32,
                    s * xrow[l + 2] as f32,
                    s * xrow[l + 3] as f32,
                ];
                simd::axpy4(&mut cc[li * n..(li + 4) * n], &coeff, grow);
                li += 4;
            }
            while li < nl {
                let l = lrange.start + li;
                simd::axpy(&mut cc[li * n..(li + 1) * n], s * xrow[l] as f32, grow);
                li += 1;
            }
        }
    });
}

/// Input-grad contraction `g @ wᵀ` over packed codes with exact i32
/// accumulation: g is packed (m x n_out) gradients, w is the packed
/// forward weight in its native (k_in x n_out) layout — the **same**
/// operand [`matmul_i8_packed`] consumed forward, reused here with its
/// rows as the nt dot operands. Result is (m x k_in). Both operands pad
/// their rows to the same lane stride (equal `cols`), and the padding
/// codes are zero, so the dot runs over the full padded rows with no
/// tail. Valid only when the weight scales are per-tensor (per-channel
/// scales vary along this reduction; the native dispatcher dequantizes
/// the cached codes and falls back to [`matmul_nt`] there).
pub fn matmul_i8_nt_packed(
    g: &crate::quant::PackedGemmOperand,
    w: &crate::quant::PackedGemmOperand,
) -> Vec<i32> {
    let (m, n) = (g.rows, w.rows);
    assert_eq!(g.cols, w.cols, "matmul_i8_nt_packed: reduction dims differ");
    assert_eq!(g.stride, w.stride, "matmul_i8_nt_packed: operand strides differ");
    let s = g.stride;
    let mut c = vec![0i32; m * n];
    if m == 0 || n == 0 {
        return c;
    }
    par_chunks_mut(&mut c, n, 2 * s.max(1) * n, |rows, cc| {
        for (ri, i) in rows.clone().enumerate() {
            let grow = &g.codes[i * s..(i + 1) * s];
            let crow = &mut cc[ri * n..(ri + 1) * n];
            for (l, cv) in crow.iter_mut().enumerate() {
                *cv = simd::dot_i8(grow, &w.codes[l * s..(l + 1) * s]);
            }
        }
    });
    c
}

/// Single rescale of an i32 GEMM accumulator back to f32:
/// `y[i,j] = (sa_i * sb_j) * c[i,j]`, with length-1 scale vectors
/// broadcasting (per-tensor operands). Elementwise and row-parallel, so
/// deterministic at every thread count.
pub fn rescale_i32(
    c: &[i32],
    row_scales: &[f32],
    col_scales: &[f32],
    m: usize,
    n: usize,
) -> Vec<f32> {
    let mut y = vec![0.0f32; m * n];
    rescale_i32_into(&mut y, c, row_scales, col_scales, m, n, false);
    y
}

/// Accumulating variant of [`rescale_i32`]: `acc[i,j] += (sa_i*sb_j)*c[i,j]`
/// (the residual-add form the out-proj / FC2 linears need).
pub fn rescale_i32_acc(
    acc: &mut [f32],
    c: &[i32],
    row_scales: &[f32],
    col_scales: &[f32],
    m: usize,
    n: usize,
) {
    rescale_i32_into(acc, c, row_scales, col_scales, m, n, true);
}

fn rescale_i32_into(
    out: &mut [f32],
    c: &[i32],
    row_scales: &[f32],
    col_scales: &[f32],
    m: usize,
    n: usize,
    accumulate: bool,
) {
    assert_eq!(c.len(), m * n, "rescale_i32: c has wrong shape");
    assert_eq!(out.len(), m * n, "rescale_i32: out has wrong shape");
    assert!(
        row_scales.len() == 1 || row_scales.len() == m,
        "rescale_i32: row scales must be 1 or m"
    );
    assert!(
        col_scales.len() == 1 || col_scales.len() == n,
        "rescale_i32: col scales must be 1 or n"
    );
    if m == 0 || n == 0 {
        return;
    }
    par_chunks_mut(out, n, 4 * n, |rows, oc| {
        for (ri, i) in rows.clone().enumerate() {
            let sr = if row_scales.len() == 1 {
                row_scales[0]
            } else {
                row_scales[i]
            };
            let crow = &c[i * n..(i + 1) * n];
            let orow = &mut oc[ri * n..(ri + 1) * n];
            for j in 0..n {
                let sc = if col_scales.len() == 1 {
                    col_scales[0]
                } else {
                    col_scales[j]
                };
                let v = (sr * sc) * crow[j] as f32;
                if accumulate {
                    orow[j] += v;
                } else {
                    orow[j] = v;
                }
            }
        }
    });
}

/// [`rescale_i32`] over an f32 accumulator — the `QPRETRAIN_INT8=off` leg
/// of the packed GEMMs, where the integer code products were folded in f32
/// (`quant::codes_f32` operands). The scale expression is the identical
/// `(sa_i * sb_j) * c[i,j]`, so wherever the f32 fold of the code products
/// was exact the two legs agree bit for bit.
pub fn rescale_f32(
    c: &[f32],
    row_scales: &[f32],
    col_scales: &[f32],
    m: usize,
    n: usize,
) -> Vec<f32> {
    let mut y = vec![0.0f32; m * n];
    rescale_f32_into(&mut y, c, row_scales, col_scales, m, n, false);
    y
}

/// Accumulating variant of [`rescale_f32`].
pub fn rescale_f32_acc(
    acc: &mut [f32],
    c: &[f32],
    row_scales: &[f32],
    col_scales: &[f32],
    m: usize,
    n: usize,
) {
    rescale_f32_into(acc, c, row_scales, col_scales, m, n, true);
}

fn rescale_f32_into(
    out: &mut [f32],
    c: &[f32],
    row_scales: &[f32],
    col_scales: &[f32],
    m: usize,
    n: usize,
    accumulate: bool,
) {
    assert_eq!(c.len(), m * n, "rescale_f32: c has wrong shape");
    assert_eq!(out.len(), m * n, "rescale_f32: out has wrong shape");
    assert!(
        row_scales.len() == 1 || row_scales.len() == m,
        "rescale_f32: row scales must be 1 or m"
    );
    assert!(
        col_scales.len() == 1 || col_scales.len() == n,
        "rescale_f32: col scales must be 1 or n"
    );
    if m == 0 || n == 0 {
        return;
    }
    par_chunks_mut(out, n, 4 * n, |rows, oc| {
        for (ri, i) in rows.clone().enumerate() {
            let sr = if row_scales.len() == 1 {
                row_scales[0]
            } else {
                row_scales[i]
            };
            let crow = &c[i * n..(i + 1) * n];
            let orow = &mut oc[ri * n..(ri + 1) * n];
            for j in 0..n {
                let sc = if col_scales.len() == 1 {
                    col_scales[0]
                } else {
                    col_scales[j]
                };
                let v = (sr * sc) * crow[j];
                if accumulate {
                    orow[j] += v;
                } else {
                    orow[j] = v;
                }
            }
        }
    });
}

// ---------------------------------------------------------------------------
// elementwise / row-wise kernels
// ---------------------------------------------------------------------------

/// `a += b` elementwise (residual-gradient accumulation).
pub fn add_assign(a: &mut [f32], b: &[f32]) {
    assert_eq!(a.len(), b.len(), "add_assign: length mismatch");
    par_chunks_mut(a, 1, 2, |range, ac| {
        for (ai, i) in range.clone().enumerate() {
            ac[ai] += b[i];
        }
    });
}

/// Add a length-`cols` bias row to every row of the (rows x cols) matrix.
pub fn bias_add(x: &mut [f32], bias: &[f32], rows: usize, cols: usize) {
    assert_eq!(x.len(), rows * cols, "bias_add: x has wrong shape");
    assert_eq!(bias.len(), cols, "bias_add: bias has wrong shape");
    if rows == 0 || cols == 0 {
        return;
    }
    par_chunks_mut(x, cols, cols, |rows_r, xc| {
        for ri in 0..(rows_r.end - rows_r.start) {
            let row = &mut xc[ri * cols..(ri + 1) * cols];
            for (rv, &bv) in row.iter_mut().zip(bias.iter()) {
                *rv += bv;
            }
        }
    });
}

/// Row-wise layernorm over (rows x d), parallel over rows; identical
/// per-row arithmetic to [`super::math::layer_norm_fwd`].
pub fn layer_norm_fwd(
    x: &[f32],
    w: &[f32],
    b: &[f32],
    rows: usize,
    d: usize,
) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    assert_eq!(x.len(), rows * d, "layer_norm_fwd: x has wrong shape");
    assert_eq!(w.len(), d, "layer_norm_fwd: w has wrong shape");
    assert_eq!(b.len(), d, "layer_norm_fwd: b has wrong shape");
    let mut y = vec![0.0f32; rows * d];
    let mut xhat = vec![0.0f32; rows * d];
    let mut rstd = vec![0.0f32; rows];
    if rows == 0 || d == 0 {
        return (y, xhat, rstd);
    }
    par_chunks3_mut(&mut y, d, &mut xhat, d, &mut rstd, 1, 8 * d, |rr, yc, xc, rc| {
        for (ri, r) in rr.clone().enumerate() {
            let xr = &x[r * d..(r + 1) * d];
            let mut mean = 0.0f32;
            for &v in xr {
                mean += v;
            }
            mean /= d as f32;
            let mut var = 0.0f32;
            for &v in xr {
                let dv = v - mean;
                var += dv * dv;
            }
            var /= d as f32;
            let rs = 1.0 / (var + LN_EPS).sqrt();
            rc[ri] = rs;
            let xh = &mut xc[ri * d..(ri + 1) * d];
            let yr = &mut yc[ri * d..(ri + 1) * d];
            for c in 0..d {
                let h = (xr[c] - mean) * rs;
                xh[c] = h;
                yr[c] = h * w[c] + b[c];
            }
        }
    });
    (y, xhat, rstd)
}

/// Layernorm backward: dx is computed row-parallel; the dw/db column
/// accumulators run on the fixed [`REDUCE_ROWS`] reduction tree — block
/// partials computed in parallel (each block's rows ascending, via the
/// shared `math::layer_norm_dwdb_block`), combined serially in ascending
/// block order — bit-identical to [`super::math::layer_norm_bwd`] at every
/// thread count.
pub fn layer_norm_bwd(
    dy: &[f32],
    xhat: &[f32],
    rstd: &[f32],
    w: &[f32],
    rows: usize,
    d: usize,
    dw_acc: &mut [f32],
    db_acc: &mut [f32],
) -> Vec<f32> {
    assert_eq!(dy.len(), rows * d, "layer_norm_bwd: dy has wrong shape");
    assert_eq!(xhat.len(), rows * d, "layer_norm_bwd: xhat has wrong shape");
    assert_eq!(rstd.len(), rows, "layer_norm_bwd: rstd has wrong shape");
    assert_eq!(w.len(), d, "layer_norm_bwd: w has wrong shape");
    assert_eq!(dw_acc.len(), d, "layer_norm_bwd: dw has wrong shape");
    assert_eq!(db_acc.len(), d, "layer_norm_bwd: db has wrong shape");
    let mut dx = vec![0.0f32; rows * d];
    if rows == 0 || d == 0 {
        return dx;
    }
    par_chunks_mut(&mut dx, d, 12 * d, |rr, dxc| {
        for (ri, r) in rr.clone().enumerate() {
            let dyr = &dy[r * d..(r + 1) * d];
            let xhr = &xhat[r * d..(r + 1) * d];
            let mut m1 = 0.0f32; // mean(dxhat)
            let mut m2 = 0.0f32; // mean(dxhat * xhat)
            for c in 0..d {
                let dxh = dyr[c] * w[c];
                m1 += dxh;
                m2 += dxh * xhr[c];
            }
            m1 /= d as f32;
            m2 /= d as f32;
            let rs = rstd[r];
            let dxr = &mut dxc[ri * d..(ri + 1) * d];
            for c in 0..d {
                let dxh = dyr[c] * w[c];
                dxr[c] = rs * (dxh - m1 - xhr[c] * m2);
            }
        }
    });
    // fixed-shape dw/db tree: one partial pair per REDUCE_ROWS block,
    // blocks in parallel, partials combined serially in ascending order —
    // the identical float-add tree `math::layer_norm_dwdb` walks serially
    let blocks = rows.div_ceil(REDUCE_ROWS);
    let mut partials = vec![0.0f32; blocks * 2 * d];
    par_chunks_mut(&mut partials, 2 * d, 3 * REDUCE_ROWS * d, |brange, pc| {
        for (bi, b) in brange.clone().enumerate() {
            let b0 = b * REDUCE_ROWS;
            let b1 = (b0 + REDUCE_ROWS).min(rows);
            let (pw, pb) = pc[bi * 2 * d..(bi + 1) * 2 * d].split_at_mut(d);
            super::math::layer_norm_dwdb_block(dy, xhat, b0, b1, d, pw, pb);
        }
    });
    for b in 0..blocks {
        let pw = &partials[b * 2 * d..b * 2 * d + d];
        let pb = &partials[b * 2 * d + d..(b + 1) * 2 * d];
        for c in 0..d {
            dw_acc[c] += pw[c];
            db_acc[c] += pb[c];
        }
    }
    dx
}

/// Embedding backward (the last serial section of the backward pass),
/// owner-computes: workers own destination token/position row ranges and
/// accumulate their hits walking the batch in ascending row order — the
/// exact per-destination accumulation order of the serial scatter
/// [`super::math::embed_scatter`], so results are bit-identical to it at
/// every thread count.
pub fn embed_scatter(
    dwte: &mut [f32],
    dwpe: &mut [f32],
    dh: &[f32],
    x: &[i32],
    m: usize,
    t: usize,
    d: usize,
) {
    assert_eq!(dh.len(), m * d, "embed_scatter: dh has wrong shape");
    assert_eq!(x.len(), m, "embed_scatter: tokens have wrong shape");
    assert!(d > 0 && t > 0, "embed_scatter: empty dims");
    assert_eq!(dwte.len() % d, 0, "embed_scatter: dwte not whole rows");
    assert_eq!(dwpe.len(), t * d, "embed_scatter: dwpe has wrong shape");
    let v = dwte.len() / d;
    // fail loudly on an out-of-range token id: the owner-computes split
    // would otherwise silently drop its gradient (no part owns it), where
    // the serial reference panics on the out-of-bounds row slice — and a
    // corrupted batch in a --release run must not train on wrong gradients
    for &tok in x {
        assert!(
            (tok as usize) < v,
            "embed_scatter: token id {tok} out of vocab range 0..{v}"
        );
    }
    // wte: each part scans the batch once and accumulates only the rows
    // whose token falls in its destination range (ascending r per token)
    par_chunks_mut(dwte, d, (4 * m * d) / v.max(1) + 4, |tokens, wc| {
        for r in 0..m {
            let tok = x[r] as usize;
            if tok >= tokens.start && tok < tokens.end {
                let dst = &mut wc[(tok - tokens.start) * d..(tok - tokens.start + 1) * d];
                let src = &dh[r * d..(r + 1) * d];
                for c in 0..d {
                    dst[c] += src[c];
                }
            }
        }
    });
    // wpe: position s receives exactly rows s, s+t, s+2t, … — a direct
    // gather, parallel over positions
    par_chunks_mut(dwpe, d, (2 * m * d) / t + 4, |srange, pc| {
        for (si, s) in srange.clone().enumerate() {
            let dst = &mut pc[si * d..(si + 1) * d];
            let mut r = s;
            while r < m {
                let src = &dh[r * d..(r + 1) * d];
                for c in 0..d {
                    dst[c] += src[c];
                }
                r += t;
            }
        }
    });
}

/// Sum of squares over a tensor list on the fixed [`NORM_BLOCK`] tree
/// (the pre-clip grad norm before the square root): f64 block partials in
/// parallel, combined serially in ascending (tensor, block) order —
/// bit-identical to [`super::math::sq_norm`] at every thread count.
pub fn sq_norm(tensors: &[Vec<f32>]) -> f64 {
    let mut blocks: Vec<(usize, usize)> = Vec::new();
    for (ti, t) in tensors.iter().enumerate() {
        for start in (0..t.len()).step_by(NORM_BLOCK) {
            blocks.push((ti, start));
        }
    }
    if blocks.is_empty() {
        return 0.0;
    }
    let mut partials = vec![0.0f64; blocks.len()];
    par_chunks_mut(&mut partials, 1, 2 * NORM_BLOCK, |brange, pc| {
        for (pi, bi) in brange.clone().enumerate() {
            let (ti, start) = blocks[bi];
            let t = &tensors[ti];
            let end = (start + NORM_BLOCK).min(t.len());
            pc[pi] = super::math::sq_norm_block(&t[start..end]);
        }
    });
    partials.iter().sum()
}

/// Tanh-approximate GELU (elementwise-parallel; same arithmetic per
/// element as [`super::math::gelu`]).
pub fn gelu(u: &[f32]) -> Vec<f32> {
    let mut out = vec![0.0f32; u.len()];
    par_chunks_mut(&mut out, 1, 16, |range, oc| {
        for (oi, i) in range.clone().enumerate() {
            let x = u[i];
            let t = (GELU_C * (x + GELU_A * x * x * x)).tanh();
            oc[oi] = 0.5 * x * (1.0 + t);
        }
    });
    out
}

/// GELU backward: `du = dg * gelu'(u)`.
pub fn gelu_bwd(u: &[f32], dg: &[f32]) -> Vec<f32> {
    assert_eq!(u.len(), dg.len(), "gelu_bwd: length mismatch");
    let mut out = vec![0.0f32; u.len()];
    par_chunks_mut(&mut out, 1, 24, |range, oc| {
        for (oi, i) in range.clone().enumerate() {
            let x = u[i];
            let d = dg[i];
            let inner = GELU_C * (x + GELU_A * x * x * x);
            let t = inner.tanh();
            let dinner = GELU_C * (1.0 + 3.0 * GELU_A * x * x);
            oc[oi] = d * (0.5 * (1.0 + t) + 0.5 * x * (1.0 - t * t) * dinner);
        }
    });
    out
}

/// Softmax of `row[..n]` into `p[..n]`: the exact per-row arithmetic of
/// [`causal_softmax`] (ascending-j max, exp, running f32 sum, divide),
/// factored out so the serve decode path (which scores one new query row
/// against the KV cache) runs bit-for-bit the same code as the full-tile
/// training forward.
pub fn softmax_row(row: &[f32], p: &mut [f32], n: usize) {
    let mut mx = f32::NEG_INFINITY;
    for &sv in row.iter().take(n) {
        mx = mx.max(sv);
    }
    let mut z = 0.0f32;
    for j in 0..n {
        let e = (row[j] - mx).exp();
        p[j] = e;
        z += e;
    }
    for pj in p.iter_mut().take(n) {
        *pj /= z;
    }
}

/// Causal row softmax of one (t x t) score tile into `p` (entries above
/// the diagonal stay exactly 0; `p` must arrive zeroed). Serial per tile —
/// the native backend fans tiles out across (batch, head) pairs.
pub fn causal_softmax(scores: &[f32], p: &mut [f32], t: usize) {
    assert_eq!(scores.len(), t * t, "causal_softmax: scores shape");
    assert_eq!(p.len(), t * t, "causal_softmax: p shape");
    for i in 0..t {
        let row = &scores[i * t..(i + 1) * t];
        let prow = &mut p[i * t..(i + 1) * t];
        softmax_row(row, prow, i + 1);
    }
}

/// One KV-cached attention row: score the query head-row `q` (hd) against
/// the `len` cached keys, scale, softmax, and contract against the cached
/// values into `ctx` (hd). Every step reuses the full-forward building
/// blocks on a 1-row tile — `math::matmul_nt` for scores (the same
/// per-element `simd::dot` lane tree), [`softmax_row`], and `math::matmul`
/// for the value contraction (the same ascending-position `simd::axpy`
/// accumulation) — so position `len-1` of a decode is bit-identical to row
/// `len-1` of the full (t x t) causal tile, which computes that row over
/// exactly the first `len` keys/values with the same operation order.
pub fn decode_attn(
    q: &[f32],
    kc: &[f32],
    vc: &[f32],
    len: usize,
    hd: usize,
    inv_sqrt_hd: f32,
    ctx: &mut [f32],
) {
    assert_eq!(q.len(), hd, "decode_attn: q shape");
    assert!(kc.len() >= len * hd, "decode_attn: key cache too short");
    assert!(vc.len() >= len * hd, "decode_attn: value cache too short");
    assert_eq!(ctx.len(), hd, "decode_attn: ctx shape");
    let mut scores = super::math::matmul_nt(q, &kc[..len * hd], 1, hd, len);
    for sv in scores.iter_mut() {
        *sv *= inv_sqrt_hd;
    }
    let mut p = vec![0.0f32; len];
    softmax_row(&scores, &mut p, len);
    ctx.copy_from_slice(&super::math::matmul(&p, &vc[..len * hd], 1, len, hd));
}

/// Per-position NLL without materializing probabilities (eval path),
/// row-parallel: `nll = -(l_target - max - ln(sum(exp(l - max))))`,
/// clamped finite so a diverged checkpoint scores terribly instead of
/// poisoning aggregates.
pub fn nll_only(logits: &[f32], y: &[i32], m: usize, v: usize) -> Vec<f32> {
    assert_eq!(logits.len(), m * v, "nll_only: logits shape");
    assert_eq!(y.len(), m, "nll_only: targets shape");
    let mut per_pos = vec![0.0f32; m];
    par_chunks_mut(&mut per_pos, 1, 6 * v, |rows, pp| {
        for (ri, r) in rows.clone().enumerate() {
            let row = &logits[r * v..(r + 1) * v];
            let mut mx = f32::NEG_INFINITY;
            for &l in row {
                mx = mx.max(l);
            }
            let mut z = 0.0f32;
            for &l in row {
                z += (l - mx).exp();
            }
            let nll = -(row[y[r] as usize] - mx - z.ln());
            pp[ri] = if nll.is_finite() {
                nll
            } else {
                -f32::MIN_POSITIVE.ln()
            };
        }
    });
    per_pos
}

/// Per-position NLL and softmax probabilities from logits (row-stable,
/// row-parallel; the backward path needs the probs for dlogits).
pub fn nll_rows(logits: &[f32], y: &[i32], m: usize, v: usize) -> (Vec<f32>, Vec<f32>) {
    assert_eq!(logits.len(), m * v, "nll_rows: logits shape");
    assert_eq!(y.len(), m, "nll_rows: targets shape");
    let mut per_pos = vec![0.0f32; m];
    let mut probs = vec![0.0f32; m * v];
    par_chunks2_mut(&mut per_pos, 1, &mut probs, v, 8 * v, |rows, pp, pc| {
        for (ri, r) in rows.clone().enumerate() {
            let row = &logits[r * v..(r + 1) * v];
            let mut mx = f32::NEG_INFINITY;
            for &l in row {
                mx = mx.max(l);
            }
            let prow = &mut pc[ri * v..(ri + 1) * v];
            let mut z = 0.0f32;
            for (pj, &l) in prow.iter_mut().zip(row.iter()) {
                let e = (l - mx).exp();
                *pj = e;
                z += e;
            }
            for pj in prow.iter_mut() {
                *pj /= z;
            }
            let target = y[r] as usize;
            pp[ri] = -(prow[target].max(f32::MIN_POSITIVE)).ln();
        }
    });
    (per_pos, probs)
}

#[cfg(test)]
mod tests {
    use super::*;

    // tests that mutate the process-wide thread knobs serialize on this
    static KNOBS: std::sync::Mutex<()> = std::sync::Mutex::new(());

    #[test]
    fn thread_override_wins() {
        let _g = KNOBS.lock().unwrap_or_else(|e| e.into_inner());
        set_threads(3);
        assert_eq!(max_threads(), 3);
        set_threads(0);
        assert!(max_threads() >= 1);
    }

    #[test]
    fn par_chunks_covers_every_chunk_once() {
        use std::sync::atomic::AtomicU32;
        let _g = KNOBS.lock().unwrap_or_else(|e| e.into_inner());
        set_threads(4);
        force_parallel(true);
        let mut data = vec![0u8; 37 * 3];
        let count = AtomicU32::new(0);
        par_chunks_mut(&mut data, 3, 1, |range, sub| {
            assert_eq!(sub.len(), (range.end - range.start) * 3);
            count.fetch_add((range.end - range.start) as u32, Ordering::Relaxed);
            for b in sub.iter_mut() {
                *b += 1;
            }
        });
        force_parallel(false);
        set_threads(0);
        assert_eq!(count.load(Ordering::Relaxed), 37);
        assert!(data.iter().all(|&b| b == 1), "every element touched exactly once");
    }

    #[test]
    fn matmul_small() {
        // [1 2; 3 4] @ [5 6; 7 8] = [19 22; 43 50]
        let a = [1.0, 2.0, 3.0, 4.0];
        let b = [5.0, 6.0, 7.0, 8.0];
        assert_eq!(matmul(&a, &b, 2, 2, 2), vec![19.0, 22.0, 43.0, 50.0]);
    }

    #[test]
    fn matches_serial_reference_on_fixed_case() {
        let m = 5;
        let k = K_PANEL + 3; // straddle a panel boundary
        let n = 7;
        let a: Vec<f32> = (0..m * k).map(|i| (i as f32 * 0.37).sin()).collect();
        let b: Vec<f32> = (0..k * n).map(|i| (i as f32 * 0.11).cos()).collect();
        assert_eq!(matmul(&a, &b, m, k, n), super::super::math::matmul(&a, &b, m, k, n));
    }

    #[test]
    #[should_panic(expected = "matmul_acc: a has wrong shape")]
    fn shape_checks_fire_in_release() {
        // promoted from debug_assert: must fail loudly in --release too
        let mut c = vec![0.0f32; 4];
        matmul_acc(&mut c, &[0.0; 3], &[0.0; 4], 2, 2, 2);
    }

    #[test]
    fn causal_softmax_rows_normalized_and_masked() {
        let t = 4;
        let scores: Vec<f32> = (0..t * t).map(|i| (i as f32 * 0.3).sin()).collect();
        let mut p = vec![0.0f32; t * t];
        causal_softmax(&scores, &mut p, t);
        for i in 0..t {
            let row = &p[i * t..(i + 1) * t];
            let sum: f32 = row.iter().take(i + 1).sum();
            assert!((sum - 1.0).abs() < 1e-6, "row {i} sums to {sum}");
            assert!(row.iter().skip(i + 1).all(|&x| x == 0.0), "row {i} not masked");
        }
    }
}
