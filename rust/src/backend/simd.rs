//! Runtime-dispatched SIMD microkernels with a **lane-deterministic**
//! scalar reference.
//!
//! Three microkernels carry the matmul inner loops of [`super::math`] and
//! [`super::kernels`]:
//!
//! * [`axpy`] — `c[j] = fma(a, b[j], c[j])` over a row (the K-panel inner
//!   loop of `matmul`/`matmul_tn`); lanes are *independent output
//!   elements*, so the per-element float-add chain (k ascending, one fused
//!   rounding per step) is the same at any vector width.
//! * [`dot`] — the `matmul_nt` reduction, on a **fixed 8-lane striped
//!   accumulator layout**: lane `l` accumulates the products at indices
//!   `j ≡ l (mod 8)` of the first `8·⌊k/8⌋` elements (fused per step),
//!   the lanes combine on a fixed pairwise tree
//!   (`(l0+l4, l1+l5, l2+l6, l3+l7) → (+2 apart) → (+1 apart)`), and the
//!   `k mod 8` tail elements fold in as a scalar fma chain. The layout is
//!   a function of `k` only — never of the ISA.
//! * [`axpy_i8`] — `c[j] += a · b[j]` widening i8→i32 (the `matmul_i8`
//!   inner loop); i32 accumulation is exact, so lane layout is irrelevant
//!   to the result by arithmetic.
//! * [`dot_i8`] — the packed `matmul_i8_nt` reduction, widening i8→i32;
//!   exact integer arithmetic, so the horizontal-sum order is free.
//!
//! Each f32 microkernel also has a **4-row register-blocked** form
//! ([`axpy4`], [`dot4`], and [`axpy4_i8`] on the integer side): one b-row
//! load feeds four independent accumulator rows (four fma chains in
//! flight), which is where the GEMM speedup comes from. Blocking never
//! changes results: each output row's per-element fma sequence is exactly
//! the 1-row kernel's, so `axpy4(c, a, b)` is bit-identical to four
//! `axpy` calls and `dot4` to four `dot` calls — on every tier. The
//! scalar emulation is defined as exactly those four 1-row calls.
//!
//! Each microkernel has an AVX2/FMA implementation (8 f32 lanes, 16 i8
//! lanes) and a scalar emulation of the **exact same lane/tail structure**
//! built on `f32::mul_add` (one rounding, the IEEE fma the vector path
//! performs per lane) — so results are bit-identical whether the vector
//! path runs or not, on every machine. `rust/tests/simd.rs` pins the
//! equivalence over randomized shapes and K tails.
//!
//! Dispatch is resolved at runtime: the vector path runs iff the CPU
//! reports `avx2` and `fma` (`is_x86_feature_detected!`) and the
//! `QPRETRAIN_SIMD` environment variable is not `off`/`0`; [`set_simd`] /
//! [`with_simd`] override it per process (the equivalence suite and the
//! scalar-vs-SIMD bench rows flip it). When the vector path is off but the
//! CPU still has fma, the scalar emulation is compiled with the `fma`
//! target feature so its `mul_add` stays a hardware instruction;  without
//! fma it falls back to the (correctly rounded, hence still bit-identical)
//! libm `fmaf`.

use std::sync::atomic::{AtomicU8, Ordering};

/// f32 lanes per vector step (AVX2 ymm width). The striped-accumulator
/// layout of [`dot`] is defined at this width on every path.
pub const F32_LANES: usize = 8;

/// i8 elements per widening i8→i32 vector step (one 128-bit load, widened
/// to 16×i16 then 2×8×i32). [`crate::quant`] pads packed GEMM rows to this
/// so the hot loop never needs a partial-lane load.
pub const I8_LANES: usize = 16;

// Resolved dispatch tier, cached so hot-loop dispatch is one relaxed load.
const TIER_UNSET: u8 = 0;
const TIER_VECTOR: u8 = 1;
const TIER_FMA_SCALAR: u8 = 2;
const TIER_SCALAR: u8 = 3;

static TIER: AtomicU8 = AtomicU8::new(TIER_UNSET);

/// Whether this CPU can run the vector microkernels (x86-64 with AVX2+FMA).
#[allow(unreachable_code)]
pub fn simd_supported() -> bool {
    #[cfg(target_arch = "x86_64")]
    return std::arch::is_x86_feature_detected!("avx2")
        && std::arch::is_x86_feature_detected!("fma");
    false
}

#[allow(unreachable_code)]
fn fma_supported() -> bool {
    #[cfg(target_arch = "x86_64")]
    return std::arch::is_x86_feature_detected!("fma");
    false
}

/// `QPRETRAIN_SIMD=off` (or `0`) disables the vector path for the process;
/// results are identical either way, only wall-clock changes.
fn env_simd_off() -> bool {
    matches!(
        std::env::var("QPRETRAIN_SIMD").as_deref(),
        Ok("off") | Ok("0") | Ok("OFF")
    )
}

fn resolve(vector_wanted: bool) -> u8 {
    if vector_wanted && simd_supported() {
        TIER_VECTOR
    } else if fma_supported() {
        TIER_FMA_SCALAR
    } else {
        TIER_SCALAR
    }
}

#[inline]
fn tier() -> u8 {
    let t = TIER.load(Ordering::Relaxed);
    if t != TIER_UNSET {
        return t;
    }
    let t = resolve(!env_simd_off());
    TIER.store(t, Ordering::Relaxed);
    t
}

/// Override the vector-path selection for this process: `Some(true)` forces
/// the vector microkernels (a no-op on CPUs without AVX2+FMA), `Some(false)`
/// pins the scalar lane emulation, `None` restores the environment/CPU
/// resolution. Results are bit-identical in every mode.
pub fn set_simd(mode: Option<bool>) {
    let t = match mode {
        Some(on) => resolve(on),
        None => resolve(!env_simd_off()),
    };
    TIER.store(t, Ordering::Relaxed);
}

/// Run `f` with the vector path pinned on/off, restoring the previous
/// selection afterwards even on panic (bench/test hook, mirroring
/// [`super::kernels::with_threads`]).
pub fn with_simd<T>(on: bool, f: impl FnOnce() -> T) -> T {
    struct Restore(u8);
    impl Drop for Restore {
        fn drop(&mut self) {
            TIER.store(self.0, Ordering::Relaxed);
        }
    }
    let _guard = Restore(tier());
    set_simd(Some(on));
    f()
}

/// Whether the vector microkernels are currently selected (CPU support ∧
/// knobs). The scalar emulation is bit-identical, so this only predicts
/// throughput, never results.
pub fn simd_active() -> bool {
    tier() == TIER_VECTOR
}

// ---------------------------------------------------------------------------
// scalar lane emulation (the reference structure, shared by every tier)
// ---------------------------------------------------------------------------

#[inline(always)]
fn axpy_body(c: &mut [f32], a: f32, b: &[f32]) {
    // lanes are independent output elements: each c[j] sees one fused
    // multiply-add per k step, in k-ascending order, at any vector width
    for (cv, &bv) in c.iter_mut().zip(b.iter()) {
        *cv = a.mul_add(bv, *cv);
    }
}

// the combine trees below (and their AVX2 shuffle twins) are written for
// exactly 8 lanes; retuning the lane width must rewrite them in lockstep
const _: () = assert!(F32_LANES == 8, "dot combine tree is hardwired to 8 lanes");

#[inline(always)]
fn dot_body(a: &[f32], b: &[f32]) -> f32 {
    let n = a.len();
    let nb = n - n % F32_LANES;
    let mut acc = [0.0f32; F32_LANES];
    let mut j = 0;
    while j < nb {
        for (l, av) in acc.iter_mut().enumerate() {
            *av = a[j + l].mul_add(b[j + l], *av);
        }
        j += F32_LANES;
    }
    // fixed pairwise combine tree (the vector path's 256→128→64→32 fold)
    let s4 = [acc[0] + acc[4], acc[1] + acc[5], acc[2] + acc[6], acc[3] + acc[7]];
    let s2 = [s4[0] + s4[2], s4[1] + s4[3]];
    let mut s = s2[0] + s2[1];
    // tail: a scalar fma chain appended after the lane tree
    while j < n {
        s = a[j].mul_add(b[j], s);
        j += 1;
    }
    s
}

#[inline(always)]
fn axpy_i8_body(c: &mut [i32], a: i8, b: &[i8]) {
    let av = a as i32;
    for (cv, &bv) in c.iter_mut().zip(b.iter()) {
        *cv += av * bv as i32;
    }
}

#[inline(always)]
fn dot_i8_body(a: &[i8], b: &[i8]) -> i32 {
    let mut s = 0i32;
    for (&av, &bv) in a.iter().zip(b.iter()) {
        s += av as i32 * bv as i32;
    }
    s
}

// The 4-row scalar emulations are *defined* as four 1-row calls: blocking
// shares loads, never arithmetic, so this is the reference the vector
// forms must (and do) reproduce bit for bit.

#[inline(always)]
fn axpy4_body(c: &mut [f32], a: &[f32; 4], b: &[f32]) {
    let n = b.len();
    let (c0, r) = c.split_at_mut(n);
    let (c1, r) = r.split_at_mut(n);
    let (c2, c3) = r.split_at_mut(n);
    axpy_body(c0, a[0], b);
    axpy_body(c1, a[1], b);
    axpy_body(c2, a[2], b);
    axpy_body(c3, a[3], b);
}

#[inline(always)]
fn dot4_body(a: &[f32], b: &[f32]) -> [f32; 4] {
    let k = a.len();
    [
        dot_body(a, &b[..k]),
        dot_body(a, &b[k..2 * k]),
        dot_body(a, &b[2 * k..3 * k]),
        dot_body(a, &b[3 * k..]),
    ]
}

#[inline(always)]
fn axpy4_i8_body(c: &mut [i32], a: &[i8; 4], b: &[i8]) {
    let n = b.len();
    let (c0, r) = c.split_at_mut(n);
    let (c1, r) = r.split_at_mut(n);
    let (c2, c3) = r.split_at_mut(n);
    axpy_i8_body(c0, a[0], b);
    axpy_i8_body(c1, a[1], b);
    axpy_i8_body(c2, a[2], b);
    axpy_i8_body(c3, a[3], b);
}

// ---------------------------------------------------------------------------
// fma-scalar tier: the same bodies compiled with the fma target feature so
// `mul_add` lowers to the hardware instruction instead of a libm call
// ---------------------------------------------------------------------------

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "fma")]
unsafe fn axpy_fma(c: &mut [f32], a: f32, b: &[f32]) {
    axpy_body(c, a, b)
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "fma")]
unsafe fn dot_fma(a: &[f32], b: &[f32]) -> f32 {
    dot_body(a, b)
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "fma")]
unsafe fn axpy4_fma(c: &mut [f32], a: &[f32; 4], b: &[f32]) {
    axpy4_body(c, a, b)
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "fma")]
unsafe fn dot4_fma(a: &[f32], b: &[f32]) -> [f32; 4] {
    dot4_body(a, b)
}

// ---------------------------------------------------------------------------
// AVX2/FMA vector tier
// ---------------------------------------------------------------------------

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2,fma")]
unsafe fn axpy_avx2(c: &mut [f32], a: f32, b: &[f32]) {
    use std::arch::x86_64::*;
    let n = c.len();
    let av = _mm256_set1_ps(a);
    let mut j = 0;
    while j + F32_LANES <= n {
        let bv = _mm256_loadu_ps(b.as_ptr().add(j));
        let cv = _mm256_loadu_ps(c.as_ptr().add(j));
        _mm256_storeu_ps(c.as_mut_ptr().add(j), _mm256_fmadd_ps(av, bv, cv));
        j += F32_LANES;
    }
    // tail lanes are independent elements: the same fused op, scalar
    while j < n {
        c[j] = a.mul_add(b[j], c[j]);
        j += 1;
    }
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2,fma")]
unsafe fn dot_avx2(a: &[f32], b: &[f32]) -> f32 {
    use std::arch::x86_64::*;
    let n = a.len();
    let nb = n - n % F32_LANES;
    let mut acc = _mm256_setzero_ps();
    let mut j = 0;
    while j < nb {
        let av = _mm256_loadu_ps(a.as_ptr().add(j));
        let bv = _mm256_loadu_ps(b.as_ptr().add(j));
        acc = _mm256_fmadd_ps(av, bv, acc);
        j += F32_LANES;
    }
    // the fixed combine tree of `dot_body`, as shuffles: lanes l and l+4,
    // then +2 apart, then +1 apart
    let lo = _mm256_castps256_ps128(acc);
    let hi = _mm256_extractf128_ps::<1>(acc);
    let s4 = _mm_add_ps(lo, hi);
    let s2 = _mm_add_ps(s4, _mm_movehl_ps(s4, s4));
    let s1 = _mm_add_ss(s2, _mm_shuffle_ps::<0b01>(s2, s2));
    let mut s = _mm_cvtss_f32(s1);
    while j < n {
        s = a[j].mul_add(b[j], s);
        j += 1;
    }
    s
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2,fma")]
unsafe fn axpy4_avx2(c: &mut [f32], a: &[f32; 4], b: &[f32]) {
    use std::arch::x86_64::*;
    let n = b.len();
    let (c0, r) = c.split_at_mut(n);
    let (c1, r) = r.split_at_mut(n);
    let (c2, c3) = r.split_at_mut(n);
    let a0 = _mm256_set1_ps(a[0]);
    let a1 = _mm256_set1_ps(a[1]);
    let a2 = _mm256_set1_ps(a[2]);
    let a3 = _mm256_set1_ps(a[3]);
    let mut j = 0;
    while j + F32_LANES <= n {
        // one b load feeds four independent fma chains; each output row's
        // per-element op sequence is exactly the 1-row axpy's
        let bv = _mm256_loadu_ps(b.as_ptr().add(j));
        let v0 = _mm256_loadu_ps(c0.as_ptr().add(j));
        let v1 = _mm256_loadu_ps(c1.as_ptr().add(j));
        let v2 = _mm256_loadu_ps(c2.as_ptr().add(j));
        let v3 = _mm256_loadu_ps(c3.as_ptr().add(j));
        _mm256_storeu_ps(c0.as_mut_ptr().add(j), _mm256_fmadd_ps(a0, bv, v0));
        _mm256_storeu_ps(c1.as_mut_ptr().add(j), _mm256_fmadd_ps(a1, bv, v1));
        _mm256_storeu_ps(c2.as_mut_ptr().add(j), _mm256_fmadd_ps(a2, bv, v2));
        _mm256_storeu_ps(c3.as_mut_ptr().add(j), _mm256_fmadd_ps(a3, bv, v3));
        j += F32_LANES;
    }
    while j < n {
        c0[j] = a[0].mul_add(b[j], c0[j]);
        c1[j] = a[1].mul_add(b[j], c1[j]);
        c2[j] = a[2].mul_add(b[j], c2[j]);
        c3[j] = a[3].mul_add(b[j], c3[j]);
        j += 1;
    }
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2,fma")]
unsafe fn dot4_avx2(a: &[f32], b: &[f32]) -> [f32; 4] {
    use std::arch::x86_64::*;
    let k = a.len();
    let nb = k - k % F32_LANES;
    let (b0, b1, b2, b3) = (&b[..k], &b[k..2 * k], &b[2 * k..3 * k], &b[3 * k..]);
    let mut acc0 = _mm256_setzero_ps();
    let mut acc1 = _mm256_setzero_ps();
    let mut acc2 = _mm256_setzero_ps();
    let mut acc3 = _mm256_setzero_ps();
    let mut j = 0;
    while j < nb {
        // one a load feeds four striped accumulators, each walking the
        // exact lane structure of the 1-row dot
        let av = _mm256_loadu_ps(a.as_ptr().add(j));
        acc0 = _mm256_fmadd_ps(av, _mm256_loadu_ps(b0.as_ptr().add(j)), acc0);
        acc1 = _mm256_fmadd_ps(av, _mm256_loadu_ps(b1.as_ptr().add(j)), acc1);
        acc2 = _mm256_fmadd_ps(av, _mm256_loadu_ps(b2.as_ptr().add(j)), acc2);
        acc3 = _mm256_fmadd_ps(av, _mm256_loadu_ps(b3.as_ptr().add(j)), acc3);
        j += F32_LANES;
    }
    // each accumulator folds on the same fixed tree as the 1-row dot
    #[target_feature(enable = "avx2")]
    unsafe fn fold(acc: std::arch::x86_64::__m256) -> f32 {
        let lo = _mm256_castps256_ps128(acc);
        let hi = _mm256_extractf128_ps::<1>(acc);
        let s4 = _mm_add_ps(lo, hi);
        let s2 = _mm_add_ps(s4, _mm_movehl_ps(s4, s4));
        let s1 = _mm_add_ss(s2, _mm_shuffle_ps::<0b01>(s2, s2));
        _mm_cvtss_f32(s1)
    }
    let mut s = [fold(acc0), fold(acc1), fold(acc2), fold(acc3)];
    while j < k {
        s[0] = a[j].mul_add(b0[j], s[0]);
        s[1] = a[j].mul_add(b1[j], s[1]);
        s[2] = a[j].mul_add(b2[j], s[2]);
        s[3] = a[j].mul_add(b3[j], s[3]);
        j += 1;
    }
    s
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn axpy_i8_avx2(c: &mut [i32], a: i8, b: &[i8]) {
    use std::arch::x86_64::*;
    let n = c.len();
    let av = _mm256_set1_epi16(a as i16);
    let mut j = 0;
    while j + I8_LANES <= n {
        // 16 i8 codes -> 16 i16 (|a·b| <= 2^14, exact in i16) -> 2x8 i32
        let bv = _mm_loadu_si128(b.as_ptr().add(j) as *const __m128i);
        let bw = _mm256_cvtepi8_epi16(bv);
        let prod = _mm256_mullo_epi16(bw, av);
        let p0 = _mm256_cvtepi16_epi32(_mm256_castsi256_si128(prod));
        let p1 = _mm256_cvtepi16_epi32(_mm256_extracti128_si256::<1>(prod));
        let c0 = _mm256_loadu_si256(c.as_ptr().add(j) as *const __m256i);
        let c1 = _mm256_loadu_si256(c.as_ptr().add(j + 8) as *const __m256i);
        _mm256_storeu_si256(c.as_mut_ptr().add(j) as *mut __m256i, _mm256_add_epi32(c0, p0));
        _mm256_storeu_si256(
            c.as_mut_ptr().add(j + 8) as *mut __m256i,
            _mm256_add_epi32(c1, p1),
        );
        j += I8_LANES;
    }
    let av = a as i32;
    while j < n {
        c[j] += av * b[j] as i32;
        j += 1;
    }
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn axpy4_i8_avx2(c: &mut [i32], a: &[i8; 4], b: &[i8]) {
    use std::arch::x86_64::*;
    let n = b.len();
    let (c0, r) = c.split_at_mut(n);
    let (c1, r) = r.split_at_mut(n);
    let (c2, c3) = r.split_at_mut(n);
    let a0 = _mm256_set1_epi16(a[0] as i16);
    let a1 = _mm256_set1_epi16(a[1] as i16);
    let a2 = _mm256_set1_epi16(a[2] as i16);
    let a3 = _mm256_set1_epi16(a[3] as i16);
    let mut j = 0;
    while j + I8_LANES <= n {
        // widen the shared b row once, then four independent i16 multiply /
        // i32 accumulate chains (exact: |a·b| <= 2^14 fits i16)
        let bv = _mm_loadu_si128(b.as_ptr().add(j) as *const __m128i);
        let bw = _mm256_cvtepi8_epi16(bv);
        #[target_feature(enable = "avx2")]
        unsafe fn acc_row(crow: &mut [i32], j: usize, bw: __m256i, av: __m256i) {
            use std::arch::x86_64::*;
            let prod = _mm256_mullo_epi16(bw, av);
            let p0 = _mm256_cvtepi16_epi32(_mm256_castsi256_si128(prod));
            let p1 = _mm256_cvtepi16_epi32(_mm256_extracti128_si256::<1>(prod));
            let c0 = _mm256_loadu_si256(crow.as_ptr().add(j) as *const __m256i);
            let c1 = _mm256_loadu_si256(crow.as_ptr().add(j + 8) as *const __m256i);
            _mm256_storeu_si256(crow.as_mut_ptr().add(j) as *mut __m256i, _mm256_add_epi32(c0, p0));
            _mm256_storeu_si256(
                crow.as_mut_ptr().add(j + 8) as *mut __m256i,
                _mm256_add_epi32(c1, p1),
            );
        }
        acc_row(c0, j, bw, a0);
        acc_row(c1, j, bw, a1);
        acc_row(c2, j, bw, a2);
        acc_row(c3, j, bw, a3);
        j += I8_LANES;
    }
    while j < n {
        let bv = b[j] as i32;
        c0[j] += a[0] as i32 * bv;
        c1[j] += a[1] as i32 * bv;
        c2[j] += a[2] as i32 * bv;
        c3[j] += a[3] as i32 * bv;
        j += 1;
    }
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn dot_i8_avx2(a: &[i8], b: &[i8]) -> i32 {
    use std::arch::x86_64::*;
    let n = a.len();
    let mut acc = _mm256_setzero_si256();
    let mut j = 0;
    while j + I8_LANES <= n {
        // widen both to i16, pairwise multiply-add into 8 i32 lanes; the
        // result is an exact integer, so lane/fold order cannot matter
        let av = _mm256_cvtepi8_epi16(_mm_loadu_si128(a.as_ptr().add(j) as *const __m128i));
        let bv = _mm256_cvtepi8_epi16(_mm_loadu_si128(b.as_ptr().add(j) as *const __m128i));
        acc = _mm256_add_epi32(acc, _mm256_madd_epi16(av, bv));
        j += I8_LANES;
    }
    let lo = _mm256_castsi256_si128(acc);
    let hi = _mm256_extracti128_si256::<1>(acc);
    let s = _mm_add_epi32(lo, hi);
    let s = _mm_add_epi32(s, _mm_shuffle_epi32::<0b0100_1110>(s));
    let s = _mm_add_epi32(s, _mm_shuffle_epi32::<0b1011_0001>(s));
    let mut sum = _mm_cvtsi128_si32(s);
    while j < n {
        sum += a[j] as i32 * b[j] as i32;
        j += 1;
    }
    sum
}

// ---------------------------------------------------------------------------
// dispatched entry points
// ---------------------------------------------------------------------------

/// `c[j] = fma(a, b[j], c[j])` for every j (one rounding per element per
/// call, k-ascending across calls). Bit-identical on every tier.
#[inline]
pub fn axpy(c: &mut [f32], a: f32, b: &[f32]) {
    assert_eq!(c.len(), b.len(), "axpy: length mismatch");
    match tier() {
        #[cfg(target_arch = "x86_64")]
        TIER_VECTOR => unsafe { axpy_avx2(c, a, b) },
        #[cfg(target_arch = "x86_64")]
        TIER_FMA_SCALAR => unsafe { axpy_fma(c, a, b) },
        _ => axpy_body(c, a, b),
    }
}

/// Striped-lane dot product of two equal-length slices (see the module
/// docs for the fixed lane/tail structure). Bit-identical on every tier.
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    assert_eq!(a.len(), b.len(), "dot: length mismatch");
    match tier() {
        #[cfg(target_arch = "x86_64")]
        TIER_VECTOR => unsafe { dot_avx2(a, b) },
        #[cfg(target_arch = "x86_64")]
        TIER_FMA_SCALAR => unsafe { dot_fma(a, b) },
        _ => dot_body(a, b),
    }
}

/// `c[j] += a · b[j]` widening i8→i32. Exact integer arithmetic: identical
/// on every tier by value, not just by ordering discipline.
#[inline]
pub fn axpy_i8(c: &mut [i32], a: i8, b: &[i8]) {
    assert_eq!(c.len(), b.len(), "axpy_i8: length mismatch");
    match tier() {
        #[cfg(target_arch = "x86_64")]
        TIER_VECTOR => unsafe { axpy_i8_avx2(c, a, b) },
        _ => axpy_i8_body(c, a, b),
    }
}

/// 4-row register-blocked [`axpy`]: `c` is four contiguous output rows of
/// `b.len()` elements; row `r` receives `fma(a[r], b[j], c_r[j])`. One
/// b-row load feeds all four accumulator rows; bit-identical to four
/// 1-row `axpy` calls on every tier.
#[inline]
pub fn axpy4(c: &mut [f32], a: &[f32; 4], b: &[f32]) {
    assert_eq!(c.len(), 4 * b.len(), "axpy4: length mismatch");
    match tier() {
        #[cfg(target_arch = "x86_64")]
        TIER_VECTOR => unsafe { axpy4_avx2(c, a, b) },
        #[cfg(target_arch = "x86_64")]
        TIER_FMA_SCALAR => unsafe { axpy4_fma(c, a, b) },
        _ => axpy4_body(c, a, b),
    }
}

/// 4-row register-blocked [`dot`]: `b` is four contiguous rows of
/// `a.len()` elements; returns the four striped-lane dot products. One
/// a-row load feeds four independent accumulators, each walking the exact
/// 1-row lane/tail structure — bit-identical to four `dot` calls on every
/// tier.
#[inline]
pub fn dot4(a: &[f32], b: &[f32]) -> [f32; 4] {
    assert_eq!(b.len(), 4 * a.len(), "dot4: length mismatch");
    match tier() {
        #[cfg(target_arch = "x86_64")]
        TIER_VECTOR => unsafe { dot4_avx2(a, b) },
        #[cfg(target_arch = "x86_64")]
        TIER_FMA_SCALAR => unsafe { dot4_fma(a, b) },
        _ => dot4_body(a, b),
    }
}

/// 4-row register-blocked [`axpy_i8`]: `c` is four contiguous i32 output
/// rows; the shared `b` row is widened once per vector step. Exact integer
/// arithmetic on every tier.
#[inline]
pub fn axpy4_i8(c: &mut [i32], a: &[i8; 4], b: &[i8]) {
    assert_eq!(c.len(), 4 * b.len(), "axpy4_i8: length mismatch");
    match tier() {
        #[cfg(target_arch = "x86_64")]
        TIER_VECTOR => unsafe { axpy4_i8_avx2(c, a, b) },
        _ => axpy4_i8_body(c, a, b),
    }
}

/// Widening i8→i32 dot product (the packed `matmul_i8_nt` reduction).
/// Exact integer arithmetic: identical on every tier. Exact while
/// `k · 127²` fits in i32 — the same bound as [`axpy_i8`] accumulation.
#[inline]
pub fn dot_i8(a: &[i8], b: &[i8]) -> i32 {
    assert_eq!(a.len(), b.len(), "dot_i8: length mismatch");
    match tier() {
        #[cfg(target_arch = "x86_64")]
        TIER_VECTOR => unsafe { dot_i8_avx2(a, b) },
        _ => dot_i8_body(a, b),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // tests here flip the process-wide tier; serialize like the thread knobs
    static KNOB: std::sync::Mutex<()> = std::sync::Mutex::new(());

    fn bits(v: &[f32]) -> Vec<u32> {
        v.iter().map(|x| x.to_bits()).collect()
    }

    #[test]
    fn dot_structure_is_lane_striped() {
        // 10 elements: body lanes 0..8, tail 8..10 — hand-walk the tree
        let a: Vec<f32> = (1..=10).map(|i| i as f32).collect();
        let b = vec![1.0f32; 10];
        let acc: Vec<f32> = a[..8].to_vec(); // fma(a, 1, 0) == a exactly
        let s4 = [acc[0] + acc[4], acc[1] + acc[5], acc[2] + acc[6], acc[3] + acc[7]];
        let s2 = [s4[0] + s4[2], s4[1] + s4[3]];
        let want = (s2[0] + s2[1] + a[8]) + a[9];
        assert_eq!(dot_body(&a, &b).to_bits(), want.to_bits());
    }

    #[test]
    fn vector_and_scalar_tiers_bit_identical() {
        let _g = KNOB.lock().unwrap_or_else(|e| e.into_inner());
        if !simd_supported() {
            return; // nothing to compare on this machine
        }
        let mut rng = crate::util::rng::Rng::new(0x51D);
        for n in [1usize, 5, 7, 8, 9, 15, 16, 17, 33, 100] {
            let a = rng.normal_vec(n, 0.0, 1.0);
            let b = rng.normal_vec(n, 0.0, 1.0);
            let c0 = rng.normal_vec(n, 0.0, 1.0);
            let (mut c_s, mut c_v) = (c0.clone(), c0.clone());
            let d_s = with_simd(false, || {
                axpy(&mut c_s, 0.37, &a);
                dot(&a, &b)
            });
            let d_v = with_simd(true, || {
                axpy(&mut c_v, 0.37, &a);
                dot(&a, &b)
            });
            assert_eq!(bits(&c_s), bits(&c_v), "axpy tiers differ at n={n}");
            assert_eq!(d_s.to_bits(), d_v.to_bits(), "dot tiers differ at n={n}");

            let ia: Vec<i8> = (0..n).map(|_| (rng.below(255) as i32 - 127) as i8).collect();
            let mut ic_s = vec![3i32; n];
            let mut ic_v = vec![3i32; n];
            with_simd(false, || axpy_i8(&mut ic_s, -77, &ia));
            with_simd(true, || axpy_i8(&mut ic_v, -77, &ia));
            assert_eq!(ic_s, ic_v, "axpy_i8 tiers differ at n={n}");

            // 4-row blocked forms: tiers identical on the same shapes
            let coeff = [0.37f32, -1.4, 0.0, 2.5e-3];
            let b4 = rng.normal_vec(4 * n, 0.0, 1.0);
            let c40 = rng.normal_vec(4 * n, 0.0, 1.0);
            let (mut c4_s, mut c4_v) = (c40.clone(), c40.clone());
            let d4_s = with_simd(false, || {
                axpy4(&mut c4_s, &coeff, &a);
                dot4(&a, &b4)
            });
            let d4_v = with_simd(true, || {
                axpy4(&mut c4_v, &coeff, &a);
                dot4(&a, &b4)
            });
            assert_eq!(bits(&c4_s), bits(&c4_v), "axpy4 tiers differ at n={n}");
            assert_eq!(bits(&d4_s), bits(&d4_v), "dot4 tiers differ at n={n}");

            let icoeff = [-77i8, 13, 0, 127];
            let ib4: Vec<i8> = (0..4 * n).map(|_| (rng.below(255) as i32 - 127) as i8).collect();
            let mut i4_s = vec![3i32; 4 * n];
            let mut i4_v = vec![3i32; 4 * n];
            with_simd(false, || axpy4_i8(&mut i4_s, &icoeff, &ia));
            with_simd(true, || axpy4_i8(&mut i4_v, &icoeff, &ia));
            assert_eq!(i4_s, i4_v, "axpy4_i8 tiers differ at n={n}");
            let id_s = with_simd(false, || dot_i8(&ia, &ib4[..n]));
            let id_v = with_simd(true, || dot_i8(&ia, &ib4[..n]));
            assert_eq!(id_s, id_v, "dot_i8 tiers differ at n={n}");
        }
    }

    #[test]
    fn blocked_forms_bit_identical_to_four_onerow_calls() {
        // the register-blocking contract: sharing loads across 4 rows never
        // changes any row's arithmetic, in either dispatch tier
        let _g = KNOB.lock().unwrap_or_else(|e| e.into_inner());
        let mut rng = crate::util::rng::Rng::new(0xB10C);
        for simd in [false, true] {
            if simd && !simd_supported() {
                continue;
            }
            with_simd(simd, || {
                for n in [1usize, 7, 8, 9, 16, 33] {
                    let a = rng.normal_vec(n, 0.0, 1.0);
                    let coeff = [1.25f32, -0.7, 3.0e-4, -2.0];
                    let b4 = rng.normal_vec(4 * n, 0.0, 1.0);
                    let c0 = rng.normal_vec(4 * n, 0.0, 1.0);

                    let mut blocked = c0.clone();
                    axpy4(&mut blocked, &coeff, &a);
                    let mut onerow = c0.clone();
                    for r in 0..4 {
                        axpy(&mut onerow[r * n..(r + 1) * n], coeff[r], &a);
                    }
                    assert_eq!(bits(&blocked), bits(&onerow), "axpy4 != 4x axpy at n={n}");

                    let d4 = dot4(&a, &b4);
                    for r in 0..4 {
                        let want = dot(&a, &b4[r * n..(r + 1) * n]);
                        assert_eq!(d4[r].to_bits(), want.to_bits(), "dot4 row {r} at n={n}");
                    }

                    let ia: Vec<i8> =
                        (0..n).map(|_| (rng.below(255) as i32 - 127) as i8).collect();
                    let icoeff = [127i8, -127, 0, 5];
                    let ic0: Vec<i32> = (0..4 * n).map(|i| i as i32 - 7).collect();
                    let mut iblocked = ic0.clone();
                    axpy4_i8(&mut iblocked, &icoeff, &ia);
                    let mut ionerow = ic0.clone();
                    for r in 0..4 {
                        axpy_i8(&mut ionerow[r * n..(r + 1) * n], icoeff[r], &ia);
                    }
                    assert_eq!(iblocked, ionerow, "axpy4_i8 != 4x axpy_i8 at n={n}");
                }
            });
        }
    }

    #[test]
    fn dot_i8_matches_widening_loop() {
        let _g = KNOB.lock().unwrap_or_else(|e| e.into_inner());
        let mut rng = crate::util::rng::Rng::new(0xD07);
        for n in [0usize, 1, 15, 16, 17, 48, 133] {
            let a: Vec<i8> = (0..n).map(|_| (rng.below(255) as i32 - 127) as i8).collect();
            let b: Vec<i8> = (0..n).map(|_| (rng.below(255) as i32 - 127) as i8).collect();
            let want: i32 = a.iter().zip(&b).map(|(&x, &y)| x as i32 * y as i32).sum();
            for simd in [false, true] {
                let got = with_simd(simd, || dot_i8(&a, &b));
                assert_eq!(got, want, "dot_i8 at n={n} simd={simd}");
            }
        }
    }

    #[test]
    fn knob_overrides_and_restores() {
        let _g = KNOB.lock().unwrap_or_else(|e| e.into_inner());
        set_simd(Some(false));
        assert!(!simd_active());
        if simd_supported() {
            set_simd(Some(true));
            assert!(simd_active());
            let outer = simd_active();
            with_simd(false, || assert!(!simd_active()));
            assert_eq!(simd_active(), outer, "with_simd did not restore");
        }
        set_simd(None);
    }
}
