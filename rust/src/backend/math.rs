//! Serial reference kernels: row-major matmuls (plain, transposed-A,
//! transposed-B), layernorm forward/backward, tanh-GELU, the embedding
//! scatter, and the fixed-shape reduction tree behind the grad norm.
//!
//! The matmuls use the axpy (ikj) loop order so the inner loop runs over
//! contiguous rows of both operands; the inner loops themselves are the
//! [`super::simd`] microkernels ([`simd::axpy`] per K step, [`simd::dot`]
//! for the transposed-B reduction), so this module **walks the exact same
//! fixed lane/tail structure** as the parallel kernels — the per-element
//! accumulation (one fused multiply-add per K step, k ascending; the
//! fixed 8-lane stripe + combine tree for dot products) is a function of
//! the problem size only, never of the ISA or thread count. Since the
//! parallel [`super::kernels`] subsystem took over the native backend's
//! hot path, this module is the **retained serial reference**: every
//! parallel kernel must produce bit-identical results to its counterpart
//! here (`rust/tests/kernels.rs` asserts it over randomized shapes), and
//! the benches report serial-vs-parallel speedup against these loops.
//!
//! Cross-row float reductions (layernorm dw/db, the grad norm) are defined
//! here as **fixed-shape tree reductions**: inputs are cut into blocks of
//! [`REDUCE_ROWS`] rows (or [`NORM_BLOCK`] elements), each block partial is
//! accumulated in ascending serial order, and the partials are combined in
//! ascending block order. The block shape depends only on the problem
//! size, so the parallel kernels reproduce the exact same float-add tree
//! at every thread count — that fixed tree, not serial execution, is the
//! determinism contract.
//!
//! Shape checks are real `assert!`s, not `debug_assert!`s: they are O(1)
//! next to the O(m·n·k) kernel body, and a shape bug in a `--release`
//! training run must fail loudly instead of silently reading adjacent
//! memory.

use super::simd;

/// Row-block size of the fixed-shape cross-row reduction tree (layernorm
/// dw/db). A function of nothing: the tree never depends on thread count.
pub const REDUCE_ROWS: usize = 64;

/// Element-block size of the fixed-shape grad-norm reduction tree.
pub const NORM_BLOCK: usize = 1 << 16;

/// `c = a @ b` where a is (m x k), b is (k x n), all row-major.
pub fn matmul(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    let mut c = vec![0.0f32; m * n];
    matmul_acc(&mut c, a, b, m, k, n);
    c
}

/// `c += a @ b` (shapes as [`matmul`]).
pub fn matmul_acc(c: &mut [f32], a: &[f32], b: &[f32], m: usize, k: usize, n: usize) {
    assert_eq!(a.len(), m * k);
    assert_eq!(b.len(), k * n);
    assert_eq!(c.len(), m * n);
    for i in 0..m {
        let arow = &a[i * k..(i + 1) * k];
        let crow = &mut c[i * n..(i + 1) * n];
        for (l, &av) in arow.iter().enumerate() {
            simd::axpy(crow, av, &b[l * n..(l + 1) * n]);
        }
    }
}

/// `aᵀ @ b` where a is (m x k), b is (m x n); result is (k x n).
pub fn matmul_tn(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    let mut c = vec![0.0f32; k * n];
    matmul_tn_acc(&mut c, a, b, m, k, n);
    c
}

/// `c += aᵀ @ b` (shapes as [`matmul_tn`]) — the weight-gradient kernel;
/// accumulating lets stacked per-layer gradients write into their slice.
pub fn matmul_tn_acc(c: &mut [f32], a: &[f32], b: &[f32], m: usize, k: usize, n: usize) {
    assert_eq!(a.len(), m * k);
    assert_eq!(b.len(), m * n);
    assert_eq!(c.len(), k * n);
    for r in 0..m {
        let arow = &a[r * k..(r + 1) * k];
        let brow = &b[r * n..(r + 1) * n];
        for (l, &av) in arow.iter().enumerate() {
            simd::axpy(&mut c[l * n..(l + 1) * n], av, brow);
        }
    }
}

/// `a @ bᵀ` where a is (m x k), b is (n x k); result is (m x n).
/// Dot-product form: both operands stream contiguous rows.
pub fn matmul_nt(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    assert_eq!(a.len(), m * k);
    assert_eq!(b.len(), n * k);
    let mut c = vec![0.0f32; m * n];
    for i in 0..m {
        let arow = &a[i * k..(i + 1) * k];
        let crow = &mut c[i * n..(i + 1) * n];
        for (j, cv) in crow.iter_mut().enumerate() {
            *cv = simd::dot(arow, &b[j * k..(j + 1) * k]);
        }
    }
    c
}

/// Column sums accumulated into `acc` (the bias-gradient kernel).
pub fn col_sum_acc(acc: &mut [f32], x: &[f32], rows: usize, cols: usize) {
    assert_eq!(x.len(), rows * cols);
    assert_eq!(acc.len(), cols);
    for r in 0..rows {
        let row = &x[r * cols..(r + 1) * cols];
        for (a, &v) in acc.iter_mut().zip(row.iter()) {
            *a += v;
        }
    }
}

pub const LN_EPS: f32 = 1e-5;

/// Row-wise layernorm over (rows x d): `y = xhat * w + b` with
/// `xhat = (x - mean) * rsqrt(var + eps)` (biased variance, matching
/// `jnp.var`). Returns (y, xhat, rstd-per-row).
pub fn layer_norm_fwd(
    x: &[f32],
    w: &[f32],
    b: &[f32],
    rows: usize,
    d: usize,
) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    assert_eq!(x.len(), rows * d);
    assert_eq!(w.len(), d);
    assert_eq!(b.len(), d);
    let mut y = vec![0.0f32; rows * d];
    let mut xhat = vec![0.0f32; rows * d];
    let mut rstd = vec![0.0f32; rows];
    for r in 0..rows {
        let xr = &x[r * d..(r + 1) * d];
        let mut mean = 0.0f32;
        for &v in xr {
            mean += v;
        }
        mean /= d as f32;
        let mut var = 0.0f32;
        for &v in xr {
            let dv = v - mean;
            var += dv * dv;
        }
        var /= d as f32;
        let rs = 1.0 / (var + LN_EPS).sqrt();
        rstd[r] = rs;
        let xh = &mut xhat[r * d..(r + 1) * d];
        let yr = &mut y[r * d..(r + 1) * d];
        for c in 0..d {
            let h = (xr[c] - mean) * rs;
            xh[c] = h;
            yr[c] = h * w[c] + b[c];
        }
    }
    (y, xhat, rstd)
}

/// Layernorm backward. Accumulates dw/db into the provided slices and
/// returns dx. Uses the standard biased-variance formula:
/// `dx = rstd * (dxhat - mean(dxhat) - xhat * mean(dxhat * xhat))`.
///
/// dw/db are cross-row reductions and follow the fixed [`REDUCE_ROWS`]
/// tree: per-block partials accumulated in ascending row order, combined
/// into the accumulators in ascending block order — the exact float-add
/// tree the parallel kernel reproduces at every thread count.
pub fn layer_norm_bwd(
    dy: &[f32],
    xhat: &[f32],
    rstd: &[f32],
    w: &[f32],
    rows: usize,
    d: usize,
    dw_acc: &mut [f32],
    db_acc: &mut [f32],
) -> Vec<f32> {
    assert_eq!(dy.len(), rows * d);
    assert_eq!(dw_acc.len(), d);
    assert_eq!(db_acc.len(), d);
    let mut dx = vec![0.0f32; rows * d];
    for r in 0..rows {
        let dyr = &dy[r * d..(r + 1) * d];
        let xhr = &xhat[r * d..(r + 1) * d];
        let mut m1 = 0.0f32; // mean(dxhat)
        let mut m2 = 0.0f32; // mean(dxhat * xhat)
        for c in 0..d {
            let dxh = dyr[c] * w[c];
            m1 += dxh;
            m2 += dxh * xhr[c];
        }
        m1 /= d as f32;
        m2 /= d as f32;
        let rs = rstd[r];
        let dxr = &mut dx[r * d..(r + 1) * d];
        for c in 0..d {
            let dxh = dyr[c] * w[c];
            dxr[c] = rs * (dxh - m1 - xhr[c] * m2);
        }
    }
    layer_norm_dwdb(dy, xhat, rows, d, dw_acc, db_acc);
    dx
}

/// The dw/db tree of [`layer_norm_bwd`], exposed so the parallel kernel
/// can reuse one block-partial implementation (determinism by shared code,
/// not by parallel re-derivation).
pub fn layer_norm_dwdb(
    dy: &[f32],
    xhat: &[f32],
    rows: usize,
    d: usize,
    dw_acc: &mut [f32],
    db_acc: &mut [f32],
) {
    assert_eq!(dy.len(), rows * d);
    assert_eq!(xhat.len(), rows * d);
    assert_eq!(dw_acc.len(), d);
    assert_eq!(db_acc.len(), d);
    let mut pw = vec![0.0f32; d];
    let mut pb = vec![0.0f32; d];
    for b0 in (0..rows).step_by(REDUCE_ROWS) {
        let b1 = (b0 + REDUCE_ROWS).min(rows);
        pw.iter_mut().for_each(|x| *x = 0.0);
        pb.iter_mut().for_each(|x| *x = 0.0);
        layer_norm_dwdb_block(dy, xhat, b0, b1, d, &mut pw, &mut pb);
        for c in 0..d {
            dw_acc[c] += pw[c];
            db_acc[c] += pb[c];
        }
    }
}

/// One block partial of the dw/db tree: rows `b0..b1` accumulated in
/// ascending order into `pw`/`pb`.
pub fn layer_norm_dwdb_block(
    dy: &[f32],
    xhat: &[f32],
    b0: usize,
    b1: usize,
    d: usize,
    pw: &mut [f32],
    pb: &mut [f32],
) {
    for r in b0..b1 {
        let dyr = &dy[r * d..(r + 1) * d];
        let xhr = &xhat[r * d..(r + 1) * d];
        for c in 0..d {
            pw[c] += dyr[c] * xhr[c];
            pb[c] += dyr[c];
        }
    }
}

/// Embedding backward: scatter `dh` rows into `dwte` (by token id) and
/// `dwpe` (by position `r % t`), accumulating in ascending batch-row order
/// per destination row. The parallel kernel computes the identical sums
/// owner-computes (each worker owns destination rows and walks the batch
/// ascending), so the two are bit-equal at every thread count.
pub fn embed_scatter(
    dwte: &mut [f32],
    dwpe: &mut [f32],
    dh: &[f32],
    x: &[i32],
    m: usize,
    t: usize,
    d: usize,
) {
    assert_eq!(dh.len(), m * d);
    assert_eq!(x.len(), m);
    assert!(d > 0 && t > 0, "embed_scatter: empty dims");
    assert_eq!(dwte.len() % d, 0);
    assert_eq!(dwpe.len(), t * d);
    for r in 0..m {
        let tok = x[r] as usize;
        let s = r % t;
        let src = &dh[r * d..(r + 1) * d];
        let wte_row = &mut dwte[tok * d..(tok + 1) * d];
        for c in 0..d {
            wte_row[c] += src[c];
        }
        let wpe_row = &mut dwpe[s * d..(s + 1) * d];
        for c in 0..d {
            wpe_row[c] += src[c];
        }
    }
}

/// Sum of squares over a tensor list (the pre-clip grad norm, before the
/// square root), on the fixed [`NORM_BLOCK`] tree: per-block f64 partials
/// in ascending element order, combined in ascending (tensor, block)
/// order.
pub fn sq_norm(tensors: &[Vec<f32>]) -> f64 {
    let mut total = 0.0f64;
    for t in tensors {
        for block in t.chunks(NORM_BLOCK) {
            total += sq_norm_block(block);
        }
    }
    total
}

/// One f64 block partial of the grad-norm tree.
pub fn sq_norm_block(block: &[f32]) -> f64 {
    let mut p = 0.0f64;
    for &x in block {
        p += (x as f64) * (x as f64);
    }
    p
}

pub const GELU_C: f32 = 0.797_884_56; // sqrt(2/pi)
pub const GELU_A: f32 = 0.044715;

/// Tanh-approximate GELU (matches `jax.nn.gelu(approximate=True)`).
pub fn gelu(u: &[f32]) -> Vec<f32> {
    u.iter()
        .map(|&x| {
            let t = (GELU_C * (x + GELU_A * x * x * x)).tanh();
            0.5 * x * (1.0 + t)
        })
        .collect()
}

/// GELU backward: `du = dg * gelu'(u)`.
pub fn gelu_bwd(u: &[f32], dg: &[f32]) -> Vec<f32> {
    assert_eq!(u.len(), dg.len());
    u.iter()
        .zip(dg.iter())
        .map(|(&x, &d)| {
            let inner = GELU_C * (x + GELU_A * x * x * x);
            let t = inner.tanh();
            let dinner = GELU_C * (1.0 + 3.0 * GELU_A * x * x);
            d * (0.5 * (1.0 + t) + 0.5 * x * (1.0 - t * t) * dinner)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_small() {
        // [1 2; 3 4] @ [5 6; 7 8] = [19 22; 43 50]
        let a = [1.0, 2.0, 3.0, 4.0];
        let b = [5.0, 6.0, 7.0, 8.0];
        assert_eq!(matmul(&a, &b, 2, 2, 2), vec![19.0, 22.0, 43.0, 50.0]);
    }

    #[test]
    fn matmul_tn_matches_explicit_transpose() {
        let a = [1.0, 2.0, 3.0, 4.0, 5.0, 6.0]; // 3x2
        let b = [1.0, 0.0, 2.0, 1.0, 0.0, 3.0]; // 3x2
        let at = [1.0, 3.0, 5.0, 2.0, 4.0, 6.0]; // 2x3
        assert_eq!(matmul_tn(&a, &b, 3, 2, 2), matmul(&at, &b, 2, 3, 2));
    }

    #[test]
    fn matmul_nt_matches_explicit_transpose() {
        let a = [1.0, 2.0, 3.0, 4.0]; // 2x2
        let b = [5.0, 6.0, 7.0, 8.0]; // 2x2
        let bt = [5.0, 7.0, 6.0, 8.0];
        assert_eq!(matmul_nt(&a, &b, 2, 2, 2), matmul(&a, &bt, 2, 2, 2));
    }

    #[test]
    fn layernorm_rows_are_normalized() {
        let x = [1.0f32, 2.0, 3.0, 4.0, 10.0, 20.0, 30.0, 40.0];
        let w = [1.0f32; 4];
        let b = [0.0f32; 4];
        let (y, xhat, rstd) = layer_norm_fwd(&x, &w, &b, 2, 4);
        for r in 0..2 {
            let row = &y[r * 4..(r + 1) * 4];
            let mean: f32 = row.iter().sum::<f32>() / 4.0;
            let var: f32 = row.iter().map(|v| (v - mean) * (v - mean)).sum::<f32>() / 4.0;
            assert!(mean.abs() < 1e-5, "row {r} mean {mean}");
            assert!((var - 1.0).abs() < 1e-2, "row {r} var {var}");
        }
        assert_eq!(y, xhat);
        assert!(rstd[0] > rstd[1]); // wider row -> smaller rstd
    }

    #[test]
    fn layernorm_bwd_finite_difference() {
        // check dx against a central finite difference of sum(ln(x) * g)
        let x = vec![0.3f32, -1.2, 0.7, 2.1, 0.9, -0.4];
        let w = vec![1.1f32, 0.9, 1.3];
        let b = vec![0.1f32, -0.2, 0.0];
        let g = vec![0.7f32, -0.3, 0.5, 0.2, 0.8, -0.6]; // upstream grad
        let f = |xs: &[f32]| -> f32 {
            let (y, _, _) = layer_norm_fwd(xs, &w, &b, 2, 3);
            y.iter().zip(&g).map(|(a, b)| a * b).sum()
        };
        let (_, xhat, rstd) = layer_norm_fwd(&x, &w, &b, 2, 3);
        let mut dw = vec![0.0f32; 3];
        let mut db = vec![0.0f32; 3];
        let dx = layer_norm_bwd(&g, &xhat, &rstd, &w, 2, 3, &mut dw, &mut db);
        for i in 0..x.len() {
            let eps = 1e-3f32;
            let mut xp = x.clone();
            xp[i] += eps;
            let mut xm = x.clone();
            xm[i] -= eps;
            let fd = (f(&xp) - f(&xm)) / (2.0 * eps);
            assert!(
                (fd - dx[i]).abs() < 2e-2 * fd.abs().max(1.0),
                "dx[{i}]: fd {fd} vs analytic {}",
                dx[i]
            );
        }
        // db is just the column sum of g
        assert!((db[0] - (g[0] + g[3])).abs() < 1e-6);
    }

    #[test]
    fn gelu_bwd_finite_difference() {
        let u = vec![-2.0f32, -0.5, 0.0, 0.3, 1.7];
        let dg = vec![1.0f32; 5];
        let du = gelu_bwd(&u, &dg);
        for i in 0..u.len() {
            let eps = 1e-3f32;
            let fp = gelu(&[u[i] + eps])[0];
            let fm = gelu(&[u[i] - eps])[0];
            let fd = (fp - fm) / (2.0 * eps);
            assert!((fd - du[i]).abs() < 1e-3, "du[{i}]: fd {fd} vs {}", du[i]);
        }
    }

    #[test]
    fn gelu_values() {
        // gelu(0) = 0, gelu(large) ~ identity, gelu(-large) ~ 0
        let y = gelu(&[0.0, 6.0, -6.0]);
        assert_eq!(y[0], 0.0);
        assert!((y[1] - 6.0).abs() < 1e-3);
        assert!(y[2].abs() < 1e-3);
    }
}
