//! Pure-rust native executor: quantized GPT-2 forward + backward + AdamW.
//!
//! This is a faithful port of the L2 compute graph (`python/compile/
//! model.py`, `quantizer.py`, `adam.py`) to hand-written rust:
//!
//! * pre-LN GPT-2 blocks (causal attention, tanh-GELU MLP, learned
//!   positional embeddings, tied input/output embeddings);
//! * fake quantization injected at the paper's Fig. 1 points via the
//!   bit-exact [`crate::quant`] oracle — forward `y = qdq_a(x) @ qdq_w(W)`,
//!   backward `dW = qdq_a(x)ᵀ @ qdq_g(g)` with the straight-through
//!   estimator (gradients flow to the latent fp32 weights), and the
//!   unstable `quantize_act_grads` variant quantizing the dx path;
//! * AdamW with optionally fake-quantized moments per §3.4: the quantized
//!   moment is what is stored *and* what the update reads, which is what
//!   makes the second moment fragile (Fig. 12's zero-bin collapse).
//!
//! The backward pass was validated against `jax.value_and_grad` of the L2
//! graph for every quant structure (max relative gradient error ~6e-7), and
//! the AdamW update against `adam.adamw_update` exactly.
//!
//! Linears whose recipe is **int8-structured** ([`int8_structure`]: both
//! operands symmetric 8-bit with scales constant along the forward
//! reduction axis — acts per-tensor/per-token, weights per-tensor/
//! per-channel) run on **packed i8 codes end to end**: forward quantizes
//! each operand once (`pack_acts_i8` / `pack_weights_i8`), caches the
//! codes in the per-step layer cache, and backward reuses them — the
//! weight-grad contraction consumes the cached activation codes plus
//! freshly packed gradient codes (`pack_grads_i8`, when the gradient
//! policy is [`quant::int8_grad_eligible`]), and the input-grad
//! contraction reuses the forward-packed weight codes, so weights are
//! packed **at most once per train step** and invalidated by construction
//! when the cache drops before the AdamW update. The [`set_int8_gemm`]
//! knob (`QPRETRAIN_INT8` env) selects only the *accumulator* on the
//! reduction-constant-scale contractions — exact i32 (on) or an f32 fold
//! of the identical integer code products (off); packing and cache reuse
//! are knob-independent, which is what lets the CI digest matrix byte-diff
//! the two legs. Recipes that are not int8-structured (asymmetric, other
//! bit-widths, per-channel acts, per-token weights, unquantized operands)
//! keep the f32 qdq reference path for the whole linear, forward and
//! backward. `rust/tests/int8.rs` pins bitwise equality where f32
//! accumulation is exact and bounds the rounding gap elsewhere. All paths
//! run on the runtime-dispatched SIMD microkernels (`backend::simd`;
//! [`simd_active`] introspects, `QPRETRAIN_SIMD=off` pins the
//! bit-identical scalar lane emulation).

use std::borrow::Cow;
use std::collections::HashMap;
use std::sync::atomic::{AtomicU8, AtomicUsize, Ordering};
use std::sync::OnceLock;

use anyhow::{bail, Result};

// Row/tile-parallel kernels for the (M x …) hot path; the serial `math`
// reference handles the small per-(batch, head) attention tiles *inside*
// the parallel regions (tiles are the unit of parallelism there, and the
// serial tile kernels are what the parallel ones are bit-equal to anyway).
use crate::backend::kernels::{
    add_assign, bias_add, causal_softmax, col_sum_acc, embed_scatter, gelu, gelu_bwd,
    layer_norm_bwd, layer_norm_fwd, matmul, matmul_acc, matmul_i8_nt_packed, matmul_i8_packed,
    matmul_i8_tn_packed, matmul_i8_tn_scaled_acc, matmul_nt, matmul_tn, matmul_tn_acc, nll_only,
    nll_rows, par_chunks2_mut, par_chunks3_mut, par_chunks_mut, rescale_f32, rescale_f32_acc,
    rescale_i32, rescale_i32_acc, sq_norm,
};
use crate::backend::math;
use crate::backend::{ActProbe, Backend, EvalOut, GradProbe, StepOut};
use crate::config::{QuantRecipe, TensorPolicy};
use crate::model::HostState;
use crate::quant;
use crate::runtime::{ModelInfo, ParamInfo};

// AdamW hyperparameters (python/compile/configs.HyperParams; paper App. A).
pub const BETA1: f32 = 0.9;
pub const BETA2: f32 = 0.95;
pub const ADAM_EPS: f32 = 1e-8;
pub const WEIGHT_DECAY: f32 = 0.1;
pub const GRAD_CLIP: f32 = 1.0;

// Parameter indices in the canonical order of `python/compile/model.py
// param_defs` (the manifest order; `model_info` reproduces it).
pub const WTE: usize = 0;
pub const WPE: usize = 1;
pub const LN1_W: usize = 2;
pub const LN1_B: usize = 3;
pub const QKV_W: usize = 4;
pub const QKV_B: usize = 5;
pub const PROJ_W: usize = 6;
pub const PROJ_B: usize = 7;
pub const LN2_W: usize = 8;
pub const LN2_B: usize = 9;
pub const FC1_W: usize = 10;
pub const FC1_B: usize = 11;
pub const FC2_W: usize = 12;
pub const FC2_B: usize = 13;
pub const LNF_W: usize = 14;
pub const LNF_B: usize = 15;

pub const N_PARAM_TENSORS: usize = 16;

// ---------------------------------------------------------------------------
// native model registry
// ---------------------------------------------------------------------------

/// Build a [`ModelInfo`] with the canonical GPT-2 parameter layout (the same
/// defs, order, init specs and decay flags `python/compile/model.py`
/// records in the manifest).
pub fn model_info(
    name: &str,
    n_layer: usize,
    d_model: usize,
    n_head: usize,
    vocab: usize,
    seq: usize,
    batch: usize,
) -> ModelInfo {
    assert!(d_model % n_head == 0, "n_head must divide d_model");
    let (l, d, v, t) = (n_layer, d_model, vocab, seq);
    let f = 4 * d;
    let p = |name: &str, shape: Vec<usize>, stacked: bool, decay: bool, init: &str| ParamInfo {
        name: name.to_string(),
        shape,
        stacked,
        decay,
        init: init.to_string(),
    };
    let params = vec![
        p("wte", vec![v, d], false, true, "normal:0.02"),
        p("wpe", vec![t, d], false, true, "normal:0.01"),
        p("ln1_w", vec![l, d], true, false, "ones"),
        p("ln1_b", vec![l, d], true, false, "zeros"),
        p("qkv_w", vec![l, d, 3 * d], true, true, "normal:0.02"),
        p("qkv_b", vec![l, 3 * d], true, false, "zeros"),
        p("proj_w", vec![l, d, d], true, true, "residual"),
        p("proj_b", vec![l, d], true, false, "zeros"),
        p("ln2_w", vec![l, d], true, false, "ones"),
        p("ln2_b", vec![l, d], true, false, "zeros"),
        p("fc1_w", vec![l, d, f], true, true, "normal:0.02"),
        p("fc1_b", vec![l, f], true, false, "zeros"),
        p("fc2_w", vec![l, f, d], true, true, "residual"),
        p("fc2_b", vec![l, d], true, false, "zeros"),
        p("lnf_w", vec![d], false, false, "ones"),
        p("lnf_b", vec![d], false, false, "zeros"),
    ];
    let per_layer = 2 * d + d * 3 * d + 3 * d + d * d + d + 2 * d + d * f + f + f * d + d;
    ModelInfo {
        name: name.to_string(),
        n_layer,
        d_model,
        n_head,
        vocab,
        seq,
        batch,
        d_ff: f,
        n_params: v * d + t * d + l * per_layer + 2 * d,
        params,
    }
}

/// The models the native backend ships: the study model `t4`, the ~100M
/// `gpt2s` (slow natively; intended for the pjrt feature or patience), and
/// `micro`, a seconds-scale model for tests, examples and CI.
pub fn native_models() -> HashMap<String, ModelInfo> {
    let mut m = HashMap::new();
    for info in [
        model_info("t4", 4, 128, 4, 512, 128, 16),
        model_info("gpt2s", 12, 768, 12, 8192, 256, 2),
        model_info("micro", 2, 32, 2, 64, 128, 4),
    ] {
        m.insert(info.name.clone(), info);
    }
    m
}

// ---------------------------------------------------------------------------
// fake-quant helpers (Fig. 1 injection points)
// ---------------------------------------------------------------------------

fn qdq_matrix(x: &[f32], rows: usize, cols: usize, policy: TensorPolicy) -> Vec<f32> {
    let mut out = x.to_vec();
    quant::qdq(&mut out, rows, cols, policy);
    out
}

/// Activation operand of a linear that is also cached raw: `None` when the
/// recipe leaves activations unquantized (avoids duplicating the buffer).
fn qdq_act_opt(
    x: &[f32],
    rows: usize,
    cols: usize,
    policy: Option<TensorPolicy>,
) -> Option<Vec<f32>> {
    policy.map(|p| qdq_matrix(x, rows, cols, p))
}

/// Fake-quantize an activation in place, consuming it (for activations not
/// otherwise cached: no copy in the unquantized case).
fn qdq_act_owned(
    mut x: Vec<f32>,
    rows: usize,
    cols: usize,
    policy: Option<TensorPolicy>,
) -> Vec<f32> {
    if let Some(p) = policy {
        quant::qdq(&mut x, rows, cols, p);
    }
    x
}

/// Weight operand: borrowed when unquantized (weights are large).
fn qdq_weight<'a>(
    w: &'a [f32],
    rows: usize,
    cols: usize,
    policy: Option<TensorPolicy>,
) -> Cow<'a, [f32]> {
    match policy {
        Some(p) => Cow::Owned(qdq_matrix(w, rows, cols, p)),
        None => Cow::Borrowed(w),
    }
}

/// Output-gradient operand of the backward matmuls.
fn qdq_grad<'a>(
    g: &'a [f32],
    rows: usize,
    cols: usize,
    policy: Option<TensorPolicy>,
) -> Cow<'a, [f32]> {
    match policy {
        Some(p) => Cow::Owned(qdq_matrix(g, rows, cols, p)),
        None => Cow::Borrowed(g),
    }
}

// ---------------------------------------------------------------------------
// packed-int8 GEMM dispatch (the quantized fast path)
// ---------------------------------------------------------------------------

const INT8_UNSET: u8 = 0;
const INT8_ON: u8 = 1;
const INT8_OFF: u8 = 2;

/// Process-wide accumulator selection for the packed-int8 GEMMs. Unset
/// resolves from the `QPRETRAIN_INT8` environment knob (on unless `off`).
static INT8_GEMM: AtomicU8 = AtomicU8::new(INT8_UNSET);

/// `QPRETRAIN_INT8=off|0|OFF` pins the packed GEMMs to the f32 fold of the
/// integer code products for the whole process (mirroring
/// `QPRETRAIN_SIMD`); the CI digest matrix runs legs of both settings to
/// prove the two accumulators agree bit for bit on the runners.
fn env_int8_off() -> bool {
    static CACHE: OnceLock<bool> = OnceLock::new();
    *CACHE.get_or_init(|| {
        matches!(
            std::env::var("QPRETRAIN_INT8").as_deref(),
            Ok("off") | Ok("0") | Ok("OFF")
        )
    })
}

/// The process default for the int8-accumulator knob as resolved from the
/// environment (`QPRETRAIN_INT8`), before any [`set_int8_gemm`] override.
/// Test guards restore to this instead of a hard-coded `true` so the CI
/// int8-off legs stay pinned through guarded sections.
pub fn int8_env_default() -> bool {
    !env_int8_off()
}

/// Pin the packed-GEMM accumulator: `true` = exact i32 + single rescale,
/// `false` = f32 fold of the *same* integer code products (the
/// digest-equivalence leg, and the timing baseline for the benches). This
/// selects arithmetic, not structure: operand packing, the packed-weight
/// cache, and backward code reuse are decided by recipe eligibility alone
/// ([`int8_structure`]), so both settings run one identical quantization
/// pass and differ only by summation rounding — `rust/tests/int8.rs`
/// bounds the gap and pins bitwise equality where f32 accumulation of the
/// integer products is exact.
pub fn set_int8_gemm(on: bool) {
    INT8_GEMM.store(if on { INT8_ON } else { INT8_OFF }, Ordering::Relaxed);
}

/// Whether the exact-i32 accumulator is currently selected (explicit
/// [`set_int8_gemm`] override, else the `QPRETRAIN_INT8` env default).
pub fn int8_gemm_enabled() -> bool {
    match INT8_GEMM.load(Ordering::Relaxed) {
        INT8_ON => true,
        INT8_OFF => false,
        _ => int8_env_default(),
    }
}

/// Whether the SIMD microkernel vector path is active for this process
/// (CPU support ∧ `QPRETRAIN_SIMD` ∧ `kernels::set_simd`). Introspection
/// only: the scalar lane emulation is bit-identical, so this predicts
/// throughput, never results.
pub fn simd_active() -> bool {
    crate::backend::simd::simd_active()
}

/// Structural eligibility of one linear for the packed-i8 path: both
/// operands quantized, symmetric 8-bit, with scales constant along the
/// forward reduction axis (activations per-tensor/per-token, weights
/// per-tensor/per-channel). Anything else — asymmetric, other bit-widths,
/// per-channel activations, per-token weights, an unquantized operand —
/// keeps the whole linear, forward *and* backward, on the f32 qdq
/// reference path. Structure is knob-independent: when it holds, the
/// operands are packed once and cached regardless of
/// [`int8_gemm_enabled`], which only picks the accumulator.
pub fn int8_structure(acts: Option<TensorPolicy>, weights: Option<TensorPolicy>) -> bool {
    acts.is_some_and(quant::int8_act_eligible)
        && weights.is_some_and(quant::int8_weight_eligible)
}

/// Whether a forward linear with these operand policies runs the packed-i8
/// GEMM with exact i32 accumulation: [`int8_structure`] ∧ the
/// [`set_int8_gemm`] knob. (With the knob off the same packed operands are
/// folded in f32 — see [`set_int8_gemm`].)
pub fn int8_dispatch(acts: Option<TensorPolicy>, weights: Option<TensorPolicy>) -> bool {
    int8_gemm_enabled() && int8_structure(acts, weights)
}

/// Dispatch counters for the packed-int8 paths. Process-wide, bumped only
/// from the dispatching (main) thread; pure introspection for tests and
/// benches — the kernels never branch on them.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct Int8Stats {
    /// Forward linears that ran on packed i8 codes.
    pub fwd_packed: usize,
    /// Backward weight-grad (`xᵀ·dy`) contractions that ran on packed codes.
    pub tn_packed: usize,
    /// Backward input-grad (`dy·wᵀ`) contractions that reused the cached
    /// packed weight codes (integer kernel or code-dequantized fallback).
    pub nt_packed: usize,
    /// `pack_weights_i8` invocations — the pack-once-per-step invariant is
    /// exactly one per eligible linear per forward pass.
    pub weight_packs: usize,
}

static FWD_PACKED: AtomicUsize = AtomicUsize::new(0);
static TN_PACKED: AtomicUsize = AtomicUsize::new(0);
static NT_PACKED: AtomicUsize = AtomicUsize::new(0);
static WEIGHT_PACKS: AtomicUsize = AtomicUsize::new(0);

/// Snapshot and reset the packed-path dispatch counters.
pub fn take_int8_stats() -> Int8Stats {
    Int8Stats {
        fwd_packed: FWD_PACKED.swap(0, Ordering::Relaxed),
        tn_packed: TN_PACKED.swap(0, Ordering::Relaxed),
        nt_packed: NT_PACKED.swap(0, Ordering::Relaxed),
        weight_packs: WEIGHT_PACKS.swap(0, Ordering::Relaxed),
    }
}

/// Cached left operand of a linear, as forward produced it: packed i8
/// codes on the int8-structure path, fake-quantized f32 values on the
/// reference path.
enum ActCache {
    F32(Vec<f32>),
    Packed(quant::PackedGemmOperand),
}

impl ActCache {
    /// The packed codes, when forward took the packed path.
    fn packed(&self) -> Option<&quant::PackedGemmOperand> {
        match self {
            ActCache::Packed(p) => Some(p),
            ActCache::F32(_) => None,
        }
    }

    /// The f32 operand for the reference matmuls: borrowed on the qdq
    /// path, dequantized from the codes on the packed path
    /// (value-identical to `quant::qdq` up to the sign of zero-bin zeros;
    /// see `quant::PackedGemmOperand`).
    fn to_f32(&self) -> Cow<'_, [f32]> {
        match self {
            ActCache::F32(v) => Cow::Borrowed(v.as_slice()),
            ActCache::Packed(p) => Cow::Owned(quant::dequant_acts_i8(p)),
        }
    }
}

/// One forward linear `y = qdq_a(x) @ qdq_w(w)` (x owned, (m x k); w
/// (k x n)). On the int8-structure path both operands are quantized
/// **once** to i8 codes, contracted over the codes (exact i32 + single
/// rescale when [`int8_gemm_enabled`], f32 fold of the same integer
/// products otherwise), and the packed operands — not dequantized f32 —
/// are returned for backward to reuse: `(y, activation cache,
/// packed weight cache)`.
fn quant_linear(
    x: Vec<f32>,
    w: &[f32],
    m: usize,
    k: usize,
    n: usize,
    qs: &QuantRecipe,
) -> (Vec<f32>, ActCache, Option<quant::PackedGemmOperand>) {
    if int8_structure(qs.acts, qs.weights) {
        let (ap, wpol) = (qs.acts.unwrap(), qs.weights.unwrap());
        let xa = quant::pack_acts_i8(&x, m, k, ap);
        let wp = quant::pack_weights_i8(w, k, n, wpol);
        FWD_PACKED.fetch_add(1, Ordering::Relaxed);
        WEIGHT_PACKS.fetch_add(1, Ordering::Relaxed);
        let y = if int8_gemm_enabled() {
            rescale_i32(&matmul_i8_packed(&xa, &wp), &xa.scales, &wp.scales, m, n)
        } else {
            let cf = matmul(&quant::codes_f32(&xa), &quant::codes_f32(&wp), m, k, n);
            rescale_f32(&cf, &xa.scales, &wp.scales, m, n)
        };
        (y, ActCache::Packed(xa), Some(wp))
    } else {
        let xq = qdq_act_owned(x, m, k, qs.acts);
        let wq = qdq_weight(w, k, n, qs.weights);
        let y = matmul(&xq, &wq, m, k, n);
        (y, ActCache::F32(xq), None)
    }
}

/// Accumulating variant (`acc += qdq_a(x) @ qdq_w(w)`) for the residual
/// linears. The activation cache is `None` when activations are
/// unquantized (matching the [`qdq_act_opt`] contract — an unquantized
/// activation operand is never int8-structured).
fn quant_linear_acc(
    x: &[f32],
    w: &[f32],
    m: usize,
    k: usize,
    n: usize,
    qs: &QuantRecipe,
    acc: &mut [f32],
) -> (Option<ActCache>, Option<quant::PackedGemmOperand>) {
    if int8_structure(qs.acts, qs.weights) {
        let (ap, wpol) = (qs.acts.unwrap(), qs.weights.unwrap());
        let xa = quant::pack_acts_i8(x, m, k, ap);
        let wp = quant::pack_weights_i8(w, k, n, wpol);
        FWD_PACKED.fetch_add(1, Ordering::Relaxed);
        WEIGHT_PACKS.fetch_add(1, Ordering::Relaxed);
        if int8_gemm_enabled() {
            let ci = matmul_i8_packed(&xa, &wp);
            rescale_i32_acc(acc, &ci, &xa.scales, &wp.scales, m, n);
        } else {
            let cf = matmul(&quant::codes_f32(&xa), &quant::codes_f32(&wp), m, k, n);
            rescale_f32_acc(acc, &cf, &xa.scales, &wp.scales, m, n);
        }
        (Some(ActCache::Packed(xa)), Some(wp))
    } else {
        let xq = qdq_act_opt(x, m, k, qs.acts);
        let wq = qdq_weight(w, k, n, qs.weights);
        matmul_acc(acc, xq.as_deref().unwrap_or(x), &wq, m, k, n);
        (xq.map(ActCache::F32), None)
    }
}

/// Left operand of a backward weight-grad contraction.
#[derive(Clone, Copy)]
enum XOperand<'a> {
    /// The linear's forward activation cache (packed codes or qdq values).
    Cache(&'a ActCache),
    /// The raw activation — the residual linears don't duplicate the
    /// buffer when the recipe leaves activations unquantized.
    Raw(&'a [f32]),
}

impl<'a> XOperand<'a> {
    fn packed(self) -> Option<&'a quant::PackedGemmOperand> {
        match self {
            XOperand::Cache(c) => c.packed(),
            XOperand::Raw(_) => None,
        }
    }

    fn to_f32(self) -> Cow<'a, [f32]> {
        match self {
            XOperand::Cache(c) => c.to_f32(),
            XOperand::Raw(r) => Cow::Borrowed(r),
        }
    }
}

/// Backward of one linear with forward shape `(m x k) @ (k x n)`:
/// accumulates the weight gradient `dw += xᵀ @ qdq_g(dy)` and returns the
/// input gradient `dx = gy @ wᵀ` (`gy` is the quantized gradient on the
/// `quantize_act_grads` variant, the raw straight-through `dy` otherwise —
/// Sec. 2.4 of the paper).
///
/// Dispatch: when forward packed this linear (`wp`/`xop` carry codes) and
/// the gradient policy is [`quant::int8_grad_eligible`], `dy` is packed
/// once to i8 codes ([`quant::pack_grads_i8`] — per-token scales sit on
/// the output axis, which both backward contractions reduce over, so the
/// scale sets are reduction-axis-constant) and both contractions consume
/// integer codes: exact i32 + single rescale where the scale sets are
/// constant over the whole reduction (knob-off leg folds the same code
/// products in f32), the row-factored [`matmul_i8_tn_scaled_acc`] for
/// per-token scale sets, and code-dequantized f32 operands where
/// per-channel weight scales vary along the input-grad reduction. Any
/// other recipe falls back to the f32 qdq reference path bit for bit,
/// still reusing the cached packed weights for the dequantize (no second
/// amax scan of the weights).
#[allow(clippy::too_many_arguments)]
fn quant_linear_bwd(
    dy: &[f32],
    xop: XOperand<'_>,
    wp: Option<&quant::PackedGemmOperand>,
    w: &[f32],
    m: usize,
    k: usize,
    n: usize,
    qs: &QuantRecipe,
    dw: &mut [f32],
) -> Vec<f32> {
    let act_grad_path = qs.grads.is_some() && qs.quantize_act_grads;
    let grad_pol = qs.grads.filter(|&p| quant::int8_grad_eligible(p));
    if let (Some(xa), Some(wpp), Some(gp)) = (xop.packed(), wp, grad_pol) {
        let gq = quant::pack_grads_i8(dy, m, n, gp);
        // weight grad: dw += xaᵀ @ gq (reduction over the m rows)
        TN_PACKED.fetch_add(1, Ordering::Relaxed);
        if xa.scales.len() == 1 && gq.scales.len() == 1 {
            // per-tensor × per-tensor: integer core, single rescale
            if int8_gemm_enabled() {
                let ci = matmul_i8_tn_packed(xa, &gq);
                rescale_i32_acc(dw, &ci, &xa.scales, &gq.scales, k, n);
            } else {
                let cf = matmul_tn(&quant::codes_f32(xa), &quant::codes_f32(&gq), m, k, n);
                rescale_f32_acc(dw, &cf, &xa.scales, &gq.scales, k, n);
            }
        } else {
            // per-token scales vary over the reduction: row-factored core
            // (knob-independent; fma folds the exact real products)
            matmul_i8_tn_scaled_acc(dw, xa, &gq);
        }
        // input grad: dx = gy @ wᵀ (reduction over the n columns)
        NT_PACKED.fetch_add(1, Ordering::Relaxed);
        if act_grad_path {
            if wpp.scales.len() == 1 {
                // per-tensor weight scale is constant along this reduction
                if int8_gemm_enabled() {
                    let ci = matmul_i8_nt_packed(&gq, wpp);
                    rescale_i32(&ci, &gq.scales, &wpp.scales, m, k)
                } else {
                    let cf = matmul_nt(&quant::codes_f32(&gq), &quant::codes_f32(wpp), m, n, k);
                    rescale_f32(&cf, &gq.scales, &wpp.scales, m, k)
                }
            } else {
                // per-channel weight scales vary along the reduction: no
                // integer fold possible — dequantize both code caches
                let wq = quant::dequant_weights_i8(wpp);
                let gyf = quant::dequant_acts_i8(&gq);
                matmul_nt(&gyf, &wq, m, n, k)
            }
        } else {
            // straight-through dx: raw dy against the code-dequantized
            // cached weights (no re-quantization pass)
            let wq = quant::dequant_weights_i8(wpp);
            matmul_nt(dy, &wq, m, n, k)
        }
    } else {
        // f32 qdq reference path: gradient not 8-bit symmetric
        // per-tensor/per-token, or the forward linear was not packed
        let gq = qdq_grad(dy, m, n, qs.grads);
        matmul_tn_acc(dw, &xop.to_f32(), &gq, m, k, n);
        let wq = match wp {
            Some(p) => Cow::Owned(quant::dequant_weights_i8(p)),
            None => qdq_weight(w, k, n, qs.weights),
        };
        let gx: &[f32] = if act_grad_path { &gq } else { dy };
        matmul_nt(gx, &wq, m, n, k)
    }
}

// ---------------------------------------------------------------------------
// forward
// ---------------------------------------------------------------------------

#[derive(Clone, Copy)]
struct Dims {
    l: usize,
    d: usize,
    h: usize,
    hd: usize,
    f: usize,
    v: usize,
    t: usize,
    b: usize,
    m: usize, // b * t rows
}

impl Dims {
    fn of(model: &ModelInfo) -> Dims {
        let d = model.d_model;
        let h = model.n_head;
        Dims {
            l: model.n_layer,
            d,
            h,
            hd: d / h,
            f: model.d_ff,
            v: model.vocab,
            t: model.seq,
            b: model.batch,
            m: model.batch * model.seq,
        }
    }
}

/// Per-layer forward cache (everything backward needs). On the packed
/// path the activation caches hold i8 codes, not dequantized f32, and the
/// `*_wp` fields carry the forward-packed weight codes — this is the
/// per-step packed-weight cache: backward reuses the codes, and the whole
/// cache is dropped before [`adamw_update`] mutates the latent weights,
/// so a stale packing can never survive an optimizer step.
struct LayerCache {
    xhat1: Vec<f32>,
    rstd1: Vec<f32>,
    xq: ActCache, // (M, d)  qdq_a(ln1 out) — the QKV matmul's left operand
    q: Vec<f32>,  // (b, h, t, hd) contiguous per (b, h)
    k: Vec<f32>,
    v: Vec<f32>,
    p: Vec<f32>,   // (b, h, t, t) softmax probabilities (0 above diagonal)
    ctx: Vec<f32>,         // (M, d) attn out-proj input (Fig. 6 probe tensor)
    cq: Option<ActCache>,  // qdq_a(ctx); None when acts are unquantized
    xhat2: Vec<f32>,
    rstd2: Vec<f32>,
    mq: ActCache, // (M, d)  qdq_a(ln2 out)
    u: Vec<f32>,           // (M, f)  pre-GELU
    g: Vec<f32>,           // (M, f)  post-GELU, FC2 input (Fig. 8 probe tensor)
    gq: Option<ActCache>,  // qdq_a(g); None when acts are unquantized
    qkv_wp: Option<quant::PackedGemmOperand>,
    proj_wp: Option<quant::PackedGemmOperand>,
    fc1_wp: Option<quant::PackedGemmOperand>,
    fc2_wp: Option<quant::PackedGemmOperand>,
}

struct Forward {
    logits: Vec<f32>, // (M, V)
    hf: Vec<f32>,     // (M, d) final-LN output
    xhatf: Vec<f32>,
    rstdf: Vec<f32>,
    caches: Vec<LayerCache>,
}

fn check_inputs(model: &ModelInfo, params: &[Vec<f32>], x: &[i32]) -> Result<()> {
    if params.len() != N_PARAM_TENSORS {
        bail!(
            "{}: expected {} parameter tensors, got {}",
            model.name,
            N_PARAM_TENSORS,
            params.len()
        );
    }
    for (info, p) in model.params.iter().zip(params.iter()) {
        if p.len() != info.elems() {
            bail!(
                "{}: parameter {} has {} elements, expected {}",
                model.name,
                info.name,
                p.len(),
                info.elems()
            );
        }
    }
    check_tokens(model, x)?;
    Ok(())
}

/// Validate one (batch*seq) token slice against the model dims.
fn check_tokens(model: &ModelInfo, toks: &[i32]) -> Result<()> {
    let dims = Dims::of(model);
    if toks.len() != dims.m {
        bail!(
            "{}: token batch has {} entries, expected batch*seq = {}",
            model.name,
            toks.len(),
            dims.m
        );
    }
    for &tok in toks {
        if tok < 0 || tok as usize >= dims.v {
            bail!("token id {tok} out of vocab range 0..{}", dims.v);
        }
    }
    Ok(())
}

/// Split a stacked per-layer tensor into layer `l`'s 2D slice.
fn layer_slice(p: &[f32], l: usize, per_layer: usize) -> &[f32] {
    &p[l * per_layer..(l + 1) * per_layer]
}

fn forward(model: &ModelInfo, params: &[Vec<f32>], x: &[i32], qs: &QuantRecipe) -> Forward {
    let dm = Dims::of(model);
    let (d, f, m, t, h, hd) = (dm.d, dm.f, dm.m, dm.t, dm.h, dm.hd);

    // embeddings: h[b*t + s] = wte[x] + wpe[s] (row-parallel gather)
    let mut hbuf = vec![0.0f32; m * d];
    par_chunks_mut(&mut hbuf, d, 2 * d, |rows, hb| {
        for (ri, r) in rows.clone().enumerate() {
            let tok = x[r] as usize;
            let s = r % t;
            let dst = &mut hb[ri * d..(ri + 1) * d];
            let wte_row = &params[WTE][tok * d..(tok + 1) * d];
            let wpe_row = &params[WPE][s * d..(s + 1) * d];
            for c in 0..d {
                dst[c] = wte_row[c] + wpe_row[c];
            }
        }
    });

    let inv_sqrt_hd = 1.0f32 / (hd as f32).sqrt();
    let mut caches = Vec::with_capacity(dm.l);

    for l in 0..dm.l {
        let ln1_w = layer_slice(&params[LN1_W], l, d);
        let ln1_b = layer_slice(&params[LN1_B], l, d);
        let qkv_w = layer_slice(&params[QKV_W], l, d * 3 * d);
        let qkv_b = layer_slice(&params[QKV_B], l, 3 * d);
        let proj_w = layer_slice(&params[PROJ_W], l, d * d);
        let proj_b = layer_slice(&params[PROJ_B], l, d);
        let ln2_w = layer_slice(&params[LN2_W], l, d);
        let ln2_b = layer_slice(&params[LN2_B], l, d);
        let fc1_w = layer_slice(&params[FC1_W], l, d * f);
        let fc1_b = layer_slice(&params[FC1_B], l, f);
        let fc2_w = layer_slice(&params[FC2_W], l, f * d);
        let fc2_b = layer_slice(&params[FC2_B], l, d);

        // --- attention ---
        let (a, xhat1, rstd1) = layer_norm_fwd(&hbuf, ln1_w, ln1_b, m, d);
        let (mut qkv, xq, qkv_wp) = quant_linear(a, qkv_w, m, d, 3 * d, qs);
        bias_add(&mut qkv, qkv_b, m, 3 * d);

        // de-interleave rows [q | k | v] into per-(batch, head) (T, hd)
        // tiles, parallel over (batch, head)
        let th = t * hd;
        let mut q = vec![0.0f32; m * d];
        let mut k = vec![0.0f32; m * d];
        let mut v = vec![0.0f32; m * d];
        par_chunks3_mut(&mut q, th, &mut k, th, &mut v, th, 3 * th, |bhr, qc, kc, vc| {
            for (i, bh) in bhr.clone().enumerate() {
                let b = bh / h;
                let hh = bh % h;
                for s in 0..t {
                    let row = &qkv[(b * t + s) * 3 * d..(b * t + s + 1) * 3 * d];
                    let o = i * th + s * hd;
                    qc[o..o + hd].copy_from_slice(&row[hh * hd..(hh + 1) * hd]);
                    kc[o..o + hd].copy_from_slice(&row[d + hh * hd..d + (hh + 1) * hd]);
                    vc[o..o + hd].copy_from_slice(&row[2 * d + hh * hd..2 * d + (hh + 1) * hd]);
                }
            }
        });

        // causal softmax attention, parallel over (batch, head) tiles; the
        // tile-local matmuls are the serial reference kernels, so every
        // tile is computed exactly as in the serial path
        let mut p = vec![0.0f32; dm.b * h * t * t];
        let mut ctx_heads = vec![0.0f32; m * d]; // (b*h, t, hd) tiles
        par_chunks2_mut(&mut p, t * t, &mut ctx_heads, th, 4 * t * t * hd, |bhr, pc, cc| {
            for (i, bh) in bhr.clone().enumerate() {
                let qs_ = &q[bh * th..(bh + 1) * th];
                let ks_ = &k[bh * th..(bh + 1) * th];
                let vs_ = &v[bh * th..(bh + 1) * th];
                let mut scores = math::matmul_nt(qs_, ks_, t, hd, t);
                for sc in scores.iter_mut() {
                    *sc *= inv_sqrt_hd;
                }
                let ptile = &mut pc[i * t * t..(i + 1) * t * t];
                causal_softmax(&scores, ptile, t); // j > i stays exactly 0
                let ctx_tile = math::matmul(ptile, vs_, t, t, hd);
                cc[i * th..(i + 1) * th].copy_from_slice(&ctx_tile);
            }
        });
        // regather head tiles into (M, d) rows, parallel over rows
        let mut ctx = vec![0.0f32; m * d];
        par_chunks_mut(&mut ctx, d, d, |rows, cx| {
            for (ri, r) in rows.clone().enumerate() {
                let b = r / t;
                let s = r % t;
                for hh in 0..h {
                    let o = ((b * h + hh) * t + s) * hd;
                    cx[ri * d + hh * hd..ri * d + (hh + 1) * hd]
                        .copy_from_slice(&ctx_heads[o..o + hd]);
                }
            }
        });

        let mut h2 = hbuf.clone();
        let (cq, proj_wp) = quant_linear_acc(&ctx, proj_w, m, d, d, qs, &mut h2);
        bias_add(&mut h2, proj_b, m, d);

        // --- MLP ---
        let (mm, xhat2, rstd2) = layer_norm_fwd(&h2, ln2_w, ln2_b, m, d);
        let (mut u, mq, fc1_wp) = quant_linear(mm, fc1_w, m, d, f, qs);
        bias_add(&mut u, fc1_b, m, f);
        let g = gelu(&u);
        let mut hout = h2.clone();
        let (gq, fc2_wp) = quant_linear_acc(&g, fc2_w, m, f, d, qs, &mut hout);
        bias_add(&mut hout, fc2_b, m, d);

        caches.push(LayerCache {
            xhat1,
            rstd1,
            xq,
            q,
            k,
            v,
            p,
            ctx,
            cq,
            xhat2,
            rstd2,
            mq,
            u,
            g,
            gq,
            qkv_wp,
            proj_wp,
            fc1_wp,
            fc2_wp,
        });
        hbuf = hout;
    }

    let (hf, xhatf, rstdf) = layer_norm_fwd(&hbuf, &params[LNF_W], &params[LNF_B], m, d);
    // tied LM head (not quantized): logits = hf @ wteᵀ
    let logits = matmul_nt(&hf, &params[WTE], m, d, dm.v);
    Forward {
        logits,
        hf,
        xhatf,
        rstdf,
        caches,
    }
}

// (cross-entropy: `kernels::nll_only` / `kernels::nll_rows`, row-parallel)

/// Full-context forward returning only the logits `(batch*seq, vocab)`.
/// The recipe is applied exactly as given (callers that start from a
/// training recipe derive `forward_only()` first). This is the reference
/// side of the serve equivalence proofs: `tests/serve.rs` pins KV-cached
/// decode bitwise against this full re-forward.
pub fn forward_logits(
    model: &ModelInfo,
    params: &[Vec<f32>],
    x: &[i32],
    qs: &QuantRecipe,
) -> Result<Vec<f32>> {
    check_inputs(model, params, x)?;
    Ok(forward(model, params, x, qs).logits)
}

// ---------------------------------------------------------------------------
// resident weights (the serve-path operand cache)
// ---------------------------------------------------------------------------

/// A linear's weight operand as the serve engine keeps it resident in
/// memory, quantized **once at checkpoint load** instead of per forward:
/// packed i8 codes when the recipe is [`int8_structure`]-eligible, the
/// fake-quantized (or raw) f32 matrix otherwise. Because packing is a
/// deterministic function of the weights and policy, contracting against
/// a load-time pack is bit-identical to the training forward's
/// pack-per-step — that is what lets the KV-decode equivalence proofs
/// compare against [`forward_logits`] directly.
pub enum ResidentWeight {
    /// Packed i8 codes + scales (the int8-structured fast path).
    Packed(quant::PackedGemmOperand),
    /// Fake-quantized (or raw, when weights are unquantized) f32 matrix.
    F32(Vec<f32>),
}

impl ResidentWeight {
    /// Whether this weight is resident as packed i8 codes.
    pub fn is_packed(&self) -> bool {
        matches!(self, ResidentWeight::Packed(_))
    }
}

/// Quantize one `(k x n)` weight matrix into its resident serving form
/// under the forward recipe. The dispatch mirrors [`int8_structure`]
/// exactly — structure is decided by the recipe alone, never by the
/// [`set_int8_gemm`] accumulator knob, so the same resident form serves
/// both digest legs.
pub fn pack_resident_weight(w: &[f32], k: usize, n: usize, qs: &QuantRecipe) -> ResidentWeight {
    if int8_structure(qs.acts, qs.weights) {
        WEIGHT_PACKS.fetch_add(1, Ordering::Relaxed);
        ResidentWeight::Packed(quant::pack_weights_i8(w, k, n, qs.weights.unwrap()))
    } else {
        ResidentWeight::F32(match qs.weights {
            Some(p) => qdq_matrix(w, k, n, p),
            None => w.to_vec(),
        })
    }
}

/// One serve-path linear `y = qdq_a(x) @ w_resident` over `m` decode rows.
/// Operation-for-operation the forward arm of [`quant_linear`] with the
/// per-step weight quantization replaced by the resident operand: packed
/// acts against packed codes (exact i32 or the f32 fold, by the
/// accumulator knob), f32 qdq acts against the resident f32 matrix
/// otherwise. Activation packing/qdq is row-local for every serve-eligible
/// policy (per-token or unquantized), so any subset of rows — one decode
/// step, a continuous batch, or the full context — produces bit-identical
/// output rows.
pub fn resident_linear(
    x: Vec<f32>,
    w: &ResidentWeight,
    m: usize,
    k: usize,
    n: usize,
    acts: Option<TensorPolicy>,
) -> Vec<f32> {
    match w {
        ResidentWeight::Packed(wp) => {
            let ap = acts.expect("packed resident weight requires quantized acts");
            let xa = quant::pack_acts_i8(&x, m, k, ap);
            FWD_PACKED.fetch_add(1, Ordering::Relaxed);
            if int8_gemm_enabled() {
                rescale_i32(&matmul_i8_packed(&xa, wp), &xa.scales, &wp.scales, m, n)
            } else {
                let cf = matmul(&quant::codes_f32(&xa), &quant::codes_f32(wp), m, k, n);
                rescale_f32(&cf, &xa.scales, &wp.scales, m, n)
            }
        }
        ResidentWeight::F32(wq) => {
            let xq = qdq_act_owned(x, m, k, acts);
            matmul(&xq, wq, m, k, n)
        }
    }
}

/// Accumulating serve-path linear (`acc += qdq_a(x) @ w_resident`) for the
/// residual projections — the serve twin of [`quant_linear_acc`].
pub fn resident_linear_acc(
    x: &[f32],
    w: &ResidentWeight,
    m: usize,
    k: usize,
    n: usize,
    acts: Option<TensorPolicy>,
    acc: &mut [f32],
) {
    match w {
        ResidentWeight::Packed(wp) => {
            let ap = acts.expect("packed resident weight requires quantized acts");
            let xa = quant::pack_acts_i8(x, m, k, ap);
            FWD_PACKED.fetch_add(1, Ordering::Relaxed);
            if int8_gemm_enabled() {
                let ci = matmul_i8_packed(&xa, wp);
                rescale_i32_acc(acc, &ci, &xa.scales, &wp.scales, m, n);
            } else {
                let cf = matmul(&quant::codes_f32(&xa), &quant::codes_f32(wp), m, k, n);
                rescale_f32_acc(acc, &cf, &xa.scales, &wp.scales, m, n);
            }
        }
        ResidentWeight::F32(wq) => {
            let xq = qdq_act_opt(x, m, k, acts);
            matmul_acc(acc, xq.as_deref().unwrap_or(x), wq, m, k, n);
        }
    }
}

// ---------------------------------------------------------------------------
// backward
// ---------------------------------------------------------------------------

struct BackOut {
    loss: f64,
    /// Unnormalized NLL sum over the batch's positions (f64 accumulation
    /// in position order); `loss` is this over `batch * seq`. The `dist`
    /// leaf exchange ships the sum so shard losses combine exactly.
    loss_sum: f64,
    grads: Vec<Vec<f32>>,
    d_ctx0: Vec<f32>,
}

/// Backward pass with an explicit gradient normalization: `inv_norm` is
/// the factor folded into `dlogits` (the whole-batch step uses
/// `1 / (batch * seq)`; a data-parallel *leaf* over one sequence passes
/// `1 / (global_batch * seq)` so per-sequence gradients are already terms
/// of the global mean and combine by pure summation).
fn loss_and_grads(
    model: &ModelInfo,
    params: &[Vec<f32>],
    x: &[i32],
    y: &[i32],
    qs: &QuantRecipe,
    inv_norm: Option<f32>,
) -> BackOut {
    let dm = Dims::of(model);
    let (d, f, m, t, h, hd, v) = (dm.d, dm.f, dm.m, dm.t, dm.h, dm.hd, dm.v);
    let fwd = forward(model, params, x, qs);
    let (per_pos, probs) = nll_rows(&fwd.logits, y, m, v);
    let loss_sum = per_pos.iter().map(|&l| l as f64).sum::<f64>();
    let loss = loss_sum / m as f64;

    let mut grads: Vec<Vec<f32>> = model.params.iter().map(|p| vec![0.0f32; p.elems()]).collect();

    // dlogits = (softmax - onehot(y)) * inv_norm (row-parallel)
    let mut dlogits = probs;
    let inv_m = inv_norm.unwrap_or(1.0f32 / m as f32);
    par_chunks_mut(&mut dlogits, v, 2 * v, |rows, dc| {
        for (ri, r) in rows.clone().enumerate() {
            let row = &mut dc[ri * v..(ri + 1) * v];
            row[y[r] as usize] -= 1.0;
            for g in row.iter_mut() {
                *g *= inv_m;
            }
        }
    });

    // tied head: dwte += dlogitsᵀ @ hf ; dhf = dlogits @ wte
    matmul_tn_acc(&mut grads[WTE], &dlogits, &fwd.hf, m, v, d);
    let dhf = matmul(&dlogits, &params[WTE], m, v, d);

    // final LN
    let (lnf_w_grad, lnf_b_grad) = {
        let (gw, gb) = grads.split_at_mut(LNF_B);
        (&mut gw[LNF_W], &mut gb[0])
    };
    let mut dh = layer_norm_bwd(
        &dhf,
        &fwd.xhatf,
        &fwd.rstdf,
        &params[LNF_W],
        m,
        d,
        lnf_w_grad,
        lnf_b_grad,
    );

    let inv_sqrt_hd = 1.0f32 / (hd as f32).sqrt();
    let mut d_ctx0 = Vec::new();

    for l in (0..dm.l).rev() {
        let c = &fwd.caches[l];
        let qkv_w = layer_slice(&params[QKV_W], l, d * 3 * d);
        let proj_w = layer_slice(&params[PROJ_W], l, d * d);
        let fc1_w = layer_slice(&params[FC1_W], l, d * f);
        let fc2_w = layer_slice(&params[FC2_W], l, f * d);

        // ---- MLP: h_out = h2 + (qdq(g) @ qdq(fc2_w) + fc2_b) ----
        let dz = &dh;
        let x2 = match &c.gq {
            Some(cc) => XOperand::Cache(cc),
            None => XOperand::Raw(&c.g),
        };
        // dG = gy2 @ W2ᵀ with W2 (f x d): transpose-B kernel
        let dg = quant_linear_bwd(
            dz,
            x2,
            c.fc2_wp.as_ref(),
            fc2_w,
            m,
            f,
            d,
            qs,
            &mut grads[FC2_W][l * f * d..(l + 1) * f * d],
        );
        col_sum_acc(&mut grads[FC2_B][l * d..(l + 1) * d], dz, m, d);
        let du = gelu_bwd(&c.u, &dg);
        // dM = gy1 @ W1ᵀ with W1 (d x f)
        let dmm = quant_linear_bwd(
            &du,
            XOperand::Cache(&c.mq),
            c.fc1_wp.as_ref(),
            fc1_w,
            m,
            d,
            f,
            qs,
            &mut grads[FC1_W][l * d * f..(l + 1) * d * f],
        );
        col_sum_acc(&mut grads[FC1_B][l * f..(l + 1) * f], &du, m, f);
        let ln2_w = layer_slice(&params[LN2_W], l, d);
        let dx2 = {
            let (gw_all, gb_all) = grads.split_at_mut(LN2_B);
            layer_norm_bwd(
                &dmm,
                &c.xhat2,
                &c.rstd2,
                ln2_w,
                m,
                d,
                &mut gw_all[LN2_W][l * d..(l + 1) * d],
                &mut gb_all[0][l * d..(l + 1) * d],
            )
        };
        let mut dh2 = dh.clone();
        add_assign(&mut dh2, &dx2);

        // ---- attention: h2 = h_in + (qdq(ctx) @ qdq(proj_w) + proj_b) ----
        let do_ = &dh2;
        let xp = match &c.cq {
            Some(cc) => XOperand::Cache(cc),
            None => XOperand::Raw(&c.ctx),
        };
        // dCtx = gyp @ Wpᵀ with Wp (d x d)
        let dctx = quant_linear_bwd(
            do_,
            xp,
            c.proj_wp.as_ref(),
            proj_w,
            m,
            d,
            d,
            qs,
            &mut grads[PROJ_W][l * d * d..(l + 1) * d * d],
        );
        col_sum_acc(&mut grads[PROJ_B][l * d..(l + 1) * d], do_, m, d);
        if l == 0 {
            d_ctx0 = dctx.clone();
        }

        // attention core backward, parallel over (batch, head) tiles: each
        // tile writes its own (T, hd) dq/dk/dv head buffers (tile-local
        // math via the serial reference kernels), then the interleaved
        // dqkv rows are regathered row-parallel
        let th = t * hd;
        let mut dq_h = vec![0.0f32; m * d];
        let mut dk_h = vec![0.0f32; m * d];
        let mut dv_h = vec![0.0f32; m * d];
        par_chunks3_mut(
            &mut dq_h,
            th,
            &mut dk_h,
            th,
            &mut dv_h,
            th,
            8 * t * t * hd,
            |bhr, dqc, dkc, dvc| {
                for (i, bh) in bhr.clone().enumerate() {
                    let b = bh / h;
                    let hh = bh % h;
                    // gather dctx head tile (T, hd)
                    let mut dctx_tile = vec![0.0f32; th];
                    for s in 0..t {
                        let src =
                            &dctx[(b * t + s) * d + hh * hd..(b * t + s) * d + (hh + 1) * hd];
                        dctx_tile[s * hd..(s + 1) * hd].copy_from_slice(src);
                    }
                    let qt = &c.q[bh * th..(bh + 1) * th];
                    let kt = &c.k[bh * th..(bh + 1) * th];
                    let vt = &c.v[bh * th..(bh + 1) * th];
                    let ptile = &c.p[bh * t * t..(bh + 1) * t * t];

                    // dP = dctx @ vᵀ ; dv = Pᵀ @ dctx
                    let dp = math::matmul_nt(&dctx_tile, vt, t, hd, t);
                    let dv = math::matmul_tn(ptile, &dctx_tile, t, t, hd);
                    // softmax backward: dS = P ⊙ (dP - rowsum(dP ⊙ P))
                    let mut ds = vec![0.0f32; t * t];
                    for r in 0..t {
                        let prow = &ptile[r * t..(r + 1) * t];
                        let dprow = &dp[r * t..(r + 1) * t];
                        let mut dot = 0.0f32;
                        for j in 0..=r {
                            dot += dprow[j] * prow[j];
                        }
                        let dsrow = &mut ds[r * t..(r + 1) * t];
                        for j in 0..=r {
                            dsrow[j] = prow[j] * (dprow[j] - dot);
                        }
                    }
                    // dq = dS @ k * inv ; dk = dSᵀ @ q * inv
                    let mut dq = math::matmul(&ds, kt, t, t, hd);
                    let mut dk = math::matmul_tn(&ds, qt, t, t, hd);
                    for x_ in dq.iter_mut() {
                        *x_ *= inv_sqrt_hd;
                    }
                    for x_ in dk.iter_mut() {
                        *x_ *= inv_sqrt_hd;
                    }
                    dqc[i * th..(i + 1) * th].copy_from_slice(&dq);
                    dkc[i * th..(i + 1) * th].copy_from_slice(&dk);
                    dvc[i * th..(i + 1) * th].copy_from_slice(&dv);
                }
            },
        );
        // regather head tiles into dqkv rows [dq | dk | dv]
        let mut dqkv = vec![0.0f32; m * 3 * d];
        par_chunks_mut(&mut dqkv, 3 * d, 3 * d, |rows, out| {
            for (ri, r) in rows.clone().enumerate() {
                let b = r / t;
                let s = r % t;
                let row = &mut out[ri * 3 * d..(ri + 1) * 3 * d];
                for hh in 0..h {
                    let o = ((b * h + hh) * t + s) * hd;
                    row[hh * hd..(hh + 1) * hd].copy_from_slice(&dq_h[o..o + hd]);
                    row[d + hh * hd..d + (hh + 1) * hd].copy_from_slice(&dk_h[o..o + hd]);
                    row[2 * d + hh * hd..2 * d + (hh + 1) * hd]
                        .copy_from_slice(&dv_h[o..o + hd]);
                }
            }
        });

        // dA = gyq @ Wqᵀ with Wq (d x 3d)
        let da = quant_linear_bwd(
            &dqkv,
            XOperand::Cache(&c.xq),
            c.qkv_wp.as_ref(),
            qkv_w,
            m,
            d,
            3 * d,
            qs,
            &mut grads[QKV_W][l * d * 3 * d..(l + 1) * d * 3 * d],
        );
        col_sum_acc(&mut grads[QKV_B][l * 3 * d..(l + 1) * 3 * d], &dqkv, m, 3 * d);
        let ln1_w = layer_slice(&params[LN1_W], l, d);
        let dx1 = {
            let (gw_all, gb_all) = grads.split_at_mut(LN1_B);
            layer_norm_bwd(
                &da,
                &c.xhat1,
                &c.rstd1,
                ln1_w,
                m,
                d,
                &mut gw_all[LN1_W][l * d..(l + 1) * d],
                &mut gb_all[0][l * d..(l + 1) * d],
            )
        };
        add_assign(&mut dh2, &dx1);
        dh = dh2;
    }

    // embeddings: scatter into wte, reduce over batch into wpe —
    // owner-computes parallel (each worker owns destination rows and walks
    // the batch ascending), bit-identical to the serial scatter
    {
        let (gw, gp) = grads.split_at_mut(WPE);
        embed_scatter(&mut gw[WTE], &mut gp[0], &dh, x, m, t, d);
    }

    BackOut {
        loss,
        loss_sum,
        grads,
        d_ctx0,
    }
}

// ---------------------------------------------------------------------------
// AdamW with quantized moments (python/compile/adam.py)
// ---------------------------------------------------------------------------

/// Fake-quantize an optimizer moment for storage: only >=2D base tensors
/// (linear weights + embeddings); stacked per-layer tensors are quantized
/// layer by layer so "per_tensor" means per layer-tensor.
fn moment_qdq(info: &ParamInfo, data: &mut [f32], policy: Option<TensorPolicy>) {
    let Some(p) = policy else { return };
    let base_ndim = info.shape.len() - usize::from(info.stacked);
    if base_ndim < 2 {
        return;
    }
    if info.stacked {
        let (rows, cols) = (info.shape[1], info.shape[2]);
        for l in 0..info.shape[0] {
            let slice = &mut data[l * rows * cols..(l + 1) * rows * cols];
            quant::qdq(slice, rows, cols, p);
        }
    } else {
        let (rows, cols) = (info.shape[0], info.shape[1]);
        quant::qdq(data, rows, cols, p);
    }
}

/// One AdamW step in place. Returns the pre-clip global gradient norm.
/// The elementwise moment/param updates are chunk-parallel (each element
/// is independent); the global grad norm runs on the fixed `NORM_BLOCK`
/// reduction tree (`kernels::sq_norm`), so it parallelizes while staying
/// bit-identical at every thread count.
fn adamw_update(
    model: &ModelInfo,
    state: &mut HostState,
    grads: &[Vec<f32>],
    lr: f32,
    t: f32,
    qs: &QuantRecipe,
) -> f64 {
    let gnorm: f64 = sq_norm(grads).sqrt();
    let clip = (GRAD_CLIP as f64 / (gnorm + 1e-12)).min(1.0) as f32;
    let bc1 = 1.0 - BETA1.powf(t);
    let bc2 = 1.0 - BETA2.powf(t);

    for (i, info) in model.params.iter().enumerate() {
        let p = &mut state.params[i];
        let m = &mut state.m[i];
        let v = &mut state.v[i];
        let g: &[f32] = &grads[i];
        par_chunks2_mut(&mut m[..], 1, &mut v[..], 1, 8, |jr, mc, vc| {
            for (ji, j) in jr.clone().enumerate() {
                let gc = g[j] * clip;
                mc[ji] = BETA1 * mc[ji] + (1.0 - BETA1) * gc;
                vc[ji] = BETA2 * vc[ji] + (1.0 - BETA2) * gc * gc;
            }
        });
        // store fake-quantized; the update below reads the stored form
        moment_qdq(info, m, qs.m1);
        moment_qdq(info, v, qs.m2);
        let mr: &[f32] = m;
        let vr: &[f32] = v;
        let decay = info.decay;
        par_chunks_mut(&mut p[..], 1, 10, |jr, pc| {
            for (ji, j) in jr.clone().enumerate() {
                let m_hat = mr[j] / bc1;
                let v_hat = vr[j] / bc2;
                let mut step = m_hat / (v_hat.sqrt() + ADAM_EPS);
                if decay {
                    step += WEIGHT_DECAY * pc[ji];
                }
                pc[ji] -= lr * step;
            }
        });
    }
    gnorm
}

// ---------------------------------------------------------------------------
// Backend impl
// ---------------------------------------------------------------------------

/// The pure-rust executor. Stateless: every call is a function of its
/// arguments, which keeps the trait object trivially shareable.
#[derive(Debug, Default, Clone, Copy)]
pub struct NativeBackend;

impl Backend for NativeBackend {
    fn name(&self) -> &'static str {
        "native"
    }

    fn train_step(
        &self,
        model: &ModelInfo,
        recipe: &QuantRecipe,
        state: &mut HostState,
        x: &[i32],
        y: &[i32],
        lr: f32,
        t: f32,
    ) -> Result<StepOut> {
        check_inputs(model, &state.params, x)?;
        check_tokens(model, y)?;
        let out = loss_and_grads(model, &state.params, x, y, recipe, None);
        let gnorm = adamw_update(model, state, &out.grads, lr, t, recipe);
        Ok(StepOut {
            loss: out.loss,
            gnorm,
        })
    }

    fn grad_step(
        &self,
        model: &ModelInfo,
        recipe: &QuantRecipe,
        params: &[Vec<f32>],
        x: &[i32],
        y: &[i32],
        inv_norm: f32,
    ) -> Result<(f64, Vec<Vec<f32>>)> {
        check_inputs(model, params, x)?;
        check_tokens(model, y)?;
        let out = loss_and_grads(model, params, x, y, recipe, Some(inv_norm));
        Ok((out.loss_sum, out.grads))
    }

    fn apply_grads(
        &self,
        model: &ModelInfo,
        recipe: &QuantRecipe,
        state: &mut HostState,
        grads: &[Vec<f32>],
        lr: f32,
        t: f32,
    ) -> Result<f64> {
        for (info, g) in model.params.iter().zip(grads) {
            anyhow::ensure!(
                g.len() == info.elems(),
                "gradient for {} has {} elements, expected {}",
                info.name,
                g.len(),
                info.elems()
            );
        }
        Ok(adamw_update(model, state, grads, lr, t, recipe))
    }

    fn eval_step(
        &self,
        model: &ModelInfo,
        recipe: &QuantRecipe,
        params: &[Vec<f32>],
        x: &[i32],
        y: &[i32],
        mask: &[f32],
    ) -> Result<EvalOut> {
        let qs = recipe.forward_only();
        check_inputs(model, params, x)?;
        check_tokens(model, y)?;
        let dm = Dims::of(model);
        let fwd = forward(model, params, x, &qs);
        let per_pos = nll_only(&fwd.logits, y, dm.m, dm.v);
        let mut num = 0.0f64;
        let mut den = 0.0f64;
        for (l, w) in per_pos.iter().zip(mask.iter()) {
            num += (*l as f64) * (*w as f64);
            den += *w as f64;
        }
        Ok(EvalOut {
            mean_nll: num / den.max(1.0),
            per_pos,
        })
    }

    fn act_probe(&self, model: &ModelInfo, params: &[Vec<f32>], x: &[i32]) -> Result<ActProbe> {
        check_inputs(model, params, x)?;
        let fwd = forward(model, params, x, &QuantRecipe::none());
        let probe = fwd
            .caches
            .last()
            .expect("model has at least one layer");
        Ok(ActProbe {
            proj_in: probe.ctx.clone(),
            fc2_in: probe.g.clone(),
        })
    }

    fn grad_probe(
        &self,
        model: &ModelInfo,
        params: &[Vec<f32>],
        x: &[i32],
        y: &[i32],
    ) -> Result<GradProbe> {
        check_inputs(model, params, x)?;
        check_tokens(model, y)?;
        let dm = Dims::of(model);
        let out = loss_and_grads(model, params, x, y, &QuantRecipe::none(), None);
        let per_layer = dm.d * 3 * dm.d;
        Ok(GradProbe {
            d_qkv_w0: out.grads[QKV_W][..per_layer].to_vec(),
            d_ctx0: out.d_ctx0,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::init_state;

    fn tiny() -> ModelInfo {
        model_info("tt", 2, 16, 2, 32, 8, 2)
    }

    fn batch(model: &ModelInfo, seed: u64) -> (Vec<i32>, Vec<i32>) {
        let mut rng = crate::util::rng::Rng::new(seed);
        let m = model.batch * model.seq;
        let x: Vec<i32> = (0..m).map(|_| rng.below(model.vocab) as i32).collect();
        let y: Vec<i32> = (0..m).map(|_| rng.below(model.vocab) as i32).collect();
        (x, y)
    }

    #[test]
    fn model_info_matches_manifest_layout() {
        let m = tiny();
        assert_eq!(m.params.len(), N_PARAM_TENSORS);
        assert_eq!(m.params[WTE].name, "wte");
        assert_eq!(m.params[QKV_W].shape, vec![2, 16, 48]);
        assert_eq!(m.params[FC2_W].shape, vec![2, 64, 16]);
        assert_eq!(m.params[LNF_B].name, "lnf_b");
        // n_params formula must match configs.py
        let t4 = model_info("t4", 4, 128, 4, 512, 128, 16);
        let per_layer = 2 * 128 + 128 * 384 + 384 + 128 * 128 + 128 + 2 * 128
            + 128 * 512 + 512 + 512 * 128 + 128;
        assert_eq!(t4.n_params, 512 * 128 + 128 * 128 + 4 * per_layer + 2 * 128);
    }

    #[test]
    fn native_registry_has_study_models() {
        let models = native_models();
        for name in ["t4", "gpt2s", "micro"] {
            assert!(models.contains_key(name), "missing {name}");
        }
        assert_eq!(models["t4"].vocab, 512);
        assert_eq!(models["micro"].seq, 128); // fits 5-shot GLUE episodes
    }

    #[test]
    fn init_loss_is_near_uniform() {
        let model = tiny();
        let state = init_state(&model, 3);
        let (x, y) = batch(&model, 1);
        let be = NativeBackend;
        let mask = vec![1.0f32; x.len()];
        let out = be
            .eval_step(&model, &QuantRecipe::none(), &state.params, &x, &y, &mask)
            .unwrap();
        let uniform = (model.vocab as f64).ln();
        assert!(
            (out.mean_nll - uniform).abs() < 0.3,
            "init NLL {} vs ln(V) {}",
            out.mean_nll,
            uniform
        );
    }

    #[test]
    fn zero_lr_step_preserves_params() {
        let model = tiny();
        let mut state = init_state(&model, 5);
        let before = state.params.clone();
        let (x, y) = batch(&model, 2);
        let be = NativeBackend;
        let out = be
            .train_step(&model, &QuantRecipe::none(), &mut state, &x, &y, 0.0, 1.0)
            .unwrap();
        assert!(out.loss.is_finite() && out.gnorm > 0.0);
        assert_eq!(state.params, before);
        // moments did move
        assert!(state.m.iter().flatten().any(|&v| v != 0.0));
    }

    #[test]
    fn train_step_deterministic() {
        let model = tiny();
        let (x, y) = batch(&model, 7);
        let be = NativeBackend;
        let recipe = QuantRecipe::parse("w8a8").unwrap();
        let mut s1 = init_state(&model, 11);
        let mut s2 = init_state(&model, 11);
        let o1 = be
            .train_step(&model, &recipe, &mut s1, &x, &y, 1e-3, 1.0)
            .unwrap();
        let o2 = be
            .train_step(&model, &recipe, &mut s2, &x, &y, 1e-3, 1.0)
            .unwrap();
        assert_eq!(o1.loss, o2.loss);
        assert_eq!(s1.params, s2.params);
    }

    #[test]
    fn probes_have_expected_shapes() {
        let model = tiny();
        let state = init_state(&model, 9);
        let (x, y) = batch(&model, 3);
        let be = NativeBackend;
        let ap = be.act_probe(&model, &state.params, &x).unwrap();
        assert_eq!(ap.proj_in.len(), model.batch * model.seq * model.d_model);
        assert_eq!(ap.fc2_in.len(), model.batch * model.seq * model.d_ff);
        let gp = be.grad_probe(&model, &state.params, &x, &y).unwrap();
        assert_eq!(gp.d_qkv_w0.len(), model.d_model * 3 * model.d_model);
        assert_eq!(gp.d_ctx0.len(), model.batch * model.seq * model.d_model);
        assert!(gp.d_qkv_w0.iter().any(|&g| g != 0.0));
    }

    #[test]
    fn rejects_bad_inputs() {
        let model = tiny();
        let state = init_state(&model, 1);
        let be = NativeBackend;
        let bad_x = vec![0i32; 3];
        let mask = vec![1.0f32; 3];
        assert!(be
            .eval_step(&model, &QuantRecipe::none(), &state.params, &bad_x, &bad_x, &mask)
            .is_err());
        let (x, y) = batch(&model, 1);
        let mut oot = x.clone();
        oot[0] = model.vocab as i32; // out of range
        let mask = vec![1.0f32; x.len()];
        assert!(be
            .eval_step(&model, &QuantRecipe::none(), &state.params, &oot, &y, &mask)
            .is_err());
    }
}
