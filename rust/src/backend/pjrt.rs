//! PJRT executor (cargo feature `pjrt`): runs the AOT-lowered HLO artifacts
//! (`artifacts/*.hlo.txt` + `manifest.json`, produced by `make artifacts`)
//! on the PJRT CPU client via the `xla` crate.
//!
//! HLO *text* is the interchange format (xla_extension 0.5.1 rejects
//! jax>=0.5 serialized protos with 64-bit instruction ids; the text parser
//! reassigns ids). Lowering uses `return_tuple=True`, so every execution
//! returns one tuple buffer which is decomposed into per-output literals.
//!
//! Relative to the seed's literal-carrying train loop, this backend round
//! trips (params, m, v) through host vectors every step to satisfy the
//! backend-agnostic [`Backend`] contract; the conversion cost is the price
//! of a host-state seam shared with the native executor.

use std::cell::RefCell;
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::rc::Rc;
use std::time::Instant;

use anyhow::{anyhow, bail, Result};

use crate::backend::{ActProbe, Backend, EvalOut, GradProbe, StepOut};
use crate::config::QuantRecipe;
use crate::model::HostState;
use crate::runtime::{ArtifactInfo, Manifest, ModelInfo};

// ---------------------------------------------------------------------------
// literal helpers (HostState <-> xla::Literal conversions live here now)
// ---------------------------------------------------------------------------

pub fn lit_f32(data: &[f32], shape: &[usize]) -> Result<xla::Literal> {
    debug_assert_eq!(data.len(), shape.iter().product::<usize>());
    if shape.is_empty() {
        return Ok(xla::Literal::scalar(data[0]));
    }
    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
    Ok(xla::Literal::vec1(data).reshape(&dims)?)
}

pub fn lit_i32(data: &[i32], shape: &[usize]) -> Result<xla::Literal> {
    debug_assert_eq!(data.len(), shape.iter().product::<usize>());
    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
    Ok(xla::Literal::vec1(data).reshape(&dims)?)
}

pub fn lit_scalar(v: f32) -> xla::Literal {
    xla::Literal::scalar(v)
}

pub fn to_f32(lit: &xla::Literal) -> Result<Vec<f32>> {
    Ok(lit.to_vec::<f32>()?)
}

pub fn scalar_f32(lit: &xla::Literal) -> Result<f32> {
    Ok(lit.to_vec::<f32>()?[0])
}

/// params+m+v as literals in the train-artifact input order.
fn state_literals(model: &ModelInfo, state: &HostState) -> Result<Vec<xla::Literal>> {
    let mut out = Vec::with_capacity(3 * state.params.len());
    for group in [&state.params, &state.m, &state.v] {
        for (p, data) in model.params.iter().zip(group.iter()) {
            out.push(lit_f32(data, &p.shape)?);
        }
    }
    Ok(out)
}

fn param_literals(model: &ModelInfo, params: &[Vec<f32>]) -> Result<Vec<xla::Literal>> {
    model
        .params
        .iter()
        .zip(params.iter())
        .map(|(p, data)| lit_f32(data, &p.shape))
        .collect()
}

// ---------------------------------------------------------------------------
// compiled-artifact cache
// ---------------------------------------------------------------------------

/// A compiled artifact plus its signature.
pub struct Executable {
    pub info: ArtifactInfo,
    exe: xla::PjRtLoadedExecutable,
}

impl Executable {
    /// Execute with literal inputs; returns per-output literals (decomposed
    /// from the single result tuple).
    pub fn run(&self, inputs: &[&xla::Literal]) -> Result<Vec<xla::Literal>> {
        if inputs.len() != self.info.inputs.len() {
            bail!(
                "{}: expected {} inputs, got {}",
                self.info.name,
                self.info.inputs.len(),
                inputs.len()
            );
        }
        let bufs = self.exe.execute::<&xla::Literal>(inputs)?;
        let tuple = bufs[0][0].to_literal_sync()?;
        Ok(tuple.to_tuple()?)
    }
}

/// PJRT-backed [`Backend`]: loads + caches compiled executables over one
/// PJRT CPU client.
pub struct PjrtBackend {
    pub client: xla::PjRtClient,
    pub dir: PathBuf,
    manifest: Manifest,
    cache: RefCell<HashMap<String, Rc<Executable>>>,
}

impl PjrtBackend {
    pub fn new(dir: &Path) -> Result<PjrtBackend> {
        let manifest = Manifest::load(dir)?;
        let client = xla::PjRtClient::cpu()?;
        Ok(PjrtBackend {
            client,
            dir: dir.to_path_buf(),
            manifest,
            cache: RefCell::new(HashMap::new()),
        })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Compile (or fetch from cache) an artifact by manifest name.
    pub fn exec(&self, name: &str) -> Result<Rc<Executable>> {
        if let Some(e) = self.cache.borrow().get(name) {
            return Ok(e.clone());
        }
        let info = self.manifest.artifact(name)?.clone();
        let path = self.dir.join(&info.file);
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
        )?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp)?;
        log::info!("compiled {name} ({:.2}s)", t0.elapsed().as_secs_f64());
        let wrapped = Rc::new(Executable { info, exe });
        self.cache
            .borrow_mut()
            .insert(name.to_string(), wrapped.clone());
        Ok(wrapped)
    }

    fn eval_artifact_name(&self, model: &str, structure: &str) -> String {
        // fall back to the unquantized eval graph when the model ships no
        // matching quantized-forward eval artifact (e.g. gpt2s only lowers
        // base)
        let name = format!("{model}/eval/{structure}");
        if self.manifest.artifacts.contains_key(&name) {
            name
        } else {
            format!("{model}/eval/base")
        }
    }
}

impl Backend for PjrtBackend {
    fn name(&self) -> &'static str {
        "pjrt"
    }

    fn train_step(
        &self,
        model: &ModelInfo,
        recipe: &QuantRecipe,
        state: &mut HostState,
        x: &[i32],
        y: &[i32],
        lr: f32,
        t: f32,
    ) -> Result<StepOut> {
        // artifact convention: the lowered structure encodes the placement,
        // bit-widths travel as runtime qmax scalars
        let structure = recipe.legacy_structure().ok_or_else(|| {
            anyhow!(
                "pjrt backend has no AOT artifact for recipe {recipe}; \
                 the artifact vocabulary covers only the legacy structures"
            )
        })?;
        let qmax = recipe.qmax_scalars();
        let np = model.params.len();
        let exe = self.exec(&format!("{}/train/{}", model.name, structure))?;
        let lits = state_literals(model, state)?;
        let xl = lit_i32(x, &[model.batch, model.seq])?;
        let yl = lit_i32(y, &[model.batch, model.seq])?;
        let lrl = lit_scalar(lr);
        let tl = lit_scalar(t);
        let qlits: Vec<xla::Literal> = qmax.iter().map(|&q| lit_scalar(q)).collect();
        let mut inputs: Vec<&xla::Literal> = lits.iter().collect();
        inputs.extend([&xl, &yl, &lrl, &tl]);
        for q in &qlits {
            inputs.push(q);
        }
        let out = exe.run(&inputs)?;
        if out.len() < 3 * np + 2 {
            bail!(
                "train artifact returned {} outputs, expected {}",
                out.len(),
                3 * np + 2
            );
        }
        let loss = scalar_f32(&out[3 * np])? as f64;
        let gnorm = scalar_f32(&out[3 * np + 1])? as f64;
        for (i, lit) in out[..np].iter().enumerate() {
            state.params[i] = to_f32(lit)?;
        }
        for (i, lit) in out[np..2 * np].iter().enumerate() {
            state.m[i] = to_f32(lit)?;
        }
        for (i, lit) in out[2 * np..3 * np].iter().enumerate() {
            state.v[i] = to_f32(lit)?;
        }
        Ok(StepOut { loss, gnorm })
    }

    fn eval_step(
        &self,
        model: &ModelInfo,
        recipe: &QuantRecipe,
        params: &[Vec<f32>],
        x: &[i32],
        y: &[i32],
        mask: &[f32],
    ) -> Result<EvalOut> {
        let fwd = recipe.forward_only();
        let structure = fwd.legacy_structure().ok_or_else(|| {
            anyhow!(
                "pjrt backend has no AOT eval artifact for recipe {fwd}; \
                 the artifact vocabulary covers only the legacy structures"
            )
        })?;
        let [qmax_w, qmax_a, ..] = fwd.qmax_scalars();
        let exe = self.exec(&self.eval_artifact_name(&model.name, structure))?;
        let lits = param_literals(model, params)?;
        let xl = lit_i32(x, &[model.batch, model.seq])?;
        let yl = lit_i32(y, &[model.batch, model.seq])?;
        let ml = lit_f32(mask, &[model.batch, model.seq])?;
        let qw = lit_scalar(qmax_w);
        let qa = lit_scalar(qmax_a);
        let mut inputs: Vec<&xla::Literal> = lits.iter().collect();
        inputs.extend([&xl, &yl, &ml, &qw, &qa]);
        let out = exe.run(&inputs)?;
        Ok(EvalOut {
            mean_nll: scalar_f32(&out[0])? as f64,
            per_pos: to_f32(&out[1])?,
        })
    }

    fn act_probe(&self, model: &ModelInfo, params: &[Vec<f32>], x: &[i32]) -> Result<ActProbe> {
        let exe = self.exec(&format!("{}/probe/act", model.name))?;
        let lits = param_literals(model, params)?;
        let xl = lit_i32(x, &[model.batch, model.seq])?;
        let one = lit_scalar(1.0);
        let mut inputs: Vec<&xla::Literal> = lits.iter().collect();
        inputs.extend([&xl, &one, &one]);
        let out = exe.run(&inputs)?;
        Ok(ActProbe {
            proj_in: to_f32(&out[0])?,
            fc2_in: to_f32(&out[1])?,
        })
    }

    fn grad_probe(
        &self,
        model: &ModelInfo,
        params: &[Vec<f32>],
        x: &[i32],
        y: &[i32],
    ) -> Result<GradProbe> {
        let exe = self.exec(&format!("{}/probe/grad", model.name))?;
        let lits = param_literals(model, params)?;
        let xl = lit_i32(x, &[model.batch, model.seq])?;
        let yl = lit_i32(y, &[model.batch, model.seq])?;
        let one = lit_scalar(1.0);
        let mut inputs: Vec<&xla::Literal> = lits.iter().collect();
        inputs.extend([&xl, &yl, &one, &one, &one]);
        let out = exe.run(&inputs)?;
        Ok(GradProbe {
            d_qkv_w0: to_f32(&out[0])?,
            d_ctx0: to_f32(&out[1])?,
        })
    }
}
