//! Few-shot downstream task analogs (paper Appendix A.2).
//!
//! Each task generates *episodes*: a 5-shot prompt followed by a query and a
//! set of candidate continuations; the model scores candidates by NLL and
//! picks the argmin, exactly the lm_evaluation_harness protocol the paper
//! follows. Accuracy is averaged over 5 seeds (the paper reports mean ± sd).
//!
//! Task inventory mirrors the paper's columns:
//!   GLUE analogs (6): mnli / mrpc / rte / qnli / sst / wnli — binary
//!     entailment-style tasks over Markov segments with varying length and
//!     noise (harder = shorter signal, more noise), plus a token-statistics
//!     task for sst.
//!   arc_easy / arc_challenge: 4-way continuation choice with far (uniform)
//!     vs near (shifted-chain) distractors.
//!   hellaswag: 4-way longer-continuation choice.
//!   lambada: final-token prediction among 4 candidates.

use crate::util::rng::{Rng, Zipf};

use super::corpus::{special, CorpusCfg, ANS, NO, QUERY, SEP, YES};

/// One scoring unit: tokens = prompt ++ candidate; the candidate region is
/// what gets NLL-scored.
#[derive(Debug, Clone)]
pub struct Episode {
    pub prompt: Vec<i32>,
    pub candidates: Vec<Vec<i32>>,
    pub correct: usize,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Task {
    Mnli,
    Mrpc,
    Rte,
    Qnli,
    Sst,
    Wnli,
    ArcEasy,
    ArcChallenge,
    Hellaswag,
    Lambada,
}

pub const GLUE_TASKS: [Task; 6] = [
    Task::Mnli,
    Task::Mrpc,
    Task::Rte,
    Task::Qnli,
    Task::Sst,
    Task::Wnli,
];

pub const ALL_TASKS: [Task; 10] = [
    Task::Mnli,
    Task::Mrpc,
    Task::Rte,
    Task::Qnli,
    Task::Sst,
    Task::Wnli,
    Task::ArcEasy,
    Task::ArcChallenge,
    Task::Hellaswag,
    Task::Lambada,
];

impl Task {
    pub fn name(&self) -> &'static str {
        match self {
            Task::Mnli => "mnli",
            Task::Mrpc => "mrpc",
            Task::Rte => "rte",
            Task::Qnli => "qnli",
            Task::Sst => "sst",
            Task::Wnli => "wnli",
            Task::ArcEasy => "arc_easy",
            Task::ArcChallenge => "arc_challenge",
            Task::Hellaswag => "hellaswag",
            Task::Lambada => "lambada",
        }
    }

    pub fn is_glue(&self) -> bool {
        GLUE_TASKS.contains(self)
    }
}

pub struct TaskGen {
    cfg: CorpusCfg,
    zipf: Zipf,
}

impl TaskGen {
    pub fn new(cfg: CorpusCfg) -> TaskGen {
        let zipf = Zipf::new(cfg.usable_vocab(), cfg.zipf_alpha);
        TaskGen { cfg, zipf }
    }

    fn chain(&self, rng: &mut Rng, start: i32, n: usize, alpha: f64) -> Vec<i32> {
        let mut out = Vec::with_capacity(n);
        let mut prev = start;
        for _ in 0..n {
            let next = if rng.bool_with(alpha) {
                self.cfg.successor(prev)
            } else {
                self.zipf.sample(rng) as i32
            };
            out.push(next);
            prev = next;
        }
        out
    }

    fn rand_tok(&self, rng: &mut Rng) -> i32 {
        self.zipf.sample(rng) as i32
    }

    /// Entailment-style GLUE analog: does segment B continue segment A?
    fn entailment_pair(
        &self,
        rng: &mut Rng,
        seg_len: usize,
        alpha: f64,
    ) -> (Vec<i32>, Vec<i32>, bool) {
        let a0 = self.rand_tok(rng);
        let a = self.chain(rng, a0, seg_len, alpha);
        let entailed = rng.bool_with(0.5);
        let b = if entailed {
            self.chain(rng, *a.last().unwrap(), seg_len, alpha)
        } else {
            let b0 = self.rand_tok(rng);
            (0..seg_len).map(|_| self.rand_tok(rng)).chain([b0]).take(seg_len).collect()
        };
        (a, b, entailed)
    }

    /// SST analog: "sentiment" = do most tokens come from the low half of
    /// the vocabulary (frequent words) or the long tail?
    fn sst_example(&self, rng: &mut Rng, seg_len: usize) -> (Vec<i32>, bool) {
        let positive = rng.bool_with(0.5);
        let u = self.cfg.usable_vocab();
        let seg: Vec<i32> = (0..seg_len)
            .map(|_| {
                if positive {
                    rng.below(u / 8) as i32 // head of the distribution
                } else {
                    (u / 2 + rng.below(u / 2)) as i32 // tail
                }
            })
            .collect();
        (seg, positive)
    }

    fn glue_episode(&self, rng: &mut Rng, task: Task, shots: usize) -> Episode {
        let v = self.cfg.vocab;
        let (seg_len, alpha) = match task {
            Task::Mnli => (8, 0.95),
            Task::Mrpc => (6, 0.9),
            // 5-shot prompt length is 12*seg_len + 23 tokens; seg_len <= 8
            // keeps every episode within the t4 context of 128.
            Task::Rte => (8, 0.85),
            Task::Qnli => (8, 0.8),
            Task::Wnli => (5, 0.7),
            Task::Sst => (8, 0.0),
            _ => unreachable!(),
        };
        let yes = special(v, YES);
        let no = special(v, NO);
        let sep = special(v, SEP);
        let q = special(v, QUERY);
        let ans = special(v, ANS);

        let mut prompt = Vec::new();
        let mut push_example = |prompt: &mut Vec<i32>, rng: &mut Rng, with_label: bool| -> bool {
            let (mut body, label) = if task == Task::Sst {
                let (seg, pos) = self.sst_example(rng, seg_len);
                (seg, pos)
            } else {
                let (a, b, ent) = self.entailment_pair(rng, seg_len, alpha);
                let mut t = a;
                t.push(sep);
                t.extend(b);
                (t, ent)
            };
            prompt.push(q);
            prompt.append(&mut body);
            prompt.push(ans);
            if with_label {
                prompt.push(if label { yes } else { no });
            }
            label
        };

        for _ in 0..shots {
            push_example(&mut prompt, rng, true);
        }
        let label = push_example(&mut prompt, rng, false);
        Episode {
            prompt,
            candidates: vec![vec![yes], vec![no]],
            correct: if label { 0 } else { 1 },
        }
    }

    fn choice_episode(&self, rng: &mut Rng, task: Task) -> Episode {
        let ctx_len = 24;
        let cont_len = match task {
            Task::Lambada => 1,
            Task::Hellaswag => 8,
            _ => 4,
        };
        let alpha = 0.98; // near-deterministic chain: the true continuation
        let start = self.rand_tok(rng);
        let mut full = self.chain(rng, start, ctx_len + cont_len, alpha);
        let cont = full.split_off(ctx_len);
        let prompt = full;

        // distractors
        let mut candidates = Vec::with_capacity(4);
        let correct = rng.below(4);
        // a shifted chain config for near-distribution distractors
        let shifted = CorpusCfg {
            mult: self.cfg.mult.wrapping_mul(7).wrapping_add(3),
            add: self.cfg.add.wrapping_add(5),
            ..self.cfg.clone()
        };
        for i in 0..4 {
            if i == correct {
                candidates.push(cont.clone());
                continue;
            }
            let d = match task {
                Task::ArcChallenge => {
                    // near-distribution: a *different* deterministic chain
                    // continuing from the same context
                    let gen = TaskGen::new(shifted.clone());
                    gen.chain(rng, *prompt.last().unwrap(), cont_len, alpha)
                }
                _ => (0..cont_len).map(|_| self.rand_tok(rng)).collect(),
            };
            candidates.push(d);
        }
        // ensure distractors differ from the truth
        for i in 0..4 {
            if i != correct && candidates[i] == cont {
                let last = candidates[i].len() - 1;
                candidates[i][last] =
                    (candidates[i][last] + 1) % self.cfg.usable_vocab() as i32;
            }
        }
        Episode {
            prompt,
            candidates,
            correct,
        }
    }

    /// Generate `n` episodes of `task` for one evaluation seed.
    pub fn episodes(&self, task: Task, n: usize, seed: u64, shots: usize) -> Vec<Episode> {
        let mut rng = Rng::new(seed ^ 0xFE57_0000 ^ (task as u64) << 32);
        (0..n)
            .map(|_| match task {
                t if t.is_glue() => self.glue_episode(&mut rng, t, shots),
                t => self.choice_episode(&mut rng, t),
            })
            .collect()
    }
}

/// The paper's aggregate: mean GLUE first, then average with the other four.
pub fn paper_average(per_task_acc: &[(Task, f64)]) -> f64 {
    let glue: Vec<f64> = per_task_acc
        .iter()
        .filter(|(t, _)| t.is_glue())
        .map(|(_, a)| *a)
        .collect();
    let glue_mean = glue.iter().sum::<f64>() / glue.len().max(1) as f64;
    let mut vals = vec![glue_mean];
    for (t, a) in per_task_acc {
        if !t.is_glue() {
            vals.push(*a);
        }
    }
    vals.iter().sum::<f64>() / vals.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    fn gen() -> TaskGen {
        TaskGen::new(CorpusCfg::train_default(512))
    }

    #[test]
    fn episodes_deterministic() {
        let g = gen();
        let a = g.episodes(Task::Mnli, 5, 7, 5);
        let b = g.episodes(Task::Mnli, 5, 7, 5);
        assert_eq!(a.len(), 5);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.prompt, y.prompt);
            assert_eq!(x.correct, y.correct);
        }
    }

    #[test]
    fn glue_episode_structure() {
        let g = gen();
        let eps = g.episodes(Task::Rte, 10, 1, 5);
        for e in &eps {
            assert_eq!(e.candidates.len(), 2);
            assert!(e.correct < 2);
            // prompt contains exactly 5 labelled examples + 1 query
            let q = special(512, QUERY);
            assert_eq!(e.prompt.iter().filter(|&&t| t == q).count(), 6);
        }
    }

    #[test]
    fn choice_episode_structure() {
        let g = gen();
        for task in [Task::ArcEasy, Task::ArcChallenge, Task::Hellaswag, Task::Lambada] {
            let eps = g.episodes(task, 8, 3, 5);
            for e in &eps {
                assert_eq!(e.candidates.len(), 4);
                assert!(e.correct < 4);
                for (i, c) in e.candidates.iter().enumerate() {
                    if i != e.correct {
                        assert_ne!(c, &e.candidates[e.correct]);
                    }
                }
            }
        }
    }

    #[test]
    fn lambada_candidates_are_single_tokens() {
        let g = gen();
        for e in g.episodes(Task::Lambada, 5, 2, 5) {
            assert!(e.candidates.iter().all(|c| c.len() == 1));
        }
    }

    #[test]
    fn correct_is_true_continuation() {
        // with alpha≈1 the true continuation follows the successor map
        let g = gen();
        let cfg = CorpusCfg::train_default(512);
        let mut hits = 0;
        let eps = g.episodes(Task::Lambada, 50, 11, 5);
        for e in &eps {
            let want = cfg.successor(*e.prompt.last().unwrap());
            if e.candidates[e.correct][0] == want {
                hits += 1;
            }
        }
        assert!(hits > 40, "only {hits}/50 follow the chain");
    }

    #[test]
    fn paper_average_formula() {
        let accs = vec![
            (Task::Mnli, 0.6),
            (Task::Mrpc, 0.4),
            (Task::ArcEasy, 0.8),
            (Task::Lambada, 0.2),
        ];
        // glue mean = 0.5; average(0.5, 0.8, 0.2) = 0.5
        assert!((paper_average(&accs) - 0.5).abs() < 1e-9);
    }

    #[test]
    fn all_episodes_fit_t4_context() {
        // eval packs prompt ++ candidate into seq+1 = 129 tokens
        let g = gen();
        for task in ALL_TASKS {
            for e in g.episodes(task, 20, 5, 5) {
                let max_cand = e.candidates.iter().map(|c| c.len()).max().unwrap();
                assert!(
                    e.prompt.len() + max_cand <= 129,
                    "{}: episode length {}",
                    task.name(),
                    e.prompt.len() + max_cand
                );
            }
        }
    }

    #[test]
    fn seeds_vary_episodes() {
        let g = gen();
        let a = g.episodes(Task::Hellaswag, 3, 1, 5);
        let b = g.episodes(Task::Hellaswag, 3, 2, 5);
        assert_ne!(a[0].prompt, b[0].prompt);
    }
}
