//! Zipf–Markov synthetic corpus generator.
//!
//! Token stream: with probability `markov_alpha` the next token is the
//! deterministic successor `g(prev) = (mult * prev + add) mod usable_vocab`;
//! otherwise it is drawn from a Zipf(`zipf_alpha`) unigram distribution.
//! The mixture gives (a) a learnable order-1 structure whose conditional
//! entropy lower-bounds the achievable loss, and (b) the long-tailed
//! marginal statistics that drive the paper's outlier phenomena.
//!
//! The top `N_SPECIALS` token ids are reserved for the few-shot task
//! vocabulary (separators / labels) and never appear in the stream.

use crate::util::rng::{Rng, Zipf};

pub const N_SPECIALS: usize = 8;

/// Special token ids, counted from the top of the vocabulary.
pub fn special(vocab: usize, k: usize) -> i32 {
    debug_assert!(k < N_SPECIALS);
    (vocab - N_SPECIALS + k) as i32
}

pub const SEP: usize = 0; // segment separator
pub const YES: usize = 1; // entailment label
pub const NO: usize = 2; // non-entailment label
pub const QUERY: usize = 3; // few-shot query marker
pub const ANS: usize = 4; // answer marker

#[derive(Debug, Clone, PartialEq)]
pub struct CorpusCfg {
    pub vocab: usize,
    pub zipf_alpha: f64,
    pub markov_alpha: f64,
    pub mult: u64,
    pub add: u64,
    pub seed: u64,
}

impl CorpusCfg {
    /// Training-distribution defaults (shared by the in-domain eval sets).
    pub fn train_default(vocab: usize) -> CorpusCfg {
        CorpusCfg {
            vocab,
            zipf_alpha: 1.05,
            markov_alpha: 0.85,
            mult: 31,
            add: 17,
            seed: 1,
        }
    }

    pub fn usable_vocab(&self) -> usize {
        self.vocab - N_SPECIALS
    }

    pub fn successor(&self, prev: i32) -> i32 {
        let u = self.usable_vocab() as u64;
        // widen through i64 and reduce into [0, u) explicitly: `prev as u64`
        // would sign-extend a negative (corrupt / special) id into a huge
        // value and silently derail the chain. Ids already in range are
        // untouched (`rem_euclid` is the identity there), so streams — and
        // the CI digests — are unchanged for well-formed tokens.
        let p = i64::from(prev).rem_euclid(u as i64) as u64;
        ((self.mult.wrapping_mul(p).wrapping_add(self.add)) % u) as i32
    }
}

/// A (x, y) pair of row-major (batch, seq) next-token training batches.
#[derive(Debug, Clone)]
pub struct Batch {
    pub x: Vec<i32>,
    pub y: Vec<i32>,
    pub batch: usize,
    pub seq: usize,
}

/// Infinite deterministic batch stream.
pub struct BatchIter {
    cfg: CorpusCfg,
    zipf: Zipf,
    rng: Rng,
    pub batch: usize,
    pub seq: usize,
    produced: u64,
}

impl BatchIter {
    pub fn new(cfg: CorpusCfg, batch: usize, seq: usize) -> BatchIter {
        let zipf = Zipf::new(cfg.usable_vocab(), cfg.zipf_alpha);
        let rng = Rng::new(cfg.seed ^ 0xDA7A_5EED);
        BatchIter {
            cfg,
            zipf,
            rng,
            batch,
            seq,
            produced: 0,
        }
    }

    /// Generate `n` tokens continuing from `prev`.
    fn fill_row(&mut self, out: &mut Vec<i32>, n: usize) {
        let mut prev = self.zipf.sample(&mut self.rng) as i32;
        for _ in 0..n {
            let next = if self.rng.bool_with(self.cfg.markov_alpha) {
                self.cfg.successor(prev)
            } else {
                self.zipf.sample(&mut self.rng) as i32
            };
            out.push(next);
            prev = next;
        }
    }

    pub fn next_batch(&mut self) -> Batch {
        let (b, t) = (self.batch, self.seq);
        let mut x = Vec::with_capacity(b * t);
        let mut y = Vec::with_capacity(b * t);
        let mut row = Vec::with_capacity(t + 1);
        for _ in 0..b {
            row.clear();
            self.fill_row(&mut row, t + 1);
            x.extend_from_slice(&row[..t]);
            y.extend_from_slice(&row[1..]);
        }
        self.produced += 1;
        Batch {
            x,
            y,
            batch: b,
            seq: t,
        }
    }

    /// Raw token stream (used by the few-shot generators and benches).
    pub fn tokens(&mut self, n: usize) -> Vec<i32> {
        let mut out = Vec::with_capacity(n);
        self.fill_row(&mut out, n);
        out
    }
}

/// Theoretical floor on the achievable per-token loss: the conditional
/// entropy of the mixture process (useful as a training sanity bound).
pub fn entropy_floor(cfg: &CorpusCfg) -> f64 {
    // H >= -(alpha * ln(alpha-ish)): a model that knows g(prev) faces a
    // bernoulli(alpha) choice plus the zipf tail. We approximate the zipf
    // branch entropy from the distribution itself.
    let u = cfg.usable_vocab();
    let mut weights: Vec<f64> =
        (0..u).map(|k| 1.0 / ((k + 2) as f64).powf(cfg.zipf_alpha)).collect();
    let total: f64 = weights.iter().sum();
    for w in weights.iter_mut() {
        *w /= total;
    }
    let h_zipf: f64 = -weights.iter().map(|&p| if p > 0.0 { p * p.ln() } else { 0.0 }).sum::<f64>();
    let a = cfg.markov_alpha;
    // successor token also receives its zipf mass; lower bound ignoring that:
    -(a * a.ln() + (1.0 - a) * (1.0 - a).ln()).max(0.0) + (1.0 - a) * h_zipf
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> CorpusCfg {
        CorpusCfg::train_default(512)
    }

    #[test]
    fn deterministic_stream() {
        let a = BatchIter::new(cfg(), 4, 32).next_batch();
        let b = BatchIter::new(cfg(), 4, 32).next_batch();
        assert_eq!(a.x, b.x);
        assert_eq!(a.y, b.y);
    }

    #[test]
    fn y_is_shifted_x() {
        let mut it = BatchIter::new(cfg(), 2, 16);
        let b = it.next_batch();
        for r in 0..2 {
            // y[t] == x[t+1] within each row
            for t in 0..15 {
                assert_eq!(b.y[r * 16 + t], b.x[r * 16 + t + 1]);
            }
        }
    }

    #[test]
    fn tokens_stay_in_usable_range() {
        let mut it = BatchIter::new(cfg(), 4, 64);
        let b = it.next_batch();
        for &t in &b.x {
            assert!((t as usize) < cfg().usable_vocab());
        }
    }

    #[test]
    fn markov_structure_is_learnable() {
        // the deterministic successor must dominate the conditional dist
        let mut it = BatchIter::new(cfg(), 1, 10_000);
        let b = it.next_batch();
        let c = cfg();
        let mut hits = 0;
        for t in 0..b.seq {
            if b.y[t] == c.successor(b.x[t]) {
                hits += 1;
            }
        }
        let frac = hits as f64 / b.seq as f64;
        assert!(frac > 0.8, "successor fraction {frac}");
    }

    #[test]
    fn successor_boundary_ids_stay_in_range() {
        let c = cfg();
        let u = c.usable_vocab() as i32;
        // every id — valid, special, negative, or extreme — must map into
        // the usable range instead of sign-extending through `as u64`
        for prev in [0, 1, u - 1, u, c.vocab as i32 - 1, -1, -u, i32::MIN, i32::MAX] {
            let s = c.successor(prev);
            assert!((0..u).contains(&s), "successor({prev}) = {s} out of range");
        }
        // congruent ids share a successor: the reduction is mod usable_vocab
        assert_eq!(c.successor(-1), c.successor(u - 1));
        assert_eq!(c.successor(0), c.successor(u));
    }

    #[test]
    fn different_seeds_different_streams() {
        let mut c2 = cfg();
        c2.seed = 2;
        let a = BatchIter::new(cfg(), 1, 64).next_batch();
        let b = BatchIter::new(c2, 1, 64).next_batch();
        assert_ne!(a.x, b.x);
    }

    #[test]
    fn entropy_floor_sane() {
        let h = entropy_floor(&cfg());
        assert!(h > 0.1 && h < (512f64).ln(), "{h}");
    }

    #[test]
    fn specials_never_generated() {
        let mut it = BatchIter::new(cfg(), 2, 256);
        let b = it.next_batch();
        let lo = special(512, 0);
        assert!(b.x.iter().all(|&t| t < lo));
    }
}
