//! Synthetic data pipeline replacing OpenWebText + the paper's eval corpora.
//!
//! See DESIGN.md §4 (substitutions): the quantization phenomena under study
//! are properties of training dynamics, not of web text; a seeded
//! Zipf–Markov process provides a learnable, long-tailed token stream with
//! controllable domain shift for the four perplexity eval sets, plus
//! generators for the few-shot downstream task analogs.

pub mod corpus;
pub mod fewshot;

pub use corpus::{Batch, BatchIter, CorpusCfg};

/// The four held-out perplexity sets standing in for WikiText103 / WikiText2
/// / PTB / 1BW (same-domain large, same-domain small, shifted transition
/// structure, higher-entropy).
pub fn eval_sets(vocab: usize) -> Vec<(&'static str, CorpusCfg)> {
    let train = CorpusCfg::train_default(vocab);
    vec![
        (
            "synthwiki103",
            CorpusCfg {
                seed: 90_001,
                ..train
            },
        ),
        (
            "synthwiki2",
            CorpusCfg {
                seed: 90_002,
                ..train
            },
        ),
        (
            "synthptb",
            CorpusCfg {
                seed: 90_003,
                mult: train.mult.wrapping_mul(5).wrapping_add(2),
                add: train.add.wrapping_add(11),
                ..train
            },
        ),
        (
            "synth1bw",
            CorpusCfg {
                seed: 90_004,
                markov_alpha: (train.markov_alpha - 0.15).max(0.0),
                ..train
            },
        ),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn eval_sets_are_distinct_and_deterministic() {
        let sets = eval_sets(512);
        assert_eq!(sets.len(), 4);
        let mut streams = Vec::new();
        for (_, cfg) in &sets {
            let mut it = BatchIter::new(cfg.clone(), 2, 16);
            let b = it.next_batch();
            streams.push(b.x.clone());
            // deterministic: same cfg -> same batch
            let mut it2 = BatchIter::new(cfg.clone(), 2, 16);
            assert_eq!(it2.next_batch().x, b.x);
        }
        assert_ne!(streams[0], streams[2]); // shifted set differs
        assert_ne!(streams[0], streams[3]);
    }
}
