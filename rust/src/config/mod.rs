//! Configuration types shared across the coordinator: the typed, composable
//! quantization recipe ([`QuantRecipe`] / [`TensorPolicy`], with a canonical
//! string codec that still accepts every artifact-era structure name as an
//! alias), training hyperparameters, and a small key=value config-file
//! loader.

use std::fmt;

use anyhow::{anyhow, bail, Result};

/// Quantization granularity, matching the python/manifest naming.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Granularity {
    PerTensor,
    PerToken,
    PerChannel,
}

impl Granularity {
    pub fn as_str(&self) -> &'static str {
        match self {
            Granularity::PerTensor => "per_tensor",
            Granularity::PerToken => "per_token",
            Granularity::PerChannel => "per_channel",
        }
    }

    /// Short token used inside recipe components (`w4_pc`, `a8_ptok`).
    pub fn short(&self) -> &'static str {
        match self {
            Granularity::PerTensor => "pt",
            Granularity::PerToken => "ptok",
            Granularity::PerChannel => "pc",
        }
    }

    pub fn parse(s: &str) -> Result<Granularity> {
        Ok(match s {
            "per_tensor" | "pt" => Granularity::PerTensor,
            "per_token" | "ptok" => Granularity::PerToken,
            "per_channel" | "pc" | "per_column" => Granularity::PerChannel,
            _ => bail!("unknown granularity {s:?}"),
        })
    }
}

// ---------------------------------------------------------------------------
// per-tensor-class policy
// ---------------------------------------------------------------------------

/// How one tensor class is quantized: bit-width, grouping granularity and
/// symmetry. This is the single quantization parameter type — the PTQ
/// harness, the analyses, `quant::qdq` and the recipe all speak it.
///
/// `bits == 0` means "placement only": the component is on the quantization
/// path but its range input is the fed-1.0 convention (`qmax() == 1.0`),
/// mirroring the artifact inputs for components a run does not quantize.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct TensorPolicy {
    pub bits: u32,
    pub granularity: Granularity,
    pub asymmetric: bool,
}

impl TensorPolicy {
    pub fn new(bits: u32, granularity: Granularity) -> TensorPolicy {
        TensorPolicy {
            bits,
            granularity,
            asymmetric: false,
        }
    }

    pub fn asym(bits: u32, granularity: Granularity) -> TensorPolicy {
        TensorPolicy {
            bits,
            granularity,
            asymmetric: true,
        }
    }

    /// The runtime quantization range: `qmax = 2^(b-1) - 1`, or 1.0 for the
    /// fed-1.0 convention when `bits == 0`. This is the one qmax
    /// implementation in the crate.
    pub fn qmax(&self) -> f32 {
        if self.bits == 0 {
            1.0
        } else {
            ((1u64 << (self.bits - 1)) - 1) as f32
        }
    }
}

/// qmax of an optional policy (1.0 for components not on the quant path).
fn opt_qmax(p: Option<TensorPolicy>) -> f32 {
    p.map(|p| p.qmax()).unwrap_or(1.0)
}

// ---------------------------------------------------------------------------
// recipe: the full experiment quantization configuration
// ---------------------------------------------------------------------------

/// A composable quantization recipe: one optional [`TensorPolicy`] per
/// tensor class (weights / activations / gradients / Adam m1 / Adam m2),
/// plus the Fig. 10 flag that extends gradient quantization to the
/// activation-gradient (dx) path.
///
/// The canonical string form joins per-class components with `+`:
///
/// ```text
/// w4_pc+a8_ptok_asym+g8_ptok+m1_8_pt+m2_8_pc
/// ```
///
/// Component grammar: class prefix (`w`/`a`/`g`/`m1`/`m2`), optional
/// bit-width, granularity (`pt`/`ptok`/`pc`), optional `_asym`, and for
/// gradients an optional `_actgrad`. Omitting the bit-width (`w_pc`) keeps
/// `bits == 0` (placement only, fed-1.0 range) — which is exactly how the
/// 17 legacy artifact structure names parse, so every old name remains a
/// valid alias. `parse(display(r)) == r` for any recipe (the act-grad flag
/// is only meaningful with a gradient component present).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct QuantRecipe {
    pub weights: Option<TensorPolicy>,
    pub acts: Option<TensorPolicy>,
    pub grads: Option<TensorPolicy>,
    /// Fig. 10 variant: quantize the activation-gradient (dx) path too.
    pub quantize_act_grads: bool,
    pub m1: Option<TensorPolicy>,
    pub m2: Option<TensorPolicy>,
}

impl QuantRecipe {
    /// The unquantized baseline (every component absent).
    pub fn none() -> QuantRecipe {
        QuantRecipe::default()
    }

    pub fn is_base(&self) -> bool {
        self.weights.is_none()
            && self.acts.is_none()
            && self.grads.is_none()
            && self.m1.is_none()
            && self.m2.is_none()
    }

    /// Every artifact-era structure name, each of which `parse` accepts as
    /// an alias of the equivalent recipe.
    pub const LEGACY_ALIASES: [&'static str; 17] = [
        "base",
        "w_pt",
        "w_pc",
        "w_pc_pallas",
        "a_pt",
        "a_ptok",
        "a_ptok_asym",
        "a_pc",
        "g_pt",
        "g_ptok",
        "g_ptok_actgrad",
        "m1_pt",
        "m1_pc",
        "m2_pt",
        "m2_pc",
        "wa",
        "wag",
    ];

    /// Parse a recipe string: the canonical `+`-joined grammar, a legacy
    /// structure name, or a `w8a8` / `w8a8g8` short label.
    pub fn parse(s: &str) -> Result<QuantRecipe> {
        let s = s.trim();
        if s.is_empty() {
            bail!("empty quantization recipe");
        }
        if s == "base" || s == "baseline" {
            return Ok(QuantRecipe::none());
        }
        let recipe = if let Some(r) = Self::parse_multi_alias(s) {
            r
        } else if let Some(r) = Self::parse_short_label(s) {
            r
        } else {
            let mut out = QuantRecipe::none();
            for comp in s.split('+') {
                Self::parse_component(&mut out, comp.trim(), s)?;
            }
            out
        };
        recipe.validate()?;
        Ok(recipe)
    }

    /// Multi-component / irregular legacy aliases. Single-class legacy names
    /// (`w_pc`, `a_ptok_asym`, `m1_pt`, …) already parse through the
    /// component grammar with `bits == 0`.
    fn parse_multi_alias(s: &str) -> Option<QuantRecipe> {
        use Granularity::*;
        match s {
            // the pallas-lowered artifact computes the same numbers; natively
            // they are one and the same code path
            "w_pc_pallas" => Some(QuantRecipe {
                weights: Some(TensorPolicy::new(0, PerChannel)),
                ..QuantRecipe::none()
            }),
            "wa" => Some(QuantRecipe {
                weights: Some(TensorPolicy::new(0, PerChannel)),
                acts: Some(TensorPolicy::new(0, PerToken)),
                ..QuantRecipe::none()
            }),
            "wag" => Some(QuantRecipe {
                weights: Some(TensorPolicy::new(0, PerChannel)),
                acts: Some(TensorPolicy::new(0, PerToken)),
                grads: Some(TensorPolicy::new(0, PerToken)),
                ..QuantRecipe::none()
            }),
            _ => None,
        }
    }

    /// `w8a8` / `w4a8g8` short labels (the run-dir names of combined runs).
    fn parse_short_label(s: &str) -> Option<QuantRecipe> {
        use Granularity::*;
        fn digits(s: &str) -> Option<(u32, &str)> {
            let end = s
                .find(|c: char| !c.is_ascii_digit())
                .unwrap_or(s.len());
            if end == 0 {
                return None;
            }
            Some((s[..end].parse().ok()?, &s[end..]))
        }
        let r = s.strip_prefix('w')?;
        let (wb, r) = digits(r)?;
        let r = r.strip_prefix('a')?;
        let (ab, r) = digits(r)?;
        let (gb, r) = match r.strip_prefix('g') {
            Some(r2) => {
                let (g, r2) = digits(r2)?;
                (Some(g), r2)
            }
            None => (None, r),
        };
        if !r.is_empty() {
            return None;
        }
        Some(QuantRecipe {
            weights: Some(TensorPolicy::new(wb, PerChannel)),
            acts: Some(TensorPolicy::new(ab, PerToken)),
            grads: gb.map(|b| TensorPolicy::new(b, PerToken)),
            ..QuantRecipe::none()
        })
    }

    fn parse_component(out: &mut QuantRecipe, comp: &str, full: &str) -> Result<()> {
        if comp.is_empty() {
            bail!("empty component in recipe {full:?}");
        }
        // longest class prefix first: m1/m2 before the single letters
        let (class, rest) = if let Some(r) = comp.strip_prefix("m1") {
            ("m1", r)
        } else if let Some(r) = comp.strip_prefix("m2") {
            ("m2", r)
        } else if let Some(r) = comp.strip_prefix('w') {
            ("w", r)
        } else if let Some(r) = comp.strip_prefix('a') {
            ("a", r)
        } else if let Some(r) = comp.strip_prefix('g') {
            ("g", r)
        } else {
            bail!(
                "unknown component {comp:?} in recipe {full:?} \
                 (expected w/a/g/m1/m2 prefix)"
            );
        };

        // optional separator, optional bit-width, then `_<granularity>`
        let mut rest = rest.strip_prefix('_').unwrap_or(rest);
        let digits_end = rest
            .find(|c: char| !c.is_ascii_digit())
            .unwrap_or(rest.len());
        let bits: u32 = if digits_end == 0 {
            0
        } else {
            rest[..digits_end]
                .parse()
                .map_err(|_| anyhow!("bad bit-width in component {comp:?}"))?
        };
        rest = &rest[digits_end..];
        if digits_end > 0 {
            rest = rest.strip_prefix('_').ok_or_else(|| {
                anyhow!("expected `_<granularity>` after bit-width in {comp:?}")
            })?;
        }

        let mut tokens = rest.split('_');
        let gran_tok = tokens.next().unwrap_or("");
        let granularity = Granularity::parse(gran_tok)
            .map_err(|_| anyhow!("unknown granularity {gran_tok:?} in component {comp:?}"))?;
        let mut asymmetric = false;
        let mut actgrad = false;
        for tok in tokens {
            match tok {
                "asym" => asymmetric = true,
                "actgrad" if class == "g" => actgrad = true,
                other => bail!("unknown modifier {other:?} in component {comp:?}"),
            }
        }

        let policy = TensorPolicy {
            bits,
            granularity,
            asymmetric,
        };
        let slot = match class {
            "w" => &mut out.weights,
            "a" => &mut out.acts,
            "g" => &mut out.grads,
            "m1" => &mut out.m1,
            _ => &mut out.m2,
        };
        if slot.is_some() {
            bail!("duplicate {class:?} component in recipe {full:?}");
        }
        *slot = Some(policy);
        if actgrad {
            out.quantize_act_grads = true;
        }
        Ok(())
    }

    /// Sanity limits on every present policy. 1-bit symmetric would give
    /// `qmax == 0` (a divide-by-zero scale), and anything past 24 bits no
    /// longer round-trips exactly through an f32 grid.
    fn validate(&self) -> Result<()> {
        for (class, p) in [
            ("w", self.weights),
            ("a", self.acts),
            ("g", self.grads),
            ("m1", self.m1),
            ("m2", self.m2),
        ] {
            if let Some(p) = p {
                if p.bits == 1 || p.bits > 24 {
                    bail!(
                        "component {class}: bit-width {} unsupported (use 0 or 2..=24)",
                        p.bits
                    );
                }
            }
        }
        if self.quantize_act_grads && self.grads.is_none() {
            bail!("quantize_act_grads requires a gradient component");
        }
        Ok(())
    }

    /// Override bit-widths per class (CLI `--wbits`-style flags); a zero
    /// leaves the component's bits unchanged, absent components ignore
    /// their override (matching the old structure-decides-placement rule).
    pub fn with_bits(mut self, w: u32, a: u32, g: u32, m1: u32, m2: u32) -> Result<QuantRecipe> {
        fn set(slot: &mut Option<TensorPolicy>, bits: u32) {
            if bits > 0 {
                if let Some(p) = slot {
                    p.bits = bits;
                }
            }
        }
        set(&mut self.weights, w);
        set(&mut self.acts, a);
        set(&mut self.grads, g);
        set(&mut self.m1, m1);
        set(&mut self.m2, m2);
        self.validate()?;
        Ok(self)
    }

    /// Forward-pass components only — the recipe an eval/scoring pass uses
    /// (gradient and optimizer-state quantization do not appear in the
    /// forward pass). This derivation replaces the old hardcoded
    /// train-structure -> eval-structure table.
    pub fn forward_only(&self) -> QuantRecipe {
        QuantRecipe {
            weights: self.weights,
            acts: self.acts,
            ..QuantRecipe::none()
        }
    }

    /// The forward recipe a serving engine may run incrementally, or an
    /// error when this recipe is not serve-eligible. KV-cached decode
    /// computes one token row at a time, so every forward statistic must be
    /// row-local: weight quantization is batch-independent (any policy
    /// qualifies), and activation quantization qualifies only when absent
    /// or per-token. Per-tensor / per-channel activation scales are amax
    /// reductions over the whole `(rows x cols)` activation matrix — an
    /// incremental step would see different statistics than the
    /// full-context re-forward and break the bitwise-equality invariant —
    /// so those recipes are rejected up front instead of serving wrong.
    pub fn serve_forward(&self) -> Result<QuantRecipe> {
        let fwd = self.forward_only();
        if let Some(a) = fwd.acts {
            if a.granularity != Granularity::PerToken {
                bail!(
                    "recipe is not serve-eligible: activation scales are {:?}, \
                     which depend on the whole batch; KV-cached decode requires \
                     row-local activation quantization (per-token) or none",
                    a.granularity
                );
            }
        }
        Ok(fwd)
    }

    /// The five runtime quantization ranges in artifact input order
    /// (w, a, g, m1, m2); absent components get the fed-1.0 convention.
    pub fn qmax_scalars(&self) -> [f32; 5] {
        [
            opt_qmax(self.weights),
            opt_qmax(self.acts),
            opt_qmax(self.grads),
            opt_qmax(self.m1),
            opt_qmax(self.m2),
        ]
    }

    /// The recipe with every bit-width zeroed: which components are on the
    /// quantization path and how, independent of bit-width (the artifact
    /// convention: one lowered structure serves every bit-width).
    pub fn placement(&self) -> QuantRecipe {
        fn strip(p: Option<TensorPolicy>) -> Option<TensorPolicy> {
            p.map(|p| TensorPolicy { bits: 0, ..p })
        }
        QuantRecipe {
            weights: strip(self.weights),
            acts: strip(self.acts),
            grads: strip(self.grads),
            quantize_act_grads: self.quantize_act_grads,
            m1: strip(self.m1),
            m2: strip(self.m2),
        }
    }

    /// The legacy artifact structure name whose placement equals this
    /// recipe's, if one exists — the PJRT backend's artifact key. `None`
    /// for combinations the artifact vocabulary could never express.
    pub fn legacy_structure(&self) -> Option<&'static str> {
        let p = self.placement();
        Self::LEGACY_ALIASES
            .iter()
            .copied()
            .find(|name| {
                QuantRecipe::parse(name)
                    .map(|r| r.placement() == p)
                    .unwrap_or(false)
            })
    }

    /// Human-readable run label: `baseline` for the empty recipe, the
    /// legacy `w8a8` / `w8a8g8` short forms for the combined W/A(/G)
    /// placements (so existing run-dir names don't churn), the canonical
    /// `Display` otherwise. Every label parses back via [`Self::parse`].
    pub fn label(&self) -> String {
        if self.is_base() {
            return "baseline".into();
        }
        if let Some(short) = self.short_label() {
            return short;
        }
        self.to_string()
    }

    fn short_label(&self) -> Option<String> {
        use Granularity::*;
        if self.m1.is_some() || self.m2.is_some() || self.quantize_act_grads {
            return None;
        }
        let w = self.weights?;
        let a = self.acts?;
        if w.bits == 0 || a.bits == 0 {
            return None;
        }
        if (w.granularity, w.asymmetric) != (PerChannel, false) {
            return None;
        }
        if (a.granularity, a.asymmetric) != (PerToken, false) {
            return None;
        }
        match self.grads {
            None => Some(format!("w{}a{}", w.bits, a.bits)),
            Some(g) if g.bits > 0 && (g.granularity, g.asymmetric) == (PerToken, false) => {
                Some(format!("w{}a{}g{}", w.bits, a.bits, g.bits))
            }
            Some(_) => None,
        }
    }
}

fn write_component(
    parts: &mut Vec<String>,
    prefix: &str,
    p: TensorPolicy,
    actgrad: bool,
) {
    let mut s = String::from(prefix);
    if p.bits > 0 {
        if prefix.len() > 1 {
            s.push('_'); // m1_8_pt, not the ambiguous m18_pt
        }
        s.push_str(&p.bits.to_string());
    }
    s.push('_');
    s.push_str(p.granularity.short());
    if p.asymmetric {
        s.push_str("_asym");
    }
    if actgrad {
        s.push_str("_actgrad");
    }
    parts.push(s);
}

impl fmt::Display for QuantRecipe {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if self.is_base() {
            return write!(f, "base");
        }
        let mut parts = Vec::new();
        if let Some(p) = self.weights {
            write_component(&mut parts, "w", p, false);
        }
        if let Some(p) = self.acts {
            write_component(&mut parts, "a", p, false);
        }
        if let Some(p) = self.grads {
            write_component(&mut parts, "g", p, self.quantize_act_grads);
        }
        if let Some(p) = self.m1 {
            write_component(&mut parts, "m1", p, false);
        }
        if let Some(p) = self.m2 {
            write_component(&mut parts, "m2", p, false);
        }
        write!(f, "{}", parts.join("+"))
    }
}

/// Gradient-exchange transport for the data-parallel trainer. A
/// wall-clock knob, never a numerics knob: both transports carry the
/// same canonical frames, so results are bit-identical across them
/// (`digest --dp 2 --transport ...` proves it in CI).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DistTransport {
    /// Run-dir frame files (`<out>/dist/step_*_rank_*_part_*.frame`),
    /// atomic tmp+rename publish, polling collect. Ranks are separate
    /// processes; needs an `--out` dir; survives any process topology.
    Filesystem,
    /// Bounded in-process MPSC channels; ranks run as threads of one
    /// process. No disk, no poll loop, no out dir required.
    Channel,
    /// Length-prefixed QDGF frames over TCP: rank 0 listens
    /// (`--listen`), workers dial (`--connect`) after a versioned `QDGH`
    /// handshake. Ranks are separate processes — loopback multi-process
    /// today, multi-host tomorrow. No out dir required.
    Socket,
}

impl DistTransport {
    pub fn parse(s: &str) -> Result<DistTransport> {
        match s {
            "filesystem" | "fs" => Ok(DistTransport::Filesystem),
            "channel" | "chan" => Ok(DistTransport::Channel),
            "socket" | "tcp" => Ok(DistTransport::Socket),
            other => bail!("unknown dist transport {other:?} (filesystem|channel|socket)"),
        }
    }

    pub fn as_str(&self) -> &'static str {
        match self {
            DistTransport::Filesystem => "filesystem",
            DistTransport::Channel => "channel",
            DistTransport::Socket => "socket",
        }
    }
}

/// Training hyperparameters (paper Appendix A, nanoGPT-style).
#[derive(Debug, Clone)]
pub struct TrainHp {
    pub steps: usize,
    pub lr_max: f64,
    pub lr_min: f64,
    pub warmup: usize,
    pub seed: u64,
    pub eval_every: usize,
    pub eval_batches: usize,
    pub probe_every: usize, // 0 = no probes
    pub log_every: usize,
    /// Kernel worker threads, pinned for the duration of the run and then
    /// restored; 0 = inherit the process setting (`--threads`,
    /// `RAYON_NUM_THREADS`, or all cores). Results are bit-identical at
    /// every value — the knob only trades wall-clock (`backend::kernels`).
    pub threads: usize,
    /// Data-parallel worker count for `dist-train` (1 = single process).
    /// Like `threads`, this is a wall-clock knob, not a numerics knob: the
    /// dist trainer combines shard gradients through a reduction tree
    /// shaped by the global batch alone, so results are bit-identical at
    /// every `dp` ([`shard_range`] derives each rank's leaf range).
    pub dp: usize,
    /// How dist ranks exchange gradient frames ([`DistTransport`]).
    /// Wall-clock only — every transport carries the same canonical bytes.
    pub dist_transport: DistTransport,
    /// Overlap shard backward with frame publish: ship each subtree-cover
    /// node the moment its leaf range completes (multi-part steps) instead
    /// of one frame after the full shard backward. The reassembled node
    /// set is byte-identical either way, so this too is wall-clock only.
    pub dist_overlap: bool,
    /// Socket transport only: the `host:port` rank 0 binds (`--listen`).
    /// `None` defaults to `127.0.0.1:0` — loopback, OS-assigned port —
    /// which is what the spawned-worker single-machine path wants.
    pub dist_listen: Option<String>,
    /// Socket transport only: the `host:port` a `dist-worker` dials
    /// (`--connect`). Required for socket workers; unused on rank 0.
    pub dist_connect: Option<String>,
}

impl TrainHp {
    /// The half-open range of global-batch leaves (sequences) rank `rank`
    /// of a `self.dp`-way run owns; see [`shard_range`].
    pub fn shard_of(&self, rank: usize, batch: usize) -> (usize, usize) {
        shard_range(batch, self.dp.max(1), rank)
    }
}

/// Contiguous leaf range `[rank*B/dp, (rank+1)*B/dp)` of a `dp`-way split
/// of `batch` sequences. Ranges tile the batch exactly for every `dp <=
/// batch` (non-divisible batches give the later ranks the larger shards),
/// and the reduction-tree cover of any such range is well-formed — no
/// alignment requirement.
pub fn shard_range(batch: usize, dp: usize, rank: usize) -> (usize, usize) {
    assert!(dp > 0 && rank < dp, "rank {rank} out of range for dp {dp}");
    (rank * batch / dp, (rank + 1) * batch / dp)
}

impl Default for TrainHp {
    fn default() -> Self {
        TrainHp {
            steps: 300,
            lr_max: 3e-3,
            lr_min: 3e-4,
            warmup: 20,
            seed: 1337,
            eval_every: 25,
            eval_batches: 4,
            probe_every: 0,
            log_every: 10,
            threads: 0,
            dp: 1,
            dist_transport: DistTransport::Filesystem,
            dist_overlap: true,
            dist_listen: None,
            dist_connect: None,
        }
    }
}

/// Cosine learning-rate schedule with linear warmup (paper: cosine half
/// cycle, lr 6e-4 -> <1e-6; scaled for the study model).
pub fn cosine_lr(hp: &TrainHp, step: usize) -> f64 {
    let s = step as f64;
    if step < hp.warmup {
        return hp.lr_max * (s + 1.0) / hp.warmup as f64;
    }
    let t = (s - hp.warmup as f64) / (hp.steps.max(hp.warmup + 1) - hp.warmup) as f64;
    let t = t.clamp(0.0, 1.0);
    hp.lr_min + 0.5 * (hp.lr_max - hp.lr_min) * (1.0 + (std::f64::consts::PI * t).cos())
}

/// Parse a simple `key = value` config file (comments with `#`).
pub fn parse_kv(text: &str) -> Result<Vec<(String, String)>> {
    let mut out = Vec::new();
    for (i, line) in text.lines().enumerate() {
        let line = line.split('#').next().unwrap().trim();
        if line.is_empty() {
            continue;
        }
        let (k, v) = line
            .split_once('=')
            .ok_or_else(|| anyhow!("line {}: expected key = value", i + 1))?;
        out.push((k.trim().to_string(), v.trim().to_string()));
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use Granularity::*;

    #[test]
    fn qmax_values() {
        assert_eq!(TensorPolicy::new(8, PerTensor).qmax(), 127.0);
        assert_eq!(TensorPolicy::new(4, PerTensor).qmax(), 7.0);
        assert_eq!(TensorPolicy::new(2, PerTensor).qmax(), 1.0);
        // fed-1.0 convention for placement-only policies
        assert_eq!(TensorPolicy::new(0, PerChannel).qmax(), 1.0);
    }

    #[test]
    fn lr_schedule_bounds() {
        let hp = TrainHp {
            steps: 100,
            lr_max: 1e-3,
            lr_min: 1e-4,
            warmup: 10,
            ..Default::default()
        };
        assert!(cosine_lr(&hp, 0) <= hp.lr_max / 5.0);
        assert!((cosine_lr(&hp, 10) - hp.lr_max).abs() < 1e-9);
        assert!((cosine_lr(&hp, 100) - hp.lr_min).abs() < 1e-6);
        // monotone decreasing after warmup
        let mut prev = cosine_lr(&hp, 10);
        for s in 11..=100 {
            let cur = cosine_lr(&hp, s);
            assert!(cur <= prev + 1e-12);
            prev = cur;
        }
    }

    #[test]
    fn labels() {
        let c = QuantRecipe {
            weights: Some(TensorPolicy::new(4, PerChannel)),
            ..QuantRecipe::none()
        };
        assert_eq!(c.label(), "w4_pc");
        assert_eq!(QuantRecipe::none().label(), "baseline");
        let c = QuantRecipe::parse("w8a8").unwrap();
        assert_eq!(c.label(), "w8a8");
        let c = QuantRecipe::parse("w8a8g8").unwrap();
        assert_eq!(c.label(), "w8a8g8");
        // the old string-surgery bug: m1 labels were mangled to m18_pt
        let c = QuantRecipe {
            m1: Some(TensorPolicy::new(8, PerTensor)),
            ..QuantRecipe::none()
        };
        assert_eq!(c.label(), "m1_8_pt");
        // every label parses back
        for label in ["w4_pc", "w8a8", "w8a8g8", "m1_8_pt", "baseline"] {
            QuantRecipe::parse(label).unwrap();
        }
    }

    #[test]
    fn combined_recipe_roundtrip() {
        let s = "w4_pc+a8_ptok_asym+g8_ptok+m1_8_pt+m2_8_pc";
        let r = QuantRecipe::parse(s).unwrap();
        assert_eq!(r.weights, Some(TensorPolicy::new(4, PerChannel)));
        assert_eq!(r.acts, Some(TensorPolicy::asym(8, PerToken)));
        assert_eq!(r.grads, Some(TensorPolicy::new(8, PerToken)));
        assert_eq!(r.m1, Some(TensorPolicy::new(8, PerTensor)));
        assert_eq!(r.m2, Some(TensorPolicy::new(8, PerChannel)));
        assert!(!r.quantize_act_grads);
        assert_eq!(r.to_string(), s);
        assert_eq!(QuantRecipe::parse(&r.to_string()).unwrap(), r);
        // the old closed vocabulary could never express this
        assert_eq!(r.legacy_structure(), None);
    }

    #[test]
    fn qmax_scalars_order() {
        let r = QuantRecipe::parse("w4_pc+a8_ptok").unwrap();
        assert_eq!(r.qmax_scalars(), [7.0, 127.0, 1.0, 1.0, 1.0]);
        assert_eq!(QuantRecipe::none().qmax_scalars(), [1.0; 5]);
    }

    #[test]
    fn with_bits_overrides_present_components() {
        let r = QuantRecipe::parse("wa").unwrap().with_bits(8, 8, 8, 8, 8).unwrap();
        assert_eq!(r, QuantRecipe::parse("w8a8").unwrap());
        // absent components ignore their override
        assert!(r.grads.is_none() && r.m1.is_none() && r.m2.is_none());
        // bad bit-widths rejected
        assert!(QuantRecipe::parse("wa").unwrap().with_bits(1, 0, 0, 0, 0).is_err());
    }

    #[test]
    fn kv_parse() {
        let kv = parse_kv("a = 1\n# comment\nb = two # inline\n").unwrap();
        assert_eq!(kv, vec![("a".into(), "1".into()), ("b".into(), "two".into())]);
        assert!(parse_kv("oops").is_err());
    }

    #[test]
    fn granularity_roundtrip() {
        for g in [PerTensor, PerToken, PerChannel] {
            assert_eq!(Granularity::parse(g.as_str()).unwrap(), g);
            assert_eq!(Granularity::parse(g.short()).unwrap(), g);
        }
        assert!(Granularity::parse("bogus").is_err());
    }

    #[test]
    fn shard_ranges_tile_the_batch() {
        for batch in 1..=16 {
            for dp in 1..=batch {
                let mut pos = 0;
                for rank in 0..dp {
                    let (lo, hi) = shard_range(batch, dp, rank);
                    assert_eq!(lo, pos, "gap/overlap at rank {rank} (B={batch} dp={dp})");
                    assert!(hi > lo || dp > batch, "empty shard below dp==batch");
                    pos = hi;
                }
                assert_eq!(pos, batch);
            }
        }
        // the micro model's B=4 under dp=3: 1 + 1 + 2 leaves
        assert_eq!(shard_range(4, 3, 0), (0, 1));
        assert_eq!(shard_range(4, 3, 1), (1, 2));
        assert_eq!(shard_range(4, 3, 2), (2, 4));
        // TrainHp carries the dp knob into the same derivation
        let hp = TrainHp {
            dp: 2,
            ..TrainHp::default()
        };
        assert_eq!(hp.shard_of(1, 4), (2, 4));
    }
}
