//! Configuration types shared across the coordinator: quantization scheme
//! naming (mirroring `python/compile/quantizer.py`), training hyperparameters
//! and run configuration, plus a small key=value config-file loader.

use anyhow::{anyhow, bail, Result};

/// Quantization granularity, matching the python/manifest naming.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Granularity {
    PerTensor,
    PerToken,
    PerChannel,
}

impl Granularity {
    pub fn as_str(&self) -> &'static str {
        match self {
            Granularity::PerTensor => "per_tensor",
            Granularity::PerToken => "per_token",
            Granularity::PerChannel => "per_channel",
        }
    }

    pub fn parse(s: &str) -> Result<Granularity> {
        Ok(match s {
            "per_tensor" | "pt" => Granularity::PerTensor,
            "per_token" | "ptok" => Granularity::PerToken,
            "per_channel" | "pc" | "per_column" => Granularity::PerChannel,
            _ => bail!("unknown granularity {s:?}"),
        })
    }
}

/// A quantization scheme for one tensor class.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Scheme {
    pub bits: u32,
    pub granularity: Granularity,
    pub asymmetric: bool,
}

impl Scheme {
    pub fn new(bits: u32, granularity: Granularity) -> Scheme {
        Scheme {
            bits,
            granularity,
            asymmetric: false,
        }
    }

    pub fn asym(bits: u32, granularity: Granularity) -> Scheme {
        Scheme {
            bits,
            granularity,
            asymmetric: true,
        }
    }

    /// qmax = 2^(b-1) - 1, the runtime scalar fed to the artifacts.
    pub fn qmax(&self) -> f32 {
        ((1u64 << (self.bits - 1)) - 1) as f32
    }
}

/// Bits per quantized component for a training run. A bit-width of 0 means
/// "component not quantized" (its qmax input is fed 1.0 and the artifact
/// structure does not quantize it anyway).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct BitWidths {
    pub weights: u32,
    pub acts: u32,
    pub grads: u32,
    pub m1: u32,
    pub m2: u32,
}

impl BitWidths {
    pub fn none() -> BitWidths {
        BitWidths {
            weights: 0,
            acts: 0,
            grads: 0,
            m1: 0,
            m2: 0,
        }
    }

    pub fn qmax(bits: u32) -> f32 {
        if bits == 0 {
            1.0
        } else {
            ((1u64 << (bits - 1)) - 1) as f32
        }
    }

    /// The five qmax scalars in train-artifact input order (w, a, g, m1, m2).
    pub fn qmax_scalars(&self) -> [f32; 5] {
        [
            Self::qmax(self.weights),
            Self::qmax(self.acts),
            Self::qmax(self.grads),
            Self::qmax(self.m1),
            Self::qmax(self.m2),
        ]
    }
}

/// A full experiment configuration: which artifact structure + bit-widths.
/// `structure` is the artifact key, e.g. "w_pc" or "a_ptok_asym"; together
/// with `bits` it identifies a paper configuration such as "4-bit per-channel
/// weight quantization".
#[derive(Debug, Clone, PartialEq)]
pub struct QuantRunCfg {
    pub structure: String,
    pub bits: BitWidths,
}

impl QuantRunCfg {
    pub fn baseline() -> QuantRunCfg {
        QuantRunCfg {
            structure: "base".into(),
            bits: BitWidths::none(),
        }
    }

    /// Human-readable label like "w4_pc" / "baseline".
    pub fn label(&self) -> String {
        if self.structure == "base" {
            return "baseline".into();
        }
        let b = &self.bits;
        let mut s = self.structure.clone();
        for (tag, bits) in [
            ("w_", b.weights),
            ("a_", b.acts),
            ("g_", b.grads),
            ("m1_", b.m1),
            ("m2_", b.m2),
        ] {
            if s.starts_with(tag) && bits > 0 {
                s = format!("{}{}{}", tag.trim_end_matches('_'), bits, &s[tag.len() - 1..]);
                break;
            }
        }
        if self.structure == "wa" {
            s = format!("w{}a{}", b.weights, b.acts);
        } else if self.structure == "wag" {
            s = format!("w{}a{}g{}", b.weights, b.acts, b.grads);
        }
        s
    }
}

/// Training hyperparameters (paper Appendix A, nanoGPT-style).
#[derive(Debug, Clone)]
pub struct TrainHp {
    pub steps: usize,
    pub lr_max: f64,
    pub lr_min: f64,
    pub warmup: usize,
    pub seed: u64,
    pub eval_every: usize,
    pub eval_batches: usize,
    pub probe_every: usize, // 0 = no probes
    pub log_every: usize,
    /// Kernel worker threads, pinned for the duration of the run and then
    /// restored; 0 = inherit the process setting (`--threads`,
    /// `RAYON_NUM_THREADS`, or all cores). Results are bit-identical at
    /// every value — the knob only trades wall-clock (`backend::kernels`).
    pub threads: usize,
}

impl Default for TrainHp {
    fn default() -> Self {
        TrainHp {
            steps: 300,
            lr_max: 3e-3,
            lr_min: 3e-4,
            warmup: 20,
            seed: 1337,
            eval_every: 25,
            eval_batches: 4,
            probe_every: 0,
            log_every: 10,
            threads: 0,
        }
    }
}

/// Cosine learning-rate schedule with linear warmup (paper: cosine half
/// cycle, lr 6e-4 -> <1e-6; scaled for the study model).
pub fn cosine_lr(hp: &TrainHp, step: usize) -> f64 {
    let s = step as f64;
    if step < hp.warmup {
        return hp.lr_max * (s + 1.0) / hp.warmup as f64;
    }
    let t = (s - hp.warmup as f64) / (hp.steps.max(hp.warmup + 1) - hp.warmup) as f64;
    let t = t.clamp(0.0, 1.0);
    hp.lr_min + 0.5 * (hp.lr_max - hp.lr_min) * (1.0 + (std::f64::consts::PI * t).cos())
}

/// Parse a simple `key = value` config file (comments with `#`).
pub fn parse_kv(text: &str) -> Result<Vec<(String, String)>> {
    let mut out = Vec::new();
    for (i, line) in text.lines().enumerate() {
        let line = line.split('#').next().unwrap().trim();
        if line.is_empty() {
            continue;
        }
        let (k, v) = line
            .split_once('=')
            .ok_or_else(|| anyhow!("line {}: expected key = value", i + 1))?;
        out.push((k.trim().to_string(), v.trim().to_string()));
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn qmax_values() {
        assert_eq!(Scheme::new(8, Granularity::PerTensor).qmax(), 127.0);
        assert_eq!(Scheme::new(4, Granularity::PerTensor).qmax(), 7.0);
        assert_eq!(Scheme::new(2, Granularity::PerTensor).qmax(), 1.0);
        assert_eq!(BitWidths::qmax(0), 1.0);
    }

    #[test]
    fn lr_schedule_bounds() {
        let hp = TrainHp {
            steps: 100,
            lr_max: 1e-3,
            lr_min: 1e-4,
            warmup: 10,
            ..Default::default()
        };
        assert!(cosine_lr(&hp, 0) <= hp.lr_max / 5.0);
        assert!((cosine_lr(&hp, 10) - hp.lr_max).abs() < 1e-9);
        assert!((cosine_lr(&hp, 100) - hp.lr_min).abs() < 1e-6);
        // monotone decreasing after warmup
        let mut prev = cosine_lr(&hp, 10);
        for s in 11..=100 {
            let cur = cosine_lr(&hp, s);
            assert!(cur <= prev + 1e-12);
            prev = cur;
        }
    }

    #[test]
    fn labels() {
        let c = QuantRunCfg {
            structure: "w_pc".into(),
            bits: BitWidths {
                weights: 4,
                ..BitWidths::none()
            },
        };
        assert_eq!(c.label(), "w4_pc");
        assert_eq!(QuantRunCfg::baseline().label(), "baseline");
        let c = QuantRunCfg {
            structure: "wa".into(),
            bits: BitWidths {
                weights: 8,
                acts: 8,
                ..BitWidths::none()
            },
        };
        assert_eq!(c.label(), "w8a8");
    }

    #[test]
    fn kv_parse() {
        let kv = parse_kv("a = 1\n# comment\nb = two # inline\n").unwrap();
        assert_eq!(kv, vec![("a".into(), "1".into()), ("b".into(), "two".into())]);
        assert!(parse_kv("oops").is_err());
    }

    #[test]
    fn granularity_roundtrip() {
        for g in [Granularity::PerTensor, Granularity::PerToken, Granularity::PerChannel] {
            assert_eq!(Granularity::parse(g.as_str()).unwrap(), g);
        }
        assert!(Granularity::parse("bogus").is_err());
    }
}
