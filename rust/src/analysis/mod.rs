//! Analyses behind the paper's diagnostic figures: m-sharpness and loss
//! surfaces (Fig. 5), activation-outlier tracking (Figs. 6 & 8), gradient
//! statistics (Fig. 10), and the Adam second-moment zero-bin histogram
//! (Fig. 12).

use anyhow::Result;

use crate::config::{QuantRecipe, TensorPolicy};
use crate::data::corpus::{BatchIter, CorpusCfg};
use crate::model::HostState;
use crate::quant;
use crate::runtime::{ModelInfo, Runtime};
use crate::util::rng::Rng;
use crate::util::stats::{channel_abs_max, kurtosis, sparsity, Histogram};

// ---------------------------------------------------------------------------
// sharpness (Fig. 5 top)
// ---------------------------------------------------------------------------

/// A filter-normalized random direction: per-tensor gaussian noise rescaled
/// so that each tensor's perturbation norm matches its parameter norm
/// (Li et al., 2018). Skips 1-D tensors (LN/bias), like the visualization
/// paper does.
pub fn filter_normalized_direction(
    state: &HostState,
    model: &ModelInfo,
    seed: u64,
) -> Vec<Vec<f32>> {
    let mut rng = Rng::new(seed);
    model
        .params
        .iter()
        .zip(&state.params)
        .map(|(info, w)| {
            if info.shape.len() < 2 {
                return vec![0.0; w.len()];
            }
            let mut d = rng.normal_vec(w.len(), 0.0, 1.0);
            let wn = w.iter().map(|&x| (x as f64).powi(2)).sum::<f64>().sqrt();
            let dn = d.iter().map(|&x| (x as f64).powi(2)).sum::<f64>().sqrt();
            let scale = if dn > 0.0 { (wn / dn) as f32 } else { 0.0 };
            for x in d.iter_mut() {
                *x *= scale;
            }
            d
        })
        .collect()
}

fn perturbed(state: &HostState, dirs: &[(&Vec<Vec<f32>>, f32)]) -> Vec<Vec<f32>> {
    state
        .params
        .iter()
        .enumerate()
        .map(|(i, w)| {
            let mut out = w.clone();
            for (d, a) in dirs {
                for (o, dv) in out.iter_mut().zip(&d[i]) {
                    *o += a * dv;
                }
            }
            out
        })
        .collect()
}

fn loss_of_params(
    rt: &Runtime,
    recipe: &QuantRecipe,
    model: &ModelInfo,
    params_host: &[Vec<f32>],
    n_batches: usize,
) -> Result<f64> {
    crate::eval::corpus_nll(
        rt,
        recipe,
        model,
        params_host,
        &CorpusCfg {
            seed: 77_777,
            ..CorpusCfg::train_default(model.vocab)
        },
        n_batches,
    )
}

/// m-sharpness proxy: for each radius, max over `n_dirs` random
/// filter-normalized directions of `L(w + rho d) - L(w)`, averaged over
/// `n_batches` minibatches. (The paper uses SAM's ascent direction; the
/// random-direction proxy preserves the sharpness *ordering* across models —
/// see DESIGN.md §4.)
pub struct SharpnessCurve {
    pub radii: Vec<f64>,
    pub sharpness: Vec<f64>, // max loss increase at each radius
    pub base_loss: f64,
}

pub fn m_sharpness(
    rt: &Runtime,
    recipe: &QuantRecipe,
    model: &ModelInfo,
    state: &HostState,
    radii: &[f64],
    n_dirs: usize,
    n_batches: usize,
) -> Result<SharpnessCurve> {
    let base = loss_of_params(rt, recipe, model, &state.params, n_batches)?;
    let dirs: Vec<Vec<Vec<f32>>> = (0..n_dirs)
        .map(|i| filter_normalized_direction(state, model, 0xD1B0 + i as u64))
        .collect();
    let mut sharp = Vec::with_capacity(radii.len());
    for &rho in radii {
        let mut worst = f64::NEG_INFINITY;
        for d in &dirs {
            let p = perturbed(state, &[(d, rho as f32)]);
            let l = loss_of_params(rt, recipe, model, &p, n_batches)?;
            worst = worst.max(l - base);
        }
        sharp.push(worst);
    }
    Ok(SharpnessCurve {
        radii: radii.to_vec(),
        sharpness: sharp,
        base_loss: base,
    })
}

// ---------------------------------------------------------------------------
// 2-D loss surface (Fig. 5 bottom)
// ---------------------------------------------------------------------------

pub struct LossSurface {
    pub alphas: Vec<f64>,
    pub betas: Vec<f64>,
    pub loss: Vec<Vec<f64>>, // loss[i][j] at (alphas[i], betas[j])
}

pub fn loss_surface(
    rt: &Runtime,
    recipe: &QuantRecipe,
    model: &ModelInfo,
    state: &HostState,
    extent: f64,
    grid: usize,
    n_batches: usize,
) -> Result<LossSurface> {
    let d1 = filter_normalized_direction(state, model, 0xFACE);
    let d2 = filter_normalized_direction(state, model, 0xBEEF);
    let coords: Vec<f64> = (0..grid)
        .map(|i| -extent + 2.0 * extent * i as f64 / (grid - 1) as f64)
        .collect();
    let mut loss = Vec::with_capacity(grid);
    for &a in &coords {
        let mut row = Vec::with_capacity(grid);
        for &b in &coords {
            let p = perturbed(state, &[(&d1, a as f32), (&d2, b as f32)]);
            row.push(loss_of_params(rt, recipe, model, &p, n_batches)?);
        }
        loss.push(row);
    }
    Ok(LossSurface {
        alphas: coords.clone(),
        betas: coords,
        loss,
    })
}

impl LossSurface {
    pub fn to_csv(&self) -> String {
        let mut out = String::from("alpha\\beta");
        for b in &self.betas {
            out.push_str(&format!(",{b:.4}"));
        }
        out.push('\n');
        for (i, a) in self.alphas.iter().enumerate() {
            out.push_str(&format!("{a:.4}"));
            for v in &self.loss[i] {
                out.push_str(&format!(",{v:.5}"));
            }
            out.push('\n');
        }
        out
    }
}

// ---------------------------------------------------------------------------
// activation outliers (Figs. 6 & 8)
// ---------------------------------------------------------------------------

pub struct ActStats {
    /// abs-max per channel of the attention out-proj input.
    pub proj_in_channel_max: Vec<f32>,
    /// abs-max per channel of the FC2 input (post-GELU).
    pub fc2_in_channel_max: Vec<f32>,
    pub proj_in_kurtosis: f64,
    pub fc2_in_max: f32,
    pub fc2_in_p999: f64,
}

pub fn activation_stats(
    rt: &Runtime,
    model: &ModelInfo,
    params: &[Vec<f32>],
) -> Result<ActStats> {
    let mut it = BatchIter::new(
        CorpusCfg {
            seed: 55_555,
            ..CorpusCfg::train_default(model.vocab)
        },
        model.batch,
        model.seq,
    );
    let b = it.next_batch();
    let probe = rt.act_probe(model, params, &b.x)?;
    let proj_in = probe.proj_in;
    let fc2_in = probe.fc2_in;
    let rows = model.batch * model.seq;
    Ok(ActStats {
        proj_in_channel_max: channel_abs_max(&proj_in, rows, model.d_model),
        fc2_in_channel_max: channel_abs_max(&fc2_in, rows, model.d_ff),
        proj_in_kurtosis: kurtosis(&proj_in),
        fc2_in_max: fc2_in.iter().fold(0.0f32, |a, &v| a.max(v.abs())),
        fc2_in_p999: crate::util::stats::quantile(&fc2_in, 0.999),
    })
}

/// Persistence of outlier channels between two snapshots: Jaccard overlap of
/// the top-k channels by abs-max (the paper's Fig. 6 claim is that the same
/// channels stay hot across training).
pub fn topk_overlap(a: &[f32], b: &[f32], k: usize) -> f64 {
    let topk = |v: &[f32]| -> Vec<usize> {
        let mut idx: Vec<usize> = (0..v.len()).collect();
        idx.sort_by(|&i, &j| v[j].total_cmp(&v[i]));
        idx.truncate(k);
        idx
    };
    let sa = topk(a);
    let sb = topk(b);
    let inter = sa.iter().filter(|i| sb.contains(i)).count();
    inter as f64 / (2 * k - inter) as f64
}

// ---------------------------------------------------------------------------
// gradient statistics (Fig. 10)
// ---------------------------------------------------------------------------

pub struct GradStats {
    /// log10 |g| histogram of the QKV weight gradient (layer 0).
    pub weight_grad_hist: Histogram,
    pub weight_grad_sparsity: f64,
    pub act_grad_sparsity: f64,
    /// L2 error between the gradient and its quantized version, per scheme.
    pub quant_rel_err: Vec<(String, f64)>,
}

pub fn gradient_stats(
    rt: &Runtime,
    model: &ModelInfo,
    params: &[Vec<f32>],
    schemes: &[(String, TensorPolicy)],
) -> Result<GradStats> {
    let mut it = BatchIter::new(
        CorpusCfg {
            seed: 66_666,
            ..CorpusCfg::train_default(model.vocab)
        },
        model.batch,
        model.seq,
    );
    let b = it.next_batch();
    let probe = rt.grad_probe(model, params, &b.x, &b.y)?;
    let dqkv = probe.d_qkv_w0;
    let dctx = probe.d_ctx0;

    let mut hist = Histogram::new(-12.0, 0.0, 48);
    for &g in &dqkv {
        if g != 0.0 {
            hist.add((g.abs() as f64).log10());
        }
    }

    let rows = model.d_model;
    let cols = 3 * model.d_model;
    let mut quant_rel_err = Vec::new();
    for (name, policy) in schemes {
        let q = quant::qdq_copy(&dqkv, rows, cols, *policy);
        let num: f64 = dqkv
            .iter()
            .zip(&q)
            .map(|(&a, &b)| ((a - b) as f64).powi(2))
            .sum();
        let den: f64 = dqkv.iter().map(|&a| (a as f64).powi(2)).sum();
        quant_rel_err.push((name.clone(), (num / den.max(1e-30)).sqrt()));
    }

    Ok(GradStats {
        weight_grad_hist: hist,
        weight_grad_sparsity: sparsity(&dqkv, 1e-3),
        act_grad_sparsity: sparsity(&dctx, 1e-3),
        quant_rel_err,
    })
}

// ---------------------------------------------------------------------------
// Adam second-moment zero bin (Fig. 12)
// ---------------------------------------------------------------------------

pub struct ZeroBinReport {
    /// per linear-weight tensor: (name, fraction of v flushed to zero at 8b).
    pub per_tensor: Vec<(String, f64)>,
    /// log10(v) histogram before quantization.
    pub v_hist: Histogram,
}

pub fn m2_zero_bin(state: &HostState, model: &ModelInfo, policy: TensorPolicy) -> ZeroBinReport {
    let mut per_tensor = Vec::new();
    let mut v_hist = Histogram::new(-16.0, 0.0, 64);
    for (info, v) in model.params.iter().zip(&state.v) {
        if !crate::ptq::LINEAR_WEIGHTS.contains(&info.name.as_str()) {
            continue;
        }
        let (l, rows, cols) = (info.shape[0], info.shape[1], info.shape[2]);
        let mut flushed = 0.0;
        for layer in 0..l {
            let slice = &v[layer * rows * cols..(layer + 1) * rows * cols];
            flushed += quant::zero_bin_fraction(slice, rows, cols, policy);
            for &x in slice {
                if x > 0.0 {
                    v_hist.add((x as f64).log10());
                }
            }
        }
        per_tensor.push((info.name.clone(), flushed / l as f64));
    }
    ZeroBinReport { per_tensor, v_hist }
}

/// Loss-gap signature: scalar summary of how much sharper `quantized` is
/// than `baseline` at matched radius (used by the fig5 report).
pub fn sharpness_gap(baseline: &SharpnessCurve, quantized: &SharpnessCurve) -> f64 {
    baseline
        .sharpness
        .iter()
        .zip(&quantized.sharpness)
        .map(|(b, q)| q - b)
        .sum::<f64>()
        / baseline.sharpness.len().max(1) as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn topk_overlap_bounds() {
        let a = vec![9.0, 1.0, 8.0, 0.5, 7.0];
        assert!((topk_overlap(&a, &a, 3) - 1.0).abs() < 1e-9);
        let b = vec![0.1, 9.0, 0.2, 8.0, 0.3];
        let o = topk_overlap(&a, &b, 2); // {0,2} vs {1,3}
        assert_eq!(o, 0.0);
    }

    #[test]
    fn filter_norm_direction_scales() {
        use crate::runtime::ParamInfo;
        let model = ModelInfo {
            name: "t".into(),
            n_layer: 1,
            d_model: 4,
            n_head: 1,
            vocab: 8,
            seq: 4,
            batch: 1,
            d_ff: 8,
            n_params: 0,
            params: vec![
                ParamInfo {
                    name: "w".into(),
                    shape: vec![16, 16],
                    stacked: false,
                    decay: true,
                    init: "normal:0.02".into(),
                },
                ParamInfo {
                    name: "b".into(),
                    shape: vec![16],
                    stacked: false,
                    decay: false,
                    init: "zeros".into(),
                },
            ],
        };
        let state = crate::model::init_state(&model, 11);
        let d = filter_normalized_direction(&state, &model, 1);
        let wn: f64 = state.params[0].iter().map(|&x| (x as f64).powi(2)).sum::<f64>().sqrt();
        let dn: f64 = d[0].iter().map(|&x| (x as f64).powi(2)).sum::<f64>().sqrt();
        assert!((wn - dn).abs() / wn < 1e-3);
        assert!(d[1].iter().all(|&x| x == 0.0)); // 1-D skipped
    }
}
