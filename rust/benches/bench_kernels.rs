//! Native-backend kernel benches: the matmul variants that carry the
//! forward/backward passes, the fake-quant oracle at every granularity, and
//! the fused qdq+matmul path vs a plain matmul (the §3.3 "linear layers
//! dominate" substrate). This is the hot path the ROADMAP's rayon-parallel
//! tiling work will be measured against.

use qpretrain::backend::math::{matmul, matmul_nt, matmul_tn};
use qpretrain::config::{Granularity, Scheme};
use qpretrain::quant::qdq_copy;
use qpretrain::util::bench::{bench, bench_throughput, section};
use qpretrain::util::rng::Rng;

fn main() {
    let mut rng = Rng::new(2);
    let (m, n, k) = (256usize, 512usize, 256usize);
    let x = rng.normal_vec(m * n, 0.0, 1.0); // (m, n)
    let w = rng.normal_vec(n * k, 0.0, 1.0); // (n, k)
    let wt = rng.normal_vec(k * n, 0.0, 1.0); // (k, n) for the nt variant
    let g = rng.normal_vec(m * k, 0.0, 1.0); // (m, k) for the tn variant

    section("native qdq kernels (256x512 f32)");
    for (name, gran, asym) in [
        ("qdq_pt", Granularity::PerTensor, false),
        ("qdq_pc", Granularity::PerChannel, false),
        ("qdq_ptok", Granularity::PerToken, false),
        ("qdq_ptok_asym", Granularity::PerToken, true),
    ] {
        let scheme = if asym {
            Scheme::asym(8, gran)
        } else {
            Scheme::new(8, gran)
        };
        bench_throughput(name, (m * n) as u64, || qdq_copy(&x, m, n, scheme));
    }

    section("matmul kernels at forward/backward shapes (2*m*n*k FLOPs each)");
    let flops = (2 * m * n * k) as u64;
    // forward: y = x @ w
    bench_throughput("matmul_nn (fwd)", flops, || matmul(&x, &w, m, n, k));
    // dx = g @ w^T
    bench_throughput("matmul_nt (dx)", flops, || matmul_nt(&x, &wt, m, n, k));
    // dw = x^T @ g
    bench_throughput("matmul_tn (dw)", flops, || matmul_tn(&x, &g, m, n, k));

    section("fused qdq-matmul vs plain matmul (the paper's W8A8 GEMM)");
    bench("qmatmul (a per-token + w per-channel + gemm)", || {
        let xq = qdq_copy(&x, m, n, Scheme::new(8, Granularity::PerToken));
        let wq = qdq_copy(&w, n, k, Scheme::new(8, Granularity::PerChannel));
        matmul(&xq, &wq, m, n, k)
    });
    bench("matmul_plain", || matmul(&x, &w, m, n, k));
}
