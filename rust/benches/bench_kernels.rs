//! L1 kernel benches through the full AOT path: pallas-lowered HLO vs
//! pure-jnp HLO vs plain matmul, executed on the PJRT CPU client.
//! (interpret=True pallas on CPU measures *structure*, not TPU speed — see
//! DESIGN.md §Perf for the VMEM/MXU estimates.)

use qpretrain::runtime::{lit_f32, lit_scalar, Runtime};
use qpretrain::util::bench::{bench, section};
use qpretrain::util::{artifact_dir, rng::Rng};

fn main() {
    let rt = Runtime::new(&artifact_dir()).expect("run `make artifacts` first");
    let mut rng = Rng::new(2);
    let (m, n, k) = (256usize, 512usize, 256usize);
    let x = lit_f32(&rng.normal_vec(m * n, 0.0, 1.0), &[m, n]).unwrap();
    let w = lit_f32(&rng.normal_vec(n * k, 0.0, 1.0), &[n, k]).unwrap();
    let q = lit_scalar(127.0);

    section("L1 qdq kernels via PJRT (256x512 f32)");
    for art in [
        "k/qdq_pt_pallas",
        "k/qdq_pc_pallas",
        "k/qdq_ptok_pallas",
        "k/qdq_ptok_asym_pallas",
        "k/qdq_pt_jnp",
    ] {
        let exe = rt.exec(art).unwrap();
        bench(art, || exe.run(&[&x, &q]).unwrap());
    }

    section("fused QDQ-matmul vs plain matmul (256x512 @ 512x256)");
    let qmm = rt.exec("k/qmatmul_pallas").unwrap();
    bench("k/qmatmul_pallas", || qmm.run(&[&x, &w, &q, &q]).unwrap());
    let mm = rt.exec("k/matmul_ref").unwrap();
    bench("k/matmul_ref", || mm.run(&[&x, &w, &q, &q]).unwrap());
}
