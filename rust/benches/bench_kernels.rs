//! Native-backend kernel benches: the serial reference (`backend::math`)
//! against the parallel production kernels (`backend::kernels`) at the
//! forward/backward matmul shapes, the SIMD vector path against its
//! bit-identical scalar lane emulation, plus the fake-quant oracle and the
//! fused qdq+matmul path (the §3.3 "linear layers dominate" substrate).
//!
//! Emits `BENCH_kernels.json` at the repo root — GFLOP/s, thread count,
//! serial-vs-parallel and scalar-vs-SIMD speedup per kernel — so future
//! perf PRs have a machine-readable trajectory to beat, then fails against
//! the committed floors in `rust/tests/bench_baseline.json`. Before timing
//! anything, every parallel kernel is asserted bit-identical to its serial
//! reference, and the SIMD path to its scalar emulation.

use qpretrain::backend::{kernels, math, simd};
use qpretrain::config::{Granularity, TensorPolicy};
use qpretrain::quant::qdq_copy;
use qpretrain::util::bench::{bench, bench_throughput, section};
use qpretrain::util::json::{self, Value};
use qpretrain::util::rng::Rng;

/// Bench a serial/parallel pair, print GFLOP/s + speedup, record a JSON row.
fn pair(
    name: &str,
    flops: u64,
    mut serial: impl FnMut() -> Vec<f32>,
    mut parallel: impl FnMut() -> Vec<f32>,
    out: &mut Vec<Value>,
) {
    let s = bench(&format!("{name}/serial"), &mut serial);
    let p = bench(&format!("{name}/parallel"), &mut parallel);
    let speedup = s.mean_ns / p.mean_ns;
    println!(
        "    {name}: {:.2} -> {:.2} GFLOP/s  ({speedup:.2}x)",
        s.gflops(flops),
        p.gflops(flops)
    );
    out.push(json::obj(vec![
        ("name", json::s(name)),
        ("flops", json::num(flops as f64)),
        ("serial_gflops", json::num(s.gflops(flops))),
        ("parallel_gflops", json::num(p.gflops(flops))),
        ("speedup", json::num(speedup)),
    ]));
}

fn main() {
    let threads = kernels::max_threads();
    println!("kernel threads: {threads} (pin with --threads / RAYON_NUM_THREADS)");
    println!(
        "simd: {} (supported: {}; pin off with QPRETRAIN_SIMD=off)",
        if kernels::simd_active() {
            "active"
        } else {
            "scalar lane emulation"
        },
        kernels::simd_supported()
    );

    let mut rng = Rng::new(2);
    let (m, n, k) = (256usize, 512usize, 256usize);
    let x = rng.normal_vec(m * n, 0.0, 1.0); // (m, n)
    let w = rng.normal_vec(n * k, 0.0, 1.0); // (n, k)
    let wt = rng.normal_vec(k * n, 0.0, 1.0); // (k, n) for the nt variant
    let g = rng.normal_vec(m * k, 0.0, 1.0); // (m, k) for the tn variant

    // the contract the speedup rests on: parallel == serial, bit for bit
    // (compare bit patterns, not floats: f32 PartialEq treats 0.0 == -0.0)
    let bits = |v: &[f32]| v.iter().map(|f| f.to_bits()).collect::<Vec<u32>>();
    assert_eq!(
        bits(&math::matmul(&x, &w, m, n, k)),
        bits(&kernels::matmul(&x, &w, m, n, k))
    );
    assert_eq!(
        bits(&math::matmul_nt(&x, &wt, m, n, k)),
        bits(&kernels::matmul_nt(&x, &wt, m, n, k))
    );
    assert_eq!(
        bits(&math::matmul_tn(&x, &g, m, n, k)),
        bits(&kernels::matmul_tn(&x, &g, m, n, k))
    );
    println!("bit-exactness preflight: parallel kernels == serial reference");

    // ...and across the ISA axis: the vector microkernels must reproduce
    // the scalar lane emulation bit for bit before their speedup means
    // anything (vacuously true on machines without AVX2+FMA)
    {
        let scalar = kernels::with_simd(false, || {
            (
                kernels::matmul(&x, &w, m, n, k),
                kernels::matmul_nt(&x, &wt, m, n, k),
                kernels::matmul_tn(&x, &g, m, n, k),
            )
        });
        let simd = kernels::with_simd(true, || {
            (
                kernels::matmul(&x, &w, m, n, k),
                kernels::matmul_nt(&x, &wt, m, n, k),
                kernels::matmul_tn(&x, &g, m, n, k),
            )
        });
        assert_eq!(bits(&scalar.0), bits(&simd.0), "matmul: simd != scalar emulation");
        assert_eq!(bits(&scalar.1), bits(&simd.1), "matmul_nt: simd != scalar emulation");
        assert_eq!(bits(&scalar.2), bits(&simd.2), "matmul_tn: simd != scalar emulation");
        println!("lane-determinism preflight: SIMD == scalar emulation");
    }

    let mut results = Vec::new();
    let flops = (2 * m * n * k) as u64;

    section(&format!("matmul serial vs parallel ({m}x{n}x{k}, {threads} threads)"));
    // forward: y = x @ w
    pair(
        "matmul_nn_fwd",
        flops,
        || math::matmul(&x, &w, m, n, k),
        || kernels::matmul(&x, &w, m, n, k),
        &mut results,
    );
    // dx = g @ w^T
    pair(
        "matmul_nt_dx",
        flops,
        || math::matmul_nt(&x, &wt, m, n, k),
        || kernels::matmul_nt(&x, &wt, m, n, k),
        &mut results,
    );
    // dw = x^T @ g
    pair(
        "matmul_tn_dw",
        flops,
        || math::matmul_tn(&x, &g, m, n, k),
        || kernels::matmul_tn(&x, &g, m, n, k),
        &mut results,
    );

    section(&format!("gpt2s-shape matmul (512x768x768, {threads} threads)"));
    let (gm, gk, gn) = (512usize, 768usize, 768usize);
    let gx = rng.normal_vec(gm * gk, 0.0, 1.0);
    let gw = rng.normal_vec(gk * gn, 0.0, 1.0);
    pair(
        "matmul_nn_gpt2s",
        (2 * gm * gk * gn) as u64,
        || math::matmul(&gx, &gw, gm, gk, gn),
        || kernels::matmul(&gx, &gw, gm, gk, gn),
        &mut results,
    );

    section("SIMD vector path vs scalar lane emulation (1 thread)");
    // the ISA axis in isolation: same kernel, same single thread, dispatch
    // pinned to the vector microkernels vs their bit-identical emulation
    let gflops_f32 = (2 * gm * gk * gn) as u64;
    let s = kernels::with_threads(1, || {
        kernels::with_simd(false, || {
            bench("f32_gemm/scalar_lanes", || kernels::matmul(&gx, &gw, gm, gk, gn))
        })
    });
    let p = kernels::with_threads(1, || {
        kernels::with_simd(true, || {
            bench("f32_gemm/simd", || kernels::matmul(&gx, &gw, gm, gk, gn))
        })
    });
    let f32_speedup = s.mean_ns / p.mean_ns;
    println!(
        "    simd_f32_gemm: {:.2} -> {:.2} GFLOP/s  ({f32_speedup:.2}x)",
        s.gflops(gflops_f32),
        p.gflops(gflops_f32)
    );
    results.push(json::obj(vec![
        ("name", json::s("simd_f32_gemm")),
        ("flops", json::num(gflops_f32 as f64)),
        ("scalar_gflops", json::num(s.gflops(gflops_f32))),
        ("simd_gflops", json::num(p.gflops(gflops_f32))),
        ("speedup", json::num(f32_speedup)),
    ]));
    let (im, ik, in_) = (256usize, 512usize, 256usize);
    let ia: Vec<i8> = (0..im * ik).map(|_| (rng.below(255) as i32 - 127) as i8).collect();
    let ib: Vec<i8> = (0..ik * in_).map(|_| (rng.below(255) as i32 - 127) as i8).collect();
    let iops = (2 * im * ik * in_) as u64;
    let s = kernels::with_threads(1, || {
        kernels::with_simd(false, || {
            bench("i8_gemm/scalar_lanes", || kernels::matmul_i8(&ia, &ib, im, ik, in_))
        })
    });
    let p = kernels::with_threads(1, || {
        kernels::with_simd(true, || {
            bench("i8_gemm/simd", || kernels::matmul_i8(&ia, &ib, im, ik, in_))
        })
    });
    let i8_speedup = s.mean_ns / p.mean_ns;
    println!(
        "    simd_i8_gemm: {:.2} -> {:.2} Giop/s  ({i8_speedup:.2}x)",
        s.gflops(iops),
        p.gflops(iops)
    );
    results.push(json::obj(vec![
        ("name", json::s("simd_i8_gemm")),
        ("flops", json::num(iops as f64)),
        ("scalar_gflops", json::num(s.gflops(iops))),
        ("simd_gflops", json::num(p.gflops(iops))),
        ("speedup", json::num(i8_speedup)),
    ]));

    section("4-row register blocking vs the 1-row microkernel walk (1 thread)");
    // bench-local replica of the pre-blocking kernel: the same K-panel walk
    // and per-element k-ascending fma order, minus the 4-row accumulator
    // blocks — so the delta isolates what the blocking buys (each b-row
    // load amortized over four output rows). The preflight proves the
    // blocking is value-neutral before its speedup means anything.
    let matmul_1row = |a: &[f32], b: &[f32], m: usize, k: usize, n: usize| -> Vec<f32> {
        let mut c = vec![0.0f32; m * n];
        for l0 in (0..k).step_by(kernels::K_PANEL) {
            let l1 = (l0 + kernels::K_PANEL).min(k);
            for i in 0..m {
                let crow = &mut c[i * n..(i + 1) * n];
                for l in l0..l1 {
                    simd::axpy(crow, a[i * k + l], &b[l * n..(l + 1) * n]);
                }
            }
        }
        c
    };
    assert_eq!(
        bits(&matmul_1row(&gx, &gw, gm, gk, gn)),
        bits(&kernels::with_threads(1, || kernels::matmul(&gx, &gw, gm, gk, gn))),
        "4-row blocked matmul != 1-row reference walk"
    );
    println!("blocking preflight: 4-row blocked == 1-row walk, bit for bit");
    let s = kernels::with_threads(1, || {
        bench("f32_gemm/1row_walk", || matmul_1row(&gx, &gw, gm, gk, gn))
    });
    let p = kernels::with_threads(1, || {
        bench("f32_gemm/4row_blocked", || kernels::matmul(&gx, &gw, gm, gk, gn))
    });
    let blocked_speedup = s.mean_ns / p.mean_ns;
    println!(
        "    blocked_vs_1row_gemm: {:.2} -> {:.2} GFLOP/s  ({blocked_speedup:.2}x)",
        s.gflops(gflops_f32),
        p.gflops(gflops_f32)
    );
    results.push(json::obj(vec![
        ("name", json::s("blocked_vs_1row_gemm")),
        ("flops", json::num(gflops_f32 as f64)),
        ("onerow_gflops", json::num(s.gflops(gflops_f32))),
        ("blocked_gflops", json::num(p.gflops(gflops_f32))),
        ("speedup", json::num(blocked_speedup)),
    ]));

    section("row/elementwise kernels serial vs parallel");
    let rows = 4096usize;
    let d = 768usize;
    let lx = rng.normal_vec(rows * d, 0.0, 1.0);
    let lw = rng.normal_vec(d, 1.0, 0.1);
    let lb = rng.normal_vec(d, 0.0, 0.1);
    pair(
        "layer_norm_fwd_4096x768",
        (8 * rows * d) as u64, // approximate op count
        || math::layer_norm_fwd(&lx, &lw, &lb, rows, d).0,
        || kernels::layer_norm_fwd(&lx, &lw, &lb, rows, d).0,
        &mut results,
    );
    let u = rng.normal_vec(rows * d, 0.0, 2.0);
    pair(
        "gelu_4096x768",
        (16 * rows * d) as u64, // tanh-heavy; approximate
        || math::gelu(&u),
        || kernels::gelu(&u),
        &mut results,
    );
    let (cm, cv) = (512usize, 8192usize);
    let logits = rng.normal_vec(cm * cv, 0.0, 2.0);
    let y: Vec<i32> = (0..cm).map(|_| rng.below(cv) as i32).collect();
    pair(
        "cross_entropy_512x8192",
        (6 * cm * cv) as u64, // approximate
        || {
            // serial leg: same kernel pinned to one thread
            kernels::with_threads(1, || kernels::nll_only(&logits, &y, cm, cv))
        },
        || kernels::nll_only(&logits, &y, cm, cv),
        &mut results,
    );

    section("native qdq kernels (256x512 f32)");
    for (name, gran, asym) in [
        ("qdq_pt", Granularity::PerTensor, false),
        ("qdq_pc", Granularity::PerChannel, false),
        ("qdq_ptok", Granularity::PerToken, false),
        ("qdq_ptok_asym", Granularity::PerToken, true),
    ] {
        let scheme = if asym {
            TensorPolicy::asym(8, gran)
        } else {
            TensorPolicy::new(8, gran)
        };
        bench_throughput(name, (m * n) as u64, || qdq_copy(&x, m, n, scheme));
    }

    section("fused qdq-matmul vs plain matmul (the paper's W8A8 GEMM)");
    bench("qmatmul (a per-token + w per-channel + gemm)", || {
        let xq = qdq_copy(&x, m, n, TensorPolicy::new(8, Granularity::PerToken));
        let wq = qdq_copy(&w, n, k, TensorPolicy::new(8, Granularity::PerChannel));
        kernels::matmul(&xq, &wq, m, n, k)
    });
    bench("matmul_plain", || kernels::matmul(&x, &w, m, n, k));

    section("packed-int8 GEMM vs the f32 qdq reference path (w8a8 operands)");
    let ap = TensorPolicy::new(8, Granularity::PerToken);
    let wp = TensorPolicy::new(8, Granularity::PerChannel);
    // exactness preflight: the packed path must sit within rounding of the
    // qdq oracle before its speedup means anything
    {
        let xq = qdq_copy(&x, m, n, ap);
        let wq = qdq_copy(&w, n, k, wp);
        let reference = kernels::matmul(&xq, &wq, m, n, k);
        let xa = qpretrain::quant::pack_acts_i8(&x, m, n, ap);
        let wa = qpretrain::quant::pack_weights_i8(&w, n, k, wp);
        let ci = kernels::matmul_i8_packed(&xa, &wa);
        let fast = kernels::rescale_i32(&ci, &xa.scales, &wa.scales, m, k);
        // bound against the output magnitude: the gap is the f32 summation
        // rounding the reference commits, which scales with the reduction,
        // not with any single (possibly cancelled-to-zero) element
        let mag = reference.iter().fold(0.0f32, |a, &v| a.max(v.abs()));
        for (i, (a, b)) in fast.iter().zip(&reference).enumerate() {
            assert!(
                (a - b).abs() <= 1e-4 * (mag + 1.0),
                "int8 preflight: element {i}: {a} vs {b} (magnitude {mag})"
            );
        }
        println!("int8 exactness preflight: packed path within rounding of qdq oracle");
    }
    // both legs include everything the native forward pays per linear:
    // group params + quantize (+ the f32 activation cache on the int8 leg)
    let s = bench("qdq_f32_path (qdq a + qdq w + f32 gemm)", || {
        let xq = qdq_copy(&x, m, n, ap);
        let wq = qdq_copy(&w, n, k, wp);
        kernels::matmul(&xq, &wq, m, n, k)
    });
    let p = bench("int8_packed_path (pack a + cache + pack w + i32 gemm + rescale)", || {
        let xa = qpretrain::quant::pack_acts_i8(&x, m, n, ap);
        let _cache = qpretrain::quant::dequant_acts_i8(&xa);
        let wa = qpretrain::quant::pack_weights_i8(&w, n, k, wp);
        let ci = kernels::matmul_i8_packed(&xa, &wa);
        kernels::rescale_i32(&ci, &xa.scales, &wa.scales, m, k)
    });
    let int8_speedup = s.mean_ns / p.mean_ns;
    println!("    int8 vs qdq path: {int8_speedup:.2}x");
    results.push(json::obj(vec![
        ("name", json::s("int8_gemm_vs_qdq_path")),
        ("flops", json::num((2 * m * n * k) as f64)),
        ("qdq_path_gflops", json::num(s.gflops((2 * m * n * k) as u64))),
        ("int8_path_gflops", json::num(p.gflops((2 * m * n * k) as u64))),
        ("speedup", json::num(int8_speedup)),
    ]));

    section("backward packed-int8 GEMMs vs the f32 qdq path (w8a8g8 grads)");
    // the PR-6 backward substrate at the bench shapes: forward here is
    // y(m x k) = x(m x n) @ w(n x k), so the weight grad is the tn GEMM
    // dw(n x k) = x^T @ g and the input grad is the nt GEMM
    // dx(m x n) = g @ w^T. Activations (tn) and weights/grad codes (nt)
    // arrive pre-packed from the per-step cache, so each leg times exactly
    // what the train step pays per backward GEMM: the int8 tn leg packs the
    // grads + runs the row-factored i8 core; the qdq tn leg qdq's the grads
    // + runs the f32 tn GEMM against the cached f32 activations.
    let gp = TensorPolicy::new(8, Granularity::PerToken);
    let xa = qpretrain::quant::pack_acts_i8(&x, m, n, ap);
    let xq = qdq_copy(&x, m, n, ap);
    {
        // exactness preflight: the row-factored tn core must sit within
        // rounding of the qdq oracle (bitwise at pow2 scales; the general
        // data here only bounds the f32-summation gap, as in the forward
        // preflight above)
        let gq = qpretrain::quant::pack_grads_i8(&g, m, k, gp);
        let mut fast = vec![0.0f32; n * k];
        kernels::matmul_i8_tn_scaled_acc(&mut fast, &xa, &gq);
        let gdq = qdq_copy(&g, m, k, gp);
        let reference = kernels::matmul_tn(&xq, &gdq, m, n, k);
        let mag = reference.iter().fold(0.0f32, |a, &v| a.max(v.abs()));
        for (i, (a, b)) in fast.iter().zip(&reference).enumerate() {
            assert!(
                (a - b).abs() <= 1e-4 * (mag + 1.0),
                "int8 tn preflight: element {i}: {a} vs {b} (magnitude {mag})"
            );
        }
        println!("int8 tn exactness preflight: row-factored core within rounding of qdq oracle");
    }
    let s = bench("qdq_tn_path (qdq g + f32 tn gemm on cached f32 acts)", || {
        let gdq = qdq_copy(&g, m, k, gp);
        kernels::matmul_tn(&xq, &gdq, m, n, k)
    });
    let p = bench("int8_tn_path (pack g + row-factored i8 tn on cached codes)", || {
        let gq = qpretrain::quant::pack_grads_i8(&g, m, k, gp);
        let mut dw = vec![0.0f32; n * k];
        kernels::matmul_i8_tn_scaled_acc(&mut dw, &xa, &gq);
        dw
    });
    let tn_speedup = s.mean_ns / p.mean_ns;
    println!("    int8 tn vs qdq tn path: {tn_speedup:.2}x");
    results.push(json::obj(vec![
        ("name", json::s("int8_tn_backward_vs_qdq_path")),
        ("flops", json::num((2 * m * n * k) as f64)),
        ("qdq_path_gflops", json::num(s.gflops((2 * m * n * k) as u64))),
        ("int8_path_gflops", json::num(p.gflops((2 * m * n * k) as u64))),
        ("speedup", json::num(tn_speedup)),
    ]));
    // nt: both operand sets are per-step-cache residents (grad codes are
    // packed once for the tn GEMM, weights once at forward), so the legs
    // compare just the GEMM+rescale: exact-i32 nt core vs the f32 nt GEMM
    // over the equivalent dequantized operands
    let wpt = TensorPolicy::new(8, Granularity::PerTensor);
    let gq = qpretrain::quant::pack_grads_i8(&g, m, k, gp);
    let wa = qpretrain::quant::pack_weights_i8(&w, n, k, wpt);
    let gdq = qpretrain::quant::dequant_acts_i8(&gq);
    let wdq = qpretrain::quant::dequant_weights_i8(&wa);
    {
        let ci = kernels::matmul_i8_nt_packed(&gq, &wa);
        let fast = kernels::rescale_i32(&ci, &gq.scales, &wa.scales, m, n);
        let reference = kernels::matmul_nt(&gdq, &wdq, m, k, n);
        let mag = reference.iter().fold(0.0f32, |a, &v| a.max(v.abs()));
        for (i, (a, b)) in fast.iter().zip(&reference).enumerate() {
            assert!(
                (a - b).abs() <= 1e-4 * (mag + 1.0),
                "int8 nt preflight: element {i}: {a} vs {b} (magnitude {mag})"
            );
        }
        println!("int8 nt exactness preflight: packed nt core within rounding of qdq oracle");
    }
    let s = bench("qdq_nt_path (f32 nt gemm on dequantized operands)", || {
        kernels::matmul_nt(&gdq, &wdq, m, k, n)
    });
    let p = bench("int8_nt_path (i32 nt gemm + rescale on cached codes)", || {
        let ci = kernels::matmul_i8_nt_packed(&gq, &wa);
        kernels::rescale_i32(&ci, &gq.scales, &wa.scales, m, n)
    });
    let nt_speedup = s.mean_ns / p.mean_ns;
    println!("    int8 nt vs qdq nt path: {nt_speedup:.2}x");
    results.push(json::obj(vec![
        ("name", json::s("int8_nt_backward_vs_qdq_path")),
        ("flops", json::num((2 * m * n * k) as f64)),
        ("qdq_path_gflops", json::num(s.gflops((2 * m * n * k) as u64))),
        ("int8_path_gflops", json::num(p.gflops((2 * m * n * k) as u64))),
        ("speedup", json::num(nt_speedup)),
    ]));

    section("pool handoff overhead (small kernel, forced parallel)");
    // a shape far below the fork threshold: forcing the parallel path
    // times the persistent pool's dispatch+barrier, the latency that used
    // to be a fresh thread spawn per call
    let (sm, sk, sn) = (16usize, 32usize, 16usize);
    let sa = rng.normal_vec(sm * sk, 0.0, 1.0);
    let sb = rng.normal_vec(sk * sn, 0.0, 1.0);
    let serial_small = bench("small_matmul/serial", || kernels::matmul(&sa, &sb, sm, sk, sn));
    kernels::force_parallel(true);
    let pool_small = bench("small_matmul/forced_pool", || kernels::matmul(&sa, &sb, sm, sk, sn));
    kernels::force_parallel(false);
    let overhead_ns = pool_small.mean_ns - serial_small.mean_ns;
    println!("    pool dispatch+barrier cost ~ {:.1} µs over serial", overhead_ns / 1e3);
    results.push(json::obj(vec![
        ("name", json::s("pool_dispatch_overhead_ns")),
        ("overhead_ns", json::num(overhead_ns)),
        ("serial_ns", json::num(serial_small.mean_ns)),
        ("forced_pool_ns", json::num(pool_small.mean_ns)),
    ]));

    let report = json::obj(vec![
        ("bench", json::s("kernels")),
        ("threads", json::num(threads as f64)),
        ("pool_workers", json::num(kernels::pool_workers() as f64)),
        ("simd", Value::Bool(kernels::simd_active())),
        ("results", Value::Arr(results)),
    ]);
    let path = qpretrain::util::repo_root().join("BENCH_kernels.json");
    std::fs::write(&path, report.to_json()).expect("write BENCH_kernels.json");
    println!("\nwrote {}", path.display());
    qpretrain::util::bench::check_against_baseline(&report, "kernels")
        .expect("bench_kernels regressed below the committed perf floors");
}
