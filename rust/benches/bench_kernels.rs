//! Native-backend kernel benches: the serial reference (`backend::math`)
//! against the parallel production kernels (`backend::kernels`) at the
//! forward/backward matmul shapes, plus the fake-quant oracle and the
//! fused qdq+matmul path (the §3.3 "linear layers dominate" substrate).
//!
//! Emits `BENCH_kernels.json` at the repo root — GFLOP/s, thread count and
//! serial-vs-parallel speedup per kernel — so future perf PRs have a
//! machine-readable trajectory to beat. Before timing anything, every
//! parallel kernel is asserted bit-identical to its serial reference.

use qpretrain::backend::{kernels, math};
use qpretrain::config::{Granularity, TensorPolicy};
use qpretrain::quant::qdq_copy;
use qpretrain::util::bench::{bench, bench_throughput, section};
use qpretrain::util::json::{self, Value};
use qpretrain::util::rng::Rng;

/// Bench a serial/parallel pair, print GFLOP/s + speedup, record a JSON row.
fn pair(
    name: &str,
    flops: u64,
    mut serial: impl FnMut() -> Vec<f32>,
    mut parallel: impl FnMut() -> Vec<f32>,
    out: &mut Vec<Value>,
) {
    let s = bench(&format!("{name}/serial"), &mut serial);
    let p = bench(&format!("{name}/parallel"), &mut parallel);
    let speedup = s.mean_ns / p.mean_ns;
    println!(
        "    {name}: {:.2} -> {:.2} GFLOP/s  ({speedup:.2}x)",
        s.gflops(flops),
        p.gflops(flops)
    );
    out.push(json::obj(vec![
        ("name", json::s(name)),
        ("flops", json::num(flops as f64)),
        ("serial_gflops", json::num(s.gflops(flops))),
        ("parallel_gflops", json::num(p.gflops(flops))),
        ("speedup", json::num(speedup)),
    ]));
}

fn main() {
    let threads = kernels::max_threads();
    println!("kernel threads: {threads} (pin with --threads / RAYON_NUM_THREADS)");

    let mut rng = Rng::new(2);
    let (m, n, k) = (256usize, 512usize, 256usize);
    let x = rng.normal_vec(m * n, 0.0, 1.0); // (m, n)
    let w = rng.normal_vec(n * k, 0.0, 1.0); // (n, k)
    let wt = rng.normal_vec(k * n, 0.0, 1.0); // (k, n) for the nt variant
    let g = rng.normal_vec(m * k, 0.0, 1.0); // (m, k) for the tn variant

    // the contract the speedup rests on: parallel == serial, bit for bit
    // (compare bit patterns, not floats: f32 PartialEq treats 0.0 == -0.0)
    let bits = |v: &[f32]| v.iter().map(|f| f.to_bits()).collect::<Vec<u32>>();
    assert_eq!(
        bits(&math::matmul(&x, &w, m, n, k)),
        bits(&kernels::matmul(&x, &w, m, n, k))
    );
    assert_eq!(
        bits(&math::matmul_nt(&x, &wt, m, n, k)),
        bits(&kernels::matmul_nt(&x, &wt, m, n, k))
    );
    assert_eq!(
        bits(&math::matmul_tn(&x, &g, m, n, k)),
        bits(&kernels::matmul_tn(&x, &g, m, n, k))
    );
    println!("bit-exactness preflight: parallel kernels == serial reference");

    let mut results = Vec::new();
    let flops = (2 * m * n * k) as u64;

    section(&format!("matmul serial vs parallel ({m}x{n}x{k}, {threads} threads)"));
    // forward: y = x @ w
    pair(
        "matmul_nn_fwd",
        flops,
        || math::matmul(&x, &w, m, n, k),
        || kernels::matmul(&x, &w, m, n, k),
        &mut results,
    );
    // dx = g @ w^T
    pair(
        "matmul_nt_dx",
        flops,
        || math::matmul_nt(&x, &wt, m, n, k),
        || kernels::matmul_nt(&x, &wt, m, n, k),
        &mut results,
    );
    // dw = x^T @ g
    pair(
        "matmul_tn_dw",
        flops,
        || math::matmul_tn(&x, &g, m, n, k),
        || kernels::matmul_tn(&x, &g, m, n, k),
        &mut results,
    );

    section(&format!("gpt2s-shape matmul (512x768x768, {threads} threads)"));
    let (gm, gk, gn) = (512usize, 768usize, 768usize);
    let gx = rng.normal_vec(gm * gk, 0.0, 1.0);
    let gw = rng.normal_vec(gk * gn, 0.0, 1.0);
    pair(
        "matmul_nn_gpt2s",
        (2 * gm * gk * gn) as u64,
        || math::matmul(&gx, &gw, gm, gk, gn),
        || kernels::matmul(&gx, &gw, gm, gk, gn),
        &mut results,
    );

    section("row/elementwise kernels serial vs parallel");
    let rows = 4096usize;
    let d = 768usize;
    let lx = rng.normal_vec(rows * d, 0.0, 1.0);
    let lw = rng.normal_vec(d, 1.0, 0.1);
    let lb = rng.normal_vec(d, 0.0, 0.1);
    pair(
        "layer_norm_fwd_4096x768",
        (8 * rows * d) as u64, // approximate op count
        || math::layer_norm_fwd(&lx, &lw, &lb, rows, d).0,
        || kernels::layer_norm_fwd(&lx, &lw, &lb, rows, d).0,
        &mut results,
    );
    let u = rng.normal_vec(rows * d, 0.0, 2.0);
    pair(
        "gelu_4096x768",
        (16 * rows * d) as u64, // tanh-heavy; approximate
        || math::gelu(&u),
        || kernels::gelu(&u),
        &mut results,
    );
    let (cm, cv) = (512usize, 8192usize);
    let logits = rng.normal_vec(cm * cv, 0.0, 2.0);
    let y: Vec<i32> = (0..cm).map(|_| rng.below(cv) as i32).collect();
    pair(
        "cross_entropy_512x8192",
        (6 * cm * cv) as u64, // approximate
        || {
            // serial leg: same kernel pinned to one thread
            let prev = kernels::threads_override();
            kernels::set_threads(1);
            let r = kernels::nll_only(&logits, &y, cm, cv);
            kernels::set_threads(prev);
            r
        },
        || kernels::nll_only(&logits, &y, cm, cv),
        &mut results,
    );

    section("native qdq kernels (256x512 f32)");
    for (name, gran, asym) in [
        ("qdq_pt", Granularity::PerTensor, false),
        ("qdq_pc", Granularity::PerChannel, false),
        ("qdq_ptok", Granularity::PerToken, false),
        ("qdq_ptok_asym", Granularity::PerToken, true),
    ] {
        let scheme = if asym {
            TensorPolicy::asym(8, gran)
        } else {
            TensorPolicy::new(8, gran)
        };
        bench_throughput(name, (m * n) as u64, || qdq_copy(&x, m, n, scheme));
    }

    section("fused qdq-matmul vs plain matmul (the paper's W8A8 GEMM)");
    bench("qmatmul (a per-token + w per-channel + gemm)", || {
        let xq = qdq_copy(&x, m, n, TensorPolicy::new(8, Granularity::PerToken));
        let wq = qdq_copy(&w, n, k, TensorPolicy::new(8, Granularity::PerChannel));
        kernels::matmul(&xq, &wq, m, n, k)
    });
    bench("matmul_plain", || kernels::matmul(&x, &w, m, n, k));

    let report = json::obj(vec![
        ("bench", json::s("kernels")),
        ("threads", json::num(threads as f64)),
        ("results", Value::Arr(results)),
    ]);
    let path = qpretrain::util::repo_root().join("BENCH_kernels.json");
    std::fs::write(&path, report.to_json()).expect("write BENCH_kernels.json");
    println!("\nwrote {}", path.display());
}
