//! Bit-width ablation bench (extension of §4.1): because qmax is a runtime
//! scalar, one per-channel weight structure serves every bit-width. Trains a
//! short run at 2..8 bits and reports final loss — the knee of the curve is
//! the paper's 4-vs-8-bit story.

use qpretrain::backend::kernels;
use qpretrain::config::{QuantRecipe, TrainHp};
use qpretrain::runtime::Runtime;
use qpretrain::train::{train, TrainCfg};

fn main() {
    let rt = Runtime::open_default().expect("runtime");
    let steps = 25;
    println!(
        "backend: {} ({} kernel threads; sweep results are thread-count-invariant)",
        rt.backend_name(),
        kernels::max_threads()
    );
    println!("w_pc weight quantization on micro, {steps} steps, runtime qmax sweep:");
    println!("bits  final_loss  diverged");
    let mut sweep_secs = 0.0f64;
    for bits in [0u32, 2, 3, 4, 5, 6, 8] {
        let recipe = if bits == 0 {
            "base".to_string()
        } else {
            format!("w{bits}_pc")
        };
        let cfg = TrainCfg::new(
            "micro",
            QuantRecipe::parse(&recipe).unwrap(),
            TrainHp {
                steps,
                eval_every: 0,
                log_every: usize::MAX,
                ..TrainHp::default()
            },
        );
        let t0 = std::time::Instant::now();
        let r = train(&rt, &cfg).unwrap();
        sweep_secs += t0.elapsed().as_secs_f64();
        println!(
            "{:>4}  {:>10.4}  {}",
            if bits == 0 {
                "fp".into()
            } else {
                bits.to_string()
            },
            r.final_loss(),
            r.diverged
        );
    }
    println!("sweep wall time: {sweep_secs:.2} s on the parallel kernels");

    // serial-vs-parallel reference point for the whole sweep substrate
    // (threads pinned per run through TrainHp, which resets the process
    // knob to its own value each time)
    let timed_run = |threads: usize| {
        let cfg = TrainCfg::new(
            "micro",
            QuantRecipe::none(),
            TrainHp {
                steps,
                eval_every: 0,
                log_every: usize::MAX,
                threads,
                ..TrainHp::default()
            },
        );
        let t0 = std::time::Instant::now();
        train(&rt, &cfg).unwrap();
        t0.elapsed().as_secs_f64()
    };
    let serial = timed_run(1);
    let parallel = timed_run(0);
    println!(
        "baseline {steps}-step run: 1 thread {serial:.2} s, {} threads {parallel:.2} s ({:.2}x)",
        kernels::max_threads(),
        serial / parallel
    );
}
