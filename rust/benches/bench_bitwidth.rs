//! Bit-width ablation bench (extension of §4.1): because qmax is a runtime
//! scalar, one per-channel weight structure serves every bit-width. Trains a
//! short run at 2..8 bits and reports final loss — the knee of the curve is
//! the paper's 4-vs-8-bit story.

use qpretrain::config::{BitWidths, QuantRunCfg, TrainHp};
use qpretrain::runtime::Runtime;
use qpretrain::train::{train, TrainCfg};

fn main() {
    let rt = Runtime::open_default().expect("runtime");
    let steps = 25;
    println!("backend: {}", rt.backend_name());
    println!("w_pc weight quantization on micro, {steps} steps, runtime qmax sweep:");
    println!("bits  final_loss  diverged");
    for bits in [0u32, 2, 3, 4, 5, 6, 8] {
        let structure = if bits == 0 { "base" } else { "w_pc" };
        let cfg = TrainCfg::new(
            "micro",
            QuantRunCfg {
                structure: structure.into(),
                bits: BitWidths {
                    weights: bits,
                    ..BitWidths::none()
                },
            },
            TrainHp {
                steps,
                eval_every: 0,
                log_every: usize::MAX,
                ..TrainHp::default()
            },
        );
        let r = train(&rt, &cfg).unwrap();
        println!(
            "{:>4}  {:>10.4}  {}",
            if bits == 0 { "fp".into() } else { bits.to_string() },
            r.final_loss(),
            r.diverged
        );
    }
}
