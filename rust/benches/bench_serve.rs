//! Serve-engine benches (feeds §Perf): KV-cached decode throughput and
//! time-to-first-token under continuous batching, across batch budgets and
//! the fp32 / packed-int8 resident-weight paths.
//!
//! Emits `BENCH_serve.json` at the repo root (tokens/s, TTFT, batch
//! occupancy, peak batch) for the perf trajectory, then fails against the
//! committed floors in `rust/tests/bench_baseline.json`; CI uploads the
//! JSON as an artifact per run. Set `QPRETRAIN_BENCH_FAST=1` for a smoke
//! run with shrunk generation budgets.
//!
//! Floor rows carry their batch budget as a JSON *string* (`"batch":
//! "4"`): the baseline matcher selects rows by string-valued fields only.

use qpretrain::backend::kernels;
use qpretrain::config::QuantRecipe;
use qpretrain::model::init_state;
use qpretrain::runtime::Runtime;
use qpretrain::serve::{Engine, Request, Sampler, ServeCfg};
use qpretrain::util::bench::section;
use qpretrain::util::json::{self, Value};
use qpretrain::util::rng::Rng;

/// Ragged synthetic request mix: prompts cycle 1..=8 tokens, budgets cycle
/// so retirements stagger and the batcher keeps refilling mid-run.
fn request_mix(n: usize, vocab: usize, max_new: usize, topk: bool) -> Vec<Request> {
    let mut rng = Rng::new(0xBE7C);
    (0..n)
        .map(|i| Request {
            prompt: (0..1 + i % 8).map(|_| rng.below(vocab) as i32).collect(),
            max_new: max_new - (i % 3),
            sampler: if topk {
                Sampler::TopK {
                    temperature: 0.9,
                    k: 16,
                }
            } else {
                Sampler::Greedy
            },
            seed: 0x5EED + i as u64,
        })
        .collect()
}

fn main() {
    let rt = Runtime::open_default().expect("runtime");
    let threads = kernels::max_threads();
    let fast = qpretrain::util::bench::fast_mode();
    println!(
        "backend: {} ({threads} kernel threads, simd {})",
        rt.backend_name(),
        if kernels::simd_active() { "on" } else { "off" }
    );
    let model = rt.model("micro").unwrap().clone();
    let state = init_state(&model, 7);
    let max_new = if fast { 8 } else { 32 };
    let mut results = Vec::new();

    section("continuous-batching decode throughput (micro, w8a8 resident weights)");
    let recipe = QuantRecipe::parse("w8a8").unwrap();
    for max_batch in [1usize, 4, 8] {
        let mut eng = Engine::new(
            &model,
            &state.params,
            &recipe,
            ServeCfg::new(max_batch, model.seq),
        )
        .expect("engine");
        let reqs = request_mix(2 * max_batch.max(2), model.vocab, max_new, false);
        let (done, stats) = eng.run(&reqs).expect("serve run");
        let tps = stats.tokens_out as f64 / stats.wall_secs.max(1e-9);
        let ttft_ms = 1e3 * done.iter().map(|c| c.ttft_secs).sum::<f64>() / done.len() as f64;
        results.push(json::obj(vec![
            ("name", json::s("decode")),
            ("recipe", json::s("w8a8")),
            ("batch", json::s(&max_batch.to_string())),
            ("requests", json::num(reqs.len() as f64)),
            ("tokens_per_sec", json::num(tps)),
            ("ttft_ms", json::num(ttft_ms)),
            ("occupancy", json::num(stats.occupancy)),
            ("peak_batch", json::num(stats.peak_batch as f64)),
            ("packed_linears", json::num(eng.packed_linears() as f64)),
        ]));
        println!(
            "batch {max_batch:>2}: {tps:>9.0} tokens/s   ttft {ttft_ms:>7.2} ms   \
             occupancy {:.2}   peak {}",
            stats.occupancy, stats.peak_batch
        );
    }

    section("resident-weight paths at batch 4 (fp32 vs packed int8, greedy vs top-k)");
    for (label, spec, topk) in [
        ("base_greedy", "base", false),
        ("w8a8_greedy", "w8a8", false),
        ("w8a8_topk", "w8a8", true),
    ] {
        let recipe = QuantRecipe::parse(spec).unwrap();
        let mut eng =
            Engine::new(&model, &state.params, &recipe, ServeCfg::new(4, model.seq))
                .expect("engine");
        let reqs = request_mix(8, model.vocab, max_new, topk);
        let (_, stats) = eng.run(&reqs).expect("serve run");
        let tps = stats.tokens_out as f64 / stats.wall_secs.max(1e-9);
        results.push(json::obj(vec![
            ("name", json::s("path")),
            ("path", json::s(label)),
            ("batch", json::s("4")),
            ("tokens_per_sec", json::num(tps)),
            ("occupancy", json::num(stats.occupancy)),
        ]));
        println!("{label:<14} {tps:>9.0} tokens/s   occupancy {:.2}", stats.occupancy);
    }

    let report = json::obj(vec![
        ("bench", json::s("serve")),
        ("threads", json::num(threads as f64)),
        ("simd", Value::Bool(kernels::simd_active())),
        ("results", Value::Arr(results)),
    ]);
    let path = qpretrain::util::repo_root().join("BENCH_serve.json");
    std::fs::write(&path, report.to_json()).expect("write BENCH_serve.json");
    println!("\nwrote {}", path.display());
    qpretrain::util::bench::check_against_baseline(&report, "serve")
        .expect("bench_serve regressed below the committed perf floors");
}
