//! Data-pipeline throughput: the synthetic Zipf–Markov corpus generator and
//! few-shot episode generation must never bottleneck the training loop.

use qpretrain::data::fewshot::{Task, TaskGen};
use qpretrain::data::{BatchIter, CorpusCfg};
use qpretrain::util::bench::{bench_throughput, section};

fn main() {
    section("corpus generation");
    let cfg = CorpusCfg::train_default(512);
    let mut it = BatchIter::new(cfg.clone(), 16, 128);
    bench_throughput("corpus/batch_16x128", (16 * 128) as u64, || it.next_batch());

    let cfg8k = CorpusCfg::train_default(8192);
    let mut it8k = BatchIter::new(cfg8k, 2, 256);
    bench_throughput("corpus/gpt2s_batch_2x256", (2 * 256) as u64, || {
        it8k.next_batch()
    });

    let mut raw = BatchIter::new(cfg.clone(), 1, 1);
    bench_throughput("corpus/raw_tokens_64k", 65536, || raw.tokens(65536));

    section("few-shot episode generation");
    let gen = TaskGen::new(CorpusCfg::train_default(512));
    bench_throughput("fewshot/mnli_24_episodes", 24, || {
        gen.episodes(Task::Mnli, 24, 1, 5)
    });
    bench_throughput("fewshot/hellaswag_24_episodes", 24, || {
        gen.episodes(Task::Hellaswag, 24, 1, 5)
    });
}
