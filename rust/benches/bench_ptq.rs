//! PTQ throughput: time to post-training-quantize full model checkpoints
//! (all block linear weights) at GPT-2-small scale — the Table 10 substrate
//! must be interactive.

use qpretrain::config::{Granularity, TensorPolicy};
use qpretrain::quant::{qdq, PackedTensor};
use qpretrain::util::bench::{bench_throughput, section};
use qpretrain::util::rng::Rng;

fn main() {
    let mut rng = Rng::new(3);
    // GPT-2 small block linears: 12 layers x (qkv 768x2304 + proj 768x768 +
    // fc1 768x3072 + fc2 3072x768)
    let shapes: Vec<(usize, usize)> = (0..12)
        .flat_map(|_| [(768, 2304), (768, 768), (768, 3072), (3072, 768)])
        .collect();
    let tensors: Vec<Vec<f32>> = shapes
        .iter()
        .map(|(r, c)| rng.normal_vec(r * c, 0.0, 0.02))
        .collect();
    let total: u64 = shapes.iter().map(|(r, c)| (r * c) as u64).sum();
    println!("checkpoint linear weights: {:.1}M params", total as f64 / 1e6);

    section("full-checkpoint fake-quant PTQ (85M linear params)");
    for gran in [Granularity::PerTensor, Granularity::PerChannel] {
        for bits in [4, 8] {
            let scheme = TensorPolicy::new(bits, gran);
            bench_throughput(
                &format!("ptq/{}/b{bits}", gran.as_str()),
                total,
                || {
                    let mut out = 0usize;
                    for ((r, c), t) in shapes.iter().zip(&tensors) {
                        let mut copy = t.clone();
                        qdq(&mut copy, *r, *c, scheme);
                        out += copy.len();
                    }
                    out
                },
            );
        }
    }

    section("packed int4 export (deployment format)");
    bench_throughput("pack_all/b4", total, || {
        shapes
            .iter()
            .zip(&tensors)
            .map(|((r, c), t)| {
                PackedTensor::quantize(t, *r, *c, TensorPolicy::new(4, Granularity::PerChannel))
                    .storage_bytes()
            })
            .sum::<usize>()
    });
}
