//! L3 quant-library throughput: fake-quant and packed quantization across
//! granularities / bit-widths on GPT-2-small-sized weight tensors.
//! (Feeds the §3.3 efficiency discussion: PTQ of a full checkpoint must be
//! fast enough to be interactive.)

use qpretrain::config::{Granularity, TensorPolicy};
use qpretrain::quant::{qdq_copy, PackedTensor};
use qpretrain::util::bench::{bench_throughput, section};
use qpretrain::util::rng::Rng;

fn main() {
    let mut rng = Rng::new(1);
    let (rows, cols) = (768, 3 * 768); // GPT-2 small QKV projection
    let data = rng.normal_vec(rows * cols, 0.0, 0.02);
    let n = (rows * cols) as u64;

    section("fake-quant (qdq) on 768x2304 f32");
    for gran in [
        Granularity::PerTensor,
        Granularity::PerToken,
        Granularity::PerChannel,
    ] {
        for bits in [4, 8] {
            let scheme = TensorPolicy::new(bits, gran);
            bench_throughput(
                &format!("qdq/{}/b{}", gran.as_str(), bits),
                n,
                || qdq_copy(&data, rows, cols, scheme),
            );
        }
    }
    bench_throughput("qdq/per_token_asym/b4", n, || {
        qdq_copy(&data, rows, cols, TensorPolicy::asym(4, Granularity::PerToken))
    });

    section("packed int storage (quantize + dequantize)");
    for bits in [4, 8] {
        let scheme = TensorPolicy::new(bits, Granularity::PerChannel);
        bench_throughput(&format!("pack/b{bits}"), n, || {
            PackedTensor::quantize(&data, rows, cols, scheme)
        });
        let packed = PackedTensor::quantize(&data, rows, cols, scheme);
        bench_throughput(&format!("unpack/b{bits}"), n, || packed.dequantize());
    }
}
