//! Fig. 2 / 14 / 15 regeneration: the analytic peak-memory tables the paper
//! reports from the PyTorch profiler (see memmodel for the accounting).

use qpretrain::memmodel::{fig15_table, fig2_table};

fn main() {
    println!("=== Fig 2/14: peak memory vs batch size (ctx 1024) ===");
    print!("{}", fig2_table(&["small", "medium", "large"], &[4, 8, 16, 32, 64], 1024));
    println!("\n=== Fig 15: peak memory vs sequence length (batch 4) ===");
    print!(
        "{}",
        fig15_table(&["small", "medium", "large"], &[128, 256, 512, 1024, 2048], 4)
    );
    println!("\npaper shape checks:");
    let small64 =
        qpretrain::memmodel::peak_memory(&qpretrain::memmodel::profile_model("small"), 64, 1024);
    println!(
        "  small@batch64: activations+logits share = {:.1}% (paper: activations dominate)",
        100.0 * (small64.activations + small64.logits) as f64 / small64.total() as f64
    );
    println!(
        "  small@batch64 peak phase = {} (paper App. B: grads absent at peak)",
        small64.peak_phase
    );
}
