//! Dist-trainer benches (feeds §Perf): data-parallel scaling and gradient
//! wire volume for the reduction-tree exchange.
//!
//! Emits `BENCH_dist.json` at the repo root (tokens/s at dp 1 and dp 2,
//! scaling efficiency, f32-vs-int8 exchange bytes per step, per-step
//! exchange wall-clock for the filesystem vs the in-process channel vs
//! the loopback TCP socket transport, and overlap-vs-barrier publish),
//! then fails against the committed floors in
//! `rust/tests/bench_baseline.json`. Set `QPRETRAIN_BENCH_FAST=1` for a
//! smoke run with fewer steps.
//!
//! Floor rows carry their dp as a JSON *string* (`"dp": "1"`): the
//! baseline matcher selects rows by string-valued fields only.

use std::path::PathBuf;

use qpretrain::backend::kernels;
use qpretrain::config::{DistTransport, QuantRecipe, TrainHp};
use qpretrain::dist::{dist_train, take_exchange_nanos, take_wire_stats};
use qpretrain::runtime::Runtime;
use qpretrain::train::TrainCfg;
use qpretrain::util::bench::section;
use qpretrain::util::json::{self, Value};

fn cfg_t(
    spec: &str,
    steps: usize,
    dp: usize,
    out: Option<PathBuf>,
    transport: DistTransport,
    overlap: bool,
) -> TrainCfg {
    let hp = TrainHp {
        steps,
        eval_every: 0,
        log_every: usize::MAX,
        dp,
        dist_transport: transport,
        dist_overlap: overlap,
        ..TrainHp::default()
    };
    let mut c = TrainCfg::new("micro", QuantRecipe::parse(spec).unwrap(), hp);
    c.out_dir = out;
    c
}

fn cfg(spec: &str, steps: usize, dp: usize, out: Option<PathBuf>) -> TrainCfg {
    cfg_t(spec, steps, dp, out, DistTransport::Filesystem, true)
}

fn main() {
    // Workers are spawned from the CLI binary, not this bench binary.
    std::env::set_var("QPRETRAIN_BIN", env!("CARGO_BIN_EXE_qpretrain"));
    let rt = Runtime::open_default().expect("runtime");
    let threads = kernels::max_threads();
    let fast = qpretrain::util::bench::fast_mode();
    let steps = if fast { 6 } else { 20 };
    println!(
        "backend: {} ({threads} kernel threads, simd {})",
        rt.backend_name(),
        if kernels::simd_active() { "on" } else { "off" }
    );
    let model = rt.model("micro").unwrap().clone();
    let tokens_per_step = (model.batch * model.seq) as f64;
    let out_root =
        std::env::temp_dir().join(format!("qpretrain_bench_dist_{}", std::process::id()));
    let mut results = Vec::new();

    section("data-parallel train throughput (micro, w8a8g8, int8 gradient wire)");
    let mut tps_by_dp = Vec::new();
    for dp in [1usize, 2] {
        let out = (dp > 1).then(|| out_root.join(format!("dp{dp}")));
        take_wire_stats(); // reset counters
        let r = dist_train(&rt, &cfg("w8a8g8", steps, dp, out)).expect("dist run");
        let (written, read) = take_wire_stats();
        let tps = r.steps_per_sec * tokens_per_step;
        tps_by_dp.push(tps);
        results.push(json::obj(vec![
            ("name", json::s("dist_train")),
            ("recipe", json::s("w8a8g8")),
            ("dp", json::s(&dp.to_string())),
            ("steps", json::num(steps as f64)),
            ("tokens_per_sec", json::num(tps)),
            ("wire_bytes_per_step", json::num((written + read) as f64 / steps as f64)),
        ]));
        println!(
            "dp {dp}: {tps:>9.0} tokens/s   wire {:>8.0} B/step",
            (written + read) as f64 / steps as f64
        );
    }
    let efficiency = tps_by_dp[1] / tps_by_dp[0].max(1e-9);
    results.push(json::obj(vec![
        ("name", json::s("scaling")),
        ("dp", json::s("2")),
        ("scaling_efficiency", json::num(efficiency)),
    ]));
    println!("dp2/dp1 scaling efficiency: {efficiency:.2}");

    section("gradient wire volume per step (dp 2): f32 vs int8 exchange");
    // Same tree, same frames-per-step; only the recipe's g policy decides
    // the encoding — so the byte ratio is the quantization win directly.
    let mut bytes_by_kind = Vec::new();
    for (kind, spec) in [("f32", "base"), ("i8", "w8a8g8")] {
        take_wire_stats();
        dist_train(&rt, &cfg(spec, steps, 2, Some(out_root.join(kind)))).expect("dist run");
        let (written, read) = take_wire_stats();
        let per_step = (written + read) as f64 / steps as f64;
        bytes_by_kind.push(per_step);
        println!("{kind:>4} wire: {per_step:>9.0} B/step");
    }
    let ratio = bytes_by_kind[0] / bytes_by_kind[1].max(1e-9);
    results.push(json::obj(vec![
        ("name", json::s("wire_bytes")),
        ("dp", json::s("2")),
        ("f32_bytes_per_step", json::num(bytes_by_kind[0])),
        ("i8_bytes_per_step", json::num(bytes_by_kind[1])),
        ("f32_over_i8", json::num(ratio)),
    ]));
    println!("f32/i8 wire ratio: {ratio:.2}x");

    section("per-step exchange wall-clock (dp 2, w8a8g8): filesystem vs channel vs socket");
    // Rank 0's publish + collect time only (take_exchange_nanos counts the
    // leader alone, so worker subprocesses don't skew it). The channel
    // transport skips the disk, the rename barrier, and the poll loop
    // entirely, so it should win by a wide margin; the socket transport
    // rides loopback TCP — no disk, but real syscalls and a hub hop — and
    // should land between the two.
    let mut ex_us = Vec::new();
    for (name, transport, out) in [
        ("filesystem", DistTransport::Filesystem, Some(out_root.join("ex_fs"))),
        ("channel", DistTransport::Channel, None),
        ("socket", DistTransport::Socket, None),
    ] {
        take_exchange_nanos(); // reset
        dist_train(&rt, &cfg_t("w8a8g8", steps, 2, out, transport, true)).expect("dist run");
        let us = take_exchange_nanos() as f64 / steps as f64 / 1e3;
        ex_us.push(us);
        println!("{name:>10}: {us:>9.1} us/step exchange");
    }
    let fs_over_channel = ex_us[0] / ex_us[1].max(1e-9);
    let fs_over_socket = ex_us[0] / ex_us[2].max(1e-9);
    results.push(json::obj(vec![
        ("name", json::s("transport")),
        ("recipe", json::s("w8a8g8")),
        ("dp", json::s("2")),
        ("fs_exchange_us_per_step", json::num(ex_us[0])),
        ("channel_exchange_us_per_step", json::num(ex_us[1])),
        ("socket_exchange_us_per_step", json::num(ex_us[2])),
        ("exchange_fs_over_channel", json::num(fs_over_channel)),
        ("exchange_fs_over_socket", json::num(fs_over_socket)),
    ]));
    println!("filesystem/channel exchange ratio: {fs_over_channel:.2}x");
    println!("filesystem/socket   exchange ratio: {fs_over_socket:.2}x");

    section("overlap vs barrier publish (dp 2, w8a8g8, filesystem)");
    // At micro scale every dp-2 shard cover is a single node, so overlap
    // and barrier ship the same one frame — this row guards that the
    // overlap path costs nothing, not that it wins (multi-node covers
    // only appear at larger batches).
    let mut ov_us = Vec::new();
    for (name, overlap) in [("overlap", true), ("barrier", false)] {
        take_exchange_nanos(); // reset
        let out = Some(out_root.join(format!("ov_{name}")));
        dist_train(&rt, &cfg_t("w8a8g8", steps, 2, out, DistTransport::Filesystem, overlap))
            .expect("dist run");
        let us = take_exchange_nanos() as f64 / steps as f64 / 1e3;
        ov_us.push(us);
        println!("{name:>8}: {us:>9.1} us/step exchange");
    }
    let barrier_over_overlap = ov_us[1] / ov_us[0].max(1e-9);
    results.push(json::obj(vec![
        ("name", json::s("overlap")),
        ("recipe", json::s("w8a8g8")),
        ("dp", json::s("2")),
        ("transport", json::s("filesystem")),
        ("overlap_us_per_step", json::num(ov_us[0])),
        ("barrier_us_per_step", json::num(ov_us[1])),
        ("barrier_over_overlap", json::num(barrier_over_overlap)),
    ]));
    println!("barrier/overlap exchange ratio: {barrier_over_overlap:.2}x");

    std::fs::remove_dir_all(&out_root).ok();

    let report = json::obj(vec![
        ("bench", json::s("dist")),
        ("threads", json::num(threads as f64)),
        ("simd", Value::Bool(kernels::simd_active())),
        ("results", Value::Arr(results)),
    ]);
    let path = qpretrain::util::repo_root().join("BENCH_dist.json");
    std::fs::write(&path, report.to_json()).expect("write BENCH_dist.json");
    println!("\nwrote {}", path.display());
    qpretrain::util::bench::check_against_baseline(&report, "dist")
        .expect("bench_dist regressed below the committed perf floors");
}
