//! L3 end-to-end train-step benches (feeds §Perf): steps/s and tokens/s
//! for the native backend across quantization recipes, serial vs pool
//! kernels, the exact-i32 accumulator vs the f32 code fold on the packed
//! w8a8 / w8a8g8 GEMMs, plus a breakdown of where the per-step wall time
//! goes (forward+backward+Adam vs data generation).
//!
//! Emits `BENCH_train_loop.json` at the repo root (steps/s, tokens/s,
//! thread count, serial-vs-pool, i32-vs-f32-fold and scalar-vs-SIMD
//! speedups)
//! for the perf trajectory, then fails against the committed floors in
//! `rust/tests/bench_baseline.json`; CI uploads the JSON as an artifact
//! per run. Set `QPRETRAIN_BENCH_FAST=1` for a smoke run with shrunk step
//! counts.

use std::time::Instant;

use qpretrain::backend::{kernels, native};
use qpretrain::config::{QuantRecipe, TrainHp};
use qpretrain::data::{BatchIter, CorpusCfg};
use qpretrain::model::init_state;
use qpretrain::runtime::Runtime;
use qpretrain::train::{train, TrainCfg};
use qpretrain::util::bench::section;
use qpretrain::util::json::{self, Value};

fn steps_per_sec(
    rt: &Runtime,
    model: &str,
    recipe: &str,
    steps: usize,
    threads: usize, // 0 = auto; train_from applies it per run
) -> f64 {
    let cfg = TrainCfg::new(
        model,
        QuantRecipe::parse(recipe).unwrap(),
        TrainHp {
            steps,
            eval_every: 0,
            log_every: usize::MAX,
            threads,
            ..TrainHp::default()
        },
    );
    let r = train(rt, &cfg).unwrap();
    r.steps_per_sec
}

fn main() {
    let rt = Runtime::open_default().expect("runtime");
    let threads = kernels::max_threads();
    let fast = qpretrain::util::bench::fast_mode();
    println!(
        "backend: {} ({threads} kernel threads, simd {})",
        rt.backend_name(),
        if kernels::simd_active() { "on" } else { "off" }
    );
    let mut results = Vec::new();
    let mut record = |model: &str, recipe: &str, nthreads: usize, sps: f64, toks: f64| {
        results.push(json::obj(vec![
            ("model", json::s(model)),
            ("recipe", json::s(recipe)),
            ("threads", json::num(nthreads as f64)),
            ("steps_per_sec", json::num(sps)),
            ("tokens_per_sec", json::num(sps * toks)),
        ]));
    };
    let micro_steps = if fast { 4 } else { 10 };
    let t4_steps = if fast { 1 } else { 2 };

    section("serial vs pool kernels (baseline recipe)");
    for (model, steps, toks) in [("micro", micro_steps, 512.0f64), ("t4", t4_steps, 2048.0)] {
        let serial = steps_per_sec(&rt, model, "base", steps, 1);
        let parallel = steps_per_sec(&rt, model, "base", steps, 0);
        record(model, "base", 1, serial, toks);
        record(model, "base", threads, parallel, toks);
        println!(
            "{model:<8} 1 thread: {serial:>7.2} steps/s   {threads} threads: {parallel:>7.2} steps/s   speedup {:.2}x",
            parallel / serial
        );
    }

    section("exact-i32 accumulator vs f32 code fold (packed GEMMs, default threads)");
    // the acceptance rows for the integer-compute claim: the same
    // packed-code run with the accumulator knob on (exact i32 + one
    // rescale) vs off (f32 fold of the identical integer code products).
    // w8a8 exercises the forward packed GEMMs; w8a8g8 adds the packed
    // backward — per-step grad packing, the row-factored i8 tn core and
    // the cached-operand nt GEMM.
    for (model, steps, toks) in [("micro", micro_steps, 512.0f64), ("t4", t4_steps, 2048.0)] {
        for recipe in ["w8a8", "w8a8g8"] {
            native::set_int8_gemm(false);
            let fold = steps_per_sec(&rt, model, recipe, steps, 0);
            native::set_int8_gemm(true);
            let int8 = steps_per_sec(&rt, model, recipe, steps, 0);
            record(model, &format!("{recipe}[f32fold]"), threads, fold, toks);
            record(model, &format!("{recipe}[int8]"), threads, int8, toks);
            results.push(json::obj(vec![
                ("name", json::s("int8_vs_f32fold")),
                ("model", json::s(model)),
                ("recipe", json::s(recipe)),
                ("speedup", json::num(int8 / fold)),
            ]));
            println!(
                "{model:<8} {recipe:<8} f32 fold: {fold:>7.2} steps/s   i32: {int8:>7.2} steps/s   speedup {:.2}x",
                int8 / fold
            );
        }
    }
    native::set_int8_gemm(native::int8_env_default());

    section("simd vector path vs scalar lane emulation (micro, default threads)");
    // the ISA-axis rows of the trajectory: the same run with the dispatch
    // pinned to the scalar lane emulation vs the vector microkernels
    // (bit-identical results; only wall-clock moves)
    for recipe in ["base", "w8a8"] {
        let scalar =
            kernels::with_simd(false, || steps_per_sec(&rt, "micro", recipe, micro_steps, 0));
        let simd =
            kernels::with_simd(true, || steps_per_sec(&rt, "micro", recipe, micro_steps, 0));
        record("micro", &format!("{recipe}[scalar]"), threads, scalar, 512.0);
        record("micro", &format!("{recipe}[simd]"), threads, simd, 512.0);
        println!(
            "micro/{recipe:<6} scalar: {scalar:>7.2} steps/s   simd: {simd:>7.2} steps/s   speedup {:.2}x",
            simd / scalar
        );
    }

    section("micro train step throughput by recipe (batch 4 x seq 128)");
    for recipe in [
        "w8_pc",
        "w8a8",
        "w8a8g8",
        "m1_8_pc",
        // the paper's full combined recipe, inexpressible pre-redesign
        "w4_pc+a8_ptok+g8_ptok+m1_8_pt+m2_8_pc",
    ] {
        let sps = steps_per_sec(&rt, "micro", recipe, micro_steps, 0);
        record("micro", recipe, threads, sps, 512.0);
        println!("{recipe:<40} {sps:>7.2} steps/s   ({:.0} tokens/s)", sps * 512.0);
    }

    section("per-step cost breakdown (micro baseline)");
    let model = rt.model("micro").unwrap().clone();
    let mut state = init_state(&model, 1);
    let mut corpus = BatchIter::new(CorpusCfg::train_default(model.vocab), model.batch, model.seq);

    // data generation
    let t0 = Instant::now();
    let reps = if fast { 10 } else { 50 };
    for _ in 0..reps {
        std::hint::black_box(corpus.next_batch());
    }
    let data_ms = t0.elapsed().as_secs_f64() * 1e3 / reps as f64;

    // full step (forward + backward + AdamW)
    let base = QuantRecipe::none();
    let mut step_ms = 0.0;
    let n = if fast { 3 } else { 10 };
    for i in 0..n {
        let b = corpus.next_batch();
        let t0 = Instant::now();
        rt.train_step(&model, &base, &mut state, &b.x, &b.y, 1e-3, i as f32 + 1.0)
            .unwrap();
        step_ms += t0.elapsed().as_secs_f64() * 1e3 / n as f64;
    }
    println!("full step:            {step_ms:>8.2} ms");
    println!("  batch generation:   {data_ms:>8.2} ms");
    println!(
        "  fwd+bwd+adam:       {:>8.2} ms (remainder)",
        step_ms - data_ms
    );

    let report = json::obj(vec![
        ("bench", json::s("train_loop")),
        ("threads", json::num(threads as f64)),
        ("simd", Value::Bool(kernels::simd_active())),
        ("results", Value::Arr(results)),
    ]);
    let path = qpretrain::util::repo_root().join("BENCH_train_loop.json");
    std::fs::write(&path, report.to_json()).expect("write BENCH_train_loop.json");
    println!("\nwrote {}", path.display());
    qpretrain::util::bench::check_against_baseline(&report, "train_loop")
        .expect("bench_train_loop regressed below the committed perf floors");
}
