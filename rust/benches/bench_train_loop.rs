//! L3 end-to-end train-step benches (feeds §Perf): steps/s for the study
//! model across quantization structures, plus a breakdown of where the
//! per-step wall time goes (device execute vs host literal traffic vs data
//! generation).

use std::time::Instant;

use qpretrain::config::{BitWidths, QuantRunCfg, TrainHp};
use qpretrain::data::{BatchIter, CorpusCfg};
use qpretrain::model::init_state;
use qpretrain::runtime::{lit_i32, lit_scalar, Runtime};
use qpretrain::train::{train, TrainCfg};
use qpretrain::util::artifact_dir;
use qpretrain::util::bench::section;

fn steps_per_sec(rt: &Runtime, structure: &str, bits: BitWidths, steps: usize) -> f64 {
    let cfg = TrainCfg::new(
        "t4",
        QuantRunCfg {
            structure: structure.into(),
            bits,
        },
        TrainHp {
            steps,
            eval_every: 0,
            log_every: usize::MAX,
            ..TrainHp::default()
        },
    );
    let r = train(rt, &cfg).unwrap();
    r.steps_per_sec
}

fn main() {
    let rt = Runtime::new(&artifact_dir()).expect("run `make artifacts` first");
    let steps = 10;

    section("t4 train step throughput (steps/s, batch 16 x seq 128)");
    for (name, structure, bits) in [
        ("baseline", "base", BitWidths::none()),
        ("w8_pc", "w_pc", BitWidths { weights: 8, ..BitWidths::none() }),
        ("w8a8", "wa", BitWidths { weights: 8, acts: 8, ..BitWidths::none() }),
        ("w8a8g8", "wag", BitWidths { weights: 8, acts: 8, grads: 8, ..BitWidths::none() }),
        ("w8_pc_pallas", "w_pc_pallas", BitWidths { weights: 8, ..BitWidths::none() }),
        ("m1_8_pc", "m1_pc", BitWidths { m1: 8, ..BitWidths::none() }),
    ] {
        let sps = steps_per_sec(&rt, structure, bits, steps);
        println!("{name:<16} {sps:>7.2} steps/s   ({:.0} tokens/s)", sps * 2048.0);
    }

    section("per-step cost breakdown (baseline)");
    let model = rt.manifest.model("t4").unwrap().clone();
    let exe = rt.exec("t4/train/base").unwrap();
    let state_host = init_state(&model, 1);
    let mut state = state_host.to_literals(&model).unwrap();
    let mut corpus = BatchIter::new(CorpusCfg::train_default(model.vocab), model.batch, model.seq);

    // data generation
    let t0 = Instant::now();
    let reps = 50;
    for _ in 0..reps {
        std::hint::black_box(corpus.next_batch());
    }
    let data_ms = t0.elapsed().as_secs_f64() * 1e3 / reps as f64;

    // literal upload (state rebuild from host)
    let t0 = Instant::now();
    for _ in 0..5 {
        std::hint::black_box(state_host.to_literals(&model).unwrap());
    }
    let upload_ms = t0.elapsed().as_secs_f64() * 1e3 / 5.0;

    // full step
    let qlits: Vec<xla::Literal> = (0..5).map(|_| lit_scalar(1.0)).collect();
    let mut step_ms = 0.0;
    for i in 0..10 {
        let b = corpus.next_batch();
        let x = lit_i32(&b.x, &[b.batch, b.seq]).unwrap();
        let y = lit_i32(&b.y, &[b.batch, b.seq]).unwrap();
        let lr = lit_scalar(1e-3);
        let t = lit_scalar(i as f32 + 1.0);
        let mut inputs: Vec<&xla::Literal> = state.iter().collect();
        inputs.extend([&x, &y, &lr, &t]);
        for q in &qlits {
            inputs.push(q);
        }
        let t0 = Instant::now();
        let mut out = exe.run(&inputs).unwrap();
        step_ms += t0.elapsed().as_secs_f64() * 1e3 / 10.0;
        out.truncate(3 * model.params.len());
        state = out;
    }
    println!("full step:            {step_ms:>8.2} ms");
    println!("  batch generation:   {data_ms:>8.2} ms");
    println!("  host->literal state:{upload_ms:>8.2} ms (only on init/ckpt)");
    println!(
        "  device exec+tuple:  {:>8.2} ms (remainder)",
        step_ms - data_ms
    );
}
