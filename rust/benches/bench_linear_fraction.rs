//! Fig. 3 regeneration: measured share of block fwd+bwd time spent in linear
//! layers vs the attention core, across GPT-2 sizes and sequence lengths,
//! on the native matmul kernels (plus the analytic FLOPs-model prediction).

use std::time::Instant;

use qpretrain::backend::{kernels, math};
use qpretrain::timemodel::{fig3_rows, rows_to_csv};
use qpretrain::util::rng::Rng;

fn main() {
    let rows = fig3_rows(2);
    print!("{}", rows_to_csv(&rows));

    // serial vs parallel on the dominating component: a full-size
    // gpt2-small FC1 forward GEMM (the fig3 grid itself is timed
    // single-threaded so its sample extrapolation stays linear)
    let threads = kernels::max_threads();
    let (m, k, n) = (512usize, 768usize, 3072usize);
    let mut rng = Rng::new(9);
    let a = rng.normal_vec(m * k, 0.0, 1.0);
    let w = rng.normal_vec(k * n, 0.0, 1.0);
    let mut serial_ms = f64::MAX;
    let mut parallel_ms = f64::MAX;
    for _ in 0..3 {
        let t0 = Instant::now();
        std::hint::black_box(math::matmul(&a, &w, m, k, n));
        serial_ms = serial_ms.min(t0.elapsed().as_secs_f64() * 1e3);
        let t0 = Instant::now();
        std::hint::black_box(kernels::matmul(&a, &w, m, k, n));
        parallel_ms = parallel_ms.min(t0.elapsed().as_secs_f64() * 1e3);
    }
    println!(
        "\nfc1 fwd GEMM {m}x{k}x{n}: serial {serial_ms:.1} ms, \
         {threads} threads {parallel_ms:.1} ms ({:.2}x)",
        serial_ms / parallel_ms
    );

    // the paper's qualitative claims, checked on the measured numbers
    let f = |size: &str, seq: usize| {
        rows.iter()
            .find(|r| r.size == size && r.seq == seq)
            .map(|r| r.measured_frac)
            .unwrap_or(f64::NAN)
    };
    println!("\npaper shape checks:");
    println!(
        "  small s128 linear share {:.1}% (paper: >80% at short seq)",
        100.0 * f("small", 128)
    );
    println!(
        "  small: s128 {:.1}% -> s1024 {:.1}% (paper: decreasing in seq)",
        100.0 * f("small", 128),
        100.0 * f("small", 1024)
    );
    println!(
        "  s512: small {:.1}% vs xl {:.1}% (paper: increasing in model size)",
        100.0 * f("small", 512),
        100.0 * f("xl", 512)
    );
}
