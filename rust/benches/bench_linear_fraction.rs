//! Fig. 3 regeneration: measured share of block fwd+bwd time spent in linear
//! layers vs the attention core, across GPT-2 sizes and sequence lengths,
//! on the native matmul kernels (plus the analytic FLOPs-model prediction).

use qpretrain::timemodel::{fig3_rows, rows_to_csv};

fn main() {
    let rows = fig3_rows(2);
    print!("{}", rows_to_csv(&rows));

    // the paper's qualitative claims, checked on the measured numbers
    let f = |size: &str, seq: usize| {
        rows.iter()
            .find(|r| r.size == size && r.seq == seq)
            .map(|r| r.measured_frac)
            .unwrap_or(f64::NAN)
    };
    println!("\npaper shape checks:");
    println!(
        "  small s128 linear share {:.1}% (paper: >80% at short seq)",
        100.0 * f("small", 128)
    );
    println!(
        "  small: s128 {:.1}% -> s1024 {:.1}% (paper: decreasing in seq)",
        100.0 * f("small", 128),
        100.0 * f("small", 1024)
    );
    println!(
        "  s512: small {:.1}% vs xl {:.1}% (paper: increasing in model size)",
        100.0 * f("small", 512),
        100.0 * f("xl", 512)
    );
}
