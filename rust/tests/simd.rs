//! SIMD/scalar lane-equivalence suite: the vector microkernels
//! (`backend::simd`) must be **bit-identical** to their scalar lane
//! emulation on every shape — K tails of every residue mod the lane
//! width, degenerate dims, i8 saturation codes near the i32 widening
//! bound — and end-to-end through the native forward. Together with
//! `rust/tests/kernels.rs` (threads axis) this pins the full determinism
//! matrix: results are a function of the problem only, never of the ISA
//! or the thread count.
//!
//! Tests here flip the process-wide SIMD/thread knobs, so they serialize
//! on a mutex and restore via an RAII guard (panic-safe).

use std::sync::{Mutex, MutexGuard};

use qpretrain::backend::{kernels, math, native};
use qpretrain::config::{Granularity, QuantRecipe, TensorPolicy};
use qpretrain::data::{BatchIter, CorpusCfg};
use qpretrain::model::init_state;
use qpretrain::quant;
use qpretrain::runtime::Runtime;
use qpretrain::util::quickcheck::{check, Config};
use qpretrain::util::rng::Rng;

static KNOBS: Mutex<()> = Mutex::new(());

/// Serializes the test and restores every process-wide knob on drop.
struct Knobs(#[allow(dead_code)] MutexGuard<'static, ()>);

fn knobs() -> Knobs {
    Knobs(KNOBS.lock().unwrap_or_else(|e| e.into_inner()))
}

impl Drop for Knobs {
    fn drop(&mut self) {
        kernels::force_parallel(false);
        kernels::set_threads(0);
        kernels::set_simd(None);
    }
}

fn bits(v: &[f32]) -> Vec<u32> {
    v.iter().map(|x| x.to_bits()).collect()
}

/// Both dispatch modes of one matmul suite (nn + nt + tn + acc forms),
/// compared bit-for-bit against each other *and* against the serial
/// reference walked in the same mode.
fn modes_identical(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> bool {
    let bt: Vec<f32> = b.iter().chain(a.iter()).cycle().take(n * k).copied().collect();
    let bn: Vec<f32> = b.iter().chain(a.iter()).cycle().take(m * n).copied().collect();
    let run = |simd: bool| {
        kernels::with_simd(simd, || {
            let mut acc: Vec<f32> = (0..m * n).map(|i| (i as f32 * 0.17).sin()).collect();
            kernels::matmul_acc(&mut acc, a, b, m, k, n);
            (
                kernels::matmul(a, b, m, k, n),
                kernels::matmul_nt(a, &bt, m, k, n),
                kernels::matmul_tn(a, &bn, m, k, n),
                acc,
                math::matmul(a, b, m, k, n),
                math::matmul_nt(a, &bt, m, k, n),
            )
        })
    };
    let s = run(false);
    let v = run(true);
    bits(&s.0) == bits(&v.0)
        && bits(&s.1) == bits(&v.1)
        && bits(&s.2) == bits(&v.2)
        && bits(&s.3) == bits(&v.3)
        // kernels == math inside each mode (the threads-axis contract
        // holds on both sides of the ISA axis)
        && bits(&s.0) == bits(&s.4)
        && bits(&s.1) == bits(&s.5)
        && bits(&v.0) == bits(&v.4)
        && bits(&v.1) == bits(&v.5)
}

#[test]
fn prop_simd_bitwise_equals_scalar_emulation() {
    let _g = knobs();
    if !kernels::simd_supported() {
        return; // single-tier machine: nothing to compare
    }
    kernels::force_parallel(true);
    check(
        Config { cases: 60, ..Config::default() },
        |rng: &mut Rng| {
            // K biased into the tail-heavy 1..=17 band the lane width cares
            // about, with occasional panel-straddling sizes
            let k = if rng.bool_with(0.6) {
                rng.range(1, 18)
            } else {
                rng.range(kernels::K_PANEL - 2, kernels::K_PANEL + 11)
            };
            let m = rng.range(1, 13);
            let n = rng.range(1, 36);
            let a = rng.normal_vec(m * k, 0.0, 1.0);
            let b = rng.normal_vec(k * n, 0.0, 1.0);
            let threads = rng.range(1, 9);
            (a, b, m, k, n, threads)
        },
        |(a, b, m, k, n, threads)| {
            kernels::set_threads(*threads);
            modes_identical(a, b, *m, *k, *n)
        },
    );
}

#[test]
fn k_tail_sweep_every_residue_bit_identical() {
    // K = 1..=17 covers every residue mod 8 (f32 lanes) and mod 16 (i8
    // lanes) plus both sides of one full lane block; N sweeps the store
    // tails of the axpy kernels
    let _g = knobs();
    let mut rng = Rng::new(0x7A11);
    for k in 1..=17usize {
        for n in [1usize, 7, 8, 9, 16, 17] {
            let m = 3;
            let a = rng.normal_vec(m * k, 0.0, 1.0);
            let b = rng.normal_vec(k * n, 0.0, 1.0);
            assert!(
                modes_identical(&a, &b, m, k, n),
                "simd/scalar modes differ at (m={m}, k={k}, n={n})"
            );
        }
    }
}

#[test]
fn degenerate_dims_no_panic_and_mode_invariant() {
    let _g = knobs();
    for (m, k, n) in [(0usize, 5usize, 3usize), (4, 0, 3), (4, 5, 0), (0, 0, 0), (1, 1, 1)] {
        let a = vec![0.5f32; m * k];
        let b = vec![-0.25f32; k * n];
        let run = |simd: bool| {
            kernels::with_simd(simd, || {
                let bt = vec![0.125f32; n * k];
                (kernels::matmul(&a, &b, m, k, n), kernels::matmul_nt(&a, &bt, m, k, n))
            })
        };
        let s = run(false);
        let v = run(true);
        assert_eq!(bits(&s.0), bits(&v.0), "matmul ({m},{k},{n})");
        assert_eq!(bits(&s.1), bits(&v.1), "matmul_nt ({m},{k},{n})");
        assert_eq!(s.0.len(), m * n);
        // k == 0 must yield exact (positive) zeros on every path
        if k == 0 {
            assert!(s.0.iter().all(|x| x.to_bits() == 0), "k=0 not +0.0");
        }

        let ia = vec![7i8; m * k];
        let ib = vec![-3i8; k * n];
        let is_ = kernels::with_simd(false, || kernels::matmul_i8(&ia, &ib, m, k, n));
        let iv = kernels::with_simd(true, || kernels::matmul_i8(&ia, &ib, m, k, n));
        assert_eq!(is_, iv, "matmul_i8 ({m},{k},{n})");
        assert_eq!(is_.len(), m * n);
    }
}

#[test]
fn i8_extreme_codes_near_i32_widening_bound() {
    // all-saturated codes (±127) at the largest K whose dot product still
    // fits i32: k·127² = 2 145 157 000 < 2 147 483 647. One row of +127
    // against a +127 column drives the accumulator within ~0.1% of
    // i32::MAX; the mirrored row does the same toward i32::MIN. The i32
    // path must agree with a widened i64 reference exactly, in both
    // dispatch modes.
    let _g = knobs();
    let k = 133_000usize;
    assert!((k as i64) * 127 * 127 <= i32::MAX as i64);
    let m = 2usize;
    let n = 4usize;
    let mut a = vec![127i8; m * k];
    for v in a[k..].iter_mut() {
        *v = -127; // second row pushes toward i32::MIN
    }
    let mut b = vec![127i8; k * n];
    for (i, v) in b.iter_mut().enumerate() {
        if i % n >= 2 {
            *v = if (i / n) % 2 == 0 { 127 } else { -127 }; // alternating cols
        }
    }
    let mut want = vec![0i64; m * n];
    for i in 0..m {
        for l in 0..k {
            for j in 0..n {
                want[i * n + j] += a[i * k + l] as i64 * b[l * n + j] as i64;
            }
        }
    }
    assert_eq!(want[0], (k as i64) * 127 * 127, "test setup: not at the bound");
    for simd in [false, true] {
        let got = kernels::with_simd(simd, || kernels::matmul_i8(&a, &b, m, k, n));
        let got64: Vec<i64> = got.iter().map(|&v| v as i64).collect();
        assert_eq!(got64, want, "saturated i8 GEMM wrong (simd={simd})");
    }
}

#[test]
fn packed_padded_layout_equals_tight_gemm() {
    let _g = knobs();
    let mut rng = Rng::new(0x9AD);
    let (m, k, n) = (6usize, 45usize, 13usize); // both strides padded
    let x = rng.normal_vec(m * k, 0.0, 1.2);
    let w = rng.normal_vec(k * n, 0.0, 0.7);
    let ap = TensorPolicy::new(8, Granularity::PerToken);
    let wp = TensorPolicy::new(8, Granularity::PerChannel);
    let xa = quant::pack_acts_i8(&x, m, k, ap);
    let wq = quant::pack_weights_i8(&w, k, n, wp);
    assert!(xa.stride > xa.cols && wq.stride > wq.cols, "shapes should need padding");
    // strip the padding to recover the tight layout
    let tight = |p: &quant::PackedGemmOperand| -> Vec<i8> {
        let mut out = Vec::with_capacity(p.rows * p.cols);
        for r in 0..p.rows {
            out.extend_from_slice(&p.codes[r * p.stride..r * p.stride + p.cols]);
        }
        out
    };
    let want = kernels::matmul_i8(&tight(&xa), &tight(&wq), m, k, n);
    for simd in [false, true] {
        let got = kernels::with_simd(simd, || kernels::matmul_i8_packed(&xa, &wq));
        assert_eq!(got, want, "padded GEMM != tight GEMM (simd={simd})");
    }
}

#[test]
fn dequant_padded_acts_bitwise_matches_qdq() {
    // odd cols force padding; strictly positive data keeps every value out
    // of the zero bin, so the -0.0 caveat never triggers and full bitwise
    // equality with the qdq oracle is the right expectation
    let _g = knobs();
    let mut rng = Rng::new(0xDE0);
    let (rows, cols) = (9usize, 13usize);
    let x: Vec<f32> = (0..rows * cols).map(|_| rng.normal_f32(0.0, 1.0).abs() + 0.25).collect();
    for gran in [Granularity::PerTensor, Granularity::PerToken] {
        let pol = TensorPolicy::new(8, gran);
        let packed = quant::pack_acts_i8(&x, rows, cols, pol);
        let deq = quant::dequant_acts_i8(&packed);
        let fake = quant::qdq_copy(&x, rows, cols, pol);
        assert_eq!(bits(&deq), bits(&fake), "{gran:?}: padded dequant != qdq");
    }
}

#[test]
fn knob_env_introspection_agree() {
    let _g = knobs();
    kernels::set_simd(Some(false));
    assert!(!kernels::simd_active());
    assert!(!native::simd_active());
    if kernels::simd_supported() {
        kernels::set_simd(Some(true));
        assert!(kernels::simd_active() && native::simd_active());
        kernels::with_simd(false, || assert!(!native::simd_active()));
        assert!(kernels::simd_active(), "with_simd did not restore the forced-on state");
    }
}

#[test]
fn native_forward_bitwise_invariant_across_simd_and_threads() {
    // the end-to-end contract: a full quantized forward (int8 fast path
    // AND f32 qdq path) produces identical bits whether the vector
    // microkernels or the scalar lane emulation run, at any thread count
    let _g = knobs();
    let rt = Runtime::native();
    let model = rt.model("micro").unwrap().clone();
    let state = init_state(&model, 57);
    let mut it = BatchIter::new(CorpusCfg::train_default(model.vocab), model.batch, model.seq);
    let b = it.next_batch();
    let mask = vec![1.0f32; model.batch * model.seq];
    for spec in ["base", "w8a8", "w4_pc+a8_ptok_asym"] {
        let recipe = QuantRecipe::parse(spec).unwrap();
        kernels::set_threads(1);
        let scalar = kernels::with_simd(false, || {
            rt.eval_step(&model, &recipe, &state.params, &b.x, &b.y, &mask).unwrap()
        });
        kernels::set_threads(7);
        kernels::force_parallel(true);
        let simd = kernels::with_simd(true, || {
            rt.eval_step(&model, &recipe, &state.params, &b.x, &b.y, &mask).unwrap()
        });
        kernels::force_parallel(false);
        assert_eq!(
            bits(&scalar.per_pos),
            bits(&simd.per_pos),
            "{spec}: scalar@1t != simd@7t"
        );
        assert_eq!(scalar.mean_nll.to_bits(), simd.mean_nll.to_bits(), "{spec}");
    }
}
