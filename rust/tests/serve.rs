//! Serve-engine determinism suite — the bitwise-equality contracts of the
//! KV-cached decode path:
//!
//! * **KV decode == full re-forward** — for every serve-eligible recipe,
//!   feeding a sequence one position at a time through the engine's K/V
//!   ring buffers produces, at every step, the same logits bit pattern as
//!   the training backend's full-context forward over the whole sequence —
//!   at every thread count, with SIMD on or off.
//! * **Load-time PTQ == train-time eval** — packing a trained w8a8g8
//!   checkpoint's weights once at engine construction reproduces the
//!   train-time `forward_only()` evaluation bit for bit, under both
//!   settings of the int8-accumulator knob.
//! * **Generation is replayable** — greedy and top-k token streams are
//!   identical across thread counts and SIMD settings.
//!
//! Tests mutate process-wide knobs, so they serialize on a mutex and
//! restore via RAII guards (same pattern as `tests/int8.rs`).

use std::sync::{Mutex, MutexGuard};

use qpretrain::backend::{kernels, native};
use qpretrain::config::{QuantRecipe, TrainHp};
use qpretrain::data::{BatchIter, CorpusCfg};
use qpretrain::model::init_state;
use qpretrain::runtime::{ModelInfo, Runtime};
use qpretrain::serve::{Engine, Request, Sampler, ServeCfg};
use qpretrain::util::rng::Rng;

static KNOBS: Mutex<()> = Mutex::new(());

struct Knobs(#[allow(dead_code)] MutexGuard<'static, ()>);

fn knobs() -> Knobs {
    Knobs(KNOBS.lock().unwrap_or_else(|e| e.into_inner()))
}

impl Drop for Knobs {
    fn drop(&mut self) {
        kernels::force_parallel(false);
        kernels::set_threads(0);
        native::set_int8_gemm(native::int8_env_default());
    }
}

fn bits(v: &[f32]) -> Vec<u32> {
    v.iter().map(|x| x.to_bits()).collect()
}

/// Small but structurally honest model: 2 layers, 2 heads, enough vocab
/// that sampling has real choices. batch * seq tokens feed the full
/// forward; each batch row is decoded independently.
fn serve_model() -> ModelInfo {
    native::model_info("sv", 2, 32, 2, 48, 10, 3)
}

fn random_tokens(model: &ModelInfo, seed: u64) -> Vec<i32> {
    let mut rng = Rng::new(seed);
    (0..model.batch * model.seq)
        .map(|_| rng.below(model.vocab) as i32)
        .collect()
}

/// Serve-eligible recipes spanning the dispatch space: fp32, the packed
/// int8 fast path, and a per-token asymmetric activation recipe that must
/// take the qdq fallback.
const RECIPES: [&str; 3] = ["base", "w8a8", "w8_pc+a8_ptok_asym"];

#[test]
fn kv_decode_matches_full_forward_across_knobs() {
    let _g = knobs();
    let model = serve_model();
    let state = init_state(&model, 41);
    let x = random_tokens(&model, 77);
    let t = model.seq;

    for spec in RECIPES {
        let recipe = QuantRecipe::parse(spec).unwrap().forward_only();
        let mut reference: Option<Vec<u32>> = None;
        for threads in [1usize, 7] {
            for simd in [false, true] {
                let got = kernels::with_threads(threads, || {
                    kernels::with_simd(simd, || {
                        let full =
                            native::forward_logits(&model, &state.params, &x, &recipe).unwrap();
                        let mut eng =
                            Engine::new(&model, &state.params, &recipe, ServeCfg::new(2, t))
                                .unwrap();
                        let mut decoded = Vec::with_capacity(full.len());
                        for b in 0..model.batch {
                            decoded
                                .extend(eng.decode_logits(&x[b * t..(b + 1) * t]).unwrap());
                        }
                        assert_eq!(
                            bits(&decoded),
                            bits(&full),
                            "{spec}: KV decode != full forward at threads={threads} simd={simd}"
                        );
                        bits(&full)
                    })
                });
                match &reference {
                    None => reference = Some(got),
                    Some(r) => assert_eq!(
                        &got, r,
                        "{spec}: logits drifted at threads={threads} simd={simd}"
                    ),
                }
            }
        }
    }
}

#[test]
fn load_time_ptq_matches_trained_eval_under_both_accumulators() {
    let _g = knobs();
    let rt = Runtime::native();
    let model = rt.model("micro").unwrap().clone();
    // short w8a8g8 training run: the checkpoint whose serving we validate
    let hp = TrainHp {
        steps: 4,
        eval_every: 0,
        log_every: usize::MAX,
        ..TrainHp::default()
    };
    let cfg = qpretrain::train::TrainCfg::new("micro", QuantRecipe::parse("w8a8g8").unwrap(), hp);
    let r = qpretrain::train::train(&rt, &cfg).unwrap();
    let params = &r.final_state.params;
    let recipe = QuantRecipe::parse("w8a8g8").unwrap().forward_only();

    let x = random_tokens(&model, 5150);
    let (t, v) = (model.seq, model.vocab);
    let rows = [0usize, model.batch - 1];
    for int8 in [true, false] {
        native::set_int8_gemm(int8);
        let full = native::forward_logits(&model, params, &x, &recipe).unwrap();
        let mut eng = Engine::new(&model, params, &recipe, ServeCfg::new(1, t)).unwrap();
        assert_eq!(
            eng.packed_linears(),
            4 * model.n_layer,
            "w8a8g8 forward recipe must keep every block linear packed"
        );
        for &b in &rows {
            let dec = eng.decode_logits(&x[b * t..(b + 1) * t]).unwrap();
            assert_eq!(
                bits(&dec),
                bits(&full[b * t * v..(b + 1) * t * v]),
                "trained w8a8g8 checkpoint: load-time pack != train-time eval \
                 (row {b}, int8={int8})"
            );
        }
    }
}

#[test]
fn generate_streams_identical_across_knobs() {
    let _g = knobs();
    let model = serve_model();
    let state = init_state(&model, 2718);
    let mut it = BatchIter::new(CorpusCfg::train_default(model.vocab), 1, 4);
    let prompt = it.next_batch().x;

    for spec in ["base", "w8a8"] {
        let recipe = QuantRecipe::parse(spec).unwrap().forward_only();
        for sampler in [
            Sampler::Greedy,
            Sampler::TopK {
                temperature: 0.8,
                k: 8,
            },
        ] {
            let mut reference: Option<Vec<i32>> = None;
            for threads in [1usize, 7] {
                for simd in [false, true] {
                    let toks = kernels::with_threads(threads, || {
                        kernels::with_simd(simd, || {
                            let mut eng = Engine::new(
                                &model,
                                &state.params,
                                &recipe,
                                ServeCfg::new(1, model.seq),
                            )
                            .unwrap();
                            eng.generate(&prompt, 5, sampler, 99).unwrap()
                        })
                    });
                    assert_eq!(toks.len(), 5);
                    match &reference {
                        None => reference = Some(toks),
                        Some(r) => assert_eq!(
                            &toks, r,
                            "{spec}: {sampler:?} stream drifted at threads={threads} simd={simd}"
                        ),
                    }
                }
            }
        }
    }
}

#[test]
fn batched_run_equals_sequential_across_knobs() {
    let _g = knobs();
    let model = serve_model();
    let state = init_state(&model, 314);
    let recipe = QuantRecipe::parse("w8a8").unwrap().forward_only();
    let mut rng = Rng::new(8);
    let reqs: Vec<Request> = (0..6)
        .map(|i| Request {
            prompt: (0..1 + i % 4)
                .map(|_| rng.below(model.vocab) as i32)
                .collect(),
            max_new: 3 + i % 3,
            sampler: if i % 2 == 0 {
                Sampler::Greedy
            } else {
                Sampler::TopK {
                    temperature: 1.1,
                    k: 6,
                }
            },
            seed: 1000 + i as u64,
        })
        .collect();

    let run_with = |max_batch: usize| {
        let mut eng =
            Engine::new(&model, &state.params, &recipe, ServeCfg::new(max_batch, model.seq))
                .unwrap();
        let (done, stats) = eng.run(&reqs).unwrap();
        (done.into_iter().map(|c| c.generated).collect::<Vec<_>>(), stats)
    };

    let (sequential, _) = run_with(1);
    for threads in [1usize, 7] {
        let (batched, stats) = kernels::with_threads(threads, || run_with(4));
        assert_eq!(
            batched, sequential,
            "continuous batching changed token streams at threads={threads}"
        );
        assert!(stats.peak_batch >= 4, "batching never filled the batch");
    }
}
