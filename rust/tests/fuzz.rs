//! Seeded byte-mutation fuzz loops over every hand-rolled parser surface:
//! `util::json::parse`, the `QuantRecipe` codec, and the `.npy` header
//! reader. Each loop takes a small corpus of *valid* inputs, applies 10k
//! seeded random mutations (byte flips, truncations, splices, insertions),
//! and asserts the invariant the parsers promise: malformed input returns
//! `Err`, it never panics, overflows, or indexes out of bounds.
//!
//! The mutations are driven by the repo's own deterministic `util::rng`,
//! so a failure reproduces exactly from the printed seed — no external
//! fuzzing framework, no corpus files on disk.

use qpretrain::config::QuantRecipe;
use qpretrain::dist::frame::{self, Frame, WireNode, WireTensor, WireView};
use qpretrain::dist::socket::{decode_handshake, encode_handshake, Handshake, HS_VERSION};
use qpretrain::util::json;
use qpretrain::util::npy;
use qpretrain::util::rng::Rng;

const ROUNDS: usize = 10_000;

/// Apply one seeded mutation batch to `base`: 1..=8 point mutations drawn
/// from byte flips, random-byte overwrites, insertions, deletions, and
/// tail truncation.
fn mutate(base: &[u8], rng: &mut Rng) -> Vec<u8> {
    let mut buf = base.to_vec();
    for _ in 0..1 + rng.below(8) {
        if buf.is_empty() {
            buf.push(rng.below(256) as u8);
            continue;
        }
        match rng.below(5) {
            0 => {
                // flip one bit
                let i = rng.below(buf.len());
                buf[i] ^= 1 << rng.below(8);
            }
            1 => {
                // overwrite with an arbitrary byte
                let i = rng.below(buf.len());
                buf[i] = rng.below(256) as u8;
            }
            2 => {
                // insert an arbitrary byte
                let i = rng.below(buf.len() + 1);
                buf.insert(i, rng.below(256) as u8);
            }
            3 => {
                // delete one byte
                let i = rng.below(buf.len());
                buf.remove(i);
            }
            _ => {
                // truncate the tail
                let i = rng.below(buf.len());
                buf.truncate(i);
            }
        }
    }
    buf
}

/// Seed corpus of valid JSON exercising every syntactic form the parser
/// accepts (nesting, escapes, exponents, unicode, literals).
fn json_corpus() -> Vec<&'static str> {
    vec![
        r#"{"a": 1, "b": [true, false, null], "c": {"d": -2.5e-3}}"#,
        r#"[{"k": "v\n\t\"\\é"}, [], {}, [1e10, -0.5, 12345678901234]]"#,
        r#"{"bench": "serve", "results": [{"name": "decode", "batch": "4"}]}"#,
        r#""just a string with A escapes""#,
        r#"[[[[[[[[1]]]]]]]]"#,
    ]
}

#[test]
fn fuzz_json_parser_never_panics() {
    let corpus = json_corpus();
    let mut rng = Rng::new(0xF00D_0001);
    for round in 0..ROUNDS {
        let base = corpus[round % corpus.len()].as_bytes();
        let mutated = mutate(base, &mut rng);
        // the parser takes &str; lossy conversion keeps arbitrary bytes in
        // play while exercising the same entry point the repo uses
        let text = String::from_utf8_lossy(&mutated);
        let _ = json::parse(&text); // Ok or Err both fine; must not panic
    }
}

#[test]
fn fuzz_json_roundtrip_survives_reserialization() {
    // mutated input that *does* parse must reserialize to JSON that parses
    // back to the same value (codec closure under mutation)
    let corpus = json_corpus();
    let mut rng = Rng::new(0xF00D_0002);
    let mut accepted = 0usize;
    for round in 0..ROUNDS {
        let base = corpus[round % corpus.len()].as_bytes();
        let text = String::from_utf8_lossy(&mutate(base, &mut rng)).into_owned();
        if let Ok(v) = json::parse(&text) {
            accepted += 1;
            let back = json::parse(&v.to_json())
                .unwrap_or_else(|e| panic!("reserialization of {text:?} failed: {e}"));
            assert_eq!(back.to_json(), v.to_json(), "roundtrip drift on {text:?}");
        }
    }
    // mutations are mostly destructive, but 1-bit flips in string bodies
    // keep plenty of inputs valid; make sure the loop actually tested some
    assert!(accepted > 50, "only {accepted} mutated inputs parsed");
}

#[test]
fn fuzz_recipe_codec_never_panics() {
    let corpus = [
        "base",
        "w8a8",
        "w8a8g8",
        "w4_pc+a8_ptok+g8_ptok+m1_8_pt+m2_8_pc",
        "a8_ptok_asym",
        "g8_pt_actgrad",
        "w8_pt+a8_pt+g8_pt_actgrad",
    ];
    let mut rng = Rng::new(0xF00D_0003);
    for round in 0..ROUNDS {
        let base = corpus[round % corpus.len()].as_bytes();
        let text = String::from_utf8_lossy(&mutate(base, &mut rng)).into_owned();
        if let Ok(r) = QuantRecipe::parse(&text) {
            // parse -> label -> parse must be a fixed point: the label is
            // the recipe's canonical spelling
            let label = r.label();
            let back = QuantRecipe::parse(&label)
                .unwrap_or_else(|e| panic!("canonical label {label:?} failed to parse: {e}"));
            assert_eq!(back.label(), label, "label not canonical for {text:?}");
        }
    }
}

/// Valid in-memory npy v1.0 bytes (mirrors `npy::write_f32`'s layout).
fn npy_bytes(shape: &[usize], data: &[f32]) -> Vec<u8> {
    let shape_str = match shape.len() {
        1 => format!("({},)", shape[0]),
        _ => format!(
            "({})",
            shape.iter().map(|d| d.to_string()).collect::<Vec<_>>().join(", ")
        ),
    };
    let mut header =
        format!("{{'descr': '<f4', 'fortran_order': False, 'shape': {shape_str}, }}");
    let unpadded = 6 + 4 + header.len() + 1;
    let pad = (64 - unpadded % 64) % 64;
    header.push_str(&" ".repeat(pad));
    header.push('\n');
    let mut buf = Vec::from(&b"\x93NUMPY"[..]);
    buf.extend_from_slice(&[1, 0]);
    buf.extend_from_slice(&(header.len() as u16).to_le_bytes());
    buf.extend_from_slice(header.as_bytes());
    for x in data {
        buf.extend_from_slice(&x.to_le_bytes());
    }
    buf
}

#[test]
fn fuzz_npy_parser_never_panics() {
    let data: Vec<f32> = (0..24).map(|i| i as f32 * 0.25 - 3.0).collect();
    let corpus = [
        npy_bytes(&[4, 6], &data),
        npy_bytes(&[24], &data),
        npy_bytes(&[2, 3, 4], &data),
        npy_bytes(&[1], &[0.0]),
    ];
    let mut rng = Rng::new(0xF00D_0004);
    for round in 0..ROUNDS {
        let base = &corpus[round % corpus.len()];
        let mutated = mutate(base, &mut rng);
        if let Ok(arr) = npy::parse_f32(&mutated) {
            // accepted arrays must be internally consistent: the element
            // count actually matches the parsed shape
            let n: usize = arr.shape.iter().product();
            assert_eq!(arr.data.len(), n, "shape/data mismatch after mutation");
        }
    }
}

/// Valid gradient-frame corpus for the dist wire codec: f32-only, mixed
/// f32/i8 (per-tensor and per-row scales), overlap-style multi-part step
/// framing (part k of n), and a minimal empty frame.
fn frame_corpus() -> Vec<Vec<u8>> {
    let f32_node = WireNode {
        level: 2,
        idx: 0,
        loss: 1.5,
        tensors: vec![
            WireTensor::F32((0..24).map(|i| i as f32 * 0.5 - 6.0).collect()),
            WireTensor::F32(vec![f32::NAN, -0.0, f32::INFINITY]),
        ],
    };
    let i8_node = WireNode {
        level: 1,
        idx: 1,
        loss: -2.25,
        tensors: vec![
            WireTensor::I8(vec![WireView {
                rows: 3,
                cols: 4,
                scales: vec![0.125],
                codes: (0..12).map(|i| (i as i8) - 6).collect(),
            }]),
            WireTensor::I8(vec![
                WireView {
                    rows: 2,
                    cols: 5,
                    scales: vec![0.5, 0.25],
                    codes: (0..10).map(|i| (i as i8) * 11 - 50).collect(),
                },
                WireView {
                    rows: 1,
                    cols: 1,
                    scales: vec![1.0],
                    codes: vec![-128],
                },
            ]),
            WireTensor::F32(vec![0.0; 7]),
        ],
    };
    vec![
        frame::encode(&Frame {
            step: 3,
            rank: 0,
            dp: 2,
            leaves: 4,
            part: 0,
            parts: 1,
            nodes: vec![f32_node.clone()],
        }),
        frame::encode(&Frame {
            step: u64::MAX,
            rank: 2,
            dp: 3,
            leaves: 7,
            part: 0,
            parts: 1,
            nodes: vec![f32_node.clone(), i8_node.clone()],
        }),
        // overlap-style multi-frame step: one cover node per frame, with
        // part/parts framing in the middle and at the end of the shipment
        frame::encode(&Frame {
            step: 12,
            rank: 1,
            dp: 3,
            leaves: 8,
            part: 1,
            parts: 3,
            nodes: vec![i8_node],
        }),
        frame::encode(&Frame {
            step: 12,
            rank: 1,
            dp: 3,
            leaves: 8,
            part: 2,
            parts: 3,
            nodes: vec![f32_node],
        }),
        frame::encode(&Frame {
            step: 1,
            rank: 1,
            dp: 2,
            leaves: 2,
            part: 0,
            parts: 1,
            nodes: vec![],
        }),
    ]
}

#[test]
fn fuzz_frame_codec_never_panics() {
    let corpus = frame_corpus();
    let mut rng = Rng::new(0xF00D_0005);
    let mut accepted = 0usize;
    for round in 0..ROUNDS {
        let base = &corpus[round % corpus.len()];
        // the FNV-64 integrity check rejects nearly every mutation, so the
        // accept path is pinned deterministically by interleaving pristine
        // frames into the stream (round % 251 == 0)
        let mutated = if round % 251 == 0 {
            base.clone()
        } else {
            mutate(base, &mut rng)
        };
        // decode must never panic; and the codec is canonical, so any
        // accepted byte string must re-encode to exactly itself — a
        // mutation either breaks the frame (Err) or yields a different
        // valid frame, never two spellings of the same frame
        if let Ok(f) = frame::decode(&mutated) {
            accepted += 1;
            assert_eq!(
                frame::encode(&f),
                mutated,
                "accepted frame bytes must be the canonical encoding"
            );
        }
    }
    assert!(
        accepted >= ROUNDS / 251,
        "accept path untested ({accepted} accepted)"
    );
}

/// Valid `QDGH` socket-join handshakes: the dp-2 common case, a
/// higher-rank worker, an empty recipe label, and a long composite one.
fn handshake_corpus() -> Vec<Vec<u8>> {
    [
        Handshake {
            version: HS_VERSION,
            dp: 2,
            rank: 1,
            nonce: 0xDEAD_BEEF_0BAD_F00D,
            recipe: "w8a8g8".to_string(),
        },
        Handshake {
            version: HS_VERSION,
            dp: 7,
            rank: 6,
            nonce: 1,
            recipe: "base".to_string(),
        },
        Handshake {
            version: HS_VERSION,
            dp: 2,
            rank: 1,
            nonce: 0,
            recipe: String::new(),
        },
        Handshake {
            version: HS_VERSION,
            dp: 3,
            rank: 2,
            nonce: u64::MAX,
            recipe: "w4_pc+a8_ptok+g8_ptok+m1_8_pt+m2_8_pc".to_string(),
        },
    ]
    .iter()
    .map(encode_handshake)
    .collect()
}

/// The socket transport's `QDGH` join handshake under the same 10k-round
/// mutation loop: truncations, version skews, oversized recipe-length
/// prefixes and flipped magic must all return `Err` (never panic, never
/// over-index), and any *accepted* byte string must re-encode to exactly
/// itself — the codec has one spelling per handshake.
#[test]
fn fuzz_handshake_codec_never_panics() {
    let corpus = handshake_corpus();
    let mut rng = Rng::new(0xF00D_0006);
    let mut accepted = 0usize;
    for round in 0..ROUNDS {
        let base = &corpus[round % corpus.len()];
        // unlike the frame codec there is no checksum, so plenty of
        // mutations stay valid; the pristine interleave still pins the
        // accept path deterministically
        let mutated = if round % 251 == 0 {
            base.clone()
        } else {
            mutate(base, &mut rng)
        };
        if let Ok(h) = decode_handshake(&mutated) {
            accepted += 1;
            assert_eq!(h.version, HS_VERSION, "only the spoken version is accepted");
            assert_eq!(
                encode_handshake(&h),
                mutated,
                "accepted handshake bytes must be the canonical encoding"
            );
        }
    }
    assert!(
        accepted >= ROUNDS / 251,
        "accept path untested ({accepted} accepted)"
    );
}

#[test]
fn fuzz_unmutated_corpus_is_valid() {
    // guard the fuzz loops against a silently-broken corpus: every seed
    // input must parse cleanly, otherwise the loops only test garbage
    for s in json_corpus() {
        json::parse(s).unwrap();
    }
    let data: Vec<f32> = (0..6).map(|i| i as f32).collect();
    let arr = npy::parse_f32(&npy_bytes(&[2, 3], &data)).unwrap();
    assert_eq!(arr.shape, vec![2, 3]);
    assert_eq!(arr.data, data);
    QuantRecipe::parse("w4_pc+a8_ptok+g8_ptok+m1_8_pt+m2_8_pc").unwrap();
    for bytes in frame_corpus() {
        let f = frame::decode(&bytes).unwrap();
        assert_eq!(frame::encode(&f), bytes, "frame corpus must be canonical");
    }
    for bytes in handshake_corpus() {
        let h = decode_handshake(&bytes).unwrap();
        assert_eq!(
            encode_handshake(&h),
            bytes,
            "handshake corpus must be canonical"
        );
    }
}
