//! Bit-exactness property suite for the parallel kernel subsystem
//! (`backend::kernels`) against the retained serial reference
//! (`backend::math`), extending the in-repo quickcheck harness.
//!
//! The whole quantization reproduction rests on bit-exact accumulation
//! (the golden fixtures chain back to the jnp oracle), so the parallel
//! kernels are required to be *identical* — not approximately equal — to
//! the serial path, at every thread count (the sweep pins {1,2,3,7,16}),
//! across randomized shapes including degenerate ones (m=1, k=1,
//! dimensions that are not multiples of the K panel or of the per-thread
//! span). The fixed-shape tree reductions (layernorm dw/db, embedding
//! scatter, grad norm) are additionally checked for repeated-run
//! stability, and the persistent worker pool gets a reuse/stress case
//! (thousands of small forced-parallel dispatches at churning thread
//! counts) to catch handoff races that a single dispatch would never hit.
//!
//! Tests here mutate the process-wide thread knobs, so they serialize on a
//! mutex and restore the knobs via an RAII guard (panic-safe).

use std::sync::{Mutex, MutexGuard};

use qpretrain::backend::{kernels, math};
use qpretrain::util::quickcheck::{check, gen, Config};
use qpretrain::util::rng::Rng;

static KNOBS: Mutex<()> = Mutex::new(());

/// Serializes the test, pins the thread count, and forces the parallel
/// path (so tiny property-test shapes exercise real forking); both knobs
/// are restored on drop even if the property panics.
struct Forced(#[allow(dead_code)] MutexGuard<'static, ()>);

fn forced(threads: usize) -> Forced {
    let g = KNOBS.lock().unwrap_or_else(|e| e.into_inner());
    kernels::set_threads(threads);
    kernels::force_parallel(true);
    Forced(g)
}

impl Drop for Forced {
    fn drop(&mut self) {
        kernels::force_parallel(false);
        kernels::set_threads(0);
    }
}

fn cfg(cases: usize) -> Config {
    Config {
        cases,
        ..Config::default()
    }
}

fn bits(v: &[f32]) -> Vec<u32> {
    v.iter().map(|x| x.to_bits()).collect()
}

/// Random matmul problem: dims straddle the K panel and thread-span
/// boundaries, values include the adversarial quant patterns.
fn gen_mm(rng: &mut Rng) -> (Vec<f32>, Vec<f32>, usize, usize, usize, usize) {
    let m = rng.range(1, 41);
    let k = if rng.bool_with(0.25) {
        rng.range(kernels::K_PANEL - 2, kernels::K_PANEL * 2 + 3)
    } else {
        rng.range(1, 41)
    };
    let n = rng.range(1, 41);
    let mut a = gen::f32_vec_adversarial(rng, m * k);
    a.resize(m * k, 0.0);
    let mut b = gen::f32_vec_adversarial(rng, k * n);
    b.resize(k * n, 0.0);
    let threads = rng.range(2, 9);
    (a, b, m, k, n, threads)
}

fn mm_case_identical(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> bool {
    // plain + transposed variants
    if bits(&kernels::matmul(a, b, m, k, n)) != bits(&math::matmul(a, b, m, k, n)) {
        return false;
    }
    // nt: b reinterpreted as (n x k) against an (m x k) a — reuse a as the
    // left operand and carve a right operand of n*k elements from b/a
    let bt: Vec<f32> = b.iter().chain(a.iter()).cycle().take(n * k).copied().collect();
    if bits(&kernels::matmul_nt(a, &bt, m, k, n)) != bits(&math::matmul_nt(a, &bt, m, k, n)) {
        return false;
    }
    // tn: a is (m x k), b must be (m x n)
    let bn: Vec<f32> = b.iter().chain(a.iter()).cycle().take(m * n).copied().collect();
    if bits(&kernels::matmul_tn(a, &bn, m, k, n)) != bits(&math::matmul_tn(a, &bn, m, k, n)) {
        return false;
    }
    // accumulating forms on a non-zero initial c
    let mut c1: Vec<f32> = (0..m * n).map(|i| (i as f32 * 0.13).sin()).collect();
    let mut c2 = c1.clone();
    kernels::matmul_acc(&mut c1, a, b, m, k, n);
    math::matmul_acc(&mut c2, a, b, m, k, n);
    if bits(&c1) != bits(&c2) {
        return false;
    }
    let mut c1: Vec<f32> = (0..k * n).map(|i| (i as f32 * 0.29).cos()).collect();
    let mut c2 = c1.clone();
    kernels::matmul_tn_acc(&mut c1, a, &bn, m, k, n);
    math::matmul_tn_acc(&mut c2, a, &bn, m, k, n);
    bits(&c1) == bits(&c2)
}

#[test]
fn prop_matmul_parallel_bit_identical_to_serial() {
    let _guard = forced(4);
    check(cfg(120), gen_mm, |(a, b, m, k, n, threads)| {
        kernels::set_threads(*threads);
        mm_case_identical(a, b, *m, *k, *n)
    });
}

#[test]
fn degenerate_shapes_bit_identical() {
    let _guard = forced(4);
    let kp = kernels::K_PANEL;
    // m=1, k=1, n=1, and dims that are not multiples of the panel/span
    let shapes = [
        (1, 1, 1),
        (1, 7, 3),
        (3, 1, 7),
        (7, 3, 1),
        (2, kp, 5),
        (2, kp + 1, 5),
        (2, kp - 1, 5),
        (5, 2 * kp + 3, 9),
        (17, 5, 23), // rows indivisible by any thread count we pin
    ];
    let mut rng = Rng::new(0xDE6E);
    for &(m, k, n) in &shapes {
        let a = rng.normal_vec(m * k, 0.0, 1.0);
        let b = rng.normal_vec(k * n, 0.0, 1.0);
        for threads in [1, 2, 3, 7, 16] {
            kernels::set_threads(threads);
            assert!(
                mm_case_identical(&a, &b, m, k, n),
                "shape ({m},{k},{n}) at {threads} threads differs from serial"
            );
        }
    }
}

#[test]
fn pool_reuse_stress_many_small_dispatches() {
    // thousands of forced-parallel dispatches of tiny kernels, with the
    // thread count churning every call: exercises the persistent pool's
    // job handoff and barrier over and over (a handoff race — a lost
    // wakeup, a leaked job, a part run twice — shows up as a wrong result
    // or a hang here long before it would in a training run)
    let _guard = forced(4);
    let mut rng = Rng::new(0x9001);
    let (m, k, n) = (5, 9, 7);
    let a = rng.normal_vec(m * k, 0.0, 1.0);
    let b = rng.normal_vec(k * n, 0.0, 1.0);
    let want_mm = bits(&math::matmul(&a, &b, m, k, n));
    let (rows, d) = (6, 5);
    let x = rng.normal_vec(rows * d, 0.0, 1.0);
    let w = rng.normal_vec(d, 1.0, 0.1);
    let bias = rng.normal_vec(d, 0.0, 0.1);
    let want_ln = math::layer_norm_fwd(&x, &w, &bias, rows, d);
    for i in 0..2000 {
        kernels::set_threads(2 + (i % 7));
        assert_eq!(
            bits(&kernels::matmul(&a, &b, m, k, n)),
            want_mm,
            "dispatch {i}: matmul diverged"
        );
        let got = kernels::layer_norm_fwd(&x, &w, &bias, rows, d);
        assert_eq!(bits(&got.0), bits(&want_ln.0), "dispatch {i}: layernorm diverged");
    }
}

#[test]
fn prop_embed_scatter_bit_identical() {
    let _guard = forced(4);
    check(
        cfg(60),
        |rng| {
            let t = rng.range(1, 9);
            let b = rng.range(1, 5);
            let d = rng.range(1, 17);
            let v = rng.range(1, 33);
            let m = b * t;
            let x: Vec<i32> = (0..m).map(|_| rng.below(v) as i32).collect();
            let dh = rng.normal_vec(m * d, 0.0, 1.0);
            let threads = rng.range(2, 17);
            (x, dh, t, d, v, threads)
        },
        |(x, dh, t, d, v, threads)| {
            let (t, d, v) = (*t, *d, *v);
            let m = x.len();
            // nonzero starting accumulators: the wte grad already holds the
            // tied-head contribution when the scatter runs
            let mut wte1 = vec![0.05f32; v * d];
            let mut wpe1 = vec![-0.1f32; t * d];
            let mut wte2 = wte1.clone();
            let mut wpe2 = wpe1.clone();
            math::embed_scatter(&mut wte1, &mut wpe1, dh, x, m, t, d);
            kernels::set_threads(*threads);
            kernels::embed_scatter(&mut wte2, &mut wpe2, dh, x, m, t, d);
            bits(&wte1) == bits(&wte2) && bits(&wpe1) == bits(&wpe2)
        },
    );
}

#[test]
fn tree_reductions_thread_invariant_and_repeat_stable() {
    // rows/elements straddle the fixed block boundaries; every thread count
    // in {1,2,3,7,16} and every repeat must produce the serial bits
    let _guard = forced(1);
    let mut rng = Rng::new(0x7EE);

    // layernorm dw/db across multiple REDUCE_ROWS blocks
    let rows = math::REDUCE_ROWS * 2 + 17;
    let d = 33;
    let x = rng.normal_vec(rows * d, 0.0, 1.0);
    let w = rng.normal_vec(d, 1.0, 0.2);
    let b = rng.normal_vec(d, 0.0, 0.2);
    let dy = rng.normal_vec(rows * d, 0.0, 1.0);
    let (_, xhat, rstd) = math::layer_norm_fwd(&x, &w, &b, rows, d);
    let mut dw_ref = vec![0.0f32; d];
    let mut db_ref = vec![0.0f32; d];
    let dx_ref = math::layer_norm_bwd(&dy, &xhat, &rstd, &w, rows, d, &mut dw_ref, &mut db_ref);

    // grad-norm blocks straddle NORM_BLOCK
    let tensors = vec![
        rng.normal_vec(math::NORM_BLOCK + 123, 0.0, 1.0),
        rng.normal_vec(7, 0.0, 1.0),
        Vec::new(),
        rng.normal_vec(2 * math::NORM_BLOCK, 0.0, 0.5),
    ];
    let norm_ref = math::sq_norm(&tensors);

    // embedding scatter on a fixed case
    let (t, d2, v) = (8, 16, 24);
    let m = 4 * t;
    let toks: Vec<i32> = (0..m).map(|_| rng.below(v) as i32).collect();
    let dh = rng.normal_vec(m * d2, 0.0, 1.0);
    let mut wte_ref = vec![0.0f32; v * d2];
    let mut wpe_ref = vec![0.0f32; t * d2];
    math::embed_scatter(&mut wte_ref, &mut wpe_ref, &dh, &toks, m, t, d2);

    for threads in [1usize, 2, 3, 7, 16] {
        for rep in 0..3 {
            kernels::set_threads(threads);
            let mut dw = vec![0.0f32; d];
            let mut db = vec![0.0f32; d];
            let dx = kernels::layer_norm_bwd(&dy, &xhat, &rstd, &w, rows, d, &mut dw, &mut db);
            assert_eq!(bits(&dx), bits(&dx_ref), "dx at {threads} threads rep {rep}");
            assert_eq!(bits(&dw), bits(&dw_ref), "dw at {threads} threads rep {rep}");
            assert_eq!(bits(&db), bits(&db_ref), "db at {threads} threads rep {rep}");

            let norm = kernels::sq_norm(&tensors);
            assert_eq!(
                norm.to_bits(),
                norm_ref.to_bits(),
                "sq_norm at {threads} threads rep {rep}"
            );

            let mut wte = vec![0.0f32; v * d2];
            let mut wpe = vec![0.0f32; t * d2];
            kernels::embed_scatter(&mut wte, &mut wpe, &dh, &toks, m, t, d2);
            assert_eq!(bits(&wte), bits(&wte_ref), "wte at {threads} threads rep {rep}");
            assert_eq!(bits(&wpe), bits(&wpe_ref), "wpe at {threads} threads rep {rep}");
        }
    }
}

#[test]
fn prop_rowwise_kernels_bit_identical() {
    let _guard = forced(3);
    check(
        cfg(100),
        |rng| {
            let rows = rng.range(1, 24);
            let d = rng.range(1, 24);
            let mut x = gen::f32_vec_adversarial(rng, rows * d);
            x.resize(rows * d, 0.0);
            let w = (0..d).map(|_| rng.normal_f32(1.0, 0.3)).collect::<Vec<_>>();
            let b = (0..d).map(|_| rng.normal_f32(0.0, 0.3)).collect::<Vec<_>>();
            let dy = (0..rows * d).map(|_| rng.normal_f32(0.0, 1.0)).collect::<Vec<_>>();
            let threads = rng.range(2, 9);
            (x, w, b, dy, rows, d, threads)
        },
        |(x, w, b, dy, rows, d, threads)| {
            kernels::set_threads(*threads);
            let (rows, d) = (*rows, *d);
            let (y1, xh1, rs1) = kernels::layer_norm_fwd(x, w, b, rows, d);
            let (y2, xh2, rs2) = math::layer_norm_fwd(x, w, b, rows, d);
            if bits(&y1) != bits(&y2) || bits(&xh1) != bits(&xh2) || bits(&rs1) != bits(&rs2) {
                return false;
            }
            let mut dw1 = vec![0.1f32; d];
            let mut db1 = vec![-0.2f32; d];
            let mut dw2 = dw1.clone();
            let mut db2 = db1.clone();
            let dx1 = kernels::layer_norm_bwd(dy, &xh1, &rs1, w, rows, d, &mut dw1, &mut db1);
            let dx2 = math::layer_norm_bwd(dy, &xh2, &rs2, w, rows, d, &mut dw2, &mut db2);
            if bits(&dx1) != bits(&dx2) || bits(&dw1) != bits(&dw2) || bits(&db1) != bits(&db2) {
                return false;
            }
            if bits(&kernels::gelu(x)) != bits(&math::gelu(x)) {
                return false;
            }
            if bits(&kernels::gelu_bwd(x, dy)) != bits(&math::gelu_bwd(x, dy)) {
                return false;
            }
            let mut a1 = vec![0.3f32; d];
            let mut a2 = a1.clone();
            kernels::col_sum_acc(&mut a1, x, rows, d);
            math::col_sum_acc(&mut a2, x, rows, d);
            bits(&a1) == bits(&a2)
        },
    );
}

#[test]
fn prop_cross_entropy_thread_count_invariant() {
    // no serial twin in `math`: the reference is the same kernel pinned to
    // one thread
    let _guard = forced(1);
    check(
        cfg(80),
        |rng| {
            let m = rng.range(1, 16);
            let v = rng.range(2, 48);
            let mut logits = gen::f32_vec_adversarial(rng, m * v);
            logits.resize(m * v, 0.0);
            let y: Vec<i32> = (0..m).map(|_| rng.below(v) as i32).collect();
            let threads = rng.range(2, 9);
            (logits, y, m, v, threads)
        },
        |(logits, y, m, v, threads)| {
            kernels::set_threads(1);
            let (pp1, pr1) = kernels::nll_rows(logits, y, *m, *v);
            let only1 = kernels::nll_only(logits, y, *m, *v);
            kernels::set_threads(*threads);
            let (pp2, pr2) = kernels::nll_rows(logits, y, *m, *v);
            let only2 = kernels::nll_only(logits, y, *m, *v);
            bits(&pp1) == bits(&pp2) && bits(&pr1) == bits(&pr2) && bits(&only1) == bits(&only2)
        },
    );
}

#[test]
fn add_assign_and_bias_add_match_serial_loops() {
    let _guard = forced(5);
    let mut rng = Rng::new(7);
    let (rows, cols) = (19, 13);
    let x = rng.normal_vec(rows * cols, 0.0, 1.0);
    let bias = rng.normal_vec(cols, 0.0, 1.0);

    let mut a1 = x.clone();
    kernels::bias_add(&mut a1, &bias, rows, cols);
    let mut a2 = x.clone();
    for r in 0..rows {
        for c in 0..cols {
            a2[r * cols + c] += bias[c];
        }
    }
    assert_eq!(bits(&a1), bits(&a2));

    let other = rng.normal_vec(rows * cols, 0.0, 1.0);
    let mut b1 = x.clone();
    kernels::add_assign(&mut b1, &other);
    let mut b2 = x;
    for (p, q) in b2.iter_mut().zip(other.iter()) {
        *p += q;
    }
    assert_eq!(bits(&b1), bits(&b2));
}

#[test]
fn thread_count_sweep_identical_results() {
    // one moderately sized problem, every thread count 1..=8 plus an
    // oversubscribed count: all results bit-identical
    let _guard = forced(1);
    let mut rng = Rng::new(0xABCD);
    let (m, k, n) = (23, 70, 31);
    let a = rng.normal_vec(m * k, 0.0, 1.0);
    let b = rng.normal_vec(k * n, 0.0, 1.0);
    kernels::set_threads(1);
    let reference = bits(&kernels::matmul(&a, &b, m, k, n));
    for threads in [2, 3, 4, 5, 6, 7, 8, 64] {
        kernels::set_threads(threads);
        assert_eq!(
            bits(&kernels::matmul(&a, &b, m, k, n)),
            reference,
            "{threads} threads changed the result"
        );
    }
}
