//! Native-backend correctness tests (run on every default build; no
//! artifacts, no Python, no PJRT):
//!
//! * end-to-end learning: loss decreases over 50 steps on the `micro` model
//!   with a strictly decreasing smoothed (windowed) curve;
//! * fake-quant injection is bit-for-bit `quant::qdq`: evaluating a latent
//!   checkpoint under "w_pc"/"w_pt" equals evaluating host-side qdq'd
//!   weights under "base";
//! * divergence detection fires on an exploding configuration;
//! * the paper's qualitative orderings (w2 < w8; m2 per-tensor 8-bit
//!   unstable) reproduce natively.

use std::sync::Mutex;

use qpretrain::config::{Granularity, QuantRecipe, TensorPolicy, TrainHp};
use qpretrain::data::{BatchIter, CorpusCfg};
use qpretrain::model::init_state;
use qpretrain::runtime::Runtime;
use qpretrain::train::{train, TrainCfg};

/// Serializes every test that either flips the process-wide int8-GEMM
/// switch or trains a recipe whose dispatch that switch decides (w8a8):
/// unlike the thread knobs, the int8 switch changes *results*, so a
/// concurrent flip mid-run would make a loss curve a nondeterministic
/// hybrid of the two paths.
static INT8_KNOB: Mutex<()> = Mutex::new(());

fn hp(steps: usize) -> TrainHp {
    TrainHp {
        steps,
        eval_every: steps,
        eval_batches: 2,
        log_every: usize::MAX,
        ..TrainHp::default()
    }
}

fn recipe(s: &str) -> QuantRecipe {
    QuantRecipe::parse(s).unwrap()
}

#[test]
fn native_train_loss_decreases_with_smooth_curve() {
    let rt = Runtime::native();
    let cfg = TrainCfg::new("micro", QuantRecipe::none(), hp(50));
    let r = train(&rt, &cfg).unwrap();
    assert!(!r.diverged, "baseline diverged");
    assert_eq!(r.losses.len(), 50);
    // init loss ~ ln(V): the model starts at the uniform predictor
    let uniform = (64f64).ln();
    assert!(
        (r.losses[0] - uniform).abs() < 0.3,
        "init loss {} vs ln(64) {}",
        r.losses[0],
        uniform
    );
    // smoothed curve strictly decreasing (10-step window means)
    let means = r.window_means(10);
    assert_eq!(means.len(), 5);
    for w in means.windows(2) {
        assert!(
            w[1] < w[0],
            "smoothed loss not strictly decreasing: {means:?}"
        );
    }
    // and meaningfully so
    assert!(
        r.final_loss() < r.losses[0] - 1.0,
        "only learned {:.3} -> {:.3}",
        r.losses[0],
        r.final_loss()
    );
    // validation ran and is finite
    assert!(r.final_val_loss().is_finite());
}

#[test]
fn forward_fake_quant_matches_qdq_bit_for_bit() {
    let rt = Runtime::native();
    let model = rt.model("micro").unwrap().clone();
    let state = init_state(&model, 42);
    let mut it = BatchIter::new(
        CorpusCfg::train_default(model.vocab),
        model.batch,
        model.seq,
    );
    let b = it.next_batch();
    let mask = vec![1.0f32; model.batch * model.seq];

    for (spec, gran, bits) in [
        ("w8_pc", Granularity::PerChannel, 8u32),
        ("w4_pc", Granularity::PerChannel, 4),
        ("w8_pt", Granularity::PerTensor, 8),
    ] {
        // latent weights through the quantized forward...
        let latent = rt
            .eval_step(&model, &recipe(spec), &state.params, &b.x, &b.y, &mask)
            .unwrap();
        // ...must equal host-side qdq'd weights through the base forward
        let mut qstate = state.clone();
        qpretrain::ptq::quantize_weights(&mut qstate, &model, TensorPolicy::new(bits, gran));
        let host = rt
            .eval_step(&model, &QuantRecipe::none(), &qstate.params, &b.x, &b.y, &mask)
            .unwrap();
        assert_eq!(
            latent.per_pos, host.per_pos,
            "{spec}: native injection differs from quant::qdq"
        );
        assert_eq!(latent.mean_nll, host.mean_nll);
    }
}

#[test]
fn activation_quant_converges_to_base_at_high_bits() {
    // many bits -> vanishing quantization error; the placement-only
    // fed-1.0 form (legacy "a_ptok", qmax 1.0) -> visible error
    let rt = Runtime::native();
    let model = rt.model("micro").unwrap().clone();
    let state = init_state(&model, 17);
    let mut it = BatchIter::new(
        CorpusCfg::train_default(model.vocab),
        model.batch,
        model.seq,
    );
    let b = it.next_batch();
    let mask = vec![1.0f32; model.batch * model.seq];
    let base = rt
        .eval_step(&model, &QuantRecipe::none(), &state.params, &b.x, &b.y, &mask)
        .unwrap();
    let hi = rt
        .eval_step(&model, &recipe("a24_ptok"), &state.params, &b.x, &b.y, &mask)
        .unwrap();
    assert!(
        (hi.mean_nll - base.mean_nll).abs() < 1e-3,
        "24-bit a_ptok {} vs base {}",
        hi.mean_nll,
        base.mean_nll
    );
    let lo = rt
        .eval_step(&model, &recipe("a_ptok"), &state.params, &b.x, &b.y, &mask)
        .unwrap();
    assert!(
        (lo.mean_nll - base.mean_nll).abs() > 1e-4,
        "1-bit-range activations should visibly perturb the forward"
    );
}

#[test]
fn divergence_detection_fires_on_exploding_config() {
    let rt = Runtime::native();
    let mut hp = hp(30);
    hp.lr_max = 30.0; // absurd learning rate
    hp.lr_min = 3.0;
    hp.eval_every = 0;
    let cfg = TrainCfg::new("micro", QuantRecipe::none(), hp);
    let r = train(&rt, &cfg).unwrap();
    assert!(r.diverged, "lr=30 run did not register as diverged");
    let at = r.diverged_at.unwrap();
    assert!(at <= 30, "diverged_at {at}");
    // early stop: no more steps after detection
    assert_eq!(r.losses.len(), at);
}

#[test]
fn w2_per_tensor_worse_than_w8() {
    let rt = Runtime::native();
    let w8 = train(&rt, &TrainCfg::new("micro", recipe("w8_pt"), hp(30))).unwrap();
    let w2 = train(&rt, &TrainCfg::new("micro", recipe("w2_pt"), hp(30))).unwrap();
    assert!(
        w2.final_loss() > w8.final_loss() + 0.02,
        "2-bit ({:.3}) should trail 8-bit ({:.3})",
        w2.final_loss(),
        w8.final_loss()
    );
}

#[test]
fn m2_per_tensor_8bit_unstable() {
    // paper Fig. 12: second-moment per-tensor quantization collapses tiny v
    // values into the zero bin and blows up the update
    let rt = Runtime::native();
    let base = train(&rt, &TrainCfg::new("micro", QuantRecipe::none(), hp(25))).unwrap();
    let m2 = train(&rt, &TrainCfg::new("micro", recipe("m2_8_pt"), hp(25))).unwrap();
    assert!(
        m2.diverged || m2.final_loss() > base.final_loss() + 0.5,
        "m2 quant unexpectedly healthy: {:.3} vs {:.3}",
        m2.final_loss(),
        base.final_loss()
    );
}

#[test]
fn wa_recipe_tracks_baseline() {
    // paper §4.5: W8 per-channel + A8 per-token stays close to fp32
    let _int8 = INT8_KNOB.lock().unwrap_or_else(|e| e.into_inner());
    let rt = Runtime::native();
    let base = train(&rt, &TrainCfg::new("micro", QuantRecipe::none(), hp(25))).unwrap();
    let wa = train(&rt, &TrainCfg::new("micro", recipe("w8a8"), hp(25))).unwrap();
    assert!(!wa.diverged);
    assert!(
        (wa.final_loss() - base.final_loss()).abs() < 0.1,
        "w8a8 {:.3} vs baseline {:.3}",
        wa.final_loss(),
        base.final_loss()
    );
}

#[test]
fn masked_eval_matches_manual_mean() {
    let rt = Runtime::native();
    let model = rt.model("micro").unwrap().clone();
    let state = init_state(&model, 5);
    let mut it = BatchIter::new(
        CorpusCfg::train_default(model.vocab),
        model.batch,
        model.seq,
    );
    let b = it.next_batch();
    let m = model.batch * model.seq;
    // mask out the second half of every row
    let mut mask = vec![1.0f32; m];
    for (i, v) in mask.iter_mut().enumerate() {
        if i % model.seq >= model.seq / 2 {
            *v = 0.0;
        }
    }
    let out = rt
        .eval_step(&model, &QuantRecipe::none(), &state.params, &b.x, &b.y, &mask)
        .unwrap();
    let manual: f64 = out
        .per_pos
        .iter()
        .zip(&mask)
        .map(|(&l, &w)| l as f64 * w as f64)
        .sum::<f64>()
        / mask.iter().map(|&w| w as f64).sum::<f64>();
    assert!((out.mean_nll - manual).abs() < 1e-9);
    assert_eq!(out.per_pos.len(), m);
}

#[test]
fn train_run_bit_identical_across_thread_counts() {
    // Full micro train runs — pinned to a single kernel thread vs forced
    // onto the parallel path with many threads — must produce bit-identical
    // loss curves, grad norms, validation losses, final params and Adam
    // moments. This is the determinism contract the parallel kernel
    // subsystem (persistent pool + fixed-shape tree reductions) is built on
    // (and what lets the golden fixtures stay unchanged). Quantization
    // active (w8a8) so the injection points run inside the parallel region
    // too — once with the exact-i32 accumulator (the default for w8a8's
    // packed GEMMs) and once with the knob-off f32 fold of the same integer
    // code products, so *both* accumulators carry the thread-invariance
    // contract.
    use qpretrain::backend::{kernels, native};

    let _int8 = INT8_KNOB.lock().unwrap_or_else(|e| e.into_inner());

    // panic-safe reset of the process-wide knobs (a mid-train panic must
    // not leave force_parallel / the int8 switch flipped for the rest of
    // the test binary)
    struct KnobReset;
    impl Drop for KnobReset {
        fn drop(&mut self) {
            kernels::force_parallel(false);
            kernels::set_threads(0);
            native::set_int8_gemm(native::int8_env_default());
        }
    }
    let _reset = KnobReset;

    let rt = Runtime::native();
    let run = |threads: usize, force: bool| {
        kernels::force_parallel(force);
        let mut h = hp(12);
        h.eval_every = 6;
        h.threads = threads; // applied per run by train_from
        let r = train(&rt, &TrainCfg::new("micro", recipe("w8a8"), h)).unwrap();
        kernels::force_parallel(false);
        r
    };

    // compare at the bit level: PartialEq on floats would let sign-of-zero
    // differences (the first symptom of a reordered reduction) slip through
    let f64_bits = |v: &[f64]| v.iter().map(|x| x.to_bits()).collect::<Vec<u64>>();
    let val_bits =
        |v: &[(usize, f64)]| v.iter().map(|(s, l)| (*s, l.to_bits())).collect::<Vec<_>>();
    let state_bits = |vv: &[Vec<f32>]| {
        vv.iter()
            .map(|t| t.iter().map(|x| x.to_bits()).collect::<Vec<u32>>())
            .collect::<Vec<_>>()
    };

    for int8 in [true, false] {
        native::set_int8_gemm(int8);
        let serial = run(1, false);
        let many = run(7, true); // force: even sub-threshold kernels fork
        let path = if int8 { "int8" } else { "qdq" };
        assert_eq!(
            f64_bits(&serial.losses),
            f64_bits(&many.losses),
            "{path}: loss curves diverged"
        );
        assert_eq!(
            f64_bits(&serial.gnorms),
            f64_bits(&many.gnorms),
            "{path}: grad norms diverged"
        );
        assert_eq!(
            val_bits(&serial.val),
            val_bits(&many.val),
            "{path}: validation losses diverged"
        );
        let (a, b) = (&serial.final_state, &many.final_state);
        assert_eq!(
            state_bits(&a.params),
            state_bits(&b.params),
            "{path}: final params diverged"
        );
        assert_eq!(state_bits(&a.m), state_bits(&b.m), "{path}: first moments diverged");
        assert_eq!(state_bits(&a.v), state_bits(&b.v), "{path}: second moments diverged");
    }
}

#[test]
fn w8a8g8_train_digest_invariant_across_threads_and_isa() {
    // The integer-backward recipe end to end: a full micro `w8a8g8` train
    // run must be bitwise invariant across (threads x ISA) — serial/scalar
    // lane emulation vs many-thread/vector path — in losses, grad norms,
    // validation, final params and both Adam moments. This is the
    // in-process mirror of the CI digest-diff matrix for the backward
    // packed path (gradient packing, the row-factored tn core, and the
    // packed-weight-cache nt reuse all run inside the measured region).
    use qpretrain::backend::{kernels, native};

    let _int8 = INT8_KNOB.lock().unwrap_or_else(|e| e.into_inner());

    struct KnobReset;
    impl Drop for KnobReset {
        fn drop(&mut self) {
            kernels::force_parallel(false);
            kernels::set_threads(0);
            native::set_int8_gemm(native::int8_env_default());
        }
    }
    let _reset = KnobReset;
    native::set_int8_gemm(true);

    let rt = Runtime::native();
    let run = |threads: usize, force: bool, simd: bool| {
        kernels::with_simd(simd, || {
            kernels::force_parallel(force);
            let mut h = hp(10);
            h.eval_every = 5;
            h.threads = threads;
            let r = train(&rt, &TrainCfg::new("micro", recipe("w8a8g8"), h)).unwrap();
            kernels::force_parallel(false);
            r
        })
    };
    let serial_scalar = run(1, false, false);
    let many_vector = run(7, true, true);

    let f64_bits = |v: &[f64]| v.iter().map(|x| x.to_bits()).collect::<Vec<u64>>();
    let val_bits =
        |v: &[(usize, f64)]| v.iter().map(|(s, l)| (*s, l.to_bits())).collect::<Vec<_>>();
    let state_bits = |vv: &[Vec<f32>]| {
        vv.iter()
            .map(|t| t.iter().map(|x| x.to_bits()).collect::<Vec<u32>>())
            .collect::<Vec<_>>()
    };
    assert_eq!(
        f64_bits(&serial_scalar.losses),
        f64_bits(&many_vector.losses),
        "w8a8g8: loss curves diverged across threads x ISA"
    );
    assert_eq!(
        f64_bits(&serial_scalar.gnorms),
        f64_bits(&many_vector.gnorms),
        "w8a8g8: grad norms diverged"
    );
    assert_eq!(
        val_bits(&serial_scalar.val),
        val_bits(&many_vector.val),
        "w8a8g8: validation losses diverged"
    );
    let (a, b) = (&serial_scalar.final_state, &many_vector.final_state);
    assert_eq!(
        state_bits(&a.params),
        state_bits(&b.params),
        "w8a8g8: final params diverged"
    );
    assert_eq!(state_bits(&a.m), state_bits(&b.m), "w8a8g8: first moments diverged");
    assert_eq!(state_bits(&a.v), state_bits(&b.v), "w8a8g8: second moments diverged");
}

#[test]
fn every_legacy_structure_runs_one_step() {
    // all 17 legacy structure names still parse (as recipe aliases) and
    // execute at 8 bits without error, producing finite loss
    let rt = Runtime::native();
    let model = rt.model("micro").unwrap().clone();
    let mut it = BatchIter::new(
        CorpusCfg::train_default(model.vocab),
        model.batch,
        model.seq,
    );
    let b = it.next_batch();
    for structure in QuantRecipe::LEGACY_ALIASES {
        let r = QuantRecipe::parse(structure)
            .unwrap()
            .with_bits(8, 8, 8, 8, 8)
            .unwrap();
        let mut state = init_state(&model, 3);
        let out = rt
            .train_step(&model, &r, &mut state, &b.x, &b.y, 1e-3, 1.0)
            .unwrap();
        assert!(out.loss.is_finite(), "{structure}: loss {}", out.loss);
        assert!(out.gnorm > 0.0, "{structure}: gnorm {}", out.gnorm);
    }
}

#[test]
fn full_combined_recipe_trains_with_decreasing_loss() {
    // the paper's full recipe — weights + activations + gradients + both
    // Adam moments quantized simultaneously — was inexpressible in the old
    // closed structure vocabulary; it must train end-to-end natively
    let rt = Runtime::native();
    let full = recipe("w4_pc+a8_ptok+g8_ptok+m1_8_pt+m2_8_pc");
    assert_eq!(full.legacy_structure(), None, "old API could express this?");
    let r = train(&rt, &TrainCfg::new("micro", full, hp(40))).unwrap();
    assert!(!r.diverged, "combined recipe diverged at {:?}", r.diverged_at);
    assert!(
        r.final_loss() < r.losses[0] - 0.3,
        "combined recipe did not learn: {:.3} -> {:.3}",
        r.losses[0],
        r.final_loss()
    );
    // smoothed curve decreases end-to-end
    let means = r.window_means(20);
    assert!(
        means.last().unwrap() < means.first().unwrap(),
        "smoothed loss not decreasing: {means:?}"
    );
}
