//! Cross-language golden tests: rust `quant` must match the python oracle
//! (`compile.kernels.ref`) bit-for-bit on the golden vectors emitted by
//! `make artifacts`.

use qpretrain::config::{Granularity, Scheme};
use qpretrain::quant::qdq_copy;
use qpretrain::util::{artifact_dir, npy};

fn golden_dir() -> std::path::PathBuf {
    artifact_dir().join("golden")
}

fn input_grid() -> (Vec<f32>, usize, usize) {
    // must match aot.write_goldens: ((31 i + 17 j) mod 257 - 128)/16
    let (rows, cols) = (64usize, 48usize);
    let mut v = Vec::with_capacity(rows * cols);
    for i in 0..rows {
        for j in 0..cols {
            v.push((((31 * i + 17 * j) % 257) as f32 - 128.0) / 16.0);
        }
    }
    (v, rows, cols)
}

#[test]
fn golden_input_matches_formula() {
    let path = golden_dir().join("input.npy");
    if !path.exists() {
        eprintln!("skipping: goldens not built (run `make artifacts`)");
        return;
    }
    let arr = npy::read_f32(&path).unwrap();
    let (want, rows, cols) = input_grid();
    assert_eq!(arr.shape, vec![rows, cols]);
    assert_eq!(arr.data, want, "python golden input grid differs from rust");
}

#[test]
fn rust_qdq_bitexact_with_python() {
    let gdir = golden_dir();
    if !gdir.exists() {
        eprintln!("skipping: goldens not built (run `make artifacts`)");
        return;
    }
    let (x, rows, cols) = input_grid();
    let cases = [
        ("pt", Granularity::PerTensor),
        ("ptok", Granularity::PerToken),
        ("pc", Granularity::PerChannel),
    ];
    for (short, gran) in cases {
        for bits in [2u32, 4, 8] {
            let want = npy::read_f32(gdir.join(format!("qdq_{short}_b{bits}.npy"))).unwrap();
            let got = qdq_copy(&x, rows, cols, Scheme::new(bits, gran));
            assert_eq!(
                got, want.data,
                "bit-exactness violated for {short} b{bits}"
            );
        }
    }
}

#[test]
fn rust_qdq_asym_bitexact_with_python() {
    let gdir = golden_dir();
    if !gdir.exists() {
        eprintln!("skipping: goldens not built");
        return;
    }
    let (x, rows, cols) = input_grid();
    for bits in [2u32, 4, 8] {
        let want = npy::read_f32(gdir.join(format!("qdq_ptok_asym_b{bits}.npy"))).unwrap();
        let got = qdq_copy(&x, rows, cols, Scheme::asym(bits, Granularity::PerToken));
        assert_eq!(got, want.data, "asym bit-exactness violated at b{bits}");
    }
    // positive (post-GELU-like) input
    let xp = npy::read_f32(gdir.join("input_pos.npy")).unwrap();
    for bits in [4u32, 8] {
        let want = npy::read_f32(gdir.join(format!("qdq_pos_ptok_asym_b{bits}.npy"))).unwrap();
        let got = qdq_copy(&xp.data, xp.shape[0], xp.shape[1], Scheme::asym(bits, Granularity::PerToken));
        assert_eq!(got, want.data, "positive asym bit-exactness at b{bits}");
    }
}
