//! Packed-int8 GEMM fast-path suite: exactness against the f32 qdq
//! reference oracle, determinism, and dispatch rules.
//!
//! Three contracts:
//!
//! * **Bitwise where f32 is exact** — when scales are exact powers of two
//!   and every intermediate f32 sum stays on the integer grid below 2^24,
//!   the qdq reference path commits no rounding, so the packed path (exact
//!   i32 accumulation + one rescale) must reproduce it bit for bit.
//! * **Bounded everywhere else** — on general data the two paths differ
//!   only by the f32 summation rounding the *reference* commits; the gap
//!   per element is bounded by a small multiple of the row magnitude.
//! * **Dispatch** — asymmetric activations, per-token weights, non-8-bit
//!   policies and unquantized operands must fall back to the qdq path
//!   (proved end-to-end: eval with the fast path enabled equals eval with
//!   it disabled, bitwise), while w8a8 takes the fast path and stays
//!   bit-identical across thread counts.
//!
//! Tests here mutate process-wide knobs (thread count, int8 switch), so
//! they serialize on a mutex and restore via RAII guards.

use std::sync::{Mutex, MutexGuard};

use qpretrain::backend::{kernels, native};
use qpretrain::config::{Granularity, QuantRecipe, TensorPolicy};
use qpretrain::data::{BatchIter, CorpusCfg};
use qpretrain::model::init_state;
use qpretrain::quant;
use qpretrain::runtime::Runtime;
use qpretrain::util::rng::Rng;

static KNOBS: Mutex<()> = Mutex::new(());

/// Serializes the test and restores every process-wide knob on drop.
struct Knobs(#[allow(dead_code)] MutexGuard<'static, ()>);

fn knobs() -> Knobs {
    Knobs(KNOBS.lock().unwrap_or_else(|e| e.into_inner()))
}

impl Drop for Knobs {
    fn drop(&mut self) {
        kernels::force_parallel(false);
        kernels::set_threads(0);
        // restore the env-resolved default, not a hard-coded `true`, so the
        // QPRETRAIN_INT8=off CI legs stay pinned between guarded sections
        native::set_int8_gemm(native::int8_env_default());
    }
}

fn bits(v: &[f32]) -> Vec<u32> {
    v.iter().map(|x| x.to_bits()).collect()
}

/// The f32 qdq reference for one linear: fake-quantize both operands, then
/// the plain f32 GEMM.
fn qdq_reference(
    x: &[f32],
    w: &[f32],
    m: usize,
    k: usize,
    n: usize,
    ap: TensorPolicy,
    wp: TensorPolicy,
) -> Vec<f32> {
    let xq = quant::qdq_copy(x, m, k, ap);
    let wq = quant::qdq_copy(w, k, n, wp);
    kernels::matmul(&xq, &wq, m, k, n)
}

/// The packed path for one linear: quantize once to lane-padded i8, i32
/// GEMM over the padded layout, rescale.
fn int8_path(
    x: &[f32],
    w: &[f32],
    m: usize,
    k: usize,
    n: usize,
    ap: TensorPolicy,
    wp: TensorPolicy,
) -> Vec<f32> {
    let xa = quant::pack_acts_i8(x, m, k, ap);
    let wq = quant::pack_weights_i8(w, k, n, wp);
    let ci = kernels::matmul_i8_packed(&xa, &wq);
    kernels::rescale_i32(&ci, &xa.scales, &wq.scales, m, n)
}

/// Integer-grid operands whose quant scales come out exactly 1.0: values
/// are integers in [-127, 127], with the per-row (acts) / per-column
/// (weights) abs-max pinned to exactly 127.
fn exact_operands(m: usize, k: usize, n: usize, seed: u64) -> (Vec<f32>, Vec<f32>) {
    let mut rng = Rng::new(seed);
    let mut x: Vec<f32> = (0..m * k).map(|_| (rng.below(201) as f32) - 100.0).collect();
    for r in 0..m {
        x[r * k] = 127.0; // row amax -> scale 127/127 = 1.0 exactly
    }
    let mut w: Vec<f32> = (0..k * n).map(|_| (rng.below(201) as f32) - 100.0).collect();
    for c in 0..n {
        w[c] = -127.0; // column amax -> scale 1.0 exactly
    }
    (x, w)
}

#[test]
fn int8_bitwise_equals_qdq_where_f32_is_exact() {
    let _g = knobs();
    // k small enough that every intermediate sum stays below 2^24:
    // |sum| <= k * 127 * 127 = 32 * 16129 ~ 5.2e5 << 1.6e7
    let (m, k, n) = (9, 32, 11);
    let (x, w) = exact_operands(m, k, n, 0x1A7);
    for (ap, wp) in [
        (
            TensorPolicy::new(8, Granularity::PerToken),
            TensorPolicy::new(8, Granularity::PerChannel),
        ),
        (
            TensorPolicy::new(8, Granularity::PerTensor),
            TensorPolicy::new(8, Granularity::PerTensor),
        ),
        (
            TensorPolicy::new(8, Granularity::PerToken),
            TensorPolicy::new(8, Granularity::PerTensor),
        ),
    ] {
        let reference = qdq_reference(&x, &w, m, k, n, ap, wp);
        for threads in [1usize, 2, 3, 7, 16] {
            kernels::set_threads(threads);
            kernels::force_parallel(threads > 1);
            let fast = int8_path(&x, &w, m, k, n, ap, wp);
            assert_eq!(
                bits(&fast),
                bits(&reference),
                "{ap:?}/{wp:?} at {threads} threads: packed path not bitwise exact"
            );
        }
        kernels::force_parallel(false);
    }
}

#[test]
fn int8_error_bounded_on_general_data() {
    let _g = knobs();
    let mut rng = Rng::new(0xE44);
    let (m, k, n) = (16, 48, 20);
    let x = rng.normal_vec(m * k, 0.0, 1.5);
    let w = rng.normal_vec(k * n, 0.0, 0.8);
    let ap = TensorPolicy::new(8, Granularity::PerToken);
    let wp = TensorPolicy::new(8, Granularity::PerChannel);
    let reference = qdq_reference(&x, &w, m, k, n, ap, wp);
    let fast = int8_path(&x, &w, m, k, n, ap, wp);
    for i in 0..m {
        let row_mag = reference[i * n..(i + 1) * n]
            .iter()
            .fold(0.0f32, |a, &v| a.max(v.abs()));
        for j in 0..n {
            let diff = (fast[i * n + j] - reference[i * n + j]).abs();
            // the only divergence is the f32 rounding the reference commits
            // over its k-term sums: a few ulps of the row magnitude
            assert!(
                diff <= 1e-4 * (row_mag + 1.0),
                "({i},{j}): int8 {} vs qdq {} (row magnitude {row_mag})",
                fast[i * n + j],
                reference[i * n + j]
            );
        }
    }
}

#[test]
fn matmul_i8_exact_vs_widened_reference() {
    let _g = knobs();
    let mut rng = Rng::new(0x18);
    let (m, k, n) = (7, 130, 9); // k straddles the K panel
    let a: Vec<i8> = (0..m * k).map(|_| (rng.below(255) as i32 - 127) as i8).collect();
    let b: Vec<i8> = (0..k * n).map(|_| (rng.below(255) as i32 - 127) as i8).collect();
    // widened i64 reference: i32 accumulation must be exact at these sizes
    let mut want = vec![0i64; m * n];
    for i in 0..m {
        for l in 0..k {
            for j in 0..n {
                want[i * n + j] += a[i * k + l] as i64 * b[l * n + j] as i64;
            }
        }
    }
    for threads in [1usize, 2, 3, 7, 16] {
        kernels::set_threads(threads);
        kernels::force_parallel(threads > 1);
        let got = kernels::matmul_i8(&a, &b, m, k, n);
        let got64: Vec<i64> = got.iter().map(|&v| v as i64).collect();
        assert_eq!(got64, want, "{threads} threads");
    }
}

#[test]
fn dispatch_rules() {
    use Granularity::*;
    let _g = knobs();
    native::set_int8_gemm(true); // the env default may be off on CI legs
    let ok_a = Some(TensorPolicy::new(8, PerToken));
    let ok_w = Some(TensorPolicy::new(8, PerChannel));
    assert!(native::int8_dispatch(ok_a, ok_w));
    assert!(native::int8_dispatch(
        Some(TensorPolicy::new(8, PerTensor)),
        Some(TensorPolicy::new(8, PerTensor))
    ));
    // asymmetric activations: zero-point cross terms -> qdq path
    assert!(!native::int8_dispatch(Some(TensorPolicy::asym(8, PerToken)), ok_w));
    // scale varies along the reduction axis -> qdq path
    assert!(!native::int8_dispatch(Some(TensorPolicy::new(8, PerChannel)), ok_w));
    assert!(!native::int8_dispatch(ok_a, Some(TensorPolicy::new(8, PerToken))));
    // other bit-widths / placement-only / unquantized operands -> qdq path
    assert!(!native::int8_dispatch(Some(TensorPolicy::new(4, PerToken)), ok_w));
    assert!(!native::int8_dispatch(ok_a, Some(TensorPolicy::new(0, PerChannel))));
    assert!(!native::int8_dispatch(None, ok_w));
    assert!(!native::int8_dispatch(ok_a, None));
    // the process-wide switch gates the i32-accumulator dispatch, but NOT
    // the structural eligibility (packing/caching is knob-independent)
    native::set_int8_gemm(false);
    assert!(!native::int8_dispatch(ok_a, ok_w));
    assert!(native::int8_structure(ok_a, ok_w));
    assert!(!native::int8_structure(Some(TensorPolicy::asym(8, PerToken)), ok_w));
    native::set_int8_gemm(true);
}

/// End-to-end fallback proof: for recipes outside the dispatch rule, a
/// forward pass with the fast path enabled is bitwise identical to one
/// with it disabled — i.e. the fast path never engaged.
#[test]
fn ineligible_recipes_fall_back_to_qdq_bitwise() {
    let _g = knobs();
    let rt = Runtime::native();
    let model = rt.model("micro").unwrap().clone();
    let state = init_state(&model, 21);
    let mut it = BatchIter::new(
        CorpusCfg::train_default(model.vocab),
        model.batch,
        model.seq,
    );
    let b = it.next_batch();
    let mask = vec![1.0f32; model.batch * model.seq];
    for spec in ["w8_pc+a8_ptok_asym", "w8_ptok+a8_ptok", "w4_pc+a8_ptok", "w8_pc"] {
        let recipe = QuantRecipe::parse(spec).unwrap();
        native::set_int8_gemm(true);
        let on = rt
            .eval_step(&model, &recipe, &state.params, &b.x, &b.y, &mask)
            .unwrap();
        native::set_int8_gemm(false);
        let off = rt
            .eval_step(&model, &recipe, &state.params, &b.x, &b.y, &mask)
            .unwrap();
        native::set_int8_gemm(true);
        assert_eq!(
            bits(&on.per_pos),
            bits(&off.per_pos),
            "{spec}: fast path engaged for an ineligible recipe"
        );
        assert_eq!(on.mean_nll.to_bits(), off.mean_nll.to_bits(), "{spec}");
    }
}

/// The eligible w8a8 recipe takes the fast path: its forward is close to
/// the knob-off leg (the f32 fold of the same integer code products —
/// rounding-level gap only, exactly zero at micro dims) and bit-identical
/// across thread counts.
#[test]
fn w8a8_fast_path_close_to_reference_and_thread_invariant() {
    let _g = knobs();
    let rt = Runtime::native();
    let model = rt.model("micro").unwrap().clone();
    let state = init_state(&model, 33);
    let mut it = BatchIter::new(
        CorpusCfg::train_default(model.vocab),
        model.batch,
        model.seq,
    );
    let b = it.next_batch();
    let mask = vec![1.0f32; model.batch * model.seq];
    let recipe = QuantRecipe::parse("w8a8").unwrap();

    native::set_int8_gemm(false);
    let reference = rt
        .eval_step(&model, &recipe, &state.params, &b.x, &b.y, &mask)
        .unwrap();
    native::set_int8_gemm(true);

    kernels::set_threads(1);
    let fast1 = rt
        .eval_step(&model, &recipe, &state.params, &b.x, &b.y, &mask)
        .unwrap();
    assert!(
        (fast1.mean_nll - reference.mean_nll).abs() < 0.02,
        "int8 {} vs qdq {}: more than rounding apart",
        fast1.mean_nll,
        reference.mean_nll
    );

    kernels::set_threads(7);
    kernels::force_parallel(true);
    let fast7 = rt
        .eval_step(&model, &recipe, &state.params, &b.x, &b.y, &mask)
        .unwrap();
    kernels::force_parallel(false);
    assert_eq!(
        bits(&fast1.per_pos),
        bits(&fast7.per_pos),
        "int8 fast path not thread-invariant"
    );
    assert_eq!(fast1.mean_nll.to_bits(), fast7.mean_nll.to_bits());
}

// ---------------------------------------------------------------------------
// backward packed-int8 path (PR 6)
// ---------------------------------------------------------------------------

/// Integer-grid operands scaled by an exact power of two: the quant scale
/// comes out exactly `2^e` (row amax pinned to `127 * 2^e`), every code is
/// nonzero, so neither packing nor the f32 qdq oracle commits rounding.
fn pow2_operands(rows: usize, cols: usize, e: i32, seed: u64) -> Vec<f32> {
    let mut rng = Rng::new(seed);
    let s = (e as f32).exp2();
    let mut v: Vec<f32> = (0..rows * cols)
        .map(|_| {
            let mag = 1.0 + rng.below(126) as f32; // [1, 126], never 0
            let sign = if rng.below(2) == 0 { -1.0 } else { 1.0 };
            sign * mag * s
        })
        .collect();
    for r in 0..rows {
        v[r * cols] = 127.0 * s;
    }
    v
}

/// Integer-grid data with the global abs-max pinned to 127: the per-tensor
/// quant scale is exactly 1.0, so the packed codes equal the values.
fn int_grid(rows: usize, cols: usize, seed: u64) -> Vec<f32> {
    let mut rng = Rng::new(seed);
    let mut v: Vec<f32> = (0..rows * cols)
        .map(|_| (rng.below(255) as f32) - 127.0)
        .collect();
    v[0] = 127.0;
    v
}

/// Pow2-scale gradients: the packed backward contractions must reproduce
/// the materialized-qdq f32 oracle bit for bit — the row-factored tn core
/// against `matmul_tn_acc` over the qdq values (per-token scales), and the
/// integer tn/nt cores + single rescale against the same oracle
/// (per-tensor scales; the oracle is exact at these reduction sizes).
#[test]
fn backward_packed_grads_bitwise_exact_on_pow2_scales() {
    use Granularity::*;
    let _g = knobs();
    let (m, k, n) = (12, 24, 18); // forward shape (m x k) @ (k x n)
    let x = pow2_operands(m, k, -3, 0xB0B);
    let dy = pow2_operands(m, n, 2, 0xB0C);
    let w = pow2_operands(k, n, -1, 0xB0D);

    // per-token acts x per-token grads -> the row-factored tn core
    let ap = TensorPolicy::new(8, PerToken);
    let gp = TensorPolicy::new(8, PerToken);
    let xa = quant::pack_acts_i8(&x, m, k, ap);
    let gq = quant::pack_grads_i8(&dy, m, n, gp);
    let xq = quant::qdq_copy(&x, m, k, ap);
    let dq = quant::qdq_copy(&dy, m, n, gp);
    let mut want = vec![0.0f32; k * n];
    kernels::matmul_tn_acc(&mut want, &xq, &dq, m, k, n);
    for threads in [1usize, 3, 7] {
        kernels::set_threads(threads);
        kernels::force_parallel(threads > 1);
        let mut got = vec![0.0f32; k * n];
        kernels::matmul_i8_tn_scaled_acc(&mut got, &xa, &gq);
        assert_eq!(
            bits(&got),
            bits(&want),
            "row-factored tn not bitwise exact at {threads} threads"
        );
    }
    kernels::force_parallel(false);

    // per-tensor everywhere -> integer cores + one rescale
    let pt = TensorPolicy::new(8, PerTensor);
    let xa = quant::pack_acts_i8(&x, m, k, pt);
    let gq = quant::pack_grads_i8(&dy, m, n, pt);
    let wp = quant::pack_weights_i8(&w, k, n, pt);
    let xq = quant::qdq_copy(&x, m, k, pt);
    let dq = quant::qdq_copy(&dy, m, n, pt);
    let wq = quant::qdq_copy(&w, k, n, pt);

    let mut want_dw = vec![0.0f32; k * n];
    kernels::matmul_tn_acc(&mut want_dw, &xq, &dq, m, k, n);
    let mut got_dw = vec![0.0f32; k * n];
    let ci = kernels::matmul_i8_tn_packed(&xa, &gq);
    kernels::rescale_i32_acc(&mut got_dw, &ci, &xa.scales, &gq.scales, k, n);
    assert_eq!(bits(&got_dw), bits(&want_dw), "integer tn + rescale");

    let want_dx = kernels::matmul_nt(&dq, &wq, m, n, k);
    let ci = kernels::matmul_i8_nt_packed(&gq, &wp);
    let got_dx = kernels::rescale_i32(&ci, &gq.scales, &wp.scales, m, k);
    assert_eq!(bits(&got_dx), bits(&want_dx), "integer nt + rescale");
}

/// The backward integer cores against a widened i64 triple loop: i32
/// accumulation must be exact, lane padding inert, at col counts that
/// straddle the 16-lane boundary, at every thread count.
#[test]
fn backward_i8_cores_match_widened_reference() {
    let _g = knobs();
    let pt = TensorPolicy::new(8, Granularity::PerTensor);
    let (m, k, n) = (9, 21, 19);
    let x = int_grid(m, k, 0x51);
    let g = int_grid(m, n, 0x52);
    let w = int_grid(k, n, 0x53);
    let xa = quant::pack_acts_i8(&x, m, k, pt);
    let gq = quant::pack_grads_i8(&g, m, n, pt);
    let wp = quant::pack_weights_i8(&w, k, n, pt);
    assert_eq!(xa.scales, vec![1.0f32]);
    assert_eq!(gq.scales, vec![1.0f32]);
    assert_eq!(wp.scales, vec![1.0f32]);

    // tn: c[l, j] = sum_r x[r, l] * g[r, j]
    let mut want_tn = vec![0i64; k * n];
    for r in 0..m {
        for l in 0..k {
            for j in 0..n {
                want_tn[l * n + j] += (x[r * k + l] as i64) * (g[r * n + j] as i64);
            }
        }
    }
    // nt: c[i, l] = sum_j g[i, j] * w[l, j]
    let mut want_nt = vec![0i64; m * k];
    for i in 0..m {
        for l in 0..k {
            for j in 0..n {
                want_nt[i * k + l] += (g[i * n + j] as i64) * (w[l * n + j] as i64);
            }
        }
    }
    for threads in [1usize, 2, 7] {
        kernels::set_threads(threads);
        kernels::force_parallel(threads > 1);
        let tn: Vec<i64> = kernels::matmul_i8_tn_packed(&xa, &gq)
            .iter()
            .map(|&v| v as i64)
            .collect();
        assert_eq!(tn, want_tn, "tn core at {threads} threads");
        let nt: Vec<i64> = kernels::matmul_i8_nt_packed(&gq, &wp)
            .iter()
            .map(|&v| v as i64)
            .collect();
        assert_eq!(nt, want_nt, "nt core at {threads} threads");
    }
}

/// One fresh-state micro train step under `spec` with the accumulator
/// knob pinned; returns the loss bits, the final state, and the packed
/// dispatch counters for exactly that step.
fn step_with_knob(
    rt: &Runtime,
    model: &qpretrain::runtime::ModelInfo,
    spec: &str,
    on: bool,
    b: &qpretrain::data::Batch,
) -> (u64, qpretrain::model::HostState, native::Int8Stats) {
    native::set_int8_gemm(on);
    let recipe = QuantRecipe::parse(spec).unwrap();
    let mut state = init_state(model, 77);
    let _ = native::take_int8_stats(); // drain counters from earlier tests
    let out = rt
        .train_step(model, &recipe, &mut state, &b.x, &b.y, 1e-3, 1.0)
        .unwrap();
    (out.loss.to_bits(), state, native::take_int8_stats())
}

/// Tentpole acceptance: under `w8a8g8` every per-layer linear (QKV / PROJ
/// / FC1 / FC2 x 2 micro layers) dispatches forward AND backward on
/// packed codes, weights are packed exactly once per train step, and the
/// step is bitwise invariant to the accumulator knob at micro dims (where
/// the f32 fold of the integer code products is exact).
#[test]
fn w8a8g8_train_step_dispatches_all_linears_packed() {
    let _g = knobs();
    let rt = Runtime::native();
    let model = rt.model("micro").unwrap().clone();
    let mut it = BatchIter::new(
        CorpusCfg::train_default(model.vocab),
        model.batch,
        model.seq,
    );
    let b = it.next_batch();
    let linears = 4 * 2;
    let (loss_on, state_on, stats_on) = step_with_knob(&rt, &model, "w8a8g8", true, &b);
    let (loss_off, state_off, stats_off) = step_with_knob(&rt, &model, "w8a8g8", false, &b);
    for (stats, leg) in [(stats_on, "i32"), (stats_off, "f32-fold")] {
        assert_eq!(stats.fwd_packed, linears, "forward packed ({leg})");
        assert_eq!(stats.tn_packed, linears, "weight-grad packed ({leg})");
        assert_eq!(stats.nt_packed, linears, "input-grad packed ({leg})");
        assert_eq!(stats.weight_packs, linears, "pack-once-per-step ({leg})");
    }
    assert_eq!(loss_on, loss_off, "w8a8g8 loss diverged across the knob");
    for (a, b2) in state_on.params.iter().zip(state_off.params.iter()) {
        assert_eq!(bits(a), bits(b2), "w8a8g8 params diverged across the knob");
    }
}

/// The per-tensor actgrad recipe drives the fully-integer backward (both
/// grad contractions on the i8 cores, input-grad consuming the quantized
/// gradient); the i32 and f32-fold accumulators must agree bit for bit at
/// micro dims.
#[test]
fn actgrad_recipe_integer_backward_knob_invariant() {
    let _g = knobs();
    let rt = Runtime::native();
    let model = rt.model("micro").unwrap().clone();
    let mut it = BatchIter::new(
        CorpusCfg::train_default(model.vocab),
        model.batch,
        model.seq,
    );
    let b = it.next_batch();
    let spec = "w8_pt+a8_pt+g8_pt_actgrad";
    let (loss_on, state_on, stats_on) = step_with_knob(&rt, &model, spec, true, &b);
    let (loss_off, state_off, stats_off) = step_with_knob(&rt, &model, spec, false, &b);
    for stats in [stats_on, stats_off] {
        assert_eq!(stats.fwd_packed, 8);
        assert_eq!(stats.tn_packed, 8);
        assert_eq!(stats.nt_packed, 8);
        assert_eq!(stats.weight_packs, 8);
    }
    assert_eq!(loss_on, loss_off, "{spec}: loss diverged across the knob");
    for (a, b2) in state_on.params.iter().zip(state_off.params.iter()) {
        assert_eq!(bits(a), bits(b2), "{spec}: params diverged across the knob");
    }
}

/// Recipes whose gradient policy is not int8-eligible (per-channel or
/// 4-bit grads) keep the packed forward but must fall back to the f32 qdq
/// reference for the whole backward — no grad contraction dispatches
/// packed, and the step stays bitwise invariant to the accumulator knob.
#[test]
fn ineligible_grad_recipes_fall_back_for_backward() {
    let _g = knobs();
    let rt = Runtime::native();
    let model = rt.model("micro").unwrap().clone();
    let mut it = BatchIter::new(
        CorpusCfg::train_default(model.vocab),
        model.batch,
        model.seq,
    );
    let b = it.next_batch();
    for spec in ["w8_pc+a8_ptok+g8_pc", "w8_pc+a8_ptok+g4_ptok"] {
        let (loss_on, state_on, stats_on) = step_with_knob(&rt, &model, spec, true, &b);
        let (loss_off, state_off, stats_off) = step_with_knob(&rt, &model, spec, false, &b);
        for stats in [stats_on, stats_off] {
            assert_eq!(stats.fwd_packed, 8, "{spec}: forward should stay packed");
            assert_eq!(stats.weight_packs, 8, "{spec}");
            assert_eq!(stats.tn_packed, 0, "{spec}: grad tn must fall back");
            assert_eq!(stats.nt_packed, 0, "{spec}: grad nt must fall back");
        }
        assert_eq!(loss_on, loss_off, "{spec}: loss diverged across the knob");
        for (a, b2) in state_on.params.iter().zip(state_off.params.iter()) {
            assert_eq!(bits(a), bits(b2), "{spec}: params diverged across the knob");
        }
    }
}
