//! Packed-int8 GEMM fast-path suite: exactness against the f32 qdq
//! reference oracle, determinism, and dispatch rules.
//!
//! Three contracts:
//!
//! * **Bitwise where f32 is exact** — when scales are exact powers of two
//!   and every intermediate f32 sum stays on the integer grid below 2^24,
//!   the qdq reference path commits no rounding, so the packed path (exact
//!   i32 accumulation + one rescale) must reproduce it bit for bit.
//! * **Bounded everywhere else** — on general data the two paths differ
//!   only by the f32 summation rounding the *reference* commits; the gap
//!   per element is bounded by a small multiple of the row magnitude.
//! * **Dispatch** — asymmetric activations, per-token weights, non-8-bit
//!   policies and unquantized operands must fall back to the qdq path
//!   (proved end-to-end: eval with the fast path enabled equals eval with
//!   it disabled, bitwise), while w8a8 takes the fast path and stays
//!   bit-identical across thread counts.
//!
//! Tests here mutate process-wide knobs (thread count, int8 switch), so
//! they serialize on a mutex and restore via RAII guards.

use std::sync::{Mutex, MutexGuard};

use qpretrain::backend::{kernels, native};
use qpretrain::config::{Granularity, QuantRecipe, TensorPolicy};
use qpretrain::data::{BatchIter, CorpusCfg};
use qpretrain::model::init_state;
use qpretrain::quant;
use qpretrain::runtime::Runtime;
use qpretrain::util::rng::Rng;

static KNOBS: Mutex<()> = Mutex::new(());

/// Serializes the test and restores every process-wide knob on drop.
struct Knobs(#[allow(dead_code)] MutexGuard<'static, ()>);

fn knobs() -> Knobs {
    Knobs(KNOBS.lock().unwrap_or_else(|e| e.into_inner()))
}

impl Drop for Knobs {
    fn drop(&mut self) {
        kernels::force_parallel(false);
        kernels::set_threads(0);
        native::set_int8_gemm(true);
    }
}

fn bits(v: &[f32]) -> Vec<u32> {
    v.iter().map(|x| x.to_bits()).collect()
}

/// The f32 qdq reference for one linear: fake-quantize both operands, then
/// the plain f32 GEMM.
fn qdq_reference(
    x: &[f32],
    w: &[f32],
    m: usize,
    k: usize,
    n: usize,
    ap: TensorPolicy,
    wp: TensorPolicy,
) -> Vec<f32> {
    let xq = quant::qdq_copy(x, m, k, ap);
    let wq = quant::qdq_copy(w, k, n, wp);
    kernels::matmul(&xq, &wq, m, k, n)
}

/// The packed path for one linear: quantize once to lane-padded i8, i32
/// GEMM over the padded layout, rescale.
fn int8_path(
    x: &[f32],
    w: &[f32],
    m: usize,
    k: usize,
    n: usize,
    ap: TensorPolicy,
    wp: TensorPolicy,
) -> Vec<f32> {
    let xa = quant::pack_acts_i8(x, m, k, ap);
    let wq = quant::pack_weights_i8(w, k, n, wp);
    let ci = kernels::matmul_i8_packed(&xa, &wq);
    kernels::rescale_i32(&ci, &xa.scales, &wq.scales, m, n)
}

/// Integer-grid operands whose quant scales come out exactly 1.0: values
/// are integers in [-127, 127], with the per-row (acts) / per-column
/// (weights) abs-max pinned to exactly 127.
fn exact_operands(m: usize, k: usize, n: usize, seed: u64) -> (Vec<f32>, Vec<f32>) {
    let mut rng = Rng::new(seed);
    let mut x: Vec<f32> = (0..m * k).map(|_| (rng.below(201) as f32) - 100.0).collect();
    for r in 0..m {
        x[r * k] = 127.0; // row amax -> scale 127/127 = 1.0 exactly
    }
    let mut w: Vec<f32> = (0..k * n).map(|_| (rng.below(201) as f32) - 100.0).collect();
    for c in 0..n {
        w[c] = -127.0; // column amax -> scale 1.0 exactly
    }
    (x, w)
}

#[test]
fn int8_bitwise_equals_qdq_where_f32_is_exact() {
    let _g = knobs();
    // k small enough that every intermediate sum stays below 2^24:
    // |sum| <= k * 127 * 127 = 32 * 16129 ~ 5.2e5 << 1.6e7
    let (m, k, n) = (9, 32, 11);
    let (x, w) = exact_operands(m, k, n, 0x1A7);
    for (ap, wp) in [
        (
            TensorPolicy::new(8, Granularity::PerToken),
            TensorPolicy::new(8, Granularity::PerChannel),
        ),
        (
            TensorPolicy::new(8, Granularity::PerTensor),
            TensorPolicy::new(8, Granularity::PerTensor),
        ),
        (
            TensorPolicy::new(8, Granularity::PerToken),
            TensorPolicy::new(8, Granularity::PerTensor),
        ),
    ] {
        let reference = qdq_reference(&x, &w, m, k, n, ap, wp);
        for threads in [1usize, 2, 3, 7, 16] {
            kernels::set_threads(threads);
            kernels::force_parallel(threads > 1);
            let fast = int8_path(&x, &w, m, k, n, ap, wp);
            assert_eq!(
                bits(&fast),
                bits(&reference),
                "{ap:?}/{wp:?} at {threads} threads: packed path not bitwise exact"
            );
        }
        kernels::force_parallel(false);
    }
}

#[test]
fn int8_error_bounded_on_general_data() {
    let _g = knobs();
    let mut rng = Rng::new(0xE44);
    let (m, k, n) = (16, 48, 20);
    let x = rng.normal_vec(m * k, 0.0, 1.5);
    let w = rng.normal_vec(k * n, 0.0, 0.8);
    let ap = TensorPolicy::new(8, Granularity::PerToken);
    let wp = TensorPolicy::new(8, Granularity::PerChannel);
    let reference = qdq_reference(&x, &w, m, k, n, ap, wp);
    let fast = int8_path(&x, &w, m, k, n, ap, wp);
    for i in 0..m {
        let row_mag = reference[i * n..(i + 1) * n]
            .iter()
            .fold(0.0f32, |a, &v| a.max(v.abs()));
        for j in 0..n {
            let diff = (fast[i * n + j] - reference[i * n + j]).abs();
            // the only divergence is the f32 rounding the reference commits
            // over its k-term sums: a few ulps of the row magnitude
            assert!(
                diff <= 1e-4 * (row_mag + 1.0),
                "({i},{j}): int8 {} vs qdq {} (row magnitude {row_mag})",
                fast[i * n + j],
                reference[i * n + j]
            );
        }
    }
}

#[test]
fn matmul_i8_exact_vs_widened_reference() {
    let _g = knobs();
    let mut rng = Rng::new(0x18);
    let (m, k, n) = (7, 130, 9); // k straddles the K panel
    let a: Vec<i8> = (0..m * k).map(|_| (rng.below(255) as i32 - 127) as i8).collect();
    let b: Vec<i8> = (0..k * n).map(|_| (rng.below(255) as i32 - 127) as i8).collect();
    // widened i64 reference: i32 accumulation must be exact at these sizes
    let mut want = vec![0i64; m * n];
    for i in 0..m {
        for l in 0..k {
            for j in 0..n {
                want[i * n + j] += a[i * k + l] as i64 * b[l * n + j] as i64;
            }
        }
    }
    for threads in [1usize, 2, 3, 7, 16] {
        kernels::set_threads(threads);
        kernels::force_parallel(threads > 1);
        let got = kernels::matmul_i8(&a, &b, m, k, n);
        let got64: Vec<i64> = got.iter().map(|&v| v as i64).collect();
        assert_eq!(got64, want, "{threads} threads");
    }
}

#[test]
fn dispatch_rules() {
    use Granularity::*;
    let _g = knobs();
    let ok_a = Some(TensorPolicy::new(8, PerToken));
    let ok_w = Some(TensorPolicy::new(8, PerChannel));
    assert!(native::int8_dispatch(ok_a, ok_w));
    assert!(native::int8_dispatch(
        Some(TensorPolicy::new(8, PerTensor)),
        Some(TensorPolicy::new(8, PerTensor))
    ));
    // asymmetric activations: zero-point cross terms -> qdq path
    assert!(!native::int8_dispatch(Some(TensorPolicy::asym(8, PerToken)), ok_w));
    // scale varies along the reduction axis -> qdq path
    assert!(!native::int8_dispatch(Some(TensorPolicy::new(8, PerChannel)), ok_w));
    assert!(!native::int8_dispatch(ok_a, Some(TensorPolicy::new(8, PerToken))));
    // other bit-widths / placement-only / unquantized operands -> qdq path
    assert!(!native::int8_dispatch(Some(TensorPolicy::new(4, PerToken)), ok_w));
    assert!(!native::int8_dispatch(ok_a, Some(TensorPolicy::new(0, PerChannel))));
    assert!(!native::int8_dispatch(None, ok_w));
    assert!(!native::int8_dispatch(ok_a, None));
    // the process-wide switch gates everything
    native::set_int8_gemm(false);
    assert!(!native::int8_dispatch(ok_a, ok_w));
    native::set_int8_gemm(true);
}

/// End-to-end fallback proof: for recipes outside the dispatch rule, a
/// forward pass with the fast path enabled is bitwise identical to one
/// with it disabled — i.e. the fast path never engaged.
#[test]
fn ineligible_recipes_fall_back_to_qdq_bitwise() {
    let _g = knobs();
    let rt = Runtime::native();
    let model = rt.model("micro").unwrap().clone();
    let state = init_state(&model, 21);
    let mut it = BatchIter::new(
        CorpusCfg::train_default(model.vocab),
        model.batch,
        model.seq,
    );
    let b = it.next_batch();
    let mask = vec![1.0f32; model.batch * model.seq];
    for spec in ["w8_pc+a8_ptok_asym", "w8_ptok+a8_ptok", "w4_pc+a8_ptok", "w8_pc"] {
        let recipe = QuantRecipe::parse(spec).unwrap();
        native::set_int8_gemm(true);
        let on = rt
            .eval_step(&model, &recipe, &state.params, &b.x, &b.y, &mask)
            .unwrap();
        native::set_int8_gemm(false);
        let off = rt
            .eval_step(&model, &recipe, &state.params, &b.x, &b.y, &mask)
            .unwrap();
        native::set_int8_gemm(true);
        assert_eq!(
            bits(&on.per_pos),
            bits(&off.per_pos),
            "{spec}: fast path engaged for an ineligible recipe"
        );
        assert_eq!(on.mean_nll.to_bits(), off.mean_nll.to_bits(), "{spec}");
    }
}

/// The eligible w8a8 recipe takes the fast path: its forward is close to
/// the qdq reference (rounding-level gap only) and bit-identical across
/// thread counts.
#[test]
fn w8a8_fast_path_close_to_reference_and_thread_invariant() {
    let _g = knobs();
    let rt = Runtime::native();
    let model = rt.model("micro").unwrap().clone();
    let state = init_state(&model, 33);
    let mut it = BatchIter::new(
        CorpusCfg::train_default(model.vocab),
        model.batch,
        model.seq,
    );
    let b = it.next_batch();
    let mask = vec![1.0f32; model.batch * model.seq];
    let recipe = QuantRecipe::parse("w8a8").unwrap();

    native::set_int8_gemm(false);
    let reference = rt
        .eval_step(&model, &recipe, &state.params, &b.x, &b.y, &mask)
        .unwrap();
    native::set_int8_gemm(true);

    kernels::set_threads(1);
    let fast1 = rt
        .eval_step(&model, &recipe, &state.params, &b.x, &b.y, &mask)
        .unwrap();
    assert!(
        (fast1.mean_nll - reference.mean_nll).abs() < 0.02,
        "int8 {} vs qdq {}: more than rounding apart",
        fast1.mean_nll,
        reference.mean_nll
    );

    kernels::set_threads(7);
    kernels::force_parallel(true);
    let fast7 = rt
        .eval_step(&model, &recipe, &state.params, &b.x, &b.y, &mask)
        .unwrap();
    kernels::force_parallel(false);
    assert_eq!(
        bits(&fast1.per_pos),
        bits(&fast7.per_pos),
        "int8 fast path not thread-invariant"
    );
    assert_eq!(fast1.mean_nll.to_bits(), fast7.mean_nll.to_bits());
}
