//! Integration tests over the default (native) runtime: the training loop,
//! evaluation, few-shot scoring, probes, PTQ and checkpointing — exercised
//! end-to-end with no AOT artifacts, no Python, no PJRT. These are the
//! tests that prove the layers compose on a clean machine.

use qpretrain::config::{QuantRecipe, TrainHp};
use qpretrain::model::init_state;
use qpretrain::runtime::Runtime;
use qpretrain::train::{train, TrainCfg};

fn hp(steps: usize) -> TrainHp {
    TrainHp {
        steps,
        eval_every: steps,
        eval_batches: 2,
        log_every: usize::MAX,
        ..TrainHp::default()
    }
}

#[test]
fn native_models_cover_all_structures() {
    let rt = Runtime::open_default().unwrap();
    let m = rt.model("micro").unwrap();
    assert_eq!(m.params.len(), 16);
    assert_eq!(m.vocab, 64);
    // every artifact-era structure name parses into a recipe alias
    for s in QuantRecipe::LEGACY_ALIASES {
        QuantRecipe::parse(s).unwrap();
    }
}

#[test]
fn train_eval_fewshot_end_to_end() {
    let rt = Runtime::native();
    let model = rt.model("micro").unwrap().clone();
    let cfg = TrainCfg::new("micro", QuantRecipe::none(), hp(50));
    let r = train(&rt, &cfg).unwrap();
    assert!(!r.diverged);
    assert!(r.final_loss() < r.losses[0] - 1.0, "no learning");

    let ppl = qpretrain::eval::perplexity_suite(
        &rt,
        &QuantRecipe::none(),
        &model,
        &r.final_state.params,
        2,
    )
    .unwrap();
    assert_eq!(ppl.len(), 4);
    for (k, v) in &ppl {
        assert!(v.is_finite() && *v > 1.0, "{k}: {v}");
    }
    // in-domain should beat the shifted transition structure
    assert!(ppl["synthwiki103"] < ppl["synthptb"] * 1.5);

    let fs = qpretrain::eval::fewshot_suite(
        &rt,
        &QuantRecipe::none(),
        &model,
        &r.final_state.params,
        8,
        2,
    )
    .unwrap();
    assert_eq!(fs.per_task.len(), 10);
    for (t, acc, _) in &fs.per_task {
        assert!((0.0..=1.0).contains(acc), "{}: {acc}", t.name());
    }
    assert!((0.0..=1.0).contains(&fs.average));
}

#[test]
fn ptq_weights_degrade_monotonically() {
    let rt = Runtime::native();
    let model = rt.model("micro").unwrap().clone();
    let cfg = TrainCfg::new("micro", QuantRecipe::none(), hp(50));
    let r = train(&rt, &cfg).unwrap();
    use qpretrain::config::Granularity::PerChannel;
    let fp = qpretrain::eval::perplexity_suite(
        &rt,
        &QuantRecipe::none(),
        &model,
        &r.final_state.params,
        2,
    )
    .unwrap()["synthwiki103"];
    let p8 = qpretrain::ptq::ptq_weights_ppl(&rt, &model, &r.final_state, 8, PerChannel, 2)
        .unwrap()["synthwiki103"];
    let p2 = qpretrain::ptq::ptq_weights_ppl(&rt, &model, &r.final_state, 2, PerChannel, 2)
        .unwrap()["synthwiki103"];
    assert!(p8 < p2, "8-bit PTQ ({p8:.2}) must beat 2-bit ({p2:.2})");
    assert!(p8 < fp * 1.2, "8-bit PTQ ({p8:.2}) should be near fp ({fp:.2})");
}

#[test]
fn probes_and_analysis_run() {
    let rt = Runtime::native();
    let model = rt.model("micro").unwrap().clone();
    let state = init_state(&model, 3);

    let stats = qpretrain::analysis::activation_stats(&rt, &model, &state.params).unwrap();
    assert_eq!(stats.proj_in_channel_max.len(), model.d_model);
    assert_eq!(stats.fc2_in_channel_max.len(), model.d_ff);
    assert!(stats.fc2_in_max.is_finite());

    let schemes = vec![(
        "int8 ptok".to_string(),
        qpretrain::config::TensorPolicy::new(8, qpretrain::config::Granularity::PerToken),
    )];
    let g = qpretrain::analysis::gradient_stats(&rt, &model, &state.params, &schemes).unwrap();
    assert!(g.weight_grad_hist.total() > 0);
    assert!((0.0..=1.0).contains(&g.weight_grad_sparsity));
    assert!(g.quant_rel_err[0].1.is_finite());
}

#[test]
fn sharpness_analysis_runs_on_trained_model() {
    let rt = Runtime::native();
    let model = rt.model("micro").unwrap().clone();
    let cfg = TrainCfg::new("micro", QuantRecipe::none(), hp(20));
    let r = train(&rt, &cfg).unwrap();
    let c = qpretrain::analysis::m_sharpness(
        &rt,
        &QuantRecipe::none(),
        &model,
        &r.final_state,
        &[0.01, 0.1],
        2,
        1,
    )
    .unwrap();
    assert!(c.base_loss.is_finite());
    assert_eq!(c.sharpness.len(), 2);
    // larger perturbations hurt at least as much
    assert!(c.sharpness[1] >= c.sharpness[0] - 1e-6);
}

#[test]
fn checkpoint_roundtrip_through_training() {
    let rt = Runtime::native();
    let model = rt.model("micro").unwrap().clone();
    let dir = std::env::temp_dir().join("qpretrain_native_ckpt");
    std::fs::create_dir_all(&dir).unwrap();
    let mut cfg = TrainCfg::new("micro", QuantRecipe::none(), hp(10));
    cfg.out_dir = Some(dir.clone());
    cfg.save_ckpt = true;
    let r = train(&rt, &cfg).unwrap();
    let loaded = qpretrain::model::load_checkpoint(&dir.join("final.ckpt"), &model).unwrap();
    assert_eq!(loaded.step, 10);
    assert_eq!(loaded.params, r.final_state.params);
    assert_eq!(loaded.m, r.final_state.m);
    std::fs::remove_dir_all(dir).ok();
}

#[test]
fn resume_continues_from_checkpoint_step() {
    let rt = Runtime::native();
    let cfg = TrainCfg::new("micro", QuantRecipe::none(), hp(6));
    let first = train(&rt, &cfg).unwrap();
    assert_eq!(first.final_state.step, 6);
    let resumed =
        qpretrain::train::train_from(&rt, &cfg, Some(first.final_state.clone())).unwrap();
    assert_eq!(resumed.final_state.step, 12);
    // resumed run continues improving (same config, fresh data offset)
    assert!(resumed.final_loss() < first.losses[0]);
}

#[test]
fn deterministic_training_same_seed() {
    let rt = Runtime::native();
    let a = train(&rt, &TrainCfg::new("micro", QuantRecipe::none(), hp(8))).unwrap();
    let b = train(&rt, &TrainCfg::new("micro", QuantRecipe::none(), hp(8))).unwrap();
    assert_eq!(a.losses, b.losses, "same seed must give identical losses");
    let mut hp2 = hp(8);
    hp2.seed += 1;
    let c = train(&rt, &TrainCfg::new("micro", QuantRecipe::none(), hp2)).unwrap();
    assert_ne!(a.losses, c.losses);
}

#[test]
fn quantized_training_recipes_learn() {
    // w8 per-channel (including through the legacy alias + bit-override
    // path) and the w8a8 recipe all reduce loss within 25 steps
    let rt = Runtime::native();
    let alias = QuantRecipe::parse("w_pc_pallas")
        .unwrap()
        .with_bits(8, 0, 0, 0, 0)
        .unwrap();
    assert_eq!(alias, QuantRecipe::parse("w8_pc").unwrap());
    let recipes = [
        ("w8_pc", QuantRecipe::parse("w8_pc").unwrap()),
        ("w_pc_pallas+8b", alias),
        ("w8a8", QuantRecipe::parse("w8a8").unwrap()),
    ];
    for (name, recipe) in recipes {
        let cfg = TrainCfg::new("micro", recipe, hp(25));
        let r = train(&rt, &cfg).unwrap();
        assert!(!r.diverged, "{name} diverged");
        assert!(
            r.final_loss() < r.losses[0] - 0.5,
            "{name}: no learning ({:.3} -> {:.3})",
            r.losses[0],
            r.final_loss()
        );
    }
}
